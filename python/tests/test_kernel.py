"""L1 correctness: each Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes, dtypes, aggregation operators, activations, and
edge-count occupancy (n_valid masking) — the dimensions the rust compiler
actually varies when it emits Tiling Blocks.
"""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import gemm, gemm_bias_act, spdmm, sddmm, vecadd
from compile.kernels import ref

SET = dict(deadline=None, max_examples=20)

dims = st.sampled_from([8, 16, 32, 48, 64])
small_dims = st.sampled_from([4, 8, 16, 32])


def rand(rng, *shape, dtype="float32"):
    return jnp.asarray(rng.normal(size=shape).astype(dtype))


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == "bfloat16" else dict(
        rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# GEMM mode
# ---------------------------------------------------------------------------

@settings(**SET)
@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_gemm_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    h, w = rand(rng, m, k), rand(rng, k, n)
    np.testing.assert_allclose(gemm(h, w), ref.gemm_ref(h, w), **tol("f32"))


@settings(**SET)
@given(
    m=dims, k=small_dims, n=small_dims,
    act=st.sampled_from(["none", "relu", "lrelu", "prelu", "exp"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gemm_bias_act_matches_ref(m, k, n, act, seed):
    rng = np.random.default_rng(seed)
    h, w, b = rand(rng, m, k), rand(rng, k, n), rand(rng, n)
    got = gemm_bias_act(h, w, b, act=act)
    want = ref.gemm_bias_act_ref(h, w, b, act)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_gemm_dtypes(dtype):
    rng = np.random.default_rng(7)
    h = jnp.asarray(rng.normal(size=(32, 32))).astype(dtype)
    w = jnp.asarray(rng.normal(size=(32, 16))).astype(dtype)
    got = gemm(h, w)
    assert got.dtype == jnp.dtype(dtype)
    np.testing.assert_allclose(
        got.astype("float32"), ref.gemm_ref(h, w).astype("float32"),
        **tol(dtype))


def test_gemm_block_sweep():
    """Different BlockSpec tilings must not change the numbers."""
    rng = np.random.default_rng(3)
    h, w = rand(rng, 64, 32), rand(rng, 32, 64)
    base = ref.gemm_ref(h, w)
    for bm in (16, 32, 64):
        for bn in (16, 32, 64):
            np.testing.assert_allclose(
                gemm(h, w, bm=bm, bn=bn), base, rtol=1e-5, atol=1e-5)


def test_gemm_rejects_ragged():
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError):
        gemm(rand(rng, 60, 16), rand(rng, 16, 64), bm=16, bn=64).block_until_ready()


# ---------------------------------------------------------------------------
# SpDMM mode (Aggregate)
# ---------------------------------------------------------------------------

@settings(**SET)
@given(
    n=st.sampled_from([16, 32, 64]),
    e=st.sampled_from([16, 64, 128]),
    f=st.sampled_from([4, 16, 32]),
    occupancy=st.floats(0.0, 1.0),
    aggop=st.sampled_from(["sum", "max", "min", "mean"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_spdmm_matches_ref(n, e, f, occupancy, aggop, seed):
    rng = np.random.default_rng(seed)
    src = jnp.asarray(rng.integers(0, n, e).astype("int32"))
    dst = jnp.asarray(rng.integers(0, n, e).astype("int32"))
    w = rand(rng, e)
    nv = jnp.asarray([int(e * occupancy)], dtype="int32")
    h = rand(rng, n, f)
    got = spdmm(src, dst, w, nv, h, n_out=n, aggop=aggop)
    want = ref.spdmm_ref(src, dst, w, nv, h, n, aggop)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_spdmm_empty_tile():
    """A fully padded subshard (0 valid edges) must produce zeros."""
    n, e, f = 16, 32, 8
    src = jnp.zeros(e, "int32")
    dst = jnp.zeros(e, "int32")
    w = jnp.ones(e, "float32")
    nv = jnp.asarray([0], "int32")
    h = jnp.ones((n, f), "float32")
    for aggop in ("sum", "max", "min"):
        out = spdmm(src, dst, w, nv, h, n_out=n, aggop=aggop)
        np.testing.assert_array_equal(out, np.zeros((n, f)))


def test_spdmm_self_loop_accumulation():
    """Many edges landing on one destination must accumulate, not race —
    the kernel analogue of the hardware RAW Unit's guarantee."""
    n, e, f = 8, 64, 4
    src = jnp.asarray(np.arange(e) % n, dtype="int32")
    dst = jnp.zeros(e, "int32")  # all edges hit vertex 0
    w = jnp.ones(e, "float32")
    nv = jnp.asarray([e], "int32")
    h = jnp.ones((n, f), "float32")
    out = spdmm(src, dst, w, nv, h, n_out=n, aggop="sum")
    np.testing.assert_allclose(out[0], np.full(f, e), rtol=1e-6)
    np.testing.assert_allclose(out[1:], np.zeros((n - 1, f)), atol=0)


# ---------------------------------------------------------------------------
# SDDMM mode (Vector-Inner)
# ---------------------------------------------------------------------------

@settings(**SET)
@given(
    n=st.sampled_from([16, 32, 64]),
    e=st.sampled_from([16, 64, 128]),
    f=st.sampled_from([4, 16, 32]),
    occupancy=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_sddmm_matches_ref(n, e, f, occupancy, seed):
    rng = np.random.default_rng(seed)
    src = jnp.asarray(rng.integers(0, n, e).astype("int32"))
    dst = jnp.asarray(rng.integers(0, n, e).astype("int32"))
    nv = jnp.asarray([int(e * occupancy)], dtype="int32")
    h = rand(rng, n, f)
    got = sddmm(src, dst, nv, h, h)
    want = ref.sddmm_ref(src, dst, nv, h, h)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_sddmm_distinct_tiles():
    """Left/right tiles differ (Alg. 7: H_in(i,k) vs H_in(j,k))."""
    rng = np.random.default_rng(11)
    n, e, f = 16, 32, 8
    src = jnp.asarray(rng.integers(0, n, e).astype("int32"))
    dst = jnp.asarray(rng.integers(0, n, e).astype("int32"))
    nv = jnp.asarray([e], "int32")
    hl, hr = rand(rng, n, f), rand(rng, n, f)
    got = sddmm(src, dst, nv, hl, hr)
    want = ref.sddmm_ref(src, dst, nv, hl, hr)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_sddmm_padded_tail_is_zero():
    rng = np.random.default_rng(5)
    n, e = 8, 16
    src = jnp.asarray(rng.integers(0, n, e).astype("int32"))
    dst = jnp.asarray(rng.integers(0, n, e).astype("int32"))
    nv = jnp.asarray([5], "int32")
    h = rand(rng, n, 4)
    out = np.asarray(sddmm(src, dst, nv, h, h))
    assert np.all(out[5:] == 0.0)


# ---------------------------------------------------------------------------
# Vector-Add mode
# ---------------------------------------------------------------------------

@settings(**SET)
@given(
    m=dims, f=small_dims,
    act=st.sampled_from(["none", "relu"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_vecadd_matches_ref(m, f, act, seed):
    rng = np.random.default_rng(seed)
    a, b = rand(rng, m, f), rand(rng, m, f)
    np.testing.assert_allclose(
        vecadd(a, b, act=act), ref.vecadd_ref(a, b, act),
        rtol=1e-6, atol=1e-6)


def test_vecadd_shape_mismatch_rejected():
    rng = np.random.default_rng(0)
    with pytest.raises(AssertionError):
        vecadd(rand(rng, 16, 4), rand(rng, 16, 8))
