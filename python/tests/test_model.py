"""L2 correctness: GNN layer math, compiler-order equivalence, fusion."""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

SET = dict(deadline=None, max_examples=15)


def make_graph(rng, n, e, nv=None):
    src = jnp.asarray(rng.integers(0, n, e).astype("int32"))
    dst = jnp.asarray(rng.integers(0, n, e).astype("int32"))
    ew = jnp.asarray(rng.normal(size=e).astype("float32"))
    nv = jnp.asarray([e if nv is None else nv], dtype="int32")
    return src, dst, ew, nv


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype("float32"))


# ---------------------------------------------------------------------------
# Computation-order optimization (paper Theorems 1-2)
# ---------------------------------------------------------------------------

@settings(**SET)
@given(seed=st.integers(0, 2**31 - 1),
       n=st.sampled_from([16, 32]), e=st.sampled_from([32, 128]))
def test_aggregate_linear_exchange(seed, n, e):
    """Sum aggregation is linear => (A H) W == A (H W) (Theorem 1)."""
    rng = np.random.default_rng(seed)
    src, dst, ew, nv = make_graph(rng, n, e)
    h = rand(rng, n, 16)
    w = rand(rng, 16, 8)
    b = jnp.zeros(8, "float32")
    al = model.gcn_layer(h, src, dst, ew, nv, w, b, act="none", order="AL")
    la = model.gcn_layer(h, src, dst, ew, nv, w, b, act="none", order="LA")
    np.testing.assert_allclose(al, la, rtol=1e-3, atol=1e-3)


def test_max_aggregation_not_exchangeable():
    """Max is non-linear: exchanging the order changes results, which is
    why the compiler's Alg. 5 checks linearity before exchanging."""
    rng = np.random.default_rng(9)
    n, e = 16, 64
    src, dst, ew, nv = make_graph(rng, n, e)
    ew = jnp.abs(ew)
    h = rand(rng, n, 8)
    w = rand(rng, 8, 8)
    agg_first = ref.spdmm_ref(src, dst, ew, nv, h, n, "max") @ w
    lin_first = ref.spdmm_ref(src, dst, ew, nv, h @ w, n, "max")
    assert not np.allclose(agg_first, lin_first, rtol=1e-3, atol=1e-3)


def test_sgc_order_equivalence():
    """SGC: A^k (X W) == (A^k X) W with zero bias (Fig. 14 b7 case)."""
    rng = np.random.default_rng(2)
    n, e = 32, 128
    src, dst, ew, nv = make_graph(rng, n, e)
    h = rand(rng, n, 32)
    w = rand(rng, 32, 4)
    b = jnp.zeros(4, "float32")
    a = model.sgc_model(h, src, dst, ew, nv, w, b, k=2)
    o = model.sgc_model_opt(h, src, dst, ew, nv, w, b, k=2)
    np.testing.assert_allclose(a, o, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# BatchNorm fusion (paper Sec. 6.4)
# ---------------------------------------------------------------------------

@settings(**SET)
@given(seed=st.integers(0, 2**31 - 1))
def test_batchnorm_folding(seed):
    rng = np.random.default_rng(seed)
    m, k, n = 32, 16, 8
    h, w, b = rand(rng, m, k), rand(rng, k, n), rand(rng, n)
    mu, gamma, beta = rand(rng, n), rand(rng, n), rand(rng, n)
    sigma2 = jnp.abs(rand(rng, n)) + 0.1
    wf, bf = model.batchnorm_fold(w, b, mu, sigma2, gamma, beta)
    fused = model.linear(h, wf, bf)
    eps = 1e-5
    unfused = (h @ w + b - mu) / jnp.sqrt(sigma2 + eps) * gamma + beta
    np.testing.assert_allclose(fused, unfused, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# Model zoo layers
# ---------------------------------------------------------------------------

def test_gcn2_shapes_and_determinism():
    rng = np.random.default_rng(4)
    n, e, f, hdim, c = 64, 256, 16, 8, 4
    src, dst, ew, nv = make_graph(rng, n, e, nv=200)
    x = rand(rng, n, f)
    w1, b1 = rand(rng, f, hdim), jnp.zeros(hdim, "float32")
    w2, b2 = rand(rng, hdim, c), jnp.zeros(c, "float32")
    y1 = model.gcn2_forward(x, src, dst, ew, nv, w1, b1, w2, b2)
    y2 = model.gcn2_forward(x, src, dst, ew, nv, w1, b1, w2, b2)
    assert y1.shape == (n, c)
    np.testing.assert_array_equal(y1, y2)


def test_gat_attention_rows_sum_to_one():
    """Per-destination attention weights must softmax-normalize."""
    rng = np.random.default_rng(6)
    n, e, f, hdim = 32, 128, 16, 8
    src, dst, ew, nv = make_graph(rng, n, e)
    x = rand(rng, n, f)
    w_att = rand(rng, f, hdim)
    a_src, a_dst = rand(rng, hdim), rand(rng, hdim)
    z = x @ w_att
    logits = (z @ a_src)[src] + (z @ a_dst)[dst]
    logits = jnp.where(logits > 0, logits, 0.2 * logits)
    att = ref.segment_softmax_ref(logits, dst, n)
    sums = np.zeros(n)
    np.add.at(sums, np.asarray(dst), np.asarray(att))
    touched = np.unique(np.asarray(dst))
    np.testing.assert_allclose(sums[touched], 1.0, rtol=1e-4)


def test_gat_layer_runs():
    rng = np.random.default_rng(8)
    n, e, f, hdim = 32, 128, 16, 8
    src, dst, _, nv = make_graph(rng, n, e)
    x = rand(rng, n, f)
    y = model.gat1_forward(x, src, dst, nv, rand(rng, f, hdim),
                           rand(rng, hdim), rand(rng, hdim))
    assert y.shape == (n, hdim)
    assert np.isfinite(np.asarray(y)).all()


def test_sage_mean_aggregation_matches_dense():
    """ew=1/deg(dst) + Sum == Mean over in-neighbors (dense check)."""
    rng = np.random.default_rng(10)
    n, e, f = 16, 64, 8
    src = np.asarray(rng.integers(0, n, e), dtype=np.int32)
    dst = np.asarray(rng.integers(0, n, e), dtype=np.int32)
    deg = np.bincount(dst, minlength=n).astype(np.float32)
    ew = 1.0 / np.maximum(deg[dst], 1.0)
    h = rng.normal(size=(n, f)).astype(np.float32)
    got = ref.spdmm_ref(
        jnp.asarray(src), jnp.asarray(dst), jnp.asarray(ew),
        jnp.asarray([e], "int32"), jnp.asarray(h), n, "sum")
    dense = np.zeros((n, f), np.float32)
    for s, d in zip(src, dst):
        dense[d] += h[s]
    dense /= np.maximum(deg[:, None], 1.0)
    np.testing.assert_allclose(got, dense, rtol=1e-4, atol=1e-4)


def test_gin_layer_eps():
    """eps=-1 cancels the self term: output depends only on neighbors."""
    rng = np.random.default_rng(12)
    n, e, f = 16, 64, 8
    src, dst, _, nv = make_graph(rng, n, e)
    ones = jnp.ones(e, "float32")
    x = rand(rng, n, f)
    w1, b1 = rand(rng, f, f), jnp.zeros(f, "float32")
    w2, b2 = rand(rng, f, f), jnp.zeros(f, "float32")
    y_a = model.gin_layer(x, src, dst, ones, nv, -1.0, w1, b1, w2, b2)
    # Perturb only the self features of an isolated change: scale x but keep
    # aggregate the same by zeroing a vertex with no outgoing edges.
    agg = ref.spdmm_ref(src, dst, ones, nv, x, n, "sum")
    z = ref.gemm_bias_act_ref(agg + 0.0 * x, w1, b1, "relu")
    want = ref.gemm_bias_act_ref(z, w2, b2, "relu")
    np.testing.assert_allclose(y_a, want, rtol=1e-3, atol=1e-3)
