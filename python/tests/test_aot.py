"""AOT export: artifacts regenerate deterministically, manifest is sound,
and the HLO text is the format the rust loader expects."""

import os
import re
import tempfile

import pytest

from compile import aot


@pytest.fixture(scope="module")
def outdir():
    with tempfile.TemporaryDirectory() as d:
        aot.export_all(d)
        yield d


def test_manifest_lists_every_artifact(outdir):
    with open(os.path.join(outdir, "manifest.txt")) as f:
        names = [line.split()[0] for line in f if line.strip()]
    files = {f[: -len(".hlo.txt")] for f in os.listdir(outdir)
             if f.endswith(".hlo.txt")}
    assert set(names) == files
    assert len(names) == len(set(names)), "duplicate artifact names"


def test_artifacts_are_hlo_text(outdir):
    for fname in os.listdir(outdir):
        if not fname.endswith(".hlo.txt"):
            continue
        with open(os.path.join(outdir, fname)) as f:
            text = f.read()
        assert text.startswith("HloModule"), fname
        # return_tuple=True => root computation returns a tuple
        assert "ROOT" in text, fname


def test_manifest_arg_format(outdir):
    pat = re.compile(r"^[a-z0-9_]+( (f32|i32)\[[0-9,]+\])+$")
    with open(os.path.join(outdir, "manifest.txt")) as f:
        for line in f:
            line = line.strip()
            if line:
                assert pat.match(line), line


def test_export_deterministic(outdir):
    """Re-export produces byte-identical HLO (required for make's no-op
    rebuild semantics and for reproducible binaries)."""
    with tempfile.TemporaryDirectory() as d2:
        aot.export_all(d2)
        for fname in sorted(os.listdir(outdir)):
            with open(os.path.join(outdir, fname)) as a, \
                 open(os.path.join(d2, fname)) as b:
                assert a.read() == b.read(), fname


def test_tile_shapes_match_constants(outdir):
    """The spdmm artifact name must encode aot.TILE_* (rust parses it)."""
    expect = f"spdmm_e{aot.TILE_E}_n{aot.TILE_N}_f{aot.TILE_F}.hlo.txt"
    assert expect in os.listdir(outdir)
