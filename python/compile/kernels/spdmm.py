"""SpDMM mode of the Adaptive Computation Kernel (paper Sec. 5.4, Alg. 2/4).

Edge-centric scatter-gather: each cycle p_sys/2 unprocessed COO edges are
fetched from the Edge Buffer, routed through the Index Shuffle Network to
the Feature Buffer bank holding h_src, and the (src.features, e) pairs are
routed through the Data Shuffle Network to an Update/Reduce pipeline that
applies  v_dst <- Reduce(v_dst, e.weight * h_src).

TPU adaptation: the banked Feature Buffer becomes a VMEM-resident feature
tile; the ISN/DSN routing becomes dynamic gather/scatter (pl.load/pl.store
with computed row indices) over that tile; the edge-parallel UR pipelines
become a sequential fori_loop here (interpret=True executes plain HLO) —
the *parallel* cycle model lives in the rust simulator (sim/ack.rs).

Edges are padded to a static count; ``n_valid`` masks the tail so one AOT
artifact serves any tile occupancy (the compiler's subshards have varying
edge counts).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF = float("-inf")
_POS_INF = float("inf")


def _spdmm_kernel(src_ref, dst_ref, w_ref, nv_ref, h_ref, o_ref, *, aggop):
    e_pad = src_ref.shape[0]
    f = h_ref.shape[1]
    n_valid = nv_ref[0]

    if aggop in ("sum", "mean"):
        init = jnp.zeros((o_ref.shape[0], f), dtype=o_ref.dtype)
    elif aggop == "max":
        init = jnp.full((o_ref.shape[0], f), _NEG_INF, dtype=o_ref.dtype)
    elif aggop == "min":
        init = jnp.full((o_ref.shape[0], f), _POS_INF, dtype=o_ref.dtype)
    else:
        raise ValueError(f"unknown aggop {aggop!r}")
    o_ref[...] = init

    def body(e, _):
        valid = e < n_valid
        s = src_ref[e]
        d = jnp.where(valid, dst_ref[e], 0)
        wt = w_ref[e]
        # Scatter phase: ISN routes the edge to the bank holding h_src.
        feats = pl.load(h_ref, (pl.dslice(s, 1), pl.dslice(0, f)))
        # Update unit: vector multiply by the edge weight.
        upd = feats * wt
        # Gather phase / Reduce unit: apply to v_dst (RAW-hazard-free here
        # because the loop is sequential; the hardware RAW Unit is modeled
        # in sim/raw.rs).
        cur = pl.load(o_ref, (pl.dslice(d, 1), pl.dslice(0, f)))
        if aggop in ("sum", "mean"):
            new = cur + jnp.where(valid, upd, 0.0)
        elif aggop == "max":
            new = jnp.where(valid, jnp.maximum(cur, upd), cur)
        else:  # min
            new = jnp.where(valid, jnp.minimum(cur, upd), cur)
        pl.store(o_ref, (pl.dslice(d, 1), pl.dslice(0, f)), new)
        return _

    jax.lax.fori_loop(0, e_pad, body, 0)

    if aggop == "max":
        o_ref[...] = jnp.where(o_ref[...] == _NEG_INF, 0.0, o_ref[...])
    elif aggop == "min":
        o_ref[...] = jnp.where(o_ref[...] == _POS_INF, 0.0, o_ref[...])


@functools.partial(jax.jit, static_argnames=("n_out", "aggop"))
def spdmm(src, dst, w, n_valid, h, *, n_out, aggop="sum"):
    """A_B (COO, padded) times H_B with element-wise aggregation.

    src, dst: (E_pad,) int32 vertex indices, rows of A_B / rows of H_B
    w:        (E_pad,) edge weights (mean aggregation pre-normalizes w
              on the compiler side, matching the paper's alpha_ji)
    n_valid:  (1,) int32 count of real edges (rest is padding)
    h:        (N_in, F) feature tile
    n_out:    static number of output rows (subshard height N1)
    """
    e_pad = src.shape[0]
    assert dst.shape == (e_pad,) and w.shape == (e_pad,)
    assert n_valid.shape == (1,)
    return pl.pallas_call(
        functools.partial(_spdmm_kernel, aggop=aggop),
        out_shape=jax.ShapeDtypeStruct((n_out, h.shape[1]), h.dtype),
        interpret=True,
    )(src, dst, w, n_valid, h)
