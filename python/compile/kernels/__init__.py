"""Layer-1 Pallas kernels: the ACK's four execution modes.

GraphAGILE's Adaptive Computation Kernel (ACK, paper Sec. 5.4) is a
p_sys x p_sys ALU array that reconfigures among four datapaths:

  * GEMM mode    -- 2-D systolic array, output-stationary dataflow
  * SpDMM mode   -- edge-centric scatter-gather (Update/Reduce pipelines)
  * SDDMM mode   -- edge-centric gathered inner products (adder trees)
  * VecAdd mode  -- vector adders (residual connections)

Each mode is expressed here as a Pallas kernel lowered with
``interpret=True`` (CPU-PJRT executable HLO; see DESIGN.md
"Hardware-Adaptation" for the FPGA->TPU mapping). The rust coordinator
never imports this package: it loads the AOT HLO artifacts produced by
``compile.aot``.
"""

from compile.kernels.gemm import gemm, gemm_bias_act
from compile.kernels.spdmm import spdmm
from compile.kernels.sddmm import sddmm
from compile.kernels.vecadd import vecadd

__all__ = ["gemm", "gemm_bias_act", "spdmm", "sddmm", "vecadd"]
