"""GEMM mode of the Adaptive Computation Kernel (paper Sec. 5.4, Alg. 1).

In GEMM mode the ACK is a p_sys x p_sys output-stationary systolic array:
each cycle it consumes p_sys elements of a feature-matrix column and p_sys
elements of a weight-matrix row, accumulating H_out[i, j] in place.

TPU adaptation: the systolic array maps onto the MXU; the Feature/Weight
Buffers map onto VMEM blocks expressed through BlockSpec.  The grid walks
output tiles (output-stationary), and the full K stripe of each operand is
resident per instance — exactly the paper's BlockMM decomposition where a
high-level GEMM instruction is expanded into a three-level nested loop of
microcode (Alg. 1).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# The paper's ACK dimension on Alveo U250 (p_sys = 16).  Block shapes are
# multiples of P_SYS so the microcode loop bounds S_B/p_sys, G_B/p_sys are
# integral, mirroring Alg. 1.
P_SYS = 16


def _gemm_kernel(h_ref, w_ref, o_ref):
    """One output tile: H_T:i (bm x K) @ W_T:j (K x bn) -> H_out:ij."""
    o_ref[...] = jnp.dot(
        h_ref[...], w_ref[...], preferred_element_type=o_ref.dtype
    )


def _gemm_bias_act_kernel(h_ref, w_ref, b_ref, o_ref, *, act):
    acc = jnp.dot(h_ref[...], w_ref[...], preferred_element_type=o_ref.dtype)
    acc = acc + b_ref[...]
    if act == "relu":
        acc = jnp.maximum(acc, 0.0)
    elif act == "lrelu":
        acc = jnp.where(acc > 0, acc, 0.01 * acc)
    elif act == "prelu":
        # PReLU with fixed slope 0.25 (slope folded at compile time).
        acc = jnp.where(acc > 0, acc, 0.25 * acc)
    elif act == "exp":
        acc = jnp.exp(acc)
    elif act != "none":
        raise ValueError(f"unknown activation {act!r}")
    o_ref[...] = acc


def _check_tiles(m, k, n, bm, bn):
    if m % bm or n % bn:
        raise ValueError(f"GEMM tile mismatch: ({m},{k},{n}) vs bm={bm} bn={bn}")
    # Hardware pads sub-p_sys dimensions to the array width; here a block
    # smaller than p_sys is only legal when it covers the full dimension
    # (the compiler's codegen guarantees p_sys-multiple tiles otherwise).
    if (bm % P_SYS and bm != m) or (bn % P_SYS and bn != n):
        raise ValueError(f"block ({bm},{bn}) not a multiple of p_sys={P_SYS}")


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def gemm(h, w, *, bm=64, bn=64):
    """H @ W with an output-stationary Pallas kernel.

    h: (M, K) feature block  (Feature Buffer resident)
    w: (K, N) weight block   (Weight Buffer resident)
    """
    m, k = h.shape
    k2, n = w.shape
    assert k == k2, f"inner dims {k} != {k2}"
    bm = min(bm, m)
    bn = min(bn, n)
    _check_tiles(m, k, n, bm, bn)
    return pl.pallas_call(
        _gemm_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), h.dtype),
        interpret=True,
    )(h, w)


@functools.partial(jax.jit, static_argnames=("act", "bm", "bn"))
def gemm_bias_act(h, w, b, *, act="none", bm=64, bn=64):
    """Fused H @ W + b with optional activation.

    This is the Linear layer after the compiler's Activation/BatchNorm
    fusion pass (paper Sec. 6.4): the bias carries the folded BatchNorm
    shift and the activation is executed in the same kernel, so no
    intermediate H_out round-trips through off-chip memory.
    """
    m, k = h.shape
    k2, n = w.shape
    assert k == k2, f"inner dims {k} != {k2}"
    assert b.shape == (n,), f"bias shape {b.shape} != ({n},)"
    bm = min(bm, m)
    bn = min(bn, n)
    _check_tiles(m, k, n, bm, bn)
    return pl.pallas_call(
        functools.partial(_gemm_bias_act_kernel, act=act),
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), h.dtype),
        interpret=True,
    )(h, w, b)
