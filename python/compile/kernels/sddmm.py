"""SDDMM mode of the Adaptive Computation Kernel (paper Sec. 5.4, Alg. 3).

Sampled dense-dense matrix multiplication A ⊙ (H Hᵀ): for every non-zero
A[i, j] (an edge), compute the inner product <h_i, h_j>.  In hardware the
ALUs of a UR pipeline re-form into a multiply-adder tree; p_sys/2 edges
are processed per cycle, each inner product of length |h| taking
ceil(|h| / p_sys) cycles at the tree root accumulator.

TPU adaptation: gathered row pairs + dot product inside the kernel;
edge parallelism is the simulator's concern (sim/ack.rs::sddmm_cycles).

Supports distinct left/right feature tiles (H_in(i,k), H_in(j,k) in the
paper's Alg. 7 partition-centric scheme) so a subshard that straddles two
row partitions can still be processed from on-chip tiles.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sddmm_kernel(src_ref, dst_ref, nv_ref, hl_ref, hr_ref, o_ref):
    e_pad = src_ref.shape[0]
    f = hl_ref.shape[1]
    n_valid = nv_ref[0]

    def body(e, _):
        valid = e < n_valid
        s = src_ref[e]
        d = dst_ref[e]
        hs = pl.load(hl_ref, (pl.dslice(s, 1), pl.dslice(0, f)))
        hd = pl.load(hr_ref, (pl.dslice(d, 1), pl.dslice(0, f)))
        # Multiply-adder tree: elementwise product reduced at the root.
        val = jnp.sum(hs * hd)
        pl.store(
            o_ref,
            (pl.dslice(e, 1),),
            jnp.where(valid, val, 0.0)[None],
        )
        return _

    jax.lax.fori_loop(0, e_pad, body, 0)


@jax.jit
def sddmm(src, dst, n_valid, h_left, h_right):
    """Edge weights w_e = <h_left[src_e], h_right[dst_e]>.

    src, dst: (E_pad,) int32 row indices into h_left / h_right
    n_valid:  (1,) int32 real edge count (padded tail produces 0)
    h_left:   (N_l, F) source-side feature tile
    h_right:  (N_r, F) destination-side feature tile
    """
    e_pad = src.shape[0]
    assert dst.shape == (e_pad,)
    assert n_valid.shape == (1,)
    assert h_left.shape[1] == h_right.shape[1]
    return pl.pallas_call(
        _sddmm_kernel,
        out_shape=jax.ShapeDtypeStruct((e_pad,), h_left.dtype),
        interpret=True,
    )(src, dst, n_valid, h_left, h_right)
