"""Pure-jnp correctness oracles for the L1 Pallas kernels.

Every kernel in compile.kernels must match its oracle here to float
tolerance; pytest + hypothesis sweep shapes/dtypes (python/tests).
No pallas imports — these are the ground truth.
"""

import jax
import jax.numpy as jnp


def gemm_ref(h, w):
    return jnp.dot(h, w, preferred_element_type=h.dtype)


def gemm_bias_act_ref(h, w, b, act="none"):
    acc = jnp.dot(h, w, preferred_element_type=h.dtype) + b
    return apply_act_ref(acc, act)


def apply_act_ref(x, act):
    if act == "none":
        return x
    if act == "relu":
        return jnp.maximum(x, 0.0)
    if act == "lrelu":
        return jnp.where(x > 0, x, 0.01 * x)
    if act == "prelu":
        return jnp.where(x > 0, x, 0.25 * x)
    if act == "exp":
        return jnp.exp(x)
    raise ValueError(f"unknown activation {act!r}")


def spdmm_ref(src, dst, w, n_valid, h, n_out, aggop="sum"):
    """Dense oracle: materialize A (n_out x n_in) and reduce row-wise."""
    e_pad = src.shape[0]
    valid = jnp.arange(e_pad) < n_valid[0]
    msgs = h[src] * w[:, None]  # (E_pad, F) update phase
    if aggop in ("sum", "mean"):
        out = jnp.zeros((n_out, h.shape[1]), h.dtype)
        out = out.at[dst].add(jnp.where(valid[:, None], msgs, 0.0))
        return out
    if aggop == "max":
        out = jnp.full((n_out, h.shape[1]), -jnp.inf, h.dtype)
        out = out.at[dst].max(jnp.where(valid[:, None], msgs, -jnp.inf))
        return jnp.where(jnp.isneginf(out), 0.0, out)
    if aggop == "min":
        out = jnp.full((n_out, h.shape[1]), jnp.inf, h.dtype)
        out = out.at[dst].min(jnp.where(valid[:, None], msgs, jnp.inf))
        return jnp.where(jnp.isposinf(out), 0.0, out)
    raise ValueError(f"unknown aggop {aggop!r}")


def sddmm_ref(src, dst, n_valid, h_left, h_right):
    e_pad = src.shape[0]
    valid = jnp.arange(e_pad) < n_valid[0]
    vals = jnp.sum(h_left[src] * h_right[dst], axis=-1)
    return jnp.where(valid, vals, 0.0)


def vecadd_ref(a, b, act="none"):
    return apply_act_ref(a + b, act)


def segment_softmax_ref(scores, dst, n):
    """Edge-score softmax grouped by destination vertex (GAT, Eq. 4)."""
    mx = jnp.full((n,), -jnp.inf, scores.dtype).at[dst].max(scores)
    ex = jnp.exp(scores - mx[dst])
    den = jnp.zeros((n,), scores.dtype).at[dst].add(ex)
    return ex / jnp.maximum(den[dst], 1e-16)
