"""Vector-Addition mode of the ACK (paper Sec. 5.4).

An Update Unit works as a vector adder: h_u + h_v, with the Reduce Unit
bypassed.  Used for residual connections (the Vector-Add IR layer).  The
kernel is a tiled elementwise add; p_sys/2 vector adds per cycle is the
simulator's timing model.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _vecadd_kernel(a_ref, b_ref, o_ref, *, act):
    acc = a_ref[...] + b_ref[...]
    if act == "relu":
        acc = jnp.maximum(acc, 0.0)
    elif act != "none":
        raise ValueError(f"unknown activation {act!r}")
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("act", "bm"))
def vecadd(a, b, *, act="none", bm=64):
    """a + b over equally partitioned feature tiles (+ fused activation)."""
    assert a.shape == b.shape, f"{a.shape} != {b.shape}"
    m, f = a.shape
    bm = min(bm, m)
    if m % bm:
        raise ValueError(f"rows {m} not divisible by block {bm}")
    return pl.pallas_call(
        functools.partial(_vecadd_kernel, act=act),
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, f), lambda i: (i, 0)),
            pl.BlockSpec((bm, f), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, f), a.dtype),
        interpret=True,
    )(a, b)
