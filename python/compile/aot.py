"""AOT export: lower L2/L1 computations to HLO *text* artifacts.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the rust ``xla`` 0.1.6 crate links) rejects; the text parser
reassigns ids and round-trips cleanly.  See /opt/xla-example/gen_hlo.py.

Artifacts (written to ``artifacts/``):

  gemm_{M}x{K}x{N}.hlo.txt          Linear tile (bias+act fused variants)
  spdmm_e{E}_n{N}_f{F}.hlo.txt      Aggregate tile (sum; padded COO)
  spdmm_max_e{E}_n{N}_f{F}.hlo.txt  Aggregate tile (max)
  sddmm_e{E}_n{N}_f{F}.hlo.txt      Vector-Inner tile
  vecadd_{M}x{F}.hlo.txt            Vector-Add tile
  gcn2_n{N}_e{E}_f{F}_h{H}_c{C}.hlo.txt   whole 2-layer GCN forward
  manifest.txt                      name -> arg shapes/dtypes (rust parses)

Every lowered function returns a tuple (return_tuple=True) and the rust
side unwraps with ``to_tuple1``.  Python runs ONCE at build time
(``make artifacts``); the rust binary is self-contained afterwards.
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels import gemm_bias_act, spdmm, sddmm, vecadd

# Functional-tile configuration: small enough that interpret-mode pallas
# lowers quickly, shaped in p_sys multiples. The rust coordinator pads
# every subshard/subfiber to these shapes (runtime/artifact registry).
TILE_N = 128      # subshard height (functional-scale N1)
TILE_F = 64       # subfiber width  (functional-scale N2)
TILE_E = 1024     # padded edges per subshard

# Whole-model demo graph (quickstart / e2e_inference example).
GCN_N = 256
GCN_E = 2048
GCN_F = 64
GCN_H = 32
GCN_C = 8


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _fmt_arg(spec):
    d = {jnp.float32: "f32", jnp.int32: "i32"}[
        jnp.float32 if spec.dtype == jnp.float32 else jnp.int32]
    return f"{d}[{','.join(str(s) for s in spec.shape)}]"


class Exporter:
    def __init__(self, outdir):
        self.outdir = outdir
        self.manifest = []

    def export(self, name, fn, specs):
        lowered = jax.jit(lambda *a: (fn(*a),)).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(self.outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        self.manifest.append(
            f"{name} {' '.join(_fmt_arg(s) for s in specs)}")
        print(f"  {name}: {len(text)} chars")

    def write_manifest(self):
        path = os.path.join(self.outdir, "manifest.txt")
        with open(path, "w") as f:
            f.write("\n".join(self.manifest) + "\n")
        print(f"  manifest: {len(self.manifest)} artifacts")


def export_all(outdir):
    os.makedirs(outdir, exist_ok=True)
    ex = Exporter(outdir)
    f32, i32 = jnp.float32, jnp.int32

    # --- Linear / GEMM tiles (bias + fused activation variants) ---------
    for act in ("none", "relu"):
        suffix = "" if act == "none" else f"_{act}"
        for (m, k, n) in ((TILE_N, TILE_F, TILE_F),):
            ex.export(
                f"gemm{suffix}_{m}x{k}x{n}",
                functools.partial(gemm_bias_act, act=act),
                [_spec((m, k)), _spec((k, n)), _spec((n,))],
            )

    # --- Aggregate / SpDMM tiles ----------------------------------------
    for aggop in ("sum", "max"):
        suffix = "" if aggop == "sum" else f"_{aggop}"
        ex.export(
            f"spdmm{suffix}_e{TILE_E}_n{TILE_N}_f{TILE_F}",
            functools.partial(spdmm, n_out=TILE_N, aggop=aggop),
            [
                _spec((TILE_E,), i32), _spec((TILE_E,), i32),
                _spec((TILE_E,), f32), _spec((1,), i32),
                _spec((TILE_N, TILE_F)),
            ],
        )

    # --- Vector-Inner / SDDMM tile ---------------------------------------
    ex.export(
        f"sddmm_e{TILE_E}_n{TILE_N}_f{TILE_F}",
        sddmm,
        [
            _spec((TILE_E,), i32), _spec((TILE_E,), i32),
            _spec((1,), i32),
            _spec((TILE_N, TILE_F)), _spec((TILE_N, TILE_F)),
        ],
    )

    # --- Vector-Add tile --------------------------------------------------
    ex.export(
        f"vecadd_{TILE_N}x{TILE_F}",
        vecadd,
        [_spec((TILE_N, TILE_F)), _spec((TILE_N, TILE_F))],
    )

    # --- Whole-model: 2-layer GCN (b1-shaped) for the e2e example --------
    ex.export(
        f"gcn2_n{GCN_N}_e{GCN_E}_f{GCN_F}_h{GCN_H}_c{GCN_C}",
        model.gcn2_forward,
        [
            _spec((GCN_N, GCN_F)),
            _spec((GCN_E,), i32), _spec((GCN_E,), i32),
            _spec((GCN_E,), f32), _spec((1,), i32),
            _spec((GCN_F, GCN_H)), _spec((GCN_H,)),
            _spec((GCN_H, GCN_C)), _spec((GCN_C,)),
        ],
    )

    ex.write_manifest()


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts",
                   help="output dir (default: ../artifacts, run from python/)")
    args = p.parse_args()
    outdir = args.out
    # Back-compat: Makefile passes the path of one artifact file.
    if outdir.endswith(".hlo.txt"):
        outdir = os.path.dirname(outdir)
    print(f"exporting HLO artifacts to {outdir}")
    export_all(outdir)


if __name__ == "__main__":
    main()
