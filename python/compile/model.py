"""Layer-2 JAX model: GNN layer math built on the L1 Pallas kernels.

This mirrors the paper's computation-layer IR (Sec. 6.1): a GNN layer is a
DAG of {Aggregate, Linear, Vector-Inner, Vector-Add, Activation, BatchNorm}
computation layers, each of which lowers onto one ACK execution mode.
The rust compiler (rust/src/ir, rust/src/compiler) manipulates the same
six-layer vocabulary; this module is the *numeric* definition used to
produce golden outputs and the AOT artifacts.

Graphs are COO edge lists padded to a static length (n_valid masks the
tail), because AOT artifacts must have fixed shapes.
"""

import functools

import jax
import jax.numpy as jnp

from compile.kernels import gemm, gemm_bias_act, spdmm, sddmm, vecadd
from compile.kernels.ref import segment_softmax_ref


# ---------------------------------------------------------------------------
# Computation layers (one per IR layer type)
# ---------------------------------------------------------------------------

def aggregate(src, dst, w, n_valid, h, *, aggop="sum"):
    """Aggregate layer == SpDMM mode (paper Eq. 5)."""
    return spdmm(src, dst, w, n_valid, h, n_out=h.shape[0], aggop=aggop)


def linear(h, w, b=None, *, act="none"):
    """Linear layer == GEMM mode (paper Eq. 6), with fused bias/activation
    (the compiler's Activation/BatchNorm fusion, Sec. 6.4)."""
    if b is None:
        b = jnp.zeros((w.shape[1],), h.dtype)
    return gemm_bias_act(h, w, b, act=act)


def vector_inner(src, dst, n_valid, h):
    """Vector-Inner layer == SDDMM mode (paper Eq. 7)."""
    return sddmm(src, dst, n_valid, h, h)


def vector_add(a, b, *, act="none"):
    """Vector-Add layer == VecAdd mode (residual connections)."""
    return vecadd(a, b, act=act)


def batchnorm_fold(w, b, mu, sigma2, gamma, beta, eps=1e-5):
    """Fold inference-time BatchNorm into the adjacent Linear layer
    (paper Sec. 6.4, BatchNorm Fusion): y = (xW + b - mu)/sqrt(s2+eps)*g + B
    becomes y = x W' + b' with W' = W*g/sqrt(s2+eps)."""
    scale = gamma / jnp.sqrt(sigma2 + eps)
    return w * scale[None, :], (b - mu) * scale + beta


# ---------------------------------------------------------------------------
# GNN layers (paper Table 5 model zoo building blocks)
# ---------------------------------------------------------------------------

def gcn_layer(h, src, dst, ew, n_valid, w, b, *, act="relu",
              order="auto"):
    """GCN layer (Eq. 3): h_i = act( sum_j alpha_ji h_j W ).

    ``ew`` carries the symmetric-normalized alpha_ji = 1/sqrt(D_j D_i)
    (precomputed by the graph loader — a linear Sum aggregation).
    ``order`` mirrors the compiler's computation-order optimization
    (Theorems 1-2): 'AL' aggregate-then-linear, 'LA' linear-then-aggregate,
    'auto' picks by f_in vs f_out.
    """
    f_in, f_out = w.shape
    if order == "auto":
        order = "LA" if f_in > f_out else "AL"
    if order == "LA":
        z = linear(h, w, b)
        z = aggregate(src, dst, ew, n_valid, z, aggop="sum")
        return _act(z, act)
    z = aggregate(src, dst, ew, n_valid, h, aggop="sum")
    return linear(z, w, b, act=act)


def sage_layer(h, src, dst, ew_mean, n_valid, w_self, w_neigh, b,
               *, act="relu"):
    """GraphSAGE (mean) layer: h_i = act(h_i W_self + mean_j(h_j) W_neigh).

    ``ew_mean`` is 1/deg(dst) per edge, so Sum aggregation realizes Mean —
    keeping the aggregation operator linear (order-exchange legal).
    """
    z_self = linear(h, w_self)
    z_neigh = aggregate(src, dst, ew_mean, n_valid, h, aggop="sum")
    z_neigh = linear(z_neigh, w_neigh, b)
    return _act(vector_add(z_self, z_neigh), act)


def gin_layer(h, src, dst, ones, n_valid, eps, w1, b1, w2, b2,
              *, act="relu"):
    """GIN layer: h_i = MLP((1 + eps) h_i + sum_j h_j); 2-layer MLP."""
    z = aggregate(src, dst, ones, n_valid, h, aggop="sum")
    z = vector_add(z, (1.0 + eps) * h)
    z = linear(z, w1, b1, act=act)
    return linear(z, w2, b2, act=act)


def gat_layer(h, src, dst, n_valid, w_att, a_src, a_dst, *, act="elu",
              lrelu_slope=0.2):
    """GAT layer (Eq. 4), single head.

    The attention logit a·[Wh_i || Wh_j] splits into a_src·Wh_i + a_dst·Wh_j;
    the per-edge term is a Vector-Inner (SDDMM) computation, the softmax is
    an edge-wise Activation + Aggregate normalization, and the final
    weighted aggregation is SpDMM with the attention weights.
    """
    n = h.shape[0]
    e_pad = src.shape[0]
    z = linear(h, w_att)                            # GEMM
    # SDDMM-style edge scores via rank-1 left/right projections:
    # s_e = <z_src, a_src> + <z_dst, a_dst>
    alpha_l = z @ a_src                              # (N,)
    alpha_r = z @ a_dst
    logits = alpha_l[src] + alpha_r[dst]
    logits = jnp.where(logits > 0, logits, lrelu_slope * logits)
    valid = jnp.arange(e_pad) < n_valid[0]
    logits = jnp.where(valid, logits, -jnp.inf)
    att = segment_softmax_ref(logits, dst, n)       # edge-wise softmax
    att = jnp.where(valid, att, 0.0)
    out = aggregate(src, dst, att, n_valid, z)      # SpDMM with att weights
    return _act(out, act)


def sgc_model(h, src, dst, ew, n_valid, w, b, *, k=2):
    """SGC (paper b7): h = A^k X W — k Aggregates then one Linear.

    The compiler's order optimization is what makes SGC fast when
    f_in >> n_classes: it hoists the Linear before the Aggregates
    (Fig. 14's 260% win on b7); numerically both orders agree, which the
    tests assert.
    """
    z = h
    for _ in range(k):
        z = aggregate(src, dst, ew, n_valid, z, aggop="sum")
    return linear(z, w, b)


def sgc_model_opt(h, src, dst, ew, n_valid, w, b, *, k=2):
    """SGC with the Linear hoisted first (compiler-exchanged order)."""
    z = linear(h, w, b)
    for _ in range(k):
        z = aggregate(src, dst, ew, n_valid, z, aggop="sum")
    return z


def _act(x, act):
    if act == "none":
        return x
    if act == "relu":
        return jnp.maximum(x, 0.0)
    if act == "elu":
        return jnp.where(x > 0, x, jnp.expm1(x))
    raise ValueError(f"unknown activation {act!r}")


# ---------------------------------------------------------------------------
# Whole models (AOT export targets; fixed shapes)
# ---------------------------------------------------------------------------

def gcn2_forward(x, src, dst, ew, n_valid, w1, b1, w2, b2):
    """2-layer GCN (paper model b1/b2) over a padded-COO graph.

    Layer 1 uses the compiler-optimized LA order (f_in > hidden);
    layer 2 uses AL order (hidden < classes would flip it, but we follow
    the per-layer auto rule exactly as the rust compiler does).
    """
    h = gcn_layer(x, src, dst, ew, n_valid, w1, b1, act="relu", order="auto")
    return gcn_layer(h, src, dst, ew, n_valid, w2, b2, act="none",
                     order="auto")


def sage2_forward(x, src, dst, ew_mean, n_valid,
                  ws1, wn1, b1, ws2, wn2, b2):
    """2-layer GraphSAGE-mean (paper b3/b4)."""
    h = sage_layer(x, src, dst, ew_mean, n_valid, ws1, wn1, b1)
    return sage_layer(h, src, dst, ew_mean, n_valid, ws2, wn2, b2,
                      act="none")


def gat1_forward(x, src, dst, n_valid, w_att, a_src, a_dst):
    """Single GAT layer (paper b6 building block)."""
    return gat_layer(x, src, dst, n_valid, w_att, a_src, a_dst)
