//! Multi-tenant QoS serving end to end: load the checked-in
//! `examples/tenants/mixed.json` policy (premium / standard /
//! best-effort), serve a mixed f32 + int8 workload from all three
//! tenants through the weighted-fair scheduler, and compare against the
//! same workload served tenant-blind (single FIFO).
//!
//! ```bash
//! cargo run --release --example qos
//! ```
//!
//! The CLI equivalent:
//!
//! ```bash
//! graphagile serve --devices 2 --requests 200 \
//!     --tenants examples/tenants/mixed.json
//! ```

use graphagile::config::HwConfig;
use graphagile::graph::dataset;
use graphagile::harness::serve_summary;
use graphagile::ir::ZooModel;
use graphagile::quant::Precision;
use graphagile::serve::{percentile, Coordinator, FleetConfig, Request, TenantConfig};
use graphagile::util::Rng;
use std::path::Path;

/// A three-tenant mix: the premium tenant sends sparse f32 traffic, the
/// standard tenant alternates f32 and int8, and the best-effort tenant
/// floods int8 requests between them.
fn workload(n: usize, seed: u64) -> Vec<Request> {
    let models = [ZooModel::B1, ZooModel::B2, ZooModel::B6, ZooModel::B7];
    let graphs = [dataset("CI").unwrap(), dataset("CO").unwrap(), dataset("PU").unwrap()];
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let model = models[rng.below(4) as usize];
            let graph = graphs[rng.below(3) as usize];
            let arrival = i as f64 * 1e-4;
            match i % 8 {
                // One premium f32 request per 8 slots.
                3 => Request::full(0, model, graph, arrival),
                // Two standard slots, alternating f32 / int8.
                1 => Request::full(1, model, graph, arrival),
                5 => Request::full(1, model, graph, arrival).with_precision(Precision::Int8),
                // The rest is the best-effort int8 flood.
                _ => Request::full(2, model, graph, arrival).with_precision(Precision::Int8),
            }
        })
        .collect()
}

/// Nearest-rank latency percentile of one tenant's served requests.
fn tenant_p99(c: &Coordinator, tenant: u32) -> f64 {
    let mut lats: Vec<f64> = c
        .responses
        .iter()
        .filter(|r| r.tenant == tenant && !r.outcome.is_shed())
        .map(|r| r.latency)
        .collect();
    lats.sort_by(f64::total_cmp);
    percentile(&lats, 0.99)
}

fn main() {
    let n: usize = std::env::var("GA_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(200);

    // 1. The checked-in policy file — the same file `serve --tenants`
    // and `daemon --tenants` take.
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("examples")
        .join("tenants")
        .join("mixed.json");
    let tenants = TenantConfig::load(&path).unwrap();
    println!("loaded {} ({} tenants):", path.display(), tenants.tenants.len());
    for t in &tenants.tenants {
        println!(
            "  tenant {} — class {:?}, weight {}, deadline {}",
            t.id,
            t.class,
            t.weight,
            t.deadline_s.map_or("none".into(), |d| format!("{:.0} ms", d * 1e3)),
        );
    }

    // 2. The same workload served twice on a two-device fleet: once
    // tenant-blind (single FIFO), once under the QoS policy.
    let reqs = workload(n, 23);
    let fleet = FleetConfig { n_devices: 2, ..FleetConfig::default() };

    let mut fifo = Coordinator::fleet(HwConfig::alveo_u250(), fleet);
    let fifo_stats = fifo.run(reqs.clone());

    let mut qos = Coordinator::fleet(HwConfig::alveo_u250(), fleet);
    qos.set_tenants(tenants);
    let qos_stats = qos.run(reqs);

    // 3. Per-tenant outcomes only exist in the QoS run — the FIFO
    // baseline records no tenant families at all.
    assert!(fifo_stats.tenants.is_empty());
    assert!(!qos_stats.tenants.is_empty());
    println!("\ntenant-blind FIFO:");
    print!("{}", serve_summary(&fifo_stats));
    println!("\nweighted-fair QoS:");
    print!("{}", serve_summary(&qos_stats));

    // 4. The point of the exercise: the premium tenant stops queueing
    // behind the best-effort flood.
    let (fifo_p99, qos_p99) = (tenant_p99(&fifo, 0), tenant_p99(&qos, 0));
    println!(
        "\npremium p99: {:.3} ms under FIFO -> {:.3} ms under QoS \
         ({} preemption(s), {} request(s) degraded, {} shed)",
        fifo_p99 * 1e3,
        qos_p99 * 1e3,
        qos.qos_preemptions(),
        qos_stats.degraded,
        qos_stats.shed,
    );
    // Under backlog QoS wins outright; the epsilon only covers the
    // unloaded regime where both runs bottom out at bare service time.
    assert!(
        qos_p99 <= fifo_p99 * 1.05 + 1e-4,
        "premium p99 must not regress under QoS ({:.3} ms vs {:.3} ms FIFO)",
        qos_p99 * 1e3,
        fifo_p99 * 1e3,
    );
    assert_eq!(qos_stats.completed + qos_stats.shed, n as u64);
}
