//! Model zoo sweep: run all eight paper benchmarks (b1–b8, Table 5) on a
//! set of graphs without any hardware regeneration — the overlay pitch:
//! one bitstream, eight models, milliseconds of compilation each.
//!
//! ```bash
//! cargo run --release --example model_zoo [-- CI,CO,PU,FL]
//! ```

use graphagile::compiler::{compile, CompileOptions};
use graphagile::config::HwConfig;
use graphagile::graph::{dataset, TileCounts};
use graphagile::ir::ALL_MODELS;
use graphagile::util::timed;

fn main() {
    let keys = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "CI,CO,PU,FL".to_string());
    let hw = HwConfig::alveo_u250();
    println!(
        "{:>5} {:>4} {:>10} {:>10} {:>12} {:>8} {:>10}",
        "model", "ds", "LoC (ms)", "LoH (ms)", "binary (KB)", "util %", "GFLOP/s"
    );
    for key in keys.split(',') {
        let ds = dataset(key).unwrap_or_else(|| panic!("unknown dataset {key}"));
        let (src, dst) = ds.edge_arrays();
        let (tiles, t_part) =
            timed(|| TileCounts::from_edges(&src, &dst, ds.n_vertices, hw.n1() as u64));
        for m in ALL_MODELS {
            let ir = m.build(ds.meta());
            let exe = compile(&ir, &tiles, &hw, CompileOptions::default());
            let sim = graphagile::sim::simulate(&exe.program, &hw);
            println!(
                "{:>5} {:>4} {:>10.3} {:>10.3} {:>12.1} {:>8.1} {:>10.1}",
                m.key(),
                ds.key,
                (t_part + exe.report.total()) * 1e3,
                sim.loh_ms(),
                exe.program.size_bytes() as f64 / 1e3,
                sim.utilization() * 100.0,
                sim.gflops(exe.ir.total_complexity()),
            );
        }
    }
}
