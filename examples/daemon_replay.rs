//! Daemon mode + record/replay end to end: start the daemon in-process
//! on an ephemeral port, drive the scripted mixed workload over TCP,
//! persist the recorded trace, replay it with verification, and then
//! replay the hand-authored events-only trace checked into
//! `examples/traces/`.
//!
//! ```bash
//! cargo run --release --example daemon_replay
//! ```
//!
//! The CLI equivalent of what this example does in one process:
//!
//! ```bash
//! graphagile daemon --port 0 --trace trace.json &   # prints the port
//! graphagile drive --port <port> --requests 200
//! graphagile replay trace.json --verify
//! ```

use graphagile::config::HwConfig;
use graphagile::daemon::{drive, replay, verify, Client, Daemon};
use graphagile::harness::{divergence_report, replay_summary, serve_summary};
use graphagile::serve::FleetConfig;
use std::path::Path;

fn main() {
    let n: usize = std::env::var("GA_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(96);

    // 1. A live daemon on an ephemeral localhost port, serving a
    // two-device fleet.
    let fleet = FleetConfig { n_devices: 2, ..FleetConfig::default() };
    let d = Daemon::bind(0, HwConfig::alveo_u250(), fleet).unwrap();
    let port = d.port();
    println!("daemon listening on 127.0.0.1:{port}");
    let server = std::thread::spawn(move || d.serve().unwrap());

    // 2. The scripted mixed workload over TCP: whole-graph f32 + int8,
    // mini-batch ego-nets, churn batches. Real arrival times are
    // stamped at admission and recorded in the trace.
    let mut client = Client::connect(port).unwrap();
    let (accepted, stats) = drive(&mut client, n, 7).unwrap();
    println!("drove {accepted} requests through the daemon:");
    print!("{}", serve_summary(&stats));
    client.shutdown().unwrap();
    let trace = server.join().unwrap();

    // 3. Persist and reload — the same file `graphagile replay` takes.
    let path = std::env::temp_dir().join("daemon_replay_example.trace.json");
    trace.save(&path).unwrap();
    let loaded = graphagile::daemon::Trace::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);

    // 4. Replay offline and verify bit-identity against the recording.
    let (_responses, replayed) = replay(&loaded);
    print!("\n{}", replay_summary(&loaded, &replayed));
    let divergences = verify(&loaded).unwrap();
    print!("{}", divergence_report(&divergences));
    assert!(divergences.is_empty(), "replay diverged: {divergences:?}");

    // 5. The checked-in example trace: hand-authored and events-only
    // (no recorded outcomes), so it can be replayed but not verified.
    let fixed = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("examples")
        .join("traces")
        .join("mixed.trace.json");
    let t = graphagile::daemon::Trace::load(&fixed).unwrap();
    let (_r, s) = replay(&t);
    print!("\nreplaying the checked-in {}:\n{}", fixed.display(), replay_summary(&t, &s));
    assert!(
        verify(&t).is_err(),
        "events-only traces must refuse --verify, not vacuously pass"
    );
    println!("verify on the events-only trace correctly refused (nothing to diff against)");
}
