//! Design-space exploration, in both directions the paper discusses:
//!
//! 1. **GNN design space (GraphGym)** — sweep pre/mp/post depth,
//!    aggregation operator, residuals, and BatchNorm; every point
//!    compiles to the same overlay in milliseconds (no re-synthesis).
//! 2. **Hardware design space** — sweep N_pe and p_sys to see where
//!    the paper's 8 x 16x16 configuration sits.
//!
//! ```bash
//! cargo run --release --example design_space
//! ```

use graphagile::compiler::{compile, CompileOptions};
use graphagile::config::HwConfig;
use graphagile::graph::dataset;
use graphagile::ir::GraphGymConfig;
use graphagile::isa::{Activation, AggOp};
use graphagile::sim::simulate;

fn main() {
    let ds = dataset("PU").unwrap();
    let hw = HwConfig::alveo_u250();
    let tiles = ds.tile_counts(hw.n1() as u64);

    println!("== GraphGym design space on {} ==", ds.name);
    println!(
        "{:>4} {:>4} {:>5} {:>9} {:>4} {:>10} {:>10} {:>12}",
        "pre", "mp", "post", "agg", "res", "LoC (us)", "LoH (ms)", "binary (KB)"
    );
    for n_pre in [0, 1] {
        for n_mp in [2, 3, 4] {
            for aggop in [AggOp::Sum, AggOp::Max] {
                for residual in [false, true] {
                    let cfg = GraphGymConfig {
                        n_pre,
                        n_mp,
                        n_post: 1,
                        hidden: 256,
                        aggop,
                        act: Activation::PRelu,
                        residual,
                        batchnorm: true,
                    };
                    let ir = cfg.build("gg", ds.meta());
                    let exe = compile(&ir, &tiles, &hw, CompileOptions::default());
                    let sim = simulate(&exe.program, &hw);
                    println!(
                        "{:>4} {:>4} {:>5} {:>9} {:>4} {:>10.1} {:>10.3} {:>12.1}",
                        n_pre,
                        n_mp,
                        1,
                        format!("{aggop:?}"),
                        if residual { "y" } else { "n" },
                        exe.report.total() * 1e6,
                        sim.loh_ms(),
                        exe.program.size_bytes() as f64 / 1e3,
                    );
                }
            }
        }
    }

    println!("\n== hardware design space (b2 on {}) ==", ds.name);
    println!("{:>6} {:>6} {:>10} {:>8}", "n_pe", "p_sys", "LoH (ms)", "util %");
    let ir = graphagile::ir::ZooModel::B2.build(ds.meta());
    for n_pe in [2usize, 4, 8, 16] {
        for p_sys in [8usize, 16, 32] {
            let hw = HwConfig { n_pe, p_sys, ..HwConfig::alveo_u250() };
            if hw.validate().is_err() {
                continue;
            }
            let exe = compile(&ir, &tiles, &hw, CompileOptions::default());
            let sim = simulate(&exe.program, &hw);
            println!(
                "{:>6} {:>6} {:>10.3} {:>8.1}",
                n_pe,
                p_sys,
                sim.loh_ms(),
                sim.utilization() * 100.0
            );
        }
    }
}
