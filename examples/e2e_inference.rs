//! End-to-end functional inference — the driver that proves all three
//! layers of the stack compose (DESIGN.md Sec. 5), now phrased through
//! the engine layer: every execution substrate implements
//! [`graphagile::engine::InferenceEngine`] and consumes the *same*
//! compiled [`graphagile::compiler::Executable`].
//!
//!   L1 Pallas kernels (GEMM/SpDMM/SDDMM/VecAdd, interpret=True)
//!     -> AOT-lowered by python/compile/aot.py to HLO text (build time)
//!   L2 JAX model (2-layer GCN) -> whole-model HLO artifact
//!   L3 rust: compiles the GNN to the GraphAGILE ISA, then executes the
//!      compiled schedule through four engines — python never runs here:
//!
//!   golden (whole-graph rust)   — ground truth
//!   functional (rust tile ops)  — compiled schedule, reference kernels
//!   pjrt (Pallas/JAX HLO tiles) — compiled schedule, AOT kernels
//!   sim (cycle model)           — the same executable's virtual T_LoH
//!
//! ```bash
//! # Prerequisite: the offline vendor set has no `xla` crate — add it to
//! # [dependencies] in Cargo.toml first (see the `pjrt` feature note).
//! make artifacts && cargo run --release --features pjrt --example e2e_inference
//! ```

use graphagile::compiler::{compile, CompileOptions};
use graphagile::config::HwConfig;
use graphagile::engine::{
    EngineInput, FunctionalEngine, GoldenEngine, InferenceEngine, PjrtEngine, SimEngine,
};
use graphagile::exec::WeightStore;
use graphagile::graph::{rmat::rmat_edges, GraphMeta, PartitionConfig, PartitionedGraph};
use graphagile::ir::ZooModel;
use graphagile::runtime::{client_args, find_artifacts_dir, PjrtRuntime};
use graphagile::util::fmt_bytes;
use std::time::Instant;

fn max_rel_err(a: &[f32], b: &[f32]) -> f32 {
    let scale = a.iter().fold(1f32, |m, v| m.max(v.abs()));
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max) / scale
}

fn main() -> anyhow::Result<()> {
    let dir = find_artifacts_dir()
        .ok_or_else(|| anyhow::anyhow!("no artifacts — run `make artifacts` first"))?;
    println!("loading + compiling AOT artifacts from {} ...", dir.display());
    let t0 = Instant::now();
    let rt = PjrtRuntime::load(&dir)?;
    println!(
        "  {} artifacts compiled in {:.2} s (once, at startup)",
        rt.manifest().entries.len(),
        t0.elapsed().as_secs_f64()
    );

    // --- The workload: a 300-vertex R-MAT graph, 2-layer GCN (b1). ----
    let meta = GraphMeta::new("demo", 300, 1500, 32, 4);
    let g = rmat_edges(meta, Default::default(), 9).gcn_normalized();
    let hw = HwConfig::functional_tiles();
    let cfg = PartitionConfig { n1: hw.n1() as u64, n2: hw.n2() as u64 };
    let pg = PartitionedGraph::build(&g, cfg);
    let ir = ZooModel::B1.build(g.meta.clone());
    let exe = compile(&ir, &pg.tile_counts(), &hw, CompileOptions::default());
    let store = WeightStore::deterministic(&exe.ir, 33);
    let x = g.random_features(5);
    println!(
        "\nworkload: {} on {} (|V|={}, |E|={} incl. self-loops), {} tiling blocks",
        exe.ir.name,
        g.meta.name,
        g.n(),
        g.m(),
        exe.report.blocks,
    );

    // --- One Executable, four engines. ---------------------------------
    let input = EngineInput { graph: &g, partitioned: &pg, store: &store, x: &x };
    let mut engines: Vec<Box<dyn InferenceEngine + '_>> = vec![
        Box::new(GoldenEngine),
        Box::new(FunctionalEngine::default()),
        Box::new(PjrtEngine::new(&rt)),
        Box::new(SimEngine::new(HwConfig::alveo_u250())),
    ];
    let mut golden: Option<Vec<f32>> = None;
    println!("\nengines over the same compiled program:");
    for engine in engines.iter_mut() {
        let p = engine.run(&exe, Some(&input))?;
        let vs = match (&golden, &p.output) {
            (Some(gold), Some(out)) => format!("err {:.2e} vs golden", max_rel_err(gold, out)),
            (None, Some(_)) => "(reference)".to_string(),
            _ => format!("{} cycles (virtual)", p.cycles),
        };
        println!(
            "  {:<10} {:>9.4} s  {:>6} launches  {:>10}  {}",
            p.engine,
            p.latency_s,
            p.kernel_launches,
            fmt_bytes(p.bytes_moved),
            vs
        );
        if let (Some(gold), Some(out)) = (&golden, &p.output) {
            anyhow::ensure!(
                max_rel_err(gold, out) < 1e-3,
                "{} diverged from golden",
                p.engine
            );
        }
        if golden.is_none() {
            golden = p.output;
        }
    }

    // --- Whole-model artifact: L2's gcn2 forward as one executable. ----
    let name = rt
        .manifest()
        .find_prefix("gcn2_")
        .ok_or_else(|| anyhow::anyhow!("no gcn2 artifact"))?
        .to_string();
    let nums: Vec<usize> = name
        .strip_prefix("gcn2_")
        .unwrap()
        .split(['n', 'e', 'f', 'h', 'c', '_'])
        .filter_map(|t| t.parse().ok())
        .collect();
    let (n, e, f, hdim, c) = (nums[0], nums[1], nums[2], nums[3], nums[4]);
    let mut rng = graphagile::util::Rng::new(7);
    let xs: Vec<f32> = (0..n * f).map(|_| rng.normal() * 0.5).collect();
    let src: Vec<i32> = (0..e).map(|_| rng.below(n as u64) as i32).collect();
    let dst: Vec<i32> = (0..e).map(|_| rng.below(n as u64) as i32).collect();
    let ew: Vec<f32> = (0..e).map(|_| rng.f32()).collect();
    let nv = [e as i32];
    let w1: Vec<f32> = (0..f * hdim).map(|_| rng.normal() * 0.1).collect();
    let b1 = vec![0f32; hdim];
    let w2: Vec<f32> = (0..hdim * c).map(|_| rng.normal() * 0.1).collect();
    let b2 = vec![0f32; c];
    use client_args::{f32s, i32s};
    let args = [
        f32s(&xs), i32s(&src), i32s(&dst), f32s(&ew), i32s(&nv),
        f32s(&w1), f32s(&b1), f32s(&w2), f32s(&b2),
    ];
    // Warm once, then time a batch of requests (python is nowhere in
    // this process).
    rt.execute(&name, &args)?;
    let reps = 50;
    let t0 = Instant::now();
    let mut out = Vec::new();
    for _ in 0..reps {
        out = rt.execute(&name, &args)?;
    }
    let per_req = t0.elapsed().as_secs_f64() / reps as f64;
    println!("\nwhole-model artifact `{name}`:");
    println!(
        "  {n} vertices x {f} features -> {c} classes: {:.3} ms/inference ({:.0} req/s, {} runs)",
        per_req * 1e3,
        1.0 / per_req,
        reps
    );
    anyhow::ensure!(out.len() == n * c && out.iter().all(|v| v.is_finite()));

    println!("\ne2e_inference OK");
    Ok(())
}
