//! Mini-batch ego-network inference end to end:
//!
//! 1. materialize a Cora-sized synthetic and GCN-normalize it,
//! 2. sample the fanout-capped 2-hop ego-net of a few target vertices,
//! 3. execute it through the shape-bucketed program cache
//!    ([`graphagile::engine::MiniBatchRunner`]),
//! 4. cross-check the full-neighborhood variant against the
//!    whole-graph golden executor on the target rows.
//!
//! Run: `cargo run --example minibatch`

use graphagile::compiler::{compile, CompileOptions};
use graphagile::config::HwConfig;
use graphagile::engine::MiniBatchRunner;
use graphagile::exec::{golden_forward, WeightStore};
use graphagile::graph::{dataset, full_fanout, Sampler, TileCounts};
use graphagile::ir::{LayerType, ZooModel};

fn main() {
    let co = dataset("CO").unwrap();
    let graph = co.materialize().gcn_normalized();
    let x = graph.random_features(5);
    let model = ZooModel::B1;
    let targets = [7u32, 42, 100, 2500];

    let sampler = Sampler::new(graph);
    let mut runner = MiniBatchRunner::new(HwConfig::functional_tiles(), 33);

    // GraphSAGE-style capped sampling: the serving configuration.
    let capped = sampler.sample(&targets, &[25, 10], 1);
    let p = runner.run(model, &capped, &x);
    println!(
        "capped [25,10] ego-net of {:?}: {} vertices / {} edges -> bucket \
         v={} e={} (hit: {})",
        targets,
        capped.n(),
        capped.m(),
        p.shape.v,
        p.shape.e,
        p.bucket_hit
    );

    // A second request with different targets lands in the same bucket:
    // no recompilation.
    let capped2 = sampler.sample(&[9, 13, 77], &[25, 10], 2);
    let p2 = runner.run(model, &capped2, &x);
    println!(
        "second request ({} vertices): bucket hit = {}, {} program(s) compiled",
        capped2.n(),
        p2.bucket_hit,
        runner.buckets()
    );

    // Full-neighborhood sampling to the model's Aggregate depth
    // reproduces the whole-graph outputs on the target rows. The golden
    // reference runs the *optimized* IR of a whole-graph compile —
    // order optimization relabels layers, and the bucket programs go
    // through the same passes, so layer ids (and therefore the
    // deterministic weights) line up.
    let ir = model.build(sampler.graph().meta.clone());
    let hops = ir.count(LayerType::Aggregate);
    let exact = sampler.sample(&targets, &full_fanout(hops), 3);
    let pe = runner.run(model, &exact, &x);
    let hw = HwConfig::functional_tiles();
    let tiles = TileCounts::from_coo(sampler.graph(), hw.n1() as u64);
    let exe_full = compile(&ir, &tiles, &hw, CompileOptions::default());
    let store = WeightStore::deterministic(&exe_full.ir, 33);
    let golden = golden_forward(&exe_full.ir, sampler.graph(), &store, &x);
    let classes = sampler.graph().meta.n_classes as usize;
    let scale = golden.iter().fold(1f32, |m, v| m.max(v.abs()));
    let mut max_err = 0f32;
    for (i, &t) in targets.iter().enumerate() {
        for c in 0..classes {
            let a = pe.targets_out[i * classes + c];
            let b = golden[t as usize * classes + c];
            max_err = max_err.max((a - b).abs());
        }
    }
    println!(
        "full-neighborhood ({hops} hops, {} vertices / {} edges, padded to {}): \
         max |mini - golden| on target rows = {max_err:.2e}",
        exact.n(),
        exact.m(),
        pe.padded_vertices
    );
    assert!(
        max_err <= 1e-3 * scale.max(1.0),
        "mini-batch diverged from the golden executor ({max_err} at scale {scale})"
    );
    println!("mini-batch path reproduces the whole-graph golden outputs ✓");
}
