//! Quickstart: compile a GCN (paper model b1) on Cora through the full
//! GraphAGILE pipeline — IR build, four-pass optimizing compile, `.ga`
//! binary generation, and cycle-level simulation of the Alveo U250
//! overlay — then print the end-to-end latency breakdown of Table 7.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use graphagile::compiler::{compile, CompileOptions};
use graphagile::config::HwConfig;
use graphagile::graph::dataset;
use graphagile::ir::ZooModel;
use graphagile::sim::{comm_seconds, simulate};
use graphagile::util::{fmt_bytes, fmt_ms, timed};

fn main() {
    // 1. The hardware: the paper's Alveo U250 overlay instance.
    let hw = HwConfig::alveo_u250();
    println!(
        "overlay: {} PEs x {}x{} ACK @ {} MHz ({:.0} GFLOPS peak, {} on-chip)",
        hw.n_pe,
        hw.p_sys,
        hw.p_sys,
        hw.freq_hz / 1e6,
        hw.peak_flops() / 1e9,
        fmt_bytes(hw.on_chip_bytes()),
    );

    // 2. The instance: model b1 (2-layer GCN, hidden 16) on Cora.
    let ds = dataset("CO").unwrap();
    let ir = ZooModel::B1.build(ds.meta());
    println!(
        "\ninstance: {} on {} (|V|={}, |E|={}, f={})",
        ir.name, ds.name, ds.n_vertices, ds.n_edges, ds.feat_len
    );
    println!("IR ({} layers):", ir.n_layers());
    for l in &ir.layers {
        println!("  layer {:2} {:?} {} -> {}", l.id, l.ltype, l.f_in, l.f_out);
    }

    // 3. Partition the graph (the synthetic Cora stand-in) and compile.
    let (src, dst) = ds.edge_arrays();
    let (tiles, t_part) = timed(|| {
        graphagile::graph::TileCounts::from_edges(&src, &dst, ds.n_vertices, hw.n1() as u64)
    });
    let exe = compile(&ir, &tiles, &hw, CompileOptions::default());
    println!("\nafter order-optimization + fusion ({} layers):", exe.ir.n_layers());
    for l in &exe.ir.layers {
        println!(
            "  layer {:2} {:?} {} -> {}{}",
            l.id,
            l.ltype,
            l.f_in,
            l.f_out,
            if l.act_enabled { "  (+act)" } else { "" }
        );
    }
    println!(
        "\nbinary: {} instructions, {}",
        exe.program.total_instrs(),
        fmt_bytes(exe.program.size_bytes()),
    );

    // 4. Simulate the overlay and assemble the Table-7 metrics.
    let sim = simulate(&exe.program, &hw);
    let t_loc = t_part + exe.report.total();
    let bytes = ds.meta().input_bytes() + exe.ir.weight_bytes() + exe.program.size_bytes();
    let t_comm = comm_seconds(&hw, bytes);
    let t_loh = sim.loh_seconds();
    println!("\nlatency breakdown (paper Table 7 metrics):");
    println!("  T_LoC  (compilation)        {}", fmt_ms(t_loc * 1e3));
    println!("  T_comm (PCIe, {} )   {}", fmt_bytes(bytes), fmt_ms(t_comm * 1e3));
    println!("  T_LoH  (hardware execution) {}", fmt_ms(t_loh * 1e3));
    println!("  T_E2E                       {}", fmt_ms((t_loc + t_comm + t_loh) * 1e3));
    println!(
        "\nACK utilization {:.1}%, effective {:.1} GFLOP/s",
        sim.utilization() * 100.0,
        sim.gflops(exe.ir.total_complexity()),
    );
}
