//! Streaming graph updates end to end: build a dynamic graph, run
//! inference, apply R-MAT-skewed churn batches (incremental
//! dirty-subshard recompilation), watch the outputs drift — then serve
//! a mixed trace with updates interleaved on the virtual clock.
//!
//! ```bash
//! cargo run --release --example streaming
//! ```

use graphagile::config::HwConfig;
use graphagile::engine::StreamingSession;
use graphagile::graph::{dataset, rmat_edges, GraphMeta};
use graphagile::ir::ZooModel;
use graphagile::serve::{Coordinator, FleetConfig, Request};
use graphagile::stream::{ChurnGenerator, ChurnSpec};
use graphagile::util::Rng;

fn main() {
    // 1. A streaming session over a mid-size R-MAT synthetic.
    let meta = GraphMeta::new("stream-demo", 2048, 16384, 32, 4);
    let g = rmat_edges(meta, Default::default(), 3).gcn_normalized();
    let hw = HwConfig::functional_tiles();
    let mut session = StreamingSession::new(g, hw, 33);
    let x = session.graph().random_features(5);
    println!(
        "epoch 0: |V| = {}, |E| = {}, adjacency density {:.5}",
        session.dyng.n_vertices(),
        session.dyng.n_edges(),
        session.dyng.adj_density()
    );
    let p0 = session.infer(ZooModel::B1, &x).unwrap();
    let out0 = p0.output.unwrap();

    // 2. Churn: three 1% batches, applied incrementally.
    let mut gen = ChurnGenerator::new(Default::default(), 7);
    for _ in 0..3 {
        let spec = ChurnSpec { inserts: 170, deletes: 40, new_vertices: 0 };
        let batch = gen.next_batch(&session.dyng, spec);
        let r = session.apply(&batch);
        println!(
            "epoch {}: +{} -{} edges, {}/{} subshards dirty, {} edges re-sorted, \
             density {:.5}{}",
            r.epoch,
            r.inserted,
            r.deleted,
            r.dirty_subshards,
            r.total_subshards,
            r.rebuilt_edges,
            r.adj_density,
            if r.compacted { " (compacted)" } else { "" }
        );
    }
    let p3 = session.infer(ZooModel::B1, &x).unwrap();
    let out3 = p3.output.unwrap();
    let drift = out0
        .iter()
        .zip(&out3)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    println!("output drift after 3 churn epochs: max |delta| = {drift:.5}\n");

    // 3. The serving fleet with updates interleaved: every 8th request
    // is a churn batch; whole-graph programs recompile per epoch,
    // bucket programs survive untouched.
    let co = dataset("CO").unwrap();
    let mut rng = Rng::new(9);
    let reqs: Vec<Request> = (0..64)
        .map(|i| {
            let arrival = i as f64 * 2e-4;
            if i % 8 == 7 {
                Request::update(0, co, 54, 13, 0, i as u64, arrival)
            } else if i % 2 == 0 {
                Request::full(i % 4, ZooModel::B1, co, arrival)
            } else {
                let targets = vec![rng.below(co.n_vertices) as u32];
                Request::minibatch(i % 4, ZooModel::B2, co, targets, vec![8, 4], i as u64, arrival)
            }
        })
        .collect();
    let mut c = Coordinator::fleet(HwConfig::alveo_u250(), FleetConfig::default());
    let stats = c.run(reqs);
    println!("served 64 requests with streaming updates interleaved:");
    print!("{}", graphagile::harness::serve_summary(&stats));
}
