//! Cross-module property tests (seeded randomized invariants via
//! `util::forall`): compiler output structure, partition coverage,
//! functional equivalence over random models and graphs.

use graphagile::compiler::{compile, CompileOptions};
use graphagile::config::HwConfig;
use graphagile::exec::{golden_forward, FunctionalExecutor, RustBackend, WeightStore};
use graphagile::graph::{rmat::rmat_edges, GraphMeta, PartitionConfig, PartitionedGraph};
use graphagile::ir::{GraphGymConfig, ZooModel, ALL_MODELS};
use graphagile::isa::{AggOp, Instr};
use graphagile::prop_assert;
use graphagile::util::forall;

#[test]
fn prop_program_edge_totals_match_graph() {
    // For every Aggregate layer of a compiled program, the SpDMM edge
    // counts sum to fibers(f) x |E| — no edge lost or duplicated by
    // partitioning + chunking.
    forall("edge-conservation", 12, |rng| {
        let n = rng.range(100, 3000);
        let e = rng.range(200, 20_000);
        let f = rng.range(8, 600);
        let meta = GraphMeta::new("p", n, e, f, 4);
        let hw = HwConfig::alveo_u250();
        let tiles = graphagile::graph::rmat::rmat_tile_counts(
            &meta,
            Default::default(),
            rng.next_u64(),
            hw.n1() as u64,
        );
        let ir = ZooModel::B7.build(meta); // two Aggregates up front
        let exe = compile(
            &ir,
            &tiles,
            &hw,
            CompileOptions { order_opt: false, ..Default::default() },
        );
        let fibers = f.div_ceil(hw.n2() as u64);
        let agg = &exe.program.layers[0];
        let total: u64 = agg
            .blocks
            .iter()
            .flat_map(|b| &b.instrs)
            .filter_map(|i| match i {
                Instr::Spdmm { n_edges, .. } => Some(*n_edges as u64),
                _ => None,
            })
            .sum();
        prop_assert!(total == fibers * e, "{total} != {fibers} x {e}");
        Ok(())
    });
}

#[test]
fn prop_functional_equals_golden_on_random_graphgym_points() {
    forall("functional-equivalence-graphgym", 6, |rng| {
        let n = rng.range(80, 400);
        let e = rng.range(150, 2500);
        let meta = GraphMeta::new("p", n, e, 16, 4);
        let g = rmat_edges(meta, Default::default(), rng.next_u64()).gcn_normalized();
        let hw = HwConfig::functional_tiles();
        let cfg = PartitionConfig { n1: hw.n1() as u64, n2: hw.n2() as u64 };
        let pg = PartitionedGraph::build(&g, cfg);
        let gg = GraphGymConfig {
            n_pre: rng.below(2) as usize,
            n_mp: 1 + rng.below(3) as usize,
            n_post: 1,
            hidden: 16,
            aggop: if rng.below(2) == 0 { AggOp::Sum } else { AggOp::Max },
            residual: rng.below(2) == 1,
            batchnorm: rng.below(2) == 1,
            ..Default::default()
        };
        let ir = gg.build("gg-rand", g.meta.clone());
        let exe = compile(&ir, &pg.tile_counts(), &hw, CompileOptions::default());
        let store = WeightStore::deterministic(&exe.ir, rng.next_u64());
        let x = g.random_features(rng.next_u64());
        let golden = golden_forward(&exe.ir, &g, &store, &x);
        let mut fx = FunctionalExecutor::new(&exe, &pg, &store, RustBackend);
        let got = fx.run(&x);
        let scale = golden.iter().fold(1f32, |m, v| m.max(v.abs()));
        let err = golden
            .iter()
            .zip(&got)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        prop_assert!(
            err <= 1e-3 * scale.max(1.0),
            "err {err} at scale {scale} for {gg:?}"
        );
        Ok(())
    });
}

#[test]
fn prop_simulated_cycles_monotone_in_edges() {
    // More edges (same everything else) must never simulate faster.
    forall("cycles-monotone-edges", 8, |rng| {
        let n = rng.range(500, 5000);
        let e1 = rng.range(1000, 30_000);
        let e2 = e1 * 2;
        let hw = HwConfig::alveo_u250();
        let seed = rng.next_u64();
        let mut cycles = Vec::new();
        for e in [e1, e2] {
            let meta = GraphMeta::new("p", n, e, 64, 4);
            let tiles = graphagile::graph::rmat::rmat_tile_counts(
                &meta,
                Default::default(),
                seed,
                hw.n1() as u64,
            );
            let ir = ZooModel::B1.build(meta);
            let exe = compile(&ir, &tiles, &hw, CompileOptions::default());
            cycles.push(graphagile::sim::simulate(&exe.program, &hw).cycles);
        }
        prop_assert!(cycles[1] >= cycles[0], "{} < {}", cycles[1], cycles[0]);
        Ok(())
    });
}

#[test]
fn prop_every_zoo_binary_decodes_everywhere() {
    // Serialize with one build, decode with the library parser, and the
    // per-block compute-cycle accounting must be preserved exactly.
    forall("binary-stability", 5, |rng| {
        let meta = GraphMeta::new("p", rng.range(100, 2000), rng.range(200, 10_000), 128, 8);
        let hw = HwConfig::alveo_u250();
        let tiles = graphagile::graph::rmat::rmat_tile_counts(
            &meta,
            Default::default(),
            rng.next_u64(),
            hw.n1() as u64,
        );
        for m in ALL_MODELS {
            let exe = compile(&m.build(meta.clone()), &tiles, &hw, CompileOptions::default());
            let back =
                graphagile::isa::Program::from_bytes(&exe.program.to_bytes()).unwrap();
            for (a, b) in exe.program.layers.iter().zip(&back.layers) {
                for (x, y) in a.blocks.iter().zip(&b.blocks) {
                    prop_assert!(
                        x.compute_cycles(16) == y.compute_cycles(16),
                        "cycle accounting changed across serialization"
                    );
                }
            }
        }
        Ok(())
    });
}
