//! Mini-batch path integration suite:
//!
//! * **sampler determinism** — the same (targets, fanout, seed) always
//!   extracts the same ego-net, independent of sampler instance;
//! * **bucket-padding equivalence** — executing an ego-net padded to
//!   its power-of-two bucket is *bit-identical* on live rows to the
//!   exact-shape execution (padding rows are zero and edge-free, so
//!   they are inert through every layer type);
//! * **golden equivalence** — full-neighborhood sampling to the
//!   model's Aggregate depth reproduces the whole-graph golden outputs
//!   on target rows, for every zoo model (the acceptance criterion);
//! * **serve-level counters** — mixed mini-batch + whole-graph fleet
//!   runs replay bit-identically and account sampling/bucket/batch
//!   telemetry.

use graphagile::compiler::bucket::{canonical_tiles, compile_bucket};
use graphagile::compiler::{compile, BucketShape, CompileOptions};
use graphagile::config::HwConfig;
use graphagile::engine::MiniBatchRunner;
use graphagile::exec::{golden_forward, WeightStore};
use graphagile::graph::{
    full_fanout, rmat_edges, CooGraph, GraphMeta, Sampler, TileCounts,
};
use graphagile::ir::{LayerType, ZooModel, ALL_MODELS};

const WEIGHT_SEED: u64 = 33;

fn test_graph(n: u64, e: u64, f: u64, seed: u64) -> CooGraph {
    rmat_edges(GraphMeta::new("t", n, e, f, 4), Default::default(), seed).gcn_normalized()
}

/// Hops a model needs for exact mini-batch inference: one per
/// Aggregate layer (Vector-Inner layers read only endpoint features of
/// sampled edges, which the same budget covers).
fn hops_of(model: ZooModel, meta: &GraphMeta) -> usize {
    model.build(meta.clone()).count(LayerType::Aggregate)
}

#[test]
fn sampler_determinism_across_instances() {
    // Two independently-built samplers over the same graph: identical
    // draws. The per-vertex RNG is seeded by (seed, hop, vertex) alone,
    // so nothing about instance history or traversal order leaks in.
    let g = test_graph(400, 4000, 8, 3);
    let s1 = Sampler::new(g.clone());
    let s2 = Sampler::new(g);
    for seed in [0u64, 7, 1 << 40] {
        let a = s1.sample(&[1, 19, 200], &[5, 3], seed);
        let b = s2.sample(&[1, 19, 200], &[5, 3], seed);
        assert_eq!(a.origin, b.origin, "seed {seed}");
        assert_eq!(a.graph.src, b.graph.src, "seed {seed}");
        assert_eq!(a.graph.dst, b.graph.dst, "seed {seed}");
        assert_eq!(a.graph.w, b.graph.w, "seed {seed}");
        assert_eq!(a.n_targets, 3);
    }
}

#[test]
fn bucket_padding_is_bit_identical_on_live_rows() {
    // The same ego-net executed at its exact shape and padded into its
    // power-of-two bucket: every live row must match to the bit. Both
    // runs share kernels and tile schedule structure; padded rows are
    // zero-featured and edge-free, and per-row kernel arithmetic is
    // row-independent, so not even float reassociation can differ.
    let g = test_graph(300, 1800, 16, 9);
    let x = g.random_features(5);
    let meta = g.meta.clone();
    let sampler = Sampler::new(g);
    let hw = HwConfig::functional_tiles();
    for model in [ZooModel::B1, ZooModel::B3, ZooModel::B6] {
        let hops = hops_of(model, &meta);
        let ego = sampler.sample(&[2, 57, 111, 250], &vec![6; hops], 17);
        let exact_shape = BucketShape::exact(&ego.graph.meta);
        let bucket_shape = BucketShape::for_graph(&ego.graph.meta);
        assert!(bucket_shape.v >= exact_shape.v);
        let mut runner = MiniBatchRunner::new(hw.clone(), WEIGHT_SEED);
        let exact = runner.run_shaped(model, exact_shape, &ego, &x);
        let padded = runner.run_shaped(model, bucket_shape, &ego, &x);
        assert_eq!(
            exact.targets_out, padded.targets_out,
            "{}: padded execution diverged on live rows",
            model.key()
        );
        // Distinct shapes means two compiled programs in the runner.
        if exact_shape != bucket_shape {
            assert_eq!(runner.buckets(), 2);
        }
    }
}

#[test]
fn minibatch_matches_whole_graph_golden_on_subset_targets() {
    // Full-neighborhood sampling of a target subset to the model's
    // Aggregate depth: target rows match the whole-graph golden output
    // to float tolerance (edge order inside a row differs between the
    // sampled layout and the whole-graph CSR, so sums reassociate).
    let g = test_graph(300, 1500, 32, 9);
    let x = g.random_features(5);
    let meta = g.meta.clone();
    let hw = HwConfig::functional_tiles();
    let tiles = TileCounts::from_coo(&g, hw.n1() as u64);
    let sampler = Sampler::new(g);
    let targets = [5u32, 17, 42, 299];
    let classes = meta.n_classes as usize;
    for model in ALL_MODELS {
        let hops = hops_of(model, &meta);
        let ego = sampler.sample(&targets, &full_fanout(hops), 1);
        let mut runner = MiniBatchRunner::new(hw.clone(), WEIGHT_SEED);
        let p = runner.run(model, &ego, &x);
        // Golden reference over the optimized whole-graph IR — the same
        // passes the bucket program went through, so layer ids (and the
        // deterministic weights) line up.
        let ir = model.build(meta.clone());
        let exe = compile(&ir, &tiles, &hw, CompileOptions::default());
        let store = WeightStore::deterministic(&exe.ir, WEIGHT_SEED);
        let golden = golden_forward(&exe.ir, sampler.graph(), &store, &x);
        let scale = golden.iter().fold(1f32, |m, v| m.max(v.abs()));
        let mut err = 0f32;
        for (i, &t) in targets.iter().enumerate() {
            for c in 0..classes {
                let a = p.targets_out[i * classes + c];
                let b = golden[t as usize * classes + c];
                err = err.max((a - b).abs());
            }
        }
        assert!(
            err <= 1e-3 * scale.max(1.0),
            "{}: mini-batch vs golden max err {err} (scale {scale}, {} hops)",
            model.key(),
            hops
        );
    }
}

#[test]
fn minibatch_of_all_vertices_reproduces_whole_graph_for_every_model() {
    // The acceptance criterion: full-neighborhood sampling of ALL
    // vertices reproduces whole-graph outputs on (all) target rows for
    // every zoo model.
    let g = test_graph(200, 1000, 16, 7);
    let x = g.random_features(6);
    let meta = g.meta.clone();
    let hw = HwConfig::functional_tiles();
    let tiles = TileCounts::from_coo(&g, hw.n1() as u64);
    let sampler = Sampler::new(g);
    let all: Vec<u32> = (0..meta.n_vertices as u32).collect();
    for model in ALL_MODELS {
        let hops = hops_of(model, &meta);
        let ego = sampler.sample(&all, &full_fanout(hops), 2);
        assert_eq!(ego.n(), meta.n_vertices as usize);
        let mut runner = MiniBatchRunner::new(hw.clone(), WEIGHT_SEED);
        let p = runner.run(model, &ego, &x);
        let ir = model.build(meta.clone());
        let exe = compile(&ir, &tiles, &hw, CompileOptions::default());
        let store = WeightStore::deterministic(&exe.ir, WEIGHT_SEED);
        let golden = golden_forward(&exe.ir, sampler.graph(), &store, &x);
        assert_eq!(p.targets_out.len(), golden.len(), "{}", model.key());
        let scale = golden.iter().fold(1f32, |m, v| m.max(v.abs()));
        let err = golden
            .iter()
            .zip(&p.targets_out)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(
            err <= 1e-3 * scale.max(1.0),
            "{}: all-vertex mini-batch vs golden max err {err} (scale {scale})",
            model.key()
        );
    }
}

#[test]
fn fanout_capped_sampling_stays_close_on_high_coverage() {
    // Not an exactness claim — a sanity bound: with fanouts near the
    // graph's degree scale, sampled inference should track the full
    // result within a loose relative error on most target entries.
    // Guards against sign/indexing bugs that exactness tests on full
    // neighborhoods cannot see.
    let g = test_graph(300, 1500, 16, 13);
    let x = g.random_features(8);
    let meta = g.meta.clone();
    let hw = HwConfig::functional_tiles();
    let tiles = TileCounts::from_coo(&g, hw.n1() as u64);
    let sampler = Sampler::new(g);
    let targets = [99u32, 222, 250];
    let model = ZooModel::B1;
    let ir = model.build(meta.clone());
    let exe = compile(&ir, &tiles, &hw, CompileOptions::default());
    let store = WeightStore::deterministic(&exe.ir, WEIGHT_SEED);
    let golden = golden_forward(&exe.ir, sampler.graph(), &store, &x);
    let ego = sampler.sample(&targets, &[128, 64], 5);
    let mut runner = MiniBatchRunner::new(hw, WEIGHT_SEED);
    let p = runner.run(model, &ego, &x);
    let classes = meta.n_classes as usize;
    let scale = golden.iter().fold(1f32, |m, v| m.max(v.abs()));
    for (i, &t) in targets.iter().enumerate() {
        for c in 0..classes {
            let a = p.targets_out[i * classes + c];
            let b = golden[t as usize * classes + c];
            assert!(
                (a - b).abs() <= 0.5 * scale,
                "capped sample wildly off at target {t} class {c}: {a} vs {b}"
            );
        }
    }
}

#[test]
fn canonical_tiles_and_bucket_compile_line_up() {
    let shape = BucketShape::of(900, 7000, 32, 4);
    assert_eq!((shape.v, shape.e), (1024, 8192));
    let hw = HwConfig::functional_tiles();
    let tiles = canonical_tiles(shape, hw.n1() as u64);
    assert_eq!(tiles.total_edges(), shape.e as u64);
    let exe = compile_bucket(ZooModel::B2, shape, &hw);
    // Bucket programs carry no GA02 section and a full task grid.
    assert!(exe.program.thresholds.is_none());
    assert_eq!(exe.cfg.n1, hw.n1() as u64);
    assert!(exe.program.total_instrs() > 0);
}

#[test]
fn serve_minibatch_mixed_fleet_replays_and_counts() {
    use graphagile::serve::{Coordinator, FleetConfig, Request};

    let co = graphagile::graph::dataset("CO").unwrap();
    let build = || {
        let mut reqs: Vec<Request> = (0..30)
            .map(|i| {
                Request::minibatch(
                    i % 4,
                    if i % 2 == 0 { ZooModel::B1 } else { ZooModel::B7 },
                    co,
                    vec![(i * 37) % 2708, (i * 91) % 2708],
                    vec![10, 5],
                    i as u64,
                    // Spaced out so the mini class is not queue-bound:
                    // its p50 then reflects per-request cost, which is
                    // what the mini-vs-full comparison pins.
                    i as f64 * 1e-3,
                )
            })
            .collect();
        reqs.extend(
            (0..10).map(|i| Request::full(i, ZooModel::B2, co, i as f64 * 1e-4)),
        );
        reqs
    };
    let run = || {
        let cfg = FleetConfig { n_devices: 2, ..FleetConfig::default() };
        let mut c = Coordinator::fleet(HwConfig::alveo_u250(), cfg);
        let stats = c.run(build());
        (stats, c.responses)
    };
    let (s1, r1) = run();
    let (s2, r2) = run();
    assert_eq!(s1, s2, "mini-batch serving must replay bit-identically");
    assert_eq!(r1, r2);
    assert_eq!(s1.completed, 40);
    assert_eq!(s1.minibatched, 30);
    assert!(s1.sampled_vertices > 0 && s1.sampled_edges > 0);
    assert!(s1.bucket_hits > 0, "bucketing produced no hits");
    assert!(s1.p50_mini > 0.0 && s1.p50_full > 0.0);
    // Mini-batch programs are small: their median latency sits below
    // the whole-graph median on the same fleet.
    assert!(
        s1.p50_mini < s1.p50_full,
        "mini p50 {} !< full p50 {}",
        s1.p50_mini,
        s1.p50_full
    );
    // Every mini-batch response accounts a sampling stall; whole-graph
    // responses never do.
    for r in &r1 {
        if r.minibatch {
            assert!(r.t_sample > 0.0 && r.sampled_vertices > 0);
        } else {
            assert!(r.t_sample == 0.0 && r.sampled_vertices == 0);
        }
    }
}
