//! Cross-platform + accelerator comparison shapes (Figs. 17-18 and
//! Table 10): who wins, and by roughly what factor. Absolute numbers are
//! not asserted (our substrate is a model, not the authors' testbed);
//! ratios and orderings are.

use graphagile::baselines::{
    awb_gcn_loh, boostgcn_loh, framework_e2e, hygcn_loh, Framework, Processor,
};
use graphagile::compiler::{compile, CompileOptions};
use graphagile::config::HwConfig;
use graphagile::graph::dataset;
use graphagile::ir::ZooModel;
use graphagile::sim::{comm_seconds, simulate};

/// GraphAGILE hardware-side latency (LoH + PCIe) — LoC is wall-clock
/// dependent and excluded from ratio tests (see EXPERIMENTS.md).
fn ga_hw_e2e(m: ZooModel, key: &str) -> f64 {
    let ds = dataset(key).unwrap();
    let hw = HwConfig::alveo_u250();
    let tiles = ds.tile_counts(hw.n1() as u64);
    let exe = compile(&m.build(ds.meta()), &tiles, &hw, CompileOptions::default());
    let bytes = ds.meta().input_bytes() + exe.ir.weight_bytes() + exe.program.size_bytes();
    comm_seconds(&hw, bytes) + simulate(&exe.program, &hw).loh_seconds()
}

fn fw(m: ZooModel, key: &str, f: Framework, p: Processor) -> Option<f64> {
    framework_e2e(&m.build(dataset(key).unwrap().meta()), f, p).seconds()
}

#[test]
fn fig17_dgl_shape() {
    // Paper: 9.1x-20.1x vs DGL-CPU, 1.7x-3.9x vs DGL-GPU. Assert
    // GraphAGILE wins against CPU by a large factor and that the GPU
    // comparison lands within an order of magnitude of the paper band.
    for (m, key) in [(ZooModel::B2, "FL"), (ZooModel::B3, "PU"), (ZooModel::B6, "FL")] {
        let ga = ga_hw_e2e(m, key);
        let cpu = fw(m, key, Framework::Dgl, Processor::Cpu).unwrap();
        let gpu = fw(m, key, Framework::Dgl, Processor::Gpu).unwrap();
        let vs_cpu = cpu / ga;
        let vs_gpu = gpu / ga;
        assert!(vs_cpu > 2.0, "{m:?}/{key}: vs DGL-CPU only {vs_cpu:.2}x");
        assert!(
            (0.3..40.0).contains(&vs_gpu),
            "{m:?}/{key}: vs DGL-GPU {vs_gpu:.2}x out of band"
        );
    }
}

#[test]
fn fig18_pyg_shape() {
    // PyG is slower than DGL on sparse-heavy work; GraphAGILE's margin
    // vs PyG-CPU must exceed its margin vs DGL-CPU (paper: 10.3-47.1x
    // vs 9.1-20.1x).
    let m = ZooModel::B2;
    let ga = ga_hw_e2e(m, "FL");
    let pyg = fw(m, "FL", Framework::PyG, Processor::Cpu).unwrap();
    let dgl = fw(m, "FL", Framework::Dgl, Processor::Cpu).unwrap();
    assert!(pyg > dgl, "PyG-CPU must trail DGL-CPU");
    assert!(pyg / ga > dgl / ga);
}

#[test]
fn table10_shape() {
    // b2 on the four large graphs: GraphAGILE beats BoostGCN by
    // 1.0-2.5x-ish, beats HyGCN on RE, loses to AWB-GCN on RE (~0.5x).
    for key in ["FL", "YE"] {
        let ir = ZooModel::B2.build(dataset(key).unwrap().meta());
        let ds = dataset(key).unwrap();
        let hw = HwConfig::alveo_u250();
        let tiles = ds.tile_counts(hw.n1() as u64);
        let exe = compile(&ir, &tiles, &hw, CompileOptions::default());
        let ga = simulate(&exe.program, &hw).loh_seconds();
        let boost = boostgcn_loh(&ir);
        let ratio = boost / ga;
        assert!(
            (0.8..6.0).contains(&ratio),
            "{key}: vs BoostGCN {ratio:.2}x out of band"
        );
    }
    // Reddit: the full podium.
    let ir = ZooModel::B2.build(dataset("RE").unwrap().meta());
    let ds = dataset("RE").unwrap();
    let hw = HwConfig::alveo_u250();
    let tiles = ds.tile_counts(hw.n1() as u64);
    let exe = compile(&ir, &tiles, &hw, CompileOptions::default());
    let ga = simulate(&exe.program, &hw).loh_seconds();
    let hygcn = hygcn_loh(&ir);
    let awb = awb_gcn_loh(&ir);
    assert!(hygcn > ga, "HyGCN must trail GraphAGILE on RE");
    assert!(awb < ga, "AWB-GCN must lead GraphAGILE on RE (paper: 0.51x)");
}

#[test]
fn oom_cells_match_paper() {
    // Fig. 18's OOM pattern (see baselines::roofline for the YE caveat).
    assert!(fw(ZooModel::B2, "RE", Framework::PyG, Processor::Gpu).is_none());
    assert!(fw(ZooModel::B2, "AP", Framework::PyG, Processor::Gpu).is_none());
    assert!(fw(ZooModel::B2, "AP", Framework::PyG, Processor::Cpu).is_none());
    assert!(fw(ZooModel::B2, "RE", Framework::PyG, Processor::Cpu).is_some());
    assert!(fw(ZooModel::B2, "RE", Framework::Dgl, Processor::Gpu).is_some());
}
