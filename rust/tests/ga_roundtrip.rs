//! Property-style coverage for the `.ga` binary format (Table 8): every
//! compiled program must survive `Program::from_bytes(to_bytes())`
//! exactly, across the whole zoo x dataset grid and under randomized
//! compiler options (`util::prop` / `util::rng` drive the cases).

use graphagile::compiler::{compile, CompileOptions, Executable};
use graphagile::config::HwConfig;
use graphagile::exec::WeightStore;
use graphagile::graph::{Dataset, ALL_DATASETS};
use graphagile::ir::{ZooModel, ALL_MODELS};
use graphagile::isa::Program;
use graphagile::prop_assert;
use graphagile::quant::{calibrate, CalibrationProfile, ScaleTable};
use graphagile::util::forall;

/// Compile one (model, dataset) instance at CI scale.
fn build(model: ZooModel, d: &Dataset, hw: &HwConfig, opts: CompileOptions) -> Executable {
    // Scale the synthetic datasets down so the full grid stays fast;
    // the wire format does not care about graph size.
    let d = d.scaled(128);
    let tiles = d.tile_counts(hw.n1() as u64);
    let ir = model.build(d.meta());
    compile(&ir, &tiles, hw, opts)
}

/// A real calibrated scale table for `exe` (deterministic weights +
/// the analytic feature-range profile) — the same path the serving
/// cache uses to mint GA03 programs.
fn calibrated_table(exe: &Executable) -> ScaleTable {
    let store = WeightStore::deterministic(&exe.ir, 33);
    let meta = &exe.ir.graph;
    let profile = CalibrationProfile::analytic(meta.n_vertices, meta.n_edges);
    calibrate(&exe.ir, &store, &profile).table
}

#[test]
fn every_zoo_model_on_every_dataset_roundtrips() {
    let hw = HwConfig::alveo_u250();
    for model in ALL_MODELS {
        for d in &ALL_DATASETS {
            let exe = build(model, d, &hw, CompileOptions::default());
            assert!(
                exe.program.thresholds.is_some(),
                "{}/{}: default compile must embed the GA02 threshold section",
                model.key(),
                d.key
            );
            let bytes = exe.program.to_bytes();
            assert_eq!(
                bytes.len() as u64,
                exe.program.size_bytes(),
                "{}/{}: size_bytes out of sync with serializer",
                model.key(),
                d.key
            );
            let back = Program::from_bytes(&bytes)
                .unwrap_or_else(|e| panic!("{}/{}: decode failed: {e:#}", model.key(), d.key));
            assert_eq!(back, exe.program, "{}/{} roundtrip", model.key(), d.key);
        }
    }
}

#[test]
fn roundtrip_holds_under_random_options() {
    let hw = HwConfig::alveo_u250();
    forall("ga-roundtrip-options", 16, |rng| {
        let model = ALL_MODELS[rng.below(ALL_MODELS.len() as u64) as usize];
        let d = ALL_DATASETS[rng.below(ALL_DATASETS.len() as u64) as usize];
        let opts = CompileOptions {
            order_opt: rng.below(2) == 0,
            fusion: rng.below(2) == 0,
            skip_empty_tiles: rng.below(2) == 0,
            dynamic_thresholds: rng.below(2) == 0,
        };
        let mut exe = build(model, &d, &hw, opts);
        prop_assert!(
            exe.program.thresholds.is_some() == opts.dynamic_thresholds,
            "threshold section must track the compile option"
        );
        // Half the cases additionally carry a GA03 scale section, in
        // all four (thresholds x scales) combinations.
        let quantized = rng.below(2) == 0;
        if quantized {
            exe.program.scales = Some(calibrated_table(&exe));
        }
        let bytes = exe.program.to_bytes();
        let want_magic: &[u8] = if quantized {
            b"GA03"
        } else if opts.dynamic_thresholds {
            b"GA02"
        } else {
            b"GA01"
        };
        prop_assert!(
            &bytes[..4] == want_magic,
            "writer must emit the oldest sufficient magic, got {:?}",
            &bytes[..4]
        );
        let back = Program::from_bytes(&bytes)
            .map_err(|e| format!("{}/{} {opts:?}: decode failed: {e:#}", model.key(), d.key))?;
        prop_assert!(
            back == exe.program,
            "{}/{} {opts:?}: decoded program differs",
            model.key(),
            d.key
        );
        prop_assert!(
            back.total_instrs() == exe.program.total_instrs(),
            "instr count drifted through the wire"
        );
        Ok(())
    });
}

#[test]
fn threshold_section_roundtrips_in_presence_and_absence() {
    let hw = HwConfig::alveo_u250();
    // Presence: the default compile carries the GA02 section.
    let with = build(ZooModel::B2, &ALL_DATASETS[1], &hw, CompileOptions::default());
    let tt = with.program.thresholds.clone().expect("GA02 section expected");
    assert!(!tt.entries.is_empty());
    let bytes = with.program.to_bytes();
    assert_eq!(&bytes[..4], b"GA02");
    let back = Program::from_bytes(&bytes).unwrap();
    assert_eq!(back.thresholds.as_ref(), Some(&tt));
    assert_eq!(back, with.program);
    // Absence: disabling the option produces legacy GA01 wire bytes.
    let without = build(
        ZooModel::B2,
        &ALL_DATASETS[1],
        &hw,
        CompileOptions { dynamic_thresholds: false, ..Default::default() },
    );
    let lbytes = without.program.to_bytes();
    assert_eq!(&lbytes[..4], b"GA01");
    let lback = Program::from_bytes(&lbytes).unwrap();
    assert!(lback.thresholds.is_none());
    assert_eq!(lback, without.program);
    // Old and new binaries describe the same instruction stream.
    assert_eq!(lback.total_instrs(), back.total_instrs());
}

#[test]
fn legacy_ga01_binaries_still_load() {
    // Simulate a pre-GA02 binary: strip the table from a modern program
    // and serialize — the writer falls back to the GA01 layout, and the
    // reader reports `thresholds: None` instead of erroring.
    let hw = HwConfig::alveo_u250();
    let exe = build(ZooModel::B1, &ALL_DATASETS[2], &hw, CompileOptions::default());
    let mut legacy = exe.program.clone();
    legacy.thresholds = None;
    let bytes = legacy.to_bytes();
    assert_eq!(&bytes[..4], b"GA01");
    assert_eq!(bytes.len() as u64, legacy.size_bytes());
    let back = Program::from_bytes(&bytes).unwrap();
    assert!(back.thresholds.is_none());
    assert_eq!(back, legacy);
}

#[test]
fn truncated_or_corrupt_binaries_are_rejected() {
    let hw = HwConfig::alveo_u250();
    let exe = build(ZooModel::B1, &ALL_DATASETS[1], &hw, CompileOptions::default());
    let bytes = exe.program.to_bytes();
    forall("ga-truncation", 32, |rng| {
        let cut = rng.below(bytes.len() as u64 - 1) as usize;
        prop_assert!(
            Program::from_bytes(&bytes[..cut]).is_err(),
            "truncation at {cut}/{} must be rejected",
            bytes.len()
        );
        Ok(())
    });
}

#[test]
fn scale_section_roundtrips_in_presence_and_absence() {
    let hw = HwConfig::alveo_u250();
    // Absence first: without scales the wire bytes are plain GA02 —
    // attaching the section must not disturb older programs.
    let mut exe = build(ZooModel::B2, &ALL_DATASETS[1], &hw, CompileOptions::default());
    let ga02_bytes = exe.program.to_bytes();
    assert_eq!(&ga02_bytes[..4], b"GA02");
    // Presence: the calibrated table promotes the binary to GA03 and
    // survives the wire exactly.
    let table = calibrated_table(&exe);
    assert!(!table.entries.is_empty());
    exe.program.scales = Some(table.clone());
    let bytes = exe.program.to_bytes();
    assert_eq!(&bytes[..4], b"GA03");
    assert_eq!(bytes.len() as u64, exe.program.size_bytes());
    let back = Program::from_bytes(&bytes).unwrap();
    assert_eq!(back.scales.as_ref(), Some(&table));
    assert_eq!(back, exe.program);
    // Detaching the section falls back to byte-identical GA02 output:
    // GA01/GA02 consumers are unaffected by the GA03 feature.
    exe.program.scales = None;
    assert_eq!(exe.program.to_bytes(), ga02_bytes);
}

#[test]
fn legacy_ga01_and_ga02_binaries_load_byte_identically() {
    // A GA03-aware reader must parse pre-scale binaries to programs
    // with `scales: None` whose re-serialization reproduces the input
    // bytes exactly — the on-disk corpus never rewrites.
    let hw = HwConfig::alveo_u250();
    let exe = build(ZooModel::B5, &ALL_DATASETS[0], &hw, CompileOptions::default());
    let ga02 = exe.program.to_bytes();
    assert_eq!(&ga02[..4], b"GA02");
    let back = Program::from_bytes(&ga02).unwrap();
    assert!(back.scales.is_none());
    assert_eq!(back.to_bytes(), ga02);
    let mut legacy = exe.program.clone();
    legacy.thresholds = None;
    let ga01 = legacy.to_bytes();
    assert_eq!(&ga01[..4], b"GA01");
    let back = Program::from_bytes(&ga01).unwrap();
    assert!(back.thresholds.is_none() && back.scales.is_none());
    assert_eq!(back.to_bytes(), ga01);
}

#[test]
fn corrupted_scale_flag_and_truncated_scale_section_are_rejected() {
    let hw = HwConfig::alveo_u250();
    let mut exe = build(ZooModel::B1, &ALL_DATASETS[2], &hw, CompileOptions::default());
    exe.program.scales = Some(calibrated_table(&exe));
    let bytes = exe.program.to_bytes();
    assert_eq!(&bytes[..4], b"GA03");
    // Offset of the scale-section flag: header + names + GA02 section.
    let p = &exe.program;
    let mut at = 4 + 4 + 4;
    at += 2 + p.model_name.len();
    at += 2 + p.graph_name.len();
    at += 1 + p.thresholds.as_ref().unwrap().size_bytes() as usize;
    assert_eq!(bytes[at], 1, "scale-section flag expected at {at}");
    // A flag byte that is neither 0 nor 1 is rejected, not guessed at.
    let mut corrupt = bytes.clone();
    corrupt[at] = 7;
    let err = Program::from_bytes(&corrupt).unwrap_err();
    assert!(format!("{err:#}").contains("scale-section flag"), "{err:#}");
    // Every truncation inside the scale table body is rejected.
    let scale_end = at + 1 + p.scales.as_ref().unwrap().size_bytes() as usize;
    forall("ga3-scale-truncation", 16, |rng| {
        let cut = at + 1 + rng.below((scale_end - at) as u64) as usize;
        prop_assert!(
            Program::from_bytes(&bytes[..cut]).is_err(),
            "truncation inside the scale section at {cut} must be rejected"
        );
        Ok(())
    });
}
