//! Property-style coverage for the `.ga` binary format (Table 8): every
//! compiled program must survive `Program::from_bytes(to_bytes())`
//! exactly, across the whole zoo x dataset grid and under randomized
//! compiler options (`util::prop` / `util::rng` drive the cases).

use graphagile::compiler::{compile, CompileOptions, Executable};
use graphagile::config::HwConfig;
use graphagile::graph::{Dataset, ALL_DATASETS};
use graphagile::ir::{ZooModel, ALL_MODELS};
use graphagile::isa::Program;
use graphagile::prop_assert;
use graphagile::util::forall;

/// Compile one (model, dataset) instance at CI scale.
fn build(model: ZooModel, d: &Dataset, hw: &HwConfig, opts: CompileOptions) -> Executable {
    // Scale the synthetic datasets down so the full grid stays fast;
    // the wire format does not care about graph size.
    let d = d.scaled(128);
    let tiles = d.tile_counts(hw.n1() as u64);
    let ir = model.build(d.meta());
    compile(&ir, &tiles, hw, opts)
}

#[test]
fn every_zoo_model_on_every_dataset_roundtrips() {
    let hw = HwConfig::alveo_u250();
    for model in ALL_MODELS {
        for d in &ALL_DATASETS {
            let exe = build(model, d, &hw, CompileOptions::default());
            let bytes = exe.program.to_bytes();
            assert_eq!(
                bytes.len() as u64,
                exe.program.size_bytes(),
                "{}/{}: size_bytes out of sync with serializer",
                model.key(),
                d.key
            );
            let back = Program::from_bytes(&bytes)
                .unwrap_or_else(|e| panic!("{}/{}: decode failed: {e:#}", model.key(), d.key));
            assert_eq!(back, exe.program, "{}/{} roundtrip", model.key(), d.key);
        }
    }
}

#[test]
fn roundtrip_holds_under_random_options() {
    let hw = HwConfig::alveo_u250();
    forall("ga-roundtrip-options", 16, |rng| {
        let model = ALL_MODELS[rng.below(ALL_MODELS.len() as u64) as usize];
        let d = ALL_DATASETS[rng.below(ALL_DATASETS.len() as u64) as usize];
        let opts = CompileOptions {
            order_opt: rng.below(2) == 0,
            fusion: rng.below(2) == 0,
            skip_empty_tiles: rng.below(2) == 0,
        };
        let exe = build(model, &d, &hw, opts);
        let back = Program::from_bytes(&exe.program.to_bytes())
            .map_err(|e| format!("{}/{} {opts:?}: decode failed: {e:#}", model.key(), d.key))?;
        prop_assert!(
            back == exe.program,
            "{}/{} {opts:?}: decoded program differs",
            model.key(),
            d.key
        );
        prop_assert!(
            back.total_instrs() == exe.program.total_instrs(),
            "instr count drifted through the wire"
        );
        Ok(())
    });
}

#[test]
fn truncated_or_corrupt_binaries_are_rejected() {
    let hw = HwConfig::alveo_u250();
    let exe = build(ZooModel::B1, &ALL_DATASETS[1], &hw, CompileOptions::default());
    let bytes = exe.program.to_bytes();
    forall("ga-truncation", 32, |rng| {
        let cut = rng.below(bytes.len() as u64 - 1) as usize;
        prop_assert!(
            Program::from_bytes(&bytes[..cut]).is_err(),
            "truncation at {cut}/{} must be rejected",
            bytes.len()
        );
        Ok(())
    });
}
