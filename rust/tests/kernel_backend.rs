//! Property suite pinning the optimized kernel backend against the
//! naive reference kernels (`ops::reference`) across random shapes, all
//! aggregation ops and activations — plus CSR<->COO round-trips on
//! [`PartitionedGraph`] and the zero-alloc steady-state guarantee. The
//! epsilon accounts for reassociated float sums (blocked GEMM and the
//! 4-way SDDMM dot change summation order, never values).

use graphagile::compiler::{compile, CompileOptions};
use graphagile::config::HwConfig;
use graphagile::exec::ops::{self, reference};
use graphagile::exec::{
    golden_forward, golden_forward_reference, FunctionalExecutor, ReferenceBackend, RustBackend,
    WeightStore,
};
use graphagile::graph::{rmat::rmat_edges, GraphMeta, PartitionConfig, PartitionedGraph};
use graphagile::ir::ZooModel;
use graphagile::isa::{Activation, AggOp};
use graphagile::prop_assert;
use graphagile::util::forall;

const ACTS: [Activation; 8] = [
    Activation::None,
    Activation::Relu,
    Activation::LRelu,
    Activation::PRelu,
    Activation::Swish,
    Activation::Exp,
    Activation::Sigmoid,
    Activation::Elu,
];

const AGGS: [AggOp; 4] = [AggOp::Sum, AggOp::Mean, AggOp::Max, AggOp::Min];

fn close(a: &[f32], b: &[f32], eps: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length {} != {}", a.len(), b.len()));
    }
    let scale = b.iter().fold(1f32, |m, v| m.max(v.abs()));
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if (x - y).abs() > eps * scale {
            return Err(format!("[{i}] {x} vs {y} (scale {scale})"));
        }
    }
    Ok(())
}

#[test]
fn prop_gemm_optimized_matches_reference() {
    forall("gemm-opt-vs-ref", 40, |rng| {
        let m = rng.range(1, 130) as usize;
        let k = rng.range(1, 200) as usize;
        let n = rng.range(1, 300) as usize;
        let act = ACTS[rng.below(ACTS.len() as u64) as usize];
        // ~25% exact zeros exercise the sparsity skip paths.
        let h: Vec<f32> = (0..m * k)
            .map(|_| if rng.below(4) == 0 { 0.0 } else { rng.normal() * 0.3 })
            .collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() * 0.3).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let want = reference::gemm_bias_act(&h, m, k, &w, n, &b, act);
        let got = ops::gemm_bias_act(&h, m, k, &w, n, &b, act);
        close(&got, &want, 1e-3).map_err(|e| format!("{m}x{k}x{n} {act:?}: {e}"))
    });
}

#[test]
fn prop_spdmm_optimized_matches_reference_all_aggops() {
    forall("spdmm-opt-vs-ref", 40, |rng| {
        let n_in = rng.range(1, 300) as usize;
        let n_out = rng.range(1, 300) as usize;
        let f = rng.range(1, 96) as usize;
        let e = rng.range(0, 4000) as usize;
        let agg = AGGS[rng.below(AGGS.len() as u64) as usize];
        let src: Vec<u32> = (0..e).map(|_| rng.below(n_in as u64) as u32).collect();
        let dst: Vec<u32> = (0..e).map(|_| rng.below(n_out as u64) as u32).collect();
        let ew: Vec<f32> = (0..e).map(|_| rng.normal()).collect();
        let h: Vec<f32> = (0..n_in * f).map(|_| rng.normal()).collect();
        let want = reference::spdmm(&src, &dst, &ew, &h, f, n_out, agg);
        let got = ops::spdmm(&src, &dst, &ew, &h, f, n_out, agg);
        // Max/Min pick the same element regardless of order: exact.
        let eps = if matches!(agg, AggOp::Max | AggOp::Min) { 0.0 } else { 1e-3 };
        close(&got, &want, eps).map_err(|e| format!("{agg:?} e={} f={f}: {e}", src.len()))
    });
}

#[test]
fn prop_sddmm_optimized_matches_reference() {
    forall("sddmm-opt-vs-ref", 40, |rng| {
        let n = rng.range(1, 300) as usize;
        let f = rng.range(1, 96) as usize;
        let e = rng.range(0, 4000) as usize;
        let src: Vec<u32> = (0..e).map(|_| rng.below(n as u64) as u32).collect();
        let dst: Vec<u32> = (0..e).map(|_| rng.below(n as u64) as u32).collect();
        let h: Vec<f32> = (0..n * f).map(|_| rng.normal()).collect();
        let want = reference::sddmm(&src, &dst, &h, &h, f);
        let got = ops::sddmm(&src, &dst, &h, &h, f);
        close(&got, &want, 1e-3).map_err(|err| format!("e={e} f={f}: {err}"))
    });
}

#[test]
fn prop_partitioned_csr_roundtrips_to_coo() {
    // Satellite: CSR<->COO round-trip on PartitionedGraph — every
    // subshard's CSR view reproduces the exact edge multiset, and the
    // perm gather hits the exact per-edge weights.
    forall("partitioned-csr-roundtrip", 15, |rng| {
        let n = rng.range(2, 600);
        let m = rng.range(1, 5000);
        let n1 = 1 << rng.range(3, 9);
        let meta = GraphMeta::new("p", n, m, 8, 2);
        let g = rmat_edges(meta, Default::default(), rng.next_u64());
        let pg = PartitionedGraph::build(&g, PartitionConfig { n1, n2: 8 });
        pg.validate().map_err(|e| e)?;
        let mut total = 0usize;
        for i in 0..pg.shards {
            for j in 0..pg.shards {
                let range = pg.subshard(i, j);
                let csr = pg.csr(i, j);
                total += csr.nnz();
                let mut from_csr: Vec<(u32, u32, u32)> = Vec::new();
                for r in 0..csr.rows as usize {
                    for slot in csr.row(r) {
                        let e = range.start + csr.perm[slot] as usize;
                        from_csr.push((
                            j as u32 * n1 as u32 + csr.cols[slot],
                            i as u32 * n1 as u32 + r as u32,
                            pg.w[e].to_bits(),
                        ));
                    }
                }
                let mut from_coo: Vec<(u32, u32, u32)> = range
                    .map(|e| (pg.src[e], pg.dst[e], pg.w[e].to_bits()))
                    .collect();
                from_csr.sort_unstable();
                from_coo.sort_unstable();
                prop_assert!(from_csr == from_coo, "({i},{j}) multiset mismatch");
            }
        }
        prop_assert!(total == g.m(), "csr covers {total} of {} edges", g.m());
        Ok(())
    });
}

#[test]
fn golden_reference_matches_golden_optimized_across_zoo() {
    let meta = GraphMeta::new("t", 220, 1100, 16, 4);
    let g = rmat_edges(meta, Default::default(), 21).gcn_normalized();
    for model in graphagile::ir::ALL_MODELS {
        let ir = model.build(g.meta.clone());
        let store = WeightStore::deterministic(&ir, 42);
        let x = g.random_features(3);
        let want = golden_forward_reference(&ir, &g, &store, &x);
        let got = golden_forward(&ir, &g, &store, &x);
        close(&got, &want, 1e-3).unwrap_or_else(|e| panic!("{}: {e}", model.key()));
    }
}

#[test]
fn tile_backends_agree_and_warm_arena_is_allocation_free() {
    let meta = GraphMeta::new("t", 300, 1600, 32, 4);
    let g = rmat_edges(meta, Default::default(), 17).gcn_normalized();
    let hw = HwConfig::functional_tiles();
    let cfg = PartitionConfig { n1: hw.n1() as u64, n2: hw.n2() as u64 };
    let pg = PartitionedGraph::build(&g, cfg);
    for model in [ZooModel::B1, ZooModel::B5, ZooModel::B7] {
        let ir = model.build(g.meta.clone());
        let exe = compile(&ir, &pg.tile_counts(), &hw, CompileOptions::default());
        let store = WeightStore::deterministic(&exe.ir, 33);
        let x = g.random_features(5);
        let naive = FunctionalExecutor::new(&exe, &pg, &store, ReferenceBackend).run(&x);
        let mut fx = FunctionalExecutor::new(&exe, &pg, &store, RustBackend);
        let opt = fx.run(&x);
        close(&opt, &naive, 1e-3).unwrap_or_else(|e| panic!("{}: {e}", exe.ir.name));
        // Steady state: rebuild the executor around the warm state; the
        // only fresh allocation allowed is the replacement for the
        // output matrix that escaped to the caller.
        let (arena, packed, _) = fx.into_state();
        let cold_fresh = arena.stats().fresh;
        let mut warm = FunctionalExecutor::with_state(
            &exe,
            &pg,
            &store,
            RustBackend,
            arena,
            Some(packed),
            None,
        );
        let again = warm.run(&x);
        assert_eq!(opt, again, "{}: warm run changed numerics", exe.ir.name);
        let fresh = warm.arena.stats().fresh - cold_fresh;
        assert!(fresh <= 1, "{}: warm run allocated {fresh} buffers", exe.ir.name);
    }
}
