//! Streaming-update integration suite:
//!
//! * **golden equivalence** — after a ~1% edge-churn batch, the
//!   incrementally maintained (dirty-subshard-only) partition produces
//!   *bit-identical* functional outputs to a from-scratch rebuild at
//!   the same epoch, for every zoo model (the acceptance criterion);
//! * **epoch snapshots** — sealed epochs read back bit-exactly across
//!   later updates; sampling merges the base CSR with the delta
//!   overlay deterministically;
//! * **incrementality** — small churn dirties a small tile fraction
//!   and rebuilds only those tiles' edges;
//! * **serve-level** — update-interleaved fleets replay bit-identically
//!   and the epoch-versioned cache keys never collide (property test).

use graphagile::compiler::BucketShape;
use graphagile::config::HwConfig;
use graphagile::engine::StreamingSession;
use graphagile::graph::{
    full_fanout, rmat_edges, CooGraph, EgoNet, GraphMeta, PartitionConfig, PartitionedGraph,
    TileCounts,
};
use graphagile::ir::{ZooModel, ALL_MODELS};
use graphagile::serve::{Key, Precision};
use graphagile::sparsity::adjacency_density;
use graphagile::stream::{ChurnGenerator, ChurnSpec, DynamicGraph, UpdateBatch};
use graphagile::util::forall;

const WEIGHT_SEED: u64 = 33;

fn test_graph(n: u64, e: u64, f: u64, seed: u64) -> CooGraph {
    rmat_edges(GraphMeta::new("t", n, e, f, 4), Default::default(), seed).gcn_normalized()
}

/// ~1% churn of `g`'s edge count.
fn one_percent_churn(g: &DynamicGraph, seed: u64) -> UpdateBatch {
    let edges = g.n_edges();
    let spec = ChurnSpec {
        inserts: (edges / 100).max(8) as u32,
        deletes: (edges / 400).max(2) as u32,
        new_vertices: 0,
    };
    ChurnGenerator::new(Default::default(), seed).next_batch(g, spec)
}

#[test]
fn incremental_rebuild_is_bit_identical_across_the_zoo() {
    // The acceptance criterion: apply a 1% churn batch, then compare
    // the incremental dirty-subshard rebuild against a from-scratch
    // partition of the materialized epoch — the partitions must be
    // equal as data structures, and the functional outputs of every
    // zoo model must match to the bit.
    let g = test_graph(300, 1800, 16, 9);
    let hw = HwConfig::functional_tiles();
    let mut s = StreamingSession::new(g, hw.clone(), WEIGHT_SEED);
    let batch = one_percent_churn(&s.dyng, 5);
    let r = s.apply(&batch);
    assert!(r.inserted > 0 && r.deleted > 0, "churn must do both kinds of work");
    assert!(r.dirty_subshards >= 1 && r.rebuilt_edges > 0);
    // Structural equality of the partitions.
    let cfg = PartitionConfig { n1: hw.n1() as u64, n2: hw.n2() as u64 };
    let materialized = s.dyng.materialize(s.epoch());
    let scratch = PartitionedGraph::build(&materialized, cfg);
    assert_eq!(s.dyng.export_partitioned(), scratch);
    assert_eq!(s.dyng.tile_counts(), TileCounts::from_coo(&materialized, cfg.n1));
    // Bit-identical numerics for every zoo model: the incremental
    // session vs a cold session built from the materialized epoch.
    let x = materialized.random_features(5);
    let mut cold = StreamingSession::new(materialized.clone(), hw.clone(), WEIGHT_SEED);
    for model in ALL_MODELS {
        let inc = s.infer(model, &x).unwrap();
        let fresh = cold.infer(model, &x).unwrap();
        assert_eq!(
            inc.output, fresh.output,
            "{}: incremental output diverged from from-scratch",
            model.key()
        );
    }
}

#[test]
fn repeated_churn_epochs_stay_equivalent() {
    // Five churn epochs in a row (including deletes of earlier
    // inserts and a vertex growth): the incremental partition tracks
    // the from-scratch build at every epoch.
    let g = test_graph(400, 3000, 8, 21);
    let cfg = PartitionConfig { n1: 64, n2: 8 };
    let mut d = DynamicGraph::new(g, cfg);
    let mut gen = ChurnGenerator::new(Default::default(), 13);
    for e in 1..=5u32 {
        let spec = ChurnSpec {
            inserts: 30,
            deletes: 12,
            new_vertices: if e == 3 { 40 } else { 0 },
        };
        let batch = gen.next_batch(&d, spec);
        let r = d.apply(&batch);
        assert_eq!(r.epoch, e);
        let materialized = d.materialize(e);
        assert_eq!(
            d.export_partitioned(),
            PartitionedGraph::build(&materialized, cfg),
            "epoch {e} diverged"
        );
        // Incremental density re-profiling agrees with a full scan.
        assert_eq!(
            d.adj_density(),
            adjacency_density(&d.tile_counts(), d.n_vertices()),
            "epoch {e} density drifted"
        );
    }
    assert_eq!(d.n_vertices(), 440);
}

#[test]
fn small_churn_dirties_a_small_fraction() {
    // On a fine partition (many tiles), 1% churn touches a small
    // fraction of the subshards and rebuilds a small fraction of the
    // edges — the quantity behind the bench's apply-vs-rebuild floor.
    let g = test_graph(4096, 32768, 8, 3);
    let mut d = DynamicGraph::new(g, PartitionConfig { n1: 128, n2: 8 });
    let batch = one_percent_churn(&d, 7);
    let r = d.apply(&batch);
    let dirty_frac = r.dirty_subshards as f64 / r.total_subshards as f64;
    let rebuilt_frac = r.rebuilt_edges as f64 / r.live_edges as f64;
    assert!(dirty_frac < 0.5, "dirty fraction {dirty_frac:.3} too high");
    assert!(rebuilt_frac < 0.5, "rebuilt fraction {rebuilt_frac:.3} too high");
    assert!(r.rebuilt_edges > 0);
}

#[test]
fn overlay_sampling_sees_inserts_and_deletes() {
    let g = test_graph(300, 2000, 8, 11);
    let mut d = DynamicGraph::new(g, PartitionConfig { n1: 64, n2: 8 });
    // Insert a fresh two-hop chain into vertex 7's neighborhood.
    d.apply(&UpdateBatch {
        inserts: vec![(250, 7, 1.0), (123, 250, 1.0)],
        deletes: vec![],
        new_vertices: 0,
    });
    let ego = d.sample(&[7], &full_fanout(2), 3);
    assert!(ego.origin.contains(&250), "overlay insert missing from the ego-net");
    assert!(ego.origin.contains(&123), "second-hop overlay insert missing");
    // Epoch pinning: the epoch-0 sample of the same request never
    // contains the inserted vertices' edge.
    let ego0 = d.sample_at(0, &[7], &full_fanout(2), 3);
    let pair = |e: &EgoNet| {
        e.graph
            .src
            .iter()
            .zip(&e.graph.dst)
            .map(|(&s, &dd)| (e.origin[s as usize], e.origin[dd as usize]))
            .collect::<Vec<_>>()
    };
    // Count-based (the base graph may happen to contain (250, 7) too):
    // the insert adds exactly one copy, the delete removes one.
    let count = |e: &EgoNet| pair(e).iter().filter(|&&p| p == (250, 7)).count();
    let n0 = count(&ego0);
    assert_eq!(count(&ego), n0 + 1);
    d.apply(&UpdateBatch {
        inserts: vec![],
        deletes: vec![(250, 7)],
        new_vertices: 0,
    });
    let ego2 = d.sample(&[7], &full_fanout(2), 3);
    assert_eq!(count(&ego2), n0);
}

#[test]
fn bucket_shapes_are_epoch_free() {
    // The serve-cache invariant behind "bucket executables survive
    // epochs": a bucket key depends only on the sampled shape, so the
    // same-shaped ego-net before and after churn maps to the same key.
    let g = test_graph(300, 2000, 8, 17);
    let mut d = DynamicGraph::new(g, PartitionConfig { n1: 64, n2: 8 });
    let before = d.sample(&[5, 9], &[4, 2], 1);
    d.apply(&one_percent_churn(&d, 23));
    let after = d.sample(&[5, 9], &[4, 2], 1);
    let kb = Key::Bucket(ZooModel::B1, BucketShape::for_graph(&before.graph.meta), Precision::F32);
    let ka = Key::Bucket(ZooModel::B1, BucketShape::for_graph(&after.graph.meta), Precision::F32);
    assert_eq!(kb, ka, "small churn must not move the pow2 bucket");
}

#[test]
fn prop_epoch_versioned_keys_never_collide() {
    // The satellite property test: distinct (model, graph, epoch)
    // triples produce distinct Whole keys, and Whole keys never equal
    // Bucket keys. Collision here would silently serve a stale epoch.
    let graphs = ["CI", "CO", "PU", "FL", "RE", "YE", "AP"];
    forall("epoch-key-uniqueness", 50, |rng| {
        let mut keys = std::collections::HashSet::new();
        let mut triples = std::collections::HashSet::new();
        for _ in 0..64 {
            let model = ALL_MODELS[rng.below(ALL_MODELS.len() as u64) as usize];
            let gkey = graphs[rng.below(graphs.len() as u64) as usize];
            let epoch = rng.below(1 << 20) as u32;
            triples.insert((model.key(), gkey, epoch));
            keys.insert(Key::Whole(model, gkey, epoch, Precision::F32));
        }
        graphagile::prop_assert!(
            keys.len() == triples.len(),
            "distinct triples {} != distinct keys {}",
            triples.len(),
            keys.len()
        );
        // Cross-class: a Whole key never equals a Bucket key.
        let shape = BucketShape::of(
            1 + rng.below(4096),
            1 + rng.below(65536),
            8,
            4,
        );
        let bucket = Key::Bucket(ALL_MODELS[0], shape, Precision::F32);
        graphagile::prop_assert!(
            !keys.contains(&bucket),
            "bucket key collided with a whole-graph key"
        );
        Ok(())
    });
}

#[test]
fn streaming_session_drift_changes_outputs_only_after_epochs() {
    let g = test_graph(256, 1500, 16, 29);
    let hw = HwConfig::functional_tiles();
    let mut s = StreamingSession::new(g, hw, WEIGHT_SEED);
    let x = s.graph().random_features(4);
    let a = s.infer(ZooModel::B7, &x).unwrap();
    let b = s.infer(ZooModel::B7, &x).unwrap();
    assert_eq!(a.output, b.output);
    let batch = one_percent_churn(&s.dyng, 31);
    s.apply(&batch);
    let c = s.infer(ZooModel::B7, &x).unwrap();
    assert_ne!(a.output, c.output, "churn must move B7's aggregations");
}
