//! Simulator integration: paper-shape assertions over the cycle model —
//! the Fig. 14/15/16 ablation signatures and cross-model orderings at
//! full dataset scale (small/medium graphs to keep test time sane).

use graphagile::compiler::{compile, CompileOptions};
use graphagile::config::HwConfig;
use graphagile::graph::dataset;
use graphagile::ir::ZooModel;
use graphagile::sim::simulate;

fn loh(
    m: ZooModel,
    key: &str,
    opts: CompileOptions,
    overlap: bool,
) -> f64 {
    let ds = dataset(key).unwrap();
    let hw = HwConfig { overlap, ..HwConfig::alveo_u250() };
    let tiles = ds.tile_counts(hw.n1() as u64);
    let exe = compile(&m.build(ds.meta()), &tiles, &hw, opts);
    simulate(&exe.program, &hw).loh_seconds()
}

const ON: CompileOptions = CompileOptions {
    order_opt: true,
    fusion: true,
    skip_empty_tiles: true,
    dynamic_thresholds: true,
};

#[test]
fn fig14_signature_order_opt() {
    // b1 and b7 gain a lot (big f_in -> small f_out); the gain on CI
    // (f=3703) is dramatic, echoing the paper's 82% / 260% averages.
    let no_order = CompileOptions { order_opt: false, ..ON };
    for m in [ZooModel::B1, ZooModel::B7] {
        let with = loh(m, "CI", ON, true);
        let without = loh(m, "CI", no_order, true);
        let speedup = without / with;
        assert!(speedup > 1.5, "{m:?} order-opt speedup {speedup}");
    }
    // b8: pre-MLP equalizes widths; no effect (paper: 0%).
    let with = loh(ZooModel::B8, "PU", ON, true);
    let without = loh(ZooModel::B8, "PU", no_order, true);
    assert!((without / with - 1.0).abs() < 0.02, "b8 must be ~0%");
}

#[test]
fn fig15_signature_fusion() {
    // Fusion removes eltwise round-trips: a few percent, always >= 0.
    let no_fusion = CompileOptions { fusion: false, ..ON };
    for m in [ZooModel::B1, ZooModel::B3, ZooModel::B8] {
        let with = loh(m, "FL", ON, true);
        let without = loh(m, "FL", no_fusion, true);
        let pct = (without / with - 1.0) * 100.0;
        assert!((0.0..60.0).contains(&pct), "{m:?} fusion {pct}%");
    }
}

#[test]
fn fig16_signature_overlap() {
    // Overlap buys tens of percent to ~2x (paper: 112%-186%).
    for m in [ZooModel::B1, ZooModel::B2, ZooModel::B5] {
        let with = loh(m, "FL", ON, true);
        let without = loh(m, "FL", ON, false);
        let speedup = without / with;
        assert!(
            (1.1..2.8).contains(&speedup),
            "{m:?} overlap speedup {speedup}"
        );
    }
}

#[test]
fn table7_cross_model_orderings() {
    // Per-column orderings of Table 7 that are structural: b1 < b2 < b4,
    // b5 is the heaviest of the non-GraphGym models on PU/FL.
    for key in ["PU", "FL"] {
        let t = |m| loh(m, key, ON, true);
        assert!(t(ZooModel::B1) < t(ZooModel::B2), "{key}: b1 < b2");
        assert!(t(ZooModel::B2) < t(ZooModel::B4), "{key}: b2 < b4");
        assert!(t(ZooModel::B5) > t(ZooModel::B3), "{key}: b5 heaviest");
    }
}

#[test]
fn utilization_improves_with_model_width() {
    // Wider models amortize memory traffic: b2 (hidden 128) must hit
    // higher ACK utilization than b1 (hidden 16) on the same graph.
    let ds = dataset("FL").unwrap();
    let hw = HwConfig::alveo_u250();
    let tiles = ds.tile_counts(hw.n1() as u64);
    let util = |m: ZooModel| {
        let exe = compile(&m.build(ds.meta()), &tiles, &hw, CompileOptions::default());
        simulate(&exe.program, &hw).utilization()
    };
    assert!(util(ZooModel::B2) > util(ZooModel::B1));
}

#[test]
fn more_pes_not_slower() {
    let ds = dataset("FL").unwrap();
    let cycles = |n_pe: usize| {
        let hw = HwConfig { n_pe, ..HwConfig::alveo_u250() };
        let tiles = ds.tile_counts(hw.n1() as u64);
        let exe = compile(
            &ZooModel::B2.build(ds.meta()),
            &tiles,
            &hw,
            CompileOptions::default(),
        );
        simulate(&exe.program, &hw).cycles
    };
    let c4 = cycles(4);
    let c8 = cycles(8);
    assert!(c8 <= c4, "8 PEs ({c8}) slower than 4 ({c4})");
}
