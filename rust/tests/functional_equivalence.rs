//! Functional equivalence across all three execution paths:
//!
//!   golden (whole-graph rust)  ==  functional/RustBackend (tile path)
//!                              ==  functional/PjrtBackend (AOT HLO
//!                                  kernels from Pallas/JAX, via PJRT)
//!
//! This is the proof that the compiler's partition-centric schedule and
//! the L1 kernels compose functionally (DESIGN.md Sec. 5). Tests are
//! skipped (not failed) when `make artifacts` has not been run.

use graphagile::compiler::{compile, CompileOptions};
use graphagile::config::HwConfig;
use graphagile::exec::{golden_forward, FunctionalExecutor, RustBackend, WeightStore};
use graphagile::graph::{rmat::rmat_edges, GraphMeta, PartitionConfig, PartitionedGraph};
use graphagile::ir::ZooModel;
use graphagile::runtime::{client_args, find_artifacts_dir, PjrtBackend, PjrtRuntime};

fn runtime() -> Option<PjrtRuntime> {
    let dir = find_artifacts_dir()?;
    Some(PjrtRuntime::load(&dir).expect("artifacts present but failed to load"))
}

fn max_rel_err(a: &[f32], b: &[f32]) -> f32 {
    let scale = a.iter().fold(1f32, |m, v| m.max(v.abs()));
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0f32, f32::max)
        / scale
}

#[test]
fn pjrt_gemm_kernel_matches_rust_ops() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    };
    use graphagile::exec::TileBackend;
    let mut be = PjrtBackend::new(&rt).unwrap();
    let g = be.geom();
    let mut rng = graphagile::util::Rng::new(1);
    let (m, k, n) = (50, 30, 20); // deliberately unpadded shapes
    let h: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
    let w: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
    let b: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
    assert!(m <= g.n && k <= g.f && n <= g.f);
    let mut got = vec![0f32; m * n];
    be.gemm(&h, m, k, &w, n, &b, &mut got);
    let want = graphagile::exec::ops::gemm_bias_act(
        &h,
        m,
        k,
        &w,
        n,
        &b,
        graphagile::isa::Activation::None,
    );
    assert!(max_rel_err(&want, &got) < 1e-4, "err {}", max_rel_err(&want, &got));
}

#[test]
fn pjrt_spdmm_kernel_matches_rust_ops() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    use graphagile::exec::TileBackend;
    use graphagile::isa::AggOp;
    let mut be = PjrtBackend::new(&rt).unwrap();
    let mut rng = graphagile::util::Rng::new(2);
    let (n_in, n_out, f, e) = (100usize, 90usize, 48usize, 700usize);
    let src: Vec<u32> = (0..e).map(|_| rng.below(n_in as u64) as u32).collect();
    let dst: Vec<u32> = (0..e).map(|_| rng.below(n_out as u64) as u32).collect();
    let ew: Vec<f32> = (0..e).map(|_| rng.normal()).collect();
    let h: Vec<f32> = (0..n_in * f).map(|_| rng.normal()).collect();
    // The backend consumes CSR subshards with perm-gathered weights.
    let csr = graphagile::exec::kernels::csr_from_coo(&src, &dst, n_out);
    for aggop in [AggOp::Sum, AggOp::Max] {
        let neutral = if aggop == AggOp::Max { f32::NEG_INFINITY } else { 0.0 };
        let mut got = vec![neutral; n_out * f];
        let mut touched = vec![0u32; n_out];
        be.spdmm_csr(&csr, &ew, &h, f, aggop, &mut got, &mut touched);
        if neutral != 0.0 {
            for (r, &t) in touched.iter().enumerate() {
                if t == 0 {
                    got[r * f..(r + 1) * f].fill(0.0);
                }
            }
        }
        let want = graphagile::exec::ops::spdmm(&src, &dst, &ew, &h, f, n_out, aggop);
        assert!(
            max_rel_err(&want, &got) < 1e-4,
            "{aggop:?} err {}",
            max_rel_err(&want, &got)
        );
    }
}

#[test]
fn pjrt_sddmm_and_vecadd_match_rust_ops() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    use graphagile::exec::TileBackend;
    let mut be = PjrtBackend::new(&rt).unwrap();
    let mut rng = graphagile::util::Rng::new(3);
    let (n, f, e) = (110usize, 40usize, 1500usize); // e > 1024: chunking
    let src: Vec<u32> = (0..e).map(|_| rng.below(n as u64) as u32).collect();
    let dst: Vec<u32> = (0..e).map(|_| rng.below(n as u64) as u32).collect();
    let h: Vec<f32> = (0..n * f).map(|_| rng.normal()).collect();
    let csr = graphagile::exec::kernels::csr_from_coo(&src, &dst, n);
    let mut vals = vec![0f32; e];
    be.sddmm_csr(&csr, &h, &h, f, &mut vals);
    // Scatter CSR slot order back to edge order before comparing.
    let mut got = vec![0f32; e];
    for (slot, &v) in vals.iter().enumerate() {
        got[csr.perm[slot] as usize] = v;
    }
    let want = graphagile::exec::ops::sddmm(&src, &dst, &h, &h, f);
    assert!(max_rel_err(&want, &got) < 1e-4);

    let a: Vec<f32> = (0..5000).map(|_| rng.normal()).collect();
    let b: Vec<f32> = (0..5000).map(|_| rng.normal()).collect();
    let mut got = vec![0f32; 5000];
    be.vecadd(&a, &b, &mut got);
    let want: Vec<f32> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
    assert_eq!(got.len(), want.len());
    assert!(max_rel_err(&want, &got) < 1e-5);
}

#[test]
fn full_pipeline_pjrt_matches_golden() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let meta = GraphMeta::new("t", 300, 1500, 32, 4);
    let g = rmat_edges(meta, Default::default(), 9).gcn_normalized();
    let hw = HwConfig::functional_tiles();
    let cfg = PartitionConfig { n1: hw.n1() as u64, n2: hw.n2() as u64 };
    let pg = PartitionedGraph::build(&g, cfg);
    for model in [ZooModel::B1, ZooModel::B7] {
        let ir = model.build(g.meta.clone());
        let exe = compile(&ir, &pg.tile_counts(), &hw, CompileOptions::default());
        let store = WeightStore::deterministic(&exe.ir, 33);
        let x = g.random_features(5);
        let golden = golden_forward(&exe.ir, &g, &store, &x);

        let mut rust_fx = FunctionalExecutor::new(&exe, &pg, &store, RustBackend);
        let rust_out = rust_fx.run(&x);
        assert!(max_rel_err(&golden, &rust_out) < 1e-3);

        let be = PjrtBackend::new(&rt).unwrap();
        let mut pjrt_fx = FunctionalExecutor::new(&exe, &pg, &store, be);
        let pjrt_out = pjrt_fx.run(&x);
        let err = max_rel_err(&golden, &pjrt_out);
        assert!(err < 1e-3, "{}: pjrt vs golden err {err}", exe.ir.name);
        assert!(pjrt_fx.backend.launches > 0, "pjrt path did not run kernels");
    }
}

#[test]
fn whole_model_gcn2_artifact_matches_rust() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    use client_args::{f32s, i32s};
    use graphagile::exec::ops;
    use graphagile::isa::Activation;
    // Artifact geometry: gcn2_n256_e2048_f64_h32_c8.
    let name = rt
        .manifest()
        .find_prefix("gcn2_")
        .expect("gcn2 artifact")
        .to_string();
    let nums: Vec<usize> = name
        .strip_prefix("gcn2_")
        .unwrap()
        .split(['n', 'e', 'f', 'h', 'c', '_'])
        .filter_map(|t| t.parse().ok())
        .collect();
    let (n, e, f, hdim, c) = (nums[0], nums[1], nums[2], nums[3], nums[4]);
    let mut rng = graphagile::util::Rng::new(7);
    let x: Vec<f32> = (0..n * f).map(|_| rng.normal() * 0.5).collect();
    let src: Vec<i32> = (0..e).map(|_| rng.below(n as u64) as i32).collect();
    let dst: Vec<i32> = (0..e).map(|_| rng.below(n as u64) as i32).collect();
    let ew: Vec<f32> = (0..e).map(|_| rng.f32()).collect();
    let nv = [e as i32];
    let w1: Vec<f32> = (0..f * hdim).map(|_| rng.normal() * 0.1).collect();
    let b1 = vec![0f32; hdim];
    let w2: Vec<f32> = (0..hdim * c).map(|_| rng.normal() * 0.1).collect();
    let b2 = vec![0f32; c];
    let got = rt
        .execute(
            &name,
            &[
                f32s(&x),
                i32s(&src),
                i32s(&dst),
                f32s(&ew),
                i32s(&nv),
                f32s(&w1),
                f32s(&b1),
                f32s(&w2),
                f32s(&b2),
            ],
        )
        .unwrap();
    // Rust replica of model.py::gcn2_forward (auto order):
    // layer 1 (f > h): LA — linear, aggregate, relu;
    // layer 2 (h > c): LA — linear, aggregate.
    let srcu: Vec<u32> = src.iter().map(|&v| v as u32).collect();
    let dstu: Vec<u32> = dst.iter().map(|&v| v as u32).collect();
    let z = ops::gemm_bias_act(&x, n, f, &w1, hdim, &b1, Activation::None);
    let mut z = ops::spdmm(&srcu, &dstu, &ew, &z, hdim, n, graphagile::isa::AggOp::Sum);
    ops::apply_act(&mut z, Activation::Relu);
    let z2 = ops::gemm_bias_act(&z, n, hdim, &w2, c, &b2, Activation::None);
    let want = ops::spdmm(&srcu, &dstu, &ew, &z2, c, n, graphagile::isa::AggOp::Sum);
    let err = max_rel_err(&want, &got);
    assert!(err < 1e-3, "gcn2 artifact vs rust: err {err}");
}
