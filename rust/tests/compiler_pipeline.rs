//! Integration tests of the full compiler pipeline: every zoo model x
//! representative datasets, binary round-trips, optimization invariants.

use graphagile::compiler::{compile, CompileOptions};
use graphagile::config::HwConfig;
use graphagile::graph::{dataset, ALL_DATASETS};
use graphagile::ir::{LayerType, ALL_MODELS};
use graphagile::isa::{Instr, Program};

#[test]
fn all_models_compile_on_small_datasets() {
    let hw = HwConfig::alveo_u250();
    for key in ["CI", "CO", "PU"] {
        let ds = dataset(key).unwrap();
        let tiles = ds.tile_counts(hw.n1() as u64);
        for m in ALL_MODELS {
            let ir = m.build(ds.meta());
            let exe = compile(&ir, &tiles, &hw, CompileOptions::default());
            exe.ir.validate().unwrap_or_else(|e| panic!("{}/{key}: {e}", m.key()));
            assert_eq!(exe.program.layers.len(), exe.ir.n_layers());
            assert_eq!(exe.program.layers.len(), exe.tasks.len());
            let bytes = exe.program.to_bytes();
            let back = Program::from_bytes(&bytes).unwrap();
            assert_eq!(back, exe.program, "{}/{key} binary roundtrip", m.key());
        }
    }
}

#[test]
fn binary_sizes_track_paper_shape() {
    // Table 8 shape: binaries are sub-MB-to-MB scale, tiny vs inputs, and
    // grow with both the model depth and the graph size.
    let hw = HwConfig::alveo_u250();
    // PU and FL share f = 500, isolating the graph-size effect.
    let pu = dataset("PU").unwrap();
    let fl = dataset("FL").unwrap();
    let pu_tiles = pu.tile_counts(hw.n1() as u64);
    let fl_tiles = fl.tile_counts(hw.n1() as u64);
    let size = |m: graphagile::ir::ZooModel,
                ds: &graphagile::graph::Dataset,
                t: &graphagile::graph::TileCounts| {
        compile(&m.build(ds.meta()), t, &hw, CompileOptions::default())
            .program
            .size_bytes()
    };
    use graphagile::ir::ZooModel::*;
    let b1_pu = size(B1, &pu, &pu_tiles);
    let b5_pu = size(B5, &pu, &pu_tiles);
    let b1_fl = size(B1, &fl, &fl_tiles);
    assert!(b1_pu < b5_pu, "deeper model => bigger binary");
    assert!(b1_pu < b1_fl, "bigger graph => bigger binary (same f)");
    assert!(b5_pu < 10 << 20, "binaries stay megabyte-scale");
    // Negligible vs the input graph (paper Sec. 8.1).
    assert!(b1_fl * 20 < fl.meta().input_bytes());
}

#[test]
fn order_opt_never_increases_complexity() {
    for m in ALL_MODELS {
        for ds in &ALL_DATASETS[..4] {
            let ir0 = m.build(ds.meta());
            let mut ir1 = ir0.clone();
            graphagile::compiler::order::optimize(&mut ir1);
            assert!(
                ir1.total_complexity() <= ir0.total_complexity(),
                "{}/{}",
                m.key(),
                ds.key
            );
            ir1.validate().unwrap();
        }
    }
}

#[test]
fn fusion_eliminates_all_eltwise_layers_in_zoo() {
    // Every zoo model's Activations/BatchNorms sit behind fusable
    // parents, so the fused IR contains none.
    let ds = dataset("PU").unwrap();
    for m in ALL_MODELS {
        let mut ir = m.build(ds.meta());
        graphagile::compiler::fusion::fuse(&mut ir);
        assert_eq!(ir.count(LayerType::Activation), 0, "{}", m.key());
        assert_eq!(ir.count(LayerType::BatchNorm), 0, "{}", m.key());
    }
}

#[test]
fn unfused_program_contains_standalone_act_instrs() {
    let hw = HwConfig::alveo_u250();
    let ds = dataset("CO").unwrap();
    let tiles = ds.tile_counts(hw.n1() as u64);
    let ir = graphagile::ir::ZooModel::B1.build(ds.meta());
    let exe = compile(
        &ir,
        &tiles,
        &hw,
        CompileOptions { fusion: false, order_opt: false, ..Default::default() },
    );
    let has_act = exe
        .program
        .layers
        .iter()
        .flat_map(|l| &l.blocks)
        .flat_map(|b| &b.instrs)
        .any(|i| matches!(i, Instr::Act { .. }));
    assert!(has_act, "standalone Activation layer must emit Act instrs");
}

#[test]
fn compiled_csi_counts_are_consistent() {
    let hw = HwConfig::alveo_u250();
    let ds = dataset("FL").unwrap();
    let tiles = ds.tile_counts(hw.n1() as u64);
    for m in [graphagile::ir::ZooModel::B2, graphagile::ir::ZooModel::B6] {
        let exe = compile(&m.build(ds.meta()), &tiles, &hw, CompileOptions::default());
        for lb in &exe.program.layers {
            let Instr::Csi { n_tiling_blocks, layer_type, .. } = lb.csi else {
                panic!("no CSI")
            };
            assert_eq!(n_tiling_blocks as usize, lb.blocks.len());
            assert!(LayerType::from_u8(layer_type).is_some());
        }
    }
}

#[test]
fn loc_scales_roughly_linearly_with_graph() {
    // T_LoC is O(|V| + |E|): PU -> FL (20x edges) must not blow up
    // super-linearly (generous slack for constant terms + timer noise).
    use graphagile::graph::TileCounts;
    use graphagile::util::timed;
    let pu = dataset("PU").unwrap();
    let fl = dataset("FL").unwrap();
    let (psrc, pdst) = pu.edge_arrays();
    let (fsrc, fdst) = fl.edge_arrays();
    let (_, t_pu) = timed(|| TileCounts::from_edges(&psrc, &pdst, pu.n_vertices, 16384));
    let (_, t_fl) = timed(|| TileCounts::from_edges(&fsrc, &fdst, fl.n_vertices, 16384));
    let edge_ratio = fl.n_edges as f64 / pu.n_edges as f64;
    assert!(
        t_fl < t_pu * edge_ratio * 8.0 + 0.05,
        "partitioning not ~linear: {t_pu}s -> {t_fl}s (edges x{edge_ratio:.0})"
    );
}
