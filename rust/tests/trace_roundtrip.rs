//! Property tests for the daemon's trace format and wire framing:
//!
//! * encode/decode round-trip for every event variant over randomized
//!   requests (all targets, precisions, registry + off-registry
//!   datasets, full-width u64 seeds, random configs),
//! * version-field forward compatibility (unknown fields ignored at
//!   every nesting level; unknown *versions* and unknown event kinds
//!   rejected),
//! * malformed-frame rejection (truncated length prefix, oversized
//!   frame, truncated payload, invalid UTF-8).

use graphagile::config::HwConfig;
use graphagile::daemon::{
    read_frame, write_frame, ClientMsg, Trace, TraceConfig, TraceEvent, MAX_FRAME, TRACE_VERSION,
};
use graphagile::graph::{dataset, Dataset};
use graphagile::ir::ALL_MODELS;
use graphagile::serve::{
    CostModel, FaultEvent, FaultPlan, FleetConfig, Precision, PriorityClass, Request, Target,
    Tenant, TenantConfig,
};
use graphagile::util::{forall, Json, Rng};
use std::io::Cursor;

fn arb_dataset(rng: &mut Rng) -> Dataset {
    let keys = ["CI", "CO", "PU", "FL"];
    let d = dataset(keys[rng.below(4) as usize]).unwrap();
    if rng.below(4) == 0 {
        // Off-registry shape: exercises the codec's intern path.
        d.scaled(2 + rng.below(50))
    } else {
        d
    }
}

fn arb_target(rng: &mut Rng) -> Target {
    match rng.below(3) {
        0 => Target::FullGraph,
        1 => Target::MiniBatch {
            targets: (0..1 + rng.below(5)).map(|_| rng.below(1 << 20) as u32).collect(),
            fanout: (0..1 + rng.below(3)).map(|_| rng.below(64) as u32).collect(),
            seed: rng.next_u64(),
        },
        _ => Target::Update {
            inserts: rng.below(4096) as u32,
            deletes: rng.below(1024) as u32,
            grow: rng.below(16) as u32,
            seed: rng.next_u64(),
        },
    }
}

fn arb_request(rng: &mut Rng) -> Request {
    Request {
        tenant: rng.below(1024) as u32,
        model: ALL_MODELS[rng.below(8) as usize],
        dataset: arb_dataset(rng),
        target: arb_target(rng),
        arrival: rng.f64() * 1e3,
        precision: if rng.below(2) == 0 { Precision::F32 } else { Precision::Int8 },
    }
}

fn arb_trace(rng: &mut Rng) -> Trace {
    let hw = if rng.below(2) == 0 {
        HwConfig::alveo_u250()
    } else {
        HwConfig { n_pe: 1 + rng.below(16) as usize, ..HwConfig::functional_tiles() }
    };
    let fleet = FleetConfig {
        n_devices: 1 + rng.below(8) as usize,
        affinity: rng.below(2) == 0,
        coalesce: rng.below(2) == 0,
        microbatch: rng.below(2) == 0,
        dynamic: rng.below(2) == 0,
        costs: CostModel {
            visit_overhead_s: rng.f64() * 1e-3,
            ..CostModel::default()
        },
    };
    let mut events = Vec::new();
    let mut at = 0.0;
    for _ in 0..rng.below(12) {
        at += rng.f64() * 1e-3;
        events.push(match rng.below(5) {
            0 => TraceEvent::Stats { at },
            1 => TraceEvent::Drain { at },
            _ => {
                let mut rq = arb_request(rng);
                rq.arrival = at;
                TraceEvent::Admit(rq)
            }
        });
    }
    let mut t = Trace {
        version: TRACE_VERSION,
        config: TraceConfig { hw, fleet, fault_plan: None, tenants: None },
        events,
        responses: Vec::new(),
        stats: None,
    };
    // Stamp the oldest sufficient version, exactly as writers do — these
    // fault-free, tenant-free traces are v1 documents.
    t.version = t.min_version();
    t
}

#[test]
fn every_event_variant_round_trips() {
    forall("trace-round-trip", 40, |rng| {
        let t = arb_trace(rng);
        let back = Trace::parse(&t.encode()).map_err(|e| format!("{e:#}"))?;
        if back != t {
            return Err("decoded trace differs from the encoded one".to_string());
        }
        Ok(())
    });
}

#[test]
fn seeds_and_arrivals_survive_bit_exactly() {
    forall("seed-arrival-exactness", 60, |rng| {
        let mut rq = arb_request(rng);
        let seed = rng.next_u64();
        rq.target = Target::MiniBatch { targets: vec![1], fanout: vec![4], seed };
        let t = Trace::from_requests(
            HwConfig::alveo_u250(),
            FleetConfig::default(),
            vec![rq.clone()],
        );
        let back = Trace::parse(&t.encode()).map_err(|e| format!("{e:#}"))?;
        let got = &back.requests()[0];
        if got.arrival.to_bits() != rq.arrival.to_bits() {
            return Err(format!("arrival drifted: {} vs {}", got.arrival, rq.arrival));
        }
        match got.target {
            Target::MiniBatch { seed: s, .. } if s == seed => Ok(()),
            _ => Err(format!("seed drifted from {seed}")),
        }
    });
}

#[test]
fn unknown_fields_are_ignored_at_every_nesting_level() {
    let mut rng = Rng::new(99);
    let mut t = arb_trace(&mut rng);
    t.events.push(TraceEvent::Admit(Request::full(
        1,
        ALL_MODELS[0],
        dataset("CO").unwrap(),
        5.0,
    )));
    let s = t.encode();
    // Top level, config, event, and request objects each gain a field
    // from the future; a version-1 reader must ignore all of them.
    let s = s.replacen("\"version\": 1,", "\"version\": 1,\n\"recorded_by\": \"v99\",", 1);
    let s = s.replacen("{\"hw\":", "{\"cluster\":\"lab-3\",\"hw\":", 1);
    let s = s.replacen("{\"kind\":\"admit\",", "{\"kind\":\"admit\",\"span_id\":17,", 1);
    let s = s.replacen("{\"tenant\":", "{\"priority\":\"high\",\"tenant\":", 1);
    let back = Trace::parse(&s).unwrap();
    assert_eq!(back, t);
}

#[test]
fn unknown_version_is_rejected() {
    let mut rng = Rng::new(3);
    let s = arb_trace(&mut rng).encode().replacen("\"version\": 1,", "\"version\": 99,", 1);
    let err = Trace::parse(&s).unwrap_err().to_string();
    assert!(err.contains("version 99"), "{err}");
}

#[test]
fn missing_version_is_rejected() {
    let err = Trace::parse("{\"config\": {}, \"events\": []}").unwrap_err().to_string();
    assert!(err.contains("version"), "{err}");
}

#[test]
fn unknown_event_kind_is_rejected_not_skipped() {
    let mut rng = Rng::new(4);
    let mut t = arb_trace(&mut rng);
    t.events = vec![TraceEvent::Drain { at: 1.0 }];
    let s = t
        .encode()
        .replacen("{\"kind\":\"drain\"", "{\"kind\":\"rollback\"", 1);
    let err = format!("{:#}", Trace::parse(&s).unwrap_err());
    assert!(err.contains("rollback"), "{err}");
}

#[test]
fn frames_round_trip_random_payloads() {
    forall("frame-round-trip", 30, |rng| {
        let msg = match rng.below(3) {
            0 => ClientMsg::Submit(arb_request(rng)),
            1 => ClientMsg::Stats,
            _ => ClientMsg::Drain,
        };
        let mut buf = Vec::new();
        write_frame(&mut buf, &msg.to_json()).map_err(|e| format!("{e:#}"))?;
        let got = read_frame(&mut Cursor::new(buf))
            .map_err(|e| format!("{e:#}"))?
            .ok_or("missing frame")?;
        let back = ClientMsg::parse(&got).map_err(|e| format!("{e:#}"))?;
        if back != msg {
            return Err("decoded frame differs".to_string());
        }
        Ok(())
    });
}

#[test]
fn truncated_length_prefix_is_rejected() {
    // 1..3 header bytes: torn mid-prefix.
    for n in 1..4usize {
        let err = read_frame(&mut Cursor::new(vec![0u8; n])).unwrap_err().to_string();
        assert!(err.contains("truncated length prefix"), "{n} bytes: {err}");
    }
    // 0 bytes is a clean EOF, not an error.
    assert!(read_frame(&mut Cursor::new(Vec::<u8>::new())).unwrap().is_none());
}

#[test]
fn oversized_frame_is_rejected_before_allocation() {
    let mut bytes = u32::MAX.to_be_bytes().to_vec();
    bytes.extend_from_slice(b"{}");
    let err = read_frame(&mut Cursor::new(bytes)).unwrap_err().to_string();
    assert!(err.contains("exceeds MAX_FRAME"), "{err}");
    // Exactly at the cap is allowed in principle (length check only).
    assert!(MAX_FRAME >= 1 << 20);
}

#[test]
fn truncated_payload_is_rejected() {
    let mut bytes = 100u32.to_be_bytes().to_vec();
    bytes.extend_from_slice(b"{\"op\":\"stats\"}");
    let err = read_frame(&mut Cursor::new(bytes)).unwrap_err().to_string();
    assert!(err.contains("truncated frame payload"), "{err}");
}

#[test]
fn invalid_utf8_payload_is_rejected() {
    let payload = [b'{', 0xC3, 0x28, b'}']; // 0xC3 0x28: invalid sequence
    let mut bytes = (payload.len() as u32).to_be_bytes().to_vec();
    bytes.extend_from_slice(&payload);
    let err = read_frame(&mut Cursor::new(bytes)).unwrap_err().to_string();
    assert!(err.contains("not UTF-8"), "{err}");
}

#[test]
fn stats_and_drain_events_carry_their_timestamps() {
    for (e, kind) in [
        (TraceEvent::Stats { at: 0.125 }, "stats"),
        (TraceEvent::Drain { at: 0.25 }, "drain"),
    ] {
        let t = Trace {
            version: TRACE_VERSION,
            config: TraceConfig {
                hw: HwConfig::alveo_u250(),
                fleet: FleetConfig::default(),
                fault_plan: None,
                tenants: None,
            },
            events: vec![e.clone()],
            responses: Vec::new(),
            stats: None,
        };
        let s = t.encode();
        assert!(s.contains(kind), "{s}");
        let back = Trace::parse(&s).unwrap();
        assert_eq!(back.events.len(), 1);
        assert_eq!(back.events[0], e);
    }
}

#[test]
fn example_trace_in_repo_parses_and_replays() {
    // The checked-in quickstart trace must stay loadable — it is the
    // README's recorded-trace example.
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("examples")
        .join("traces")
        .join("mixed.trace.json");
    let t = Trace::load(&path).unwrap();
    // The recording predates faults and tenant QoS, so it stays a v1
    // document under the oldest-sufficient-version rule.
    assert_eq!(t.version, 1);
    assert!(!t.requests().is_empty());
    let (responses, stats) = graphagile::daemon::replay(&t);
    assert_eq!(responses.len(), t.requests().len());
    assert_eq!(stats.completed as usize, responses.len());
    // Replay of a fixed file is deterministic across runs/machines.
    let (responses2, stats2) = graphagile::daemon::replay(&t);
    assert_eq!(responses, responses2);
    assert!(stats.diff(&stats2).is_empty());
}

#[test]
fn v2_fault_traces_round_trip_under_the_v3_reader() {
    // Forward compat: a fault-era recording (v2 content, no tenant
    // content) still stamps v2, carries no v3 keys, and round-trips
    // bit-identically through the current reader.
    let mut rng = Rng::new(11);
    let mut t = arb_trace(&mut rng);
    t.config.fault_plan = Some(FaultPlan {
        seed: 9,
        events: vec![FaultEvent::TransientStall { device: 0, at: 0.0, duration: 1e-6 }],
    });
    t.version = t.min_version();
    assert_eq!(t.version, 2);
    let s = t.encode();
    assert!(!s.contains("\"tenants\""), "{s}");
    assert!(!s.contains("t_qos"), "{s}");
    let back = Trace::parse(&s).unwrap();
    assert_eq!(back, t);
}

#[test]
fn v3_tenant_traces_round_trip() {
    let mut rng = Rng::new(12);
    let mut t = arb_trace(&mut rng);
    t.config.tenants = Some(TenantConfig {
        tenants: vec![
            Tenant { id: 0, weight: 2.5, deadline_s: Some(0.01), class: PriorityClass::Premium },
            Tenant { id: 7, weight: 1.0, deadline_s: None, class: PriorityClass::BestEffort },
        ],
    });
    t.version = t.min_version();
    assert_eq!(t.version, 3);
    let back = Trace::parse(&t.encode()).unwrap();
    assert_eq!(back, t);
    assert_eq!(back.config.tenants, t.config.tenants);
}

#[test]
fn json_codec_is_reexported_for_tools() {
    // Downstream scripts build frames by hand; keep the Json value
    // type publicly reachable.
    let v = Json::parse("{\"op\":\"stats\"}").unwrap();
    assert_eq!(v.str_of("op").unwrap(), "stats");
}
