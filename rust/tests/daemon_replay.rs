//! Differential record/replay suite — the regression harness ISSUE 7
//! is built around:
//!
//! 1. record a mixed workload (whole-graph f32 + int8, mini-batch
//!    ego-nets, streaming churn) through the *live daemon TCP path*,
//! 2. replay the captured trace twice and assert the Response stream
//!    and final ServeStats are bit-identical to each other *and* to the
//!    recorded originals,
//! 3. repeat the replay under different `GA_KERNEL_THREADS` settings —
//!    the virtual clock must not leak host parallelism,
//! 4. prove `verify` actually fails on a divergent trace (a harness
//!    that cannot fail is not a harness).

use graphagile::config::HwConfig;
use graphagile::daemon::{drive, replay, verify, Client, Daemon, Trace};
use graphagile::serve::FleetConfig;

/// Record `n` scripted requests through a real daemon over TCP and
/// return the sealed trace (responses + stats included).
fn record_via_daemon(n: usize, seed: u64) -> Trace {
    let fleet = FleetConfig { n_devices: 2, ..FleetConfig::default() };
    let d = Daemon::bind(0, HwConfig::alveo_u250(), fleet).unwrap();
    let port = d.port();
    let server = std::thread::spawn(move || d.serve().unwrap());

    let mut c = Client::connect(port).unwrap();
    let (accepted, stats) = drive(&mut c, n, seed).unwrap();
    assert!(accepted > 0);
    assert_eq!(stats.completed as usize, accepted);
    c.shutdown().unwrap();

    let trace = server.join().unwrap();
    assert_eq!(trace.requests().len(), accepted);
    assert_eq!(trace.responses.len(), accepted);
    assert!(trace.stats.is_some(), "drained run must seal stats");
    trace
}

/// Run `f` with `GA_KERNEL_THREADS` pinned to `t`, restoring the
/// previous value afterwards (same idiom as rust/tests/quant.rs).
fn with_threads<T>(t: &str, f: impl FnOnce() -> T) -> T {
    let prev = std::env::var("GA_KERNEL_THREADS").ok();
    std::env::set_var("GA_KERNEL_THREADS", t);
    let out = f();
    match prev {
        Some(v) => std::env::set_var("GA_KERNEL_THREADS", v),
        None => std::env::remove_var("GA_KERNEL_THREADS"),
    }
    out
}

#[test]
fn daemon_recording_replays_bit_identically() {
    let trace = record_via_daemon(48, 7);

    // The recording stamped real wall-clock arrivals; replay feeds the
    // same events back through a fresh coordinator.
    let (r1, s1) = replay(&trace);
    let (r2, s2) = replay(&trace);

    // Replay vs replay: the coordinator is a pure function of the trace.
    assert_eq!(r1, r2);
    assert_eq!(s1.diff(&s2), Vec::<String>::new());

    // Replay vs the recorded originals: bit-identical, field for field.
    assert_eq!(r1, trace.responses);
    assert_eq!(s1.diff(trace.stats.as_ref().unwrap()), Vec::<String>::new());

    // And the verify entry point agrees.
    assert_eq!(verify(&trace).unwrap(), Vec::<String>::new());
}

#[test]
fn replay_is_bit_identical_through_the_codec_and_disk() {
    let trace = record_via_daemon(32, 21);

    // Through the in-memory codec.
    let decoded = Trace::parse(&trace.encode()).unwrap();
    assert_eq!(decoded, trace);

    // Through an actual file, like `graphagile replay trace.json`.
    let path = std::env::temp_dir()
        .join(format!("ga_daemon_replay_{}.trace.json", std::process::id()));
    trace.save(&path).unwrap();
    let loaded = Trace::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(loaded, trace);

    let (resp, stats) = replay(&loaded);
    assert_eq!(resp, trace.responses);
    assert_eq!(stats.diff(trace.stats.as_ref().unwrap()), Vec::<String>::new());
}

#[test]
fn replay_does_not_depend_on_kernel_thread_count() {
    // One fixed recording, replayed under different host-parallelism
    // settings: the virtual clock models all latencies, so the thread
    // knob must be invisible in every response bit and stats counter.
    let trace = record_via_daemon(40, 3);

    let (r1, s1) = with_threads("1", || replay(&trace));
    let (r4, s4) = with_threads("4", || replay(&trace));

    assert_eq!(r1, r4);
    assert_eq!(s1.diff(&s4), Vec::<String>::new());
    assert_eq!(r1, trace.responses);
    assert_eq!(s1.diff(trace.stats.as_ref().unwrap()), Vec::<String>::new());
}

#[test]
fn workload_mix_exercises_every_serving_path() {
    // The scripted workload is the CI record/replay input; if it ever
    // degenerates to one request class, the harness stops covering the
    // paths it exists to guard.
    let trace = record_via_daemon(64, 7);
    let stats = trace.stats.as_ref().unwrap();
    assert!(stats.minibatched > 0, "no mini-batches recorded");
    assert!(stats.updates > 0, "no churn batches recorded");
    assert!(stats.quantized > 0, "no int8 requests recorded");
    assert!(
        stats.completed > stats.minibatched + stats.updates + stats.quantized,
        "no plain f32 whole-graph requests recorded"
    );
    // Stamped arrivals are monotone non-decreasing in admission order —
    // the wall clock enters the system exactly once, at admission.
    let reqs = trace.requests();
    for w in reqs.windows(2) {
        assert!(w[0].arrival <= w[1].arrival, "arrivals not monotone");
    }
}

#[test]
fn verify_flags_a_divergent_trace() {
    let mut trace = record_via_daemon(16, 5);

    // Forge the recording: flip one latency by one ulp and one counter
    // by one. A bit-exact harness must catch both.
    let i = trace.responses.len() / 2;
    trace.responses[i].latency = f64::from_bits(trace.responses[i].latency.to_bits() + 1);
    if let Some(s) = trace.stats.as_mut() {
        s.cache_hits += 1;
    }

    let divergences = verify(&trace).unwrap();
    assert!(
        divergences.iter().any(|d| d.contains(&format!("responses[{i}]")) && d.contains("latency")),
        "ulp-level response forgery not flagged: {divergences:?}"
    );
    assert!(
        divergences.iter().any(|d| d.contains("stats.cache_hits")),
        "stats forgery not flagged: {divergences:?}"
    );
}

#[test]
fn verify_refuses_an_events_only_trace() {
    let mut trace = record_via_daemon(8, 9);
    trace.responses.clear();
    trace.stats = None;
    let err = verify(&trace).unwrap_err().to_string();
    assert!(err.contains("no recorded responses"), "{err}");
}
