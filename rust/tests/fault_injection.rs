//! Fault-injection suite — the chaos harness behind "no accepted
//! request is ever lost":
//!
//! 1. corrupted-artifact recovery: flip one byte in each `.ga` wire
//!    format (GA01/GA02/GA03) of a *really compiled* program and prove
//!    the loader rejects every one; then let the coordinator's armed
//!    corruption events bite cached f32 and int8 artifacts in situ and
//!    assert it evicts, recompiles, and completes,
//! 2. accounting under a seeded crash-and-recover plan: every admitted
//!    request ends `Completed`, `Degraded`, or `Shed` — and the whole
//!    faulty run is a pure function of (plan, workload),
//! 3. fleet wipe: permanent crashes on every device shed with a named
//!    reason instead of hanging or panicking,
//! 4. record a faulty run through the live daemon TCP path and replay
//!    it bit-identically — including across `GA_KERNEL_THREADS`.

use graphagile::compiler::{compile, CompileOptions};
use graphagile::config::HwConfig;
use graphagile::daemon::{drive, replay, verify, Client, Daemon, Trace};
use graphagile::graph::dataset;
use graphagile::ir::ZooModel;
use graphagile::isa::Program;
use graphagile::serve::{
    Coordinator, CostModel, FaultEvent, FaultPlan, FleetConfig, Key, Outcome, Precision,
    Request, ShedReason,
};

/// A fleet whose deadline never fires: these tests isolate the crash /
/// corruption machinery from the fidelity cascade.
fn patient_fleet(n_devices: usize) -> FleetConfig {
    FleetConfig {
        n_devices,
        costs: CostModel { deadline_s: f64::INFINITY, ..CostModel::default() },
        ..FleetConfig::default()
    }
}

/// Run `f` with `GA_KERNEL_THREADS` pinned to `t`, restoring the
/// previous value afterwards (same idiom as rust/tests/daemon_replay.rs).
fn with_threads<T>(t: &str, f: impl FnOnce() -> T) -> T {
    let prev = std::env::var("GA_KERNEL_THREADS").ok();
    std::env::set_var("GA_KERNEL_THREADS", t);
    let out = f();
    match prev {
        Some(v) => std::env::set_var("GA_KERNEL_THREADS", v),
        None => std::env::remove_var("GA_KERNEL_THREADS"),
    }
    out
}

#[test]
fn one_byte_flip_in_each_ga_format_trips_the_loader() {
    let hw = HwConfig::alveo_u250();
    let d = dataset("CO").unwrap();
    let tiles = d.tile_counts(hw.n1() as u64);
    let ir = ZooModel::B1.build(d.meta());

    // GA02: the default whole-graph compile embeds a threshold table.
    let ga02 = compile(&ir, &tiles, &hw, CompileOptions::default()).program;
    assert!(ga02.thresholds.is_some());
    assert_eq!(&ga02.to_bytes()[..4], b"GA02");

    // GA01: no optional sections at all.
    let ga01 = compile(
        &ir,
        &tiles,
        &hw,
        CompileOptions { dynamic_thresholds: false, ..Default::default() },
    )
    .program;
    assert!(ga01.thresholds.is_none() && ga01.scales.is_none());
    assert_eq!(&ga01.to_bytes()[..4], b"GA01");

    // GA03: serve one int8 request and pull the calibrated artifact out
    // of the device cache — the same bytes the corruption event bites.
    let co = dataset("CO").unwrap();
    let mut c = Coordinator::fleet(HwConfig::alveo_u250(), patient_fleet(1));
    c.admit(Request::full(0, ZooModel::B1, co, 0.0).with_precision(Precision::Int8));
    let key = Key::Whole(ZooModel::B1, co.key, 0, Precision::Int8);
    let ga03 = c.devices()[0].cached(&key).expect("int8 program cached").program.clone();
    assert!(ga03.scales.is_some());
    assert_eq!(&ga03.to_bytes()[..4], b"GA03");

    for p in [ga01, ga02, ga03] {
        let mut bytes = p.to_bytes();
        assert!(Program::from_bytes(&bytes).is_ok());
        // The section-flag flip the fault injector uses...
        bytes[p.corruption_offset()] ^= 0xFF;
        assert!(Program::from_bytes(&bytes).is_err(), "{:?} survived a section flip", &bytes[..4]);
        // ...and the magic itself, load-bearing for every format.
        let mut bytes = p.to_bytes();
        bytes[0] ^= 0xFF;
        assert!(Program::from_bytes(&bytes).is_err());
    }
}

#[test]
fn corruption_evicts_recompiles_and_completes_for_both_precisions() {
    let co = dataset("CO").unwrap();
    let corrupt = |at: f64| FaultEvent::ArtifactCorruption {
        device: 0,
        at,
        model: ZooModel::B1,
        dataset: "CO".into(),
    };
    let mut c = Coordinator::fleet(HwConfig::alveo_u250(), patient_fleet(1));
    c.set_fault_plan(FaultPlan { seed: 11, events: vec![corrupt(0.5), corrupt(2.5)] });

    // f32: warm compile, then the armed corruption forces a recompile.
    let r1 = c.admit(Request::full(0, ZooModel::B1, co, 0.0));
    let r2 = c.admit(Request::full(0, ZooModel::B1, co, 1.0));
    assert!(!r1.cache_hit && !r2.cache_hit);
    assert!(r2.t_compile > 0.0, "corrupted artifact must be recompiled");
    assert_eq!(r2.outcome, Outcome::Completed);

    // int8: same dance through the GA03 artifact.
    let r3 = c.admit(Request::full(1, ZooModel::B1, co, 2.0).with_precision(Precision::Int8));
    let r4 = c.admit(Request::full(1, ZooModel::B1, co, 3.0).with_precision(Precision::Int8));
    assert!(!r3.cache_hit && !r4.cache_hit);
    assert!(r4.t_compile > 0.0);
    assert_eq!(r4.outcome, Outcome::Completed);

    // Once recompiled, the caches are warm again.
    let r5 = c.admit(Request::full(0, ZooModel::B1, co, 4.0));
    assert!(r5.cache_hit);

    let stats = c.stats();
    assert_eq!(stats.corruptions, 2);
    assert_eq!(stats.shed, 0);
    assert_eq!(stats.completed, 5);
}

#[test]
fn seeded_crash_plan_accounts_for_every_admitted_request() {
    let models = [ZooModel::B1, ZooModel::B2, ZooModel::B7];
    let graphs = [dataset("CO").unwrap(), dataset("PU").unwrap()];
    let workload: Vec<Request> = (0..40)
        .map(|i| {
            Request::full(
                (i % 3) as u32,
                models[i % models.len()],
                graphs[i % graphs.len()],
                i as f64 * 1e-4,
            )
        })
        // A flush past the plan horizon: every scheduled event fires.
        .chain([Request::full(0, ZooModel::B1, dataset("CO").unwrap(), 1.0)])
        .collect();
    let plan = FaultPlan::crash_and_recover(13, 3, 6e-3);

    let run = || {
        let mut c = Coordinator::fleet(HwConfig::alveo_u250(), patient_fleet(3));
        c.set_fault_plan(plan.clone());
        let stats = c.run(workload.clone());
        (c.responses.clone(), stats)
    };
    let (responses, stats) = run();

    // No lost work: one response per admitted request, each with a
    // definite outcome, and the stats families add up.
    assert_eq!(responses.len(), workload.len());
    let shed = responses.iter().filter(|r| r.outcome.is_shed()).count() as u64;
    let degraded = responses.iter().filter(|r| r.outcome.is_degraded()).count() as u64;
    assert_eq!(stats.shed, shed);
    assert_eq!(stats.degraded, degraded);
    assert_eq!(stats.completed + stats.shed, workload.len() as u64);
    assert_eq!(stats.crashes, 2, "devices 1 and 2 each crash once");
    assert_eq!(stats.stalls, 1);
    assert!(stats.downtime > 0.0);

    // The faulty run is a pure function of (plan, workload).
    let (responses2, stats2) = run();
    assert_eq!(responses, responses2);
    assert_eq!(stats.diff(&stats2), Vec::<String>::new());
}

#[test]
fn fleet_wipe_sheds_every_request_with_a_named_reason() {
    let co = dataset("CO").unwrap();
    let mut c = Coordinator::fleet(HwConfig::alveo_u250(), patient_fleet(2));
    c.set_fault_plan(FaultPlan {
        seed: 2,
        events: (0..2)
            .map(|d| FaultEvent::DeviceCrash { device: d, at: 0.0, recover_after: 0.0 })
            .collect(),
    });
    for i in 0..4 {
        let r = c.admit(Request::full(i, ZooModel::B1, co, 0.1 + i as f64 * 1e-4));
        assert_eq!(r.outcome, Outcome::Shed(ShedReason::NoHealthyDevice));
        assert_eq!(r.device, u32::MAX);
    }
    let stats = c.stats();
    assert_eq!(stats.shed, 4);
    assert_eq!(stats.completed, 0);
    assert_eq!(stats.crashes, 2);
}

#[test]
fn faulty_daemon_recording_replays_bit_identically() {
    // Record through the live TCP path under a seeded plan whose events
    // land inside the wall-clock span of the drive.
    let plan = FaultPlan::crash_and_recover(5, 2, 2e-2);
    let d = Daemon::bind_with_plan(
        0,
        HwConfig::alveo_u250(),
        FleetConfig { n_devices: 2, ..FleetConfig::default() },
        Some(plan),
    )
    .unwrap();
    let port = d.port();
    let server = std::thread::spawn(move || d.serve().unwrap());

    let mut c = Client::connect(port).unwrap();
    let (accepted, _stats) = drive(&mut c, 60, 17).unwrap();
    assert!(accepted > 0);
    c.shutdown().unwrap();
    let trace = server.join().unwrap();

    // The plan makes the trace a v2 document, and every accepted
    // request has a recorded response — none were lost to the faults.
    assert_eq!(trace.version, 2);
    assert!(trace.config.fault_plan.is_some());
    assert_eq!(trace.responses.len(), accepted);

    // Replay is deterministic, matches the recording, and survives the
    // codec and the kernel-thread knob.
    let (r1, s1) = replay(&trace);
    let (r2, s2) = replay(&trace);
    assert_eq!(r1, r2);
    assert_eq!(s1.diff(&s2), Vec::<String>::new());
    assert_eq!(r1, trace.responses);
    assert_eq!(s1.diff(trace.stats.as_ref().unwrap()), Vec::<String>::new());
    assert_eq!(verify(&trace).unwrap(), Vec::<String>::new());

    let decoded = Trace::parse(&trace.encode()).unwrap();
    assert_eq!(decoded, trace);
    assert_eq!(verify(&decoded).unwrap(), Vec::<String>::new());

    let (rt1, st1) = with_threads("1", || replay(&trace));
    let (rt4, st4) = with_threads("4", || replay(&trace));
    assert_eq!(rt1, rt4);
    assert_eq!(st1.diff(&st4), Vec::<String>::new());
    assert_eq!(rt1, trace.responses);
}
