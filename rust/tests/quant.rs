//! Scale-aware golden equivalence for the quantized datapath: for every
//! zoo model, the int8 execution must land within the bound the
//! calibration pass derived from its measured ranges (never a
//! hand-tuned epsilon), and — because the quantized kernels accumulate
//! in exact i32 arithmetic — must be bit-identical across repeated runs
//! and kernel thread counts.

use graphagile::compiler::{compile, CompileOptions, Executable};
use graphagile::config::HwConfig;
use graphagile::exec::{golden_forward, FunctionalExecutor, RustBackend, WeightStore};
use graphagile::graph::{rmat::rmat_edges, CooGraph, GraphMeta, PartitionConfig, PartitionedGraph};
use graphagile::ir::{ZooModel, ALL_MODELS};
use graphagile::quant::{calibrate, CalibrationProfile};

const WEIGHT_SEED: u64 = 33;

fn test_graph() -> CooGraph {
    let meta = GraphMeta::new("q", 260, 1400, 16, 4);
    rmat_edges(meta, Default::default(), 11).gcn_normalized()
}

/// Compile `model` over `g` and attach a scale table calibrated from
/// the *exact* profile of `(g, x)` — the tightest bound the math emits.
fn quantized_exe(
    model: ZooModel,
    g: &CooGraph,
    pg: &PartitionedGraph,
    hw: &HwConfig,
    x: &[f32],
) -> (Executable, WeightStore, f32) {
    let ir = model.build(g.meta.clone());
    let mut exe = compile(&ir, &pg.tile_counts(), hw, CompileOptions::default());
    let store = WeightStore::deterministic(&exe.ir, WEIGHT_SEED);
    let cal = calibrate(&exe.ir, &store, &CalibrationProfile::exact(g, x));
    assert!(
        cal.bound.is_finite() && cal.bound > 0.0,
        "{}: calibration bound {} must be a positive finite number",
        model.key(),
        cal.bound
    );
    exe.program.scales = Some(cal.table);
    (exe, store, cal.bound)
}

fn max_err(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max)
}

#[test]
fn every_zoo_model_matches_golden_within_its_calibrated_bound() {
    let g = test_graph();
    let hw = HwConfig::functional_tiles();
    let cfg = PartitionConfig { n1: hw.n1() as u64, n2: hw.n2() as u64 };
    let pg = PartitionedGraph::build(&g, cfg);
    let x = g.random_features(5);
    for model in ALL_MODELS {
        let (exe, store, bound) = quantized_exe(model, &g, &pg, &hw, &x);
        let golden = golden_forward(&exe.ir, &g, &store, &x);
        let mut fx = FunctionalExecutor::new(&exe, &pg, &store, RustBackend);
        let got = fx.run(&x);
        assert!(
            fx.quant_visits > 0 && fx.requant_ops > 0 && fx.int8_bytes > 0,
            "{}: quantized datapath never engaged",
            exe.ir.name
        );
        let err = max_err(&golden, &got);
        assert!(
            err <= bound,
            "{}: int8 error {err} exceeds the calibration-derived bound {bound}",
            exe.ir.name
        );
        // Exact i32 accumulation: a repeat run reproduces every bit.
        let again = fx.run(&x);
        assert_eq!(got, again, "{}: quantized run is not deterministic", exe.ir.name);
    }
}

#[test]
fn quantized_outputs_are_bit_identical_across_thread_counts() {
    let g = test_graph();
    let hw = HwConfig::functional_tiles();
    let cfg = PartitionConfig { n1: hw.n1() as u64, n2: hw.n2() as u64 };
    let pg = PartitionedGraph::build(&g, cfg);
    let x = g.random_features(7);
    let prev = std::env::var("GA_KERNEL_THREADS").ok();
    for model in [ZooModel::B1, ZooModel::B4, ZooModel::B7] {
        let (exe, store, _) = quantized_exe(model, &g, &pg, &hw, &x);
        let run = |t: &str| {
            std::env::set_var("GA_KERNEL_THREADS", t);
            FunctionalExecutor::new(&exe, &pg, &store, RustBackend).run(&x)
        };
        let (one, four) = (run("1"), run("4"));
        assert_eq!(
            one, four,
            "{}: quantized output depends on the thread count",
            exe.ir.name
        );
    }
    match prev {
        Some(v) => std::env::set_var("GA_KERNEL_THREADS", v),
        None => std::env::remove_var("GA_KERNEL_THREADS"),
    }
}
