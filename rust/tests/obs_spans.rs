//! Observability suite — the per-request accounting invariant and the
//! span-stream determinism contract:
//!
//! 1. property test over mixed workloads (f32 / int8 / mini-batch /
//!    churn, then faulty, then QoS-paced): every response's
//!    reconstructed phase timeline covers its latency to within
//!    [`ACCOUNTING_TOL_S`], every segment stays inside the request's
//!    `[arrival, done]` window, and per-phase widths match the public
//!    accounting fields they were rebuilt from,
//! 2. with tracing on, the phase children of every root span tile at
//!    least 99% of the request's latency,
//! 3. tracing off is dormant: responses and stats are bit-identical to
//!    a traced run, and no spans are recorded,
//! 4. span-stream determinism: a faulty and a tenanted daemon session
//!    (mutually exclusive configs) each replay to Chrome trace JSON
//!    byte-identical to the live session, across repeated replays, an
//!    encode/decode cycle, and `GA_KERNEL_THREADS` values,
//! 5. the histogram-backed percentile brackets the exact sorted-sample
//!    percentile from above within one log2 bucket factor.

use graphagile::config::HwConfig;
use graphagile::daemon::{replay, replay_traced, DaemonSession, Trace};
use graphagile::graph::dataset;
use graphagile::ir::ZooModel;
use graphagile::obs::{
    accounting_gap, coverage, segments, Phase, Segment, ACCOUNTING_TOL_S,
};
use graphagile::serve::{
    percentile, Coordinator, CostModel, FaultEvent, FaultPlan, FleetConfig, Precision,
    PriorityClass, Request, Response, Tenant, TenantConfig,
};
use graphagile::util::{Json, Rng};

/// A fleet whose deadline never fires: the accounting tests isolate the
/// phase model from the fidelity cascade.
fn patient_fleet(n_devices: usize) -> FleetConfig {
    FleetConfig {
        n_devices,
        costs: CostModel { deadline_s: f64::INFINITY, ..CostModel::default() },
        ..FleetConfig::default()
    }
}

/// Run `f` with `GA_KERNEL_THREADS` pinned to `t`, restoring the
/// previous value afterwards (same idiom as rust/tests/daemon_replay.rs).
fn with_threads<T>(t: &str, f: impl FnOnce() -> T) -> T {
    let prev = std::env::var("GA_KERNEL_THREADS").ok();
    std::env::set_var("GA_KERNEL_THREADS", t);
    let out = f();
    match prev {
        Some(v) => std::env::set_var("GA_KERNEL_THREADS", v),
        None => std::env::remove_var("GA_KERNEL_THREADS"),
    }
    out
}

/// The deterministic mixed workload every accounting test serves:
/// whole-graph f32 and int8, mini-batch ego-nets, and churn batches —
/// arrival-sorted, so `zip`ping with `Coordinator::responses` pairs
/// each response with its request.
fn mixed_workload(n: usize, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    let models = [ZooModel::B1, ZooModel::B2, ZooModel::B7];
    let graphs = [dataset("CO").unwrap(), dataset("PU").unwrap()];
    (0..n)
        .map(|i| {
            let tenant = rng.below(4) as u32;
            let ds = graphs[rng.below(2) as usize];
            let model = models[rng.below(3) as usize];
            let arrival = i as f64 * 1e-4;
            match rng.below(8) {
                0 => Request::update(
                    tenant,
                    ds,
                    16 + rng.below(48) as u32,
                    rng.below(8) as u32,
                    rng.below(3) as u32,
                    seed ^ i as u64,
                    arrival,
                ),
                1 | 2 => {
                    let k = 1 + rng.below(3) as usize;
                    let targets =
                        (0..k).map(|_| rng.below(ds.n_vertices) as u32).collect();
                    Request::minibatch(
                        tenant,
                        model,
                        ds,
                        targets,
                        vec![8, 4],
                        seed.wrapping_add(i as u64),
                        arrival,
                    )
                }
                3 => Request::full(tenant, model, ds, arrival)
                    .with_precision(Precision::Int8),
                _ => Request::full(tenant, model, ds, arrival),
            }
        })
        .collect()
}

/// Total width the reconstructed timeline spends in one phase.
fn phase_total(segs: &[Segment], phase: Phase) -> f64 {
    segs.iter().filter(|s| s.phase == phase).map(|s| s.until - s.from).sum()
}

/// The accounting invariant, checked field by field for one
/// (request, response) pair.
fn check_accounting(rq: &Request, r: &Response) {
    let segs = segments(rq.arrival, r);
    let gap = accounting_gap(rq.arrival, r);
    assert!(
        gap <= ACCOUNTING_TOL_S,
        "accounting gap {gap} s on {r:?} (arrival {})",
        rq.arrival
    );
    // Every window stays inside the request's lifetime.
    let done = rq.arrival + r.latency;
    for s in &segs {
        assert!(s.until > s.from, "empty or inverted window {s:?}");
        assert!(
            s.from >= rq.arrival - ACCOUNTING_TOL_S && s.until <= done + ACCOUNTING_TOL_S,
            "window {s:?} outside [{}, {done}]",
            rq.arrival
        );
    }
    // Per-phase widths match the accounting fields they encode.
    let tol = ACCOUNTING_TOL_S;
    assert!((phase_total(&segs, Phase::Sample) - r.t_sample).abs() <= tol);
    if r.update {
        assert!((phase_total(&segs, Phase::Update) - r.latency).abs() <= tol);
        return;
    }
    assert!((phase_total(&segs, Phase::Backoff) - r.t_backoff).abs() <= tol);
    if r.outcome.is_shed() {
        return;
    }
    assert!((phase_total(&segs, Phase::Queue) - r.t_queue).abs() <= tol);
    if r.coalesced || r.batched {
        // Riders: `t_exec` is item-only time, not a wall phase.
        assert!(phase_total(&segs, Phase::Exec) == 0.0);
    } else {
        assert!((phase_total(&segs, Phase::Exec) - r.t_exec).abs() <= tol);
        assert!((phase_total(&segs, Phase::Compile) - r.t_compile).abs() <= tol);
        assert!((phase_total(&segs, Phase::QosPace) - r.t_qos).abs() <= tol);
    }
}

#[test]
fn accounting_invariant_holds_on_mixed_plain_serving() {
    let reqs = mixed_workload(64, 7);
    let mut c = Coordinator::fleet(HwConfig::alveo_u250(), patient_fleet(2));
    let stats = c.run(reqs.clone());
    assert_eq!(stats.completed + stats.shed, 64);
    // The mix actually exercised the paths the phase model names.
    assert!(stats.minibatched > 0, "no mini-batches in the mix");
    assert!(stats.updates > 0, "no churn in the mix");
    assert!(stats.quantized > 0, "no int8 in the mix");
    for (rq, r) in reqs.iter().zip(&c.responses) {
        check_accounting(rq, r);
    }
}

#[test]
fn accounting_invariant_holds_for_coalesced_riders() {
    // An identical burst: the first request compiles, the other seven
    // ride its job — the Queue + Ride reconstruction path.
    let pu = dataset("PU").unwrap();
    let reqs: Vec<Request> =
        (0..8).map(|i| Request::full(i, ZooModel::B2, pu, 0.0)).collect();
    let mut c = Coordinator::new(HwConfig::alveo_u250());
    let stats = c.run(reqs.clone());
    assert!(stats.coalesced > 0, "burst did not coalesce");
    for (rq, r) in reqs.iter().zip(&c.responses) {
        check_accounting(rq, r);
    }
}

#[test]
fn accounting_invariant_holds_under_faults() {
    // A crash and a stall at t=0 on a patient 2-device fleet: retries,
    // backoff pauses, and re-routes all enter the reconstruction.
    let plan = FaultPlan {
        seed: 11,
        events: vec![
            FaultEvent::DeviceCrash { device: 0, at: 0.0, recover_after: 5e-3 },
            FaultEvent::TransientStall { device: 1, at: 0.0, duration: 1e-3 },
        ],
    };
    let reqs = mixed_workload(32, 13);
    let mut c = Coordinator::fleet(HwConfig::alveo_u250(), patient_fleet(2));
    c.set_fault_plan(plan);
    let stats = c.run(reqs.clone());
    assert!(stats.retries > 0 || stats.rerouted > 0, "plan never bit");
    for (rq, r) in reqs.iter().zip(&c.responses) {
        check_accounting(rq, r);
    }

    // Fleet wipe: permanent crashes on every device shed with a named
    // reason — the Sample + Backoff reconstruction path.
    let wipe = FaultPlan {
        seed: 3,
        events: vec![
            FaultEvent::DeviceCrash { device: 0, at: 0.0, recover_after: f64::INFINITY },
            FaultEvent::DeviceCrash { device: 1, at: 0.0, recover_after: f64::INFINITY },
        ],
    };
    let co = dataset("CO").unwrap();
    let wreqs: Vec<Request> = (0..3)
        .map(|i| Request::full(i, ZooModel::B1, co, i as f64 * 1e-4))
        .collect();
    let mut wc = Coordinator::fleet(HwConfig::alveo_u250(), patient_fleet(2));
    wc.set_fault_plan(wipe);
    let wstats = wc.run(wreqs.clone());
    assert!(wstats.shed > 0, "fleet wipe must shed");
    for (rq, r) in wreqs.iter().zip(&wc.responses) {
        check_accounting(rq, r);
    }
}

#[test]
fn accounting_invariant_holds_under_qos() {
    // Saturating three-tenant traffic on one device: SFQ pacing charges
    // `t_qos`, and the impossible-deadline best-effort tenant sheds.
    let tenants = TenantConfig {
        tenants: vec![
            Tenant { id: 0, weight: 8.0, deadline_s: None, class: PriorityClass::Premium },
            Tenant { id: 1, weight: 2.0, deadline_s: None, class: PriorityClass::Standard },
            Tenant {
                id: 2,
                weight: 1.0,
                deadline_s: Some(1e-9),
                class: PriorityClass::BestEffort,
            },
        ],
    };
    let models = [ZooModel::B1, ZooModel::B2, ZooModel::B7];
    let graphs = [dataset("CO").unwrap(), dataset("PU").unwrap()];
    let mut rng = Rng::new(23);
    let reqs: Vec<Request> = (0..48)
        .map(|i| {
            Request::full(
                (i % 3) as u32,
                models[rng.below(3) as usize],
                graphs[rng.below(2) as usize],
                i as f64 * 1e-5,
            )
        })
        .collect();
    let cfg = FleetConfig {
        n_devices: 1,
        coalesce: false,
        microbatch: false,
        ..FleetConfig::default()
    };
    let mut c = Coordinator::fleet(HwConfig::alveo_u250(), cfg);
    c.set_tenants(tenants);
    let stats = c.run(reqs.clone());
    assert!(c.responses.iter().any(|r| r.t_qos > 0.0), "pacing never charged");
    assert!(stats.shed > 0, "impossible deadline never shed");
    for (rq, r) in reqs.iter().zip(&c.responses) {
        check_accounting(rq, r);
    }
}

#[test]
fn span_phase_children_tile_every_root() {
    let reqs = mixed_workload(48, 17);
    let mut c = Coordinator::fleet(HwConfig::alveo_u250(), patient_fleet(2));
    c.set_tracing(true);
    c.run(reqs);
    let spans = c.spans();
    assert!(!spans.is_empty());
    let roots: Vec<_> = spans.iter().filter(|s| s.cat == "request").collect();
    assert_eq!(roots.len(), 48, "one root span per admitted request");
    for root in roots {
        if root.dur <= 0.0 {
            continue;
        }
        // The phase children of this request, as coverage windows.
        let windows: Vec<Segment> = spans
            .iter()
            .filter(|s| s.request == root.request && s.cat == "phase")
            .map(|s| Segment { phase: Phase::Exec, from: s.from, until: s.from + s.dur })
            .collect();
        let covered = coverage(&windows);
        assert!(
            covered >= 0.99 * root.dur,
            "request {} phases cover {covered} of {} s",
            root.request,
            root.dur
        );
        // Kernel spans stay inside their request's lifetime.
        for s in spans.iter().filter(|s| s.request == root.request && s.cat == "kernel") {
            assert!(s.from >= root.from - ACCOUNTING_TOL_S);
            assert!(s.from + s.dur <= root.from + root.dur + ACCOUNTING_TOL_S);
        }
    }
}

#[test]
fn tracing_off_is_dormant_and_byte_identical() {
    let run = |traced: bool| {
        let mut c = Coordinator::fleet(HwConfig::alveo_u250(), patient_fleet(2));
        c.set_tracing(traced);
        let stats = c.run(mixed_workload(40, 29));
        let spans = c.spans().len();
        let json = c.chrome_trace_json();
        (c.responses, stats, spans, json)
    };
    let (r_off, s_off, n_off, j_off) = run(false);
    let (r_on, s_on, n_on, _) = run(true);
    assert_eq!(r_off, r_on, "tracing changed a response");
    assert_eq!(s_off, s_on, "tracing changed the stats");
    assert_eq!(n_off, 0, "dormant tracer recorded spans");
    assert!(n_on > 0, "live tracer recorded nothing");
    // An untraced export is the two metadata events and nothing else.
    let Json::Arr(events) = Json::parse(j_off.trim()).unwrap() else {
        panic!("chrome trace must be a top-level array")
    };
    assert_eq!(events.len(), 2);
    assert!(events.iter().all(|e| e.str_of("ph").unwrap() == "M"));
}

/// Replay a trace under both thread counts and through an encode cycle,
/// asserting the span stream is byte-identical to `live` everywhere.
fn assert_span_determinism(trace: &Trace, live: &str) {
    let (r1, s1, j1) = replay_traced(trace);
    let (_, _, j2) = replay_traced(trace);
    assert_eq!(j1, j2, "two replays disagree");
    assert_eq!(j1, live, "replayed span stream diverges from the live session");
    // Tracing only observes: an untraced replay serves identically.
    let (ur, us) = replay(trace);
    assert_eq!(ur, r1);
    assert_eq!(us, s1);
    // Bit-identical across kernel thread counts and an encode cycle.
    let jt1 = with_threads("1", || replay_traced(trace).2);
    let jt4 = with_threads("4", || replay_traced(trace).2);
    assert_eq!(jt1, j1, "span stream varies with GA_KERNEL_THREADS=1");
    assert_eq!(jt4, j1, "span stream varies with GA_KERNEL_THREADS=4");
    let decoded = Trace::parse(&trace.encode()).unwrap();
    assert_eq!(replay_traced(&decoded).2, j1, "encode cycle changed the span stream");
}

#[test]
fn faulty_span_stream_replays_bit_identically() {
    let costs = CostModel { deadline_s: f64::INFINITY, ..CostModel::default() };
    let fleet = FleetConfig { n_devices: 2, costs, ..FleetConfig::default() };
    let plan = FaultPlan {
        seed: 7,
        events: vec![
            FaultEvent::DeviceCrash { device: 0, at: 0.0, recover_after: 1e-3 },
            FaultEvent::TransientStall { device: 1, at: 0.0, duration: 1e-6 },
        ],
    };
    let mut s = DaemonSession::with_plan(HwConfig::alveo_u250(), fleet, Some(plan));
    s.enable_tracing();
    let co = dataset("CO").unwrap();
    let pu = dataset("PU").unwrap();
    s.submit(Request::full(0, ZooModel::B1, co, 0.0)).unwrap();
    s.submit(Request::minibatch(1, ZooModel::B1, co, vec![5, 9], vec![8, 4], 3, 0.0))
        .unwrap();
    s.submit(Request::full(2, ZooModel::B2, pu, 0.0).with_precision(Precision::Int8))
        .unwrap();
    s.submit(Request::update(0, pu, 32, 8, 1, 11, 0.0)).unwrap();
    s.drain();
    let live = s.chrome_trace_json();
    let trace = s.finalize();
    assert_eq!(trace.version, 2);
    // The fired fault events render as instant events.
    assert!(live.contains("\"cat\":"), "{}", &live[..live.len().min(200)]);
    let Json::Arr(events) = Json::parse(live.trim()).unwrap() else {
        panic!("chrome trace must be a top-level array")
    };
    assert!(events.iter().any(|e| e.str_of("ph").map(|p| p == "i").unwrap_or(false)));
    assert_span_determinism(&trace, &live);
}

#[test]
fn tenant_span_stream_replays_bit_identically() {
    let tenants = TenantConfig {
        tenants: vec![
            Tenant { id: 0, weight: 4.0, deadline_s: None, class: PriorityClass::Premium },
            Tenant {
                id: 1,
                weight: 1.0,
                deadline_s: Some(1e-9),
                class: PriorityClass::BestEffort,
            },
        ],
    };
    let fleet = FleetConfig { n_devices: 2, ..FleetConfig::default() };
    let mut s = DaemonSession::with_tenants(HwConfig::alveo_u250(), fleet, Some(tenants));
    s.enable_tracing();
    let co = dataset("CO").unwrap();
    let pu = dataset("PU").unwrap();
    s.submit(Request::full(0, ZooModel::B2, co, 0.0)).unwrap();
    // The impossible deadline walks the cascade and sheds — a span the
    // replay must reproduce too.
    s.submit(Request::full(1, ZooModel::B1, co, 0.0)).unwrap();
    s.submit(Request::minibatch(0, ZooModel::B1, co, vec![5, 9], vec![8, 4], 3, 0.0))
        .unwrap();
    s.submit(Request::full(0, ZooModel::B7, pu, 0.0)).unwrap();
    s.drain();
    let live = s.chrome_trace_json();
    let trace = s.finalize();
    assert_eq!(trace.version, 3);
    assert_span_determinism(&trace, &live);
}

#[test]
fn histogram_percentiles_bracket_the_exact_path() {
    let reqs = mixed_workload(64, 31);
    let mut c = Coordinator::fleet(HwConfig::alveo_u250(), patient_fleet(2));
    c.run(reqs);
    let hist = c.latency_histogram();
    let mut lats: Vec<f64> = c
        .responses
        .iter()
        .filter(|r| !r.update && !r.outcome.is_shed())
        .map(|r| r.latency)
        .collect();
    lats.sort_by(f64::total_cmp);
    assert_eq!(hist.count(), lats.len() as u64);
    assert!((hist.sum() - lats.iter().sum::<f64>()).abs() <= 1e-9);
    for p in [0.5, 0.9, 0.99] {
        let exact = percentile(&lats, p);
        let bucketed = hist.quantile(p);
        assert!(exact > 0.0);
        assert!(bucketed >= exact, "p{p}: bucket bound must bracket from above");
        assert!(bucketed <= exact * 2.0, "p{p}: within one log2 bucket factor");
    }
}
