//! Offline stand-in for the `anyhow` crate (the build environment has no
//! crates.io access; see DESIGN.md "Substitutions"). Implements exactly
//! the subset the repository uses: [`Error`], [`Result`], the `anyhow!`,
//! `bail!` and `ensure!` macros, and the [`Context`] extension trait on
//! `Result` and `Option`.
//!
//! Semantics match anyhow where it matters to callers: `{err}` prints the
//! outermost message, `{err:#}` and `{err:?}` print the whole
//! colon-separated context chain, `?` converts any `std::error::Error`,
//! and `.context()` wraps an error (or a `None`) with an outer message.

use std::fmt;

/// An error: a chain of messages, outermost context first.
pub struct Error {
    msgs: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msgs: vec![m.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.msgs.insert(0, c.to_string());
        self
    }

    fn from_std(e: &(dyn std::error::Error + 'static)) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        Error { msgs }
    }

    /// The full context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.msgs.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.msgs.join(": "))
        } else {
            f.write_str(&self.msgs[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msgs.join(": "))
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::from_std(&e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

mod ext {
    use super::Error;

    /// Anything `.context()` accepts as the wrapped error. The concrete
    /// impl for [`Error`] does not overlap the std-error blanket because
    /// `Error` deliberately does not implement `std::error::Error`.
    pub trait IntoError {
        fn into_error(self) -> Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> Error {
            Error::from(self)
        }
    }

    impl IntoError for Error {
        fn into_error(self) -> Error {
            self
        }
    }
}

/// Extension trait adding `.context()` / `.with_context()`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: ext::IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into_error().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an error.
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($tt)*))
    };
}

/// Early-return with an error when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::concat!(
                "condition failed: `",
                ::std::stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($tt:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($tt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<()> {
        let e = std::fs::read("/definitely/not/a/real/path/42");
        let _ = e.context("reading config")?;
        Ok(())
    }

    #[test]
    fn context_chains_render() {
        let err = fails_io().unwrap_err();
        assert_eq!(format!("{err}"), "reading config");
        let full = format!("{err:#}");
        assert!(full.starts_with("reading config: "), "{full}");
        assert_eq!(format!("{err:?}"), full);
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let err = v.context("missing value").unwrap_err();
        assert_eq!(format!("{err}"), "missing value");
        assert_eq!(Some(7).context("missing").unwrap(), 7);
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x != 1, "one is not allowed: {x}");
            ensure!(x != 2);
            if x == 3 {
                bail!("three is out");
            }
            Err(anyhow!("fallthrough {}", x))
        }
        assert_eq!(format!("{}", f(1).unwrap_err()), "one is not allowed: 1");
        assert!(format!("{}", f(2).unwrap_err()).contains("x != 2"));
        assert_eq!(format!("{}", f(3).unwrap_err()), "three is out");
        assert_eq!(format!("{}", f(4).unwrap_err()), "fallthrough 4");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("41").unwrap(), 41);
        assert!(parse("x").is_err());
    }
}
