//! The GraphAGILE software compiler (paper Sec. 6).
//!
//! Translation phase: the input parser produces a [`crate::ir::ModelIr`]
//! (Sec. 6.1–6.2; see `ir::zoo` for the benchmark builders that play the
//! role of the PyG front-end). Optimization phase — four steps:
//!
//! 1. [`order`] — computation order optimization (Alg. 5, Theorems 1–2),
//! 2. [`fusion`] — Activation and BatchNorm fusion (Sec. 6.4),
//! 3. [`partition`] — Fiber-Shard data partitioning (Sec. 6.5),
//! 4. [`mapping`] — kernel mapping to Layer/Tiling Blocks, instruction
//!    interleaving and mutex (WAR) annotation, code generation (Sec. 6.6).
//!
//! The output is an [`Executable`]: the `.ga` binary [`Program`] plus the
//! structured tile tasks the functional runtime executes, and a
//! [`CompileReport`] with per-pass wall-clock times (T_LoC in Table 7).
//!
//! [`bucket`] adds the mini-batch entry point: sampled ego-networks are
//! compiled once per power-of-two shape class ([`BucketShape`]) instead
//! of once per request, so the serving fleet's program cache absorbs
//! arbitrarily diverse mini-batch streams.

pub mod bucket;
pub mod fusion;
pub mod mapping;
pub mod order;
pub mod partition;
pub mod superpartition;

use crate::config::HwConfig;
use crate::graph::{PartitionConfig, TileCounts};
use crate::ir::ModelIr;
use crate::isa::Program;
use crate::util::timed;

pub use bucket::{compile_bucket, BucketShape};
pub use mapping::{LayerTasks, TileTask};
pub use partition::LayerGrid;

/// Compiler switches (all on by default; the Fig. 14–16 ablations turn
/// individual passes off).
#[derive(Clone, Copy, Debug)]
pub struct CompileOptions {
    /// Step 1: computation order optimization.
    pub order_opt: bool,
    /// Step 2: layer fusion.
    pub fusion: bool,
    /// Skip empty subshards (no instructions for zero-edge tiles).
    pub skip_empty_tiles: bool,
    /// Profile densities and embed the threshold table (the optional
    /// GA02 section) so engines can re-map kernels at run time; off
    /// emits a legacy GA01 binary with purely static mapping.
    pub dynamic_thresholds: bool,
}

impl Default for CompileOptions {
    fn default() -> Self {
        CompileOptions {
            order_opt: true,
            fusion: true,
            skip_empty_tiles: true,
            dynamic_thresholds: true,
        }
    }
}

/// Compile-cost report: measured per-pass wall-clock seconds plus the
/// deterministic work counters the pass sizes are a function of.
///
/// Two notions of "compile time" coexist deliberately:
/// * the measured `t_*` fields (and [`measured_total`]) are real
///   wall-clock — what a profiler of this binary would see, useful for
///   optimizing the compiler itself but different on every run;
/// * [`total`] is the *modeled* latency-of-compilation: a linear cost
///   model over the work counters, calibrated to the measured release
///   build (~25 ns/instruction emitted). It is bit-identical across
///   runs, which is what the serving fleet's virtual clock needs (and
///   it keeps the compiler-pass share of T_LoC in Table 7 independent
///   of build profile; the harness's measured partitioning term is the
///   one remaining wall-clock input to that column).
///
/// [`measured_total`]: CompileReport::measured_total
/// [`total`]: CompileReport::total
#[derive(Clone, Copy, Debug, Default)]
pub struct CompileReport {
    pub t_order: f64,
    pub t_fusion: f64,
    pub t_partition: f64,
    pub t_mapping: f64,
    /// IR layers after order optimization + fusion.
    pub layers: u64,
    /// Instructions in the emitted `.ga` binary (CSIs + HALT included).
    pub instrs: u64,
    /// Tiling Blocks in the emitted binary.
    pub blocks: u64,
}

impl CompileReport {
    /// Per-pass modeled costs (seconds per work unit).
    const PASS_SETUP_S: f64 = 2e-6; // per layer, per pass (4 passes)
    const PER_INSTR_S: f64 = 25e-9; // encode + emit one instruction
    const PER_BLOCK_S: f64 = 120e-9; // schedule + mutex-annotate one block

    /// Deterministic modeled compile seconds (the virtual-clock cost the
    /// serving coordinator charges per cache miss).
    pub fn total(&self) -> f64 {
        self.modeled_passes() + self.modeled_emit() + self.modeled_schedule()
    }

    /// Modeled pass-setup share of [`CompileReport::total`] (four
    /// optimization passes over the IR layers). These three modeled
    /// addends are what the span tracer subdivides a compile stall by
    /// — unlike the measured `t_*` fields, they are deterministic.
    pub fn modeled_passes(&self) -> f64 {
        self.layers as f64 * 4.0 * Self::PASS_SETUP_S
    }

    /// Modeled instruction-emit share of [`CompileReport::total`].
    pub fn modeled_emit(&self) -> f64 {
        self.instrs as f64 * Self::PER_INSTR_S
    }

    /// Modeled block-schedule share of [`CompileReport::total`]
    /// (scheduling + mutex annotation per Tiling Block).
    pub fn modeled_schedule(&self) -> f64 {
        self.blocks as f64 * Self::PER_BLOCK_S
    }

    /// Measured wall-clock sum of the four passes.
    pub fn measured_total(&self) -> f64 {
        self.t_order + self.t_fusion + self.t_partition + self.t_mapping
    }
}

/// Compiler output.
#[derive(Clone, Debug)]
pub struct Executable {
    /// The optimized IR (after steps 1–2).
    pub ir: ModelIr,
    /// The partition configuration used (from the HwConfig buffers).
    pub cfg: PartitionConfig,
    /// The `.ga` binary.
    pub program: Program,
    /// Structured tile tasks, one per Tiling Block, in program order —
    /// the loader metadata the functional runtime uses to bind tiles to
    /// actual graph data.
    pub tasks: Vec<LayerTasks>,
    pub report: CompileReport,
}

/// Run the full compiler: (model IR, per-subshard edge counts, hardware
/// configuration) -> executable. `tiles.n1` must equal the HwConfig's N1.
pub fn compile(
    model: &ModelIr,
    tiles: &TileCounts,
    hw: &HwConfig,
    opts: CompileOptions,
) -> Executable {
    let mut report = CompileReport::default();
    let mut ir = model.clone();

    if opts.order_opt {
        let (_, t) = timed(|| order::optimize(&mut ir));
        report.t_order = t;
    }
    if opts.fusion {
        let (_, t) = timed(|| fusion::fuse(&mut ir));
        report.t_fusion = t;
    }

    let cfg = PartitionConfig { n1: hw.n1() as u64, n2: hw.n2() as u64 };
    debug_assert_eq!(
        tiles.n1, cfg.n1,
        "tile counts were built with a different N1 than the hardware config"
    );

    let (grids, t_part) = timed(|| partition::plan(&ir, cfg, hw));
    report.t_partition = t_part;

    let ((program, tasks), t_map) =
        timed(|| mapping::map_program(&ir, tiles, &grids, cfg, hw, &opts));
    report.t_mapping = t_map;

    report.layers = ir.layers.len() as u64;
    report.instrs = program.total_instrs();
    report.blocks = program.layers.iter().map(|l| l.blocks.len() as u64).sum();

    Executable { ir, cfg, program, tasks, report }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{dataset, GraphMeta};
    use crate::ir::ZooModel;

    #[test]
    fn end_to_end_compile_b1_cora() {
        let ds = dataset("CO").unwrap();
        let hw = HwConfig::alveo_u250();
        let tiles = ds.tile_counts(hw.n1() as u64);
        let ir = ZooModel::B1.build(ds.meta());
        let exe = compile(&ir, &tiles, &hw, CompileOptions::default());
        exe.ir.validate().unwrap();
        assert!(!exe.program.layers.is_empty());
        assert_eq!(exe.program.layers.len(), exe.tasks.len());
        assert!(exe.program.size_bytes() > 0);
        // Round-trip the binary.
        let back = Program::from_bytes(&exe.program.to_bytes()).unwrap();
        assert_eq!(back, exe.program);
    }

    #[test]
    fn report_times_are_measured() {
        let ds = dataset("CO").unwrap();
        let hw = HwConfig::alveo_u250();
        let tiles = ds.tile_counts(hw.n1() as u64);
        let ir = ZooModel::B2.build(ds.meta());
        let exe = compile(&ir, &tiles, &hw, CompileOptions::default());
        assert!(exe.report.measured_total() > 0.0);
        assert!(exe.report.t_mapping > 0.0);
        assert!(exe.report.layers > 0 && exe.report.instrs > 0 && exe.report.blocks > 0);
    }

    #[test]
    fn modeled_compile_cost_is_deterministic() {
        // The virtual-clock cost must not change between two compiles of
        // the same instance (the serving fleet replays on it).
        let ds = dataset("CO").unwrap();
        let hw = HwConfig::alveo_u250();
        let tiles = ds.tile_counts(hw.n1() as u64);
        let ir = ZooModel::B2.build(ds.meta());
        let a = compile(&ir, &tiles, &hw, CompileOptions::default());
        let b = compile(&ir, &tiles, &hw, CompileOptions::default());
        assert!(a.report.total() > 0.0);
        assert_eq!(a.report.total(), b.report.total());
        assert_eq!(
            (a.report.layers, a.report.instrs, a.report.blocks),
            (b.report.layers, b.report.instrs, b.report.blocks),
        );
    }

    #[test]
    fn options_disable_passes() {
        let meta = GraphMeta::new("t", 1000, 4000, 500, 4);
        let tiles = crate::graph::rmat::rmat_tile_counts(
            &meta,
            Default::default(),
            1,
            16384,
        );
        let hw = HwConfig::alveo_u250();
        let ir = ZooModel::B7.build(meta);
        let on = compile(&ir, &tiles, &hw, CompileOptions::default());
        let off = compile(
            &ir,
            &tiles,
            &hw,
            CompileOptions { order_opt: false, fusion: false, ..Default::default() },
        );
        // SGC benefits enormously from order opt: fewer flops with it on.
        assert!(on.ir.total_complexity() < off.ir.total_complexity());
    }
}
