//! Step 2 — layer fusion (paper Sec. 6.4).
//!
//! * **Activation fusion**: an Activation layer merges into its adjacent
//!   (single-parent) Aggregate / Linear / Vector-Inner / Vector-Add layer;
//!   the activation then executes in the same Tiling Block, eliminating a
//!   round-trip of the feature map through external memory.
//! * **BatchNorm fusion**: inference-time batch normalization is an
//!   affine map, so it folds into the adjacent Linear layer's weights and
//!   bias (the numeric fold itself lives in `python/compile/model.py::
//!   batchnorm_fold`; here the IR transformation removes the layer).
//!
//! Both transformations preserve the DAG invariants (`ModelIr::validate`).

use crate::ir::{LayerType, ModelIr};

/// Fuse until fixpoint. Returns the number of layers eliminated.
pub fn fuse(ir: &mut ModelIr) -> usize {
    let mut removed = 0;
    loop {
        let step = fuse_one(ir);
        removed += step;
        if step == 0 {
            debug_assert_eq!(ir.validate(), Ok(()));
            return removed;
        }
    }
}

/// Find and apply one fusion; returns 1 if something fused.
fn fuse_one(ir: &mut ModelIr) -> usize {
    for pos in 0..ir.layers.len() {
        let l = &ir.layers[pos];
        match l.ltype {
            LayerType::Activation => {
                if l.parents.len() != 1 {
                    continue;
                }
                let pid = l.parents[0];
                let parent = ir.layer(pid);
                // The parent must feed only this activation, and must be a
                // fusable compute layer that has no activation yet.
                let fusable = matches!(
                    parent.ltype,
                    LayerType::Aggregate
                        | LayerType::Linear
                        | LayerType::VectorInner
                        | LayerType::VectorAdd
                );
                if !fusable || parent.children.len() != 1 || parent.act_enabled {
                    continue;
                }
                let act = l.act;
                let id = l.id;
                remove_passthrough(ir, pos);
                let p = ir.layer_mut(pid);
                p.act = act;
                p.act_enabled = true;
                debug_assert!(!p.children.contains(&id));
                return 1;
            }
            LayerType::BatchNorm => {
                if l.parents.len() != 1 {
                    continue;
                }
                let pid = l.parents[0];
                let parent = ir.layer(pid);
                // BatchNorm folds into Linear weights/bias only; a
                // BatchNorm behind an activation or non-Linear parent
                // stays standalone (rare in practice).
                if parent.ltype != LayerType::Linear
                    || parent.children.len() != 1
                    || parent.act_enabled
                {
                    continue;
                }
                remove_passthrough(ir, pos);
                ir.layer_mut(pid).batchnorm_folded = true;
                return 1;
            }
            _ => {}
        }
    }
    0
}

/// Remove a single-parent pass-through layer at `pos`, splicing its
/// children onto its parent.
fn remove_passthrough(ir: &mut ModelIr, pos: usize) {
    let node = ir.layers[pos].clone();
    debug_assert_eq!(node.parents.len(), 1);
    let pid = node.parents[0];
    // Parent inherits the node's children in place of the node.
    {
        let parent = ir.layer_mut(pid);
        let at = parent
            .children
            .iter()
            .position(|&c| c == node.id)
            .expect("asymmetric edge");
        parent.children.remove(at);
        for &c in &node.children {
            parent.children.insert(at, c);
        }
    }
    // Children re-point at the parent.
    for &c in &node.children {
        let child = ir.layer_mut(c);
        for p in child.parents.iter_mut() {
            if *p == node.id {
                *p = pid;
            }
        }
    }
    ir.layers.remove(pos);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphMeta;
    use crate::ir::{GraphGymConfig, LayerIr, ZooModel};
    use crate::isa::Activation;

    fn meta() -> GraphMeta {
        GraphMeta::new("t", 1000, 8000, 128, 8)
    }

    #[test]
    fn gcn_activation_fuses_into_linear() {
        let mut ir = ZooModel::B1.build(meta());
        assert_eq!(ir.count(LayerType::Activation), 1);
        let removed = fuse(&mut ir);
        assert_eq!(removed, 1);
        assert_eq!(ir.count(LayerType::Activation), 0);
        // The first Linear now carries ReLU.
        let lin = ir
            .layers
            .iter()
            .find(|l| l.ltype == LayerType::Linear && l.act_enabled)
            .expect("fused linear");
        assert_eq!(lin.act, Activation::Relu);
        ir.validate().unwrap();
    }

    #[test]
    fn b8_batchnorms_fold_into_linears() {
        let mut ir = ZooModel::B8.build(meta());
        let bn_before = ir.count(LayerType::BatchNorm);
        assert!(bn_before > 0);
        fuse(&mut ir);
        assert_eq!(ir.count(LayerType::BatchNorm), 0);
        assert_eq!(ir.count(LayerType::Activation), 0);
        assert!(ir.layers.iter().any(|l| l.batchnorm_folded));
        ir.validate().unwrap();
    }

    #[test]
    fn fusion_reduces_layer_count_everywhere_in_zoo() {
        for m in crate::ir::ALL_MODELS {
            let mut ir = m.build(meta());
            let before = ir.n_layers();
            let removed = fuse(&mut ir);
            assert_eq!(ir.n_layers(), before - removed, "{}", m.key());
            ir.validate().unwrap_or_else(|e| panic!("{}: {e}", m.key()));
        }
    }

    #[test]
    fn branch_point_blocks_activation_fusion() {
        // Parent with two children cannot absorb the activation (the
        // other child needs the pre-activation value).
        let mut ir = ModelIr::new("t", meta());
        let a = ir.push(LayerIr::new(0, LayerType::Linear, 128, 64, 1000, 8000));
        let act = LayerIr::new(0, LayerType::Activation, 64, 64, 1000, 8000)
            .with_act(Activation::Relu);
        let _b = ir.push_with_parents(act, &[a]);
        let side = LayerIr::new(0, LayerType::Linear, 64, 32, 1000, 8000);
        ir.push_with_parents(side, &[a]);
        ir.validate().unwrap();
        assert_eq!(fuse(&mut ir), 0);
    }

    #[test]
    fn chained_act_after_bn_both_fuse() {
        // Lin -> BN -> Act: BN folds first, then Act fuses into the Lin.
        let cfg = GraphGymConfig { n_pre: 1, n_mp: 0, n_post: 0, ..Default::default() };
        let mut ir = cfg.build("pre-only", meta());
        assert_eq!(ir.n_layers(), 3);
        assert_eq!(fuse(&mut ir), 2);
        assert_eq!(ir.n_layers(), 1);
        let l = &ir.layers[0];
        assert!(l.act_enabled && l.batchnorm_folded);
        ir.validate().unwrap();
    }

    #[test]
    fn fusion_preserves_complexity_of_compute_layers() {
        // Fusion only removes element-wise layers; Aggregate/Linear
        // complexity terms must be untouched.
        let mut ir = ZooModel::B2.build(meta());
        let heavy_before: u64 = ir
            .layers
            .iter()
            .filter(|l| {
                matches!(l.ltype, LayerType::Aggregate | LayerType::Linear)
            })
            .map(|l| l.complexity())
            .sum();
        fuse(&mut ir);
        let heavy_after: u64 = ir
            .layers
            .iter()
            .filter(|l| {
                matches!(l.ltype, LayerType::Aggregate | LayerType::Linear)
            })
            .map(|l| l.complexity())
            .sum();
        assert_eq!(heavy_before, heavy_after);
    }
}
