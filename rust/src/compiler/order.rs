//! Step 1 — computation order optimization (paper Sec. 6.3, Alg. 5).
//!
//! For every adjacent {Aggregate, Linear} pair on a simple chain where
//! the aggregation operator is linear (Definition 1), exchange the two
//! layers when doing so lowers the theoretical complexity (Theorem 2):
//! Aggregate-Linear costs 2 f1 |E| + 2 f1 f2 |V|; Linear-Aggregate costs
//! 2 f1 f2 |V| + 2 f2 |E| — exchange iff the |E| term shrinks. Iterate to
//! a fixpoint (Aggregates bubble across multi-layer chains, e.g. SGC).

use crate::ir::{LayerIr, LayerType, ModelIr};

/// One Alg. 5 sweep plus the outer fixpoint loop. Returns the number of
/// exchanges performed.
pub fn optimize(ir: &mut ModelIr) -> usize {
    let mut total = 0;
    loop {
        let swapped = sweep(ir);
        total += swapped;
        if swapped == 0 {
            debug_assert_eq!(ir.validate(), Ok(()));
            return total;
        }
    }
}

/// A single forward sweep (the `for l in 1..L` loop of Alg. 5).
fn sweep(ir: &mut ModelIr) -> usize {
    let mut swaps = 0;
    for pos in 0..ir.layers.len().saturating_sub(1) {
        let (a, b) = (&ir.layers[pos], &ir.layers[pos + 1]);
        // Alg. 5 condition checks, in order:
        // 1. layer l has exactly one child: layer m (and m follows l).
        if a.children.len() != 1 || a.children[0] != b.id {
            continue;
        }
        // 2. layer m has exactly one parent: layer l.
        if b.parents.len() != 1 || b.parents[0] != a.id {
            continue;
        }
        // 3. {l, m} is an {Aggregate, Linear} pair (either order).
        let (agg_first, exchangeable) = match (a.ltype, b.ltype) {
            (LayerType::Aggregate, LayerType::Linear) => (true, true),
            (LayerType::Linear, LayerType::Aggregate) => (false, true),
            _ => (false, false),
        };
        if !exchangeable {
            continue;
        }
        // 4. the aggregation operator is linear.
        let agg = if agg_first { a } else { b };
        if !agg.has_linear_aggop() {
            continue;
        }
        // 5. exchanging reduces complexity.
        let current = a.complexity() + b.complexity();
        let exchanged = exchanged_complexity(a, b);
        if exchanged >= current {
            continue;
        }
        exchange(ir, pos);
        swaps += 1;
    }
    swaps
}

/// Complexity of the pair after exchange (Eqs. 12–13 generalized to both
/// directions).
fn exchanged_complexity(a: &LayerIr, b: &LayerIr) -> u64 {
    match (a.ltype, b.ltype) {
        (LayerType::Aggregate, LayerType::Linear) => {
            // Agg(f1) -> Lin(f1->f2)  becomes  Lin(f1->f2) -> Agg(f2).
            let (f1, f2) = (b.f_in, b.f_out);
            2 * f1 * f2 * b.nv + 2 * f2 * a.ne
        }
        (LayerType::Linear, LayerType::Aggregate) => {
            // Lin(f1->f2) -> Agg(f2)  becomes  Agg(f1) -> Lin(f1->f2).
            let (f1, f2) = (a.f_in, a.f_out);
            2 * f1 * a.ne + 2 * f1 * f2 * a.nv
        }
        _ => unreachable!("checked by caller"),
    }
}

/// Exchange layers at positions `pos` and `pos+1` on a simple chain,
/// preserving ids at their positions so neighbor references stay valid.
fn exchange(ir: &mut ModelIr, pos: usize) {
    let a = ir.layers[pos].clone();
    let b = ir.layers[pos + 1].clone();
    let (agg, lin, lin_first_after) = if a.ltype == LayerType::Aggregate {
        (a.clone(), b.clone(), true) // Agg->Lin becomes Lin->Agg
    } else {
        (b.clone(), a.clone(), false) // Lin->Agg becomes Agg->Lin
    };
    let (f1, f2) = (lin.f_in, lin.f_out);
    if lin_first_after {
        // positions: [pos] = Linear (id of a), [pos+1] = Aggregate (id b).
        ir.layers[pos] = LayerIr {
            id: a.id,
            ltype: LayerType::Linear,
            parents: a.parents.clone(),
            children: a.children.clone(), // still [b.id]
            f_in: f1,
            f_out: f2,
            ..lin.clone()
        };
        ir.layers[pos + 1] = LayerIr {
            id: b.id,
            ltype: LayerType::Aggregate,
            parents: b.parents.clone(), // still [a.id]
            children: b.children.clone(),
            f_in: f2,
            f_out: f2,
            ..agg
        };
    } else {
        // Lin->Agg becomes Agg->Lin: aggregate now runs at width f1.
        ir.layers[pos] = LayerIr {
            id: a.id,
            ltype: LayerType::Aggregate,
            parents: a.parents.clone(),
            children: a.children.clone(),
            f_in: f1,
            f_out: f1,
            ..agg
        };
        ir.layers[pos + 1] = LayerIr {
            id: b.id,
            ltype: LayerType::Linear,
            parents: b.parents.clone(),
            children: b.children.clone(),
            f_in: f1,
            f_out: f2,
            ..lin
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphMeta;
    use crate::ir::{LayerIr, ZooModel};
    use crate::isa::AggOp;

    fn meta(f: u64) -> GraphMeta {
        GraphMeta::new("t", 1000, 50_000, f, 8)
    }

    fn agg(f: u64) -> LayerIr {
        LayerIr::new(0, LayerType::Aggregate, f, f, 1000, 50_000)
    }

    fn lin(fi: u64, fo: u64) -> LayerIr {
        LayerIr::new(0, LayerType::Linear, fi, fo, 1000, 50_000)
    }

    #[test]
    fn shrinking_linear_hoists_before_aggregate() {
        // f1=512 >> f2=8: Linear-Aggregate is cheaper (Theorem 2).
        let mut ir = ModelIr::new("t", meta(512));
        ir.push(agg(512));
        ir.push(lin(512, 8));
        let before = ir.total_complexity();
        let swaps = optimize(&mut ir);
        assert_eq!(swaps, 1);
        assert!(ir.total_complexity() < before);
        assert_eq!(ir.layers[0].ltype, LayerType::Linear);
        assert_eq!(ir.layers[1].ltype, LayerType::Aggregate);
        assert_eq!(ir.layers[1].f_in, 8);
        ir.validate().unwrap();
    }

    #[test]
    fn growing_linear_stays_after_aggregate() {
        // f1=8 << f2=512: Aggregate-Linear already optimal; no exchange.
        let mut ir = ModelIr::new("t", meta(8));
        ir.push(agg(8));
        ir.push(lin(8, 512));
        assert_eq!(optimize(&mut ir), 0);
        assert_eq!(ir.layers[0].ltype, LayerType::Aggregate);
    }

    #[test]
    fn reverse_direction_exchange() {
        // Lin(8->512) -> Agg(512): aggregate is cheaper at width 8, so
        // the pass moves the Aggregate first.
        let mut ir = ModelIr::new("t", meta(8));
        ir.push(lin(8, 512));
        ir.push(agg(512));
        let before = ir.total_complexity();
        assert_eq!(optimize(&mut ir), 1);
        assert!(ir.total_complexity() < before);
        assert_eq!(ir.layers[0].ltype, LayerType::Aggregate);
        assert_eq!(ir.layers[0].f_in, 8);
        ir.validate().unwrap();
    }

    #[test]
    fn nonlinear_aggop_blocks_exchange() {
        let mut ir = ModelIr::new("t", meta(512));
        ir.push(agg(512).with_aggop(AggOp::Max));
        ir.push(lin(512, 8));
        assert_eq!(optimize(&mut ir), 0);
    }

    #[test]
    fn sgc_hoists_linear_across_both_aggregates() {
        // b7 = Agg, Agg, Lin(500 -> 8): fixpoint needs two sweeps and the
        // Linear ends up first (the paper's 260% b7 win, Fig. 14).
        let ds = meta(500);
        let mut ir = ZooModel::B7.build(ds);
        let before = ir.total_complexity();
        let swaps = optimize(&mut ir);
        assert_eq!(swaps, 2);
        assert_eq!(ir.layers[0].ltype, LayerType::Linear);
        assert_eq!(ir.layers[1].ltype, LayerType::Aggregate);
        assert_eq!(ir.layers[2].ltype, LayerType::Aggregate);
        assert!(ir.total_complexity() < before / 10);
        ir.validate().unwrap();
    }

    #[test]
    fn b8_sees_no_exchanges() {
        // GraphGym's pre-processing MLP equalizes widths (f1 == f2 == 256)
        // so no exchange helps — the paper's 0% on b8 (Fig. 14).
        let mut ir = ZooModel::B8.build(GraphMeta::new("t", 1000, 50_000, 500, 8));
        assert_eq!(optimize(&mut ir), 0);
    }

    #[test]
    fn branching_chains_are_left_alone() {
        // SAGE's Aggregate has siblings (branch point) — Alg. 5's
        // single-child/single-parent conditions must block the exchange.
        let mut ir = ZooModel::B3.build(meta(512));
        let before = ir.clone();
        // b3's aggregates feed linears but the shared parent branches.
        optimize(&mut ir);
        ir.validate().unwrap();
        // Any swap must not break the DAG; for b3 the first-layer
        // Aggregate->Linear chain (agg -> lin_neigh) IS a simple chain,
        // so an exchange is legal there when profitable. Just assert
        // complexity never increased.
        assert!(ir.total_complexity() <= before.total_complexity());
    }

    #[test]
    fn idempotent_at_fixpoint() {
        let mut ir = ZooModel::B7.build(meta(500));
        optimize(&mut ir);
        let frozen = ir.clone();
        assert_eq!(optimize(&mut ir), 0);
        assert_eq!(ir, frozen);
    }
}
