//! Step 3 — data partitioning (paper Sec. 6.5).
//!
//! Fixes the Fiber-Shard configuration (N1, N2) from the hardware buffer
//! dimensions and derives, per layer, the Layer Block's tiling grid: how
//! many Tiling Blocks the kernel-mapping step emits and what each block
//! iterates over. The same (N1, N2) is applied to every layer so outputs
//! are already partitioned for the next layer (no re-partitioning).

use crate::config::HwConfig;
use crate::graph::PartitionConfig;
use crate::ir::{LayerIr, LayerType, ModelIr};

/// Row-block height for Linear (GEMM) Tiling Blocks: GEMM has no
/// cross-row dependence, so the mapper splits each shard into smaller
/// row blocks targeting ~2 blocks per PE for dynamic load balance
/// (Alg. 9), clamped to [p_sys, N1] and p_sys-aligned.
pub fn linear_row_block(nv: u64, cfg: PartitionConfig, hw: &HwConfig) -> u64 {
    let p = hw.p_sys as u64;
    let target = nv.div_ceil(2 * hw.n_pe as u64);
    let aligned = target.div_ceil(p) * p;
    aligned.clamp(p, cfg.n1)
}

/// The tiling grid of one layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LayerGrid {
    /// Outer dimension i (fibers for Aggregate/Linear/VectorAdd, shards
    /// for Vector-Inner — see Alg. 6–8).
    pub outer: u64,
    /// Inner dimension j (shards).
    pub inner: u64,
    /// Sequential loop trip count inside a Tiling Block (subshards k for
    /// Aggregate, input fibers for Linear/Vector-Inner, 1 otherwise).
    pub depth: u64,
}

impl LayerGrid {
    pub fn n_tiles(&self) -> u64 {
        self.outer * self.inner
    }
}

/// Grid for one layer under `cfg` (Alg. 6, 7, 8 loop bounds).
pub fn grid_for_layer(layer: &LayerIr, cfg: PartitionConfig, hw: &HwConfig) -> LayerGrid {
    let shards = cfg.shards(layer.nv);
    match layer.ltype {
        // Alg. 6: for i in f_in/N2, for j in |V|/N1; inner loop over
        // subshards k in |V|/N1.
        LayerType::Aggregate => LayerGrid {
            outer: cfg.fibers(layer.f_in),
            inner: shards,
            depth: shards,
        },
        // Standard block matmul: one Tiling Block per vertex row-block
        // (sub-shard granularity for load balance); the sequential loop
        // streams the f_in fibers of H_in.
        LayerType::Linear => LayerGrid {
            outer: 1,
            inner: layer.nv.div_ceil(linear_row_block(layer.nv, cfg, hw)),
            depth: cfg.fibers(layer.f_in),
        },
        // Alg. 7: for i, j in |V|/N1 x |V|/N1; loop over fibers k.
        LayerType::VectorInner => LayerGrid {
            outer: shards,
            inner: shards,
            depth: cfg.fibers(layer.f_in),
        },
        // Alg. 8: for i in f/N2, for j in |V|/N1.
        LayerType::VectorAdd => LayerGrid {
            outer: cfg.fibers(layer.f_in),
            inner: shards,
            depth: 1,
        },
        // Standalone element-wise layers sweep the same fiber grid.
        LayerType::Activation | LayerType::BatchNorm => LayerGrid {
            outer: cfg.fibers(layer.f_in),
            inner: shards,
            depth: 1,
        },
    }
}

/// Grids for every layer of the model.
pub fn plan(ir: &ModelIr, cfg: PartitionConfig, hw: &HwConfig) -> Vec<LayerGrid> {
    ir.layers.iter().map(|l| grid_for_layer(l, cfg, hw)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphMeta;
    use crate::ir::ZooModel;

    const CFG: PartitionConfig = PartitionConfig { n1: 16384, n2: 16 };

    fn hw() -> HwConfig {
        HwConfig::alveo_u250()
    }

    #[test]
    fn aggregate_grid_matches_alg6() {
        // Reddit-scale: |V| = 232965 -> 15 shards; f = 602 -> 38 fibers.
        let l = LayerIr::new(1, LayerType::Aggregate, 602, 602, 232_965, 1);
        let g = grid_for_layer(&l, CFG, &hw());
        assert_eq!(g, LayerGrid { outer: 38, inner: 15, depth: 15 });
        assert_eq!(g.n_tiles(), 570);
    }

    #[test]
    fn linear_grid_streams_fibers_and_balances() {
        let l = LayerIr::new(1, LayerType::Linear, 602, 128, 232_965, 1);
        let g = grid_for_layer(&l, CFG, &hw());
        // Row blocks target ~2 per PE: 232965/(2*8) = 14561 -> 14576.
        assert_eq!(g.depth, 38);
        assert_eq!(g.inner, 232_965u64.div_ceil(14576));
        assert!(g.inner >= 2 * hw().n_pe as u64 - 1);
    }

    #[test]
    fn linear_row_block_bounds() {
        let hw = hw();
        // Tiny graph: clamped to p_sys.
        assert_eq!(linear_row_block(10, CFG, &hw), 16);
        // Huge graph: clamped to N1.
        assert_eq!(linear_row_block(10_000_000, CFG, &hw), 16384);
        // Mid: p_sys aligned.
        assert_eq!(linear_row_block(2708, CFG, &hw) % 16, 0);
    }

    #[test]
    fn vector_inner_grid_matches_alg7() {
        let l = LayerIr::new(1, LayerType::VectorInner, 64, 64, 40_000, 1);
        let g = grid_for_layer(&l, CFG, &hw());
        assert_eq!(g, LayerGrid { outer: 3, inner: 3, depth: 4 });
    }

    #[test]
    fn small_graph_single_shard() {
        // Cora fits in one shard: aggregates have inner == 1, while
        // Linear layers still split row blocks across PEs.
        let ir = ZooModel::B1.build(GraphMeta::new("co", 2708, 10_858, 1433, 7));
        for (l, g) in ir.layers.iter().zip(plan(&ir, CFG, &hw())) {
            match l.ltype {
                LayerType::Linear => assert!(g.inner > 1, "linear should split"),
                _ => assert_eq!(g.inner, 1, "{:?}", l.ltype),
            }
        }
    }

    #[test]
    fn plan_covers_all_layers() {
        let ir = ZooModel::B8.build(GraphMeta::new("t", 100_000, 1_000_000, 500, 7));
        assert_eq!(plan(&ir, CFG, &hw()).len(), ir.n_layers());
    }
}
