//! Large-scale input graphs (paper Sec. 9 — described as an extension):
//! when a graph exceeds the FPGA's on-board DDR, the compiler first cuts
//! it into **super data partitions**, each sized to *half* the DDR so the
//! runtime can double-buffer CPU->FPGA transfers against execution; the
//! fine-grained Fiber-Shard pipeline (Sec. 6.5–6.6) then runs per super
//! partition, and a host-side runtime schedules them.
//!
//! This module implements the super-partition planner plus the host
//! schedule with transfer/execute overlap accounting, so ogbn-papers100M
//! scale inputs compile without the graph ever fitting on the board.

use crate::graph::GraphMeta;

/// FPGA board memory budget.
#[derive(Clone, Copy, Debug)]
pub struct BoardMemory {
    /// Total on-board DDR bytes (Alveo U250: 64 GB).
    pub ddr_bytes: u64,
}

impl Default for BoardMemory {
    fn default() -> Self {
        BoardMemory { ddr_bytes: 64 << 30 }
    }
}

/// One super data partition: a contiguous vertex range plus its incident
/// edges (the compiler assigns whole shards, preserving Fiber-Shard
/// alignment downstream).
#[derive(Clone, Debug, PartialEq)]
pub struct SuperPartition {
    pub index: usize,
    /// Vertex range [v0, v1).
    pub v0: u64,
    pub v1: u64,
    /// Estimated resident bytes (features + edges + working set).
    pub bytes: u64,
}

/// The plan: partitions plus the budget each was sized against.
#[derive(Clone, Debug)]
pub struct SuperPlan {
    pub partitions: Vec<SuperPartition>,
    /// Half the DDR (double-buffering budget).
    pub budget: u64,
}

/// Estimated resident bytes for a vertex range: features for the widest
/// layer + the range's edges (estimated proportionally) + output buffer.
fn range_bytes(meta: &GraphMeta, max_f: u64, v0: u64, v1: u64) -> u64 {
    let nv = v1 - v0;
    let feat = nv * max_f * 4 * 2; // in + out feature tiles
    let edges = (meta.n_edges as f64 * (nv as f64 / meta.n_vertices as f64)) as u64 * 12;
    feat + edges
}

/// Plan super partitions for a model whose widest layer has `max_f`
/// features. Returns a single whole-graph partition when everything fits
/// in half the DDR (the common case for Table 4's graphs).
pub fn plan_super_partitions(meta: &GraphMeta, max_f: u64, board: BoardMemory) -> SuperPlan {
    let budget = board.ddr_bytes / 2;
    let total = range_bytes(meta, max_f, 0, meta.n_vertices);
    if total <= budget {
        return SuperPlan {
            partitions: vec![SuperPartition {
                index: 0,
                v0: 0,
                v1: meta.n_vertices,
                bytes: total,
            }],
            budget,
        };
    }
    // Greedy: grow each partition until the next vertex block would
    // exceed the budget. Block granularity of 64K keeps alignment with
    // N1 = 16384 shards (4 shards per block).
    const BLOCK: u64 = 65536;
    let mut partitions = Vec::new();
    let mut v0 = 0u64;
    while v0 < meta.n_vertices {
        let mut v1 = (v0 + BLOCK).min(meta.n_vertices);
        while v1 < meta.n_vertices
            && range_bytes(meta, max_f, v0, v1 + BLOCK) <= budget
        {
            v1 = (v1 + BLOCK).min(meta.n_vertices);
        }
        partitions.push(SuperPartition {
            index: partitions.len(),
            v0,
            v1,
            bytes: range_bytes(meta, max_f, v0, v1),
        });
        v0 = v1;
    }
    SuperPlan { partitions, budget }
}

/// Host-runtime schedule estimate: per-partition transfer (PCIe) and
/// execution (accelerator) phases, pipelined with double buffering.
/// Returns (total seconds, transfer seconds hidden by overlap).
pub fn schedule_super(plan: &SuperPlan, pcie_bw: f64, exec_secs: &[f64]) -> (f64, f64) {
    assert_eq!(plan.partitions.len(), exec_secs.len());
    let mut t_ready = 0.0f64; // when the next transfer can start
    let mut t_done = 0.0f64; // when the accelerator finishes
    let mut hidden = 0.0f64;
    for (p, &exec) in plan.partitions.iter().zip(exec_secs) {
        let xfer = p.bytes as f64 / pcie_bw;
        let arrive = t_ready + xfer;
        let start = arrive.max(t_done);
        hidden += xfer.min((t_done - t_ready).max(0.0));
        t_done = start + exec;
        t_ready = arrive;
    }
    (t_done, hidden)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn papers100m() -> GraphMeta {
        // ogbn-papers100M-scale (paper Sec. 9: >100 GB raw).
        GraphMeta::new("papers", 111_059_956, 1_615_685_872, 128, 172)
    }

    #[test]
    fn small_graph_single_partition() {
        let meta = GraphMeta::new("co", 2708, 5429, 1433, 7);
        let plan = plan_super_partitions(&meta, 1433, BoardMemory::default());
        assert_eq!(plan.partitions.len(), 1);
        assert_eq!(plan.partitions[0].v1, 2708);
    }

    #[test]
    fn papers100m_splits_under_budget() {
        let meta = papers100m();
        let plan = plan_super_partitions(&meta, 256, BoardMemory::default());
        assert!(plan.partitions.len() > 1, "must split");
        for p in &plan.partitions {
            assert!(p.bytes <= plan.budget, "partition {} over budget", p.index);
        }
        // Coverage: contiguous, disjoint, total.
        let mut at = 0;
        for p in &plan.partitions {
            assert_eq!(p.v0, at);
            assert!(p.v1 > p.v0);
            at = p.v1;
        }
        assert_eq!(at, meta.n_vertices);
    }

    #[test]
    fn double_buffering_hides_transfers() {
        let meta = papers100m();
        let plan = plan_super_partitions(&meta, 256, BoardMemory::default());
        let n = plan.partitions.len();
        // Execution much longer than transfer: all but the first
        // transfer should hide.
        let slow_exec = vec![10.0; n];
        let (total, hidden) = schedule_super(&plan, 31.5e9, &slow_exec);
        let xfer0 = plan.partitions[0].bytes as f64 / 31.5e9;
        assert!((total - (n as f64 * 10.0 + xfer0)).abs() < 1.0, "total {total}");
        assert!(hidden > 0.0);
        // Execution instantaneous: transfers serialize (no hiding).
        let fast_exec = vec![0.0; n];
        let (total_fast, _) = schedule_super(&plan, 31.5e9, &fast_exec);
        let all_xfer: f64 =
            plan.partitions.iter().map(|p| p.bytes as f64 / 31.5e9).sum();
        assert!((total_fast - all_xfer).abs() < 1e-6);
    }

    #[test]
    fn budget_is_half_ddr() {
        let plan = plan_super_partitions(&papers100m(), 256, BoardMemory::default());
        assert_eq!(plan.budget, 32 << 30);
    }
}
