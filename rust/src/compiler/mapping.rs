//! Step 4 — kernel mapping, instruction interleaving, code generation
//! (paper Sec. 6.6).
//!
//! The kernel choices emitted here are *provisional*: they encode the
//! static per-layer mapping (Aggregate -> SpDMM, Linear -> GEMM,
//! Vector-Inner -> SDDMM, ...), and — when
//! `CompileOptions::dynamic_thresholds` is on — the pass also profiles
//! tile densities and embeds a `crate::sparsity::ThresholdTable` in the
//! binary (the GA02 section) so engines can re-map GEMM<->SpDMM per
//! Tiling Block once runtime densities are known.
//!
//! Each layer maps to a **Layer Block**: a Control-and-Scheduling
//! Instruction followed by the layer's **Tiling Blocks** (the unfolded
//! outer loops of Alg. 6–8). A Tiling Block is an inseparable instruction
//! sequence executed by one PE: memory reads (annotated with the buffer
//! mutex `lock` bit that prevents WAR hazards under look-ahead issue),
//! ACK compute instructions, and the result write-back.
//!
//! Hardware constraints honored here:
//! * a subshard whose edge count exceeds the Edge Buffer capacity is
//!   processed in buffer-sized chunks (MemRead + SpDMM per chunk);
//! * a weight matrix larger than the Weight Buffer is split into
//!   column chunks (MemRead + GEMM per chunk);
//! * the fused activation executes on the final compute instruction of a
//!   tile, when the accumulator holds the complete result.

use super::partition::LayerGrid;
use super::CompileOptions;
use crate::config::HwConfig;
use crate::graph::{PartitionConfig, TileCounts};
use crate::ir::{LayerIr, LayerType, ModelIr};
use crate::isa::{
    Activation, AggOp, BufferId, Instr, LayerBlock, Program, TilingBlock,
};
use crate::util::ceil_div;

/// Reference to one subshard's edges within a Tiling Block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SubshardRef {
    /// Source shard index k (column block of A).
    pub k: u32,
    /// Edge count of subshard (shard, k).
    pub ne: u64,
}

/// Structured description of one Tiling Block — what the functional
/// runtime needs to bind the block to actual tile data (the `.ga` binary
/// carries the same information as DDR addresses).
#[derive(Clone, Debug, PartialEq)]
pub enum TileTask {
    /// Alg. 6: H_out(fiber, shard) = AggOp over subshards k.
    Aggregate {
        fiber: u32,
        shard: u32,
        rows: u32,
        cols: u16,
        aggop: AggOp,
        act: Activation,
        subshards: Vec<SubshardRef>,
    },
    /// Block matmul of one vertex row-block against the weight matrix.
    Linear {
        /// First vertex row of this block (row blocks are sub-shard
        /// sized for load balance; see `partition::linear_row_block`).
        row0: u32,
        rows: u32,
        f_in: u32,
        f_out: u32,
        act: Activation,
        batchnorm_folded: bool,
    },
    /// Alg. 7: edge weights of subshard (i, j) via SDDMM.
    VectorInner {
        i: u32,
        j: u32,
        ne: u64,
        cols_total: u32,
        act: Activation,
    },
    /// Alg. 8: tile-wise H_a + H_b.
    VectorAdd {
        fiber: u32,
        shard: u32,
        rows: u32,
        cols: u16,
        act: Activation,
    },
    /// Standalone element-wise layer (fusion disabled), Activation or
    /// BatchNorm.
    Eltwise {
        fiber: u32,
        shard: u32,
        rows: u32,
        cols: u16,
        act: Activation,
        batchnorm: bool,
    },
}

/// All Tiling Blocks of one layer, aligned 1:1 (same order) with the
/// corresponding `LayerBlock.blocks` of the Program.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerTasks {
    pub layer_id: u16,
    pub ltype: LayerType,
    pub tasks: Vec<TileTask>,
}

/// DDR address map: edges first, then weights, then one feature region
/// per layer boundary (region 0 = graph input features).
struct AddrMap {
    edge_base: u64,
    /// Prefix sums (in edges) over subshards, row-major.
    edge_prefix: Vec<u64>,
    shards: usize,
    weight_base: u64,
    /// feature_region[l] = base address of the tensor produced by layer
    /// index l-1 (region 0 is the graph input).
    feature_region: Vec<u64>,
}

impl AddrMap {
    fn new(ir: &ModelIr, tiles: &TileCounts) -> AddrMap {
        let mut edge_prefix = Vec::with_capacity(tiles.counts.len() + 1);
        let mut acc = 0u64;
        edge_prefix.push(0);
        for &c in &tiles.counts {
            acc += c;
            edge_prefix.push(acc);
        }
        let edge_bytes = acc * 12;
        let weight_base = edge_bytes;
        // Generous static weight region (weights are small).
        let weight_region = 64 << 20;
        let max_f = ir.layers.iter().map(|l| l.f_in.max(l.f_out)).max().unwrap_or(1);
        let region_stride = (ir.graph.n_vertices * max_f * 4).next_power_of_two();
        let base = weight_base + weight_region;
        let feature_region = (0..=ir.layers.len())
            .map(|l| base + l as u64 * region_stride)
            .collect();
        AddrMap {
            edge_base: 0,
            edge_prefix,
            shards: tiles.shards,
            weight_base,
            feature_region,
        }
    }

    fn edge_addr(&self, shard: usize, k: usize) -> u64 {
        self.edge_base + 12 * self.edge_prefix[shard * self.shards + k]
    }

    /// Address of subfiber (row block `shard`, fiber `fiber`) of the
    /// tensor in `region`, laid out fiber-major as in Fig. 8.
    fn feat_addr(&self, region: usize, shard: u64, fiber: u64, n1: u64, nv: u64) -> u64 {
        let col_bytes = 4 * (nv * fiber); // whole fibers before this one
        self.feature_region[region] + col_bytes + shard * n1 * 4
    }
}

/// Map the optimized IR onto the ISA. Returns the `.ga` Program and the
/// aligned structured tasks.
pub fn map_program(
    ir: &ModelIr,
    tiles: &TileCounts,
    grids: &[LayerGrid],
    cfg: PartitionConfig,
    hw: &HwConfig,
    opts: &CompileOptions,
) -> (Program, Vec<LayerTasks>) {
    debug_assert_eq!(grids.len(), ir.layers.len());
    let addr = AddrMap::new(ir, tiles);
    // region index of each layer's *input*: parent position + 1, or 0.
    let pos_of: std::collections::HashMap<u16, usize> =
        ir.layers.iter().enumerate().map(|(p, l)| (l.id, p)).collect();

    let mut layers = Vec::with_capacity(ir.layers.len());
    let mut all_tasks = Vec::with_capacity(ir.layers.len());
    for (pos, layer) in ir.layers.iter().enumerate() {
        let grid = grids[pos];
        let in_region = layer
            .parents
            .first()
            .map(|p| pos_of[p] + 1)
            .unwrap_or(0);
        let in_region2 = layer
            .parents
            .get(1)
            .map(|p| pos_of[p] + 1)
            .unwrap_or(in_region);
        let out_region = pos + 1;
        let ctx = MapCtx {
            layer,
            tiles,
            cfg,
            hw,
            opts,
            addr: &addr,
            in_region,
            in_region2,
            out_region,
        };
        let (blocks, tasks) = match layer.ltype {
            LayerType::Aggregate => map_aggregate(&ctx),
            LayerType::Linear => map_linear(&ctx),
            LayerType::VectorInner => map_vector_inner(&ctx),
            LayerType::VectorAdd => map_vector_add(&ctx),
            LayerType::Activation | LayerType::BatchNorm => map_eltwise(&ctx),
        };
        debug_assert_eq!(blocks.len(), tasks.len());
        debug_assert_eq!(blocks.len() as u64, grid.n_tiles());
        let csi = Instr::Csi {
            layer_id: layer.id,
            layer_type: layer.ltype as u8,
            n_tiling_blocks: blocks.len() as u32,
        };
        layers.push(LayerBlock { csi, blocks });
        all_tasks.push(LayerTasks {
            layer_id: layer.id,
            ltype: layer.ltype,
            tasks,
        });
    }
    // Provisional kernel choices are what the instructions above encode;
    // the threshold table rides along so engines can override them per
    // Tiling Block once runtime densities are known (crate::sparsity).
    let thresholds = if opts.dynamic_thresholds {
        Some(crate::sparsity::build_table(ir, tiles))
    } else {
        None
    };
    let program = Program {
        n1: cfg.n1 as u32,
        n2: cfg.n2 as u32,
        model_name: ir.name.clone(),
        graph_name: ir.graph.name.clone(),
        thresholds,
        // Calibration is a post-compile attach (`quant::calibrate` needs
        // the weight store, which compilation does not see).
        scales: None,
        layers,
    };
    (program, all_tasks)
}

struct MapCtx<'a> {
    layer: &'a LayerIr,
    tiles: &'a TileCounts,
    cfg: PartitionConfig,
    hw: &'a HwConfig,
    opts: &'a CompileOptions,
    addr: &'a AddrMap,
    in_region: usize,
    in_region2: usize,
    out_region: usize,
}

impl<'a> MapCtx<'a> {
    fn rows_of_shard(&self, j: u64) -> u32 {
        (self.layer.nv - j * self.cfg.n1).min(self.cfg.n1) as u32
    }

    fn cols_of_fiber(&self, i: u64, f: u64) -> u16 {
        ((f - i * self.cfg.n2).min(self.cfg.n2)) as u16
    }

    fn act(&self) -> Activation {
        if self.layer.act_enabled { self.layer.act } else { Activation::None }
    }
}

/// Alg. 6 — Aggregate layer.
fn map_aggregate(ctx: &MapCtx) -> (Vec<TilingBlock>, Vec<TileTask>) {
    let l = ctx.layer;
    let (n1, _n2) = (ctx.cfg.n1, ctx.cfg.n2);
    let shards = ctx.cfg.shards(l.nv);
    let fibers = ctx.cfg.fibers(l.f_in);
    let aggop = l.aggop.unwrap_or(AggOp::Sum);
    let act = ctx.act();
    let edge_cap = ctx.hw.edge_capacity as u64;
    let mut blocks = Vec::new();
    let mut tasks = Vec::new();
    for i in 0..fibers {
        let cols = ctx.cols_of_fiber(i, l.f_in);
        for j in 0..shards {
            let rows = ctx.rows_of_shard(j);
            let mut instrs = vec![Instr::Init { rows, cols, aggop }];
            let mut refs = Vec::new();
            // Which subshards contribute?
            let contributing: Vec<(u64, u64)> = (0..shards)
                .map(|k| (k, ctx.tiles.get(j as usize, k as usize)))
                .filter(|&(_, ne)| ne > 0 || !ctx.opts.skip_empty_tiles)
                .collect();
            let last = contributing
                .iter()
                .rposition(|&(_, ne)| ne > 0)
                .unwrap_or(usize::MAX);
            for (idx, &(k, ne)) in contributing.iter().enumerate() {
                refs.push(SubshardRef { k: k as u32, ne });
                // Feature subfiber H_in(k, i). A sparse subshard
                // references at most `ne` distinct source rows, so the
                // loader issues an index-bounded gather instead of the
                // full subfiber (the ISN routes rows by index anyway);
                // this caps feature traffic on low-degree graphs.
                let rows_k = ctx.rows_of_shard(k) as u64;
                let gather_rows = rows_k.min(ne.max(1));
                instrs.push(Instr::MemRead {
                    buf: BufferId::Feature0,
                    addr: ctx.addr.feat_addr(ctx.in_region, k, i, n1, l.nv),
                    bytes: (gather_rows * cols as u64 * 4) as u32,
                    lock: true,
                });
                // Edge chunks of subshard (j, k).
                let chunks = ceil_div(ne, edge_cap).max(1);
                for c in 0..chunks {
                    let ne_c = if ne == 0 {
                        0
                    } else {
                        (ne - c * edge_cap).min(edge_cap)
                    };
                    instrs.push(Instr::MemRead {
                        buf: BufferId::Edge0,
                        addr: ctx.addr.edge_addr(j as usize, k as usize) + c * edge_cap * 12,
                        bytes: (ne_c * 12) as u32,
                        lock: true,
                    });
                    let is_last = idx == last && c + 1 == chunks;
                    instrs.push(Instr::Spdmm {
                        n_edges: ne_c as u32,
                        feat: cols,
                        aggop,
                        act: if is_last { act } else { Activation::None },
                    });
                }
            }
            instrs.push(Instr::MemWrite {
                buf: BufferId::Result,
                addr: ctx.addr.feat_addr(ctx.out_region, j, i, n1, l.nv),
                bytes: rows * cols as u32 * 4,
            });
            blocks.push(TilingBlock::new(instrs));
            tasks.push(TileTask::Aggregate {
                fiber: i as u32,
                shard: j as u32,
                rows,
                cols,
                aggop,
                act,
                subshards: refs,
            });
        }
    }
    (blocks, tasks)
}

/// Standard block matmul — Linear layer. Row blocks are sub-shard sized
/// (`partition::linear_row_block`) so small graphs still fan out across
/// all PEs.
fn map_linear(ctx: &MapCtx) -> (Vec<TilingBlock>, Vec<TileTask>) {
    let l = ctx.layer;
    let n1 = ctx.cfg.n1;
    let rb = super::partition::linear_row_block(l.nv, ctx.cfg, ctx.hw);
    let n_blocks = l.nv.div_ceil(rb);
    let fibers_in = ctx.cfg.fibers(l.f_in);
    let act = ctx.act();
    // Weight Buffer capacity in f32 words; chunk f_out columns to fit.
    let w_cap = (ctx.hw.weight_rows * ctx.hw.p_sys) as u64;
    let w_cols_max = (w_cap / l.f_in.max(1)).max(1).min(u16::MAX as u64);
    let mut blocks = Vec::new();
    let mut tasks = Vec::new();
    for j in 0..n_blocks {
        let row0 = j * rb;
        let rows = (l.nv - row0).min(rb) as u32;
        let mut instrs = Vec::new();
        let mut c0 = 0u64;
        while c0 < l.f_out {
            let wc = (l.f_out - c0).min(w_cols_max);
            instrs.push(Instr::MemRead {
                buf: BufferId::Weight0,
                addr: ctx.addr.weight_base + (l.id as u64) * (4 << 20) + c0 * l.f_in * 4,
                bytes: (l.f_in * wc * 4) as u32,
                lock: true,
            });
            for k in 0..fibers_in {
                let cols_k = ctx.cols_of_fiber(k, l.f_in);
                instrs.push(Instr::MemRead {
                    buf: BufferId::Feature0,
                    addr: ctx.addr.feat_addr(ctx.in_region, row0 / n1, k, n1, l.nv)
                        + (row0 % n1) * 4,
                    bytes: rows * cols_k as u32 * 4,
                    lock: true,
                });
            }
            instrs.push(Instr::Gemm {
                rows,
                len: l.f_in as u16,
                cols: wc as u16,
                act,
                accumulate: false,
            });
            instrs.push(Instr::MemWrite {
                buf: BufferId::Result,
                addr: ctx.addr.feat_addr(ctx.out_region, row0 / n1, c0 / ctx.cfg.n2, n1, l.nv)
                    + (row0 % n1) * 4,
                bytes: rows * wc as u32 * 4,
            });
            c0 += wc;
        }
        blocks.push(TilingBlock::new(instrs));
        tasks.push(TileTask::Linear {
            row0: row0 as u32,
            rows,
            f_in: l.f_in as u32,
            f_out: l.f_out as u32,
            act,
            batchnorm_folded: l.batchnorm_folded,
        });
    }
    (blocks, tasks)
}

/// Alg. 7 — Vector-Inner (SDDMM) layer.
fn map_vector_inner(ctx: &MapCtx) -> (Vec<TilingBlock>, Vec<TileTask>) {
    let l = ctx.layer;
    let n1 = ctx.cfg.n1;
    let shards = ctx.cfg.shards(l.nv);
    let fibers = ctx.cfg.fibers(l.f_in);
    let act = ctx.act();
    let edge_cap = ctx.hw.edge_capacity as u64;
    let mut blocks = Vec::new();
    let mut tasks = Vec::new();
    for i in 0..shards {
        for j in 0..shards {
            let ne = ctx.tiles.get(i as usize, j as usize);
            let mut instrs = Vec::new();
            if ne > 0 || !ctx.opts.skip_empty_tiles {
                let chunks = ceil_div(ne, edge_cap).max(1);
                for c in 0..chunks {
                    let ne_c = if ne == 0 {
                        0
                    } else {
                        (ne - c * edge_cap).min(edge_cap)
                    };
                    instrs.push(Instr::MemRead {
                        buf: BufferId::Edge0,
                        addr: ctx.addr.edge_addr(i as usize, j as usize) + c * edge_cap * 12,
                        bytes: (ne_c * 12) as u32,
                        lock: true,
                    });
                    for k in 0..fibers {
                        let cols_k = ctx.cols_of_fiber(k, l.f_in);
                        // Destination-side and source-side subfibers.
                        instrs.push(Instr::MemRead {
                            buf: BufferId::Feature0,
                            addr: ctx.addr.feat_addr(ctx.in_region, i, k, n1, l.nv),
                            bytes: ctx.rows_of_shard(i) * cols_k as u32 * 4,
                            lock: true,
                        });
                        instrs.push(Instr::MemRead {
                            buf: BufferId::Feature1,
                            addr: ctx.addr.feat_addr(ctx.in_region, j, k, n1, l.nv),
                            bytes: ctx.rows_of_shard(j) * cols_k as u32 * 4,
                            lock: true,
                        });
                        instrs.push(Instr::Sddmm {
                            n_edges: ne_c as u32,
                            feat: cols_k,
                            act: if k + 1 == fibers { act } else { Activation::None },
                        });
                    }
                    // Updated edge weights go back to DDR.
                    instrs.push(Instr::MemWrite {
                        buf: BufferId::Edge0,
                        addr: ctx.addr.edge_addr(i as usize, j as usize) + c * edge_cap * 12,
                        bytes: (ne_c * 12) as u32,
                    });
                }
            }
            blocks.push(TilingBlock::new(instrs));
            tasks.push(TileTask::VectorInner {
                i: i as u32,
                j: j as u32,
                ne,
                cols_total: l.f_in as u32,
                act,
            });
        }
    }
    (blocks, tasks)
}

/// Alg. 8 — Vector-Add layer.
fn map_vector_add(ctx: &MapCtx) -> (Vec<TilingBlock>, Vec<TileTask>) {
    let l = ctx.layer;
    let n1 = ctx.cfg.n1;
    let shards = ctx.cfg.shards(l.nv);
    let fibers = ctx.cfg.fibers(l.f_in);
    let act = ctx.act();
    let mut blocks = Vec::new();
    let mut tasks = Vec::new();
    for i in 0..fibers {
        let cols = ctx.cols_of_fiber(i, l.f_in);
        for j in 0..shards {
            let rows = ctx.rows_of_shard(j);
            let instrs = vec![
                Instr::MemRead {
                    buf: BufferId::Feature0,
                    addr: ctx.addr.feat_addr(ctx.in_region, j, i, n1, l.nv),
                    bytes: rows * cols as u32 * 4,
                    lock: true,
                },
                Instr::MemRead {
                    buf: BufferId::Feature1,
                    addr: ctx.addr.feat_addr(ctx.in_region2, j, i, n1, l.nv),
                    bytes: rows * cols as u32 * 4,
                    lock: true,
                },
                Instr::Vadd { rows, cols, act },
                Instr::MemWrite {
                    buf: BufferId::Result,
                    addr: ctx.addr.feat_addr(ctx.out_region, j, i, n1, l.nv),
                    bytes: rows * cols as u32 * 4,
                },
            ];
            blocks.push(TilingBlock::new(instrs));
            tasks.push(TileTask::VectorAdd {
                fiber: i as u32,
                shard: j as u32,
                rows,
                cols,
                act,
            });
        }
    }
    (blocks, tasks)
}

/// Standalone Activation / BatchNorm layer (fusion off).
fn map_eltwise(ctx: &MapCtx) -> (Vec<TilingBlock>, Vec<TileTask>) {
    let l = ctx.layer;
    let n1 = ctx.cfg.n1;
    let shards = ctx.cfg.shards(l.nv);
    let fibers = ctx.cfg.fibers(l.f_in);
    let batchnorm = l.ltype == LayerType::BatchNorm;
    // BatchNorm executes on the same element-wise path as activations
    // (scale+shift per element; the Activation Unit's multiply-add).
    let act = if batchnorm { Activation::None } else { l.act };
    let mut blocks = Vec::new();
    let mut tasks = Vec::new();
    for i in 0..fibers {
        let cols = ctx.cols_of_fiber(i, l.f_in);
        for j in 0..shards {
            let rows = ctx.rows_of_shard(j);
            let instrs = vec![
                Instr::MemRead {
                    buf: BufferId::Feature0,
                    addr: ctx.addr.feat_addr(ctx.in_region, j, i, n1, l.nv),
                    bytes: rows * cols as u32 * 4,
                    lock: true,
                },
                Instr::Act { rows, cols, act },
                Instr::MemWrite {
                    buf: BufferId::Result,
                    addr: ctx.addr.feat_addr(ctx.out_region, j, i, n1, l.nv),
                    bytes: rows * cols as u32 * 4,
                },
            ];
            blocks.push(TilingBlock::new(instrs));
            tasks.push(TileTask::Eltwise {
                fiber: i as u32,
                shard: j as u32,
                rows,
                cols,
                act,
                batchnorm,
            });
        }
    }
    (blocks, tasks)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOptions};
    use crate::graph::{dataset, GraphMeta};
    use crate::ir::ZooModel;

    fn compile_model(m: ZooModel, key: &str) -> crate::compiler::Executable {
        let ds = dataset(key).unwrap();
        let hw = HwConfig::alveo_u250();
        let tiles = ds.tile_counts(hw.n1() as u64);
        compile(&m.build(ds.meta()), &tiles, &hw, CompileOptions::default())
    }

    #[test]
    fn blocks_align_with_tasks() {
        let exe = compile_model(ZooModel::B1, "PU");
        for (lb, lt) in exe.program.layers.iter().zip(&exe.tasks) {
            assert_eq!(lb.blocks.len(), lt.tasks.len());
            if let Instr::Csi { n_tiling_blocks, .. } = lb.csi {
                assert_eq!(n_tiling_blocks as usize, lb.blocks.len());
            } else {
                panic!("missing CSI");
            }
        }
    }

    #[test]
    fn aggregate_blocks_have_init_and_writeback() {
        let exe = compile_model(ZooModel::B1, "PU");
        let agg = exe
            .tasks
            .iter()
            .position(|l| l.ltype == LayerType::Aggregate)
            .unwrap();
        for block in &exe.program.layers[agg].blocks {
            assert!(matches!(block.instrs.first(), Some(Instr::Init { .. })));
            assert!(matches!(block.instrs.last(), Some(Instr::MemWrite { .. })));
        }
    }

    #[test]
    fn edge_chunking_respects_buffer_capacity() {
        // Flickr has ~900K edges in few shards; every SpDMM must stay
        // within the 65536-edge buffer.
        let exe = compile_model(ZooModel::B2, "FL");
        let cap = HwConfig::alveo_u250().edge_capacity as u32;
        let mut spdmm_seen = 0;
        for lb in &exe.program.layers {
            for b in &lb.blocks {
                for ins in &b.instrs {
                    if let Instr::Spdmm { n_edges, .. } = ins {
                        assert!(*n_edges <= cap);
                        spdmm_seen += 1;
                    }
                }
            }
        }
        assert!(spdmm_seen > 0);
    }

    #[test]
    fn total_spdmm_edges_cover_graph_per_fiber_sweep() {
        // For one Aggregate layer, the sum of SpDMM edge counts equals
        // fibers x |E| (each fiber sweep processes every edge once).
        let ds = dataset("PU").unwrap();
        let hw = HwConfig::alveo_u250();
        let tiles = ds.tile_counts(hw.n1() as u64);
        let ir = ZooModel::B7.build(ds.meta()); // starts with Aggregates
        let exe = compile(
            &ir,
            &tiles,
            &hw,
            CompileOptions { order_opt: false, ..Default::default() },
        );
        let agg_layer = &exe.program.layers[0];
        let total: u64 = agg_layer
            .blocks
            .iter()
            .flat_map(|b| &b.instrs)
            .filter_map(|i| match i {
                Instr::Spdmm { n_edges, .. } => Some(*n_edges as u64),
                _ => None,
            })
            .sum();
        let fibers = (ds.feat_len as u64).div_ceil(hw.n2() as u64);
        assert_eq!(total, fibers * ds.n_edges);
    }

    #[test]
    fn weight_chunking_on_wide_layers() {
        // Citeseer f_in = 3703: weight buffer fits 262144/3703 = 70 cols;
        // a Linear to 128 outputs must emit >= 2 weight chunks.
        let meta = GraphMeta::new("ci-like", 3000, 10_000, 3703, 6);
        let hw = HwConfig::alveo_u250();
        let tiles =
            crate::graph::rmat::rmat_tile_counts(&meta, Default::default(), 1, hw.n1() as u64);
        let ir = ZooModel::B2.build(meta);
        let exe = compile(
            &ir,
            &tiles,
            &hw,
            CompileOptions { order_opt: false, fusion: true, ..Default::default() },
        );
        let lin = exe
            .tasks
            .iter()
            .position(|l| l.ltype == LayerType::Linear)
            .unwrap();
        let gemms = exe.program.layers[lin].blocks[0]
            .instrs
            .iter()
            .filter(|i| matches!(i, Instr::Gemm { .. }))
            .count();
        assert!(gemms >= 2, "expected weight chunking, got {gemms} GEMM(s)");
    }

    #[test]
    fn empty_subshards_skipped_by_default() {
        let ds = dataset("PU").unwrap(); // 2 shards, sparse
        let hw = HwConfig::alveo_u250();
        let tiles = ds.tile_counts(hw.n1() as u64);
        let ir = ZooModel::B1.build(ds.meta());
        let on = compile(&ir, &tiles, &hw, CompileOptions::default());
        let off = compile(
            &ir,
            &tiles,
            &hw,
            CompileOptions { skip_empty_tiles: false, ..Default::default() },
        );
        assert!(on.program.size_bytes() <= off.program.size_bytes());
    }

    #[test]
    fn addresses_fit_40_bits() {
        let exe = compile_model(ZooModel::B8, "YE");
        for lb in &exe.program.layers {
            for b in &lb.blocks {
                for ins in &b.instrs {
                    if let Instr::MemRead { addr, .. } | Instr::MemWrite { addr, .. } = ins {
                        assert!(*addr < (1u64 << 40), "addr {addr:#x}");
                    }
                }
            }
        }
    }

    #[test]
    fn threshold_table_follows_the_option() {
        let ds = dataset("PU").unwrap();
        let hw = HwConfig::alveo_u250();
        let tiles = ds.tile_counts(hw.n1() as u64);
        let ir = ZooModel::B1.build(ds.meta());
        let on = compile(&ir, &tiles, &hw, CompileOptions::default());
        let tt = on.program.thresholds.as_ref().expect("default emits the GA02 section");
        // One provisional entry per emitted layer, ids aligned.
        assert_eq!(tt.entries.len(), on.program.layers.len());
        for lb in &on.program.layers {
            if let Instr::Csi { layer_id, .. } = lb.csi {
                assert!(tt.entry(layer_id).is_some(), "no entry for layer {layer_id}");
            }
        }
        let off = compile(
            &ir,
            &tiles,
            &hw,
            CompileOptions { dynamic_thresholds: false, ..Default::default() },
        );
        assert!(off.program.thresholds.is_none());
    }

    #[test]
    fn vector_inner_grid_is_shards_squared() {
        let exe = compile_model(ZooModel::B6, "PU");
        let vi = exe
            .tasks
            .iter()
            .find(|l| l.ltype == LayerType::VectorInner)
            .unwrap();
        let shards = dataset("PU").unwrap().n_vertices.div_ceil(16384);
        assert_eq!(vi.tasks.len() as u64, shards * shards);
    }
}
