//! Shape-bucketed compilation for mini-batch ego-networks.
//!
//! Thousands of distinct ego-nets would mean thousands of distinct
//! `Executable`s if each were compiled at its exact (|V|, |E|). Instead,
//! sampled shapes are rounded **up** to power-of-two buckets
//! ([`BucketShape::for_graph`]): every ego-net with `v <= 2^a` vertices
//! and `e <= 2^b` edges executes the one program compiled for
//! `(2^a, 2^b)`, so a serving fleet sees a handful of bucket keys — and
//! near-perfect program-cache hit rates — no matter how diverse the
//! requests are.
//!
//! Why padding is sound:
//! * **vertices** — the ego-net is re-homed in a `2^a`-vertex graph
//!   whose extra vertices are isolated and whose extra feature rows are
//!   zero ([`crate::graph::sample::EgoNet::padded_features`]). No edge
//!   references a padded row, Linear/eltwise layers are row-local, and
//!   aggregation zeroes untouched rows — live-row results are
//!   bit-identical to the unpadded execution (pinned by
//!   `rust/tests/minibatch.rs`);
//! * **edges** — the bucket's edge count only sizes the instruction
//!   stream (a timing model input). The functional executor binds tiles
//!   to the *member* graph's partition, so the canonical edge placement
//!   ([`canonical_tiles`]) never affects numerics.
//!
//! Bucket programs are compiled with [`bucket_options`]: every subshard
//! gets a task (a member ego-net decides at run time which tiles hold
//! edges, so none may be skipped at compile time), and the GA02
//! threshold table is omitted (canonical densities say nothing about
//! members; the static kernel mapping is authoritative).

use super::{compile, CompileOptions, Executable};
use crate::config::HwConfig;
use crate::graph::{GraphMeta, TileCounts};
use crate::ir::ZooModel;

/// Smallest vertex bucket: tiny ego-nets all share one program.
pub const MIN_BUCKET_VERTICES: u64 = 64;
/// Smallest edge bucket.
pub const MIN_BUCKET_EDGES: u64 = 256;

/// A compiled-program shape class: vertex/edge counts rounded up to
/// powers of two, plus the (exact) feature length and class count the
/// model was built for.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BucketShape {
    /// Vertex bucket (power of two, >= [`MIN_BUCKET_VERTICES`]).
    pub v: u32,
    /// Edge bucket (power of two, >= [`MIN_BUCKET_EDGES`]).
    pub e: u32,
    /// Input feature length (exact — it shapes every weight matrix).
    pub f: u32,
    /// Output classes (exact).
    pub c: u32,
}

impl BucketShape {
    /// The bucket covering a `(n_vertices, n_edges)` ego-net.
    pub fn of(n_vertices: u64, n_edges: u64, feat_len: u64, n_classes: u64) -> BucketShape {
        BucketShape {
            v: n_vertices.max(MIN_BUCKET_VERTICES).next_power_of_two() as u32,
            e: n_edges.max(MIN_BUCKET_EDGES).next_power_of_two() as u32,
            f: feat_len as u32,
            c: n_classes as u32,
        }
    }

    /// The bucket covering `meta`.
    pub fn for_graph(meta: &GraphMeta) -> BucketShape {
        BucketShape::of(meta.n_vertices, meta.n_edges, meta.feat_len, meta.n_classes)
    }

    /// The exact (unrounded) shape of `meta` — the baseline the
    /// padding-equivalence test compares bucket execution against.
    pub fn exact(meta: &GraphMeta) -> BucketShape {
        BucketShape {
            v: meta.n_vertices.max(1) as u32,
            e: meta.n_edges.max(1) as u32,
            f: meta.feat_len as u32,
            c: meta.n_classes as u32,
        }
    }

    /// Graph metadata of the canonical bucket instance.
    pub fn meta(&self) -> GraphMeta {
        GraphMeta::new("bucket", self.v as u64, self.e as u64, self.f as u64, self.c as u64)
    }
}

/// Canonical per-subshard edge counts for a bucket: `e` edges spread
/// uniformly over the `shards^2` grid (remainder to the leading tiles).
/// Total is exactly `e`, so the modeled execution time of the bucket
/// program is a stable upper-envelope cost for every member ego-net.
pub fn canonical_tiles(shape: BucketShape, n1: u64) -> TileCounts {
    let shards = (shape.v as u64).div_ceil(n1) as usize;
    let cells = (shards * shards) as u64;
    let (base, rem) = (shape.e as u64 / cells, shape.e as u64 % cells);
    let counts = (0..cells).map(|i| base + u64::from(i < rem)).collect();
    TileCounts { n1, shards, counts }
}

/// Compile options for bucket executables (see the module docs).
pub fn bucket_options() -> CompileOptions {
    CompileOptions {
        skip_empty_tiles: false,
        dynamic_thresholds: false,
        ..CompileOptions::default()
    }
}

/// Compile the canonical program of `(model, shape)` — the one
/// executable every member ego-net of the bucket runs on.
pub fn compile_bucket(model: ZooModel, shape: BucketShape, hw: &HwConfig) -> Executable {
    let tiles = canonical_tiles(shape, hw.n1() as u64);
    let ir = model.build(shape.meta());
    compile(&ir, &tiles, hw, bucket_options())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::LayerType;

    #[test]
    fn rounding_hits_the_floors_and_powers_of_two() {
        let tiny = BucketShape::of(1, 0, 32, 4);
        assert_eq!((tiny.v, tiny.e), (64, 256));
        let mid = BucketShape::of(300, 1500, 32, 4);
        assert_eq!((mid.v, mid.e), (512, 2048));
        // Exact powers of two stay put (no off-by-one doubling).
        let pow = BucketShape::of(512, 2048, 32, 4);
        assert_eq!((pow.v, pow.e), (512, 2048));
    }

    #[test]
    fn nearby_shapes_share_a_bucket() {
        let a = BucketShape::of(130, 900, 64, 8);
        let b = BucketShape::of(255, 1024, 64, 8);
        assert_eq!(a, b);
        let c = BucketShape::of(257, 1024, 64, 8);
        assert_ne!(a, c);
    }

    #[test]
    fn canonical_tiles_cover_the_bucket_exactly() {
        let shape = BucketShape::of(300, 1500, 32, 4);
        let tiles = canonical_tiles(shape, 128);
        assert_eq!(tiles.shards, 4); // 512 / 128
        assert_eq!(tiles.total_edges(), shape.e as u64);
        // Uniform spread: counts differ by at most one.
        let (lo, hi) = (
            tiles.counts.iter().min().unwrap(),
            tiles.counts.iter().max().unwrap(),
        );
        assert!(hi - lo <= 1, "counts not uniform: {lo}..{hi}");
    }

    #[test]
    fn bucket_program_tasks_every_tile() {
        // skip_empty_tiles off: each Aggregate task references every
        // source subshard, so any member edge distribution is covered.
        let shape = BucketShape::of(300, 1500, 32, 4);
        let hw = HwConfig::functional_tiles();
        let exe = compile_bucket(ZooModel::B1, shape, &hw);
        assert!(exe.program.thresholds.is_none(), "buckets omit GA02");
        let shards = (shape.v as u64).div_ceil(hw.n1() as u64) as usize;
        for lt in &exe.tasks {
            if lt.ltype == LayerType::Aggregate {
                for t in &lt.tasks {
                    if let crate::compiler::TileTask::Aggregate { subshards, .. } = t {
                        assert_eq!(subshards.len(), shards);
                    }
                }
            }
        }
    }

    #[test]
    fn exact_shape_reflects_meta() {
        let meta = GraphMeta::new("ego", 37, 91, 16, 4);
        let ex = BucketShape::exact(&meta);
        assert_eq!((ex.v, ex.e, ex.f, ex.c), (37, 91, 16, 4));
        let rounded = BucketShape::for_graph(&meta);
        assert_eq!((rounded.v, rounded.e), (64, 256));
    }
}
