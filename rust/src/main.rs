//! GraphAGILE command-line interface.
//!
//! ```text
//! graphagile tables --id t7 [--scale N] [--datasets CO,PU]
//! graphagile compile --model b1 --dataset CO --out prog.ga
//! graphagile simulate --model b1 --dataset CO [--no-order] [--no-fusion]
//!                     [--no-overlap] [--scale N]
//! graphagile sweep --model b2 --dataset FL      (design-space explorer)
//! graphagile serve --requests 256 --devices 4   (multi-tenant fleet demo)
//! graphagile serve --minibatch --fanout 25,10   (ego-network serving path)
//! graphagile serve --streaming --update-every 8 (edge-churn + epoch serving)
//! graphagile serve --fault-plan plan.json       (chaos run: seeded crashes,
//!                                                stalls, artifact corruption)
//! graphagile serve --tenants tenants.json       (per-tenant QoS: weighted-fair
//!                                                pacing, deadlines, classes)
//! graphagile serve --chrome-trace out.json      (span tracing: per-request
//!                                                phase timelines for Perfetto)
//! graphagile daemon [--port 0] [--devices N] [--trace trace.json]
//!                   [--fault-plan plan.json]
//!                   [--tenants tenants.json]
//!                   [--chrome-trace out.json]   (long-running TCP server;
//!                                                records every accepted event)
//! graphagile drive --port P [--requests 200] [--seed 7] [--metrics]
//!                                               (scripted client workload,
//!                                                then drain + shutdown;
//!                                                --metrics scrapes a live
//!                                                Prometheus snapshot first)
//! graphagile replay trace.json [--verify] [--chrome-trace out.json]
//!                                              (bit-identical offline replay;
//!                                               --verify diffs against the
//!                                               recorded responses/stats)
//! graphagile info                               (hardware + zoo summary)
//! ```

use anyhow::{anyhow, Context, Result};
use graphagile::compiler::{compile, CompileOptions};
use graphagile::config::HwConfig;
use graphagile::graph::{dataset, Dataset, ALL_DATASETS};
use graphagile::harness::tables::{by_id, Ctx};
use graphagile::ir::{zoo_model, ALL_MODELS};
use graphagile::sim::simulate;
use graphagile::util::fmt_bytes;
use std::collections::HashMap;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Minimal flag parser: subcommand + positional operands (e.g. the
/// trace path of `replay`) + `--key value` / `--flag`.
struct Args {
    cmd: String,
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

fn parse_args() -> Result<Args> {
    let mut it = std::env::args().skip(1);
    let cmd = it.next().unwrap_or_else(|| "help".to_string());
    let mut flags = HashMap::new();
    let mut positional = Vec::new();
    while let Some(a) = it.next() {
        let Some(key) = a.strip_prefix("--") else {
            positional.push(a);
            continue;
        };
        let key = key.to_string();
        // Boolean flags take no value: the --no-* switches, --minibatch,
        // --streaming, --verify and --metrics. Every other flag requires
        // a value — a missing one stays a hard error rather than
        // silently parsing as true.
        if key.starts_with("no-")
            || key == "minibatch"
            || key == "streaming"
            || key == "verify"
            || key == "metrics"
        {
            flags.insert(key, "true".into());
        } else {
            let val = it.next().ok_or_else(|| anyhow!("--{key} needs a value"))?;
            flags.insert(key, val);
        }
    }
    Ok(Args { cmd, positional, flags })
}

impl Args {
    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn scale(&self) -> u64 {
        self.get("scale").and_then(|s| s.parse().ok()).unwrap_or(1)
    }

    fn datasets(&self) -> Result<Vec<Dataset>> {
        match self.get("datasets") {
            None => Ok(ALL_DATASETS.to_vec()),
            Some(list) => list
                .split(',')
                .map(|k| dataset(k).ok_or_else(|| anyhow!("unknown dataset {k}")))
                .collect(),
        }
    }

    fn options(&self) -> CompileOptions {
        CompileOptions {
            order_opt: self.get("no-order").is_none(),
            fusion: self.get("no-fusion").is_none(),
            ..Default::default()
        }
    }
}

fn run() -> Result<()> {
    let args = parse_args()?;
    match args.cmd.as_str() {
        "tables" => cmd_tables(&args),
        "compile" => cmd_compile(&args),
        "simulate" => cmd_simulate(&args),
        "sweep" => cmd_sweep(&args),
        "disasm" => cmd_disasm(&args),
        "serve" => cmd_serve(&args),
        "daemon" => cmd_daemon(&args),
        "drive" => cmd_drive(&args),
        "replay" => cmd_replay(&args),
        "info" => cmd_info(),
        _ => {
            println!(
                "usage: graphagile <tables|compile|simulate|sweep|disasm|serve|daemon|drive|replay|info> [flags]\n\
                 see `rust/src/main.rs` docs for flag details"
            );
            Ok(())
        }
    }
}

fn cmd_tables(args: &Args) -> Result<()> {
    let id = args.get("id").context("--id required (t4,t5,t7,t8,t9,t10,f14..f18)")?;
    let mut ctx = Ctx::new(args.scale());
    let datasets = args.datasets()?;
    let out = by_id(&mut ctx, id, &datasets)
        .ok_or_else(|| anyhow!("unknown table/figure id {id}"))?;
    println!("{out}");
    Ok(())
}

fn model_and_dataset(args: &Args) -> Result<(graphagile::ir::ZooModel, Dataset)> {
    let m = args.get("model").context("--model required (b1..b8)")?;
    let d = args.get("dataset").context("--dataset required (CI,CO,PU,FL,RE,YE,AP)")?;
    Ok((
        zoo_model(m).ok_or_else(|| anyhow!("unknown model {m}"))?,
        dataset(d).ok_or_else(|| anyhow!("unknown dataset {d}"))?,
    ))
}

fn cmd_compile(args: &Args) -> Result<()> {
    let (m, d) = model_and_dataset(args)?;
    let d = if args.scale() > 1 { d.scaled(args.scale()) } else { d };
    let hw = HwConfig::alveo_u250();
    let tiles = d.tile_counts(hw.n1() as u64);
    let ir = m.build(d.meta());
    let exe = compile(&ir, &tiles, &hw, args.options());
    let bytes = exe.program.to_bytes();
    let out = args.get("out").unwrap_or("out.ga");
    std::fs::write(out, &bytes)?;
    println!(
        "compiled {} on {}: {} layers, {} instructions, {} -> {out}",
        m.key(),
        d.key,
        exe.program.layers.len(),
        exe.program.total_instrs(),
        fmt_bytes(bytes.len() as u64),
    );
    println!(
        "passes: order {:.1} us, fusion {:.1} us, partition {:.1} us, mapping {:.1} us",
        exe.report.t_order * 1e6,
        exe.report.t_fusion * 1e6,
        exe.report.t_partition * 1e6,
        exe.report.t_mapping * 1e6,
    );
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let (m, d) = model_and_dataset(args)?;
    let d = if args.scale() > 1 { d.scaled(args.scale()) } else { d };
    let hw = HwConfig {
        overlap: args.get("no-overlap").is_none(),
        ..HwConfig::alveo_u250()
    };
    let tiles = d.tile_counts(hw.n1() as u64);
    let ir = m.build(d.meta());
    let exe = compile(&ir, &tiles, &hw, args.options());
    let sim = simulate(&exe.program, &hw);
    println!(
        "{} on {}: LoH {:.3} ms ({} cycles), utilization {:.1}%, {:.1} GFLOP/s effective",
        m.key(),
        d.key,
        sim.loh_ms(),
        sim.cycles,
        sim.utilization() * 100.0,
        sim.gflops(exe.ir.total_complexity()),
    );
    println!("per-layer:");
    for l in &sim.layers {
        println!(
            "  layer {:3} type {} blocks {:6} cycles {:10} mem {}",
            l.layer_id,
            l.layer_type,
            l.n_blocks,
            l.cycles,
            fmt_bytes(l.mem_bytes),
        );
    }
    Ok(())
}

/// Hardware design-space sweep: vary p_sys and N_pe, report LoH.
fn cmd_sweep(args: &Args) -> Result<()> {
    let (m, d) = model_and_dataset(args)?;
    let d = if args.scale() > 1 { d.scaled(args.scale()) } else { d };
    println!("design-space sweep of {} on {}:", m.key(), d.key);
    println!("{:>6} {:>6} {:>12} {:>10}", "n_pe", "p_sys", "LoH (ms)", "util %");
    for n_pe in [2usize, 4, 8, 16] {
        for p_sys in [8usize, 16, 32] {
            let hw = HwConfig { n_pe, p_sys, ..HwConfig::alveo_u250() };
            if hw.validate().is_err() {
                continue;
            }
            let tiles = d.tile_counts(hw.n1() as u64);
            let ir = m.build(d.meta());
            let exe = compile(&ir, &tiles, &hw, args.options());
            let sim = simulate(&exe.program, &hw);
            println!(
                "{:>6} {:>6} {:>12.3} {:>10.1}",
                n_pe,
                p_sys,
                sim.loh_ms(),
                sim.utilization() * 100.0
            );
        }
    }
    Ok(())
}

/// Disassemble a `.ga` binary (or compile+disassemble a model/dataset).
fn cmd_disasm(args: &Args) -> Result<()> {
    let program = if let Some(path) = args.get("file") {
        let bytes = std::fs::read(path)?;
        graphagile::isa::Program::from_bytes(&bytes)?
    } else {
        let (m, d) = model_and_dataset(args)?;
        let d = if args.scale() > 1 { d.scaled(args.scale()) } else { d };
        let hw = HwConfig::alveo_u250();
        let tiles = d.tile_counts(hw.n1() as u64);
        compile(&m.build(d.meta()), &tiles, &hw, args.options()).program
    };
    let max_blocks = args
        .get("blocks")
        .and_then(|s| s.parse().ok())
        .unwrap_or(3usize);
    println!("{}", graphagile::isa::disasm::disassemble(&program, max_blocks));
    Ok(())
}

/// Multi-tenant serving demo: a mixed request stream over a fleet of
/// overlay devices (the cloud-FPGA scenario of the paper's
/// introduction). Deterministic: the same flags print the same stats.
///
/// Flags: `--requests N` (default 64), `--devices N` (default 1),
/// `--no-affinity`, `--no-coalesce`, `--no-dynamic` (static kernel
/// mapping), `--datasets CO,PU`, `--visit-overhead SECONDS` (sweep the
/// mini-batch visit overhead, default 4e-5), `--precision int8|f32`
/// (serve every request on the quantized or full-precision datapath;
/// default f32 — int8 compiles calibrated GA03 programs and the
/// summary grows the quantized counters).
///
/// Mini-batch mode: `--minibatch` serves per-request ego-network
/// inference instead of whole graphs — each request samples 1–4 target
/// vertices with a `--fanout 25,10`-capped k-hop neighborhood and
/// executes through the shape-bucketed program cache.
/// `--no-batch` disables micro-batched dispatch.
///
/// Streaming mode: `--streaming` turns every `--update-every`-th
/// request (default 16) into an R-MAT-skewed graph-update batch; the
/// fleet applies it between inference requests, seals a new epoch,
/// selectively invalidates stale whole-graph programs and keeps
/// serving — the summary then shows the epoch/dirty-subshard/
/// invalidation counters.
///
/// Chaos mode: `--fault-plan plan.json` loads a seeded fault plan
/// (device crashes, transient stalls, cached-artifact corruption on
/// the virtual clock); the fleet retries/re-routes with backoff,
/// degrades over-deadline requests through the fidelity cascade, and
/// the summary grows the fault counter block. Deterministic: the same
/// plan and flags print the same stats.
///
/// QoS mode: `--tenants tenants.json` installs a per-tenant policy
/// table (weight, priority class, optional deadline); admission
/// switches to weighted-fair virtual-clock pacing with deadline-aware
/// degradation, and the summary grows a per-tenant block (p50/p99,
/// miss rate, sheds). Mutually exclusive with `--fault-plan`.
///
/// Tracing: `--chrome-trace out.json` turns the span tracer on and
/// exports every request's phase timeline (admission → sample →
/// compile → queue → per-layer kernel execution, fault windows as
/// instant events) as Chrome trace-event JSON for `chrome://tracing`
/// or Perfetto. Stats are unchanged — tracing only observes.
fn cmd_serve(args: &Args) -> Result<()> {
    use graphagile::serve::{Coordinator, CostModel, FleetConfig, Precision, Request};
    use graphagile::util::Rng;
    let n: usize = args.get("requests").and_then(|s| s.parse().ok()).unwrap_or(64);
    let precision: Precision = match args.get("precision") {
        None => Precision::F32,
        Some(v) => v.parse().map_err(|e| anyhow!("bad --precision: {e}"))?,
    };
    let mut costs = CostModel::default();
    if let Some(v) = args.get("visit-overhead") {
        costs.visit_overhead_s = v.parse().map_err(|_| anyhow!("bad --visit-overhead {v}"))?;
    }
    let cfg = FleetConfig {
        n_devices: args.get("devices").and_then(|s| s.parse().ok()).unwrap_or(1),
        affinity: args.get("no-affinity").is_none(),
        coalesce: args.get("no-coalesce").is_none(),
        microbatch: args.get("no-batch").is_none(),
        dynamic: args.get("no-dynamic").is_none(),
        costs,
    };
    anyhow::ensure!(cfg.n_devices >= 1, "--devices must be >= 1");
    let minibatch = args.get("minibatch").is_some();
    let streaming = args.get("streaming").is_some();
    let update_every: usize = args
        .get("update-every")
        .and_then(|s| s.parse().ok())
        .unwrap_or(16);
    anyhow::ensure!(update_every >= 2, "--update-every must be >= 2");
    let fanout: Vec<u32> = match args.get("fanout") {
        None => vec![25, 10],
        Some(list) => list
            .split(',')
            .map(|v| v.trim().parse().map_err(|_| anyhow!("bad --fanout entry {v}")))
            .collect::<Result<_>>()?,
    };
    let datasets = args.datasets()?;
    let small: Vec<_> = datasets
        .into_iter()
        .filter(|d| d.n_edges < 10_000_000)
        .collect();
    anyhow::ensure!(!small.is_empty(), "no datasets small enough for the demo");
    let mut rng = Rng::new(7);
    let reqs: Vec<Request> = (0..n)
        .map(|i| {
            let tenant = rng.below(4) as u32;
            let model = ALL_MODELS[rng.below(8) as usize];
            let ds = small[rng.below(small.len() as u64) as usize];
            let arrival = i as f64 * 2e-4;
            if streaming && i % update_every == update_every - 1 {
                let inserts = (ds.n_edges / 100).clamp(16, 4096) as u32;
                return Request::update(tenant, ds, inserts, inserts / 4, 0, i as u64, arrival);
            }
            if minibatch {
                let k = 1 + rng.below(4) as usize;
                let targets = (0..k).map(|_| rng.below(ds.n_vertices) as u32).collect();
                Request::minibatch(tenant, model, ds, targets, fanout.clone(), i as u64, arrival)
                    .with_precision(precision)
            } else {
                Request::full(tenant, model, ds, arrival).with_precision(precision)
            }
        })
        .collect();
    anyhow::ensure!(
        !(args.get("fault-plan").is_some() && args.get("tenants").is_some()),
        "--fault-plan and --tenants are mutually exclusive (the outage calendar \
         and the QoS gap scheduler disagree about device timelines)"
    );
    let mut c = Coordinator::fleet(HwConfig::alveo_u250(), cfg);
    if let Some(path) = args.get("fault-plan") {
        let plan = graphagile::serve::FaultPlan::load(std::path::Path::new(path))?;
        c.set_fault_plan(plan);
    }
    if let Some(path) = args.get("tenants") {
        let tenants = graphagile::serve::TenantConfig::load(std::path::Path::new(path))?;
        c.set_tenants(tenants);
    }
    let trace_out = args.get("chrome-trace");
    c.set_tracing(trace_out.is_some());
    let stats = c.run(reqs);
    if let Some(path) = trace_out {
        std::fs::write(path, c.chrome_trace_json())
            .with_context(|| format!("writing chrome trace {path}"))?;
        println!("wrote {} spans -> {path}", c.spans().len());
    }
    println!(
        "served {} requests across 4 tenants on {} device(s):",
        stats.completed,
        c.n_devices()
    );
    print!("{}", graphagile::harness::serve_summary(&stats));
    let util = if stats.makespan > 0.0 {
        stats.device_busy / (stats.makespan * c.n_devices() as f64) * 100.0
    } else {
        0.0
    };
    println!("  fleet utilization {util:.1}%");
    for d in c.devices() {
        println!(
            "  device {}: {} programs ({}), busy {:.3} s",
            d.id,
            d.cache_len(),
            fmt_bytes(d.cache_bytes()),
            d.busy
        );
    }
    Ok(())
}

/// The fleet shape shared by `daemon` (same switches as `serve`).
fn fleet_config(args: &Args) -> Result<graphagile::serve::FleetConfig> {
    use graphagile::serve::{CostModel, FleetConfig};
    let mut costs = CostModel::default();
    if let Some(v) = args.get("visit-overhead") {
        costs.visit_overhead_s = v.parse().map_err(|_| anyhow!("bad --visit-overhead {v}"))?;
    }
    let cfg = FleetConfig {
        n_devices: args.get("devices").and_then(|s| s.parse().ok()).unwrap_or(1),
        affinity: args.get("no-affinity").is_none(),
        coalesce: args.get("no-coalesce").is_none(),
        microbatch: args.get("no-batch").is_none(),
        dynamic: args.get("no-dynamic").is_none(),
        costs,
    };
    anyhow::ensure!(cfg.n_devices >= 1, "--devices must be >= 1");
    Ok(cfg)
}

/// Long-running serving daemon: accepts length-prefixed JSON frames on
/// localhost, stamps real arrival times onto the virtual clock, and
/// records every accepted event. On `shutdown` the recorded trace is
/// written to `--trace` (default `trace.json`) for `graphagile replay`.
///
/// Flags: `--port N` (default 0 = ephemeral; the bound port is printed
/// on the `listening` line for scripts to scrape), `--trace PATH`,
/// `--fault-plan plan.json` (serve under a seeded fault plan; the
/// recorded trace becomes a v2 document that replays the faults
/// bit-identically), `--tenants tenants.json` (serve under per-tenant
/// QoS; the recorded trace becomes a v3 document that replays the
/// scheduling decisions bit-identically — mutually exclusive with
/// `--fault-plan`), `--chrome-trace out.json` (span-trace the session;
/// the Chrome trace-event JSON is written at shutdown alongside the
/// trace), plus the `serve` fleet switches (`--devices`,
/// `--no-affinity`, `--no-coalesce`, `--no-batch`, `--no-dynamic`,
/// `--visit-overhead`).
fn cmd_daemon(args: &Args) -> Result<()> {
    use graphagile::daemon::Daemon;
    let port: u16 = match args.get("port") {
        None => 0,
        Some(v) => v.parse().map_err(|_| anyhow!("bad --port {v}"))?,
    };
    let trace_path = args.get("trace").unwrap_or("trace.json").to_string();
    let plan = match args.get("fault-plan") {
        None => None,
        Some(p) => Some(graphagile::serve::FaultPlan::load(std::path::Path::new(p))?),
    };
    let tenants = match args.get("tenants") {
        None => None,
        Some(p) => Some(graphagile::serve::TenantConfig::load(std::path::Path::new(p))?),
    };
    anyhow::ensure!(
        !(plan.is_some() && tenants.is_some()),
        "--fault-plan and --tenants are mutually exclusive (the outage calendar \
         and the QoS gap scheduler disagree about device timelines)"
    );
    let mut d =
        Daemon::bind_with_config(port, HwConfig::alveo_u250(), fleet_config(args)?, plan, tenants)?;
    if let Some(p) = args.get("chrome-trace") {
        d.set_chrome_trace(std::path::PathBuf::from(p));
    }
    println!("graphagile daemon listening on 127.0.0.1:{}", d.port());
    let trace = d.serve()?;
    trace.save(std::path::Path::new(&trace_path))?;
    println!(
        "daemon shut down: {} events, {} responses recorded -> {trace_path}",
        trace.events.len(),
        trace.responses.len(),
    );
    Ok(())
}

/// Scripted client for a live daemon: drives `--requests` mixed
/// requests (whole-graph f32/int8, mini-batch, churn) from `--seed`,
/// drains, prints the daemon's stats, and shuts it down (which makes
/// the daemon persist its trace). `--metrics` scrapes and prints a
/// Prometheus text-exposition snapshot of the live counters after the
/// drain, before shutdown (the scrape is read-only and unrecorded).
fn cmd_drive(args: &Args) -> Result<()> {
    use graphagile::daemon::{drive, Client};
    let port: u16 = args
        .get("port")
        .context("--port required (scrape the daemon's 'listening' line)")?
        .parse()
        .map_err(|_| anyhow!("bad --port"))?;
    let n: usize = args.get("requests").and_then(|s| s.parse().ok()).unwrap_or(200);
    let seed: u64 = args.get("seed").and_then(|s| s.parse().ok()).unwrap_or(7);
    let mut client = Client::connect(port)?;
    let (accepted, stats) = drive(&mut client, n, seed)?;
    println!("drove {accepted} accepted requests (seed {seed}):");
    print!("{}", graphagile::harness::serve_summary(&stats));
    if args.get("metrics").is_some() {
        println!("live metrics snapshot:");
        print!("{}", client.metrics()?);
    }
    let events = client.shutdown()?;
    println!("daemon shutdown acknowledged ({events} recorded events)");
    Ok(())
}

/// Re-execute a recorded trace offline, bit-identically:
/// `graphagile replay trace.json [--verify] [--chrome-trace out.json]`.
/// With `--verify` the replayed responses and stats are diffed
/// field-by-field against the recorded ones; any divergence is named
/// and the exit code is nonzero. With `--chrome-trace` the replay runs
/// with the span tracer on and exports the regenerated span stream —
/// byte-identical to what the recording daemon would have exported.
fn cmd_replay(args: &Args) -> Result<()> {
    use graphagile::daemon::{replay, replay_traced, verify, Trace};
    let path = args
        .positional
        .first()
        .context("usage: graphagile replay <trace.json> [--verify] [--chrome-trace out.json]")?;
    let trace = Trace::load(std::path::Path::new(path))?;
    let stats = if let Some(out) = args.get("chrome-trace") {
        let (_responses, stats, spans) = replay_traced(&trace);
        std::fs::write(out, spans).with_context(|| format!("writing chrome trace {out}"))?;
        println!("wrote replayed span stream -> {out}");
        stats
    } else {
        let (_responses, stats) = replay(&trace);
        stats
    };
    print!("{}", graphagile::harness::replay_summary(&trace, &stats));
    if args.get("verify").is_some() {
        let divergences = verify(&trace)?;
        print!("{}", graphagile::harness::divergence_report(&divergences));
        if !divergences.is_empty() {
            anyhow::bail!(
                "replay diverged from the recorded run ({} divergence(s))",
                divergences.len()
            );
        }
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    let hw = HwConfig::alveo_u250();
    println!("GraphAGILE overlay (Alveo U250 configuration)");
    println!("  PEs: {}  p_sys: {}  freq: {} MHz", hw.n_pe, hw.p_sys, hw.freq_hz / 1e6);
    println!(
        "  peak {:.0} GFLOPS, on-chip {}  DDR {:.0} GB/s  PCIe {:.1} GB/s",
        hw.peak_flops() / 1e9,
        fmt_bytes(hw.on_chip_bytes()),
        hw.ddr_bw / 1e9,
        hw.pcie_bw / 1e9,
    );
    println!("models: {:?}", ALL_MODELS.iter().map(|m| m.key()).collect::<Vec<_>>());
    println!(
        "datasets: {:?}",
        ALL_DATASETS.iter().map(|d| d.key).collect::<Vec<_>>()
    );
    match graphagile::runtime::find_artifacts_dir() {
        Some(dir) => println!("artifacts: {}", dir.display()),
        None => println!("artifacts: NOT FOUND (run `make artifacts`)"),
    }
    Ok(())
}
