//! Multi-tenant QoS: weighted-fair pacing, deadline-aware placement,
//! and the per-tenant policy table that drives both.
//!
//! A [`TenantConfig`] names the tenants sharing a fleet: each carries a
//! weight (its share of modeled device capacity), an optional per-request
//! deadline, and a [`PriorityClass`]. The scheduler is start-time fair
//! queuing over modeled visit cost ([`FairQueue`]): virtual time advances
//! at `n_devices / W_total` per wall second, every non-premium visit is
//! stamped with a start tag `S = max(V(arrival), F_tenant)` and charges
//! its tenant's finish tag `F_tenant = S + cost / weight`, and the visit
//! becomes *eligible* at the wall time `S * W_total / n_devices`. The
//! pacing delay `eligible - arrival` is exactly the wait a tenant sees
//! when it exceeds its reserved rate `(weight / W_total) * n_devices`
//! devices — capacity reservation in the cgroup-quota sense, not
//! work-conserving scavenging, so a flooding tenant cannot move another
//! tenant's tags. Premium traffic skips the queue entirely: zero delay,
//! no tag charged.
//!
//! Placement is gap-aware: [`QosState`] keeps each device's busy
//! timeline as a sorted interval list and places a newly eligible visit
//! into the earliest idle gap that fits — which is how admission
//! preempts *unstarted* visits (a premium or under-share arrival starts
//! before paced work that was admitted earlier but not yet begun;
//! nothing already started, and no already-emitted response, is ever
//! retracted). Backfills ahead of scheduled work are counted in
//! [`QosState::preemptions`].
//!
//! With no config (or an empty one) the coordinator takes its historical
//! code path untouched: tenant-free serving stays byte-identical to a
//! build without this module.

use crate::util::Json;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

use super::fault::DecisionRecord;

/// Scheduling class of a tenant, coarsest knob first: premium bypasses
/// the fair queue, best-effort is the only class the scheduler may shed
/// on a missed deadline.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PriorityClass {
    /// Strict priority: never paced (no virtual-clock delay, no tag
    /// charged) and never shed; served late if its deadline is missed.
    Premium,
    /// Paced by weighted-fair queuing; degraded under deadline pressure
    /// but never shed — a missed deadline is served late and flagged.
    Standard,
    /// Paced like standard, but a request still over its deadline after
    /// the full fidelity cascade is shed with
    /// [`ShedReason::DeadlineMissed`](super::fault::ShedReason::DeadlineMissed).
    BestEffort,
}

impl PriorityClass {
    /// Stable wire key (tenants.json and trace v3 encoding).
    pub fn key(&self) -> &'static str {
        match self {
            PriorityClass::Premium => "premium",
            PriorityClass::Standard => "standard",
            PriorityClass::BestEffort => "best_effort",
        }
    }

    /// Inverse of [`PriorityClass::key`]; unknown classes are a hard
    /// error (mirrors the fault-event codec's versioning rules).
    pub fn parse(s: &str) -> Result<PriorityClass> {
        Ok(match s {
            "premium" => PriorityClass::Premium,
            "standard" => PriorityClass::Standard,
            "best_effort" => PriorityClass::BestEffort,
            _ => bail!("unknown priority class '{s}'"),
        })
    }
}

/// One tenant's QoS policy row.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Tenant {
    /// Tenant id, matched against [`Request::tenant`](super::Request).
    pub id: u32,
    /// Fair-queue weight: this tenant's reserved fraction of fleet
    /// capacity is `weight / total_weight`. Must be finite and > 0.
    pub weight: f64,
    /// Per-request latency deadline in seconds from arrival. `None`
    /// disables the deadline machinery (no cascade, no miss flag) for
    /// this tenant.
    pub deadline_s: Option<f64>,
    /// Scheduling class (see [`PriorityClass`]).
    pub class: PriorityClass,
}

impl Tenant {
    /// Policy applied to a request whose tenant id is not in the
    /// config: weight-1 standard traffic with no deadline, contending
    /// against the configured tenants' total weight.
    pub fn fallback(id: u32) -> Tenant {
        Tenant { id, weight: 1.0, deadline_s: None, class: PriorityClass::Standard }
    }
}

/// The tenant policy table (the `--tenants` file format). Empty means
/// QoS off: the coordinator installs no scheduler state at all.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TenantConfig {
    /// Policy rows, one per tenant id.
    pub tenants: Vec<Tenant>,
}

impl TenantConfig {
    /// An empty config: serving behaves exactly as if none were set.
    pub fn empty() -> TenantConfig {
        TenantConfig::default()
    }

    /// True when no tenants are configured (QoS stays dormant).
    pub fn is_empty(&self) -> bool {
        self.tenants.is_empty()
    }

    /// Sum of configured weights — the `W_total` of the SFQ virtual
    /// clock. Requests from unknown tenants contend at weight 1 against
    /// this total without enlarging it.
    pub fn total_weight(&self) -> f64 {
        self.tenants.iter().map(|t| t.weight).sum()
    }

    /// The policy row for `id`, falling back to
    /// [`Tenant::fallback`] for unknown tenants.
    pub fn get(&self, id: u32) -> Tenant {
        self.tenants.iter().find(|t| t.id == id).copied().unwrap_or(Tenant::fallback(id))
    }

    /// JSON encoding (`deadline_s` is omitted when absent, so
    /// deadline-free rows round-trip byte-identically).
    pub fn to_json(&self) -> Json {
        let rows = self
            .tenants
            .iter()
            .map(|t| {
                let mut fields = vec![
                    ("id", Json::Num(t.id as f64)),
                    ("weight", Json::Num(t.weight)),
                    ("class", Json::Str(t.class.key().to_string())),
                ];
                if let Some(d) = t.deadline_s {
                    fields.push(("deadline_s", Json::Num(d)));
                }
                Json::obj(fields)
            })
            .collect();
        Json::obj(vec![("tenants", Json::Arr(rows))])
    }

    /// Inverse of [`TenantConfig::to_json`], validating every row:
    /// weights must be finite and positive, deadlines positive, and
    /// tenant ids unique.
    pub fn from_json(j: &Json) -> Result<TenantConfig> {
        let tenants = j
            .arr_of("tenants")?
            .iter()
            .enumerate()
            .map(|(i, row)| {
                let parse_row = || -> Result<Tenant> {
                    let id = row.u32_of("id")?;
                    let weight = row.f64_of("weight")?;
                    if !weight.is_finite() || weight <= 0.0 {
                        bail!("weight {weight} is not finite and positive");
                    }
                    let deadline_s = match row.get("deadline_s") {
                        None => None,
                        Some(_) => {
                            let d = row.f64_of("deadline_s")?;
                            if !d.is_finite() || d <= 0.0 {
                                bail!("deadline_s {d} is not finite and positive");
                            }
                            Some(d)
                        }
                    };
                    let class = PriorityClass::parse(row.str_of("class")?)?;
                    Ok(Tenant { id, weight, deadline_s, class })
                };
                parse_row().with_context(|| format!("tenants[{i}]"))
            })
            .collect::<Result<Vec<Tenant>>>()?;
        for (i, t) in tenants.iter().enumerate() {
            if tenants[..i].iter().any(|u| u.id == t.id) {
                bail!("tenants[{i}]: duplicate tenant id {}", t.id);
            }
        }
        Ok(TenantConfig { tenants })
    }

    /// Parse a config from its JSON text (the `--tenants` file format).
    pub fn parse(text: &str) -> Result<TenantConfig> {
        TenantConfig::from_json(&Json::parse(text).context("tenant config is not valid JSON")?)
    }

    /// Load a config from a `tenants.json` file.
    pub fn load(path: &Path) -> Result<TenantConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading tenant config {}", path.display()))?;
        TenantConfig::parse(&text)
            .with_context(|| format!("parsing tenant config {}", path.display()))
    }

    /// Write the config as pretty-stable JSON (one trailing newline).
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
            .with_context(|| format!("writing tenant config {}", path.display()))
    }
}

/// Start-time fair queuing over modeled cost, reduced to its virtual
/// clock: wall time `a` maps to virtual time `V(a) = a * C / W` (for
/// `C` devices of capacity and total weight `W`), a visit of cost `c`
/// from a tenant of weight `w` gets start tag `S = max(V(a), F)` and
/// advances that tenant's finish tag `F <- S + c / w`, and the visit
/// may start at wall time `S * W / C`. [`FairQueue::delay`] returns the
/// resulting pacing wait; a tenant inside its reserved rate
/// `(w / W) * C` always sees zero.
#[derive(Clone, Debug)]
pub struct FairQueue {
    /// Total configured weight (`W` in the virtual-time map).
    total_weight: f64,
    /// Fleet capacity in devices (`C` in the virtual-time map).
    capacity: f64,
    /// Per-tenant virtual finish tag of the last stamped visit.
    finish: HashMap<u32, f64>,
}

impl FairQueue {
    /// A queue for `n_devices` devices shared by tenants of summed
    /// weight `total_weight` (clamped to at least one device / unit
    /// weight so a degenerate config cannot divide by zero).
    pub fn new(total_weight: f64, n_devices: usize) -> FairQueue {
        FairQueue {
            total_weight: total_weight.max(f64::MIN_POSITIVE),
            capacity: (n_devices.max(1)) as f64,
            finish: HashMap::new(),
        }
    }

    /// Stamp one visit of modeled cost `cost` seconds for `tenant`
    /// (weight `weight`) arriving at wall time `arrival`, and return
    /// the pacing delay in wall seconds (0 when the tenant is inside
    /// its reserved rate). Charges the tenant's finish tag: call
    /// exactly once per admitted visit.
    pub fn delay(&mut self, tenant: u32, weight: f64, arrival: f64, cost: f64) -> f64 {
        let v = arrival * self.capacity / self.total_weight;
        let f = self.finish.entry(tenant).or_insert(0.0);
        let start_tag = v.max(*f);
        *f = start_tag + cost / weight.max(f64::MIN_POSITIVE);
        (start_tag * self.total_weight / self.capacity - arrival).max(0.0)
    }
}

/// Live scheduler state the coordinator carries while a tenant config
/// is installed (`Option<QosState>` — `None` keeps the historical FIFO
/// path byte-identical, mirroring the fault module's dormant pattern).
#[derive(Clone, Debug)]
pub struct QosState {
    config: TenantConfig,
    queue: FairQueue,
    /// Per-device busy timelines: sorted, disjoint `(start, end)`
    /// intervals of committed (possibly not yet started) visits.
    busy: Vec<Vec<(f64, f64)>>,
    /// Degrade/shed decisions, spliced into traces exactly like the
    /// fault module's decision log.
    pub(super) decisions: Vec<DecisionRecord>,
    preemptions: u64,
}

impl QosState {
    /// Scheduler state for `config` over an `n_devices` fleet.
    pub fn new(config: TenantConfig, n_devices: usize) -> QosState {
        let queue = FairQueue::new(config.total_weight(), n_devices);
        QosState {
            config,
            queue,
            busy: vec![Vec::new(); n_devices.max(1)],
            decisions: Vec::new(),
            preemptions: 0,
        }
    }

    /// The installed tenant policy table.
    pub fn config(&self) -> &TenantConfig {
        &self.config
    }

    /// Policy row for `id` (unknown ids get [`Tenant::fallback`]).
    pub fn tenant(&self, id: u32) -> Tenant {
        self.config.get(id)
    }

    /// SFQ pacing delay for one visit of modeled cost `cost`. Premium
    /// tenants bypass the queue: zero delay, no tag charged. Charges
    /// the tenant's finish tag otherwise — call exactly once per
    /// admitted visit (the fidelity cascade re-places but never
    /// re-charges).
    pub fn pacing_delay(&mut self, t: &Tenant, arrival: f64, cost: f64) -> f64 {
        if t.class == PriorityClass::Premium {
            return 0.0;
        }
        self.queue.delay(t.id, t.weight, arrival, cost)
    }

    /// Earliest instant `>= ready` at which `device` has an idle gap of
    /// at least `dur` seconds — the gap-aware twin of `Device::free_at`
    /// scheduling, and the mechanism that lets eligible work start
    /// ahead of paced, unstarted visits.
    pub fn earliest_start(&self, device: usize, ready: f64, dur: f64) -> f64 {
        let mut t = ready;
        for &(s, e) in &self.busy[device] {
            if t + dur <= s {
                break;
            }
            if e > t {
                t = e;
            }
        }
        t
    }

    /// Commit `[start, start + dur)` on `device`'s busy timeline.
    /// Placing ahead of an already-committed interval (a backfill that
    /// preempts an unstarted visit) bumps the preemption counter.
    pub fn reserve(&mut self, device: usize, start: f64, dur: f64) {
        if dur <= 0.0 {
            return;
        }
        let end = start + dur;
        let iv = &mut self.busy[device];
        if iv.last().is_some_and(|&(s, _)| s >= end) {
            self.preemptions += 1;
        }
        let pos = iv.partition_point(|&(s, _)| s < start);
        iv.insert(pos, (start, end));
        let mut i = pos;
        if i > 0 && iv[i - 1].1 >= iv[i].0 {
            iv[i - 1].1 = iv[i - 1].1.max(iv[i].1);
            iv.remove(i);
            i -= 1;
        }
        if i + 1 < iv.len() && iv[i].1 >= iv[i + 1].0 {
            iv[i].1 = iv[i].1.max(iv[i + 1].1);
            iv.remove(i + 1);
        }
    }

    /// Visits that started ahead of an earlier-admitted, not-yet-started
    /// visit (gap backfills — the observable form of preemption under
    /// the respond-at-admission discipline).
    pub fn preemptions(&self) -> u64 {
        self.preemptions
    }

    /// Degrade/shed decisions logged so far (trace `decision` events).
    pub fn decisions(&self) -> &[DecisionRecord] {
        &self.decisions
    }
}

/// Per-tenant serving counters, one row per tenant id seen in a run
/// (rendered by `serve_summary`, carried in `ServeStats::tenants` and
/// trace v3). Latency percentiles cover served inference (sheds and
/// churn excluded), matching the fleet-wide `p50`/`p99` convention.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TenantStats {
    /// Tenant id.
    pub tenant: u32,
    /// Configured fair-queue weight (1.0 for unknown tenants).
    pub weight: f64,
    /// Requests served (completed or degraded; churn excluded).
    pub completed: u64,
    /// Requests served on a lower fidelity rung.
    pub degraded: u64,
    /// Requests shed.
    pub shed: u64,
    /// Requests past their deadline (served-late flags plus
    /// deadline sheds).
    pub missed: u64,
    /// Median served latency, seconds.
    pub p50: f64,
    /// 99th-percentile served latency, seconds.
    pub p99: f64,
    /// Total QoS pacing delay charged to this tenant, seconds.
    pub t_qos: f64,
    /// Device-seconds executed for this tenant (throughput-share
    /// numerator: `busy / sum(busy)` is the tenant's realized share).
    pub busy: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::forall;

    fn sample_config() -> TenantConfig {
        TenantConfig {
            tenants: vec![
                Tenant {
                    id: 0,
                    weight: 4.0,
                    deadline_s: Some(0.02),
                    class: PriorityClass::Premium,
                },
                Tenant { id: 1, weight: 2.0, deadline_s: None, class: PriorityClass::Standard },
                Tenant {
                    id: 7,
                    weight: 1.0,
                    deadline_s: Some(0.05),
                    class: PriorityClass::BestEffort,
                },
            ],
        }
    }

    #[test]
    fn tenant_config_round_trips_through_json() {
        let cfg = sample_config();
        let back = TenantConfig::parse(&cfg.to_json().to_string()).unwrap();
        assert_eq!(back, cfg);
        assert_eq!(back.total_weight(), 7.0);
        let empty = TenantConfig::empty();
        assert!(empty.is_empty());
        assert_eq!(TenantConfig::parse(&empty.to_json().to_string()).unwrap(), empty);
    }

    #[test]
    fn config_validation_rejects_bad_rows() {
        let bad_weight = r#"{"tenants": [{"id": 0, "weight": -1.0, "class": "standard"}]}"#;
        let err = TenantConfig::parse(bad_weight).unwrap_err().to_string();
        assert!(err.contains("tenants[0]"), "{err}");
        let bad_class = r#"{"tenants": [{"id": 0, "weight": 1.0, "class": "platinum"}]}"#;
        let err = format!("{:#}", TenantConfig::parse(bad_class).unwrap_err());
        assert!(err.contains("unknown priority class 'platinum'"), "{err}");
        let dup = r#"{"tenants": [
            {"id": 3, "weight": 1.0, "class": "standard"},
            {"id": 3, "weight": 2.0, "class": "premium"}]}"#;
        let err = TenantConfig::parse(dup).unwrap_err().to_string();
        assert!(err.contains("duplicate tenant id 3"), "{err}");
        let bad_deadline =
            r#"{"tenants": [{"id": 0, "weight": 1.0, "class": "standard", "deadline_s": 0.0}]}"#;
        assert!(TenantConfig::parse(bad_deadline).is_err());
    }

    #[test]
    fn unknown_tenants_fall_back_without_widening_the_clock() {
        let cfg = sample_config();
        let t = cfg.get(99);
        assert_eq!(t, Tenant::fallback(99));
        assert_eq!(t.class, PriorityClass::Standard);
        // The fallback row does not join the configured total.
        assert_eq!(cfg.total_weight(), 7.0);
    }

    #[test]
    fn premium_is_never_paced_and_charges_no_tag() {
        let mut q = QosState::new(sample_config(), 1);
        let premium = q.tenant(0);
        let standard = q.tenant(1);
        for _ in 0..100 {
            assert_eq!(q.pacing_delay(&premium, 0.0, 1.0), 0.0);
        }
        // The fair queue never saw premium: standard's first visit at
        // t=0 starts the virtual clock from zero delay.
        assert_eq!(q.pacing_delay(&standard, 0.0, 1e-3), 0.0);
    }

    #[test]
    fn a_flooding_tenant_is_paced_to_its_reserved_rate() {
        // Two equal-weight tenants, one device: a burst of cost-c jobs
        // from tenant A at t=0 must be spaced c * W / w = 2c apart.
        let mut q = FairQueue::new(2.0, 1);
        let c = 1e-3;
        assert_eq!(q.delay(0, 1.0, 0.0, c), 0.0);
        let d1 = q.delay(0, 1.0, 0.0, c);
        assert!((d1 - 2.0 * c).abs() < 1e-12, "second job delayed {d1}");
        let d2 = q.delay(0, 1.0, 0.0, c);
        assert!((d2 - 4.0 * c).abs() < 1e-12, "third job delayed {d2}");
        // Tenant B arriving mid-burst is inside its reserved rate:
        // no delay at all.
        assert_eq!(q.delay(1, 1.0, 3.0 * c, c), 0.0);
    }

    #[test]
    fn sfq_shares_track_weights() {
        // Property: tenants flooding from t=0 each get eligible work
        // proportional to weight, within one job of exact.
        forall("sfq_shares_track_weights", 60, |rng| {
            let n = 2 + rng.below(3) as usize;
            let weights: Vec<f64> = (0..n).map(|_| 0.5 + 3.5 * rng.f64()).collect();
            let total: f64 = weights.iter().sum();
            let mut q = FairQueue::new(total, 1);
            let horizon = 1.0;
            let max_cost = 5e-3;
            let mut eligible_work = vec![0.0; n];
            for (k, &w) in weights.iter().enumerate() {
                loop {
                    let cost = 1e-3 + (max_cost - 1e-3) * rng.f64();
                    if q.delay(k as u32, w, 0.0, cost) > horizon {
                        break;
                    }
                    eligible_work[k] += cost;
                }
            }
            for (k, &w) in weights.iter().enumerate() {
                let share = eligible_work[k] / horizon;
                let want = w / total;
                if (share - want).abs() > max_cost / horizon + 1e-9 {
                    return Err(format!(
                        "tenant {k}: share {share:.5} vs weight share {want:.5} \
                         (weights {weights:?})"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn gap_placement_backfills_and_counts_preemptions() {
        let mut q = QosState::new(sample_config(), 2);
        // Paced visit far out on device 0.
        assert_eq!(q.earliest_start(0, 10.0, 1.0), 10.0);
        q.reserve(0, 10.0, 1.0);
        assert_eq!(q.preemptions(), 0);
        // An eligible visit backfills the idle gap ahead of it...
        assert_eq!(q.earliest_start(0, 0.0, 1.0), 0.0);
        q.reserve(0, 0.0, 1.0);
        assert_eq!(q.preemptions(), 1);
        // ...but a visit too big for the gap queues behind.
        assert_eq!(q.earliest_start(0, 2.0, 9.0), 11.0);
        // Back-to-back placements merge into one interval.
        q.reserve(0, 1.0, 2.0);
        assert_eq!(q.earliest_start(0, 0.0, 1.0), 3.0);
        // Other devices are untouched.
        assert_eq!(q.earliest_start(1, 0.0, 5.0), 0.0);
    }

    #[test]
    fn reserve_merges_overlapping_neighbors() {
        let mut q = QosState::new(TenantConfig::empty(), 1);
        q.reserve(0, 0.0, 1.0);
        q.reserve(0, 2.0, 1.0);
        q.reserve(0, 1.0, 1.0); // exactly bridges the gap
        assert_eq!(q.earliest_start(0, 0.0, 0.5), 3.0);
        assert_eq!(q.preemptions(), 1); // the bridge landed before (2,3)
    }
}
