//! One overlay device of the serving fleet: a per-device program cache,
//! a compile-warmth ledger, and a busy timeline on the fleet's virtual
//! clock. Devices never read wall-clock time — all scheduling arithmetic
//! is over virtual seconds, so a fleet replay is bit-identical.

use super::cache::{Key, ProgramCache};
use super::clock::{self, CostModel};
use crate::compiler::{BucketShape, Executable};
use crate::config::HwConfig;
use crate::exec::{BufferArena, PackedWeightSet, PackedWeightSetI8};
use crate::graph::{Dataset, GraphMeta, TileCounts};
use crate::ir::ZooModel;
use crate::quant::Precision;
use std::collections::HashMap;
use std::sync::Arc;

/// A scheduled unit of accelerator work (the virtual timeline does not
/// distinguish in-flight from completed — `done` may be in the future).
///
/// For a mini-batch job the unit is one device *visit*: the creator's
/// ego-net plus any micro-batched riders, sharing one
/// [`clock::VISIT_OVERHEAD_S`]. `t_exec` is the visit total.
#[derive(Clone, Copy, Debug)]
pub struct Job {
    pub key: Key,
    /// When the program is ready to start (arrival + any sampling and
    /// compile stalls).
    pub ready: f64,
    pub start: f64,
    pub done: f64,
    pub t_exec: f64,
    pub cache_hit: bool,
    /// Requests coalesced onto this job beyond the one that created it
    /// (identical whole-graph work: no extra device time).
    pub riders: u32,
    /// Mini-batch items micro-batched onto this visit beyond the one
    /// that created it (each adds its own execution time but shares the
    /// visit overhead).
    pub batched: u32,
}

pub struct Device {
    pub id: usize,
    cache: ProgramCache,
    /// Virtual time each key's compile finishes on this device. A hit on
    /// a still-compiling entry waits for it rather than recompiling.
    warm_at: HashMap<Key, f64>,
    /// When the accelerator is next free.
    pub free_at: f64,
    /// Accumulated execution seconds (utilization numerator).
    pub busy: f64,
    /// Device-resident reusable tile buffers — the software analogue of
    /// the overlay's Feature/Result buffers. Functional replays on this
    /// device ([`crate::serve::Coordinator::functional_replay`]) draw
    /// from and recycle into this pool, so repeated replays allocate
    /// nothing in steady state.
    pub arena: BufferArena,
    /// Packed Linear-layer weights of the last functionally-replayed
    /// program (fingerprint-checked on reuse, rebuilt on mismatch), so
    /// back-to-back replays of the same (model, graph) pair skip
    /// repacking entirely.
    pub packed: Option<PackedWeightSet>,
    /// Int8 twin of `packed`: quantized weight panels of the last
    /// replayed quantized program, kept warm under the same
    /// fingerprint discipline.
    pub packed_i8: Option<PackedWeightSetI8>,
    /// Host-side cost coefficients (set from the fleet config so
    /// benches can sweep what used to be hard-coded constants).
    pub costs: CostModel,
    pub jobs: Vec<Job>,
    /// Index of the first job that may not have started yet. Start times
    /// are nondecreasing per device (each job begins no earlier than its
    /// predecessor's completion), so everything before this index has
    /// started for any later arrival — the coalescing scan never needs
    /// to revisit it.
    first_pending: usize,
}

impl Device {
    pub fn new(id: usize, hw: HwConfig) -> Device {
        Device {
            id,
            cache: ProgramCache::new(hw),
            warm_at: HashMap::new(),
            free_at: 0.0,
            busy: 0.0,
            arena: BufferArena::new(),
            packed: None,
            packed_i8: None,
            costs: CostModel::default(),
            jobs: Vec::new(),
            first_pending: 0,
        }
    }

    /// Advance the pending cursor past jobs that have started by `now`.
    /// Arrivals are processed in nondecreasing time order, so the cursor
    /// only ever moves forward (amortized O(1) per request).
    pub fn retire_started(&mut self, now: f64) {
        while self.first_pending < self.jobs.len()
            && self.jobs[self.first_pending].start < now
        {
            self.first_pending += 1;
        }
    }

    /// Jobs not yet started as of the last [`Device::retire_started`]
    /// call, with their indices into `jobs`.
    pub fn pending_jobs(&self) -> impl Iterator<Item = (usize, &Job)> + '_ {
        let base = self.first_pending;
        self.jobs[base..].iter().enumerate().map(move |(i, j)| (base + i, j))
    }

    /// Cache-warm for `key` (the affinity-routing predicate).
    pub fn is_warm(&self, key: &Key) -> bool {
        self.cache.contains(key)
    }

    /// Number of programs compiled on this device.
    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    /// Bytes of compiled binaries resident on this device.
    pub fn cache_bytes(&self) -> u64 {
        self.cache.binary_bytes()
    }

    /// Schedule one ready-at-`ready` unit of work whose executable was
    /// fetched with `hit`: queue behind in-flight work, advance the busy
    /// timeline, record the job.
    fn push_job(&mut self, key: Key, ready: f64, t_exec: f64, hit: bool) -> usize {
        let start = ready.max(self.free_at);
        let done = start + t_exec;
        self.free_at = done;
        self.busy += t_exec;
        self.jobs.push(Job {
            key,
            ready,
            start,
            done,
            t_exec,
            cache_hit: hit,
            riders: 0,
            batched: 0,
        });
        self.jobs.len() - 1
    }

    /// Compile-or-reuse readiness for `key`: on a miss, the virtual
    /// compile stall starts at `at`; a hit on a still-compiling entry
    /// waits for it rather than recompiling.
    fn ready_at(&mut self, key: Key, at: f64, exe: &Executable) -> f64 {
        match self.warm_at.get(&key) {
            Some(&warm) => at.max(warm),
            None => {
                let warm = at + clock::compile_cost(&exe.report);
                self.warm_at.insert(key, warm);
                warm
            }
        }
    }

    /// Admit one whole-graph request at `arrival`: compile-or-reuse the
    /// program, charge the virtual compile cost on a miss (or the
    /// residual stall when the compile from an earlier miss is still in
    /// flight), then queue behind in-flight work. `exec_seconds`
    /// supplies the modeled execution time of an executable (memoized
    /// fleet-wide by the coordinator). Returns the executable and the
    /// new job's index.
    pub fn admit(
        &mut self,
        arrival: f64,
        model: ZooModel,
        ds: &Dataset,
        exec_seconds: &mut dyn FnMut(&Executable) -> f64,
    ) -> (Arc<Executable>, usize) {
        self.admit_at(arrival, model, ds, 0, None, Precision::F32, exec_seconds)
    }

    /// [`Device::admit`] against a specific graph epoch: a streamed
    /// dataset passes its current epoch plus the dynamic graph's
    /// snapshot (metadata + live tile counts) so a cache miss compiles
    /// against the churned graph, not the frozen dataset.
    pub fn admit_at(
        &mut self,
        arrival: f64,
        model: ZooModel,
        ds: &Dataset,
        epoch: u32,
        snapshot: Option<(&GraphMeta, &Arc<TileCounts>)>,
        precision: Precision,
        exec_seconds: &mut dyn FnMut(&Executable) -> f64,
    ) -> (Arc<Executable>, usize) {
        let key = Key::Whole(model, ds.key, epoch, precision);
        let (exe, hit) = self.cache.get_at(model, ds, epoch, snapshot, precision);
        let ready = self.ready_at(key, arrival, &exe);
        let t_exec = exec_seconds(&exe);
        let j = self.push_job(key, ready, t_exec, hit);
        (exe, j)
    }

    /// Selective invalidation after a streaming update: drop stale
    /// whole-graph programs (epoch below `epoch`) of `ds_key` from the
    /// program cache and the compile-warmth ledger. Bucket programs
    /// survive untouched. Returns the number of programs dropped.
    pub fn invalidate_dataset(&mut self, ds_key: &str, epoch: u32) -> usize {
        self.warm_at
            .retain(|k, _| !matches!(k, Key::Whole(_, d, e, _) if *d == ds_key && *e < epoch));
        self.cache.invalidate_whole_before(ds_key, epoch)
    }

    /// Admit one mini-batch request: the bucket program compiles (or
    /// hits) like any other, but readiness additionally waits out the
    /// host-side sampling stall, and the device visit carries a fixed
    /// [`clock::VISIT_OVERHEAD_S`] on top of the item's execution time.
    pub fn admit_minibatch(
        &mut self,
        arrival: f64,
        model: ZooModel,
        shape: BucketShape,
        t_sample: f64,
        precision: Precision,
        exec_seconds: &mut dyn FnMut(&Executable) -> f64,
    ) -> (Arc<Executable>, usize) {
        let key = Key::Bucket(model, shape, precision);
        let (exe, hit) = self.cache.get_bucket(model, shape, precision);
        let ready = self.ready_at(key, arrival + t_sample, &exe);
        let t_visit = self.costs.visit_overhead_s + exec_seconds(&exe);
        let j = self.push_job(key, ready, t_visit, hit);
        (exe, j)
    }

    /// Micro-batch one more compatible mini-batch item onto the tail
    /// job `j`, which must not have started: the visit stretches by the
    /// item's execution time, and the rider shares the already-paid
    /// visit overhead and compile stall.
    pub fn extend_batch(&mut self, j: usize, t_item: f64) {
        debug_assert_eq!(j + 1, self.jobs.len(), "micro-batch extends only the tail job");
        let job = &mut self.jobs[j];
        debug_assert!(
            matches!(job.key, Key::Bucket(..)),
            "only mini-batch visits micro-batch"
        );
        job.t_exec += t_item;
        job.done += t_item;
        job.batched += 1;
        self.free_at = self.free_at.max(job.done);
        self.busy += t_item;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::dataset;

    #[test]
    fn miss_pays_compile_then_hits_are_free() {
        let mut dev = Device::new(0, HwConfig::alveo_u250());
        let co = dataset("CO").unwrap();
        let mut exec = |_: &Executable| 1e-4;
        let (_, j0) = dev.admit(0.0, ZooModel::B1, &co, &mut exec);
        let first = dev.jobs[j0];
        assert!(!first.cache_hit);
        assert!(first.ready > 0.0, "miss must stall on the virtual compile");
        // Much later, same key: warm, starts immediately.
        let (_, j1) = dev.admit(1.0, ZooModel::B1, &co, &mut exec);
        let second = dev.jobs[j1];
        assert!(second.cache_hit);
        assert_eq!(second.ready, 1.0);
        assert_eq!(dev.cache_len(), 1);
        assert!(dev.is_warm(&Key::Whole(ZooModel::B1, "CO", 0, Precision::F32)));
    }

    #[test]
    fn hit_during_inflight_compile_waits_for_it() {
        let mut dev = Device::new(0, HwConfig::alveo_u250());
        let co = dataset("CO").unwrap();
        let mut exec = |_: &Executable| 1e-4;
        let (_, j0) = dev.admit(0.0, ZooModel::B2, &co, &mut exec);
        let warm = dev.jobs[j0].ready;
        // Arrives while the first compile is still in flight: the cache
        // already holds the program, but readiness waits for the compile.
        let mid = warm * 0.5;
        let (_, j1) = dev.admit(mid, ZooModel::B2, &co, &mut exec);
        assert!(dev.jobs[j1].cache_hit);
        assert_eq!(dev.jobs[j1].ready, warm);
    }

    #[test]
    fn queueing_behind_inflight_work() {
        let mut dev = Device::new(0, HwConfig::alveo_u250());
        let co = dataset("CO").unwrap();
        let mut exec = |_: &Executable| 1.0; // huge exec: forces queueing
        dev.admit(0.0, ZooModel::B1, &co, &mut exec);
        let (_, j1) = dev.admit(0.0, ZooModel::B1, &co, &mut exec);
        let job = dev.jobs[j1];
        assert!(job.start >= 1.0, "second job must queue behind the first");
        assert_eq!(dev.busy, 2.0);
        assert_eq!(dev.free_at, job.done);
    }

    #[test]
    fn minibatch_visit_pays_overhead_and_batches_share_it() {
        let mut dev = Device::new(0, HwConfig::alveo_u250());
        let shape = BucketShape::of(200, 900, 64, 8);
        let t_item = 1e-4;
        let mut exec = |_: &Executable| t_item;
        let (_, j) = dev.admit_minibatch(0.0, ZooModel::B1, shape, 1e-6, Precision::F32, &mut exec);
        let job = dev.jobs[j];
        assert!(!job.cache_hit);
        assert!(job.ready >= 1e-6, "readiness waits out the sampling stall");
        assert!((job.t_exec - (clock::VISIT_OVERHEAD_S + t_item)).abs() < 1e-12);
        // A rider extends the visit by its item time only.
        let done0 = job.done;
        dev.extend_batch(j, t_item);
        let job = dev.jobs[j];
        assert_eq!(job.batched, 1);
        assert!((job.done - (done0 + t_item)).abs() < 1e-12);
        assert_eq!(dev.free_at, job.done);
        // Same bucket later: cache hit, no second compile.
        let (_, j2) =
            dev.admit_minibatch(1.0, ZooModel::B1, shape, 1e-6, Precision::F32, &mut exec);
        assert!(dev.jobs[j2].cache_hit);
        assert_eq!(dev.cache_len(), 1);
        assert!(dev.is_warm(&Key::Bucket(ZooModel::B1, shape, Precision::F32)));
    }
}
