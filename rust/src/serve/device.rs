//! One overlay device of the serving fleet: a per-device program cache,
//! a compile-warmth ledger, and a busy timeline on the fleet's virtual
//! clock. Devices never read wall-clock time — all scheduling arithmetic
//! is over virtual seconds, so a fleet replay is bit-identical.

use super::cache::{Key, ProgramCache};
use super::clock::{self, CostModel};
use crate::compiler::{BucketShape, Executable};
use crate::config::HwConfig;
use crate::exec::{BufferArena, PackedWeightSet, PackedWeightSetI8};
use crate::graph::{Dataset, GraphMeta, TileCounts};
use crate::ir::ZooModel;
use crate::quant::Precision;
use std::collections::HashMap;
use std::sync::Arc;

/// A scheduled unit of accelerator work (the virtual timeline does not
/// distinguish in-flight from completed — `done` may be in the future).
///
/// For a mini-batch job the unit is one device *visit*: the creator's
/// ego-net plus any micro-batched riders, sharing one
/// [`clock::VISIT_OVERHEAD_S`]. `t_exec` is the visit total.
#[derive(Clone, Copy, Debug)]
pub struct Job {
    /// Program-cache key of the executable this job ran.
    pub key: Key,
    /// When the program is ready to start (arrival + any sampling and
    /// compile stalls).
    pub ready: f64,
    /// When the device began executing.
    pub start: f64,
    /// When the device finished.
    pub done: f64,
    /// Execution seconds charged to the busy timeline.
    pub t_exec: f64,
    /// Whether the executable came from the program cache.
    pub cache_hit: bool,
    /// Requests coalesced onto this job beyond the one that created it
    /// (identical whole-graph work: no extra device time).
    pub riders: u32,
    /// Mini-batch items micro-batched onto this visit beyond the one
    /// that created it (each adds its own execution time but shares the
    /// visit overhead).
    pub batched: u32,
}

/// One outage-calendar window on a device's timeline, derived from the
/// fleet's [`FaultPlan`](super::fault::FaultPlan) at setup. `crash`
/// windows kill work that would cross them; stall windows pause it.
/// `until` is `f64::INFINITY` for a permanent crash. `event` indexes
/// the plan event that produced the window (so firing it is recorded
/// once).
#[derive(Clone, Copy, Debug)]
pub struct FaultWindow {
    /// Window start (virtual seconds).
    pub from: f64,
    /// Window end (`f64::INFINITY` for a permanent crash).
    pub until: f64,
    /// Crash window (kills crossing work) vs. stall window (pauses it).
    pub crash: bool,
    /// Index of the plan event that produced this window.
    pub event: usize,
}

/// The outcome of quoting one unit of work against a device's fault
/// windows ([`Device::quote`]).
#[derive(Clone, Copy, Debug)]
pub enum Quote {
    /// Work completes: started at `start`, done at `done` (stall
    /// windows inside the attempt pause execution, stretching `done`
    /// past `start + t_exec`).
    Done { start: f64, done: f64 },
    /// The attempt crosses a crash window: everything computed between
    /// `start` and `at` is lost, and plan event `event` fired.
    Crashed { start: f64, at: f64, event: usize },
}

/// One overlay accelerator in the fleet: program cache, compile-warmth
/// ledger, outage calendar, and a busy timeline on the virtual clock.
pub struct Device {
    /// Fleet-assigned device index.
    pub id: usize,
    cache: ProgramCache,
    /// Virtual time each key's compile finishes on this device. A hit on
    /// a still-compiling entry waits for it rather than recompiling.
    warm_at: HashMap<Key, f64>,
    /// When the accelerator is next free.
    pub free_at: f64,
    /// Accumulated execution seconds (utilization numerator).
    pub busy: f64,
    /// Device-resident reusable tile buffers — the software analogue of
    /// the overlay's Feature/Result buffers. Functional replays on this
    /// device ([`crate::serve::Coordinator::functional_replay`]) draw
    /// from and recycle into this pool, so repeated replays allocate
    /// nothing in steady state.
    pub arena: BufferArena,
    /// Packed Linear-layer weights of the last functionally-replayed
    /// program (fingerprint-checked on reuse, rebuilt on mismatch), so
    /// back-to-back replays of the same (model, graph) pair skip
    /// repacking entirely.
    pub packed: Option<PackedWeightSet>,
    /// Int8 twin of `packed`: quantized weight panels of the last
    /// replayed quantized program, kept warm under the same
    /// fingerprint discipline.
    pub packed_i8: Option<PackedWeightSetI8>,
    /// Host-side cost coefficients (set from the fleet config so
    /// benches can sweep what used to be hard-coded constants).
    pub costs: CostModel,
    /// Outage calendar (sorted by `from`; empty without a fault plan —
    /// the zero-fault path never consults it).
    faults: Vec<FaultWindow>,
    /// Every unit of work scheduled on this device, in admission order.
    pub jobs: Vec<Job>,
    /// Index of the first job that may not have started yet. Start times
    /// are nondecreasing per device (each job begins no earlier than its
    /// predecessor's completion), so everything before this index has
    /// started for any later arrival — the coalescing scan never needs
    /// to revisit it.
    first_pending: usize,
}

impl Device {
    /// A fresh device with an empty cache and an idle timeline.
    pub fn new(id: usize, hw: HwConfig) -> Device {
        Device {
            id,
            cache: ProgramCache::new(hw),
            warm_at: HashMap::new(),
            free_at: 0.0,
            busy: 0.0,
            arena: BufferArena::new(),
            packed: None,
            packed_i8: None,
            costs: CostModel::default(),
            faults: Vec::new(),
            jobs: Vec::new(),
            first_pending: 0,
        }
    }

    /// Install this device's slice of the fleet's outage calendar
    /// (sorted by window start; the quote walk relies on the order).
    pub fn set_fault_windows(&mut self, mut windows: Vec<FaultWindow>) {
        windows.sort_by(|a, b| a.from.total_cmp(&b.from));
        self.faults = windows;
    }

    /// This device's slice of the fleet's outage calendar.
    pub fn fault_windows(&self) -> &[FaultWindow] {
        &self.faults
    }

    /// Earliest instant at or after `t` when this device is not inside
    /// a crash window — `f64::INFINITY` if it never comes back.
    pub fn up_at(&self, t: f64) -> f64 {
        let mut t = t;
        for w in &self.faults {
            if w.crash && w.from <= t && t < w.until {
                t = w.until;
            }
        }
        t
    }

    /// Quote `t_exec` seconds of work becoming ready at `ready` against
    /// the outage calendar: the attempt starts once the device is both
    /// free and up, pauses through stall windows, and dies at the first
    /// crash window it would cross.
    pub fn quote(&self, ready: f64, t_exec: f64) -> Quote {
        let start = self.up_at(ready.max(self.free_at));
        if start.is_infinite() {
            // Permanently down: model as an immediate crash at the
            // window that swallowed the start.
            let w = self
                .faults
                .iter()
                .find(|w| w.crash && w.until.is_infinite())
                .expect("infinite up_at implies an unbounded crash window");
            return Quote::Crashed { start: w.from, at: w.from, event: w.event };
        }
        let mut cur = start;
        let mut remaining = t_exec;
        for w in &self.faults {
            if w.until <= cur {
                continue;
            }
            if w.from >= cur + remaining {
                break;
            }
            if w.crash {
                // The window intersects the attempt (`until > cur`,
                // `from < cur + remaining`): the work dies when the
                // crash opens — even if that instant fell inside a
                // stall the attempt was paused in (`from <= cur`).
                return Quote::Crashed { start, at: w.from.max(start), event: w.event };
            }
            // Transient stall: progress pauses, no work is lost.
            if w.from > cur {
                remaining -= w.from - cur;
                cur = w.from;
            }
            cur = w.until;
        }
        Quote::Done { start, done: cur + remaining }
    }

    /// The crash itself: every compiled artifact and every compile-warmth
    /// entry is gone — the device rejoins (if it recovers) with a cold
    /// cache and repays every compile. Host-side state (tile counts,
    /// arena) survives.
    pub fn crash_wipe(&mut self, at: f64) {
        self.cache.clear();
        self.warm_at.clear();
        self.free_at = self.free_at.max(at);
    }

    /// Whether the compiled artifact itself is resident (unlike
    /// [`Device::is_warm`] this is exactly cache presence — the
    /// corruption fault needs an artifact to corrupt).
    pub fn has_cached(&self, key: &Key) -> bool {
        self.cache.contains(key)
    }

    /// The resident executable, if any (no compile, no warmth changes —
    /// the corruption fault serializes the artifact it damages).
    pub fn cached(&self, key: &Key) -> Option<Arc<Executable>> {
        self.cache.peek(key)
    }

    /// Evict one artifact and forget its warmth (corrupted-artifact
    /// recovery: the next access recompiles).
    pub fn evict(&mut self, key: &Key) -> bool {
        self.warm_at.remove(key);
        self.cache.remove(key)
    }

    /// Advance the pending cursor past jobs that have started by `now`.
    /// Arrivals are processed in nondecreasing time order, so the cursor
    /// only ever moves forward (amortized O(1) per request).
    pub fn retire_started(&mut self, now: f64) {
        while self.first_pending < self.jobs.len()
            && self.jobs[self.first_pending].start < now
        {
            self.first_pending += 1;
        }
    }

    /// Jobs not yet started as of the last [`Device::retire_started`]
    /// call, with their indices into `jobs`.
    pub fn pending_jobs(&self) -> impl Iterator<Item = (usize, &Job)> + '_ {
        let base = self.first_pending;
        self.jobs[base..].iter().enumerate().map(move |(i, j)| (base + i, j))
    }

    /// Cache-warm for `key` (the affinity-routing predicate).
    pub fn is_warm(&self, key: &Key) -> bool {
        self.cache.contains(key)
    }

    /// Number of programs compiled on this device.
    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    /// Bytes of compiled binaries resident on this device.
    pub fn cache_bytes(&self) -> u64 {
        self.cache.binary_bytes()
    }

    /// Schedule one ready-at-`ready` unit of work whose executable was
    /// fetched with `hit`: queue behind in-flight work, advance the busy
    /// timeline, record the job.
    fn push_job(&mut self, key: Key, ready: f64, t_exec: f64, hit: bool) -> usize {
        let start = ready.max(self.free_at);
        let done = start + t_exec;
        self.free_at = done;
        self.busy += t_exec;
        self.jobs.push(Job {
            key,
            ready,
            start,
            done,
            t_exec,
            cache_hit: hit,
            riders: 0,
            batched: 0,
        });
        self.jobs.len() - 1
    }

    /// Compile-or-reuse readiness for `key`: on a miss, the virtual
    /// compile stall starts at `at`; a hit on a still-compiling entry
    /// waits for it rather than recompiling.
    fn ready_at(&mut self, key: Key, at: f64, exe: &Executable) -> f64 {
        match self.warm_at.get(&key) {
            Some(&warm) => at.max(warm),
            None => {
                let warm = at + clock::compile_cost(&exe.report);
                self.warm_at.insert(key, warm);
                warm
            }
        }
    }

    /// Admit one whole-graph request at `arrival`: compile-or-reuse the
    /// program, charge the virtual compile cost on a miss (or the
    /// residual stall when the compile from an earlier miss is still in
    /// flight), then queue behind in-flight work. `exec_seconds`
    /// supplies the modeled execution time of an executable (memoized
    /// fleet-wide by the coordinator). Returns the executable and the
    /// new job's index.
    pub fn admit(
        &mut self,
        arrival: f64,
        model: ZooModel,
        ds: &Dataset,
        exec_seconds: &mut dyn FnMut(&Executable) -> f64,
    ) -> (Arc<Executable>, usize) {
        self.admit_at(arrival, model, ds, 0, None, Precision::F32, exec_seconds)
    }

    /// [`Device::admit`] against a specific graph epoch: a streamed
    /// dataset passes its current epoch plus the dynamic graph's
    /// snapshot (metadata + live tile counts) so a cache miss compiles
    /// against the churned graph, not the frozen dataset.
    pub fn admit_at(
        &mut self,
        arrival: f64,
        model: ZooModel,
        ds: &Dataset,
        epoch: u32,
        snapshot: Option<(&GraphMeta, &Arc<TileCounts>)>,
        precision: Precision,
        exec_seconds: &mut dyn FnMut(&Executable) -> f64,
    ) -> (Arc<Executable>, usize) {
        let key = Key::Whole(model, ds.key, epoch, precision);
        let (exe, hit) = self.cache.get_at(model, ds, epoch, snapshot, precision);
        let ready = self.ready_at(key, arrival, &exe);
        let t_exec = exec_seconds(&exe);
        let j = self.push_job(key, ready, t_exec, hit);
        (exe, j)
    }

    /// The fault path's two-phase admission, phase one: fetch-or-compile
    /// the whole-graph program and settle compile readiness, but do
    /// *not* schedule device time yet — the coordinator first quotes the
    /// attempt against the outage calendar (and may re-route, retry, or
    /// degrade it) before committing with [`Device::commit`].
    pub fn prepare(
        &mut self,
        at: f64,
        model: ZooModel,
        ds: &Dataset,
        epoch: u32,
        snapshot: Option<(&GraphMeta, &Arc<TileCounts>)>,
        precision: Precision,
    ) -> (Arc<Executable>, f64, bool) {
        let key = Key::Whole(model, ds.key, epoch, precision);
        let (exe, hit) = self.cache.get_at(model, ds, epoch, snapshot, precision);
        let ready = self.ready_at(key, at, &exe);
        (exe, ready, hit)
    }

    /// [`Device::prepare`] for a bucketed mini-batch program; `at`
    /// already includes the host-side sampling stall.
    pub fn prepare_bucket(
        &mut self,
        at: f64,
        model: ZooModel,
        shape: BucketShape,
        precision: Precision,
    ) -> (Arc<Executable>, f64, bool) {
        let key = Key::Bucket(model, shape, precision);
        let (exe, hit) = self.cache.get_bucket(model, shape, precision);
        let ready = self.ready_at(key, at, &exe);
        (exe, ready, hit)
    }

    /// The fault path's phase two: record a quoted attempt that
    /// completed. `done - start` may exceed `t_exec` (stall windows);
    /// only `t_exec` counts toward busy time.
    pub fn commit(&mut self, key: Key, ready: f64, start: f64, done: f64, t_exec: f64, hit: bool) -> usize {
        debug_assert!(start >= self.free_at, "quoted start predates device availability");
        self.free_at = done;
        self.busy += t_exec;
        self.jobs.push(Job {
            key,
            ready,
            start,
            done,
            t_exec,
            cache_hit: hit,
            riders: 0,
            batched: 0,
        });
        self.jobs.len() - 1
    }

    /// The QoS path's gap-placement twin of [`Device::commit`]: `start`
    /// may precede `free_at` (the scheduler verified the idle gap
    /// `[start, done)` against its own interval timeline, backfilling
    /// ahead of admitted-but-unstarted work), so `free_at` only ever
    /// moves forward. Gap placement forgoes coalescing and
    /// micro-batching — QoS serving never scans `pending_jobs`, so the
    /// out-of-order starts this records are harmless to the cursor.
    pub fn commit_gap(
        &mut self,
        key: Key,
        ready: f64,
        start: f64,
        done: f64,
        t_exec: f64,
        hit: bool,
    ) -> usize {
        self.free_at = self.free_at.max(done);
        self.busy += t_exec;
        self.jobs.push(Job {
            key,
            ready,
            start,
            done,
            t_exec,
            cache_hit: hit,
            riders: 0,
            batched: 0,
        });
        self.jobs.len() - 1
    }

    /// A crashed attempt: the device computed from `start` until the
    /// crash at `at` and lost all of it — the waste still occupies the
    /// busy timeline (that is the cost the retry pays for).
    pub fn charge_wasted(&mut self, start: f64, at: f64) {
        self.busy += (at - start).max(0.0);
        self.free_at = self.free_at.max(at);
    }

    /// Selective invalidation after a streaming update: drop stale
    /// whole-graph programs (epoch below `epoch`) of `ds_key` from the
    /// program cache and the compile-warmth ledger. Bucket programs
    /// survive untouched. Returns the number of programs dropped.
    pub fn invalidate_dataset(&mut self, ds_key: &str, epoch: u32) -> usize {
        self.warm_at
            .retain(|k, _| !matches!(k, Key::Whole(_, d, e, _) if *d == ds_key && *e < epoch));
        self.cache.invalidate_whole_before(ds_key, epoch)
    }

    /// Admit one mini-batch request: the bucket program compiles (or
    /// hits) like any other, but readiness additionally waits out the
    /// host-side sampling stall, and the device visit carries a fixed
    /// [`clock::VISIT_OVERHEAD_S`] on top of the item's execution time.
    pub fn admit_minibatch(
        &mut self,
        arrival: f64,
        model: ZooModel,
        shape: BucketShape,
        t_sample: f64,
        precision: Precision,
        exec_seconds: &mut dyn FnMut(&Executable) -> f64,
    ) -> (Arc<Executable>, usize) {
        let key = Key::Bucket(model, shape, precision);
        let (exe, hit) = self.cache.get_bucket(model, shape, precision);
        let ready = self.ready_at(key, arrival + t_sample, &exe);
        let t_visit = self.costs.visit_overhead_s + exec_seconds(&exe);
        let j = self.push_job(key, ready, t_visit, hit);
        (exe, j)
    }

    /// Micro-batch one more compatible mini-batch item onto the tail
    /// job `j`, which must not have started: the visit stretches by the
    /// item's execution time, and the rider shares the already-paid
    /// visit overhead and compile stall.
    pub fn extend_batch(&mut self, j: usize, t_item: f64) {
        debug_assert_eq!(j + 1, self.jobs.len(), "micro-batch extends only the tail job");
        let job = &mut self.jobs[j];
        debug_assert!(
            matches!(job.key, Key::Bucket(..)),
            "only mini-batch visits micro-batch"
        );
        job.t_exec += t_item;
        job.done += t_item;
        job.batched += 1;
        self.free_at = self.free_at.max(job.done);
        self.busy += t_item;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::dataset;

    #[test]
    fn miss_pays_compile_then_hits_are_free() {
        let mut dev = Device::new(0, HwConfig::alveo_u250());
        let co = dataset("CO").unwrap();
        let mut exec = |_: &Executable| 1e-4;
        let (_, j0) = dev.admit(0.0, ZooModel::B1, &co, &mut exec);
        let first = dev.jobs[j0];
        assert!(!first.cache_hit);
        assert!(first.ready > 0.0, "miss must stall on the virtual compile");
        // Much later, same key: warm, starts immediately.
        let (_, j1) = dev.admit(1.0, ZooModel::B1, &co, &mut exec);
        let second = dev.jobs[j1];
        assert!(second.cache_hit);
        assert_eq!(second.ready, 1.0);
        assert_eq!(dev.cache_len(), 1);
        assert!(dev.is_warm(&Key::Whole(ZooModel::B1, "CO", 0, Precision::F32)));
    }

    #[test]
    fn hit_during_inflight_compile_waits_for_it() {
        let mut dev = Device::new(0, HwConfig::alveo_u250());
        let co = dataset("CO").unwrap();
        let mut exec = |_: &Executable| 1e-4;
        let (_, j0) = dev.admit(0.0, ZooModel::B2, &co, &mut exec);
        let warm = dev.jobs[j0].ready;
        // Arrives while the first compile is still in flight: the cache
        // already holds the program, but readiness waits for the compile.
        let mid = warm * 0.5;
        let (_, j1) = dev.admit(mid, ZooModel::B2, &co, &mut exec);
        assert!(dev.jobs[j1].cache_hit);
        assert_eq!(dev.jobs[j1].ready, warm);
    }

    #[test]
    fn queueing_behind_inflight_work() {
        let mut dev = Device::new(0, HwConfig::alveo_u250());
        let co = dataset("CO").unwrap();
        let mut exec = |_: &Executable| 1.0; // huge exec: forces queueing
        dev.admit(0.0, ZooModel::B1, &co, &mut exec);
        let (_, j1) = dev.admit(0.0, ZooModel::B1, &co, &mut exec);
        let job = dev.jobs[j1];
        assert!(job.start >= 1.0, "second job must queue behind the first");
        assert_eq!(dev.busy, 2.0);
        assert_eq!(dev.free_at, job.done);
    }

    #[test]
    fn minibatch_visit_pays_overhead_and_batches_share_it() {
        let mut dev = Device::new(0, HwConfig::alveo_u250());
        let shape = BucketShape::of(200, 900, 64, 8);
        let t_item = 1e-4;
        let mut exec = |_: &Executable| t_item;
        let (_, j) = dev.admit_minibatch(0.0, ZooModel::B1, shape, 1e-6, Precision::F32, &mut exec);
        let job = dev.jobs[j];
        assert!(!job.cache_hit);
        assert!(job.ready >= 1e-6, "readiness waits out the sampling stall");
        assert!((job.t_exec - (clock::VISIT_OVERHEAD_S + t_item)).abs() < 1e-12);
        // A rider extends the visit by its item time only.
        let done0 = job.done;
        dev.extend_batch(j, t_item);
        let job = dev.jobs[j];
        assert_eq!(job.batched, 1);
        assert!((job.done - (done0 + t_item)).abs() < 1e-12);
        assert_eq!(dev.free_at, job.done);
        // Same bucket later: cache hit, no second compile.
        let (_, j2) =
            dev.admit_minibatch(1.0, ZooModel::B1, shape, 1e-6, Precision::F32, &mut exec);
        assert!(dev.jobs[j2].cache_hit);
        assert_eq!(dev.cache_len(), 1);
        assert!(dev.is_warm(&Key::Bucket(ZooModel::B1, shape, Precision::F32)));
    }

    #[test]
    fn quote_walks_the_outage_calendar() {
        let mut dev = Device::new(0, HwConfig::alveo_u250());
        dev.set_fault_windows(vec![
            FaultWindow { from: 10.0, until: 12.0, crash: true, event: 0 },
            FaultWindow { from: 2.0, until: 3.0, crash: false, event: 1 },
        ]);
        // Unaffected work: finishes before any window.
        match dev.quote(0.0, 1.0) {
            Quote::Done { start, done } => {
                assert_eq!(start, 0.0);
                assert_eq!(done, 1.0);
            }
            q => panic!("expected Done, got {q:?}"),
        }
        // Work crossing the stall pauses through it: 2s of work starting
        // at 1.0 loses [2, 3) and lands at 4.0.
        match dev.quote(1.0, 2.0) {
            Quote::Done { done, .. } => assert!((done - 4.0).abs() < 1e-12),
            q => panic!("expected Done, got {q:?}"),
        }
        // Work crossing the crash dies at the crash instant.
        match dev.quote(9.5, 1.0) {
            Quote::Crashed { start, at, event } => {
                assert_eq!(start, 9.5);
                assert_eq!(at, 10.0);
                assert_eq!(event, 0);
            }
            q => panic!("expected Crashed, got {q:?}"),
        }
        // Ready inside the crash window: the start is pushed past it.
        match dev.quote(10.5, 1.0) {
            Quote::Done { start, done } => {
                assert_eq!(start, 12.0);
                assert_eq!(done, 13.0);
            }
            q => panic!("expected Done, got {q:?}"),
        }
        assert_eq!(dev.up_at(11.0), 12.0);
        assert_eq!(dev.up_at(13.0), 13.0);
    }

    #[test]
    fn permanent_crash_never_comes_back() {
        let mut dev = Device::new(0, HwConfig::alveo_u250());
        dev.set_fault_windows(vec![FaultWindow {
            from: 1.0,
            until: f64::INFINITY,
            crash: true,
            event: 3,
        }]);
        assert!(dev.up_at(2.0).is_infinite());
        match dev.quote(2.0, 1.0) {
            Quote::Crashed { at, event, .. } => {
                assert_eq!(at, 1.0);
                assert_eq!(event, 3);
            }
            q => panic!("expected Crashed, got {q:?}"),
        }
    }

    #[test]
    fn crash_wipe_leaves_a_cold_cache() {
        let mut dev = Device::new(0, HwConfig::alveo_u250());
        let co = dataset("CO").unwrap();
        let mut exec = |_: &Executable| 1e-4;
        dev.admit(0.0, ZooModel::B1, &co, &mut exec);
        let key = Key::Whole(ZooModel::B1, "CO", 0, Precision::F32);
        assert!(dev.is_warm(&key));
        dev.crash_wipe(5.0);
        assert!(!dev.is_warm(&key));
        assert_eq!(dev.cache_len(), 0);
        assert!(dev.free_at >= 5.0);
        // The rejoin repays the compile.
        let (_, j) = dev.admit(10.0, ZooModel::B1, &co, &mut exec);
        assert!(!dev.jobs[j].cache_hit);
        assert!(dev.jobs[j].ready > 10.0);
    }

    #[test]
    fn prepare_then_commit_matches_admit_scheduling() {
        let co = dataset("CO").unwrap();
        let mut exec = |_: &Executable| 1e-3;
        let mut a = Device::new(0, HwConfig::alveo_u250());
        let (_, j) = a.admit(0.0, ZooModel::B1, &co, &mut exec);
        let via_admit = a.jobs[j];
        let mut b = Device::new(1, HwConfig::alveo_u250());
        let (_, ready, hit) = b.prepare(0.0, ZooModel::B1, &co, 0, None, Precision::F32);
        assert_eq!(ready, via_admit.ready);
        assert_eq!(hit, via_admit.cache_hit);
        let (start, done) = match b.quote(ready, 1e-3) {
            Quote::Done { start, done } => (start, done),
            q => panic!("expected Done, got {q:?}"),
        };
        let key = Key::Whole(ZooModel::B1, "CO", 0, Precision::F32);
        let j = b.commit(key, ready, start, done, 1e-3, hit);
        let via_commit = b.jobs[j];
        assert_eq!(via_commit.start, via_admit.start);
        assert_eq!(via_commit.done, via_admit.done);
        assert_eq!(b.free_at, a.free_at);
        assert_eq!(b.busy, a.busy);
        // Eviction (the corruption ritual's tail) forces a recompile.
        assert!(b.has_cached(&key));
        assert!(b.evict(&key));
        let (_, ready2, hit2) = b.prepare(1.0, ZooModel::B1, &co, 0, None, Precision::F32);
        assert!(!hit2);
        assert!(ready2 > 1.0);
    }

    #[test]
    fn charge_wasted_occupies_the_timeline() {
        let mut dev = Device::new(0, HwConfig::alveo_u250());
        dev.charge_wasted(1.0, 1.5);
        assert!((dev.busy - 0.5).abs() < 1e-12);
        assert_eq!(dev.free_at, 1.5);
    }
}
