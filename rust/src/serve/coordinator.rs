//! The serving request loop: tenants submit (model, graph) inference
//! requests; the coordinator compiles-or-reuses the program, accounts the
//! accelerator timeline (one overlay, FIFO with per-model affinity
//! batching), and reports per-tenant latency percentiles.
//!
//! Execution latency comes from the cycle-level simulator (one overlay
//! "device"); the functional PJRT path is exercised separately by
//! `examples/e2e_inference.rs` — this module is about the *coordination*
//! behaviour: cache warmup, queueing, batching, fairness.

use super::cache::ProgramCache;
use crate::config::HwConfig;
use crate::graph::Dataset;
use crate::ir::ZooModel;
use crate::sim::simulate;
use std::collections::HashMap;

/// One inference request.
#[derive(Clone, Copy, Debug)]
pub struct Request {
    pub tenant: u32,
    pub model: ZooModel,
    pub dataset: Dataset,
    /// Arrival time on the serving clock (seconds).
    pub arrival: f64,
}

/// Completion record.
#[derive(Clone, Copy, Debug)]
pub struct Response {
    pub tenant: u32,
    pub model: ZooModel,
    /// Compile time paid by this request (0 on cache hit).
    pub t_compile: f64,
    /// Simulated accelerator execution time.
    pub t_exec: f64,
    /// Queueing delay before the accelerator was free.
    pub t_queue: f64,
    /// arrival -> completion.
    pub latency: f64,
    pub cache_hit: bool,
}

/// Aggregate statistics.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub completed: u64,
    pub cache_hits: u64,
    pub p50: f64,
    pub p99: f64,
    pub mean: f64,
    pub device_busy: f64,
    pub makespan: f64,
}

/// Single-overlay coordinator.
pub struct Coordinator {
    cache: ProgramCache,
    /// Simulated exec time memo per (model, graph).
    exec_memo: HashMap<(ZooModel, &'static str), f64>,
    hw: HwConfig,
    /// Accelerator-free time on the serving clock.
    device_free: f64,
    pub responses: Vec<Response>,
}

impl Coordinator {
    pub fn new(hw: HwConfig) -> Coordinator {
        Coordinator {
            cache: ProgramCache::new(hw.clone()),
            exec_memo: HashMap::new(),
            hw,
            device_free: 0.0,
            responses: Vec::new(),
        }
    }

    /// Process requests in arrival order (the scheduler's dynamic
    /// batching happens *inside* a program via Alg. 9; across requests
    /// the overlay runs FIFO — switching models costs nothing but the
    /// binary pointer swap, which is the overlay's selling point).
    pub fn run(&mut self, mut requests: Vec<Request>) -> ServeStats {
        requests.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        for rq in requests {
            let t0 = std::time::Instant::now();
            let (exe, hit) = self.cache.get(rq.model, &rq.dataset);
            let t_compile = if hit { 0.0 } else { t0.elapsed().as_secs_f64() };
            let t_exec = *self
                .exec_memo
                .entry((rq.model, rq.dataset.key))
                .or_insert_with(|| simulate(&exe.program, &self.hw).loh_seconds());
            // Ready once compiled; waits for the device.
            let ready = rq.arrival + t_compile;
            let start = ready.max(self.device_free);
            let done = start + t_exec;
            self.device_free = done;
            self.responses.push(Response {
                tenant: rq.tenant,
                model: rq.model,
                t_compile,
                t_exec,
                t_queue: start - ready,
                latency: done - rq.arrival,
                cache_hit: hit,
            });
        }
        self.stats()
    }

    pub fn stats(&self) -> ServeStats {
        let mut lats: Vec<f64> = self.responses.iter().map(|r| r.latency).collect();
        if lats.is_empty() {
            return ServeStats::default();
        }
        lats.sort_by(f64::total_cmp);
        let pct = |p: f64| lats[((lats.len() as f64 - 1.0) * p) as usize];
        let busy: f64 = self.responses.iter().map(|r| r.t_exec).sum();
        ServeStats {
            completed: self.responses.len() as u64,
            cache_hits: self.responses.iter().filter(|r| r.cache_hit).count() as u64,
            p50: pct(0.50),
            p99: pct(0.99),
            mean: lats.iter().sum::<f64>() / lats.len() as f64,
            device_busy: busy,
            makespan: self.device_free,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::dataset;
    use crate::util::Rng;

    fn mixed_workload(n: usize, seed: u64) -> Vec<Request> {
        // Three tenants, three models, two graphs — the cloud scenario.
        let mut rng = Rng::new(seed);
        let models = [ZooModel::B1, ZooModel::B2, ZooModel::B7];
        let graphs = [dataset("CO").unwrap(), dataset("PU").unwrap()];
        (0..n)
            .map(|i| Request {
                tenant: rng.below(3) as u32,
                model: models[rng.below(3) as usize],
                dataset: graphs[rng.below(2) as usize],
                arrival: i as f64 * 1e-4,
            })
            .collect()
    }

    #[test]
    fn serves_mixed_tenants_with_cache_reuse() {
        let mut c = Coordinator::new(HwConfig::alveo_u250());
        let stats = c.run(mixed_workload(60, 1));
        assert_eq!(stats.completed, 60);
        // 3 models x 2 graphs = at most 6 compiles; everything else hits.
        assert!(stats.cache_hits >= 54, "hits {}", stats.cache_hits);
        assert!(stats.p99 >= stats.p50);
        assert!(stats.device_busy <= stats.makespan + 1e-9);
    }

    #[test]
    fn model_switching_is_free_of_recompiles() {
        // Alternate two models on one graph: after warmup, every request
        // is a cache hit — the "no FPGA reconfiguration" property.
        let co = dataset("CO").unwrap();
        let reqs: Vec<Request> = (0..20)
            .map(|i| Request {
                tenant: 0,
                model: if i % 2 == 0 { ZooModel::B1 } else { ZooModel::B6 },
                dataset: co,
                arrival: i as f64 * 1e-3,
            })
            .collect();
        let mut c = Coordinator::new(HwConfig::alveo_u250());
        c.run(reqs);
        let warm = &c.responses[2..];
        assert!(warm.iter().all(|r| r.cache_hit && r.t_compile == 0.0));
    }

    #[test]
    fn queueing_appears_under_burst() {
        // All requests arrive at t=0: later ones must queue.
        let pu = dataset("PU").unwrap();
        let reqs: Vec<Request> = (0..8)
            .map(|_| Request {
                tenant: 0,
                model: ZooModel::B2,
                dataset: pu,
                arrival: 0.0,
            })
            .collect();
        let mut c = Coordinator::new(HwConfig::alveo_u250());
        let stats = c.run(reqs);
        let queued = c.responses.iter().filter(|r| r.t_queue > 0.0).count();
        assert!(queued >= 6, "queued {queued}");
        // Makespan ~= sum of exec times (single device, saturated).
        assert!((stats.makespan - stats.device_busy).abs() < stats.makespan * 0.5);
    }

    #[test]
    fn empty_workload() {
        let mut c = Coordinator::new(HwConfig::alveo_u250());
        let stats = c.run(vec![]);
        assert_eq!(stats.completed, 0);
    }
}
