//! The serving request loop: tenants submit inference requests — whole
//! graphs or mini-batch ego-networks — and the coordinator routes each
//! across a fleet of N overlay devices ([`super::device::Device`]) via
//! the policy in [`super::dispatcher::Dispatcher`] — coalesce identical
//! in-flight work, micro-batch compatible mini-batches, else prefer a
//! cache-warm device — and accounts every latency on the deterministic
//! virtual clock ([`super::clock`]).
//!
//! Compile stalls are charged from the modeled
//! [`crate::compiler::CompileReport::total`], sampling stalls from
//! [`super::clock::sample_cost`], execution from the cycle simulator
//! (one overlay design ⇒ one exec time per program, memoized
//! fleet-wide). Nothing reads wall-clock time, so a replayed workload
//! produces bit-identical [`ServeStats`].
//!
//! Mini-batch requests ([`Target::MiniBatch`]) sample a k-hop ego-net
//! from the dataset (deterministic in the request seed), round its
//! shape up to a power-of-two bucket
//! ([`crate::compiler::BucketShape`]), and execute the bucket's cached
//! program — so per-request cost is proportional to the sampled
//! neighborhood, and thousands of distinct ego-nets share a handful of
//! compiled programs.

use super::cache::Key;
use super::clock::{self, VirtualClock};
use super::device::Device;
use super::dispatcher::{Dispatcher, Route};
use crate::compiler::{BucketShape, Executable};
use crate::config::HwConfig;
use crate::engine::{EngineInput, ExecProfile};
use crate::exec::{CountingBackend, FunctionalExecutor, RustBackend};
use crate::graph::{Dataset, Sampler};
use crate::ir::ZooModel;
use crate::sim::{simulate, simulate_dynamic};
use crate::util::timed;
use anyhow::{bail, Result};
use std::collections::HashMap;

/// What a request asks to run over.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Target {
    /// Inference over the whole dataset graph (the original request
    /// class).
    FullGraph,
    /// Inference over the k-hop ego-network of `targets`
    /// (`k = fanout.len()`; [`crate::graph::FULL_NEIGHBORHOOD`] per hop
    /// keeps every in-neighbor). Sampling is deterministic in `seed`.
    MiniBatch {
        targets: Vec<u32>,
        fanout: Vec<u32>,
        seed: u64,
    },
}

impl Target {
    pub fn is_minibatch(&self) -> bool {
        matches!(self, Target::MiniBatch { .. })
    }
}

/// One inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub tenant: u32,
    pub model: ZooModel,
    pub dataset: Dataset,
    pub target: Target,
    /// Arrival time on the serving clock (seconds).
    pub arrival: f64,
}

impl Request {
    /// A whole-graph request (the pre-mini-batch request shape).
    pub fn full(tenant: u32, model: ZooModel, dataset: Dataset, arrival: f64) -> Request {
        Request { tenant, model, dataset, target: Target::FullGraph, arrival }
    }

    /// A mini-batch request over `targets` with per-hop `fanout`.
    pub fn minibatch(
        tenant: u32,
        model: ZooModel,
        dataset: Dataset,
        targets: Vec<u32>,
        fanout: Vec<u32>,
        seed: u64,
        arrival: f64,
    ) -> Request {
        Request {
            tenant,
            model,
            dataset,
            target: Target::MiniBatch { targets, fanout, seed },
            arrival,
        }
    }
}

/// Completion record.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Response {
    pub tenant: u32,
    pub model: ZooModel,
    /// Device that executed (or will execute) the work.
    pub device: u32,
    /// Compile stall paid by this request (0 on a warm hit).
    pub t_compile: f64,
    /// Host-side sampling stall (0 for whole-graph requests).
    pub t_sample: f64,
    /// Simulated accelerator execution time (for a mini-batch creator
    /// this includes the fixed visit overhead; riders report their item
    /// time only).
    pub t_exec: f64,
    /// Queueing delay between program-ready and device-free.
    pub t_queue: f64,
    /// arrival -> completion.
    pub latency: f64,
    pub cache_hit: bool,
    /// Rode an identical in-flight job (no extra device work).
    pub coalesced: bool,
    /// Mini-batch request micro-batched onto an existing device visit.
    pub batched: bool,
    /// Whether this was a mini-batch request.
    pub minibatch: bool,
    /// Ego-net vertices sampled for this request (0 for whole-graph).
    pub sampled_vertices: u64,
    /// Ego-net edges sampled for this request (0 for whole-graph).
    pub sampled_edges: u64,
    /// Density-driven kernel re-maps in the execution serving this
    /// request (riders report the re-maps of the job they rode).
    pub remaps: u64,
}

/// Aggregate statistics. `PartialEq` so replay determinism is testable
/// as plain equality.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServeStats {
    pub completed: u64,
    pub cache_hits: u64,
    pub coalesced: u64,
    /// Completed mini-batch requests.
    pub minibatched: u64,
    /// Mini-batch requests that micro-batched onto an existing visit.
    pub batched: u64,
    /// Mini-batch requests whose bucket program was already compiled
    /// on the serving device (riders count: they never compile).
    pub bucket_hits: u64,
    /// Ego-net vertices sampled across all mini-batch requests.
    pub sampled_vertices: u64,
    /// Ego-net edges sampled across all mini-batch requests.
    pub sampled_edges: u64,
    /// Kernel re-maps summed over *executed* jobs (coalesced riders are
    /// excluded so one execution is not counted once per rider).
    pub remaps: u64,
    pub p50: f64,
    pub p99: f64,
    pub mean: f64,
    /// p50 over mini-batch responses only (0 when there are none).
    pub p50_mini: f64,
    /// p50 over whole-graph responses only (0 when there are none).
    pub p50_full: f64,
    /// Sum of execution seconds across devices.
    pub device_busy: f64,
    pub makespan: f64,
}

/// Fleet shape and routing policy.
#[derive(Clone, Copy, Debug)]
pub struct FleetConfig {
    pub n_devices: usize,
    pub affinity: bool,
    pub coalesce: bool,
    /// Micro-batch compatible mini-batch requests into one device
    /// visit.
    pub microbatch: bool,
    /// Serve with density-aware dynamic kernel re-mapping (execution
    /// time and re-map counts from [`crate::sim::simulate_dynamic`],
    /// which is never slower than the static mapping).
    pub dynamic: bool,
}

impl Default for FleetConfig {
    fn default() -> FleetConfig {
        FleetConfig {
            n_devices: 1,
            affinity: true,
            coalesce: true,
            microbatch: true,
            dynamic: true,
        }
    }
}

/// Nearest-rank percentile of an ascending-sorted slice: the smallest
/// value with at least `ceil(p * n)` observations ≤ it.
///
/// Panics on an empty slice (a percentile of nothing has no answer).
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of an empty sample");
    let rank = (p * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// p50 of an unsorted latency class, 0 when the class is empty.
fn class_p50(mut lats: Vec<f64>) -> f64 {
    if lats.is_empty() {
        return 0.0;
    }
    lats.sort_by(f64::total_cmp);
    percentile(&lats, 0.50)
}

/// Fleet-wide modeled execution memo: (exec seconds, kernel re-maps)
/// per program key, simulated on first use. One helper for both
/// request classes so the memoization policy cannot drift between
/// them. Borrows only the memo and hardware config, so callers can
/// hold a device mutably at the same time.
fn memo_exec<'a>(
    memo: &'a mut HashMap<Key, (f64, u64)>,
    hw: &'a HwConfig,
    dynamic: bool,
    key: Key,
) -> impl FnMut(&Executable) -> f64 + 'a {
    move |exe: &Executable| {
        memo.entry(key)
            .or_insert_with(|| {
                let sim = if dynamic {
                    simulate_dynamic(&exe.program, hw)
                } else {
                    simulate(&exe.program, hw)
                };
                (sim.loh_seconds(), sim.remaps)
            })
            .0
    }
}

/// Multi-device coordinator.
pub struct Coordinator {
    devices: Vec<Device>,
    dispatcher: Dispatcher,
    clock: VirtualClock,
    /// Modeled (exec seconds, kernel re-maps) per program key: every
    /// device is the same overlay design, so execution is a fleet-wide
    /// property.
    exec_memo: HashMap<Key, (f64, u64)>,
    /// Per-dataset ego-net extractors, built on first mini-batch use
    /// (materialize + whole-graph CSR, amortized across requests).
    samplers: HashMap<&'static str, Sampler>,
    hw: HwConfig,
    dynamic: bool,
    pub responses: Vec<Response>,
}

impl Coordinator {
    /// Single-overlay coordinator (the paper's deployment).
    pub fn new(hw: HwConfig) -> Coordinator {
        Coordinator::fleet(hw, FleetConfig::default())
    }

    pub fn fleet(hw: HwConfig, cfg: FleetConfig) -> Coordinator {
        assert!(cfg.n_devices >= 1, "fleet needs at least one device");
        Coordinator {
            devices: (0..cfg.n_devices).map(|i| Device::new(i, hw.clone())).collect(),
            dispatcher: Dispatcher {
                affinity: cfg.affinity,
                coalesce: cfg.coalesce,
                microbatch: cfg.microbatch,
            },
            clock: VirtualClock::new(),
            exec_memo: HashMap::new(),
            samplers: HashMap::new(),
            hw,
            dynamic: cfg.dynamic,
            responses: Vec::new(),
        }
    }

    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    pub fn devices(&self) -> &[Device] {
        &self.devices
    }

    /// Virtual time of the last processed event.
    pub fn now(&self) -> f64 {
        self.clock.now()
    }

    /// Fleet-wide cache hit rate over processed responses (coalesced
    /// and batched responses count as hits: they never touched a
    /// compiler).
    pub fn hit_rate(&self) -> f64 {
        if self.responses.is_empty() {
            return 0.0;
        }
        self.responses.iter().filter(|r| r.cache_hit).count() as f64
            / self.responses.len() as f64
    }

    /// Process a workload: arrival events in deterministic order (time,
    /// then tenant/model/graph/target for simultaneous arrivals), each
    /// routed by the dispatcher, scheduled on a device timeline, and
    /// accounted on the virtual clock.
    pub fn run(&mut self, mut requests: Vec<Request>) -> ServeStats {
        requests.sort_by(|a, b| {
            a.arrival
                .total_cmp(&b.arrival)
                .then(a.tenant.cmp(&b.tenant))
                .then(a.model.key().cmp(b.model.key()))
                .then(a.dataset.key.cmp(b.dataset.key))
                .then(a.target.cmp(&b.target))
        });
        for rq in requests {
            self.clock.advance_to(rq.arrival);
            for d in &mut self.devices {
                d.retire_started(rq.arrival);
            }
            let resp = match &rq.target {
                Target::FullGraph => self.serve_full(&rq),
                Target::MiniBatch { targets, fanout, seed } => {
                    self.serve_minibatch(&rq, targets, fanout, *seed)
                }
            };
            self.clock.advance_to(rq.arrival + resp.latency);
            self.responses.push(resp);
        }
        self.stats()
    }

    fn serve_full(&mut self, rq: &Request) -> Response {
        let key = Key::Whole(rq.model, rq.dataset.key);
        let route = self.dispatcher.route(&self.devices, &key, rq.arrival);
        match route {
            Route::Coalesce(dev, j) => {
                let remaps = self.exec_memo.get(&key).map_or(0, |e| e.1);
                let job = &mut self.devices[dev].jobs[j];
                job.riders += 1;
                Response {
                    tenant: rq.tenant,
                    model: rq.model,
                    device: dev as u32,
                    t_compile: 0.0,
                    t_sample: 0.0,
                    t_exec: job.t_exec,
                    t_queue: (job.start - rq.arrival).max(0.0),
                    latency: job.done - rq.arrival,
                    cache_hit: true,
                    coalesced: true,
                    batched: false,
                    minibatch: false,
                    sampled_vertices: 0,
                    sampled_edges: 0,
                    remaps,
                }
            }
            Route::Device(dev) => {
                // Inner scope: the memoizing closure's &mut borrow of
                // exec_memo must end before the memo is read below.
                let job = {
                    let mut exec_seconds =
                        memo_exec(&mut self.exec_memo, &self.hw, self.dynamic, key);
                    let device = &mut self.devices[dev];
                    let (_exe, j) =
                        device.admit(rq.arrival, rq.model, &rq.dataset, &mut exec_seconds);
                    device.jobs[j]
                };
                Response {
                    tenant: rq.tenant,
                    model: rq.model,
                    device: dev as u32,
                    t_compile: job.ready - rq.arrival,
                    t_sample: 0.0,
                    t_exec: job.t_exec,
                    t_queue: job.start - job.ready,
                    latency: job.done - rq.arrival,
                    cache_hit: job.cache_hit,
                    coalesced: false,
                    batched: false,
                    minibatch: false,
                    sampled_vertices: 0,
                    sampled_edges: 0,
                    remaps: self.exec_memo.get(&key).map_or(0, |e| e.1),
                }
            }
            Route::Batch(..) => unreachable!("whole-graph requests never micro-batch"),
        }
    }

    fn serve_minibatch(
        &mut self,
        rq: &Request,
        targets: &[u32],
        fanout: &[u32],
        seed: u64,
    ) -> Response {
        let ego = {
            // GCN-normalize like the functional paths (MiniBatchRunner,
            // golden tests) do: the self-loop edges are part of every
            // ego-net there, so modeled sample sizes and bucket shapes
            // stay cross-checkable against a functional replay of the
            // same trace.
            let sampler = self
                .samplers
                .entry(rq.dataset.key)
                .or_insert_with(|| Sampler::new(rq.dataset.materialize().gcn_normalized()));
            sampler.sample(targets, fanout, seed)
        };
        let shape = BucketShape::for_graph(&ego.graph.meta);
        let (sampled_v, sampled_e) = (ego.n() as u64, ego.m() as u64);
        let t_sample = clock::sample_cost(sampled_v, sampled_e);
        let key = Key::Bucket(rq.model, shape);
        // A visit can only be ridden once the rider's ego-net exists:
        // route against the post-sampling ready time, not the arrival.
        let ready = rq.arrival + t_sample;
        let route = self.dispatcher.route_minibatch(&self.devices, &key, ready);
        match route {
            Route::Batch(dev, j) => {
                // The tail visit's bucket program is compiled (or
                // compiling) on this device, so its exec time is
                // already memoized.
                let (t_item, remaps) = *self
                    .exec_memo
                    .get(&key)
                    .expect("batched onto a visit whose exec time is memoized");
                let device = &mut self.devices[dev];
                device.extend_batch(j, t_item);
                let job = device.jobs[j];
                Response {
                    tenant: rq.tenant,
                    model: rq.model,
                    device: dev as u32,
                    t_compile: 0.0,
                    t_sample,
                    t_exec: t_item,
                    t_queue: (job.start - ready).max(0.0),
                    latency: job.done - rq.arrival,
                    cache_hit: true,
                    coalesced: false,
                    batched: true,
                    minibatch: true,
                    sampled_vertices: sampled_v,
                    sampled_edges: sampled_e,
                    remaps,
                }
            }
            Route::Device(dev) => {
                // Inner scope: the memoizing closure's &mut borrow of
                // exec_memo must end before the memo is read below.
                let job = {
                    let mut exec_seconds =
                        memo_exec(&mut self.exec_memo, &self.hw, self.dynamic, key);
                    let device = &mut self.devices[dev];
                    let (_exe, j) = device.admit_minibatch(
                        rq.arrival,
                        rq.model,
                        shape,
                        t_sample,
                        &mut exec_seconds,
                    );
                    device.jobs[j]
                };
                Response {
                    tenant: rq.tenant,
                    model: rq.model,
                    device: dev as u32,
                    t_compile: (job.ready - rq.arrival - t_sample).max(0.0),
                    t_sample,
                    t_exec: job.t_exec,
                    t_queue: job.start - job.ready,
                    latency: job.done - rq.arrival,
                    cache_hit: job.cache_hit,
                    coalesced: false,
                    batched: false,
                    minibatch: true,
                    sampled_vertices: sampled_v,
                    sampled_edges: sampled_e,
                    remaps: self.exec_memo.get(&key).map_or(0, |e| e.1),
                }
            }
            Route::Coalesce(..) => {
                unreachable!("mini-batch requests micro-batch, never coalesce")
            }
        }
    }

    /// Execute real numerics for one compiled program on a specific
    /// device's functional substrate — the fleet's audit path for
    /// spot-checking that a served (model, graph) pair still produces
    /// golden-equivalent outputs. Tile buffers come from the *device's*
    /// own [`crate::exec::BufferArena`] (the software analogue of its
    /// resident Feature Buffer), so repeated replays on a device are
    /// allocation-free in steady state. The virtual clock is untouched:
    /// a replay is offline verification, not a served request.
    pub fn functional_replay(
        &mut self,
        device: usize,
        exe: &Executable,
        input: &EngineInput<'_>,
    ) -> Result<ExecProfile> {
        if device >= self.devices.len() {
            bail!("no device {device} in a {}-device fleet", self.devices.len());
        }
        if exe.cfg != input.partitioned.cfg {
            bail!(
                "graph partitioned with (N1={}, N2={}) but executable wants (N1={}, N2={})",
                input.partitioned.cfg.n1,
                input.partitioned.cfg.n2,
                exe.cfg.n1,
                exe.cfg.n2
            );
        }
        let arena = std::mem::take(&mut self.devices[device].arena);
        let packed = self.devices[device].packed.take();
        let mut fx = FunctionalExecutor::with_state(
            exe,
            input.partitioned,
            input.store,
            CountingBackend::new(RustBackend),
            arena,
            packed,
        );
        fx.dynamic = self.dynamic;
        let (out, secs) = timed(|| fx.run(input.x));
        let profile = ExecProfile {
            engine: "functional",
            latency_s: secs,
            cycles: 0,
            kernel_launches: fx.backend.launches,
            bytes_moved: fx.backend.bytes,
            remaps: fx.remaps,
            output: Some(out),
        };
        let (arena, packed) = fx.into_state();
        self.devices[device].arena = arena;
        self.devices[device].packed = Some(packed);
        Ok(profile)
    }

    pub fn stats(&self) -> ServeStats {
        let mut lats: Vec<f64> = self.responses.iter().map(|r| r.latency).collect();
        if lats.is_empty() {
            return ServeStats::default();
        }
        lats.sort_by(f64::total_cmp);
        let class = |mini: bool| -> Vec<f64> {
            self.responses
                .iter()
                .filter(|r| r.minibatch == mini)
                .map(|r| r.latency)
                .collect()
        };
        ServeStats {
            completed: self.responses.len() as u64,
            cache_hits: self.responses.iter().filter(|r| r.cache_hit).count() as u64,
            coalesced: self.responses.iter().filter(|r| r.coalesced).count() as u64,
            minibatched: self.responses.iter().filter(|r| r.minibatch).count() as u64,
            batched: self.responses.iter().filter(|r| r.batched).count() as u64,
            bucket_hits: self
                .responses
                .iter()
                .filter(|r| r.minibatch && r.cache_hit)
                .count() as u64,
            sampled_vertices: self.responses.iter().map(|r| r.sampled_vertices).sum(),
            sampled_edges: self.responses.iter().map(|r| r.sampled_edges).sum(),
            remaps: self
                .responses
                .iter()
                .filter(|r| !r.coalesced)
                .map(|r| r.remaps)
                .sum(),
            p50: percentile(&lats, 0.50),
            p99: percentile(&lats, 0.99),
            mean: lats.iter().sum::<f64>() / lats.len() as f64,
            p50_mini: class_p50(class(true)),
            p50_full: class_p50(class(false)),
            device_busy: self.devices.iter().map(|d| d.busy).sum(),
            makespan: self.clock.now(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{dataset, FULL_NEIGHBORHOOD};
    use crate::util::Rng;

    fn mixed_workload(n: usize, seed: u64) -> Vec<Request> {
        // Three tenants, three models, two graphs — the cloud scenario.
        let mut rng = Rng::new(seed);
        let models = [ZooModel::B1, ZooModel::B2, ZooModel::B7];
        let graphs = [dataset("CO").unwrap(), dataset("PU").unwrap()];
        (0..n)
            .map(|i| {
                Request::full(
                    rng.below(3) as u32,
                    models[rng.below(3) as usize],
                    graphs[rng.below(2) as usize],
                    i as f64 * 1e-4,
                )
            })
            .collect()
    }

    fn minibatch_workload(n: usize, seed: u64, spacing: f64) -> Vec<Request> {
        let mut rng = Rng::new(seed);
        let models = [ZooModel::B1, ZooModel::B7];
        let co = dataset("CO").unwrap();
        (0..n)
            .map(|i| {
                let k = 1 + rng.below(3) as usize;
                let targets: Vec<u32> =
                    (0..k).map(|_| rng.below(co.n_vertices) as u32).collect();
                Request::minibatch(
                    rng.below(4) as u32,
                    models[rng.below(2) as usize],
                    co,
                    targets,
                    vec![8, 4],
                    seed ^ i as u64,
                    i as f64 * spacing,
                )
            })
            .collect()
    }

    #[test]
    fn serves_mixed_tenants_with_cache_reuse() {
        let mut c = Coordinator::new(HwConfig::alveo_u250());
        let stats = c.run(mixed_workload(60, 1));
        assert_eq!(stats.completed, 60);
        // 3 models x 2 graphs = at most 6 compiles; everything else hits
        // (a coalesced ride counts as a hit).
        assert!(stats.cache_hits >= 54, "hits {}", stats.cache_hits);
        assert!(stats.p99 >= stats.p50);
        assert!(stats.device_busy <= stats.makespan + 1e-9);
        // A whole-graph workload samples nothing.
        assert_eq!(stats.minibatched, 0);
        assert_eq!(stats.sampled_edges, 0);
        assert_eq!(stats.p50_full, stats.p50);
        assert_eq!(stats.p50_mini, 0.0);
    }

    #[test]
    fn model_switching_is_free_of_recompiles() {
        // Alternate two models on one graph: after warmup, every request
        // is a cache hit — the "no FPGA reconfiguration" property.
        let co = dataset("CO").unwrap();
        let reqs: Vec<Request> = (0..20)
            .map(|i| {
                Request::full(
                    0,
                    if i % 2 == 0 { ZooModel::B1 } else { ZooModel::B6 },
                    co,
                    i as f64 * 1e-3,
                )
            })
            .collect();
        let mut c = Coordinator::new(HwConfig::alveo_u250());
        c.run(reqs);
        let warm = &c.responses[2..];
        assert!(warm.iter().all(|r| r.cache_hit && r.t_compile == 0.0));
    }

    #[test]
    fn queueing_appears_under_burst() {
        // All requests arrive at t=0 on one device with coalescing off:
        // later ones must queue.
        let pu = dataset("PU").unwrap();
        let reqs: Vec<Request> = (0..8)
            .map(|i| Request::full(i, ZooModel::B2, pu, 0.0))
            .collect();
        let cfg = FleetConfig { coalesce: false, ..FleetConfig::default() };
        let mut c = Coordinator::fleet(HwConfig::alveo_u250(), cfg);
        let stats = c.run(reqs);
        let queued = c.responses.iter().filter(|r| r.t_queue > 0.0).count();
        assert!(queued >= 6, "queued {queued}");
        // Makespan ~= sum of exec times (single device, saturated).
        assert!((stats.makespan - stats.device_busy).abs() < stats.makespan * 0.5);
    }

    #[test]
    fn identical_burst_coalesces_into_one_execution() {
        let pu = dataset("PU").unwrap();
        let reqs: Vec<Request> = (0..8)
            .map(|i| Request::full(i, ZooModel::B2, pu, 0.0))
            .collect();
        let mut c = Coordinator::new(HwConfig::alveo_u250());
        let stats = c.run(reqs);
        // The first request compiles; the other seven ride its job while
        // it waits on the (virtual) compile.
        assert_eq!(stats.completed, 8);
        assert_eq!(stats.coalesced, 7, "coalesced {}", stats.coalesced);
        let exec_once = c.responses[0].t_exec;
        assert!((stats.device_busy - exec_once).abs() < 1e-12, "one execution total");
        assert_eq!(c.devices()[0].jobs.len(), 1);
        assert_eq!(c.devices()[0].jobs[0].riders, 7);
    }

    #[test]
    fn replay_is_bit_identical() {
        // The satellite guarantee: no wall-clock leaks into serving
        // stats — two runs of the same workload agree exactly.
        let run = || {
            let cfg = FleetConfig { n_devices: 3, ..FleetConfig::default() };
            let mut c = Coordinator::fleet(HwConfig::alveo_u250(), cfg);
            let stats = c.run(mixed_workload(40, 7));
            (stats, c.responses)
        };
        let (s1, r1) = run();
        let (s2, r2) = run();
        assert_eq!(s1, s2);
        assert_eq!(r1, r2);
    }

    #[test]
    fn four_devices_beat_one_on_saturating_burst() {
        // A saturating burst (coalescing off, so every request is real
        // device work): four overlays must finish strictly sooner than
        // one, and cache-affinity must keep the fleet hit rate at least
        // at the single-device level (at most one compile per distinct
        // key fleet-wide).
        let run = |n_devices: usize| {
            let cfg =
                FleetConfig { n_devices, coalesce: false, ..FleetConfig::default() };
            let mut c = Coordinator::fleet(HwConfig::alveo_u250(), cfg);
            let stats = c.run(mixed_workload(48, 3));
            (stats, c)
        };
        let (s1, _) = run(1);
        let (s4, c4) = run(4);
        assert_eq!(s1.completed, s4.completed);
        assert!(
            s4.makespan < s1.makespan,
            "4-device makespan {} !< 1-device {}",
            s4.makespan,
            s1.makespan
        );
        assert!(
            s4.cache_hits >= s1.cache_hits,
            "fleet hits {} < single-device {}",
            s4.cache_hits,
            s1.cache_hits
        );
        // The burst spread across the fleet.
        let active = c4.devices().iter().filter(|d| d.busy > 0.0).count();
        assert!(active >= 2, "only {active} devices did work");
        // Per-device caches: fleet-wide at most one compile per key.
        let compiles: usize = c4.devices().iter().map(|d| d.cache_len()).sum();
        assert!(compiles <= 6, "{compiles} compiles for 6 distinct keys");
    }

    #[test]
    fn percentiles_nearest_rank() {
        // The satellite fix: (len-1)*p truncation under-reported p99 (on
        // 100 samples it indexed 98.01 -> 98, i.e. the 99th sample, but
        // on small n it collapsed toward p50). Nearest-rank is exact.
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.50), 50.0);
        assert_eq!(percentile(&v, 0.99), 99.0);
        assert_eq!(percentile(&v, 1.00), 100.0);
        let small = [10.0, 20.0, 30.0, 40.0, 50.0];
        assert_eq!(percentile(&small, 0.50), 30.0);
        assert_eq!(percentile(&small, 0.99), 50.0);
        // The old truncating formula pinned p99 of 5 samples to index
        // (5-1)*0.99 = 3 (40.0) — the tail sample was unreachable.
        assert_eq!(percentile(&small, 0.25), 20.0);
    }

    #[test]
    fn remap_counters_are_deterministic_and_not_double_counted() {
        let run = |dynamic: bool| {
            let cfg = FleetConfig { dynamic, ..FleetConfig::default() };
            let mut c = Coordinator::fleet(HwConfig::alveo_u250(), cfg);
            let stats = c.run(mixed_workload(30, 5));
            (stats, c.responses)
        };
        let (s1, r1) = run(true);
        let (s2, r2) = run(true);
        assert_eq!(s1, s2);
        assert_eq!(r1, r2);
        // Riders echo their job's remap count but only executed jobs are
        // summed into the stats.
        let executed: u64 = r1.iter().filter(|r| !r.coalesced).map(|r| r.remaps).sum();
        assert_eq!(s1.remaps, executed);
        // Static serving reports zero re-maps everywhere.
        let (s0, r0) = run(false);
        assert_eq!(s0.remaps, 0);
        assert!(r0.iter().all(|r| r.remaps == 0));
        // Dynamic execution times are never slower (memoized per key).
        assert!(s1.makespan <= s0.makespan + 1e-12);
    }

    #[test]
    fn minibatch_requests_sample_bucket_and_batch() {
        // A mini-batch burst over one small dataset: two models, a few
        // buckets, plenty of compatible visits to micro-batch.
        let reqs = minibatch_workload(40, 3, 1e-5);
        let mut c = Coordinator::new(HwConfig::alveo_u250());
        let stats = c.run(reqs);
        assert_eq!(stats.completed, 40);
        assert_eq!(stats.minibatched, 40);
        assert!(stats.sampled_vertices > 0 && stats.sampled_edges > 0);
        // Bucketing: far fewer compiled programs than requests.
        let compiles: usize = c.devices().iter().map(|d| d.cache_len()).sum();
        assert!(compiles <= 12, "{compiles} bucket programs for 40 requests");
        assert_eq!(stats.bucket_hits, 40 - compiles as u64);
        // The tight burst batches at least one visit.
        assert!(stats.batched > 0, "no micro-batching under a tight burst");
        assert_eq!(stats.p50_mini, stats.p50);
        assert_eq!(stats.p50_full, 0.0);
        // Every mini-batch latency includes its sampling stall.
        assert!(c.responses.iter().all(|r| r.t_sample > 0.0));
    }

    #[test]
    fn minibatch_replay_is_bit_identical() {
        let run = || {
            let cfg = FleetConfig { n_devices: 2, ..FleetConfig::default() };
            let mut c = Coordinator::fleet(HwConfig::alveo_u250(), cfg);
            let mut reqs = minibatch_workload(24, 9, 5e-5);
            reqs.extend(mixed_workload(24, 9));
            let stats = c.run(reqs);
            (stats, c.responses)
        };
        let (s1, r1) = run();
        let (s2, r2) = run();
        assert_eq!(s1, s2);
        assert_eq!(r1, r2);
        // Mixed workload: both latency classes are populated.
        assert!(s1.p50_mini > 0.0 && s1.p50_full > 0.0);
        assert_eq!(s1.minibatched, 24);
    }

    #[test]
    fn microbatching_reduces_device_time_without_hurting_latency() {
        let run = |microbatch: bool| {
            let cfg = FleetConfig { microbatch, ..FleetConfig::default() };
            let mut c = Coordinator::fleet(HwConfig::alveo_u250(), cfg);
            c.run(minibatch_workload(32, 5, 1e-6))
        };
        let on = run(true);
        let off = run(false);
        assert!(on.batched > 0);
        assert_eq!(off.batched, 0);
        // Riders share the fixed visit overhead: the fleet does
        // strictly less device work for the same request stream.
        assert!(
            on.device_busy < off.device_busy,
            "batched busy {} !< unbatched {}",
            on.device_busy,
            off.device_busy
        );
        // ...and never at the cost of latency: on a single device the
        // batched schedule dominates (every visit starts no later), so
        // the deterministic percentiles cannot regress.
        assert!(
            on.p50 <= off.p50 + 1e-12 && on.p99 <= off.p99 + 1e-12,
            "batching hurt latency: p50 {} vs {}, p99 {} vs {}",
            on.p50,
            off.p50,
            on.p99,
            off.p99
        );
    }

    #[test]
    fn functional_replay_uses_the_device_arena() {
        use crate::compiler::{compile, CompileOptions};
        use crate::exec::{golden_forward, WeightStore};
        use crate::graph::{rmat::rmat_edges, GraphMeta, PartitionConfig, PartitionedGraph};
        use crate::ir::ZooModel;

        let meta = GraphMeta::new("t", 300, 1500, 32, 4);
        let g = rmat_edges(meta, Default::default(), 9).gcn_normalized();
        let hw = HwConfig::functional_tiles();
        let cfg = PartitionConfig { n1: hw.n1() as u64, n2: hw.n2() as u64 };
        let pg = PartitionedGraph::build(&g, cfg);
        let ir = ZooModel::B1.build(g.meta.clone());
        let exe = compile(&ir, &pg.tile_counts(), &hw, CompileOptions::default());
        let store = WeightStore::deterministic(&exe.ir, 33);
        let x = g.random_features(5);
        let input = crate::engine::EngineInput {
            graph: &g,
            partitioned: &pg,
            store: &store,
            x: &x,
        };
        let fleet = FleetConfig { n_devices: 2, ..FleetConfig::default() };
        let mut c = Coordinator::fleet(hw, fleet);
        assert!(c.functional_replay(7, &exe, &input).is_err(), "bad device id");
        let p1 = c.functional_replay(0, &exe, &input).unwrap();
        let cold_fresh = c.devices()[0].arena.stats().fresh;
        assert!(cold_fresh > 0);
        // The replayed numerics match the golden reference.
        let golden = golden_forward(&exe.ir, &g, &store, &x);
        let out = p1.output.as_ref().unwrap();
        let err = golden
            .iter()
            .zip(out)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(err < 1e-3, "replay vs golden max err {err}");
        // A second replay on the same device is served from its arena.
        let p2 = c.functional_replay(0, &exe, &input).unwrap();
        assert_eq!(p1.output, p2.output);
        let warm_fresh = c.devices()[0].arena.stats().fresh - cold_fresh;
        assert!(warm_fresh <= 1, "warm replay allocated {warm_fresh} buffers");
        // The other device's arena is untouched (per-device pools).
        assert_eq!(c.devices()[1].arena.stats().fresh, 0);
    }

    #[test]
    fn full_neighborhood_minibatch_of_everything_still_buckets() {
        // Degenerate mini-batch: every vertex targeted, full fanout —
        // the ego-net is the whole graph, and the request still routes
        // through the bucket path deterministically.
        let co = dataset("CO").unwrap();
        let all: Vec<u32> = (0..co.n_vertices as u32).collect();
        let rq = Request::minibatch(
            0,
            ZooModel::B1,
            co,
            all,
            vec![FULL_NEIGHBORHOOD],
            1,
            0.0,
        );
        let mut c = Coordinator::new(HwConfig::alveo_u250());
        let stats = c.run(vec![rq]);
        assert_eq!(stats.minibatched, 1);
        assert_eq!(stats.sampled_vertices, co.n_vertices);
        // The serving sampler works over the GCN-normalized graph, so
        // every vertex's self-loop edge is part of the neighborhood.
        assert_eq!(stats.sampled_edges, co.n_edges + co.n_vertices);
        assert_eq!(stats.bucket_hits, 0);
    }

    #[test]
    fn empty_workload() {
        let mut c = Coordinator::new(HwConfig::alveo_u250());
        let stats = c.run(vec![]);
        assert_eq!(stats.completed, 0);
        assert_eq!(stats, ServeStats::default());
    }
}
