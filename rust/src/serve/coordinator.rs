//! The serving request loop: tenants submit inference requests — whole
//! graphs or mini-batch ego-networks — and the coordinator routes each
//! across a fleet of N overlay devices ([`super::device::Device`]) via
//! the policy in [`super::dispatcher::Dispatcher`] — coalesce identical
//! in-flight work, micro-batch compatible mini-batches, else prefer a
//! cache-warm device — and accounts every latency on the deterministic
//! virtual clock ([`super::clock`]).
//!
//! Compile stalls are charged from the modeled
//! [`crate::compiler::CompileReport::total`], sampling stalls from
//! [`super::clock::sample_cost`], execution from the cycle simulator
//! (one overlay design ⇒ one exec time per program, memoized
//! fleet-wide). Nothing reads wall-clock time, so a replayed workload
//! produces bit-identical [`ServeStats`].
//!
//! Mini-batch requests ([`Target::MiniBatch`]) sample a k-hop ego-net
//! from the dataset (deterministic in the request seed), round its
//! shape up to a power-of-two bucket
//! ([`crate::compiler::BucketShape`]), and execute the bucket's cached
//! program — so per-request cost is proportional to the sampled
//! neighborhood, and thousands of distinct ego-nets share a handful of
//! compiled programs.

use super::cache::Key;
use super::clock::{CostModel, VirtualClock};
use super::device::{Device, FaultWindow, Quote};
use super::dispatcher::{Dispatcher, Route};
use super::fault::{
    DecisionRecord, Degradation, FaultEvent, FaultPlan, FaultRecord, Outcome, ShedReason,
    DEGRADED_FANOUT_CAP,
};
use super::qos::{PriorityClass, QosState, TenantConfig, TenantStats};
use crate::compiler::{BucketShape, Executable};
use crate::config::HwConfig;
use crate::engine::{EngineInput, ExecProfile};
use crate::exec::{CountingBackend, FunctionalExecutor, RustBackend};
use crate::graph::{Dataset, GraphMeta, PartitionConfig, Sampler, TileCounts};
use crate::ir::ZooModel;
use crate::isa::Program;
use crate::obs::{self, LayerSlice, ObsJob, ObsState, Span};
use crate::quant::Precision;
use crate::sim::{simulate, simulate_dynamic};
use crate::stream::{ChurnGenerator, ChurnSpec, DynamicGraph};
use crate::util::timed;
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::sync::Arc;

/// What a request asks to run over.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Target {
    /// Inference over the whole dataset graph (the original request
    /// class).
    FullGraph,
    /// Inference over the k-hop ego-network of `targets`
    /// (`k = fanout.len()`; [`crate::graph::FULL_NEIGHBORHOOD`] per hop
    /// keeps every in-neighbor). Sampling is deterministic in `seed`.
    MiniBatch {
        targets: Vec<u32>,
        fanout: Vec<u32>,
        seed: u64,
    },
    /// A streaming graph-update batch: `inserts` R-MAT-skewed edge
    /// inserts, `deletes` live-edge delete attempts, and `grow` vertex
    /// additions, synthesized deterministically in `seed` by
    /// [`crate::stream::ChurnGenerator`] against the dataset's dynamic
    /// graph. Applying it seals a new epoch: whole-graph programs of
    /// older epochs are selectively invalidated, bucket programs
    /// survive untouched, and later requests read the new epoch.
    Update {
        inserts: u32,
        deletes: u32,
        grow: u32,
        seed: u64,
    },
}

impl Target {
    /// True for [`Target::MiniBatch`].
    pub fn is_minibatch(&self) -> bool {
        matches!(self, Target::MiniBatch { .. })
    }

    /// True for [`Target::Update`].
    pub fn is_update(&self) -> bool {
        matches!(self, Target::Update { .. })
    }
}

/// One inference request. `PartialEq` so a trace decoded from disk is
/// testable against the workload that produced it.
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    /// Submitting tenant (a QoS policy row under an installed
    /// [`TenantConfig`]; an opaque label otherwise).
    pub tenant: u32,
    /// Model to run.
    pub model: ZooModel,
    /// Input graph.
    pub dataset: Dataset,
    /// What to run it over (see [`Target`]).
    pub target: Target,
    /// Arrival time on the serving clock (seconds).
    pub arrival: f64,
    /// Execution precision ([`Precision::F32`] unless the tenant opts
    /// into the quantized datapath). Precision is part of the program
    /// key, so f32 and int8 tenants never share a compiled artifact.
    pub precision: Precision,
}

impl Request {
    /// A whole-graph request (the pre-mini-batch request shape).
    pub fn full(tenant: u32, model: ZooModel, dataset: Dataset, arrival: f64) -> Request {
        Request {
            tenant,
            model,
            dataset,
            target: Target::FullGraph,
            arrival,
            precision: Precision::F32,
        }
    }

    /// The same request served on an explicit precision.
    pub fn with_precision(mut self, precision: Precision) -> Request {
        self.precision = precision;
        self
    }

    /// A mini-batch request over `targets` with per-hop `fanout`.
    pub fn minibatch(
        tenant: u32,
        model: ZooModel,
        dataset: Dataset,
        targets: Vec<u32>,
        fanout: Vec<u32>,
        seed: u64,
        arrival: f64,
    ) -> Request {
        Request {
            tenant,
            model,
            dataset,
            target: Target::MiniBatch { targets, fanout, seed },
            arrival,
            precision: Precision::F32,
        }
    }

    /// A streaming graph-update request (`model` is irrelevant for
    /// updates and fixed to a placeholder).
    pub fn update(
        tenant: u32,
        dataset: Dataset,
        inserts: u32,
        deletes: u32,
        grow: u32,
        seed: u64,
        arrival: f64,
    ) -> Request {
        Request {
            tenant,
            model: ZooModel::B1,
            dataset,
            target: Target::Update { inserts, deletes, grow, seed },
            arrival,
            precision: Precision::F32,
        }
    }
}

/// Completion record.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Response {
    /// Tenant the request came from.
    pub tenant: u32,
    /// Model served.
    pub model: ZooModel,
    /// Device that executed (or will execute) the work.
    pub device: u32,
    /// Compile stall paid by this request (0 on a warm hit).
    pub t_compile: f64,
    /// Host-side sampling stall (0 for whole-graph requests).
    pub t_sample: f64,
    /// Simulated accelerator execution time (for a mini-batch creator
    /// this includes the fixed visit overhead; riders report their item
    /// time only).
    pub t_exec: f64,
    /// Queueing delay between program-ready and device-free.
    pub t_queue: f64,
    /// arrival -> completion.
    pub latency: f64,
    /// Whether the program came from the serving device's cache.
    pub cache_hit: bool,
    /// Rode an identical in-flight job (no extra device work).
    pub coalesced: bool,
    /// Mini-batch request micro-batched onto an existing device visit.
    pub batched: bool,
    /// Whether this was a mini-batch request.
    pub minibatch: bool,
    /// Ego-net vertices sampled for this request (0 for whole-graph).
    pub sampled_vertices: u64,
    /// Ego-net edges sampled for this request (0 for whole-graph).
    pub sampled_edges: u64,
    /// Density-driven kernel re-maps in the execution serving this
    /// request (riders report the re-maps of the job they rode).
    pub remaps: u64,
    /// Precision the request was served at.
    pub precision: Precision,
    /// Modeled quantized tile launches in the execution serving this
    /// request (0 for f32; riders echo their job's count).
    pub quant_visits: u64,
    /// Modeled quantize/requantize epilogues in the execution.
    pub requant_ops: u64,
    /// Modeled 1-byte operand bytes moved by the execution.
    pub int8_bytes: u64,
    /// Whether this was a streaming update request (host-side: no
    /// device work; `device` is a sentinel).
    pub update: bool,
    /// Graph epoch this response was served at (the epoch an update
    /// sealed; 0 for never-streamed datasets).
    pub epoch: u32,
    /// Modeled host-side apply cost of an update (0 otherwise).
    pub t_update: f64,
    /// Dirty subshards the update rebuilt (0 otherwise).
    pub dirty_subshards: u32,
    /// Edges re-sorted rebuilding dirty subshards (0 otherwise).
    pub rebuilt_edges: u64,
    /// Stale whole-graph programs invalidated fleet-wide by this
    /// update (0 otherwise).
    pub invalidated: u32,
    /// Whether this update triggered an overlay compaction.
    pub compacted: bool,
    /// Crashed attempts retried (0 on the fault-free path).
    pub retries: u32,
    /// Whether the serving device differs from the first device routed
    /// to — a retry landed the work somewhere else.
    pub rerouted: bool,
    /// Total exponential-backoff pause charged to this request across
    /// its retries (seconds on the virtual clock).
    pub t_backoff: f64,
    /// QoS pacing delay charged by the fair queue (deadline-capped;
    /// 0 without an installed tenant config, for premium traffic, and
    /// for tenants inside their reserved rate).
    pub t_qos: f64,
    /// Whether this request finished past its tenant deadline (served
    /// late, or shed with
    /// [`ShedReason::DeadlineMissed`]). Always false without a tenant
    /// config or for tenants without a deadline.
    pub deadline_missed: bool,
    /// Terminal state: completed at full fidelity, degraded down the
    /// fidelity cascade, or shed with a named reason. Always
    /// `Completed` on the fault-free path.
    pub outcome: Outcome,
}

impl Response {
    /// Field-by-field comparison naming every diverging field, so a
    /// `replay --verify` failure reports `t_exec: 1e-4 != 2e-4` instead
    /// of dumping two structs. Float fields compare by raw bits — the
    /// replay guarantee is *bit*-identity, not approximate equality.
    pub fn diff(&self, other: &Response) -> Vec<String> {
        let mut out = Vec::new();
        macro_rules! cmp {
            ($($f:ident),+ $(,)?) => {$(
                if self.$f != other.$f {
                    out.push(format!(
                        concat!(stringify!($f), ": {:?} != {:?}"),
                        self.$f, other.$f
                    ));
                }
            )+};
        }
        macro_rules! cmp_f64 {
            ($($f:ident),+ $(,)?) => {$(
                if self.$f.to_bits() != other.$f.to_bits() {
                    out.push(format!(
                        concat!(stringify!($f), ": {:?} != {:?}"),
                        self.$f, other.$f
                    ));
                }
            )+};
        }
        cmp!(
            tenant, model, device, cache_hit, coalesced, batched, minibatch,
            sampled_vertices, sampled_edges, remaps, precision, quant_visits,
            requant_ops, int8_bytes, update, epoch, dirty_subshards,
            rebuilt_edges, invalidated, compacted, retries, rerouted,
            deadline_missed, outcome,
        );
        cmp_f64!(t_compile, t_sample, t_exec, t_queue, latency, t_update, t_backoff, t_qos);
        out
    }
}

/// Aggregate statistics. `PartialEq` so replay determinism is testable
/// as plain equality.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServeStats {
    /// Requests that reached a served outcome (completed or degraded).
    pub completed: u64,
    /// Responses whose program came from a device cache.
    pub cache_hits: u64,
    /// Requests that rode an identical in-flight job.
    pub coalesced: u64,
    /// Completed mini-batch requests.
    pub minibatched: u64,
    /// Mini-batch requests that micro-batched onto an existing visit.
    pub batched: u64,
    /// Mini-batch requests whose bucket program was already compiled
    /// on the serving device (riders count: they never compile).
    pub bucket_hits: u64,
    /// Ego-net vertices sampled across all mini-batch requests.
    pub sampled_vertices: u64,
    /// Ego-net edges sampled across all mini-batch requests.
    pub sampled_edges: u64,
    /// Kernel re-maps summed over *executed* jobs (coalesced riders are
    /// excluded so one execution is not counted once per rider).
    pub remaps: u64,
    /// Completed inference requests served on the int8 datapath.
    pub quantized: u64,
    /// Quantized tile launches summed over executed jobs (riders
    /// excluded, like `remaps`).
    pub quant_visits: u64,
    /// Quantize/requantize epilogues summed over executed jobs.
    pub requant_ops: u64,
    /// Modeled 1-byte operand traffic summed over executed jobs.
    pub int8_bytes: u64,
    /// Streaming update requests applied.
    pub updates: u64,
    /// Highest graph epoch reached by any streamed dataset.
    pub max_epoch: u32,
    /// Dirty subshards rebuilt across all updates.
    pub dirty_subshards: u64,
    /// Edges re-sorted rebuilding dirty subshards across all updates.
    pub rebuilt_edges: u64,
    /// Stale whole-graph programs invalidated across all updates.
    pub invalidated: u64,
    /// Overlay compactions triggered across all updates.
    pub compactions: u64,
    /// Median served-inference latency, seconds.
    pub p50: f64,
    /// 99th-percentile served-inference latency, seconds.
    pub p99: f64,
    /// Mean served-inference latency, seconds.
    pub mean: f64,
    /// p50 over mini-batch responses only (0 when there are none).
    pub p50_mini: f64,
    /// p50 over whole-graph responses only (0 when there are none).
    pub p50_full: f64,
    /// Sum of execution seconds across devices.
    pub device_busy: f64,
    /// Virtual time of the last processed event.
    pub makespan: f64,
    /// Crashed attempts retried, summed over all requests.
    pub retries: u64,
    /// Requests whose serving device differs from their first route.
    pub rerouted: u64,
    /// Requests that completed down the fidelity cascade.
    pub degraded: u64,
    /// Requests shed with a named [`ShedReason`].
    pub shed: u64,
    /// Device-crash events fired from the fault plan.
    pub crashes: u64,
    /// Transient-stall events fired from the fault plan.
    pub stalls: u64,
    /// Armed artifact corruptions that bit (evicted a cached program).
    pub corruptions: u64,
    /// Scheduled device downtime summed over fired finite crashes
    /// (seconds; permanent crashes contribute nothing finite).
    pub downtime: f64,
    /// Backoff pause charged across all retried requests (seconds).
    pub t_backoff: f64,
    /// Per-tenant counter rows, sorted by tenant id — populated only
    /// under an installed [`TenantConfig`] (empty otherwise, so
    /// tenant-free stats stay byte-identical on the wire).
    pub tenants: Vec<TenantStats>,
}

impl ServeStats {
    /// Field-by-field comparison naming every diverging counter — the
    /// `replay --verify` failure story: instead of two dumped structs,
    /// each divergence reads `cache_hits: 54 != 53`. Counters compare
    /// exactly; latency/percentile fields compare by raw f64 bits (the
    /// replay guarantee is bit-identity). Returns an empty vec when the
    /// stats agree.
    pub fn diff(&self, other: &ServeStats) -> Vec<String> {
        let mut out = Vec::new();
        macro_rules! cmp {
            ($($f:ident),+ $(,)?) => {$(
                if self.$f != other.$f {
                    out.push(format!(
                        concat!(stringify!($f), ": {} != {}"),
                        self.$f, other.$f
                    ));
                }
            )+};
        }
        macro_rules! cmp_f64 {
            ($($f:ident),+ $(,)?) => {$(
                if self.$f.to_bits() != other.$f.to_bits() {
                    out.push(format!(
                        concat!(stringify!($f), ": {} != {}"),
                        self.$f, other.$f
                    ));
                }
            )+};
        }
        // Throughput / cache family.
        cmp!(completed, cache_hits, coalesced);
        // Mini-batch family.
        cmp!(minibatched, batched, bucket_hits, sampled_vertices, sampled_edges);
        // Kernel re-map + quantized datapath family.
        cmp!(remaps, quantized, quant_visits, requant_ops, int8_bytes);
        // Streaming-update family.
        cmp!(updates, max_epoch, dirty_subshards, rebuilt_edges, invalidated, compactions);
        // Fault / degradation family.
        cmp!(retries, rerouted, degraded, shed, crashes, stalls, corruptions);
        // Latency family (bit-exact).
        cmp_f64!(p50, p99, mean, p50_mini, p50_full, device_busy, makespan);
        cmp_f64!(downtime, t_backoff);
        // Per-tenant QoS family: a length mismatch is one divergence;
        // matched rows name the exact field, `tenants[i].p99: ...`.
        if self.tenants.len() != other.tenants.len() {
            out.push(format!(
                "tenants.len: {} != {}",
                self.tenants.len(),
                other.tenants.len()
            ));
        } else {
            for (i, (a, b)) in self.tenants.iter().zip(&other.tenants).enumerate() {
                macro_rules! tcmp {
                    ($($f:ident),+ $(,)?) => {$(
                        if a.$f != b.$f {
                            out.push(format!(
                                concat!("tenants[{}].", stringify!($f), ": {} != {}"),
                                i, a.$f, b.$f
                            ));
                        }
                    )+};
                }
                macro_rules! tcmp_f64 {
                    ($($f:ident),+ $(,)?) => {$(
                        if a.$f.to_bits() != b.$f.to_bits() {
                            out.push(format!(
                                concat!("tenants[{}].", stringify!($f), ": {} != {}"),
                                i, a.$f, b.$f
                            ));
                        }
                    )+};
                }
                tcmp!(tenant, completed, degraded, shed, missed);
                tcmp_f64!(weight, p50, p99, t_qos, busy);
            }
        }
        out
    }
}

/// Fleet shape and routing policy. `PartialEq` so a recorded trace's
/// config round-trip is testable as plain equality.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FleetConfig {
    /// Number of identical overlay devices.
    pub n_devices: usize,
    /// Prefer a cache-warm device when routing.
    pub affinity: bool,
    /// Coalesce identical in-flight whole-graph requests.
    pub coalesce: bool,
    /// Micro-batch compatible mini-batch requests into one device
    /// visit.
    pub microbatch: bool,
    /// Serve with density-aware dynamic kernel re-mapping (execution
    /// time and re-map counts from [`crate::sim::simulate_dynamic`],
    /// which is never slower than the static mapping).
    pub dynamic: bool,
    /// Host-side cost coefficients (sampling, visit overhead, update
    /// apply) — promoted from hard-coded `clock` constants so benches
    /// can sweep them; defaults are the original values.
    pub costs: CostModel,
}

impl Default for FleetConfig {
    fn default() -> FleetConfig {
        FleetConfig {
            n_devices: 1,
            affinity: true,
            coalesce: true,
            microbatch: true,
            dynamic: true,
            costs: CostModel::default(),
        }
    }
}

/// Nearest-rank percentile of an ascending-sorted slice: the smallest
/// value with at least `ceil(p * n)` observations ≤ it.
///
/// An empty sample has no observations, so every percentile of it is
/// reported as 0 (the serving stats' "no data" value) rather than
/// panicking — update-only workloads produce empty latency classes.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (p * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// p50 of an unsorted latency class, 0 when the class is empty.
fn class_p50(mut lats: Vec<f64>) -> f64 {
    if lats.is_empty() {
        return 0.0;
    }
    lats.sort_by(f64::total_cmp);
    percentile(&lats, 0.50)
}

/// Modeled execution cost of one program key: seconds plus the
/// simulator's per-run counters (re-maps, quantized datapath work). A
/// quantized program simulates on the widened int8 ack automatically —
/// the compiled program carries its scale table — so the memo needs no
/// precision-specific logic beyond the key.
#[derive(Clone, Debug, Default)]
struct ExecCost {
    secs: f64,
    remaps: u64,
    quant_blocks: u64,
    requant_ops: u64,
    int8_bytes: u64,
    /// Per-layer cycle split of the simulated program, captured once
    /// per key for the span tracer's kernel-level breakdown (shared
    /// via `Arc`: the memo clones are pointer copies; unread when
    /// tracing is off).
    layers: Arc<[LayerSlice]>,
}

/// Fleet-wide modeled execution memo: [`ExecCost`] per program key,
/// simulated on first use. One helper for both request classes so the
/// memoization policy cannot drift between them. Borrows only the memo
/// and hardware config, so callers can hold a device mutably at the
/// same time.
fn memo_exec<'a>(
    memo: &'a mut HashMap<Key, ExecCost>,
    hw: &'a HwConfig,
    dynamic: bool,
    key: Key,
) -> impl FnMut(&Executable) -> f64 + 'a {
    move |exe: &Executable| {
        memo.entry(key)
            .or_insert_with(|| {
                let sim = if dynamic {
                    simulate_dynamic(&exe.program, hw)
                } else {
                    simulate(&exe.program, hw)
                };
                let layers: Vec<LayerSlice> = sim
                    .layers
                    .iter()
                    .map(|l| LayerSlice {
                        layer_id: l.layer_id,
                        kind: l.layer_type,
                        cycles: l.cycles,
                    })
                    .collect();
                ExecCost {
                    secs: sim.loh_seconds(),
                    remaps: sim.remaps,
                    quant_blocks: sim.quant_blocks,
                    requant_ops: sim.requant_ops,
                    int8_bytes: sim.int8_bytes,
                    layers: layers.into(),
                }
            })
            .secs
    }
}

/// Per-dataset streaming state: the dynamic graph plus a lazily
/// refreshed snapshot of the current epoch's compile inputs.
struct StreamState {
    dyng: DynamicGraph,
    /// `(epoch, metadata, live tile counts)` of the last snapshot;
    /// refreshed when an update seals a newer epoch.
    snap: Option<(u32, GraphMeta, Arc<TileCounts>)>,
}

impl StreamState {
    /// Wrap the dataset's materialized, GCN-normalized graph — the
    /// same base the static mini-batch sampler uses, so epoch-0
    /// behavior is unchanged. Streaming therefore only works on
    /// materializable (< 10M edge) datasets.
    fn new(ds: &Dataset, hw: &HwConfig) -> StreamState {
        let g = ds.materialize().gcn_normalized();
        let cfg = PartitionConfig { n1: hw.n1() as u64, n2: hw.n2() as u64 };
        StreamState { dyng: DynamicGraph::new(g, cfg), snap: None }
    }

    /// The current epoch's compile snapshot (metadata + tile counts),
    /// shared fleet-wide through `Arc`.
    fn snapshot(&mut self) -> (u32, GraphMeta, Arc<TileCounts>) {
        let e = self.dyng.epoch();
        let stale = match &self.snap {
            Some((se, _, _)) => *se != e,
            None => true,
        };
        if stale {
            self.snap = Some((e, self.dyng.meta().clone(), Arc::new(self.dyng.tile_counts())));
        }
        let (e, meta, tiles) = self.snap.as_ref().unwrap();
        (*e, meta.clone(), tiles.clone())
    }
}

/// Runtime state of an installed [`FaultPlan`]: the event calendar
/// sorted by fire time, per-event fired flags (a crashed quote fires
/// its event ahead of the arrival cursor, and the cursor must not fire
/// it again), armed-but-unbitten corruptions, and the fault/decision
/// logs a recorded trace serializes.
struct FaultState {
    plan: FaultPlan,
    /// Fired flag per `plan.events` index.
    fired: Vec<bool>,
    /// Event indices sorted by (fire time, index) — the cursor order.
    order: Vec<usize>,
    /// Cursor into `order`: events before it fired via `advance_to`.
    next: usize,
    crashes: u64,
    stalls: u64,
    corruptions: u64,
    /// Scheduled downtime of fired finite crashes (seconds).
    downtime: f64,
    /// Corruptions waiting for their target artifact to be resident:
    /// `(event index, device, model, dataset key)`.
    armed: Vec<(usize, usize, ZooModel, String)>,
    fault_log: Vec<FaultRecord>,
    decisions: Vec<DecisionRecord>,
}

impl FaultState {
    fn new(plan: FaultPlan) -> FaultState {
        let mut order: Vec<usize> = (0..plan.events.len()).collect();
        order.sort_by(|&a, &b| {
            plan.events[a]
                .at()
                .total_cmp(&plan.events[b].at())
                .then(a.cmp(&b))
        });
        FaultState {
            fired: vec![false; plan.events.len()],
            order,
            next: 0,
            crashes: 0,
            stalls: 0,
            corruptions: 0,
            downtime: 0.0,
            armed: Vec::new(),
            fault_log: Vec::new(),
            decisions: Vec::new(),
            plan,
        }
    }

    /// Fire one event (idempotent: a crash already fired by the quote
    /// path is skipped when the arrival cursor reaches it). Crashes
    /// wipe the device cold; corruptions arm and bite later, on the
    /// next access to their target artifact.
    fn fire(&mut self, i: usize, devices: &mut [Device]) {
        if self.fired[i] {
            return;
        }
        self.fired[i] = true;
        let e = self.plan.events[i].clone();
        match &e {
            FaultEvent::DeviceCrash { device, at, recover_after } => {
                self.crashes += 1;
                if *recover_after > 0.0 {
                    self.downtime += recover_after;
                }
                if let Some(d) = devices.get_mut(*device as usize) {
                    d.crash_wipe(*at);
                }
            }
            FaultEvent::TransientStall { .. } => self.stalls += 1,
            FaultEvent::ArtifactCorruption { device, model, dataset, .. } => {
                self.armed
                    .push((i, *device as usize, *model, dataset.clone()));
            }
        }
        self.fault_log.push(FaultRecord { at: e.at(), fault: e });
    }

    /// Fire every not-yet-fired event scheduled at or before `now`.
    fn advance_to(&mut self, now: f64, devices: &mut [Device]) {
        while self.next < self.order.len() {
            let i = self.order[self.next];
            if self.plan.events[i].at() > now {
                break;
            }
            self.next += 1;
            self.fire(i, devices);
        }
    }
}

/// Multi-device coordinator.
pub struct Coordinator {
    devices: Vec<Device>,
    dispatcher: Dispatcher,
    clock: VirtualClock,
    /// Modeled [`ExecCost`] per program key: every device is the same
    /// overlay design, so execution is a fleet-wide property.
    exec_memo: HashMap<Key, ExecCost>,
    /// Per-dataset ego-net extractors, built on first mini-batch use
    /// (materialize + whole-graph CSR, amortized across requests).
    samplers: HashMap<&'static str, Sampler>,
    /// Per-dataset dynamic graphs, created by the first
    /// [`Target::Update`] a dataset receives. Once a dataset streams,
    /// its whole-graph compiles and mini-batch samples read the
    /// dynamic graph's current epoch.
    streams: HashMap<&'static str, StreamState>,
    hw: HwConfig,
    dynamic: bool,
    costs: CostModel,
    /// Active fault plan, if any ([`Coordinator::set_fault_plan`]).
    /// `None` — including after installing an *empty* plan — leaves
    /// every historical code path untouched: routing, coalescing,
    /// micro-batching and all response fields behave (and serialize)
    /// exactly as before faults existed.
    fault: Option<FaultState>,
    /// Active tenant QoS state, if any ([`Coordinator::set_tenants`]).
    /// `None` — including after installing an *empty* config — leaves
    /// every historical code path untouched, exactly like `fault`.
    qos: Option<QosState>,
    /// Active span tracer, if any ([`Coordinator::set_tracing`]).
    /// Same dormant pattern as `fault`/`qos`: `None` (the default)
    /// leaves every serving path, response and stat byte-identical to
    /// a tracing-free build — spans are reconstructed *from* admitted
    /// responses, never threaded through the serving paths.
    obs: Option<ObsState>,
    /// Per-admission scratch for the tracer: the executed program's
    /// layer split + compile report, stashed by the non-rider serving
    /// paths and consumed at the end of [`Coordinator::admit`]. Always
    /// `None` when `obs` is.
    obs_scratch: Option<ObsJob>,
    /// Every completion record, in admission order.
    pub responses: Vec<Response>,
}

impl Coordinator {
    /// Single-overlay coordinator (the paper's deployment).
    pub fn new(hw: HwConfig) -> Coordinator {
        Coordinator::fleet(hw, FleetConfig::default())
    }

    /// Multi-device coordinator over `cfg.n_devices` identical
    /// overlays sharing one routing policy.
    pub fn fleet(hw: HwConfig, cfg: FleetConfig) -> Coordinator {
        assert!(cfg.n_devices >= 1, "fleet needs at least one device");
        Coordinator {
            devices: (0..cfg.n_devices)
                .map(|i| {
                    let mut d = Device::new(i, hw.clone());
                    d.costs = cfg.costs;
                    d
                })
                .collect(),
            dispatcher: Dispatcher {
                affinity: cfg.affinity,
                coalesce: cfg.coalesce,
                microbatch: cfg.microbatch,
            },
            clock: VirtualClock::new(),
            exec_memo: HashMap::new(),
            samplers: HashMap::new(),
            streams: HashMap::new(),
            hw,
            dynamic: cfg.dynamic,
            costs: cfg.costs,
            fault: None,
            qos: None,
            obs: None,
            obs_scratch: None,
            responses: Vec::new(),
        }
    }

    /// Install a seeded fault plan before serving: each device gets its
    /// outage calendar (crash/stall windows) for quoting, and admission
    /// switches to the retry/re-route/degrade path. An empty plan
    /// installs nothing — the fault-free path stays byte-identical.
    pub fn set_fault_plan(&mut self, plan: FaultPlan) {
        if plan.events.is_empty() {
            self.fault = None;
            return;
        }
        assert!(
            self.qos.is_none(),
            "fault plans and tenant QoS are mutually exclusive"
        );
        for d in &mut self.devices {
            let windows: Vec<FaultWindow> = plan
                .events
                .iter()
                .enumerate()
                .filter_map(|(i, e)| match e {
                    FaultEvent::DeviceCrash { device, at, recover_after }
                        if *device as usize == d.id =>
                    {
                        let until = if *recover_after > 0.0 {
                            at + recover_after
                        } else {
                            f64::INFINITY
                        };
                        Some(FaultWindow { from: *at, until, crash: true, event: i })
                    }
                    FaultEvent::TransientStall { device, at, duration }
                        if *device as usize == d.id =>
                    {
                        Some(FaultWindow {
                            from: *at,
                            until: at + duration,
                            crash: false,
                            event: i,
                        })
                    }
                    _ => None,
                })
                .collect();
            d.set_fault_windows(windows);
        }
        self.fault = Some(FaultState::new(plan));
    }

    /// The installed fault plan (None without one — or with an empty
    /// one, which installs nothing).
    pub fn fault_plan(&self) -> Option<&FaultPlan> {
        self.fault.as_ref().map(|f| &f.plan)
    }

    /// Install a tenant QoS config before serving: admission switches
    /// to SFQ-paced, deadline-aware, gap-placed scheduling (coalescing
    /// and micro-batching are bypassed on that path — a gap-placed
    /// timeline has no single tail to ride). An empty config installs
    /// nothing — the tenant-blind path stays byte-identical. Mutually
    /// exclusive with a fault plan: the outage calendar quotes against
    /// `free_at` order, which gap placement deliberately breaks.
    pub fn set_tenants(&mut self, config: TenantConfig) {
        if config.is_empty() {
            self.qos = None;
            return;
        }
        assert!(
            self.fault.is_none(),
            "fault plans and tenant QoS are mutually exclusive"
        );
        self.qos = Some(QosState::new(config, self.devices.len()));
    }

    /// The installed tenant config (None without one — or with an
    /// empty one, which installs nothing).
    pub fn tenants(&self) -> Option<&TenantConfig> {
        self.qos.as_ref().map(|q| q.config())
    }

    /// Enable (or disable) deterministic span tracing. Off by default;
    /// the dormant path is byte-identical to a tracing-free build.
    /// With tracing on, every admitted request records a span tree
    /// (root + phase windows + compiler-pass and per-layer kernel
    /// children) built from the same modeled quantities the response
    /// bills — so the span stream is bit-identical across
    /// `GA_KERNEL_THREADS` values and across record/replay.
    pub fn set_tracing(&mut self, on: bool) {
        self.obs = if on { Some(ObsState::new()) } else { None };
        self.obs_scratch = None;
    }

    /// Spans recorded so far, in admission order (empty with tracing
    /// off).
    pub fn spans(&self) -> &[Span] {
        self.obs.as_ref().map_or(&[], |o| o.spans())
    }

    /// Chrome trace-event JSON of the recorded spans plus the fired
    /// fault log as instant events (loads in `chrome://tracing` /
    /// Perfetto).
    pub fn chrome_trace_json(&self) -> String {
        obs::chrome_trace(self.spans(), self.fault_log())
    }

    /// Log-bucketed histogram over served-inference latencies (the
    /// same population as the exact `p50`/`p99` percentiles: updates
    /// and sheds excluded).
    pub fn latency_histogram(&self) -> obs::Histogram {
        obs::Histogram::from_latencies(
            self.responses
                .iter()
                .filter(|r| !r.update && !r.outcome.is_shed())
                .map(|r| r.latency),
        )
    }

    /// Stash the executed program's tracer scratch ([`ObsJob`]) for
    /// the request being admitted. No-op when tracing is off — the
    /// report lookup and `Arc` clone are never paid on the dormant
    /// path.
    fn stash_obs(&mut self, dev: usize, key: &Key, cost: &ExecCost) {
        if self.obs.is_none() {
            return;
        }
        let report = self.devices[dev].cached(key).map(|e| e.report).unwrap_or_default();
        self.obs_scratch = Some(ObsJob { layers: cost.layers.clone(), report });
    }

    /// QoS gap backfills that started ahead of an earlier-admitted,
    /// not-yet-started visit (0 without a tenant config).
    pub fn qos_preemptions(&self) -> u64 {
        self.qos.as_ref().map_or(0, |q| q.preemptions())
    }

    /// Fault events fired so far, in fire order — what a recorded
    /// trace serializes as v2 `fault` events.
    pub fn fault_log(&self) -> &[FaultRecord] {
        self.fault.as_ref().map_or(&[], |f| f.fault_log.as_slice())
    }

    /// Degradation/shed decisions taken so far, in admission order —
    /// what a recorded trace serializes as `decision` events. Fault
    /// plans and QoS are mutually exclusive, so at most one of the two
    /// logs exists.
    pub fn decision_log(&self) -> &[DecisionRecord] {
        if let Some(f) = self.fault.as_ref() {
            return f.decisions.as_slice();
        }
        self.qos.as_ref().map_or(&[], |q| q.decisions())
    }

    /// Number of devices in the fleet.
    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    /// The fleet's devices, in id order.
    pub fn devices(&self) -> &[Device] {
        &self.devices
    }

    /// Virtual time of the last processed event.
    pub fn now(&self) -> f64 {
        self.clock.now()
    }

    /// Fleet-wide cache hit rate over processed *inference* responses
    /// (coalesced and batched responses count as hits: they never
    /// touched a compiler; update requests are not inference and are
    /// excluded).
    pub fn hit_rate(&self) -> f64 {
        let served = self
            .responses
            .iter()
            .filter(|r| !r.update && !r.outcome.is_shed())
            .count();
        if served == 0 {
            return 0.0;
        }
        self.responses
            .iter()
            .filter(|r| !r.update && !r.outcome.is_shed() && r.cache_hit)
            .count() as f64
            / served as f64
    }

    /// Current graph epoch of a dataset (0 until it receives updates).
    pub fn epoch_of(&self, ds_key: &str) -> u32 {
        self.streams.get(ds_key).map_or(0, |s| s.dyng.epoch())
    }

    /// Process a workload: arrival events in deterministic order (time,
    /// then tenant/model/graph/target for simultaneous arrivals), each
    /// routed by the dispatcher, scheduled on a device timeline, and
    /// accounted on the virtual clock.
    pub fn run(&mut self, mut requests: Vec<Request>) -> ServeStats {
        requests.sort_by(|a, b| {
            a.arrival
                .total_cmp(&b.arrival)
                .then(a.tenant.cmp(&b.tenant))
                .then(a.model.key().cmp(b.model.key()))
                .then(a.dataset.key.cmp(b.dataset.key))
                .then(a.target.cmp(&b.target))
                .then(a.precision.cmp(&b.precision))
        });
        for rq in requests {
            self.admit(rq);
        }
        self.stats()
    }

    /// Admit one request at its (already-stamped) arrival time: route it
    /// by the dispatcher, schedule it on a device timeline, account it
    /// on the virtual clock, and return its completion record. This is
    /// the daemon's ingestion point — a live server stamps real arrival
    /// times onto the virtual clock and feeds requests here one at a
    /// time, so the coordinator core stays bit-deterministic and a
    /// recorded trace replays through the identical code path.
    ///
    /// Requests must be admitted in nondecreasing arrival order
    /// ([`Coordinator::run`] sorts a whole workload first; the daemon
    /// stamps monotone arrivals at admission) — the per-device pending
    /// cursor ([`Device::retire_started`]) only moves forward.
    pub fn admit(&mut self, rq: Request) -> Response {
        self.clock.advance_to(rq.arrival);
        for d in &mut self.devices {
            d.retire_started(rq.arrival);
        }
        let resp = if self.fault.is_some() {
            self.admit_faulty(&rq)
        } else if self.qos.is_some() {
            self.admit_qos(&rq)
        } else {
            match &rq.target {
                Target::FullGraph => self.serve_full(&rq),
                Target::MiniBatch { targets, fanout, seed } => {
                    self.serve_minibatch(&rq, targets, fanout, *seed)
                }
                Target::Update { inserts, deletes, grow, seed } => {
                    self.serve_update(&rq, *inserts, *deletes, *grow, *seed)
                }
            }
        };
        self.clock.advance_to(rq.arrival + resp.latency);
        // Per-request accounting invariant: the union of the phase
        // windows reconstructed from the response's public fields must
        // cover its latency exactly (every serving path bills every
        // second it charges). Debug builds check it on every admission,
        // tracing on or off.
        debug_assert!(
            obs::accounting_gap(rq.arrival, &resp) <= obs::ACCOUNTING_TOL_S,
            "phase accounting drift: gap {} s on {:?}",
            obs::accounting_gap(rq.arrival, &resp),
            resp
        );
        let job = self.obs_scratch.take();
        if let Some(o) = self.obs.as_mut() {
            o.record(&rq, &resp, job.as_ref(), self.costs.visit_overhead_s);
        }
        self.responses.push(resp);
        resp
    }

    /// The inference-free baseline all non-update Response literals
    /// start from.
    fn base_response(rq: &Request, epoch: u32) -> Response {
        Response {
            tenant: rq.tenant,
            model: rq.model,
            device: 0,
            t_compile: 0.0,
            t_sample: 0.0,
            t_exec: 0.0,
            t_queue: 0.0,
            latency: 0.0,
            cache_hit: false,
            coalesced: false,
            batched: false,
            minibatch: false,
            sampled_vertices: 0,
            sampled_edges: 0,
            remaps: 0,
            precision: rq.precision,
            quant_visits: 0,
            requant_ops: 0,
            int8_bytes: 0,
            update: false,
            epoch,
            t_update: 0.0,
            dirty_subshards: 0,
            rebuilt_edges: 0,
            invalidated: 0,
            compacted: false,
            retries: 0,
            rerouted: false,
            t_backoff: 0.0,
            t_qos: 0.0,
            deadline_missed: false,
            outcome: Outcome::Completed,
        }
    }

    fn serve_full(&mut self, rq: &Request) -> Response {
        // A streamed dataset serves its current epoch: the key is
        // epoch-versioned and cache misses compile against the dynamic
        // graph's live snapshot. Note the snapshot's base is the
        // GCN-normalized graph (matching the mini-batch sampler), so
        // the epoch-0 -> 1 boundary includes a one-time +|V| self-loop
        // step in the modeled edge count on top of the churn
        // (DESIGN.md Sec. 3e).
        let snapshot = self.streams.get_mut(rq.dataset.key).map(|st| st.snapshot());
        let epoch = snapshot.as_ref().map_or(0, |s| s.0);
        let key = Key::Whole(rq.model, rq.dataset.key, epoch, rq.precision);
        let route = self.dispatcher.route(&self.devices, &key, rq.arrival);
        match route {
            Route::Coalesce(dev, j) => {
                let cost = self.exec_memo.get(&key).cloned().unwrap_or_default();
                let job = &mut self.devices[dev].jobs[j];
                job.riders += 1;
                Response {
                    device: dev as u32,
                    t_exec: job.t_exec,
                    t_queue: (job.start - rq.arrival).max(0.0),
                    latency: job.done - rq.arrival,
                    cache_hit: true,
                    coalesced: true,
                    remaps: cost.remaps,
                    quant_visits: cost.quant_blocks,
                    requant_ops: cost.requant_ops,
                    int8_bytes: cost.int8_bytes,
                    ..Self::base_response(rq, epoch)
                }
            }
            Route::Device(dev) => {
                // Inner scope: the memoizing closure's &mut borrow of
                // exec_memo must end before the memo is read below.
                let job = {
                    let mut exec_seconds =
                        memo_exec(&mut self.exec_memo, &self.hw, self.dynamic, key);
                    let device = &mut self.devices[dev];
                    let snap_ref = snapshot.as_ref().map(|(_, m, t)| (m, t));
                    let (_exe, j) = device.admit_at(
                        rq.arrival,
                        rq.model,
                        &rq.dataset,
                        epoch,
                        snap_ref,
                        rq.precision,
                        &mut exec_seconds,
                    );
                    device.jobs[j]
                };
                let cost = self.exec_memo.get(&key).cloned().unwrap_or_default();
                self.stash_obs(dev, &key, &cost);
                Response {
                    device: dev as u32,
                    t_compile: job.ready - rq.arrival,
                    t_exec: job.t_exec,
                    t_queue: job.start - job.ready,
                    latency: job.done - rq.arrival,
                    cache_hit: job.cache_hit,
                    remaps: cost.remaps,
                    quant_visits: cost.quant_blocks,
                    requant_ops: cost.requant_ops,
                    int8_bytes: cost.int8_bytes,
                    ..Self::base_response(rq, epoch)
                }
            }
            Route::Batch(..) => unreachable!("whole-graph requests never micro-batch"),
        }
    }

    fn serve_minibatch(
        &mut self,
        rq: &Request,
        targets: &[u32],
        fanout: &[u32],
        seed: u64,
    ) -> Response {
        // A streamed dataset samples through the dynamic graph's
        // CSR + overlay merge at the current epoch; otherwise the
        // static per-dataset sampler. Both are GCN-normalized at the
        // base like the functional paths (MiniBatchRunner, golden
        // tests), so modeled sample sizes and bucket shapes stay
        // cross-checkable against a functional replay of the same
        // trace — and at epoch 0 the two paths sample identically.
        let (ego, epoch) = if let Some(st) = self.streams.get(rq.dataset.key) {
            (st.dyng.sample(targets, fanout, seed), st.dyng.epoch())
        } else {
            let sampler = self
                .samplers
                .entry(rq.dataset.key)
                .or_insert_with(|| Sampler::new(rq.dataset.materialize().gcn_normalized()));
            (sampler.sample(targets, fanout, seed), 0)
        };
        let shape = BucketShape::for_graph(&ego.graph.meta);
        let (sampled_v, sampled_e) = (ego.n() as u64, ego.m() as u64);
        let t_sample = self.costs.sample_cost(sampled_v, sampled_e);
        let key = Key::Bucket(rq.model, shape, rq.precision);
        // A visit can only be ridden once the rider's ego-net exists:
        // route against the post-sampling ready time, not the arrival.
        let ready = rq.arrival + t_sample;
        let route = self.dispatcher.route_minibatch(&self.devices, &key, ready);
        match route {
            Route::Batch(dev, j) => {
                // The tail visit's bucket program is compiled (or
                // compiling) on this device, so its exec time is
                // already memoized.
                let cost = self
                    .exec_memo
                    .get(&key)
                    .expect("batched onto a visit whose exec time is memoized")
                    .clone();
                let device = &mut self.devices[dev];
                device.extend_batch(j, cost.secs);
                let job = device.jobs[j];
                Response {
                    device: dev as u32,
                    t_sample,
                    t_exec: cost.secs,
                    t_queue: (job.start - ready).max(0.0),
                    latency: job.done - rq.arrival,
                    cache_hit: true,
                    batched: true,
                    minibatch: true,
                    sampled_vertices: sampled_v,
                    sampled_edges: sampled_e,
                    remaps: cost.remaps,
                    quant_visits: cost.quant_blocks,
                    requant_ops: cost.requant_ops,
                    int8_bytes: cost.int8_bytes,
                    ..Self::base_response(rq, epoch)
                }
            }
            Route::Device(dev) => {
                // Inner scope: the memoizing closure's &mut borrow of
                // exec_memo must end before the memo is read below.
                let job = {
                    let mut exec_seconds =
                        memo_exec(&mut self.exec_memo, &self.hw, self.dynamic, key);
                    let device = &mut self.devices[dev];
                    let (_exe, j) = device.admit_minibatch(
                        rq.arrival,
                        rq.model,
                        shape,
                        t_sample,
                        rq.precision,
                        &mut exec_seconds,
                    );
                    device.jobs[j]
                };
                let cost = self.exec_memo.get(&key).cloned().unwrap_or_default();
                self.stash_obs(dev, &key, &cost);
                Response {
                    device: dev as u32,
                    t_compile: (job.ready - rq.arrival - t_sample).max(0.0),
                    t_sample,
                    t_exec: job.t_exec,
                    t_queue: job.start - job.ready,
                    latency: job.done - rq.arrival,
                    cache_hit: job.cache_hit,
                    minibatch: true,
                    sampled_vertices: sampled_v,
                    sampled_edges: sampled_e,
                    remaps: cost.remaps,
                    quant_visits: cost.quant_blocks,
                    requant_ops: cost.requant_ops,
                    int8_bytes: cost.int8_bytes,
                    ..Self::base_response(rq, epoch)
                }
            }
            Route::Coalesce(..) => {
                unreachable!("mini-batch requests micro-batch, never coalesce")
            }
        }
    }

    /// [`Coordinator::admit`] under an active fault plan: fire every
    /// event scheduled at or before this arrival, then serve through
    /// the retry/re-route/degrade path. Updates are host-side work and
    /// take their normal path — device faults cannot touch them.
    fn admit_faulty(&mut self, rq: &Request) -> Response {
        {
            let f = self.fault.as_mut().expect("admit_faulty requires fault state");
            f.advance_to(rq.arrival, &mut self.devices);
        }
        match &rq.target {
            Target::FullGraph => self.serve_full_faulty(rq),
            Target::MiniBatch { targets, fanout, seed } => {
                self.serve_minibatch_faulty(rq, targets, fanout, *seed)
            }
            Target::Update { inserts, deletes, grow, seed } => {
                self.serve_update(rq, *inserts, *deletes, *grow, *seed)
            }
        }
    }

    /// [`Coordinator::admit`] under an installed tenant config: pace
    /// non-premium traffic with the SFQ fair queue, place eligible work
    /// into per-device idle gaps, and walk over-deadline requests down
    /// the fidelity cascade. Updates are host-side and tenant-blind —
    /// they take their normal path.
    fn admit_qos(&mut self, rq: &Request) -> Response {
        match &rq.target {
            Target::FullGraph => self.serve_full_qos(rq),
            Target::MiniBatch { targets, fanout, seed } => {
                self.serve_minibatch_qos(rq, targets, fanout, *seed)
            }
            Target::Update { inserts, deletes, grow, seed } => {
                self.serve_update(rq, *inserts, *deletes, *grow, *seed)
            }
        }
    }

    /// Device pick for the QoS path: a cache-warm device first
    /// (affinity), else the device whose busy timeline offers the
    /// earliest gap for the estimated cost, ties to the lowest id. No
    /// coalescing or micro-batching — a gap-placed timeline has no
    /// single tail job to ride, and a rider on a preempted-past visit
    /// would inherit a start its own class never earned.
    fn qos_route(&self, key: &Key, ready: f64, est: f64) -> usize {
        let q = self.qos.as_ref().expect("QoS routing requires qos state");
        let pick = |warm_only: bool| -> Option<usize> {
            self.devices
                .iter()
                .filter(|d| !warm_only || d.is_warm(key))
                .map(|d| (q.earliest_start(d.id, ready, est), d.id))
                .min_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)))
                .map(|(_, id)| id)
        };
        if self.dispatcher.affinity {
            if let Some(dev) = pick(true) {
                return dev;
            }
        }
        pick(false).expect("fleet has at least one device")
    }

    /// Whole-graph serving under QoS: charge the SFQ pacing delay once
    /// (at the requested fidelity's cost), cap it so pacing alone never
    /// forfeits the deadline, gap-place the visit, and if the placement
    /// still lands past the deadline walk the cascade — int8 first,
    /// then (best-effort only) shed with
    /// [`ShedReason::DeadlineMissed`]. Standard and premium traffic is
    /// never shed: a hopeless deadline serves late with
    /// `deadline_missed` set.
    fn serve_full_qos(&mut self, rq: &Request) -> Response {
        let snapshot = self.streams.get_mut(rq.dataset.key).map(|st| st.snapshot());
        let epoch = snapshot.as_ref().map_or(0, |s| s.0);
        let tenant = self
            .qos
            .as_ref()
            .expect("QoS serving requires qos state")
            .tenant(rq.tenant);
        let deadline = tenant.deadline_s.map(|d| rq.arrival + d);
        let mut precision = rq.precision;
        // Raw pacing delay, charged exactly once: the cascade re-places
        // the visit but never re-bills the fair queue.
        let mut paced: Option<f64> = None;
        loop {
            let key = Key::Whole(rq.model, rq.dataset.key, epoch, precision);
            let est = self.exec_memo.get(&key).map_or(0.0, |c| c.secs);
            let dev = self.qos_route(&key, rq.arrival, est);
            let snap_ref = snapshot.as_ref().map(|(_, m, t)| (m, t));
            let (exe, ready, hit) = self.devices[dev].prepare(
                rq.arrival,
                rq.model,
                &rq.dataset,
                epoch,
                snap_ref,
                precision,
            );
            let t_exec = {
                let mut exec_seconds =
                    memo_exec(&mut self.exec_memo, &self.hw, self.dynamic, key);
                exec_seconds(&exe)
            };
            let delay = *paced.get_or_insert_with(|| {
                self.qos
                    .as_mut()
                    .expect("QoS serving requires qos state")
                    .pacing_delay(&tenant, rq.arrival, t_exec)
            });
            // Deadline-capped eligibility: pacing alone never pushes a
            // request past the last instant it could still finish in
            // time (the device may — that is what the cascade is for).
            let mut eligible = rq.arrival + delay;
            if let Some(d) = deadline {
                eligible = eligible.min((d - t_exec).max(rq.arrival));
            }
            let t_qos = eligible - rq.arrival;
            let job_ready = ready.max(eligible);
            let start = self
                .qos
                .as_ref()
                .expect("QoS serving requires qos state")
                .earliest_start(dev, job_ready, t_exec);
            let done = start + t_exec;
            let missed = deadline.is_some_and(|d| done > d);
            if missed && precision == Precision::F32 {
                // Fidelity cascade, rung one: the int8 twin compiles
                // smaller and executes faster (GA03).
                precision = Precision::Int8;
                continue;
            }
            if missed && tenant.class == PriorityClass::BestEffort {
                let mut r =
                    self.shed(rq, epoch, ShedReason::DeadlineMissed, false, 0.0, 0, 0, 0, 0.0);
                r.t_qos = t_qos;
                r.deadline_missed = true;
                return r;
            }
            self.qos
                .as_mut()
                .expect("QoS serving requires qos state")
                .reserve(dev, start, t_exec);
            let j = self.devices[dev].commit_gap(key, job_ready, start, done, t_exec, hit);
            let job = self.devices[dev].jobs[j];
            let cost = self.exec_memo.get(&key).cloned().unwrap_or_default();
            self.stash_obs(dev, &key, &cost);
            let outcome = if precision != rq.precision {
                Outcome::Degraded(Degradation::Int8)
            } else {
                Outcome::Completed
            };
            self.record_decision(rq, outcome);
            return Response {
                device: dev as u32,
                t_compile: ready - rq.arrival,
                t_exec: job.t_exec,
                t_queue: job.start - job.ready,
                latency: job.done - rq.arrival,
                cache_hit: job.cache_hit,
                remaps: cost.remaps,
                precision,
                quant_visits: cost.quant_blocks,
                requant_ops: cost.requant_ops,
                int8_bytes: cost.int8_bytes,
                t_qos,
                deadline_missed: missed,
                outcome,
                ..Self::base_response(rq, epoch)
            };
        }
    }

    /// Mini-batch serving under QoS. Sampling is host-side and
    /// unpaced; the device visit is paced, gap-placed, and cascaded
    /// like whole-graph work, with the extra capped-fanout rung
    /// (re-sample with every hop clamped to [`DEGRADED_FANOUT_CAP`];
    /// the original sample's cost stays on the bill).
    fn serve_minibatch_qos(
        &mut self,
        rq: &Request,
        targets: &[u32],
        fanout: &[u32],
        seed: u64,
    ) -> Response {
        let tenant = self
            .qos
            .as_ref()
            .expect("QoS serving requires qos state")
            .tenant(rq.tenant);
        let deadline = tenant.deadline_s.map(|d| rq.arrival + d);
        let (mut sampled_v, mut sampled_e, mut shape, epoch) =
            self.sample_shape(rq, targets, fanout, seed);
        let mut t_sample = self.costs.sample_cost(sampled_v, sampled_e);
        let mut precision = rq.precision;
        let mut capped = false;
        let mut paced: Option<f64> = None;
        loop {
            let key = Key::Bucket(rq.model, shape, precision);
            let est = self
                .exec_memo
                .get(&key)
                .map_or(0.0, |c| self.costs.visit_overhead_s + c.secs);
            let dev = self.qos_route(&key, rq.arrival + t_sample, est);
            let (exe, ready, hit) =
                self.devices[dev].prepare_bucket(rq.arrival + t_sample, rq.model, shape, precision);
            let t_item = {
                let mut exec_seconds =
                    memo_exec(&mut self.exec_memo, &self.hw, self.dynamic, key);
                exec_seconds(&exe)
            };
            let t_visit = self.costs.visit_overhead_s + t_item;
            let delay = *paced.get_or_insert_with(|| {
                self.qos
                    .as_mut()
                    .expect("QoS serving requires qos state")
                    .pacing_delay(&tenant, rq.arrival, t_visit)
            });
            let mut eligible = rq.arrival + delay;
            if let Some(d) = deadline {
                eligible = eligible.min((d - t_visit).max(rq.arrival));
            }
            let t_qos = eligible - rq.arrival;
            let job_ready = ready.max(eligible);
            let start = self
                .qos
                .as_ref()
                .expect("QoS serving requires qos state")
                .earliest_start(dev, job_ready, t_visit);
            let done = start + t_visit;
            let missed = deadline.is_some_and(|d| done > d);
            if missed {
                if precision == Precision::F32 {
                    precision = Precision::Int8;
                    continue;
                }
                if !capped && fanout.iter().any(|&h| h > DEGRADED_FANOUT_CAP) {
                    // Rung two: re-sample a capped ego-net. The
                    // original sample was real host work — its cost
                    // stays on the bill.
                    capped = true;
                    let capped_fanout: Vec<u32> =
                        fanout.iter().map(|&h| h.min(DEGRADED_FANOUT_CAP)).collect();
                    let (v, e, s, _) = self.sample_shape(rq, targets, &capped_fanout, seed);
                    sampled_v = v;
                    sampled_e = e;
                    shape = s;
                    t_sample += self.costs.sample_cost(v, e);
                    continue;
                }
                if tenant.class == PriorityClass::BestEffort {
                    let mut r = self.shed(
                        rq,
                        epoch,
                        ShedReason::DeadlineMissed,
                        true,
                        t_sample,
                        sampled_v,
                        sampled_e,
                        0,
                        0.0,
                    );
                    r.t_qos = t_qos;
                    r.deadline_missed = true;
                    return r;
                }
            }
            self.qos
                .as_mut()
                .expect("QoS serving requires qos state")
                .reserve(dev, start, t_visit);
            let j = self.devices[dev].commit_gap(key, job_ready, start, done, t_visit, hit);
            let job = self.devices[dev].jobs[j];
            let cost = self.exec_memo.get(&key).cloned().unwrap_or_default();
            self.stash_obs(dev, &key, &cost);
            let outcome = match (precision != rq.precision, capped) {
                (false, false) => Outcome::Completed,
                (true, false) => Outcome::Degraded(Degradation::Int8),
                (false, true) => Outcome::Degraded(Degradation::CappedFanout),
                (true, true) => Outcome::Degraded(Degradation::Int8CappedFanout),
            };
            self.record_decision(rq, outcome);
            return Response {
                device: dev as u32,
                t_compile: (ready - rq.arrival - t_sample).max(0.0),
                t_sample,
                t_exec: job.t_exec,
                t_queue: job.start - job.ready,
                latency: job.done - rq.arrival,
                cache_hit: job.cache_hit,
                minibatch: true,
                sampled_vertices: sampled_v,
                sampled_edges: sampled_e,
                remaps: cost.remaps,
                precision,
                quant_visits: cost.quant_blocks,
                requant_ops: cost.requant_ops,
                int8_bytes: cost.int8_bytes,
                t_qos,
                deadline_missed: missed,
                outcome,
                ..Self::base_response(rq, epoch)
            };
        }
    }

    /// Log a non-`Completed` outcome (completions are the common case
    /// and are not logged, so the trace stays compact). The record
    /// lands in whichever decision log is live — fault state and QoS
    /// state are mutually exclusive.
    fn record_decision(&mut self, rq: &Request, outcome: Outcome) {
        if outcome == Outcome::Completed {
            return;
        }
        let rec = DecisionRecord { at: rq.arrival, tenant: rq.tenant, outcome };
        if let Some(f) = self.fault.as_mut() {
            f.decisions.push(rec);
        } else if let Some(q) = self.qos.as_mut() {
            q.decisions.push(rec);
        } else {
            panic!("decisions only exist under a fault plan or tenant config");
        }
    }

    /// A shed request: no device work; the outcome is named and logged.
    /// Its pseudo-latency is the host time burned discovering the shed
    /// (sampling plus backoff). Shed responses stay out of the latency
    /// percentiles and the completed count.
    #[allow(clippy::too_many_arguments)]
    fn shed(
        &mut self,
        rq: &Request,
        epoch: u32,
        reason: ShedReason,
        minibatch: bool,
        t_sample: f64,
        sampled_vertices: u64,
        sampled_edges: u64,
        retries: u32,
        t_backoff: f64,
    ) -> Response {
        let outcome = Outcome::Shed(reason);
        self.record_decision(rq, outcome);
        Response {
            device: u32::MAX,
            t_sample,
            latency: t_sample + t_backoff,
            minibatch,
            sampled_vertices,
            sampled_edges,
            retries,
            t_backoff,
            outcome,
            ..Self::base_response(rq, epoch)
        }
    }

    /// The corruption fault's bite: if an armed corruption targets this
    /// device's resident whole-graph artifact, serialize it, flip the
    /// byte [`Program::corruption_offset`] points at, let the loader's
    /// own validation reject the damage, and evict — the caller's
    /// `prepare` then recompiles and the request completes anyway.
    fn maybe_corrupt(&mut self, dev: usize, key: &Key) {
        let Key::Whole(model, ds_key, _, _) = *key else {
            return; // bucket programs are not corruption targets
        };
        let Some(slot) = self.fault.as_ref().and_then(|f| {
            f.armed
                .iter()
                .position(|(_, d, m, g)| *d == dev && *m == model && g == ds_key)
        }) else {
            return;
        };
        let Some(exe) = self.devices[dev].cached(key) else {
            return; // nothing resident yet — the corruption keeps waiting
        };
        let mut bytes = exe.program.to_bytes();
        bytes[exe.program.corruption_offset()] ^= 0xFF;
        if Program::from_bytes(&bytes).is_ok() {
            // Unreachable by construction — the offset lands in the
            // magic or a section flag — but refuse to evict on a flip
            // the loader would have accepted.
            return;
        }
        self.devices[dev].evict(key);
        let f = self
            .fault
            .as_mut()
            .expect("an armed corruption implies fault state");
        f.corruptions += 1;
        f.armed.remove(slot);
    }

    /// Whole-graph serving under a fault plan: quote every attempt
    /// against the device's outage calendar, retry crashed attempts
    /// with exponential backoff (re-routing to whichever device comes
    /// up first), degrade to int8 when the best quote lands past the
    /// deadline, and shed — with a named reason — only when no healthy
    /// device exists or the retry budget is spent.
    ///
    /// No coalescing here: a rider on a job that a later-quoted crash
    /// kills would be silently lost, which is exactly the invariant
    /// this path defends (every accepted request reaches a terminal
    /// outcome).
    fn serve_full_faulty(&mut self, rq: &Request) -> Response {
        let snapshot = self.streams.get_mut(rq.dataset.key).map(|st| st.snapshot());
        let epoch = snapshot.as_ref().map_or(0, |s| s.0);
        let deadline = rq.arrival + self.costs.deadline_s;
        let mut precision = rq.precision;
        let mut retries = 0u32;
        let mut t_backoff = 0.0;
        // Earliest time the next attempt may start (arrival, pushed
        // forward by each backoff pause).
        let mut floor = rq.arrival;
        let mut first_dev: Option<usize> = None;
        loop {
            let key = Key::Whole(rq.model, rq.dataset.key, epoch, precision);
            let Some(dev) = self.dispatcher.route_healthy(&self.devices, &key, floor) else {
                return self.shed(
                    rq, epoch, ShedReason::NoHealthyDevice, false, 0.0, 0, 0, retries,
                    t_backoff,
                );
            };
            if first_dev.is_none() {
                first_dev = Some(dev);
            }
            self.maybe_corrupt(dev, &key);
            let snap_ref = snapshot.as_ref().map(|(_, m, t)| (m, t));
            let (exe, ready, hit) =
                self.devices[dev].prepare(floor, rq.model, &rq.dataset, epoch, snap_ref, precision);
            let t_exec = {
                let mut exec_seconds =
                    memo_exec(&mut self.exec_memo, &self.hw, self.dynamic, key);
                exec_seconds(&exe)
            };
            match self.devices[dev].quote(ready, t_exec) {
                Quote::Crashed { start, at, event } => {
                    // The crash fires now — possibly ahead of the
                    // arrival cursor — and whatever this attempt ran
                    // since `start` is wasted device time the retry
                    // pays for. The direct wipe also drops anything
                    // compiled *for* the doomed attempt, even when the
                    // event itself already fired: a recovered device
                    // rejoins cold.
                    {
                        let f = self
                            .fault
                            .as_mut()
                            .expect("a crashed quote implies fault state");
                        f.fire(event, &mut self.devices);
                    }
                    self.devices[dev].crash_wipe(at);
                    self.devices[dev].charge_wasted(start, at);
                    if retries >= self.costs.max_retries {
                        return self.shed(
                            rq, epoch, ShedReason::RetriesExhausted, false, 0.0, 0, 0,
                            retries, t_backoff,
                        );
                    }
                    retries += 1;
                    let pause = self.costs.backoff(retries);
                    t_backoff += pause;
                    floor = at.max(floor) + pause;
                }
                Quote::Done { start, done } => {
                    if done > deadline && precision == Precision::F32 {
                        // Fidelity cascade, rung one: the int8 twin
                        // compiles smaller and executes faster (GA03).
                        precision = Precision::Int8;
                        continue;
                    }
                    let j = self.devices[dev].commit(key, ready, start, done, t_exec, hit);
                    let job = self.devices[dev].jobs[j];
                    let cost = self.exec_memo.get(&key).cloned().unwrap_or_default();
                    self.stash_obs(dev, &key, &cost);
                    let outcome = if precision != rq.precision {
                        Outcome::Degraded(Degradation::Int8)
                    } else {
                        Outcome::Completed
                    };
                    self.record_decision(rq, outcome);
                    return Response {
                        device: dev as u32,
                        t_compile: job.ready - floor,
                        t_exec: job.t_exec,
                        t_queue: job.start - job.ready,
                        latency: job.done - rq.arrival,
                        cache_hit: job.cache_hit,
                        remaps: cost.remaps,
                        precision,
                        quant_visits: cost.quant_blocks,
                        requant_ops: cost.requant_ops,
                        int8_bytes: cost.int8_bytes,
                        retries,
                        rerouted: first_dev != Some(dev),
                        t_backoff,
                        outcome,
                        ..Self::base_response(rq, epoch)
                    };
                }
            }
        }
    }

    /// Sample one ego-net for the fault path (streamed epoch or static
    /// sampler, exactly as [`Coordinator::serve_minibatch`] does) and
    /// return its modeled footprint: (vertices, edges, bucket, epoch).
    fn sample_shape(
        &mut self,
        rq: &Request,
        targets: &[u32],
        fanout: &[u32],
        seed: u64,
    ) -> (u64, u64, BucketShape, u32) {
        let (ego, epoch) = if let Some(st) = self.streams.get(rq.dataset.key) {
            (st.dyng.sample(targets, fanout, seed), st.dyng.epoch())
        } else {
            let sampler = self
                .samplers
                .entry(rq.dataset.key)
                .or_insert_with(|| Sampler::new(rq.dataset.materialize().gcn_normalized()));
            (sampler.sample(targets, fanout, seed), 0)
        };
        let shape = BucketShape::for_graph(&ego.graph.meta);
        (ego.n() as u64, ego.m() as u64, shape, epoch)
    }

    /// Mini-batch serving under a fault plan. Sampling is host-side
    /// and fault-free; the device visit is quoted and retried like
    /// whole-graph work. The fidelity cascade has two rungs here: int8
    /// first, then a re-sample with every hop's fanout clamped to
    /// [`DEGRADED_FANOUT_CAP`] (the smaller ego-net quotes a sooner
    /// completion). No micro-batching, for the same
    /// lost-rider reason [`Coordinator::serve_full_faulty`] gives for
    /// coalescing.
    fn serve_minibatch_faulty(
        &mut self,
        rq: &Request,
        targets: &[u32],
        fanout: &[u32],
        seed: u64,
    ) -> Response {
        let deadline = rq.arrival + self.costs.deadline_s;
        let (mut sampled_v, mut sampled_e, mut shape, epoch) =
            self.sample_shape(rq, targets, fanout, seed);
        let mut t_sample = self.costs.sample_cost(sampled_v, sampled_e);
        let mut precision = rq.precision;
        let mut capped = false;
        let mut retries = 0u32;
        let mut t_backoff = 0.0;
        let mut floor = rq.arrival + t_sample;
        let mut first_dev: Option<usize> = None;
        loop {
            let key = Key::Bucket(rq.model, shape, precision);
            let Some(dev) = self.dispatcher.route_healthy(&self.devices, &key, floor) else {
                return self.shed(
                    rq, epoch, ShedReason::NoHealthyDevice, true, t_sample, sampled_v,
                    sampled_e, retries, t_backoff,
                );
            };
            if first_dev.is_none() {
                first_dev = Some(dev);
            }
            let (exe, ready, hit) =
                self.devices[dev].prepare_bucket(floor, rq.model, shape, precision);
            let t_item = {
                let mut exec_seconds =
                    memo_exec(&mut self.exec_memo, &self.hw, self.dynamic, key);
                exec_seconds(&exe)
            };
            let t_visit = self.costs.visit_overhead_s + t_item;
            match self.devices[dev].quote(ready, t_visit) {
                Quote::Crashed { start, at, event } => {
                    {
                        let f = self
                            .fault
                            .as_mut()
                            .expect("a crashed quote implies fault state");
                        f.fire(event, &mut self.devices);
                    }
                    self.devices[dev].crash_wipe(at);
                    self.devices[dev].charge_wasted(start, at);
                    if retries >= self.costs.max_retries {
                        return self.shed(
                            rq, epoch, ShedReason::RetriesExhausted, true, t_sample,
                            sampled_v, sampled_e, retries, t_backoff,
                        );
                    }
                    retries += 1;
                    let pause = self.costs.backoff(retries);
                    t_backoff += pause;
                    floor = at.max(floor) + pause;
                }
                Quote::Done { start, done } => {
                    if done > deadline {
                        if precision == Precision::F32 {
                            precision = Precision::Int8;
                            continue;
                        }
                        if !capped && fanout.iter().any(|&h| h > DEGRADED_FANOUT_CAP) {
                            // Rung two: re-sample a capped ego-net. The
                            // original sample was real host work — its
                            // cost stays on the bill.
                            capped = true;
                            let capped_fanout: Vec<u32> = fanout
                                .iter()
                                .map(|&h| h.min(DEGRADED_FANOUT_CAP))
                                .collect();
                            let (v, e, s, _) =
                                self.sample_shape(rq, targets, &capped_fanout, seed);
                            sampled_v = v;
                            sampled_e = e;
                            shape = s;
                            let extra = self.costs.sample_cost(v, e);
                            t_sample += extra;
                            floor += extra;
                            continue;
                        }
                    }
                    let j = self.devices[dev].commit(key, ready, start, done, t_visit, hit);
                    let job = self.devices[dev].jobs[j];
                    let cost = self.exec_memo.get(&key).cloned().unwrap_or_default();
                    self.stash_obs(dev, &key, &cost);
                    let outcome = match (precision != rq.precision, capped) {
                        (false, false) => Outcome::Completed,
                        (true, false) => Outcome::Degraded(Degradation::Int8),
                        (false, true) => Outcome::Degraded(Degradation::CappedFanout),
                        (true, true) => Outcome::Degraded(Degradation::Int8CappedFanout),
                    };
                    self.record_decision(rq, outcome);
                    return Response {
                        device: dev as u32,
                        t_compile: (job.ready - floor).max(0.0),
                        t_sample,
                        t_exec: job.t_exec,
                        t_queue: job.start - job.ready,
                        latency: job.done - rq.arrival,
                        cache_hit: job.cache_hit,
                        minibatch: true,
                        sampled_vertices: sampled_v,
                        sampled_edges: sampled_e,
                        remaps: cost.remaps,
                        precision,
                        quant_visits: cost.quant_blocks,
                        requant_ops: cost.requant_ops,
                        int8_bytes: cost.int8_bytes,
                        retries,
                        rerouted: first_dev != Some(dev),
                        t_backoff,
                        outcome,
                        ..Self::base_response(rq, epoch)
                    };
                }
            }
        }
    }

    /// Apply one streaming update batch: synthesize the churn
    /// deterministically from the request descriptor, apply it to the
    /// dataset's dynamic graph (creating the stream on first use),
    /// charge the modeled apply cost on the virtual clock, and
    /// selectively invalidate stale whole-graph programs fleet-wide.
    /// Bucket programs are shape-only and survive untouched.
    fn serve_update(
        &mut self,
        rq: &Request,
        inserts: u32,
        deletes: u32,
        grow: u32,
        seed: u64,
    ) -> Response {
        // The dynamic graph supersedes the static sampler for this
        // dataset (serve_minibatch consults `streams` first) — drop the
        // sampler so two copies of the graph + CSR don't stay resident.
        self.samplers.remove(rq.dataset.key);
        let hw = &self.hw;
        let st = self
            .streams
            .entry(rq.dataset.key)
            .or_insert_with(|| StreamState::new(&rq.dataset, hw));
        let spec = ChurnSpec { inserts, deletes, new_vertices: grow };
        let batch = ChurnGenerator::new(rq.dataset.params(), seed).next_batch(&st.dyng, spec);
        let changed = batch.changes() as u64;
        let report = st.dyng.apply(&batch);
        st.snap = None;
        let t_update = self.costs.update_cost(
            changed,
            report.dirty_subshards as u64,
            report.rebuilt_edges,
        );
        let mut invalidated = 0usize;
        for d in &mut self.devices {
            invalidated += d.invalidate_dataset(rq.dataset.key, report.epoch);
        }
        // The modeled-exec memo holds the same now-unreachable keys the
        // device caches just dropped — prune it too, or a long stream
        // grows one dead entry per (model, stale epoch).
        self.exec_memo.retain(|k, _| {
            !matches!(k, Key::Whole(_, d, e, _) if *d == rq.dataset.key && *e < report.epoch)
        });
        Response {
            // Updates are host-side: no device executes them.
            device: u32::MAX,
            latency: t_update,
            update: true,
            t_update,
            dirty_subshards: report.dirty_subshards,
            rebuilt_edges: report.rebuilt_edges,
            invalidated: invalidated as u32,
            compacted: report.compacted,
            ..Self::base_response(rq, report.epoch)
        }
    }

    /// Execute real numerics for one compiled program on a specific
    /// device's functional substrate — the fleet's audit path for
    /// spot-checking that a served (model, graph) pair still produces
    /// golden-equivalent outputs. Tile buffers come from the *device's*
    /// own [`crate::exec::BufferArena`] (the software analogue of its
    /// resident Feature Buffer), so repeated replays on a device are
    /// allocation-free in steady state. The virtual clock is untouched:
    /// a replay is offline verification, not a served request.
    pub fn functional_replay(
        &mut self,
        device: usize,
        exe: &Executable,
        input: &EngineInput<'_>,
    ) -> Result<ExecProfile> {
        if device >= self.devices.len() {
            bail!("no device {device} in a {}-device fleet", self.devices.len());
        }
        if exe.cfg != input.partitioned.cfg {
            bail!(
                "graph partitioned with (N1={}, N2={}) but executable wants (N1={}, N2={})",
                input.partitioned.cfg.n1,
                input.partitioned.cfg.n2,
                exe.cfg.n1,
                exe.cfg.n2
            );
        }
        let arena = std::mem::take(&mut self.devices[device].arena);
        let packed = self.devices[device].packed.take();
        let packed_i8 = self.devices[device].packed_i8.take();
        let mut fx = FunctionalExecutor::with_state(
            exe,
            input.partitioned,
            input.store,
            CountingBackend::new(RustBackend),
            arena,
            packed,
            packed_i8,
        );
        fx.dynamic = self.dynamic;
        let (out, secs) = timed(|| fx.run(input.x));
        let profile = ExecProfile {
            engine: "functional",
            latency_s: secs,
            cycles: 0,
            // Quantized tiles bypass the counting backend, so their
            // launches and operand traffic are added back here.
            kernel_launches: fx.backend.launches + fx.quant_visits,
            bytes_moved: fx.backend.bytes + fx.int8_bytes,
            remaps: fx.remaps,
            quant_visits: fx.quant_visits,
            requant_ops: fx.requant_ops,
            int8_bytes: fx.int8_bytes,
            output: Some(out),
        };
        let (arena, packed, packed_i8) = fx.into_state();
        self.devices[device].arena = arena;
        self.devices[device].packed = Some(packed);
        self.devices[device].packed_i8 = packed_i8;
        Ok(profile)
    }

    /// Aggregate the responses served so far into the counter families
    /// of [`ServeStats`] (latencies are nearest-rank percentiles over
    /// non-shed inference responses).
    pub fn stats(&self) -> ServeStats {
        if self.responses.is_empty() {
            return ServeStats::default();
        }
        // Latency statistics cover inference responses only: an
        // update's modeled apply cost is not a serving latency, and a
        // shed request's pseudo-latency is not a completion.
        let mut lats: Vec<f64> = self
            .responses
            .iter()
            .filter(|r| !r.update && !r.outcome.is_shed())
            .map(|r| r.latency)
            .collect();
        lats.sort_by(f64::total_cmp);
        let class = |mini: bool| -> Vec<f64> {
            self.responses
                .iter()
                .filter(|r| !r.update && !r.outcome.is_shed() && r.minibatch == mini)
                .map(|r| r.latency)
                .collect()
        };
        let mean = if lats.is_empty() {
            0.0
        } else {
            lats.iter().sum::<f64>() / lats.len() as f64
        };
        ServeStats {
            completed: self
                .responses
                .iter()
                .filter(|r| !r.outcome.is_shed())
                .count() as u64,
            cache_hits: self.responses.iter().filter(|r| r.cache_hit).count() as u64,
            coalesced: self.responses.iter().filter(|r| r.coalesced).count() as u64,
            minibatched: self.responses.iter().filter(|r| r.minibatch).count() as u64,
            batched: self.responses.iter().filter(|r| r.batched).count() as u64,
            bucket_hits: self
                .responses
                .iter()
                .filter(|r| r.minibatch && r.cache_hit)
                .count() as u64,
            sampled_vertices: self.responses.iter().map(|r| r.sampled_vertices).sum(),
            sampled_edges: self.responses.iter().map(|r| r.sampled_edges).sum(),
            remaps: self
                .responses
                .iter()
                .filter(|r| !r.coalesced)
                .map(|r| r.remaps)
                .sum(),
            quantized: self
                .responses
                .iter()
                .filter(|r| !r.update && !r.outcome.is_shed() && r.precision == Precision::Int8)
                .count() as u64,
            quant_visits: self
                .responses
                .iter()
                .filter(|r| !r.coalesced)
                .map(|r| r.quant_visits)
                .sum(),
            requant_ops: self
                .responses
                .iter()
                .filter(|r| !r.coalesced)
                .map(|r| r.requant_ops)
                .sum(),
            int8_bytes: self
                .responses
                .iter()
                .filter(|r| !r.coalesced)
                .map(|r| r.int8_bytes)
                .sum(),
            updates: self.responses.iter().filter(|r| r.update).count() as u64,
            max_epoch: self.responses.iter().map(|r| r.epoch).max().unwrap_or(0),
            dirty_subshards: self.responses.iter().map(|r| r.dirty_subshards as u64).sum(),
            rebuilt_edges: self.responses.iter().map(|r| r.rebuilt_edges).sum(),
            invalidated: self.responses.iter().map(|r| r.invalidated as u64).sum(),
            compactions: self.responses.iter().filter(|r| r.compacted).count() as u64,
            p50: percentile(&lats, 0.50),
            p99: percentile(&lats, 0.99),
            mean,
            p50_mini: class_p50(class(true)),
            p50_full: class_p50(class(false)),
            device_busy: self.devices.iter().map(|d| d.busy).sum(),
            makespan: self.clock.now(),
            retries: self.responses.iter().map(|r| r.retries as u64).sum(),
            rerouted: self.responses.iter().filter(|r| r.rerouted).count() as u64,
            degraded: self
                .responses
                .iter()
                .filter(|r| r.outcome.is_degraded())
                .count() as u64,
            shed: self.responses.iter().filter(|r| r.outcome.is_shed()).count() as u64,
            crashes: self.fault.as_ref().map_or(0, |f| f.crashes),
            stalls: self.fault.as_ref().map_or(0, |f| f.stalls),
            corruptions: self.fault.as_ref().map_or(0, |f| f.corruptions),
            downtime: self.fault.as_ref().map_or(0.0, |f| f.downtime),
            t_backoff: self.responses.iter().map(|r| r.t_backoff).sum(),
            tenants: self.tenant_stats(),
        }
    }

    /// Per-tenant latency and outcome families, one row per tenant id
    /// seen in the inference responses (ascending id; updates are
    /// tenant-blind host work and excluded). Empty unless a tenant
    /// config is installed.
    fn tenant_stats(&self) -> Vec<TenantStats> {
        let Some(q) = self.qos.as_ref() else {
            return Vec::new();
        };
        let mut ids: Vec<u32> = self
            .responses
            .iter()
            .filter(|r| !r.update)
            .map(|r| r.tenant)
            .collect();
        ids.sort_unstable();
        ids.dedup();
        ids.iter()
            .map(|&id| {
                let rows: Vec<&Response> = self
                    .responses
                    .iter()
                    .filter(|r| !r.update && r.tenant == id)
                    .collect();
                let mut lats: Vec<f64> = rows
                    .iter()
                    .filter(|r| !r.outcome.is_shed())
                    .map(|r| r.latency)
                    .collect();
                lats.sort_by(f64::total_cmp);
                TenantStats {
                    tenant: id,
                    weight: q.tenant(id).weight,
                    completed: rows.iter().filter(|r| !r.outcome.is_shed()).count() as u64,
                    degraded: rows.iter().filter(|r| r.outcome.is_degraded()).count() as u64,
                    shed: rows.iter().filter(|r| r.outcome.is_shed()).count() as u64,
                    missed: rows.iter().filter(|r| r.deadline_missed).count() as u64,
                    p50: percentile(&lats, 0.50),
                    p99: percentile(&lats, 0.99),
                    t_qos: rows.iter().map(|r| r.t_qos).sum(),
                    busy: rows
                        .iter()
                        .filter(|r| !r.outcome.is_shed())
                        .map(|r| r.t_exec)
                        .sum(),
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{dataset, FULL_NEIGHBORHOOD};
    use crate::serve::qos::Tenant;
    use crate::util::Rng;

    fn mixed_workload(n: usize, seed: u64) -> Vec<Request> {
        // Three tenants, three models, two graphs — the cloud scenario.
        let mut rng = Rng::new(seed);
        let models = [ZooModel::B1, ZooModel::B2, ZooModel::B7];
        let graphs = [dataset("CO").unwrap(), dataset("PU").unwrap()];
        (0..n)
            .map(|i| {
                Request::full(
                    rng.below(3) as u32,
                    models[rng.below(3) as usize],
                    graphs[rng.below(2) as usize],
                    i as f64 * 1e-4,
                )
            })
            .collect()
    }

    fn minibatch_workload(n: usize, seed: u64, spacing: f64) -> Vec<Request> {
        let mut rng = Rng::new(seed);
        let models = [ZooModel::B1, ZooModel::B7];
        let co = dataset("CO").unwrap();
        (0..n)
            .map(|i| {
                let k = 1 + rng.below(3) as usize;
                let targets: Vec<u32> =
                    (0..k).map(|_| rng.below(co.n_vertices) as u32).collect();
                Request::minibatch(
                    rng.below(4) as u32,
                    models[rng.below(2) as usize],
                    co,
                    targets,
                    vec![8, 4],
                    seed ^ i as u64,
                    i as f64 * spacing,
                )
            })
            .collect()
    }

    #[test]
    fn serves_mixed_tenants_with_cache_reuse() {
        let mut c = Coordinator::new(HwConfig::alveo_u250());
        let stats = c.run(mixed_workload(60, 1));
        assert_eq!(stats.completed, 60);
        // 3 models x 2 graphs = at most 6 compiles; everything else hits
        // (a coalesced ride counts as a hit).
        assert!(stats.cache_hits >= 54, "hits {}", stats.cache_hits);
        assert!(stats.p99 >= stats.p50);
        assert!(stats.device_busy <= stats.makespan + 1e-9);
        // A whole-graph workload samples nothing.
        assert_eq!(stats.minibatched, 0);
        assert_eq!(stats.sampled_edges, 0);
        assert_eq!(stats.p50_full, stats.p50);
        assert_eq!(stats.p50_mini, 0.0);
    }

    #[test]
    fn model_switching_is_free_of_recompiles() {
        // Alternate two models on one graph: after warmup, every request
        // is a cache hit — the "no FPGA reconfiguration" property.
        let co = dataset("CO").unwrap();
        let reqs: Vec<Request> = (0..20)
            .map(|i| {
                Request::full(
                    0,
                    if i % 2 == 0 { ZooModel::B1 } else { ZooModel::B6 },
                    co,
                    i as f64 * 1e-3,
                )
            })
            .collect();
        let mut c = Coordinator::new(HwConfig::alveo_u250());
        c.run(reqs);
        let warm = &c.responses[2..];
        assert!(warm.iter().all(|r| r.cache_hit && r.t_compile == 0.0));
    }

    #[test]
    fn queueing_appears_under_burst() {
        // All requests arrive at t=0 on one device with coalescing off:
        // later ones must queue.
        let pu = dataset("PU").unwrap();
        let reqs: Vec<Request> = (0..8)
            .map(|i| Request::full(i, ZooModel::B2, pu, 0.0))
            .collect();
        let cfg = FleetConfig { coalesce: false, ..FleetConfig::default() };
        let mut c = Coordinator::fleet(HwConfig::alveo_u250(), cfg);
        let stats = c.run(reqs);
        let queued = c.responses.iter().filter(|r| r.t_queue > 0.0).count();
        assert!(queued >= 6, "queued {queued}");
        // Makespan ~= sum of exec times (single device, saturated).
        assert!((stats.makespan - stats.device_busy).abs() < stats.makespan * 0.5);
    }

    #[test]
    fn identical_burst_coalesces_into_one_execution() {
        let pu = dataset("PU").unwrap();
        let reqs: Vec<Request> = (0..8)
            .map(|i| Request::full(i, ZooModel::B2, pu, 0.0))
            .collect();
        let mut c = Coordinator::new(HwConfig::alveo_u250());
        let stats = c.run(reqs);
        // The first request compiles; the other seven ride its job while
        // it waits on the (virtual) compile.
        assert_eq!(stats.completed, 8);
        assert_eq!(stats.coalesced, 7, "coalesced {}", stats.coalesced);
        let exec_once = c.responses[0].t_exec;
        assert!((stats.device_busy - exec_once).abs() < 1e-12, "one execution total");
        assert_eq!(c.devices()[0].jobs.len(), 1);
        assert_eq!(c.devices()[0].jobs[0].riders, 7);
    }

    #[test]
    fn replay_is_bit_identical() {
        // The satellite guarantee: no wall-clock leaks into serving
        // stats — two runs of the same workload agree exactly.
        let run = || {
            let cfg = FleetConfig { n_devices: 3, ..FleetConfig::default() };
            let mut c = Coordinator::fleet(HwConfig::alveo_u250(), cfg);
            let stats = c.run(mixed_workload(40, 7));
            (stats, c.responses)
        };
        let (s1, r1) = run();
        let (s2, r2) = run();
        assert_eq!(s1, s2);
        assert_eq!(r1, r2);
    }

    #[test]
    fn four_devices_beat_one_on_saturating_burst() {
        // A saturating burst (coalescing off, so every request is real
        // device work): four overlays must finish strictly sooner than
        // one, and cache-affinity must keep the fleet hit rate at least
        // at the single-device level (at most one compile per distinct
        // key fleet-wide).
        let run = |n_devices: usize| {
            let cfg =
                FleetConfig { n_devices, coalesce: false, ..FleetConfig::default() };
            let mut c = Coordinator::fleet(HwConfig::alveo_u250(), cfg);
            let stats = c.run(mixed_workload(48, 3));
            (stats, c)
        };
        let (s1, _) = run(1);
        let (s4, c4) = run(4);
        assert_eq!(s1.completed, s4.completed);
        assert!(
            s4.makespan < s1.makespan,
            "4-device makespan {} !< 1-device {}",
            s4.makespan,
            s1.makespan
        );
        assert!(
            s4.cache_hits >= s1.cache_hits,
            "fleet hits {} < single-device {}",
            s4.cache_hits,
            s1.cache_hits
        );
        // The burst spread across the fleet.
        let active = c4.devices().iter().filter(|d| d.busy > 0.0).count();
        assert!(active >= 2, "only {active} devices did work");
        // Per-device caches: fleet-wide at most one compile per key.
        let compiles: usize = c4.devices().iter().map(|d| d.cache_len()).sum();
        assert!(compiles <= 6, "{compiles} compiles for 6 distinct keys");
    }

    #[test]
    fn percentiles_nearest_rank() {
        // The satellite fix: (len-1)*p truncation under-reported p99 (on
        // 100 samples it indexed 98.01 -> 98, i.e. the 99th sample, but
        // on small n it collapsed toward p50). Nearest-rank is exact.
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.50), 50.0);
        assert_eq!(percentile(&v, 0.99), 99.0);
        assert_eq!(percentile(&v, 1.00), 100.0);
        let small = [10.0, 20.0, 30.0, 40.0, 50.0];
        assert_eq!(percentile(&small, 0.50), 30.0);
        assert_eq!(percentile(&small, 0.99), 50.0);
        // The old truncating formula pinned p99 of 5 samples to index
        // (5-1)*0.99 = 3 (40.0) — the tail sample was unreachable.
        assert_eq!(percentile(&small, 0.25), 20.0);
        // The empty-sample edge case: every percentile of no data is
        // the stats' 0 "no data" value, never a panic (update-only
        // workloads have empty latency classes).
        assert_eq!(percentile(&[], 0.50), 0.0);
        assert_eq!(percentile(&[], 0.99), 0.0);
    }

    #[test]
    fn incremental_admission_matches_batch_run() {
        // The daemon's ingestion path: admitting a pre-sorted workload
        // one request at a time is the identical computation to run().
        let mut reqs = minibatch_workload(16, 21, 5e-5);
        reqs.extend(mixed_workload(16, 21));
        reqs.push(Request::update(0, dataset("CO").unwrap(), 32, 8, 0, 4, 2e-3));
        reqs.sort_by(|a, b| {
            a.arrival
                .total_cmp(&b.arrival)
                .then(a.tenant.cmp(&b.tenant))
                .then(a.model.key().cmp(b.model.key()))
                .then(a.dataset.key.cmp(b.dataset.key))
                .then(a.target.cmp(&b.target))
                .then(a.precision.cmp(&b.precision))
        });
        let mut batch = Coordinator::new(HwConfig::alveo_u250());
        let s_batch = batch.run(reqs.clone());
        let mut incr = Coordinator::new(HwConfig::alveo_u250());
        let per_request: Vec<Response> = reqs.into_iter().map(|rq| incr.admit(rq)).collect();
        let s_incr = incr.stats();
        assert_eq!(s_batch, s_incr);
        assert_eq!(batch.responses, incr.responses);
        // admit() returns the same record it appends.
        assert_eq!(per_request, incr.responses);
        assert!(s_batch.diff(&s_incr).is_empty());
    }

    #[test]
    fn stats_diff_names_throughput_and_cache_counters() {
        let a = ServeStats { completed: 5, cache_hits: 4, coalesced: 1, ..Default::default() };
        let mut b = a.clone();
        assert!(a.diff(&b).is_empty());
        b.completed = 6;
        b.cache_hits = 3;
        b.coalesced = 2;
        let d = a.diff(&b);
        assert_eq!(d.len(), 3, "{d:?}");
        assert!(d[0].contains("completed: 5 != 6"), "{d:?}");
        assert!(d[1].contains("cache_hits: 4 != 3"), "{d:?}");
        assert!(d[2].contains("coalesced: 1 != 2"), "{d:?}");
    }

    #[test]
    fn stats_diff_names_minibatch_counters() {
        let a = ServeStats {
            minibatched: 8,
            batched: 2,
            bucket_hits: 6,
            sampled_vertices: 100,
            sampled_edges: 900,
            ..Default::default()
        };
        let mut b = a.clone();
        b.batched = 3;
        b.sampled_edges = 901;
        let d = a.diff(&b);
        assert_eq!(d.len(), 2, "{d:?}");
        assert!(d.iter().any(|s| s.contains("batched: 2 != 3")), "{d:?}");
        assert!(d.iter().any(|s| s.contains("sampled_edges: 900 != 901")), "{d:?}");
    }

    #[test]
    fn stats_diff_names_quant_and_remap_counters() {
        let a = ServeStats {
            remaps: 4,
            quantized: 3,
            quant_visits: 70,
            requant_ops: 80,
            int8_bytes: 9000,
            ..Default::default()
        };
        let mut b = a.clone();
        b.remaps = 5;
        b.int8_bytes = 9001;
        let d = a.diff(&b);
        assert_eq!(d.len(), 2, "{d:?}");
        assert!(d.iter().any(|s| s.contains("remaps: 4 != 5")), "{d:?}");
        assert!(d.iter().any(|s| s.contains("int8_bytes: 9000 != 9001")), "{d:?}");
    }

    #[test]
    fn stats_diff_names_streaming_counters() {
        let a = ServeStats {
            updates: 2,
            max_epoch: 2,
            dirty_subshards: 7,
            rebuilt_edges: 500,
            invalidated: 1,
            compactions: 0,
            ..Default::default()
        };
        let mut b = a.clone();
        b.max_epoch = 3;
        b.compactions = 1;
        let d = a.diff(&b);
        assert_eq!(d.len(), 2, "{d:?}");
        assert!(d.iter().any(|s| s.contains("max_epoch: 2 != 3")), "{d:?}");
        assert!(d.iter().any(|s| s.contains("compactions: 0 != 1")), "{d:?}");
    }

    #[test]
    fn stats_diff_latency_family_is_bit_exact() {
        let a = ServeStats { p50: 0.001, p99: 0.002, mean: 0.0015, ..Default::default() };
        let mut b = a.clone();
        assert!(a.diff(&b).is_empty());
        // One ulp of divergence is a real divergence — the replay
        // guarantee is bit-identity, not tolerance.
        b.p99 = f64::from_bits(a.p99.to_bits() + 1);
        b.makespan = 1e-12;
        let d = a.diff(&b);
        assert_eq!(d.len(), 2, "{d:?}");
        assert!(d[0].starts_with("p99:"), "{d:?}");
        assert!(d[1].starts_with("makespan:"), "{d:?}");
    }

    #[test]
    fn response_diff_names_the_field() {
        let mut c = Coordinator::new(HwConfig::alveo_u250());
        c.run(mixed_workload(4, 2));
        let a = c.responses[0];
        assert!(a.diff(&a).is_empty());
        let mut b = a;
        b.device = a.device + 1;
        b.t_exec += 1e-9;
        b.cache_hit = !a.cache_hit;
        let d = a.diff(&b);
        assert_eq!(d.len(), 3, "{d:?}");
        assert!(d.iter().any(|s| s.starts_with("device:")), "{d:?}");
        assert!(d.iter().any(|s| s.starts_with("t_exec:")), "{d:?}");
        assert!(d.iter().any(|s| s.starts_with("cache_hit:")), "{d:?}");
    }

    #[test]
    fn remap_counters_are_deterministic_and_not_double_counted() {
        let run = |dynamic: bool| {
            let cfg = FleetConfig { dynamic, ..FleetConfig::default() };
            let mut c = Coordinator::fleet(HwConfig::alveo_u250(), cfg);
            let stats = c.run(mixed_workload(30, 5));
            (stats, c.responses)
        };
        let (s1, r1) = run(true);
        let (s2, r2) = run(true);
        assert_eq!(s1, s2);
        assert_eq!(r1, r2);
        // Riders echo their job's remap count but only executed jobs are
        // summed into the stats.
        let executed: u64 = r1.iter().filter(|r| !r.coalesced).map(|r| r.remaps).sum();
        assert_eq!(s1.remaps, executed);
        // Static serving reports zero re-maps everywhere.
        let (s0, r0) = run(false);
        assert_eq!(s0.remaps, 0);
        assert!(r0.iter().all(|r| r.remaps == 0));
        // Dynamic execution times are never slower (memoized per key).
        assert!(s1.makespan <= s0.makespan + 1e-12);
    }

    #[test]
    fn int8_requests_serve_faster_on_their_own_programs() {
        let co = dataset("CO").unwrap();
        let mk = |precision: Precision| -> Vec<Request> {
            (0..6)
                .map(|i| {
                    Request::full(i, ZooModel::B2, co, i as f64 * 1e-3)
                        .with_precision(precision)
                })
                .collect()
        };
        let run = |reqs: Vec<Request>| {
            let mut c = Coordinator::new(HwConfig::alveo_u250());
            let stats = c.run(reqs);
            let compiles: usize = c.devices().iter().map(|d| d.cache_len()).sum();
            (stats, c.responses, compiles)
        };
        let (sf, rf, _) = run(mk(Precision::F32));
        let (sq, rq, _) = run(mk(Precision::Int8));
        assert_eq!(sf.quantized, 0);
        assert_eq!(sq.quantized, 6);
        assert!(sf.quant_visits == 0 && sf.int8_bytes == 0);
        assert!(
            sq.quant_visits > 0 && sq.requant_ops > 0 && sq.int8_bytes > 0,
            "int8 serving must report quantized datapath work"
        );
        // The widened int8 ack plus 1-byte operand traffic makes the
        // modeled execution strictly faster for the same workload.
        let t_f32 = rf.iter().map(|r| r.t_exec).fold(0.0, f64::max);
        let t_int8 = rq.iter().map(|r| r.t_exec).fold(0.0, f64::max);
        assert!(t_int8 < t_f32, "int8 exec {t_int8} !< f32 {t_f32}");
        // Mixed precisions compile one program each and replay
        // bit-identically.
        let mixed = || {
            let mut v = mk(Precision::F32);
            v.extend(mk(Precision::Int8));
            v
        };
        let (s1, r1, compiles) = run(mixed());
        let (s2, r2, _) = run(mixed());
        assert_eq!(s1, s2);
        assert_eq!(r1, r2);
        assert_eq!(compiles, 2, "one program per precision");
        assert_eq!(s1.quantized, 6);
    }

    #[test]
    fn minibatch_requests_sample_bucket_and_batch() {
        // A mini-batch burst over one small dataset: two models, a few
        // buckets, plenty of compatible visits to micro-batch.
        let reqs = minibatch_workload(40, 3, 1e-5);
        let mut c = Coordinator::new(HwConfig::alveo_u250());
        let stats = c.run(reqs);
        assert_eq!(stats.completed, 40);
        assert_eq!(stats.minibatched, 40);
        assert!(stats.sampled_vertices > 0 && stats.sampled_edges > 0);
        // Bucketing: far fewer compiled programs than requests.
        let compiles: usize = c.devices().iter().map(|d| d.cache_len()).sum();
        assert!(compiles <= 12, "{compiles} bucket programs for 40 requests");
        assert_eq!(stats.bucket_hits, 40 - compiles as u64);
        // The tight burst batches at least one visit.
        assert!(stats.batched > 0, "no micro-batching under a tight burst");
        assert_eq!(stats.p50_mini, stats.p50);
        assert_eq!(stats.p50_full, 0.0);
        // Every mini-batch latency includes its sampling stall.
        assert!(c.responses.iter().all(|r| r.t_sample > 0.0));
    }

    #[test]
    fn minibatch_replay_is_bit_identical() {
        let run = || {
            let cfg = FleetConfig { n_devices: 2, ..FleetConfig::default() };
            let mut c = Coordinator::fleet(HwConfig::alveo_u250(), cfg);
            let mut reqs = minibatch_workload(24, 9, 5e-5);
            reqs.extend(mixed_workload(24, 9));
            let stats = c.run(reqs);
            (stats, c.responses)
        };
        let (s1, r1) = run();
        let (s2, r2) = run();
        assert_eq!(s1, s2);
        assert_eq!(r1, r2);
        // Mixed workload: both latency classes are populated.
        assert!(s1.p50_mini > 0.0 && s1.p50_full > 0.0);
        assert_eq!(s1.minibatched, 24);
    }

    #[test]
    fn microbatching_reduces_device_time_without_hurting_latency() {
        let run = |microbatch: bool| {
            let cfg = FleetConfig { microbatch, ..FleetConfig::default() };
            let mut c = Coordinator::fleet(HwConfig::alveo_u250(), cfg);
            c.run(minibatch_workload(32, 5, 1e-6))
        };
        let on = run(true);
        let off = run(false);
        assert!(on.batched > 0);
        assert_eq!(off.batched, 0);
        // Riders share the fixed visit overhead: the fleet does
        // strictly less device work for the same request stream.
        assert!(
            on.device_busy < off.device_busy,
            "batched busy {} !< unbatched {}",
            on.device_busy,
            off.device_busy
        );
        // ...and never at the cost of latency: on a single device the
        // batched schedule dominates (every visit starts no later), so
        // the deterministic percentiles cannot regress.
        assert!(
            on.p50 <= off.p50 + 1e-12 && on.p99 <= off.p99 + 1e-12,
            "batching hurt latency: p50 {} vs {}, p99 {} vs {}",
            on.p50,
            off.p50,
            on.p99,
            off.p99
        );
    }

    #[test]
    fn functional_replay_uses_the_device_arena() {
        use crate::compiler::{compile, CompileOptions};
        use crate::exec::{golden_forward, WeightStore};
        use crate::graph::{rmat::rmat_edges, GraphMeta, PartitionConfig, PartitionedGraph};
        use crate::ir::ZooModel;

        let meta = GraphMeta::new("t", 300, 1500, 32, 4);
        let g = rmat_edges(meta, Default::default(), 9).gcn_normalized();
        let hw = HwConfig::functional_tiles();
        let cfg = PartitionConfig { n1: hw.n1() as u64, n2: hw.n2() as u64 };
        let pg = PartitionedGraph::build(&g, cfg);
        let ir = ZooModel::B1.build(g.meta.clone());
        let exe = compile(&ir, &pg.tile_counts(), &hw, CompileOptions::default());
        let store = WeightStore::deterministic(&exe.ir, 33);
        let x = g.random_features(5);
        let input = crate::engine::EngineInput {
            graph: &g,
            partitioned: &pg,
            store: &store,
            x: &x,
        };
        let fleet = FleetConfig { n_devices: 2, ..FleetConfig::default() };
        let mut c = Coordinator::fleet(hw, fleet);
        assert!(c.functional_replay(7, &exe, &input).is_err(), "bad device id");
        let p1 = c.functional_replay(0, &exe, &input).unwrap();
        let cold_fresh = c.devices()[0].arena.stats().fresh;
        assert!(cold_fresh > 0);
        // The replayed numerics match the golden reference.
        let golden = golden_forward(&exe.ir, &g, &store, &x);
        let out = p1.output.as_ref().unwrap();
        let err = golden
            .iter()
            .zip(out)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(err < 1e-3, "replay vs golden max err {err}");
        // A second replay on the same device is served from its arena.
        let p2 = c.functional_replay(0, &exe, &input).unwrap();
        assert_eq!(p1.output, p2.output);
        let warm_fresh = c.devices()[0].arena.stats().fresh - cold_fresh;
        assert!(warm_fresh <= 1, "warm replay allocated {warm_fresh} buffers");
        // The other device's arena is untouched (per-device pools).
        assert_eq!(c.devices()[1].arena.stats().fresh, 0);
    }

    #[test]
    fn full_neighborhood_minibatch_of_everything_still_buckets() {
        // Degenerate mini-batch: every vertex targeted, full fanout —
        // the ego-net is the whole graph, and the request still routes
        // through the bucket path deterministically.
        let co = dataset("CO").unwrap();
        let all: Vec<u32> = (0..co.n_vertices as u32).collect();
        let rq = Request::minibatch(
            0,
            ZooModel::B1,
            co,
            all,
            vec![FULL_NEIGHBORHOOD],
            1,
            0.0,
        );
        let mut c = Coordinator::new(HwConfig::alveo_u250());
        let stats = c.run(vec![rq]);
        assert_eq!(stats.minibatched, 1);
        assert_eq!(stats.sampled_vertices, co.n_vertices);
        // The serving sampler works over the GCN-normalized graph, so
        // every vertex's self-loop edge is part of the neighborhood.
        assert_eq!(stats.sampled_edges, co.n_edges + co.n_vertices);
        assert_eq!(stats.bucket_hits, 0);
    }

    #[test]
    fn empty_workload() {
        let mut c = Coordinator::new(HwConfig::alveo_u250());
        let stats = c.run(vec![]);
        assert_eq!(stats.completed, 0);
        assert_eq!(stats, ServeStats::default());
    }

    #[test]
    fn updates_interleave_and_invalidate_selectively() {
        let co = dataset("CO").unwrap();
        let mut reqs: Vec<Request> = (0..10)
            .map(|i| Request::full(0, ZooModel::B1, co, i as f64 * 1e-3))
            .collect();
        // One churn batch lands mid-trace: requests before it serve
        // epoch 0, requests after it recompile against epoch 1.
        reqs.push(Request::update(0, co, 64, 8, 0, 1, 5.5e-3));
        let mut c = Coordinator::new(HwConfig::alveo_u250());
        let stats = c.run(reqs);
        assert_eq!(stats.completed, 11);
        assert_eq!(stats.updates, 1);
        assert_eq!(stats.max_epoch, 1);
        assert_eq!(c.epoch_of("CO"), 1);
        assert!(stats.dirty_subshards >= 1);
        assert!(stats.rebuilt_edges > 0);
        // Exactly two compiles (epoch 0 once, epoch 1 once); the stale
        // epoch-0 program was selectively invalidated at the update.
        assert_eq!(stats.cache_hits, 8);
        assert_eq!(stats.invalidated, 1);
        let resident: usize = c.devices().iter().map(|d| d.cache_len()).sum();
        assert_eq!(resident, 1, "only the epoch-1 program stays resident");
        // Epochs stamp the responses in arrival order.
        let epochs: Vec<u32> = c.responses.iter().filter(|r| !r.update).map(|r| r.epoch).collect();
        assert_eq!(&epochs[..6], &[0; 6]);
        assert_eq!(&epochs[6..], &[1; 4]);
        // Update latency is the modeled apply cost, and update
        // responses stay out of the inference latency classes.
        let upd = c.responses.iter().find(|r| r.update).unwrap();
        assert!(upd.t_update > 0.0 && upd.latency == upd.t_update);
        assert!(stats.p50_full > 0.0);
        assert_eq!(stats.p50_mini, 0.0);
    }

    #[test]
    fn streaming_replays_and_bucket_cache_survives_epochs() {
        let co = dataset("CO").unwrap();
        let build = || {
            let mut reqs: Vec<Request> = (0..30)
                .map(|i| {
                    Request::minibatch(
                        i % 3,
                        ZooModel::B1,
                        co,
                        vec![(i * 53) % 2708],
                        vec![6, 3],
                        i as u64,
                        i as f64 * 1e-3,
                    )
                })
                .collect();
            reqs.push(Request::update(0, co, 40, 10, 0, 7, 0.0101));
            reqs.push(Request::update(0, co, 40, 10, 2, 8, 0.0202));
            reqs
        };
        let run = |reqs: Vec<Request>| {
            let mut c = Coordinator::new(HwConfig::alveo_u250());
            let stats = c.run(reqs);
            (stats, c.responses)
        };
        let (s1, r1) = run(build());
        let (s2, r2) = run(build());
        assert_eq!(s1, s2, "update-interleaved serving must replay bit-identically");
        assert_eq!(r1, r2);
        assert_eq!(s1.updates, 2);
        assert_eq!(s1.max_epoch, 2);
        assert_eq!(s1.minibatched, 30);
        // Bucket programs are shape-only: the epoch bumps invalidated
        // nothing (no whole-graph program exists) and the bucket hit
        // rate matches the same trace served without any updates.
        assert_eq!(s1.invalidated, 0);
        let no_updates: Vec<Request> =
            build().into_iter().filter(|r| !r.target.is_update()).collect();
        let (s0, _) = run(no_updates);
        assert_eq!(s1.bucket_hits, s0.bucket_hits, "churn must not evict buckets");
        // Post-update samples read the churned epoch.
        assert!(r1.iter().filter(|r| r.minibatch).any(|r| r.epoch > 0));
    }

    #[test]
    fn update_only_workload_has_empty_latency_classes() {
        let co = dataset("CO").unwrap();
        let reqs = vec![
            Request::update(0, co, 16, 4, 0, 1, 0.0),
            Request::update(0, co, 16, 4, 0, 2, 1e-3),
        ];
        let mut c = Coordinator::new(HwConfig::alveo_u250());
        let stats = c.run(reqs);
        assert_eq!(stats.completed, 2);
        assert_eq!(stats.updates, 2);
        assert_eq!(stats.max_epoch, 2);
        // No inference: every latency statistic reads 0, no panics.
        assert_eq!(stats.p50, 0.0);
        assert_eq!(stats.p99, 0.0);
        assert_eq!(stats.mean, 0.0);
        assert_eq!(c.hit_rate(), 0.0);
        // The virtual clock still advanced through the apply costs.
        assert!(stats.makespan > 0.0);
    }

    #[test]
    fn stats_diff_names_fault_counters() {
        let a = ServeStats {
            retries: 3,
            rerouted: 2,
            degraded: 1,
            shed: 1,
            crashes: 2,
            stalls: 1,
            corruptions: 1,
            downtime: 0.5,
            t_backoff: 0.015,
            ..Default::default()
        };
        let mut b = a.clone();
        assert!(a.diff(&b).is_empty());
        b.retries = 4;
        b.shed = 0;
        b.downtime = 0.25;
        let d = a.diff(&b);
        assert_eq!(d.len(), 3, "{d:?}");
        assert!(d.iter().any(|s| s.contains("retries: 3 != 4")), "{d:?}");
        assert!(d.iter().any(|s| s.contains("shed: 1 != 0")), "{d:?}");
        assert!(d.iter().any(|s| s.contains("downtime: 0.5 != 0.25")), "{d:?}");
    }

    #[test]
    fn response_diff_names_fault_fields() {
        let mut c = Coordinator::new(HwConfig::alveo_u250());
        c.run(mixed_workload(2, 2));
        let a = c.responses[0];
        let mut b = a;
        b.retries = 2;
        b.rerouted = true;
        b.t_backoff = 1e-3;
        b.outcome = Outcome::Shed(ShedReason::RetriesExhausted);
        let d = a.diff(&b);
        assert_eq!(d.len(), 4, "{d:?}");
        assert!(d.iter().any(|s| s.starts_with("retries:")), "{d:?}");
        assert!(d.iter().any(|s| s.starts_with("rerouted:")), "{d:?}");
        assert!(d.iter().any(|s| s.starts_with("t_backoff:")), "{d:?}");
        assert!(d.iter().any(|s| s.starts_with("outcome:")), "{d:?}");
    }

    #[test]
    fn empty_fault_plan_serves_byte_identically() {
        let run = |plan: Option<FaultPlan>| {
            let cfg = FleetConfig { n_devices: 2, ..FleetConfig::default() };
            let mut c = Coordinator::fleet(HwConfig::alveo_u250(), cfg);
            if let Some(p) = plan {
                c.set_fault_plan(p);
            }
            let mut reqs = mixed_workload(24, 13);
            reqs.extend(minibatch_workload(12, 13, 1e-4));
            let stats = c.run(reqs);
            let none = c.fault_plan().is_none();
            (stats, c.responses, none)
        };
        let (s0, r0, _) = run(None);
        let (s1, r1, none) = run(Some(FaultPlan::empty()));
        assert_eq!(s0, s1);
        assert_eq!(r0, r1);
        assert!(none, "an empty plan must not activate the fault path");
        assert!(r1
            .iter()
            .all(|r| r.outcome == Outcome::Completed && r.retries == 0 && !r.rerouted));
    }

    #[test]
    fn crash_mid_execution_retries_on_another_device() {
        let co = dataset("CO").unwrap();
        let cfg = FleetConfig {
            n_devices: 2,
            costs: CostModel { deadline_s: f64::INFINITY, ..CostModel::default() },
            ..FleetConfig::default()
        };
        // Probe run: when does the second (cache-warm) request execute?
        let mut probe = Coordinator::fleet(HwConfig::alveo_u250(), cfg);
        let r0 = probe.admit(Request::full(0, ZooModel::B1, co, 0.0));
        let t1 = r0.latency + 1.0;
        let r1 = probe.admit(Request::full(1, ZooModel::B1, co, t1));
        assert!(r1.cache_hit && r1.t_exec > 0.0);
        // Same workload, but device 0 dies halfway through that exec.
        let crash_at = t1 + r1.t_exec * 0.5;
        let mut c = Coordinator::fleet(HwConfig::alveo_u250(), cfg);
        c.set_fault_plan(FaultPlan {
            seed: 0,
            events: vec![FaultEvent::DeviceCrash {
                device: 0,
                at: crash_at,
                recover_after: 1.0,
            }],
        });
        let a = c.admit(Request::full(0, ZooModel::B1, co, 0.0));
        assert_eq!(a.outcome, Outcome::Completed);
        assert_eq!(a.device, 0, "the calendar is clear at time zero");
        let b = c.admit(Request::full(1, ZooModel::B1, co, t1));
        assert_eq!(b.outcome, Outcome::Completed);
        assert_eq!(b.retries, 1);
        assert!(b.rerouted);
        assert_eq!(b.device, 1, "the retry re-routes to the healthy device");
        assert!(!b.cache_hit, "the rescue device is cold and recompiles");
        assert_eq!(b.t_backoff, CostModel::default().backoff(1));
        let s = c.stats();
        assert_eq!(s.crashes, 1);
        assert_eq!(s.retries, 1);
        assert_eq!(s.rerouted, 1);
        assert_eq!(s.shed, 0);
        assert_eq!(s.downtime, 1.0);
        assert_eq!(c.fault_log().len(), 1);
        // The crashed device's window ends; the fleet keeps serving.
        let d = c.admit(Request::full(0, ZooModel::B1, co, crash_at + 1.5));
        assert_eq!(d.outcome, Outcome::Completed);
    }

    #[test]
    fn fleet_loss_sheds_with_named_reasons() {
        let co = dataset("CO").unwrap();
        // Permanent loss of the only device: nowhere to route.
        let mut c = Coordinator::new(HwConfig::alveo_u250());
        c.set_fault_plan(FaultPlan {
            seed: 0,
            events: vec![FaultEvent::DeviceCrash {
                device: 0,
                at: 0.0,
                recover_after: -1.0,
            }],
        });
        let r = c.admit(Request::full(3, ZooModel::B1, co, 0.1));
        assert_eq!(r.outcome, Outcome::Shed(ShedReason::NoHealthyDevice));
        assert_eq!(r.device, u32::MAX);
        let s = c.stats();
        assert_eq!(s.shed, 1);
        assert_eq!(s.completed, 0, "a shed request never counts as completed");
        assert_eq!(s.crashes, 1);
        assert_eq!(s.p50, 0.0, "shed pseudo-latencies stay out of the percentiles");
        assert_eq!(c.decision_log().len(), 1);
        assert_eq!(c.decision_log()[0].tenant, 3);

        // A zero-retry budget sheds on the first crashed attempt.
        let costs = CostModel {
            max_retries: 0,
            deadline_s: f64::INFINITY,
            ..CostModel::default()
        };
        let cfg = FleetConfig { costs, ..FleetConfig::default() };
        let mut probe = Coordinator::fleet(HwConfig::alveo_u250(), cfg);
        let p = probe.admit(Request::full(0, ZooModel::B1, co, 0.0));
        // Crash inside the probe's execution window, so the quoted
        // attempt crosses it instead of starting after recovery.
        let crash_at = p.t_compile + p.t_exec * 0.5;
        let mut c = Coordinator::fleet(HwConfig::alveo_u250(), cfg);
        c.set_fault_plan(FaultPlan {
            seed: 0,
            events: vec![FaultEvent::DeviceCrash {
                device: 0,
                at: crash_at,
                recover_after: 5.0,
            }],
        });
        let r = c.admit(Request::full(0, ZooModel::B1, co, 0.0));
        assert_eq!(r.outcome, Outcome::Shed(ShedReason::RetriesExhausted));
        assert_eq!(r.retries, 0, "a zero budget performs zero retries");
        assert_eq!(c.stats().shed, 1);
    }

    #[test]
    fn deadline_pressure_walks_the_fidelity_cascade() {
        let co = dataset("CO").unwrap();
        // A zero deadline forces the cascade on every request; a
        // far-future stall keeps the fault path active without any
        // actual outage.
        let costs = CostModel { deadline_s: 0.0, ..CostModel::default() };
        let cfg = FleetConfig { costs, ..FleetConfig::default() };
        let idle_plan = FaultPlan {
            seed: 0,
            events: vec![FaultEvent::TransientStall {
                device: 0,
                at: 1e9,
                duration: 1.0,
            }],
        };
        let mut c = Coordinator::fleet(HwConfig::alveo_u250(), cfg);
        c.set_fault_plan(idle_plan.clone());
        let full = c.admit(Request::full(0, ZooModel::B1, co, 0.0));
        assert_eq!(full.outcome, Outcome::Degraded(Degradation::Int8));
        assert_eq!(full.precision, Precision::Int8, "served on the GA03 datapath");
        let mini = c.admit(Request::minibatch(
            0,
            ZooModel::B1,
            co,
            vec![7, 11, 13],
            vec![64, 64],
            5,
            1.0,
        ));
        assert_eq!(mini.outcome, Outcome::Degraded(Degradation::Int8CappedFanout));
        assert_eq!(mini.precision, Precision::Int8);
        let s = c.stats();
        assert_eq!(s.degraded, 2);
        assert_eq!(s.shed, 0);
        assert_eq!(s.completed, 2, "degraded requests still complete");
        assert_eq!(c.decision_log().len(), 2);

        // An int8 tenant under the same pressure caps fanout only, and
        // the capped re-sample shrinks the ego-net while paying for
        // both samples.
        let mut c2 = Coordinator::fleet(HwConfig::alveo_u250(), cfg);
        c2.set_fault_plan(idle_plan);
        let q = c2.admit(
            Request::minibatch(0, ZooModel::B1, co, vec![7], vec![64, 64], 5, 0.0)
                .with_precision(Precision::Int8),
        );
        assert_eq!(q.outcome, Outcome::Degraded(Degradation::CappedFanout));
        let mut probe = Coordinator::new(HwConfig::alveo_u250());
        let p = probe.admit(Request::minibatch(
            0,
            ZooModel::B1,
            co,
            vec![7],
            vec![64, 64],
            5,
            0.0,
        ));
        assert!(q.sampled_edges <= p.sampled_edges);
        assert!(q.t_sample > p.t_sample);
    }

    #[test]
    fn transient_stall_stretches_latency_without_losing_work() {
        let co = dataset("CO").unwrap();
        let cfg = FleetConfig {
            costs: CostModel { deadline_s: f64::INFINITY, ..CostModel::default() },
            ..FleetConfig::default()
        };
        // Probe where a warm request executes, then stall across it.
        let mut probe = Coordinator::fleet(HwConfig::alveo_u250(), cfg);
        let r0 = probe.admit(Request::full(0, ZooModel::B1, co, 0.0));
        let t1 = r0.latency + 1.0;
        let r1 = probe.admit(Request::full(0, ZooModel::B1, co, t1));
        let stall = 0.3;
        let mut c = Coordinator::fleet(HwConfig::alveo_u250(), cfg);
        c.set_fault_plan(FaultPlan {
            seed: 0,
            events: vec![FaultEvent::TransientStall {
                device: 0,
                at: t1 + r1.t_exec * 0.5,
                duration: stall,
            }],
        });
        c.admit(Request::full(0, ZooModel::B1, co, 0.0));
        let b = c.admit(Request::full(0, ZooModel::B1, co, t1));
        assert_eq!(b.outcome, Outcome::Completed);
        assert_eq!(b.retries, 0, "a stall pauses work, it does not kill it");
        assert!(
            (b.latency - (r1.latency + stall)).abs() < 1e-9,
            "the stall stretches completion: {} vs {} + {stall}",
            b.latency,
            r1.latency
        );
        assert_eq!(b.t_exec, r1.t_exec, "no work is lost or redone");
        // The stall event itself fires once the cursor passes it.
        let d = c.admit(Request::full(0, ZooModel::B1, co, t1 + 10.0));
        assert_eq!(d.outcome, Outcome::Completed);
        assert_eq!(c.stats().stalls, 1);
        assert_eq!(c.fault_log().len(), 1);
    }

    #[test]
    fn armed_corruption_evicts_and_recompiles_in_situ() {
        let co = dataset("CO").unwrap();
        let cfg = FleetConfig {
            costs: CostModel { deadline_s: f64::INFINITY, ..CostModel::default() },
            ..FleetConfig::default()
        };
        let mut probe = Coordinator::fleet(HwConfig::alveo_u250(), cfg);
        let r0 = probe.admit(Request::full(0, ZooModel::B1, co, 0.0));
        let t1 = r0.latency + 1.0;
        let mut c = Coordinator::fleet(HwConfig::alveo_u250(), cfg);
        c.set_fault_plan(FaultPlan {
            seed: 0,
            events: vec![FaultEvent::ArtifactCorruption {
                device: 0,
                at: t1 * 0.5,
                model: ZooModel::B1,
                dataset: "CO".to_string(),
            }],
        });
        let a = c.admit(Request::full(0, ZooModel::B1, co, 0.0));
        assert!(!a.cache_hit);
        // The corruption bites on the next access: the poisoned bytes
        // fail the loader's validation, the artifact is evicted, and
        // the request recompiles and still completes.
        let b = c.admit(Request::full(0, ZooModel::B1, co, t1));
        assert!(!b.cache_hit, "the poisoned artifact was evicted, not served");
        assert!(b.t_compile > 0.0, "the recompile is paid for");
        assert_eq!(b.outcome, Outcome::Completed);
        assert_eq!(c.stats().corruptions, 1);
        // One bite only: later requests hit the fresh artifact.
        let d = c.admit(Request::full(0, ZooModel::B1, co, t1 + 1.0));
        assert!(d.cache_hit);
        assert_eq!(c.stats().corruptions, 1);
    }

    #[test]
    fn faulty_serving_replays_bit_identically() {
        let run = || {
            let cfg = FleetConfig { n_devices: 3, ..FleetConfig::default() };
            let mut c = Coordinator::fleet(HwConfig::alveo_u250(), cfg);
            let mut reqs = mixed_workload(30, 17);
            reqs.extend(minibatch_workload(20, 17, 1e-4));
            // A late probe past the plan horizon flushes the event
            // cursor, so every scheduled fault deterministically fires.
            reqs.push(Request::full(0, ZooModel::B1, dataset("CO").unwrap(), 1.0));
            c.set_fault_plan(FaultPlan::crash_and_recover(41, 3, 6e-3));
            let stats = c.run(reqs);
            let faults = c.fault_log().to_vec();
            let decisions = c.decision_log().to_vec();
            (stats, c.responses, faults, decisions)
        };
        let (s1, r1, f1, d1) = run();
        let (s2, r2, f2, d2) = run();
        assert_eq!(s1, s2);
        assert_eq!(r1, r2);
        assert_eq!(f1, f2);
        assert_eq!(d1, d2);
        assert_eq!(s1.crashes, 2, "both scheduled crashes fired");
        assert_eq!(s1.stalls, 1);
        // Every accepted request is accounted for: completed, degraded
        // or shed — never lost.
        assert_eq!(r1.len(), 51);
        assert_eq!(
            s1.completed + s1.shed,
            51,
            "every request ends in exactly one terminal state"
        );
    }

    fn tenant_trio() -> TenantConfig {
        TenantConfig {
            tenants: vec![
                Tenant { id: 0, weight: 4.0, deadline_s: None, class: PriorityClass::Premium },
                Tenant { id: 1, weight: 2.0, deadline_s: None, class: PriorityClass::Standard },
                Tenant {
                    id: 2,
                    weight: 1.0,
                    deadline_s: Some(0.05),
                    class: PriorityClass::BestEffort,
                },
            ],
        }
    }

    #[test]
    fn empty_tenant_config_serves_byte_identically() {
        let run = |tenants: Option<TenantConfig>| {
            let cfg = FleetConfig { n_devices: 2, ..FleetConfig::default() };
            let mut c = Coordinator::fleet(HwConfig::alveo_u250(), cfg);
            if let Some(t) = tenants {
                c.set_tenants(t);
            }
            let mut reqs = mixed_workload(24, 13);
            reqs.extend(minibatch_workload(12, 13, 1e-4));
            let stats = c.run(reqs);
            let none = c.tenants().is_none();
            (stats, c.responses, none)
        };
        let (s0, r0, _) = run(None);
        let (s1, r1, none) = run(Some(TenantConfig::empty()));
        assert_eq!(s0, s1);
        assert_eq!(r0, r1);
        assert!(none, "an empty config must not activate the QoS path");
        assert!(s1.tenants.is_empty(), "no per-tenant families without a config");
        assert!(r1.iter().all(|r| r.t_qos == 0.0 && !r.deadline_missed));
    }

    #[test]
    #[should_panic(expected = "mutually exclusive")]
    fn tenant_config_and_fault_plan_are_mutually_exclusive() {
        let mut c = Coordinator::new(HwConfig::alveo_u250());
        c.set_fault_plan(FaultPlan::crash_and_recover(41, 3, 6e-3));
        c.set_tenants(tenant_trio());
    }

    #[test]
    fn premium_backfills_ahead_of_paced_best_effort() {
        let co = dataset("CO").unwrap();
        let cfg = FleetConfig { n_devices: 1, ..FleetConfig::default() };
        let mut c = Coordinator::fleet(HwConfig::alveo_u250(), cfg);
        c.set_tenants(TenantConfig {
            tenants: vec![
                Tenant { id: 0, weight: 4.0, deadline_s: None, class: PriorityClass::Premium },
                Tenant { id: 9, weight: 1.0, deadline_s: None, class: PriorityClass::BestEffort },
            ],
        });
        // A best-effort flood, then one premium arrival mid-burst.
        let mut reqs: Vec<Request> = (0..6)
            .map(|i| Request::full(9, ZooModel::B1, co, i as f64 * 1e-5))
            .collect();
        reqs.push(Request::full(0, ZooModel::B1, co, 2.5e-5));
        c.run(reqs);
        let premium: Vec<&Response> = c.responses.iter().filter(|r| r.tenant == 0).collect();
        let flood: Vec<&Response> = c.responses.iter().filter(|r| r.tenant == 9).collect();
        assert_eq!(premium.len(), 1);
        assert_eq!(premium[0].t_qos, 0.0, "premium is never paced");
        assert!(
            flood.iter().skip(1).all(|r| r.t_qos > 0.0),
            "the flood is paced to its reserved rate"
        );
        let worst_flood = flood.iter().map(|r| r.latency).fold(0.0, f64::max);
        assert!(
            premium[0].latency < worst_flood,
            "premium ({}) must undercut the paced flood ({worst_flood})",
            premium[0].latency
        );
        assert!(
            c.qos_preemptions() > 0,
            "the premium visit backfills a gap ahead of reserved work"
        );
        assert!(c.responses.iter().all(|r| r.outcome == Outcome::Completed));
        let s = c.stats();
        assert_eq!(s.tenants.len(), 2);
        let p = s.tenants.iter().find(|t| t.tenant == 0).unwrap();
        assert_eq!((p.weight, p.missed, p.shed), (4.0, 0, 0));
        let b = s.tenants.iter().find(|t| t.tenant == 9).unwrap();
        assert!(b.t_qos > 0.0, "the flood's pacing delay is accounted per tenant");
    }

    #[test]
    fn qos_deadline_walks_cascade_and_sheds_best_effort() {
        let co = dataset("CO").unwrap();
        // A hopeless deadline forces the full cascade on every request.
        let mut c = Coordinator::new(HwConfig::alveo_u250());
        c.set_tenants(TenantConfig {
            tenants: vec![
                Tenant {
                    id: 1,
                    weight: 1.0,
                    deadline_s: Some(1e-9),
                    class: PriorityClass::Standard,
                },
                Tenant {
                    id: 2,
                    weight: 1.0,
                    deadline_s: Some(1e-9),
                    class: PriorityClass::BestEffort,
                },
            ],
        });
        // Standard is never shed: it serves late at degraded fidelity.
        let a = c.admit(Request::full(1, ZooModel::B1, co, 0.0));
        assert_eq!(a.outcome, Outcome::Degraded(Degradation::Int8));
        assert_eq!(a.precision, Precision::Int8, "served on the GA03 datapath");
        assert!(a.deadline_missed);
        // Best effort under the same pressure sheds with a named reason.
        let b = c.admit(Request::full(2, ZooModel::B1, co, 1e-4));
        assert_eq!(b.outcome, Outcome::Shed(ShedReason::DeadlineMissed));
        assert!(b.deadline_missed);
        assert_eq!(b.device, u32::MAX);
        // Mini-batch walks both rungs before the verdict.
        let m = c.admit(Request::minibatch(
            1,
            ZooModel::B1,
            co,
            vec![7, 11],
            vec![64, 64],
            5,
            2e-4,
        ));
        assert_eq!(m.outcome, Outcome::Degraded(Degradation::Int8CappedFanout));
        assert!(m.deadline_missed);
        let bm = c.admit(Request::minibatch(2, ZooModel::B1, co, vec![7], vec![64, 64], 5, 3e-4));
        assert_eq!(bm.outcome, Outcome::Shed(ShedReason::DeadlineMissed));
        assert!(bm.t_sample > 0.0, "the shed bills the sampling already done");
        let s = c.stats();
        assert_eq!((s.shed, s.degraded, s.completed), (2, 2, 2));
        assert_eq!(c.decision_log().len(), 4);
        let t1 = s.tenants.iter().find(|t| t.tenant == 1).unwrap();
        let t2 = s.tenants.iter().find(|t| t.tenant == 2).unwrap();
        assert_eq!((t1.degraded, t1.missed, t1.shed), (2, 2, 0));
        assert_eq!((t2.shed, t2.completed), (2, 0));
    }

    #[test]
    fn stats_diff_names_tenant_families() {
        let a = ServeStats {
            tenants: vec![
                TenantStats { tenant: 0, weight: 4.0, completed: 5, p99: 1e-3, ..Default::default() },
                TenantStats { tenant: 2, weight: 1.0, shed: 1, ..Default::default() },
            ],
            ..Default::default()
        };
        let mut b = a.clone();
        assert!(a.diff(&b).is_empty());
        b.tenants[0].p99 = 2e-3;
        b.tenants[1].shed = 2;
        let d = a.diff(&b);
        assert_eq!(d.len(), 2, "{d:?}");
        assert!(
            d.iter().any(|s| s.contains("tenants[0].p99: 0.001 != 0.002")),
            "{d:?}"
        );
        assert!(d.iter().any(|s| s.contains("tenants[1].shed: 1 != 2")), "{d:?}");
        b.tenants.pop();
        assert!(
            a.diff(&b).iter().any(|s| s.contains("tenants.len: 2 != 1")),
            "{:?}",
            a.diff(&b)
        );
    }

    #[test]
    fn response_diff_names_qos_fields() {
        let mut c = Coordinator::new(HwConfig::alveo_u250());
        c.run(mixed_workload(2, 2));
        let a = c.responses[0];
        let mut b = a;
        b.t_qos = 1e-3;
        b.deadline_missed = true;
        let d = a.diff(&b);
        assert_eq!(d.len(), 2, "{d:?}");
        assert!(d.iter().any(|s| s.starts_with("t_qos:")), "{d:?}");
        assert!(d.iter().any(|s| s.starts_with("deadline_missed:")), "{d:?}");
    }

    #[test]
    fn qos_serving_replays_bit_identically() {
        let run = || {
            let cfg = FleetConfig { n_devices: 3, ..FleetConfig::default() };
            let mut c = Coordinator::fleet(HwConfig::alveo_u250(), cfg);
            c.set_tenants(tenant_trio());
            let mut reqs = mixed_workload(30, 17);
            reqs.extend(minibatch_workload(20, 17, 1e-4));
            let stats = c.run(reqs);
            let decisions = c.decision_log().to_vec();
            (stats, c.responses, decisions, c.qos_preemptions())
        };
        let (s1, r1, d1, p1) = run();
        let (s2, r2, d2, p2) = run();
        assert_eq!(s1, s2);
        assert_eq!(r1, r2);
        assert_eq!(d1, d2);
        assert_eq!(p1, p2);
        assert!(!s1.tenants.is_empty(), "per-tenant families exist under a config");
        assert_eq!(
            s1.completed + s1.shed,
            50,
            "every request ends in exactly one terminal state"
        );
        assert!(r1.iter().any(|r| r.t_qos > 0.0), "somebody pays a pacing delay");
    }
}
