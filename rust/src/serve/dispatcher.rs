//! Routing policy for the serving fleet: cross-request coalescing of
//! identical in-flight work, then cache-affinity device selection.
//! Pure functions over device state — all tie-breaks are by device id,
//! so routing is deterministic.

use super::cache::Key;
use super::device::Device;

/// Where a request goes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    /// Dispatch to a device (compile-or-hit there, then queue).
    Device(usize),
    /// Ride an identical not-yet-started job: (device id, job index).
    /// One execution serves many responses.
    Coalesce(usize, usize),
}

#[derive(Clone, Copy, Debug)]
pub struct Dispatcher {
    /// Prefer devices whose cache already holds the (model, graph)
    /// program over the globally least-loaded device.
    pub affinity: bool,
    /// Merge requests identical to a job that has not started yet.
    pub coalesce: bool,
}

impl Dispatcher {
    pub fn route(&self, devices: &[Device], key: &Key, arrival: f64) -> Route {
        let target = self.dispatch_device(devices, key, arrival);
        if self.coalesce {
            // An identical job that has not started by `arrival` can
            // serve this request too; pick the one finishing first. Only
            // each device's pending tail is scanned (the coordinator
            // retires started jobs before routing).
            let mut best: Option<(f64, usize, usize)> = None;
            for d in devices {
                for (j, job) in d.pending_jobs() {
                    if job.key == *key && job.start >= arrival {
                        let cand = (job.done, d.id, j);
                        if best.map_or(true, |b| cand < b) {
                            best = Some(cand);
                        }
                    }
                }
            }
            if let Some((done, dev, j)) = best {
                // Ride only when it finishes no later than dispatching
                // the same work fresh would: same key ⇒ same exec time,
                // so compare against the target device's queue floor
                // (a cold dispatch also pays a compile, conservatively
                // counted as 0 here — under-coalescing only costs a
                // duplicate execution, never latency).
                let floor = devices[target].free_at.max(arrival);
                let t_exec = devices[dev].jobs[j].t_exec;
                if done <= floor + t_exec {
                    return Route::Coalesce(dev, j);
                }
            }
        }
        Route::Device(target)
    }

    /// The device a fresh dispatch would go to: cache-warm first (when
    /// affinity is on), else least-loaded; ties to the lowest id.
    fn dispatch_device(&self, devices: &[Device], key: &Key, arrival: f64) -> usize {
        let pick = |warm_only: bool| -> Option<usize> {
            devices
                .iter()
                .filter(|d| !warm_only || d.is_warm(key))
                .map(|d| (d.free_at.max(arrival), d.id))
                .min_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)))
                .map(|(_, id)| id)
        };
        if self.affinity {
            // Warm devices skip the compile entirely; even a queued warm
            // device usually beats a cold one (compile >> queue at the
            // paper's request rates), and keeping keys sticky maximizes
            // fleet-wide hit rate.
            if let Some(id) = pick(true) {
                return id;
            }
        }
        pick(false).expect("fleet has at least one device")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HwConfig;
    use crate::graph::dataset;
    use crate::ir::ZooModel;

    fn fleet(n: usize) -> Vec<Device> {
        (0..n).map(|i| Device::new(i, HwConfig::alveo_u250())).collect()
    }

    #[test]
    fn cold_fleet_routes_to_least_loaded() {
        let mut devs = fleet(3);
        devs[0].free_at = 5.0;
        devs[1].free_at = 1.0;
        devs[2].free_at = 3.0;
        let d = Dispatcher { affinity: true, coalesce: true };
        let key = (ZooModel::B1, "CO");
        assert_eq!(d.route(&devs, &key, 0.0), Route::Device(1));
    }

    #[test]
    fn affinity_prefers_warm_device() {
        let mut devs = fleet(2);
        let co = dataset("CO").unwrap();
        let mut exec = |_: &crate::compiler::Executable| 1e-4;
        devs[1].admit(0.0, ZooModel::B1, &co, &mut exec);
        // Device 1 is warm but busier; affinity still picks it.
        let key = (ZooModel::B1, "CO");
        let arrival = devs[1].free_at + 1.0; // after its job started
        let on = Dispatcher { affinity: true, coalesce: false };
        let off = Dispatcher { affinity: false, coalesce: false };
        assert_eq!(on.route(&devs, &key, arrival), Route::Device(1));
        // Without affinity the tie on (idle, idle) breaks to device 0.
        assert_eq!(off.route(&devs, &key, arrival), Route::Device(0));
    }

    #[test]
    fn coalesce_rides_unstarted_identical_job() {
        let mut devs = fleet(2);
        let co = dataset("CO").unwrap();
        let mut exec = |_: &crate::compiler::Executable| 1e-4;
        let (_, j) = devs[0].admit(0.0, ZooModel::B1, &co, &mut exec);
        let start = devs[0].jobs[j].start;
        let d = Dispatcher { affinity: true, coalesce: true };
        let key = (ZooModel::B1, "CO");
        // Before the job starts: ride it.
        assert_eq!(d.route(&devs, &key, start * 0.5), Route::Coalesce(0, j));
        // After it started: a fresh dispatch (warm, device 0).
        assert_eq!(d.route(&devs, &key, start + 1.0), Route::Device(0));
        // Different key never coalesces.
        let other = (ZooModel::B2, "CO");
        assert!(matches!(d.route(&devs, &other, start * 0.5), Route::Device(_)));
    }

    #[test]
    fn ride_rejected_when_idle_device_finishes_sooner() {
        // Device 0 is warm but has a deep queue; device 1 is idle. With
        // affinity off the dispatch target is the idle device, and the
        // ride (behind the queue) would finish later — so no coalesce.
        let mut devs = fleet(2);
        let co = dataset("CO").unwrap();
        let mut exec = |_: &crate::compiler::Executable| 1.0;
        devs[0].admit(0.0, ZooModel::B1, &co, &mut exec); // running by 0.5
        let (_, j) = devs[0].admit(0.0, ZooModel::B1, &co, &mut exec); // queued
        let key = (ZooModel::B1, "CO");
        let off = Dispatcher { affinity: false, coalesce: true };
        assert_eq!(off.route(&devs, &key, 0.5), Route::Device(1));
        // With affinity the dispatch target is the warm (queued) device
        // itself, so riding the queued job ties on completion and wins
        // by not duplicating the execution.
        let on = Dispatcher { affinity: true, coalesce: true };
        assert_eq!(on.route(&devs, &key, 0.5), Route::Coalesce(0, j));
    }
}
