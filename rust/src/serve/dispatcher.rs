//! Routing policy for the serving fleet: cross-request coalescing of
//! identical in-flight work, cache-affinity device selection, and
//! micro-batching of compatible mini-batch requests into one device
//! visit. Pure functions over device state — all tie-breaks are by
//! device id, so routing is deterministic.

use super::cache::Key;
use super::device::Device;

/// Where a request goes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    /// Dispatch to a device (compile-or-hit there, then queue).
    Device(usize),
    /// Ride an identical not-yet-started job: (device id, job index).
    /// One execution serves many responses.
    Coalesce(usize, usize),
    /// Micro-batch onto a compatible not-yet-started mini-batch visit:
    /// (device id, job index). The rider adds its own execution time
    /// but shares the visit overhead and compile stall.
    Batch(usize, usize),
}

/// The fleet's routing policy knobs (all default-on under
/// [`FleetConfig`](super::coordinator::FleetConfig)).
#[derive(Clone, Copy, Debug)]
pub struct Dispatcher {
    /// Prefer devices whose cache already holds the requested program
    /// over the globally least-loaded device.
    pub affinity: bool,
    /// Merge requests identical to a job that has not started yet.
    pub coalesce: bool,
    /// Micro-batch compatible mini-batch requests into one device
    /// visit.
    pub microbatch: bool,
}

impl Dispatcher {
    /// Route a whole-graph request: ride an identical unstarted job
    /// when coalescing is on and the ride finishes no later than a
    /// fresh dispatch, else dispatch fresh (warm-first under affinity,
    /// else least-loaded).
    pub fn route(&self, devices: &[Device], key: &Key, arrival: f64) -> Route {
        let target = self.dispatch_device(devices, key, arrival);
        if self.coalesce {
            // An identical job that has not started by `arrival` can
            // serve this request too; pick the one finishing first. Only
            // each device's pending tail is scanned (the coordinator
            // retires started jobs before routing).
            let mut best: Option<(f64, usize, usize)> = None;
            for d in devices {
                for (j, job) in d.pending_jobs() {
                    if job.key == *key && job.start >= arrival {
                        let cand = (job.done, d.id, j);
                        if best.map_or(true, |b| cand < b) {
                            best = Some(cand);
                        }
                    }
                }
            }
            if let Some((done, dev, j)) = best {
                // Ride only when it finishes no later than dispatching
                // the same work fresh would: same key ⇒ same exec time,
                // so compare against the target device's queue floor
                // (a cold dispatch also pays a compile, conservatively
                // counted as 0 here — under-coalescing only costs a
                // duplicate execution, never latency).
                let floor = devices[target].free_at.max(arrival);
                let t_exec = devices[dev].jobs[j].t_exec;
                if done <= floor + t_exec {
                    return Route::Coalesce(dev, j);
                }
            }
        }
        Route::Device(target)
    }

    /// Mini-batch routing: the device choice is the same as a fresh
    /// dispatch, but when that device's *tail* job is a pending visit
    /// for the same bucket, the request rides it. `ready` is the
    /// earliest time the rider's work exists (arrival + its sampling
    /// stall): a visit that starts before `ready` would execute an
    /// ego-net not yet sampled, so it cannot be ridden. Extending the
    /// tail can never delay other jobs (nothing is queued behind it),
    /// and the rider finishes no later than a fresh dispatch would —
    /// `tail.done + t_item` vs `free_at + overhead + t_item` with
    /// `tail.done == free_at` — while saving the visit overhead.
    pub fn route_minibatch(&self, devices: &[Device], key: &Key, ready: f64) -> Route {
        let target = self.dispatch_device(devices, key, ready);
        if self.microbatch {
            if let Some(j) = devices[target].jobs.len().checked_sub(1) {
                let job = &devices[target].jobs[j];
                if job.key == *key && job.start >= ready {
                    return Route::Batch(target, j);
                }
            }
        }
        Route::Device(target)
    }

    /// Health-aware fresh dispatch for the fault path: like
    /// [`Dispatcher::route`]'s device pick, but the load metric is the
    /// earliest instant the device could actually *start* work that is
    /// ready at `ready` — crash windows (from the outage calendar)
    /// push a device's availability to its recovery, and a permanently
    /// down device drops out entirely. `None` when every device is
    /// permanently down (the coordinator sheds with
    /// `ShedReason::NoHealthyDevice`). Coalescing and micro-batching
    /// are deliberately absent here: a ridden job may crash, and the
    /// retry bookkeeping per rider is not worth the overhead saved.
    pub fn route_healthy(&self, devices: &[Device], key: &Key, ready: f64) -> Option<usize> {
        let pick = |warm_only: bool| -> Option<usize> {
            devices
                .iter()
                .filter(|d| !warm_only || d.is_warm(key))
                .map(|d| (d.up_at(ready.max(d.free_at)), d.id))
                .filter(|(t, _)| t.is_finite())
                .min_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)))
                .map(|(_, id)| id)
        };
        if self.affinity {
            if let Some(id) = pick(true) {
                return Some(id);
            }
        }
        pick(false)
    }

    /// The device a fresh dispatch would go to: cache-warm first (when
    /// affinity is on), else least-loaded; ties to the lowest id.
    fn dispatch_device(&self, devices: &[Device], key: &Key, arrival: f64) -> usize {
        let pick = |warm_only: bool| -> Option<usize> {
            devices
                .iter()
                .filter(|d| !warm_only || d.is_warm(key))
                .map(|d| (d.free_at.max(arrival), d.id))
                .min_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)))
                .map(|(_, id)| id)
        };
        if self.affinity {
            // Warm devices skip the compile entirely; even a queued warm
            // device usually beats a cold one (compile >> queue at the
            // paper's request rates), and keeping keys sticky maximizes
            // fleet-wide hit rate.
            if let Some(id) = pick(true) {
                return id;
            }
        }
        pick(false).expect("fleet has at least one device")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::BucketShape;
    use crate::config::HwConfig;
    use crate::graph::dataset;
    use crate::ir::ZooModel;
    use crate::quant::Precision;

    const ALL_ON: Dispatcher = Dispatcher { affinity: true, coalesce: true, microbatch: true };

    fn fleet(n: usize) -> Vec<Device> {
        (0..n).map(|i| Device::new(i, HwConfig::alveo_u250())).collect()
    }

    #[test]
    fn cold_fleet_routes_to_least_loaded() {
        let mut devs = fleet(3);
        devs[0].free_at = 5.0;
        devs[1].free_at = 1.0;
        devs[2].free_at = 3.0;
        let key = Key::Whole(ZooModel::B1, "CO", 0, Precision::F32);
        assert_eq!(ALL_ON.route(&devs, &key, 0.0), Route::Device(1));
    }

    #[test]
    fn affinity_prefers_warm_device() {
        let mut devs = fleet(2);
        let co = dataset("CO").unwrap();
        let mut exec = |_: &crate::compiler::Executable| 1e-4;
        devs[1].admit(0.0, ZooModel::B1, &co, &mut exec);
        // Device 1 is warm but busier; affinity still picks it.
        let key = Key::Whole(ZooModel::B1, "CO", 0, Precision::F32);
        let arrival = devs[1].free_at + 1.0; // after its job started
        let on = Dispatcher { coalesce: false, ..ALL_ON };
        let off = Dispatcher { affinity: false, coalesce: false, ..ALL_ON };
        assert_eq!(on.route(&devs, &key, arrival), Route::Device(1));
        // Without affinity the tie on (idle, idle) breaks to device 0.
        assert_eq!(off.route(&devs, &key, arrival), Route::Device(0));
    }

    #[test]
    fn coalesce_rides_unstarted_identical_job() {
        let mut devs = fleet(2);
        let co = dataset("CO").unwrap();
        let mut exec = |_: &crate::compiler::Executable| 1e-4;
        let (_, j) = devs[0].admit(0.0, ZooModel::B1, &co, &mut exec);
        let start = devs[0].jobs[j].start;
        let key = Key::Whole(ZooModel::B1, "CO", 0, Precision::F32);
        // Before the job starts: ride it.
        assert_eq!(ALL_ON.route(&devs, &key, start * 0.5), Route::Coalesce(0, j));
        // After it started: a fresh dispatch (warm, device 0).
        assert_eq!(ALL_ON.route(&devs, &key, start + 1.0), Route::Device(0));
        // Different key never coalesces.
        let other = Key::Whole(ZooModel::B2, "CO", 0, Precision::F32);
        assert!(matches!(ALL_ON.route(&devs, &other, start * 0.5), Route::Device(_)));
    }

    #[test]
    fn ride_rejected_when_idle_device_finishes_sooner() {
        // Device 0 is warm but has a deep queue; device 1 is idle. With
        // affinity off the dispatch target is the idle device, and the
        // ride (behind the queue) would finish later — so no coalesce.
        let mut devs = fleet(2);
        let co = dataset("CO").unwrap();
        let mut exec = |_: &crate::compiler::Executable| 1.0;
        devs[0].admit(0.0, ZooModel::B1, &co, &mut exec); // running by 0.5
        let (_, j) = devs[0].admit(0.0, ZooModel::B1, &co, &mut exec); // queued
        let key = Key::Whole(ZooModel::B1, "CO", 0, Precision::F32);
        let off = Dispatcher { affinity: false, ..ALL_ON };
        assert_eq!(off.route(&devs, &key, 0.5), Route::Device(1));
        // With affinity the dispatch target is the warm (queued) device
        // itself, so riding the queued job ties on completion and wins
        // by not duplicating the execution.
        assert_eq!(ALL_ON.route(&devs, &key, 0.5), Route::Coalesce(0, j));
    }

    #[test]
    fn minibatch_batches_onto_compatible_tail_visit() {
        let mut devs = fleet(2);
        let shape = BucketShape::of(100, 800, 64, 8);
        let mut exec = |_: &crate::compiler::Executable| 1e-4;
        let (_, j) =
            devs[0].admit_minibatch(0.0, ZooModel::B1, shape, 1e-6, Precision::F32, &mut exec);
        let start = devs[0].jobs[j].start;
        let key = Key::Bucket(ZooModel::B1, shape, Precision::F32);
        // Unstarted compatible tail: batch onto it.
        assert_eq!(
            ALL_ON.route_minibatch(&devs, &key, start * 0.5),
            Route::Batch(0, j)
        );
        // Micro-batching off: fresh dispatch to the warm device.
        let off = Dispatcher { microbatch: false, ..ALL_ON };
        assert_eq!(off.route_minibatch(&devs, &key, start * 0.5), Route::Device(0));
        // A different bucket never batches.
        let other = Key::Bucket(ZooModel::B1, BucketShape::of(5000, 800, 64, 8), Precision::F32);
        assert!(matches!(
            ALL_ON.route_minibatch(&devs, &other, start * 0.5),
            Route::Device(_)
        ));
        // After the visit started: fresh dispatch.
        assert_eq!(
            ALL_ON.route_minibatch(&devs, &key, start + 1.0),
            Route::Device(0)
        );
    }

    #[test]
    fn healthy_routing_skips_downed_devices() {
        use crate::serve::device::FaultWindow;
        let mut devs = fleet(3);
        let key = Key::Whole(ZooModel::B1, "CO", 0, Precision::F32);
        // Device 0 crashed at 1.0 and recovers at 4.0; device 2 is gone
        // for good. At t=2.0 only device 1 is immediately available.
        devs[0].set_fault_windows(vec![FaultWindow { from: 1.0, until: 4.0, crash: true, event: 0 }]);
        devs[2].set_fault_windows(vec![FaultWindow {
            from: 0.5,
            until: f64::INFINITY,
            crash: true,
            event: 1,
        }]);
        assert_eq!(ALL_ON.route_healthy(&devs, &key, 2.0), Some(1));
        // Once device 0 recovers it wins the id tie-break again.
        assert_eq!(ALL_ON.route_healthy(&devs, &key, 5.0), Some(0));
        // A warm device still attracts (affinity), even while another
        // is idle.
        let co = dataset("CO").unwrap();
        let mut exec = |_: &crate::compiler::Executable| 1e-4;
        devs[1].admit(0.0, ZooModel::B1, &co, &mut exec);
        assert_eq!(ALL_ON.route_healthy(&devs, &key, 5.0), Some(1));
        // Every device permanently down: nobody to route to.
        for d in &mut devs {
            d.set_fault_windows(vec![FaultWindow {
                from: 0.0,
                until: f64::INFINITY,
                crash: true,
                event: 9,
            }]);
        }
        assert_eq!(ALL_ON.route_healthy(&devs, &key, 2.0), None);
    }
}
