//! Multi-tenant serving coordinator (the paper's motivating deployment,
//! Sec. 1: "in a cloud-based system, multiple users share the same FPGA.
//! Different users may run different GNN models with different input
//! graphs" — the overlay makes switching instant because no bitstream is
//! regenerated).
//!
//! * [`cache`] — the compiled-program cache keyed by (model, graph):
//!   first request pays the milliseconds-scale software compile; repeats
//!   are pure lookups,
//! * [`coordinator`] — the request loop: a queue, a worker that binds
//!   programs to the accelerator (simulated execution latency from
//!   `sim::engine`), and latency statistics (p50/p99) per tenant.

pub mod cache;
pub mod coordinator;

pub use cache::ProgramCache;
pub use coordinator::{Coordinator, Request, Response, ServeStats};
