//! Multi-tenant serving fleet (the paper's motivating deployment,
//! Sec. 1: "in a cloud-based system, multiple users share the same FPGA.
//! Different users may run different GNN models with different input
//! graphs" — the overlay makes switching instant because no bitstream is
//! regenerated). Scaled out: N identical overlay devices behind one
//! deterministic coordinator.
//!
//! * [`cache`] — the compiled-program cache keyed by (model, graph):
//!   first request pays the milliseconds-scale software compile; repeats
//!   are pure lookups,
//! * [`clock`] — the virtual clock: compile stalls charged from the
//!   deterministic [`crate::compiler::CompileReport::total`] model,
//!   execution from the cycle simulator — never `Instant::now()`,
//! * [`device`] — one overlay: per-device cache, warmth ledger, busy
//!   timeline,
//! * [`dispatcher`] — routing policy: coalesce identical in-flight
//!   requests, micro-batch compatible mini-batches into one device
//!   visit, else prefer a cache-warm device (affinity), else the
//!   least-loaded one,
//! * [`coordinator`] — the event loop binding it together, plus latency
//!   statistics (nearest-rank p50/p99).
//!
//! Three request classes share the fleet
//! ([`Target`](coordinator::Target)): whole-graph inference,
//! mini-batch inference over sampled k-hop ego-networks
//! ([`crate::graph::Sampler`]) executed through shape-bucketed programs
//! ([`crate::compiler::BucketShape`]) so per-request cost tracks the
//! sampled neighborhood, not the full graph — and streaming graph
//! *updates* ([`Target::Update`](coordinator::Target::Update)):
//! R-MAT-skewed churn batches applied to a per-dataset
//! [`crate::stream::DynamicGraph`] between inference requests. Updates
//! seal epochs; whole-graph cache keys are epoch-versioned with
//! selective invalidation, bucket programs (shape-only) survive
//! untouched, and mini-batch sampling reads the churned epoch through
//! the CSR + delta-overlay merge.
//!
//! The fleet serves with density-aware dynamic kernel re-mapping by
//! default ([`FleetConfig`](coordinator::FleetConfig)`::dynamic`):
//! execution times and per-request re-map counters come from
//! [`crate::sim::simulate_dynamic`], which is never slower than the
//! static mapping.
//!
//! Requests carry a [`Precision`]: an int8 request compiles (and
//! caches) a calibrated program with an embedded GA03 scale table,
//! simulates on the widened int8 datapath, and reports quantized-work
//! counters in its [`Response`](coordinator::Response) — f32 and int8
//! tenants never share a compiled artifact.
//!
//! The coordinator ingests either as a batch
//! ([`Coordinator::run`](coordinator::Coordinator::run), which sorts by
//! arrival) or incrementally
//! ([`Coordinator::admit`](coordinator::Coordinator::admit), one
//! request at a time in nondecreasing-arrival order — the daemon path).
//! The two are equivalent on a sorted stream (pinned by a coordinator
//! test), which is what makes [`crate::daemon`] recordings replayable:
//! a trace's admitted events re-run through `admit` and reproduce the
//! recorded responses bit-for-bit.
//!
//! Serving is fault-tolerant under a seeded [`fault::FaultPlan`] — an
//! outage calendar of device crashes, transient stalls, and cached
//! `.ga` corruptions scheduled on the virtual clock. Crashed attempts
//! retry with exponential backoff and re-route to healthy devices,
//! over-deadline requests degrade through a fidelity cascade
//! (f32 → int8, full fanout → capped) before being shed with a named
//! reason, and the whole faulty run replays bit-identically. With no
//! plan (or an empty one) every code path above is untouched.
//!
//! Multi-tenant QoS rides on the same dormant-state pattern
//! ([`qos`]): a [`qos::TenantConfig`] gives each tenant a fair-queue
//! weight, an optional deadline, and a priority class; the coordinator
//! then paces non-premium traffic with start-time fair queuing over
//! modeled visit cost, places eligible work into per-device idle gaps
//! (preempting *unstarted* visits for higher-priority arrivals), walks
//! over-deadline requests down the same fidelity cascade, and sheds
//! only best-effort traffic — with
//! [`ShedReason::DeadlineMissed`](fault::ShedReason::DeadlineMissed).
//! With no config installed, serving stays byte-identical to the
//! tenant-blind fleet.
#![warn(missing_docs)]

pub mod cache;
pub mod clock;
pub mod coordinator;
pub mod device;
pub mod dispatcher;
pub mod fault;
pub mod qos;

pub use cache::{Key, ProgramCache, SERVE_WEIGHT_SEED};
pub use crate::quant::Precision;
pub use clock::{CostModel, VirtualClock};
pub use coordinator::{
    percentile, Coordinator, FleetConfig, Request, Response, ServeStats, Target,
};
pub use device::Device;
pub use dispatcher::{Dispatcher, Route};
pub use fault::{
    DecisionRecord, Degradation, FaultEvent, FaultPlan, FaultRecord, Health, Outcome,
    ShedReason,
};
pub use qos::{FairQueue, PriorityClass, QosState, Tenant, TenantConfig, TenantStats};
