//! Compiled-program cache: (model, graph) -> Executable. The overlay's
//! killer property is that this cache is filled by a milliseconds-scale
//! software compile instead of an hours-scale hardware regeneration.

use crate::compiler::{compile, CompileOptions, Executable};
use crate::config::HwConfig;
use crate::graph::{Dataset, TileCounts};
use crate::ir::ZooModel;
use std::collections::HashMap;
use std::sync::Arc;

/// Cache key: which benchmark model on which graph instance.
pub type Key = (ZooModel, &'static str);

pub struct ProgramCache {
    hw: HwConfig,
    programs: HashMap<Key, Arc<Executable>>,
    tiles: HashMap<&'static str, Arc<TileCounts>>,
    pub hits: u64,
    pub misses: u64,
}

impl ProgramCache {
    pub fn new(hw: HwConfig) -> ProgramCache {
        ProgramCache {
            hw,
            programs: HashMap::new(),
            tiles: HashMap::new(),
            hits: 0,
            misses: 0,
        }
    }

    /// Get-or-compile. Returns the executable and whether it was a hit.
    pub fn get(&mut self, model: ZooModel, ds: &Dataset) -> (Arc<Executable>, bool) {
        let key = (model, ds.key);
        if let Some(exe) = self.programs.get(&key) {
            self.hits += 1;
            return (exe.clone(), true);
        }
        self.misses += 1;
        let n1 = self.hw.n1() as u64;
        let tiles = self
            .tiles
            .entry(ds.key)
            .or_insert_with(|| Arc::new(ds.tile_counts(n1)))
            .clone();
        let ir = model.build(ds.meta());
        let exe = Arc::new(compile(&ir, &tiles, &self.hw, CompileOptions::default()));
        self.programs.insert(key, exe.clone());
        (exe, false)
    }

    /// Whether `key` is already compiled here (affinity-routing probe —
    /// does not touch the hit/miss counters).
    pub fn contains(&self, key: &Key) -> bool {
        self.programs.contains_key(key)
    }

    pub fn len(&self) -> usize {
        self.programs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.programs.is_empty()
    }

    /// Total bytes of cached binaries (capacity planning).
    pub fn binary_bytes(&self) -> u64 {
        self.programs.values().map(|e| e.program.size_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::dataset;

    #[test]
    fn compile_once_then_hit() {
        let mut cache = ProgramCache::new(HwConfig::alveo_u250());
        let co = dataset("CO").unwrap();
        let (_, hit1) = cache.get(ZooModel::B1, &co);
        assert!(!hit1);
        let (_, hit2) = cache.get(ZooModel::B1, &co);
        assert!(hit2);
        assert_eq!((cache.hits, cache.misses), (1, 1));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn tiles_shared_across_models() {
        // Two models on the same graph partition the graph once.
        let mut cache = ProgramCache::new(HwConfig::alveo_u250());
        let co = dataset("CO").unwrap();
        cache.get(ZooModel::B1, &co);
        cache.get(ZooModel::B2, &co);
        assert_eq!(cache.tiles.len(), 1);
        assert_eq!(cache.len(), 2);
        assert!(cache.binary_bytes() > 0);
    }
}
