//! Compiled-program cache: request key -> Executable. The overlay's
//! killer property is that this cache is filled by a milliseconds-scale
//! software compile instead of an hours-scale hardware regeneration.
//!
//! Two key classes share the cache:
//! * [`Key::Whole`] — whole-graph inference of (model, dataset,
//!   *epoch*): streaming updates advance a dataset's epoch, so a
//!   churned graph compiles fresh programs while sealed-epoch entries
//!   stay consistent until selectively invalidated
//!   ([`ProgramCache::invalidate_whole_before`]);
//! * [`Key::Bucket`] — a shape-bucketed mini-batch program
//!   ([`crate::compiler::BucketShape`]): thousands of distinct ego-nets
//!   round up to a handful of buckets, so the mini-batch hit rate stays
//!   near 100% under arbitrarily diverse request streams. Bucket
//!   programs are shape-only — no graph data is baked in — so they
//!   deliberately carry **no** epoch and survive graph churn untouched.

use crate::compiler::bucket::compile_bucket;
use crate::compiler::{compile, BucketShape, CompileOptions, Executable};
use crate::config::HwConfig;
use crate::exec::WeightStore;
use crate::graph::{Dataset, GraphMeta, TileCounts};
use crate::ir::ZooModel;
use crate::quant::{calibrate, CalibrationProfile, Precision};
use std::collections::HashMap;
use std::sync::Arc;

/// Weight seed of the fleet's deterministic serving weights — the same
/// convention the functional-replay and golden-equivalence paths use,
/// so an int8 program calibrated here quantizes the exact weights a
/// replay executes.
pub const SERVE_WEIGHT_SEED: u64 = 33;

/// Cache key: which compiled program a request needs. Precision is part
/// of the key: an int8 program embeds a GA03 scale table (and simulates
/// on the widened datapath), so it is a distinct compiled artifact from
/// its f32 twin.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Key {
    /// Whole-graph inference: (model, dataset key, graph epoch,
    /// precision). Epoch 0 is the frozen dataset; streaming updates
    /// bump it.
    Whole(ZooModel, &'static str, u32, Precision),
    /// Mini-batch inference: (model, shape bucket, precision) —
    /// epoch-free by construction.
    Bucket(ZooModel, BucketShape, Precision),
}

/// The compiled-program cache of one overlay device: get-or-compile
/// keyed by [`Key`], with host-side tile counts shared across models on
/// the same graph.
pub struct ProgramCache {
    hw: HwConfig,
    programs: HashMap<Key, Arc<Executable>>,
    tiles: HashMap<(&'static str, u32), Arc<TileCounts>>,
    /// Requests served from an already-compiled program.
    pub hits: u64,
    /// Requests that paid the software compile.
    pub misses: u64,
}

impl ProgramCache {
    /// Empty cache compiling against `hw`.
    pub fn new(hw: HwConfig) -> ProgramCache {
        ProgramCache {
            hw,
            programs: HashMap::new(),
            tiles: HashMap::new(),
            hits: 0,
            misses: 0,
        }
    }

    /// Get-or-compile the whole-graph program of (model, dataset) at
    /// epoch 0 (the frozen dataset), full f32. Returns the executable
    /// and whether it was a hit.
    pub fn get(&mut self, model: ZooModel, ds: &Dataset) -> (Arc<Executable>, bool) {
        self.get_at(model, ds, 0, None, Precision::F32)
    }

    /// Get-or-compile the whole-graph program of (model, dataset,
    /// epoch). For epoch 0 the dataset's own metadata and streamed tile
    /// counts are used; a streamed epoch passes its `snapshot` — the
    /// dynamic graph's current metadata (vertex/edge counts drift) and
    /// *live* per-subshard edge counts, so the compile (and its GA02
    /// density profile) tracks the churn.
    /// An `Int8` request compiles the same program and then calibrates
    /// and embeds a GA03 scale table ([`Self::attach_scales`]) — the
    /// int8 artifact is cached under its own key.
    pub fn get_at(
        &mut self,
        model: ZooModel,
        ds: &Dataset,
        epoch: u32,
        snapshot: Option<(&GraphMeta, &Arc<TileCounts>)>,
        precision: Precision,
    ) -> (Arc<Executable>, bool) {
        let key = Key::Whole(model, ds.key, epoch, precision);
        if let Some(exe) = self.programs.get(&key) {
            self.hits += 1;
            return (exe.clone(), true);
        }
        self.misses += 1;
        let (ir, tiles) = match snapshot {
            // Snapshot tiles are owned by the coordinator's stream
            // state (Arc-shared per epoch) — nothing to cache here.
            Some((meta, tiles)) => (model.build(meta.clone()), tiles.clone()),
            None => {
                debug_assert_eq!(epoch, 0, "epoch > 0 requires a stream snapshot");
                let n1 = self.hw.n1() as u64;
                let tiles = self
                    .tiles
                    .entry((ds.key, 0))
                    .or_insert_with(|| Arc::new(ds.tile_counts(n1)))
                    .clone();
                (model.build(ds.meta()), tiles)
            }
        };
        let mut exe = compile(&ir, &tiles, &self.hw, CompileOptions::default());
        if precision == Precision::Int8 {
            Self::attach_scales(&mut exe);
        }
        let exe = Arc::new(exe);
        self.programs.insert(key, exe.clone());
        (exe, false)
    }

    /// Get-or-compile the canonical bucket program of (model, shape).
    /// Every member ego-net of the bucket executes this one program.
    pub fn get_bucket(
        &mut self,
        model: ZooModel,
        shape: BucketShape,
        precision: Precision,
    ) -> (Arc<Executable>, bool) {
        let key = Key::Bucket(model, shape, precision);
        if let Some(exe) = self.programs.get(&key) {
            self.hits += 1;
            return (exe.clone(), true);
        }
        self.misses += 1;
        let mut exe = compile_bucket(model, shape, &self.hw);
        if precision == Precision::Int8 {
            Self::attach_scales(&mut exe);
        }
        let exe = Arc::new(exe);
        self.programs.insert(key, exe.clone());
        (exe, false)
    }

    /// Calibrate the program against the fleet's deterministic serving
    /// weights and embed the resulting scale table (persisted as the
    /// GA03 section when the binary is serialized). The analytic
    /// feature-range profile needs only the program's own graph
    /// metadata, so cache misses stay compile-time-cheap — no graph
    /// materialization.
    fn attach_scales(exe: &mut Executable) {
        let store = WeightStore::deterministic(&exe.ir, SERVE_WEIGHT_SEED);
        let meta = &exe.ir.graph;
        let profile = CalibrationProfile::analytic(meta.n_vertices, meta.n_edges);
        exe.program.scales = Some(calibrate(&exe.ir, &store, &profile).table);
    }

    /// Whether `key` is already compiled here (affinity-routing probe —
    /// does not touch the hit/miss counters).
    pub fn contains(&self, key: &Key) -> bool {
        self.programs.contains_key(key)
    }

    /// The resident executable, if any — no compile, no counter changes
    /// (the corruption fault serializes the artifact it is about to
    /// damage).
    pub fn peek(&self, key: &Key) -> Option<Arc<Executable>> {
        self.programs.get(key).cloned()
    }

    /// Evict one compiled artifact (the corrupted-artifact recovery
    /// path: a cached `.ga` that fails its load check is dropped and
    /// recompiled on the next access). Returns whether it was present.
    pub fn remove(&mut self, key: &Key) -> bool {
        self.programs.remove(key).is_some()
    }

    /// Drop every compiled artifact — a crashed device rejoins with a
    /// cold cache. Host-side tile counts survive (they live in host
    /// memory, not on the device).
    pub fn clear(&mut self) {
        self.programs.clear();
    }

    /// Selective invalidation after a streaming update: drop every
    /// whole-graph program (and cached tile counts) of `ds_key` with an
    /// epoch below `epoch` — they can never be hit again. Bucket
    /// programs are shape-only and deliberately survive. Returns the
    /// number of programs dropped.
    pub fn invalidate_whole_before(&mut self, ds_key: &str, epoch: u32) -> usize {
        let before = self.programs.len();
        self.programs
            .retain(|k, _| !matches!(k, Key::Whole(_, d, e, _) if *d == ds_key && *e < epoch));
        self.tiles.retain(|(d, e), _| !(*d == ds_key && *e < epoch));
        before - self.programs.len()
    }

    /// Number of resident compiled programs.
    pub fn len(&self) -> usize {
        self.programs.len()
    }

    /// Whether no compiled program is resident.
    pub fn is_empty(&self) -> bool {
        self.programs.is_empty()
    }

    /// Total bytes of cached binaries (capacity planning).
    pub fn binary_bytes(&self) -> u64 {
        self.programs.values().map(|e| e.program.size_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::dataset;

    #[test]
    fn compile_once_then_hit() {
        let mut cache = ProgramCache::new(HwConfig::alveo_u250());
        let co = dataset("CO").unwrap();
        let (_, hit1) = cache.get(ZooModel::B1, &co);
        assert!(!hit1);
        let (_, hit2) = cache.get(ZooModel::B1, &co);
        assert!(hit2);
        assert_eq!((cache.hits, cache.misses), (1, 1));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn tiles_shared_across_models() {
        // Two models on the same graph partition the graph once.
        let mut cache = ProgramCache::new(HwConfig::alveo_u250());
        let co = dataset("CO").unwrap();
        cache.get(ZooModel::B1, &co);
        cache.get(ZooModel::B2, &co);
        assert_eq!(cache.tiles.len(), 1);
        assert_eq!(cache.len(), 2);
        assert!(cache.binary_bytes() > 0);
    }

    #[test]
    fn bucket_programs_cache_by_shape() {
        let mut cache = ProgramCache::new(HwConfig::alveo_u250());
        let a = BucketShape::of(100, 900, 64, 8);
        let b = BucketShape::of(120, 1000, 64, 8); // same bucket
        let c = BucketShape::of(300, 900, 64, 8); // larger vertex bucket
        assert_eq!(a, b);
        let (_, h1) = cache.get_bucket(ZooModel::B1, a, Precision::F32);
        let (_, h2) = cache.get_bucket(ZooModel::B1, b, Precision::F32);
        let (_, h3) = cache.get_bucket(ZooModel::B1, c, Precision::F32);
        assert!(!h1 && h2 && !h3);
        assert_eq!(cache.len(), 2);
        assert!(cache.contains(&Key::Bucket(ZooModel::B1, a, Precision::F32)));
        assert!(!cache.contains(&Key::Whole(ZooModel::B1, "CO", 0, Precision::F32)));
    }

    #[test]
    fn int8_programs_cache_separately_and_carry_scales() {
        let mut cache = ProgramCache::new(HwConfig::alveo_u250());
        let co = dataset("CO").unwrap();
        let (f32_exe, _) = cache.get(ZooModel::B1, &co);
        let (q_exe, hit) = cache.get_at(ZooModel::B1, &co, 0, None, Precision::Int8);
        assert!(!hit, "int8 must not alias the f32 program");
        assert_eq!(cache.len(), 2);
        assert!(f32_exe.program.scales.is_none());
        let table = q_exe.program.scales.as_ref().expect("int8 program carries a scale table");
        assert!(!table.entries.is_empty());
        // Second int8 request hits the calibrated artifact.
        let (_, hit) = cache.get_at(ZooModel::B1, &co, 0, None, Precision::Int8);
        assert!(hit);
        // Bucket programs calibrate too.
        let shape = BucketShape::of(100, 900, co.feat_len, co.n_classes);
        let (qb, _) = cache.get_bucket(ZooModel::B1, shape, Precision::Int8);
        assert!(qb.program.scales.is_some());
    }

    #[test]
    fn epoch_keys_and_selective_invalidation() {
        let mut cache = ProgramCache::new(HwConfig::alveo_u250());
        let co = dataset("CO").unwrap();
        let pu = dataset("PU").unwrap();
        cache.get(ZooModel::B1, &co);
        cache.get(ZooModel::B1, &pu);
        // An epoch-1 snapshot of CO compiles a distinct program.
        let meta = GraphMeta::new(
            "CO",
            co.n_vertices + 4,
            co.n_edges + co.n_vertices,
            co.feat_len,
            co.n_classes,
        );
        let n1 = HwConfig::alveo_u250().n1() as u64;
        let tiles = std::sync::Arc::new(
            crate::graph::TileCounts::from_coo(&co.materialize().gcn_normalized(), n1),
        );
        let (_, hit) = cache.get_at(ZooModel::B1, &co, 1, Some((&meta, &tiles)), Precision::F32);
        assert!(!hit);
        assert_eq!(cache.len(), 3);
        assert!(cache.contains(&Key::Whole(ZooModel::B1, "CO", 0, Precision::F32)));
        assert!(cache.contains(&Key::Whole(ZooModel::B1, "CO", 1, Precision::F32)));
        // Invalidating CO below epoch 1 drops only the stale CO entry.
        let dropped = cache.invalidate_whole_before("CO", 1);
        assert_eq!(dropped, 1);
        assert!(!cache.contains(&Key::Whole(ZooModel::B1, "CO", 0, Precision::F32)));
        assert!(cache.contains(&Key::Whole(ZooModel::B1, "CO", 1, Precision::F32)));
        assert!(cache.contains(&Key::Whole(ZooModel::B1, "PU", 0, Precision::F32)));
        // The epoch-1 entry now hits; bucket entries never invalidate.
        let (_, hit) = cache.get_at(ZooModel::B1, &co, 1, Some((&meta, &tiles)), Precision::F32);
        assert!(hit);
        let shape = BucketShape::of(100, 900, 64, 8);
        cache.get_bucket(ZooModel::B1, shape, Precision::F32);
        cache.invalidate_whole_before("CO", 99);
        assert!(cache.contains(&Key::Bucket(ZooModel::B1, shape, Precision::F32)));
    }

    #[test]
    fn remove_evicts_one_entry_and_clear_empties() {
        let mut cache = ProgramCache::new(HwConfig::alveo_u250());
        let co = dataset("CO").unwrap();
        cache.get(ZooModel::B1, &co);
        cache.get(ZooModel::B2, &co);
        let key = Key::Whole(ZooModel::B1, "CO", 0, Precision::F32);
        assert!(cache.remove(&key));
        assert!(!cache.remove(&key), "second eviction is a no-op");
        assert!(!cache.contains(&key));
        assert_eq!(cache.len(), 1);
        // Eviction forces a recompile (miss), not an error.
        let (_, hit) = cache.get(ZooModel::B1, &co);
        assert!(!hit);
        cache.clear();
        assert!(cache.is_empty());
        // Host-side tile counts survive a device cold start.
        assert!(!cache.tiles.is_empty());
    }
}
