//! Compiled-program cache: request key -> Executable. The overlay's
//! killer property is that this cache is filled by a milliseconds-scale
//! software compile instead of an hours-scale hardware regeneration.
//!
//! Two key classes share the cache:
//! * [`Key::Whole`] — whole-graph inference of (model, dataset);
//! * [`Key::Bucket`] — a shape-bucketed mini-batch program
//!   ([`crate::compiler::BucketShape`]): thousands of distinct ego-nets
//!   round up to a handful of buckets, so the mini-batch hit rate stays
//!   near 100% under arbitrarily diverse request streams.

use crate::compiler::bucket::compile_bucket;
use crate::compiler::{compile, BucketShape, CompileOptions, Executable};
use crate::config::HwConfig;
use crate::graph::{Dataset, TileCounts};
use crate::ir::ZooModel;
use std::collections::HashMap;
use std::sync::Arc;

/// Cache key: which compiled program a request needs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Key {
    /// Whole-graph inference: (model, dataset key).
    Whole(ZooModel, &'static str),
    /// Mini-batch inference: (model, shape bucket).
    Bucket(ZooModel, BucketShape),
}

pub struct ProgramCache {
    hw: HwConfig,
    programs: HashMap<Key, Arc<Executable>>,
    tiles: HashMap<&'static str, Arc<TileCounts>>,
    pub hits: u64,
    pub misses: u64,
}

impl ProgramCache {
    pub fn new(hw: HwConfig) -> ProgramCache {
        ProgramCache {
            hw,
            programs: HashMap::new(),
            tiles: HashMap::new(),
            hits: 0,
            misses: 0,
        }
    }

    /// Get-or-compile the whole-graph program of (model, dataset).
    /// Returns the executable and whether it was a hit.
    pub fn get(&mut self, model: ZooModel, ds: &Dataset) -> (Arc<Executable>, bool) {
        let key = Key::Whole(model, ds.key);
        if let Some(exe) = self.programs.get(&key) {
            self.hits += 1;
            return (exe.clone(), true);
        }
        self.misses += 1;
        let n1 = self.hw.n1() as u64;
        let tiles = self
            .tiles
            .entry(ds.key)
            .or_insert_with(|| Arc::new(ds.tile_counts(n1)))
            .clone();
        let ir = model.build(ds.meta());
        let exe = Arc::new(compile(&ir, &tiles, &self.hw, CompileOptions::default()));
        self.programs.insert(key, exe.clone());
        (exe, false)
    }

    /// Get-or-compile the canonical bucket program of (model, shape).
    /// Every member ego-net of the bucket executes this one program.
    pub fn get_bucket(&mut self, model: ZooModel, shape: BucketShape) -> (Arc<Executable>, bool) {
        let key = Key::Bucket(model, shape);
        if let Some(exe) = self.programs.get(&key) {
            self.hits += 1;
            return (exe.clone(), true);
        }
        self.misses += 1;
        let exe = Arc::new(compile_bucket(model, shape, &self.hw));
        self.programs.insert(key, exe.clone());
        (exe, false)
    }

    /// Whether `key` is already compiled here (affinity-routing probe —
    /// does not touch the hit/miss counters).
    pub fn contains(&self, key: &Key) -> bool {
        self.programs.contains_key(key)
    }

    pub fn len(&self) -> usize {
        self.programs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.programs.is_empty()
    }

    /// Total bytes of cached binaries (capacity planning).
    pub fn binary_bytes(&self) -> u64 {
        self.programs.values().map(|e| e.program.size_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::dataset;

    #[test]
    fn compile_once_then_hit() {
        let mut cache = ProgramCache::new(HwConfig::alveo_u250());
        let co = dataset("CO").unwrap();
        let (_, hit1) = cache.get(ZooModel::B1, &co);
        assert!(!hit1);
        let (_, hit2) = cache.get(ZooModel::B1, &co);
        assert!(hit2);
        assert_eq!((cache.hits, cache.misses), (1, 1));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn tiles_shared_across_models() {
        // Two models on the same graph partition the graph once.
        let mut cache = ProgramCache::new(HwConfig::alveo_u250());
        let co = dataset("CO").unwrap();
        cache.get(ZooModel::B1, &co);
        cache.get(ZooModel::B2, &co);
        assert_eq!(cache.tiles.len(), 1);
        assert_eq!(cache.len(), 2);
        assert!(cache.binary_bytes() > 0);
    }

    #[test]
    fn bucket_programs_cache_by_shape() {
        let mut cache = ProgramCache::new(HwConfig::alveo_u250());
        let a = BucketShape::of(100, 900, 64, 8);
        let b = BucketShape::of(120, 1000, 64, 8); // same bucket
        let c = BucketShape::of(300, 900, 64, 8); // larger vertex bucket
        assert_eq!(a, b);
        let (_, h1) = cache.get_bucket(ZooModel::B1, a);
        let (_, h2) = cache.get_bucket(ZooModel::B1, b);
        let (_, h3) = cache.get_bucket(ZooModel::B1, c);
        assert!(!h1 && h2 && !h3);
        assert_eq!(cache.len(), 2);
        assert!(cache.contains(&Key::Bucket(ZooModel::B1, a)));
        assert!(!cache.contains(&Key::Whole(ZooModel::B1, "CO")));
    }
}
