//! Deterministic virtual time for the serving fleet.
//!
//! Every latency the coordinator reports is charged from a *modeled*
//! cost — compile stalls from [`CompileReport::total`] (the
//! deterministic work-counter model), execution from the cycle
//! simulator — never from `Instant::now()`. Replaying a workload
//! therefore produces bit-identical statistics, which is what makes
//! serving regressions diffable across commits and machines.

use crate::compiler::CompileReport;

/// Monotonic virtual clock (seconds since fleet start). The coordinator
/// advances it through request arrivals and job completions; its final
/// reading is the workload makespan.
#[derive(Clone, Copy, Debug, Default)]
pub struct VirtualClock {
    now: f64,
}

impl VirtualClock {
    /// A clock at t = 0.
    pub fn new() -> VirtualClock {
        VirtualClock { now: 0.0 }
    }

    /// Current virtual time in seconds.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Advance to `t`; no-op when `t` is already past (jobs on different
    /// devices complete out of arrival order).
    pub fn advance_to(&mut self, t: f64) {
        if t > self.now {
            self.now = t;
        }
    }
}

/// The virtual cost of one software compile: the deterministic modeled
/// pass total of the report the compile produced.
pub fn compile_cost(report: &CompileReport) -> f64 {
    report.total()
}

/// Modeled host-side sampling setup per mini-batch request (CSR row
/// lookups, hash-map init).
pub const SAMPLE_SETUP_S: f64 = 2e-6;
/// Modeled cost per sampled vertex (relabeling + feature-row gather).
pub const SAMPLE_PER_VERTEX_S: f64 = 3e-9;
/// Modeled cost per sampled edge (slot scan + weight gather).
pub const SAMPLE_PER_EDGE_S: f64 = 5e-9;

/// Fixed per-device-visit dispatch overhead of a mini-batch job
/// (descriptor setup + PCIe doorbell). Micro-batched riders append to
/// an already-scheduled visit and share this one overhead — which is
/// exactly the batching win the dispatcher chases.
pub const VISIT_OVERHEAD_S: f64 = 4e-5;

/// Modeled fixed setup of one streaming update batch (epoch bookkeeping,
/// dirty-set init).
pub const UPDATE_SETUP_S: f64 = 5e-6;
/// Modeled cost per changed edge (overlay append / tombstone + tile
/// scan).
pub const UPDATE_PER_EDGE_S: f64 = 8e-9;
/// Modeled cost per dirty subshard (bookkeeping + density re-profile).
pub const UPDATE_PER_SUBSHARD_S: f64 = 1e-6;
/// Modeled cost per edge re-sorted while rebuilding dirty subshards'
/// CSRs (the incremental-recompilation term — a full rebuild would pay
/// it for every edge of the graph).
pub const UPDATE_PER_REBUILT_EDGE_S: f64 = 4e-9;

/// Base of the exponential retry backoff charged on the virtual clock
/// after a device crash kills an attempt: retry `k` waits
/// `RETRY_BACKOFF_BASE_S * 2^(k-1)` before re-routing. Only consulted
/// when a [`FaultPlan`](super::fault::FaultPlan) is active.
pub const RETRY_BACKOFF_BASE_S: f64 = 5e-3;
/// Retries after the first failed attempt before a request is shed
/// with `ShedReason::RetriesExhausted`.
pub const MAX_RETRIES: u32 = 3;
/// Per-request completion deadline under a fault plan: a request whose
/// best quote lands past `arrival + DEADLINE_S` enters the fidelity
/// cascade (f32 -> int8, full fanout -> capped) before being served.
pub const DEADLINE_S: f64 = 0.1;

/// The host-side cost coefficients of the serving fleet, promoted from
/// hard-coded constants so
/// [`FleetConfig`](super::coordinator::FleetConfig) carries them and
/// benches can sweep them. The `Default` values are the original
/// constants.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostModel {
    /// Fixed host-side sampling setup per mini-batch request.
    pub sample_setup_s: f64,
    /// Sampling cost per sampled vertex.
    pub sample_per_vertex_s: f64,
    /// Sampling cost per sampled edge.
    pub sample_per_edge_s: f64,
    /// Fixed per-device-visit dispatch overhead of a mini-batch job.
    pub visit_overhead_s: f64,
    /// Fixed setup of one streaming update batch.
    pub update_setup_s: f64,
    /// Update cost per changed edge.
    pub update_per_edge_s: f64,
    /// Update cost per dirty subshard.
    pub update_per_subshard_s: f64,
    /// Update cost per edge re-sorted rebuilding dirty subshards.
    pub update_per_rebuilt_edge_s: f64,
    /// Exponential-backoff base after a crashed attempt (fault serving
    /// only; the zero-fault path never reads it).
    pub retry_backoff_base_s: f64,
    /// Retries before a request is shed (fault serving only).
    pub max_retries: u32,
    /// Completion deadline that triggers the fidelity cascade (fault
    /// serving only).
    pub deadline_s: f64,
}

impl Default for CostModel {
    fn default() -> CostModel {
        CostModel {
            sample_setup_s: SAMPLE_SETUP_S,
            sample_per_vertex_s: SAMPLE_PER_VERTEX_S,
            sample_per_edge_s: SAMPLE_PER_EDGE_S,
            visit_overhead_s: VISIT_OVERHEAD_S,
            update_setup_s: UPDATE_SETUP_S,
            update_per_edge_s: UPDATE_PER_EDGE_S,
            update_per_subshard_s: UPDATE_PER_SUBSHARD_S,
            update_per_rebuilt_edge_s: UPDATE_PER_REBUILT_EDGE_S,
            retry_backoff_base_s: RETRY_BACKOFF_BASE_S,
            max_retries: MAX_RETRIES,
            deadline_s: DEADLINE_S,
        }
    }
}

impl CostModel {
    /// Deterministic modeled cost of extracting one ego-net. Linear in
    /// the sampled neighborhood — the whole point of the mini-batch
    /// path is that no per-request cost scales with the full graph.
    pub fn sample_cost(&self, vertices: u64, edges: u64) -> f64 {
        self.sample_setup_s
            + vertices as f64 * self.sample_per_vertex_s
            + edges as f64 * self.sample_per_edge_s
    }

    /// Deterministic modeled cost of applying one streaming update
    /// batch: linear in the changed edges, the dirty subshards, and the
    /// edges re-sorted rebuilding them — never in the whole graph,
    /// which is the incremental-recompilation win the streaming bench
    /// pins against a full rebuild.
    pub fn update_cost(&self, changed_edges: u64, dirty_subshards: u64, rebuilt_edges: u64) -> f64 {
        self.update_setup_s
            + changed_edges as f64 * self.update_per_edge_s
            + dirty_subshards as f64 * self.update_per_subshard_s
            + rebuilt_edges as f64 * self.update_per_rebuilt_edge_s
    }

    /// Backoff charged before retry `k` (1-based): exponential from
    /// [`Self::retry_backoff_base_s`].
    pub fn backoff(&self, retry: u32) -> f64 {
        self.retry_backoff_base_s * 2f64.powi(retry.saturating_sub(1) as i32)
    }

    /// Whether the fault knobs still sit at their defaults — the trace
    /// writer emits them (and bumps the trace version) only when they
    /// do not, so zero-fault traces stay byte-identical to v1.
    pub fn fault_knobs_default(&self) -> bool {
        self.retry_backoff_base_s == RETRY_BACKOFF_BASE_S
            && self.max_retries == MAX_RETRIES
            && self.deadline_s == DEADLINE_S
    }
}

/// [`CostModel::sample_cost`] at the default coefficients (kept for
/// callers outside the fleet).
pub fn sample_cost(vertices: u64, edges: u64) -> f64 {
    CostModel::default().sample_cost(vertices, edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_is_monotonic() {
        let mut c = VirtualClock::new();
        assert_eq!(c.now(), 0.0);
        c.advance_to(2.5);
        c.advance_to(1.0); // in the past: ignored
        assert_eq!(c.now(), 2.5);
        c.advance_to(3.0);
        assert_eq!(c.now(), 3.0);
    }

    #[test]
    fn compile_cost_tracks_work() {
        let small = CompileReport { layers: 4, instrs: 100, blocks: 10, ..Default::default() };
        let large =
            CompileReport { layers: 4, instrs: 100_000, blocks: 9_000, ..Default::default() };
        assert!(compile_cost(&small) > 0.0);
        assert!(compile_cost(&large) > compile_cost(&small));
        // Measured wall-clock fields do not leak into the virtual cost.
        let noisy = CompileReport { t_mapping: 123.0, ..small };
        assert_eq!(compile_cost(&noisy), compile_cost(&small));
    }

    #[test]
    fn sample_cost_scales_with_the_neighborhood() {
        let tiny = sample_cost(8, 16);
        let big = sample_cost(8_000, 160_000);
        assert!(tiny > 0.0);
        assert!(big > tiny);
        // A visit's fixed overhead dominates a tiny sample: batching
        // riders must be worth something.
        assert!(VISIT_OVERHEAD_S > tiny);
    }

    #[test]
    fn cost_model_defaults_match_the_constants_and_sweep() {
        let m = CostModel::default();
        assert_eq!(m.sample_cost(8, 16), sample_cost(8, 16));
        assert_eq!(m.visit_overhead_s, VISIT_OVERHEAD_S);
        // Update cost scales in every term and never in graph size.
        let base = m.update_cost(100, 10, 1000);
        assert!(base > 0.0);
        assert!(m.update_cost(200, 10, 1000) > base);
        assert!(m.update_cost(100, 20, 1000) > base);
        assert!(m.update_cost(100, 10, 2000) > base);
        // Coefficients are sweepable (the satellite's point).
        let swept = CostModel { visit_overhead_s: 1e-3, ..CostModel::default() };
        assert!(swept.visit_overhead_s > m.visit_overhead_s);
        assert_eq!(swept.sample_cost(8, 16), m.sample_cost(8, 16));
    }

    #[test]
    fn backoff_doubles_per_retry_and_knobs_track_defaults() {
        let m = CostModel::default();
        assert_eq!(m.backoff(1), RETRY_BACKOFF_BASE_S);
        assert_eq!(m.backoff(2), 2.0 * RETRY_BACKOFF_BASE_S);
        assert_eq!(m.backoff(3), 4.0 * RETRY_BACKOFF_BASE_S);
        assert!(m.fault_knobs_default());
        let swept = CostModel { max_retries: 7, ..CostModel::default() };
        assert!(!swept.fault_knobs_default());
        let swept = CostModel { retry_backoff_base_s: 1e-2, ..CostModel::default() };
        assert!(!swept.fault_knobs_default());
        assert_eq!(swept.backoff(2), 2e-2);
    }
}
