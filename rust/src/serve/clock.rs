//! Deterministic virtual time for the serving fleet.
//!
//! Every latency the coordinator reports is charged from a *modeled*
//! cost — compile stalls from [`CompileReport::total`] (the
//! deterministic work-counter model), execution from the cycle
//! simulator — never from `Instant::now()`. Replaying a workload
//! therefore produces bit-identical statistics, which is what makes
//! serving regressions diffable across commits and machines.

use crate::compiler::CompileReport;

/// Monotonic virtual clock (seconds since fleet start). The coordinator
/// advances it through request arrivals and job completions; its final
/// reading is the workload makespan.
#[derive(Clone, Copy, Debug, Default)]
pub struct VirtualClock {
    now: f64,
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock { now: 0.0 }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Advance to `t`; no-op when `t` is already past (jobs on different
    /// devices complete out of arrival order).
    pub fn advance_to(&mut self, t: f64) {
        if t > self.now {
            self.now = t;
        }
    }
}

/// The virtual cost of one software compile: the deterministic modeled
/// pass total of the report the compile produced.
pub fn compile_cost(report: &CompileReport) -> f64 {
    report.total()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_is_monotonic() {
        let mut c = VirtualClock::new();
        assert_eq!(c.now(), 0.0);
        c.advance_to(2.5);
        c.advance_to(1.0); // in the past: ignored
        assert_eq!(c.now(), 2.5);
        c.advance_to(3.0);
        assert_eq!(c.now(), 3.0);
    }

    #[test]
    fn compile_cost_tracks_work() {
        let small = CompileReport { layers: 4, instrs: 100, blocks: 10, ..Default::default() };
        let large = CompileReport { layers: 4, instrs: 100_000, blocks: 9_000, ..Default::default() };
        assert!(compile_cost(&small) > 0.0);
        assert!(compile_cost(&large) > compile_cost(&small));
        // Measured wall-clock fields do not leak into the virtual cost.
        let noisy = CompileReport { t_mapping: 123.0, ..small };
        assert_eq!(compile_cost(&noisy), compile_cost(&small));
    }
}
