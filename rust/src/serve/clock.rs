//! Deterministic virtual time for the serving fleet.
//!
//! Every latency the coordinator reports is charged from a *modeled*
//! cost — compile stalls from [`CompileReport::total`] (the
//! deterministic work-counter model), execution from the cycle
//! simulator — never from `Instant::now()`. Replaying a workload
//! therefore produces bit-identical statistics, which is what makes
//! serving regressions diffable across commits and machines.

use crate::compiler::CompileReport;

/// Monotonic virtual clock (seconds since fleet start). The coordinator
/// advances it through request arrivals and job completions; its final
/// reading is the workload makespan.
#[derive(Clone, Copy, Debug, Default)]
pub struct VirtualClock {
    now: f64,
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock { now: 0.0 }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Advance to `t`; no-op when `t` is already past (jobs on different
    /// devices complete out of arrival order).
    pub fn advance_to(&mut self, t: f64) {
        if t > self.now {
            self.now = t;
        }
    }
}

/// The virtual cost of one software compile: the deterministic modeled
/// pass total of the report the compile produced.
pub fn compile_cost(report: &CompileReport) -> f64 {
    report.total()
}

/// Modeled host-side sampling setup per mini-batch request (CSR row
/// lookups, hash-map init).
pub const SAMPLE_SETUP_S: f64 = 2e-6;
/// Modeled cost per sampled vertex (relabeling + feature-row gather).
pub const SAMPLE_PER_VERTEX_S: f64 = 3e-9;
/// Modeled cost per sampled edge (slot scan + weight gather).
pub const SAMPLE_PER_EDGE_S: f64 = 5e-9;

/// Fixed per-device-visit dispatch overhead of a mini-batch job
/// (descriptor setup + PCIe doorbell). Micro-batched riders append to
/// an already-scheduled visit and share this one overhead — which is
/// exactly the batching win the dispatcher chases.
pub const VISIT_OVERHEAD_S: f64 = 4e-5;

/// Deterministic modeled cost of extracting one ego-net. Linear in the
/// sampled neighborhood — the whole point of the mini-batch path is
/// that no per-request cost scales with the full graph.
pub fn sample_cost(vertices: u64, edges: u64) -> f64 {
    SAMPLE_SETUP_S
        + vertices as f64 * SAMPLE_PER_VERTEX_S
        + edges as f64 * SAMPLE_PER_EDGE_S
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_is_monotonic() {
        let mut c = VirtualClock::new();
        assert_eq!(c.now(), 0.0);
        c.advance_to(2.5);
        c.advance_to(1.0); // in the past: ignored
        assert_eq!(c.now(), 2.5);
        c.advance_to(3.0);
        assert_eq!(c.now(), 3.0);
    }

    #[test]
    fn compile_cost_tracks_work() {
        let small = CompileReport { layers: 4, instrs: 100, blocks: 10, ..Default::default() };
        let large = CompileReport { layers: 4, instrs: 100_000, blocks: 9_000, ..Default::default() };
        assert!(compile_cost(&small) > 0.0);
        assert!(compile_cost(&large) > compile_cost(&small));
        // Measured wall-clock fields do not leak into the virtual cost.
        let noisy = CompileReport { t_mapping: 123.0, ..small };
        assert_eq!(compile_cost(&noisy), compile_cost(&small));
    }

    #[test]
    fn sample_cost_scales_with_the_neighborhood() {
        let tiny = sample_cost(8, 16);
        let big = sample_cost(8_000, 160_000);
        assert!(tiny > 0.0);
        assert!(big > tiny);
        // A visit's fixed overhead dominates a tiny sample: batching
        // riders must be worth something.
        assert!(VISIT_OVERHEAD_S > tiny);
    }
}
