//! Deterministic fault injection for the serving fleet.
//!
//! A [`FaultPlan`] is a seeded list of virtual-clock-scheduled events:
//! device crashes (with an optional recovery), transient stalls, and
//! cached-artifact corruptions. The plan is fully known when serving
//! starts — an *outage calendar* — so the coordinator can keep its
//! respond-at-admission discipline: every attempt is quoted against the
//! per-device fault windows, an attempt that would cross a crash window
//! fails at the crash instant and is retried with exponential backoff
//! charged on the virtual clock, and the whole faulty run replays
//! bit-identically from its trace (the plan rides in the trace config).
//!
//! Health is derived, not stored: [`FaultPlan::health_at`] reads the
//! calendar — `Healthy → Stalled → Down → Recovering` — where
//! `Recovering` is the cold-cache window right after a crash ends
//! (the device serves again but repays every compile).
//!
//! With no plan (or an empty one) the coordinator takes its historical
//! code path untouched: zero-fault serving stays byte-identical to a
//! build without this module.

use crate::ir::{zoo_model, ZooModel};
use crate::util::{Json, Rng};
use anyhow::{bail, Context, Result};
use std::path::Path;

/// One scheduled fault. Times are virtual-clock seconds since fleet
/// start (the daemon stamps real arrivals onto the same clock, so a
/// live chaos run and its offline replay agree).
#[derive(Clone, Debug, PartialEq)]
pub enum FaultEvent {
    /// Device `device` dies at `at`. `recover_after > 0` brings it back
    /// (with a cold cache) after that many seconds; `recover_after <= 0`
    /// is a permanent loss.
    DeviceCrash { device: u32, at: f64, recover_after: f64 },
    /// Device `device` stops making progress during
    /// `[at, at + duration)`: in-flight work pauses and resumes — no
    /// work is lost, latency stretches.
    TransientStall { device: u32, at: f64, duration: f64 },
    /// From `at` on, the next access to the cached whole-graph artifact
    /// of (`model`, `dataset`) on `device` finds its `.ga` bytes
    /// corrupted: the loader rejects it, the entry is evicted and the
    /// program recompiles (the request still completes).
    ArtifactCorruption { device: u32, at: f64, model: ZooModel, dataset: String },
}

impl FaultEvent {
    /// The scheduled instant of this event.
    pub fn at(&self) -> f64 {
        match self {
            FaultEvent::DeviceCrash { at, .. }
            | FaultEvent::TransientStall { at, .. }
            | FaultEvent::ArtifactCorruption { at, .. } => *at,
        }
    }

    /// The device this event targets.
    pub fn device(&self) -> u32 {
        match self {
            FaultEvent::DeviceCrash { device, .. }
            | FaultEvent::TransientStall { device, .. }
            | FaultEvent::ArtifactCorruption { device, .. } => *device,
        }
    }
}

/// Derived per-device health at an instant (see [`FaultPlan::health_at`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Health {
    /// No fault window covers this instant.
    Healthy,
    /// Inside a transient-stall window: alive, not progressing.
    Stalled,
    /// Inside a crash window (or permanently lost).
    Down,
    /// Crash window over, cache still cold: serving, repaying compiles.
    Recovering,
}

/// How long a rejoined device counts as `Recovering` after its crash
/// window ends (purely an observability classification — routing treats
/// recovering and healthy devices alike; the cold cache is the real
/// penalty either way).
pub const RECOVERY_WINDOW_S: f64 = 0.05;

/// A seeded, fully-scheduled fault calendar.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// The seed the plan was generated from (0 for hand-authored
    /// plans) — recorded for provenance, not consulted at serve time.
    pub seed: u64,
    /// The scheduled events, in authoring order (serve-time lookups
    /// scan, so order only matters for tie-breaking identical instants).
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// An empty plan: serving behaves exactly as if no plan were set.
    pub fn empty() -> FaultPlan {
        FaultPlan::default()
    }

    /// Deterministic crash-and-recover chaos schedule: every device
    /// except device 0 crashes once inside `[0, horizon_s)` and
    /// recovers after roughly a quarter horizon; a transient stall and
    /// one artifact corruption ride along. Device 0 never crashes, so a
    /// healthy route always exists and no request is shed for want of a
    /// device.
    pub fn crash_and_recover(seed: u64, n_devices: usize, horizon_s: f64) -> FaultPlan {
        let mut rng = Rng::new(seed ^ 0xFA01);
        let mut events = Vec::new();
        for d in 1..n_devices {
            let at = horizon_s * (0.1 + 0.6 * (rng.below(1000) as f64 / 1000.0));
            events.push(FaultEvent::DeviceCrash {
                device: d as u32,
                at,
                recover_after: horizon_s * 0.25,
            });
        }
        events.push(FaultEvent::TransientStall {
            device: 0,
            at: horizon_s * 0.05,
            duration: horizon_s * 0.02,
        });
        events.push(FaultEvent::ArtifactCorruption {
            device: 0,
            at: horizon_s * 0.5,
            model: ZooModel::B1,
            dataset: "CO".to_string(),
        });
        FaultPlan { seed, events }
    }

    /// Derived health of `device` at `t` (ties broken toward the more
    /// degraded state: a stall scheduled inside a crash window reads as
    /// `Down`).
    pub fn health_at(&self, device: u32, t: f64) -> Health {
        let mut health = Health::Healthy;
        for e in &self.events {
            if e.device() != device {
                continue;
            }
            match *e {
                FaultEvent::DeviceCrash { at, recover_after, .. } => {
                    let until = if recover_after > 0.0 { at + recover_after } else { f64::INFINITY };
                    if at <= t && t < until {
                        return Health::Down;
                    }
                    if recover_after > 0.0 && until <= t && t < until + RECOVERY_WINDOW_S {
                        health = Health::Recovering;
                    }
                }
                FaultEvent::TransientStall { at, duration, .. } => {
                    if at <= t && t < at + duration && health == Health::Healthy {
                        health = Health::Stalled;
                    }
                }
                FaultEvent::ArtifactCorruption { .. } => {}
            }
        }
        health
    }

    /// JSON encoding (`seed` as a decimal string so u64 round-trips
    /// exactly).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seed", Json::Str(self.seed.to_string())),
            ("events", Json::Arr(self.events.iter().map(fault_event_json).collect())),
        ])
    }

    /// Inverse of [`FaultPlan::to_json`].
    pub fn from_json(j: &Json) -> Result<FaultPlan> {
        let seed = j
            .str_of("seed")?
            .parse::<u64>()
            .map_err(|_| anyhow::anyhow!("fault-plan field 'seed' is not a u64 string"))?;
        let events = j
            .arr_of("events")?
            .iter()
            .enumerate()
            .map(|(i, e)| fault_event_from(e).with_context(|| format!("events[{i}]")))
            .collect::<Result<Vec<_>>>()?;
        Ok(FaultPlan { seed, events })
    }

    /// Parse a plan from its JSON text (the `--fault-plan` file format).
    pub fn parse(text: &str) -> Result<FaultPlan> {
        FaultPlan::from_json(&Json::parse(text).context("fault plan is not valid JSON")?)
    }

    /// Load a plan from a `--fault-plan` JSON file.
    pub fn load(path: &Path) -> Result<FaultPlan> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading fault plan {}", path.display()))?;
        FaultPlan::parse(&text).with_context(|| format!("parsing fault plan {}", path.display()))
    }

    /// Write the plan as JSON (one trailing newline).
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
            .with_context(|| format!("writing fault plan {}", path.display()))
    }
}

/// JSON codec of one fault event (`kind` discriminant; unknown kinds
/// are a hard error, matching the trace format's versioning rules).
pub fn fault_event_json(e: &FaultEvent) -> Json {
    match e {
        FaultEvent::DeviceCrash { device, at, recover_after } => Json::obj(vec![
            ("kind", Json::Str("crash".into())),
            ("device", Json::Num(*device as f64)),
            ("at", Json::Num(*at)),
            ("recover_after", Json::Num(*recover_after)),
        ]),
        FaultEvent::TransientStall { device, at, duration } => Json::obj(vec![
            ("kind", Json::Str("stall".into())),
            ("device", Json::Num(*device as f64)),
            ("at", Json::Num(*at)),
            ("duration", Json::Num(*duration)),
        ]),
        FaultEvent::ArtifactCorruption { device, at, model, dataset } => Json::obj(vec![
            ("kind", Json::Str("corruption".into())),
            ("device", Json::Num(*device as f64)),
            ("at", Json::Num(*at)),
            ("model", Json::Str(model.key().to_string())),
            ("dataset", Json::Str(dataset.clone())),
        ]),
    }
}

/// Inverse of [`fault_event_json`].
pub fn fault_event_from(j: &Json) -> Result<FaultEvent> {
    match j.str_of("kind")? {
        "crash" => Ok(FaultEvent::DeviceCrash {
            device: j.u32_of("device")?,
            at: j.f64_of("at")?,
            recover_after: j.f64_of("recover_after")?,
        }),
        "stall" => Ok(FaultEvent::TransientStall {
            device: j.u32_of("device")?,
            at: j.f64_of("at")?,
            duration: j.f64_of("duration")?,
        }),
        "corruption" => {
            let m = j.str_of("model")?;
            Ok(FaultEvent::ArtifactCorruption {
                device: j.u32_of("device")?,
                at: j.f64_of("at")?,
                model: zoo_model(m).ok_or_else(|| anyhow::anyhow!("unknown model '{m}'"))?,
                dataset: j.str_of("dataset")?.to_string(),
            })
        }
        k => bail!("unknown fault event kind '{k}'"),
    }
}

/// Per-hop fanout cap of the `CappedFanout` degradation rung: a
/// mini-batch degraded under deadline pressure re-samples with every
/// hop's fanout clamped to this, so the smaller ego-net quotes a
/// sooner completion.
pub const DEGRADED_FANOUT_CAP: u32 = 4;

/// Which rung of the fidelity cascade a degraded request landed on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Degradation {
    /// Served int8 instead of the requested f32 (GA03 path).
    Int8,
    /// Mini-batch re-sampled with the fanout capped.
    CappedFanout,
    /// Both rungs.
    Int8CappedFanout,
}

/// Why a request was shed instead of served.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ShedReason {
    /// Every device sat in an unrecoverable crash window.
    NoHealthyDevice,
    /// `CostModel::max_retries` attempts all died under crashes.
    RetriesExhausted,
    /// A best-effort request still past its QoS deadline after the full
    /// fidelity cascade (see [`super::qos`]); shed by policy, not by a
    /// fault.
    DeadlineMissed,
}

/// How a request ended. Every accepted request gets exactly one — the
/// no-lost-work invariant the fault tests pin.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Outcome {
    /// Served at requested fidelity.
    Completed,
    /// Served on a lower fidelity rung.
    Degraded(Degradation),
    /// Not served.
    Shed(ShedReason),
}

impl Default for Outcome {
    fn default() -> Outcome {
        Outcome::Completed
    }
}

impl Outcome {
    /// Stable wire key (trace v2 encoding).
    pub fn key(&self) -> &'static str {
        match self {
            Outcome::Completed => "completed",
            Outcome::Degraded(Degradation::Int8) => "degraded:int8",
            Outcome::Degraded(Degradation::CappedFanout) => "degraded:capped_fanout",
            Outcome::Degraded(Degradation::Int8CappedFanout) => "degraded:int8_capped_fanout",
            Outcome::Shed(ShedReason::NoHealthyDevice) => "shed:no_healthy_device",
            Outcome::Shed(ShedReason::RetriesExhausted) => "shed:retries_exhausted",
            Outcome::Shed(ShedReason::DeadlineMissed) => "shed:deadline_missed",
        }
    }

    /// Inverse of [`Outcome::key`]; unknown outcomes are a hard error.
    pub fn parse(s: &str) -> Result<Outcome> {
        Ok(match s {
            "completed" => Outcome::Completed,
            "degraded:int8" => Outcome::Degraded(Degradation::Int8),
            "degraded:capped_fanout" => Outcome::Degraded(Degradation::CappedFanout),
            "degraded:int8_capped_fanout" => Outcome::Degraded(Degradation::Int8CappedFanout),
            "shed:no_healthy_device" => Outcome::Shed(ShedReason::NoHealthyDevice),
            "shed:retries_exhausted" => Outcome::Shed(ShedReason::RetriesExhausted),
            "shed:deadline_missed" => Outcome::Shed(ShedReason::DeadlineMissed),
            _ => bail!("unknown outcome '{s}'"),
        })
    }

    /// True for any [`Outcome::Shed`].
    pub fn is_shed(&self) -> bool {
        matches!(self, Outcome::Shed(_))
    }

    /// True for any [`Outcome::Degraded`].
    pub fn is_degraded(&self) -> bool {
        matches!(self, Outcome::Degraded(_))
    }
}

/// One fired fault, as the coordinator logged it (spliced into the v2
/// trace as a `fault` event; `at` is the *scheduled* instant).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultRecord {
    /// Scheduled instant of the event (virtual-clock seconds).
    pub at: f64,
    /// The fired event.
    pub fault: FaultEvent,
}

/// One degrade/shed decision (spliced into the v2 trace as a `decision`
/// event; completions are not logged — they are the common case).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DecisionRecord {
    /// Arrival of the affected request (virtual-clock seconds).
    pub at: f64,
    /// Tenant of the affected request.
    pub tenant: u32,
    /// The non-`Completed` outcome decided.
    pub outcome: Outcome,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_plan() -> FaultPlan {
        FaultPlan {
            seed: 9,
            events: vec![
                FaultEvent::DeviceCrash { device: 1, at: 0.01, recover_after: 0.05 },
                FaultEvent::DeviceCrash { device: 2, at: 0.02, recover_after: -1.0 },
                FaultEvent::TransientStall { device: 0, at: 0.005, duration: 0.002 },
                FaultEvent::ArtifactCorruption {
                    device: 0,
                    at: 0.03,
                    model: ZooModel::B1,
                    dataset: "CO".to_string(),
                },
            ],
        }
    }

    #[test]
    fn plan_round_trips_through_json() {
        let plan = sample_plan();
        let text = plan.to_json().to_string();
        let back = FaultPlan::parse(&text).unwrap();
        assert_eq!(back, plan);
        // u64 seeds survive exactly (decimal-string convention).
        let big = FaultPlan { seed: u64::MAX, events: vec![] };
        assert_eq!(FaultPlan::parse(&big.to_json().to_string()).unwrap(), big);
    }

    #[test]
    fn unknown_fault_kind_is_a_hard_error() {
        let j = Json::parse(r#"{"kind": "meteor", "device": 0, "at": 0.1}"#).unwrap();
        let err = fault_event_from(&j).unwrap_err().to_string();
        assert!(err.contains("unknown fault event kind 'meteor'"), "{err}");
    }

    #[test]
    fn health_walks_the_state_machine() {
        let plan = sample_plan();
        // Device 0: stalled inside its stall window, healthy otherwise.
        assert_eq!(plan.health_at(0, 0.0), Health::Healthy);
        assert_eq!(plan.health_at(0, 0.006), Health::Stalled);
        assert_eq!(plan.health_at(0, 0.008), Health::Healthy);
        // Device 1: down inside the crash window, recovering (cold)
        // just after, healthy later.
        assert_eq!(plan.health_at(1, 0.02), Health::Down);
        assert_eq!(plan.health_at(1, 0.061), Health::Recovering);
        assert_eq!(plan.health_at(1, 0.2), Health::Healthy);
        // Device 2: permanent loss.
        assert_eq!(plan.health_at(2, 0.02), Health::Down);
        assert_eq!(plan.health_at(2, 1e9), Health::Down);
    }

    #[test]
    fn outcome_keys_round_trip() {
        let all = [
            Outcome::Completed,
            Outcome::Degraded(Degradation::Int8),
            Outcome::Degraded(Degradation::CappedFanout),
            Outcome::Degraded(Degradation::Int8CappedFanout),
            Outcome::Shed(ShedReason::NoHealthyDevice),
            Outcome::Shed(ShedReason::RetriesExhausted),
            Outcome::Shed(ShedReason::DeadlineMissed),
        ];
        for o in all {
            assert_eq!(Outcome::parse(o.key()).unwrap(), o);
        }
        assert!(Outcome::parse("vaporized").is_err());
        assert!(Outcome::Shed(ShedReason::NoHealthyDevice).is_shed());
        assert!(Outcome::Degraded(Degradation::Int8).is_degraded());
        assert!(!Outcome::Completed.is_shed());
    }

    #[test]
    fn seeded_generator_is_deterministic_and_spares_device_zero() {
        let a = FaultPlan::crash_and_recover(7, 4, 1.0);
        let b = FaultPlan::crash_and_recover(7, 4, 1.0);
        assert_eq!(a, b);
        let crashes: Vec<u32> = a
            .events
            .iter()
            .filter_map(|e| match e {
                FaultEvent::DeviceCrash { device, .. } => Some(*device),
                _ => None,
            })
            .collect();
        assert_eq!(crashes, vec![1, 2, 3]);
        assert_ne!(FaultPlan::crash_and_recover(8, 4, 1.0), a);
    }
}
