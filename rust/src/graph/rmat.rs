//! R-MAT synthetic graph generator (Chakrabarti et al.): power-law degree
//! skew matching real-world graphs. Used to synthesize stand-ins for the
//! Table-4 datasets (see DESIGN.md "Substitutions") at the exact |V|/|E|.
//!
//! Two paths:
//! * [`rmat_edges`] materializes edges (small graphs, functional tests);
//! * [`rmat_tile_counts`] streams edges directly into per-subshard
//!   histograms without storing them — Reddit (116M) and Amazon-Products
//!   (264M) never need materializing for compilation or simulation.

use super::coo::{CooGraph, GraphMeta};
use super::partition::TileCounts;
use crate::util::Rng;

/// R-MAT quadrant probabilities plus a community-locality term.
///
/// Real benchmark graphs (Yelp, Amazon-Products especially) have strong
/// community structure: most edges stay inside a vertex neighborhood the
/// size of an on-chip partition, which is precisely what determines
/// subshard occupancy. Pure R-MAT spreads edges too uniformly across
/// subshards, inflating cross-tile traffic. `locality` is the fraction
/// of edges redirected to land within the source's `community`-sized
/// block (see DESIGN.md "Substitutions").
#[derive(Clone, Copy, Debug)]
pub struct RmatParams {
    pub a: f64,
    pub b: f64,
    pub c: f64,
    /// Probability an edge stays within the source's community block.
    pub locality: f64,
    /// Community block size (vertices); defaults to N1 = 16384.
    pub community: u64,
}

impl Default for RmatParams {
    fn default() -> Self {
        // d = 1 - a - b - c = 0.05
        RmatParams { a: 0.57, b: 0.19, c: 0.19, locality: 0.0, community: 16384 }
    }
}

impl RmatParams {
    pub fn with_locality(locality: f64) -> RmatParams {
        RmatParams { locality, ..Default::default() }
    }
}

impl RmatParams {
    /// 16-bit quantized cumulative quadrant thresholds (quantization
    /// bias ~1e-5 — irrelevant for synthetic degree-skew matching, and
    /// ~6x faster than per-level f64 draws: four levels per u64 draw).
    #[inline]
    fn thresholds(&self) -> (u64, u64, u64) {
        let q = 65536.0;
        (
            (self.a * q) as u64,
            ((self.a + self.b) * q) as u64,
            ((self.a + self.b + self.c) * q) as u64,
        )
    }

    /// Sample one directed edge in an n x n adjacency matrix
    /// (n rounded up to a power of two internally, rejected if >= n).
    #[inline]
    fn sample(&self, rng: &mut Rng, n: u64) -> (u32, u32) {
        self.sample_with(rng, n, self.thresholds())
    }

    #[inline]
    fn sample_with(&self, rng: &mut Rng, n: u64, t: (u64, u64, u64)) -> (u32, u32) {
        let bits = 64 - (n - 1).leading_zeros() as u64;
        loop {
            let (mut r, mut c) = (0u64, 0u64);
            let mut pool = 0u64;
            let mut avail = 0u32;
            for _ in 0..bits {
                if avail == 0 {
                    pool = rng.next_u64();
                    avail = 4;
                }
                let v = pool & 0xFFFF;
                pool >>= 16;
                avail -= 1;
                // Branchless quadrant select: the three cumulative
                // thresholds partition [0, 65536) into the four R-MAT
                // quadrants; row bit = v >= t2, col bit toggles at every
                // threshold crossing except t2.
                let ge1 = (v >= t.0) as u64;
                let ge2 = (v >= t.1) as u64;
                let ge3 = (v >= t.2) as u64;
                r = (r << 1) | ge2;
                c = (c << 1) | (ge1 ^ ge2 ^ ge3);
            }
            if r < n && c < n {
                return (r as u32, c as u32);
            }
        }
    }

    /// Apply the community-locality redirection to a sampled edge.
    #[inline]
    fn localize(&self, rng: &mut Rng, n: u64, s: u32, d: u32) -> (u32, u32) {
        if self.locality > 0.0 && rng.f64() < self.locality {
            let block = (s as u64 / self.community) * self.community;
            let width = self.community.min(n - block);
            (s, (block + rng.below(width)) as u32)
        } else {
            (s, d)
        }
    }

    /// Bulk-sample `m` edges into packed (src, dst) pairs.
    pub fn sample_edges(&self, rng: &mut Rng, n: u64, m: usize) -> (Vec<u32>, Vec<u32>) {
        let t = self.thresholds();
        let mut src = Vec::with_capacity(m);
        let mut dst = Vec::with_capacity(m);
        for _ in 0..m {
            let (s, d) = self.sample_with(rng, n, t);
            let (s, d) = self.localize(rng, n, s, d);
            src.push(s);
            dst.push(d);
        }
        (src, dst)
    }
}

/// Materialize an R-MAT graph with exactly `meta.n_edges` edges and unit
/// weights. Deterministic in `seed`.
pub fn rmat_edges(meta: GraphMeta, params: RmatParams, seed: u64) -> CooGraph {
    let mut rng = Rng::new(seed);
    let m = meta.n_edges as usize;
    let mut src = Vec::with_capacity(m);
    let mut dst = Vec::with_capacity(m);
    for _ in 0..m {
        let (s, d) = params.sample(&mut rng, meta.n_vertices);
        let (s, d) = params.localize(&mut rng, meta.n_vertices, s, d);
        src.push(s);
        dst.push(d);
    }
    let w = vec![1.0f32; m];
    CooGraph::new(meta, src, dst, w)
}

/// Stream R-MAT edges directly into Fiber-Shard tile counts: counts[i][j]
/// = number of edges whose dst is in shard i (rows) and src in subshard j
/// (cols), with shard height/width N1. Memory is O((|V|/N1)^2), never
/// O(|E|) — this is what makes compiling Amazon-Products-scale synthetic
/// graphs practical.
pub fn rmat_tile_counts(
    meta: &GraphMeta,
    params: RmatParams,
    seed: u64,
    n1: u64,
) -> TileCounts {
    let mut rng = Rng::new(seed);
    let shards = meta.n_vertices.div_ceil(n1) as usize;
    let mut counts = vec![0u64; shards * shards];
    for _ in 0..meta.n_edges {
        let (s, d) = params.sample(&mut rng, meta.n_vertices);
        let (s, d) = params.localize(&mut rng, meta.n_vertices, s, d);
        let (si, sj) = ((d as u64 / n1) as usize, (s as u64 / n1) as usize);
        counts[si * shards + sj] += 1;
    }
    TileCounts { n1, shards, counts }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(n: u64, m: u64) -> GraphMeta {
        GraphMeta::new("rmat-test", n, m, 16, 4)
    }

    #[test]
    fn exact_edge_count_and_range() {
        let g = rmat_edges(meta(1000, 5000), RmatParams::default(), 1);
        assert_eq!(g.m(), 5000);
        assert!(g.src.iter().all(|&s| (s as u64) < 1000));
        assert!(g.dst.iter().all(|&d| (d as u64) < 1000));
    }

    #[test]
    fn deterministic_in_seed() {
        let a = rmat_edges(meta(256, 1024), RmatParams::default(), 7);
        let b = rmat_edges(meta(256, 1024), RmatParams::default(), 7);
        assert_eq!(a.src, b.src);
        assert_eq!(a.dst, b.dst);
        let c = rmat_edges(meta(256, 1024), RmatParams::default(), 8);
        assert_ne!(a.src, c.src);
    }

    #[test]
    fn skewed_degrees() {
        // a=0.57 concentrates mass in low vertex ids: max degree must be
        // far above the mean (power-law-ish skew).
        let g = rmat_edges(meta(1024, 16384), RmatParams::default(), 3);
        let deg = g.in_degree();
        let max = *deg.iter().max().unwrap() as f64;
        let mean = 16384.0 / 1024.0;
        assert!(max > 8.0 * mean, "max {max} mean {mean}");
    }

    #[test]
    fn tile_counts_match_materialized() {
        let m = meta(512, 4096);
        let n1 = 128;
        let tc = rmat_tile_counts(&m, RmatParams::default(), 9, n1);
        let g = rmat_edges(m, RmatParams::default(), 9);
        let shards = tc.shards;
        let mut want = vec![0u64; shards * shards];
        for i in 0..g.m() {
            let (si, sj) = (
                (g.dst[i] as u64 / n1) as usize,
                (g.src[i] as u64 / n1) as usize,
            );
            want[si * shards + sj] += 1;
        }
        assert_eq!(tc.counts, want);
        assert_eq!(tc.total_edges(), 4096);
    }

    #[test]
    fn non_pow2_vertex_count() {
        let g = rmat_edges(meta(300, 1000), RmatParams::default(), 5);
        assert!(g.src.iter().all(|&s| (s as u64) < 300));
        assert!(g.dst.iter().all(|&d| (d as u64) < 300));
    }
}
