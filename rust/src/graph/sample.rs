//! Deterministic k-hop ego-network samplers for mini-batch inference
//! (the latency-bound serving regime of arXiv 2206.08536: per-request
//! inference over a handful of target vertices, with cost proportional
//! to the sampled neighborhood instead of the whole graph).
//!
//! A [`Sampler`] owns one whole-graph destination-row CSR (built once,
//! O(|V| + |E|), the same index the optimized kernels use) and extracts
//! induced ego-networks from it:
//!
//! * **full-neighborhood** sampling (`fanout[h] == `[`FULL_NEIGHBORHOOD`])
//!   keeps every in-edge of every frontier vertex — after `k` hops the
//!   target rows of a `k`-Aggregate model reproduce the whole-graph
//!   outputs exactly (the golden-equivalence property the test suite
//!   pins);
//! * **fanout-capped** sampling (GraphSAGE-style, e.g. `[25, 10]`) caps
//!   each vertex's expansion per hop with a seed-stamped deterministic
//!   draw, keeping tail-degree vertices from blowing up the ego-net.
//!
//! Determinism: the per-vertex neighbor draw is seeded by
//! `(seed, hop, vertex)` alone — independent of traversal order, thread
//! count, or any global RNG state — so the same request always yields
//! the same ego-net, bit for bit. Extraction itself touches only the
//! sampled rows of the CSR: O(sampled edges) per request.

use super::coo::{CooGraph, GraphMeta};
use super::partition::CsrSubshard;
use crate::util::Rng;
use std::collections::hash_map::Entry;
use std::collections::HashMap;

/// Per-hop fanout value meaning "keep every in-neighbor".
pub const FULL_NEIGHBORHOOD: u32 = u32::MAX;

/// A `hops`-deep full-neighborhood fanout vector.
pub fn full_fanout(hops: usize) -> Vec<u32> {
    vec![FULL_NEIGHBORHOOD; hops]
}

/// An extracted ego-network: the induced subgraph on the sampled
/// vertices (relabeled to a compact local id space, targets first in
/// request order) plus the local -> global vertex map.
///
/// Edge direction and weights are preserved verbatim from the parent
/// graph; local edge order is (hop, destination-in-frontier-order,
/// ascending CSR slot), which is itself deterministic.
#[derive(Clone, Debug)]
pub struct EgoNet {
    /// The induced subgraph; `meta` inherits `feat_len`/`n_classes`
    /// from the parent graph.
    pub graph: CooGraph,
    /// Local vertex id -> parent-graph vertex id (targets occupy
    /// locals `0..n_targets`).
    pub origin: Vec<u32>,
    /// Number of (deduplicated) target vertices.
    pub n_targets: usize,
    /// The request seed the sample was drawn with.
    pub seed: u64,
}

impl EgoNet {
    /// Sampled vertex count (targets + neighborhood).
    pub fn n(&self) -> usize {
        self.graph.n()
    }

    /// Sampled edge count.
    pub fn m(&self) -> usize {
        self.graph.m()
    }

    /// Gather the sampled vertices' feature rows from the parent
    /// feature matrix `x` (row-major, `f` columns), in local-id order.
    pub fn gather_features(&self, x: &[f32], f: usize) -> Vec<f32> {
        self.padded_features(x, f, self.n())
    }

    /// [`EgoNet::gather_features`] zero-padded to `padded_n` rows — the
    /// input shape of a bucket executable. Padding rows are zero and
    /// edge-free, so they are inert through Sum/Mean/Max aggregation:
    /// no edge references a padded row, and untouched rows are zeroed
    /// by the kernels' touched-row convention.
    pub fn padded_features(&self, x: &[f32], f: usize, padded_n: usize) -> Vec<f32> {
        assert!(padded_n >= self.n(), "padded_n {padded_n} < sampled {}", self.n());
        let mut out = vec![0f32; padded_n * f];
        for (l, &g) in self.origin.iter().enumerate() {
            let at = g as usize * f;
            out[l * f..(l + 1) * f].copy_from_slice(&x[at..at + f]);
        }
        out
    }

    /// The same edges re-homed in a `padded_n`-vertex graph (the bucket
    /// shape). The extra vertices are isolated, so every kernel result
    /// on the live rows is bit-identical to the unpadded execution.
    pub fn padded_graph(&self, padded_n: u64) -> CooGraph {
        assert!(padded_n >= self.n() as u64);
        let meta = GraphMeta::new(
            &self.graph.meta.name,
            padded_n,
            self.graph.meta.n_edges,
            self.graph.meta.feat_len,
            self.graph.meta.n_classes,
        );
        CooGraph::new(
            meta,
            self.graph.src.clone(),
            self.graph.dst.clone(),
            self.graph.w.clone(),
        )
    }
}

/// Per-(seed, hop, vertex) RNG seed: decorrelated so a vertex's draw is
/// independent of when (or how often) the traversal reaches it.
fn vertex_seed(seed: u64, hop: u32, v: u32) -> u64 {
    let h = seed ^ 0x5EED_CAFE_F00Du64;
    let h = h.wrapping_mul(0x100000001B3) ^ (((hop as u64) << 32) | v as u64);
    h.wrapping_mul(0x9E3779B97F4A7C15)
}

/// An in-neighborhood source the ego-net extractor can traverse: the
/// static [`Sampler`] (whole-graph CSR) and the streaming
/// [`crate::stream::DynamicGraph`] (base CSR + delta overlay merge)
/// both implement it, so mini-batch sampling is one algorithm with one
/// determinism contract regardless of whether the graph is frozen or
/// churning.
pub trait NeighborView {
    /// Vertex count of the view (targets must be below it).
    fn n_vertices(&self) -> u64;

    /// Input feature length inherited by sampled ego-nets.
    fn feat_len(&self) -> u64;

    /// Class count inherited by sampled ego-nets.
    fn n_classes(&self) -> u64;

    /// Append `v`'s in-edges as `(src, weight)` pairs in the view's
    /// canonical order. The order must be stable for a given view state
    /// — it is what makes capped draws deterministic.
    fn in_edges(&self, v: u32, out: &mut Vec<(u32, f32)>);
}

/// Extract the k-hop ego-network of `targets` from any
/// [`NeighborView`] (`k = fanout.len()`). Hop `h` expands every vertex
/// first discovered at depth `h`, keeping at most `fanout[h]` of its
/// in-edges ([`FULL_NEIGHBORHOOD`] keeps all). Each vertex is expanded
/// at most once — under full-neighborhood sampling the expansion is
/// exhaustive, so repeat visits would only duplicate edges.
///
/// The capped draw picks *positions* within the row via a partial
/// Fisher-Yates seeded by `(seed, hop, vertex)` and restores ascending
/// position order, so the result depends only on the row contents and
/// the seed — identical to what [`Sampler::sample`] always produced.
pub fn sample_view(
    view: &impl NeighborView,
    targets: &[u32],
    fanout: &[u32],
    seed: u64,
) -> EgoNet {
    assert!(!targets.is_empty(), "mini-batch needs at least one target");
    let n = view.n_vertices() as u32;
    let mut local: HashMap<u32, u32> = HashMap::new();
    let mut origin: Vec<u32> = Vec::new();
    for &t in targets {
        assert!(t < n, "target {t} out of range (|V| = {n})");
        if let Entry::Vacant(e) = local.entry(t) {
            e.insert(origin.len() as u32);
            origin.push(t);
        }
    }
    let n_targets = origin.len();
    let mut src: Vec<u32> = Vec::new();
    let mut dst: Vec<u32> = Vec::new();
    let mut w: Vec<f32> = Vec::new();
    let mut frontier: Vec<u32> = origin.clone();
    let mut row: Vec<(u32, f32)> = Vec::new();
    let mut picks: Vec<usize> = Vec::new();
    for (hop, &cap) in fanout.iter().enumerate() {
        let mut next: Vec<u32> = Vec::new();
        for &v in &frontier {
            let v_local = local[&v];
            row.clear();
            view.in_edges(v, &mut row);
            let deg = row.len();
            picks.clear();
            picks.extend(0..deg);
            if (cap as usize) < deg {
                // Deterministic partial Fisher-Yates: pick `cap`
                // distinct positions, then restore ascending order so
                // the ego-net's edge layout is stable.
                let mut rng = Rng::new(vertex_seed(seed, hop as u32, v));
                let k = cap as usize;
                for i in 0..k {
                    let j = i + rng.below((deg - i) as u64) as usize;
                    picks.swap(i, j);
                }
                picks.truncate(k);
                picks.sort_unstable();
            }
            for &p in &picks {
                let (u, wt) = row[p];
                let u_local = match local.entry(u) {
                    Entry::Occupied(o) => *o.get(),
                    Entry::Vacant(e) => {
                        let id = origin.len() as u32;
                        e.insert(id);
                        origin.push(u);
                        next.push(u);
                        id
                    }
                };
                src.push(u_local);
                dst.push(v_local);
                w.push(wt);
            }
        }
        frontier = next;
        if frontier.is_empty() {
            break;
        }
    }
    let meta = GraphMeta::new(
        "ego",
        origin.len() as u64,
        src.len() as u64,
        view.feat_len(),
        view.n_classes(),
    );
    EgoNet {
        graph: CooGraph::new(meta, src, dst, w),
        origin,
        n_targets,
        seed,
    }
}

/// Ego-network extractor over one parent graph: the whole-graph
/// destination-row CSR is built once and shared by every sample.
pub struct Sampler {
    graph: CooGraph,
    csr: CsrSubshard,
}

impl Sampler {
    /// Build the whole-graph in-edge index. O(|V| + |E|), done once.
    pub fn new(graph: CooGraph) -> Sampler {
        let csr = CsrSubshard::from_local_coo(
            graph.dst.iter().copied(),
            graph.src.iter().copied(),
            graph.n(),
        );
        Sampler { graph, csr }
    }

    pub fn graph(&self) -> &CooGraph {
        &self.graph
    }

    /// Extract the k-hop ego-network of `targets` (`k = fanout.len()`)
    /// — [`sample_view`] over the whole-graph CSR.
    pub fn sample(&self, targets: &[u32], fanout: &[u32], seed: u64) -> EgoNet {
        sample_view(self, targets, fanout, seed)
    }
}

impl NeighborView for Sampler {
    fn n_vertices(&self) -> u64 {
        self.graph.meta.n_vertices
    }

    fn feat_len(&self) -> u64 {
        self.graph.meta.feat_len
    }

    fn n_classes(&self) -> u64 {
        self.graph.meta.n_classes
    }

    fn in_edges(&self, v: u32, out: &mut Vec<(u32, f32)>) {
        for slot in self.csr.row(v as usize) {
            out.push((self.csr.cols[slot], self.graph.w[self.csr.perm[slot] as usize]));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::rmat::{rmat_edges, RmatParams};

    fn skewed(n: u64, m: u64, seed: u64) -> CooGraph {
        rmat_edges(GraphMeta::new("t", n, m, 8, 2), RmatParams::default(), seed)
            .gcn_normalized()
    }

    #[test]
    fn ring_one_hop_is_the_predecessor() {
        // Ring i -> (i+1): in-neighborhood of vertex 3 is vertex 2.
        let s = Sampler::new(CooGraph::ring(8, 4, 2));
        let ego = s.sample(&[3], &[FULL_NEIGHBORHOOD], 1);
        assert_eq!(ego.n_targets, 1);
        assert_eq!(ego.origin, vec![3, 2]);
        assert_eq!(ego.m(), 1);
        assert_eq!((ego.graph.src[0], ego.graph.dst[0]), (1, 0));
        // Two hops: 3 <- 2 <- 1.
        let ego2 = s.sample(&[3], &full_fanout(2), 1);
        assert_eq!(ego2.origin, vec![3, 2, 1]);
        assert_eq!(ego2.m(), 2);
    }

    #[test]
    fn full_sampling_of_all_vertices_is_the_whole_graph() {
        let g = skewed(200, 1200, 5);
        let s = Sampler::new(g.clone());
        let targets: Vec<u32> = (0..200).collect();
        let ego = s.sample(&targets, &full_fanout(1), 9);
        assert_eq!(ego.n(), g.n());
        assert_eq!(ego.m(), g.m());
        // Identity relabeling (targets in id order), same edge multiset.
        assert_eq!(ego.origin, targets);
        let mut a: Vec<(u32, u32, u32)> = ego
            .graph
            .src
            .iter()
            .zip(&ego.graph.dst)
            .zip(&ego.graph.w)
            .map(|((&s, &d), &w)| (s, d, w.to_bits()))
            .collect();
        let mut b: Vec<(u32, u32, u32)> = g
            .src
            .iter()
            .zip(&g.dst)
            .zip(&g.w)
            .map(|((&s, &d), &w)| (s, d, w.to_bits()))
            .collect();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn fanout_caps_expansion() {
        let g = skewed(512, 8192, 3);
        let s = Sampler::new(g);
        let ego = s.sample(&[0, 1], &[4, 2], 7);
        // Hop 0 emits <= 2 * 4 edges; hop 1 <= (new vertices) * 2.
        assert!(ego.m() <= 8 + (ego.n() - 2) * 2, "{} edges", ego.m());
        // Every edge references sampled-local vertices only.
        assert!(ego.graph.src.iter().all(|&v| (v as usize) < ego.n()));
        assert!(ego.graph.dst.iter().all(|&v| (v as usize) < ego.n()));
    }

    #[test]
    fn same_seed_same_egonet_different_seed_differs() {
        let g = skewed(512, 8192, 3);
        let s = Sampler::new(g);
        let a = s.sample(&[0, 5, 9], &[3, 2], 11);
        let b = s.sample(&[0, 5, 9], &[3, 2], 11);
        assert_eq!(a.origin, b.origin);
        assert_eq!(a.graph.src, b.graph.src);
        assert_eq!(a.graph.dst, b.graph.dst);
        assert_eq!(a.graph.w, b.graph.w);
        // Some seed in a small set must draw a different neighborhood
        // (vertex 0 of a skewed R-MAT has degree far above the cap).
        let differs = (12..18).any(|seed| {
            let c = s.sample(&[0, 5, 9], &[3, 2], seed);
            c.origin != a.origin || c.graph.src != a.graph.src
        });
        assert!(differs, "capped sampling ignored the seed");
    }

    #[test]
    fn duplicate_targets_are_deduplicated() {
        let s = Sampler::new(CooGraph::ring(8, 4, 2));
        let ego = s.sample(&[3, 3, 5, 3], &[FULL_NEIGHBORHOOD], 1);
        assert_eq!(ego.n_targets, 2);
        assert_eq!(&ego.origin[..2], &[3, 5]);
    }

    #[test]
    fn padded_features_zero_fill_and_graph_keeps_edges() {
        let s = Sampler::new(CooGraph::ring(8, 2, 2));
        let ego = s.sample(&[3], &[FULL_NEIGHBORHOOD], 1);
        let x: Vec<f32> = (0..16).map(|i| i as f32).collect(); // 8 x 2
        let xf = ego.padded_features(&x, 2, 4);
        // Local 0 = vertex 3, local 1 = vertex 2; rows 2..4 are padding.
        assert_eq!(&xf[..4], &[6.0, 7.0, 4.0, 5.0]);
        assert!(xf[4..].iter().all(|&v| v == 0.0));
        let pg = ego.padded_graph(16);
        assert_eq!(pg.meta.n_vertices, 16);
        assert_eq!(pg.m(), ego.m());
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_target_panics() {
        let s = Sampler::new(CooGraph::ring(8, 4, 2));
        let _ = s.sample(&[8], &[1], 1);
    }
}
