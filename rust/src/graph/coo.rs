//! COO graph representation (paper Sec. 5.1): each edge is the 3-tuple
//! (src, dst, weight). Feature matrix H is stored row-major per vertex.

use crate::util::Rng;

/// Metadata of an input graph instance (what the paper calls "graph meta
/// data": the compiler only needs sizes, the functional path needs edges).
#[derive(Clone, Debug, PartialEq)]
pub struct GraphMeta {
    pub name: String,
    pub n_vertices: u64,
    pub n_edges: u64,
    /// Input feature length (f of layer 0).
    pub feat_len: u64,
    /// Output classes of the task.
    pub n_classes: u64,
}

impl GraphMeta {
    pub fn new(name: &str, n_vertices: u64, n_edges: u64, feat_len: u64, n_classes: u64) -> Self {
        GraphMeta {
            name: name.to_string(),
            n_vertices,
            n_edges,
            feat_len,
            n_classes,
        }
    }

    /// Bytes of the input: features (f32) + edges (COO 3 x u32), the
    /// quantity moved over PCIe for T_comm and reported in Table 8 row 9.
    pub fn input_bytes(&self) -> u64 {
        self.n_vertices * self.feat_len * 4 + self.n_edges * 12
    }
}

/// A materialized COO graph. `dst` is the aggregating vertex: edge e =
/// (src, dst, w) contributes w * h_src to vertex dst (SpDMM row = dst).
#[derive(Clone, Debug)]
pub struct CooGraph {
    pub meta: GraphMeta,
    pub src: Vec<u32>,
    pub dst: Vec<u32>,
    pub w: Vec<f32>,
}

impl CooGraph {
    pub fn new(meta: GraphMeta, src: Vec<u32>, dst: Vec<u32>, w: Vec<f32>) -> Self {
        assert_eq!(src.len(), dst.len());
        assert_eq!(src.len(), w.len());
        assert_eq!(src.len() as u64, meta.n_edges);
        CooGraph { meta, src, dst, w }
    }

    pub fn n(&self) -> usize {
        self.meta.n_vertices as usize
    }

    pub fn m(&self) -> usize {
        self.src.len()
    }

    /// In-degree per vertex (number of incoming edges at each dst).
    pub fn in_degree(&self) -> Vec<u32> {
        let mut deg = vec![0u32; self.n()];
        for &d in &self.dst {
            deg[d as usize] += 1;
        }
        deg
    }

    /// Out-degree per vertex.
    pub fn out_degree(&self) -> Vec<u32> {
        let mut deg = vec![0u32; self.n()];
        for &s in &self.src {
            deg[s as usize] += 1;
        }
        deg
    }

    /// Replace edge weights with GCN symmetric normalization including
    /// self-loops: alpha_ji = 1/sqrt(D(j) D(i)) (paper Eq. 3). Appends
    /// self-loop edges; updates n_edges.
    pub fn gcn_normalized(mut self) -> CooGraph {
        let n = self.n();
        // Degrees counting the self loop.
        let mut deg = vec![1u32; n];
        for &d in &self.dst {
            deg[d as usize] += 1;
        }
        for i in 0..self.m() {
            let (s, d) = (self.src[i] as usize, self.dst[i] as usize);
            self.w[i] = 1.0 / ((deg[s] as f32).sqrt() * (deg[d] as f32).sqrt());
        }
        for v in 0..n as u32 {
            self.src.push(v);
            self.dst.push(v);
            self.w.push(1.0 / deg[v as usize] as f32);
        }
        self.meta.n_edges = self.src.len() as u64;
        self
    }

    /// Mean-aggregation weights: w_e = 1/in_degree(dst) so a Sum
    /// aggregation computes the mean (keeps the operator linear).
    pub fn mean_normalized(mut self) -> CooGraph {
        let deg = self.in_degree();
        for i in 0..self.m() {
            let d = deg[self.dst[i] as usize].max(1);
            self.w[i] = 1.0 / d as f32;
        }
        self
    }

    /// Deterministic random features (layer-0 H), row-major n x f.
    pub fn random_features(&self, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed ^ 0xFEA7);
        let len = self.n() * self.meta.feat_len as usize;
        (0..len).map(|_| rng.normal() * 0.5).collect()
    }

    /// A ring graph: vertex i -> (i+1) % n. Deterministic test fixture.
    pub fn ring(n: u64, feat_len: u64, n_classes: u64) -> CooGraph {
        let meta = GraphMeta::new("ring", n, n, feat_len, n_classes);
        let src: Vec<u32> = (0..n as u32).collect();
        let dst: Vec<u32> = (0..n as u32).map(|i| (i + 1) % n as u32).collect();
        let w = vec![1.0; n as usize];
        CooGraph::new(meta, src, dst, w)
    }

    /// A star graph: all vertices point at vertex 0 (worst-case RAW
    /// conflicts — every edge lands on one Feature Buffer bank).
    pub fn star(n: u64, feat_len: u64, n_classes: u64) -> CooGraph {
        let meta = GraphMeta::new("star", n, n - 1, feat_len, n_classes);
        let src: Vec<u32> = (1..n as u32).collect();
        let dst = vec![0u32; (n - 1) as usize];
        let w = vec![1.0; (n - 1) as usize];
        CooGraph::new(meta, src, dst, w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_degrees() {
        let g = CooGraph::ring(8, 4, 2);
        assert_eq!(g.in_degree(), vec![1; 8]);
        assert_eq!(g.out_degree(), vec![1; 8]);
    }

    #[test]
    fn star_degrees() {
        let g = CooGraph::star(5, 4, 2);
        assert_eq!(g.in_degree(), vec![4, 0, 0, 0, 0]);
        assert_eq!(g.out_degree(), vec![0, 1, 1, 1, 1]);
    }

    #[test]
    fn gcn_normalization_adds_self_loops() {
        let g = CooGraph::ring(4, 4, 2).gcn_normalized();
        assert_eq!(g.m(), 8); // 4 edges + 4 self loops
        // ring in-degree incl. self loop = 2 for all; alpha = 1/2.
        for i in 0..4 {
            assert!((g.w[i] - 0.5).abs() < 1e-6, "w[{i}]={}", g.w[i]);
        }
    }

    #[test]
    fn mean_normalization_sums_to_one() {
        let g = CooGraph::star(6, 4, 2).mean_normalized();
        let total: f32 = g.w.iter().sum();
        assert!((total - 1.0).abs() < 1e-6, "total {total}");
    }

    #[test]
    fn input_bytes_formula() {
        let meta = GraphMeta::new("x", 100, 1000, 32, 4);
        assert_eq!(meta.input_bytes(), 100 * 32 * 4 + 1000 * 12);
    }

    #[test]
    fn random_features_deterministic() {
        let g = CooGraph::ring(8, 4, 2);
        assert_eq!(g.random_features(1), g.random_features(1));
        assert_ne!(g.random_features(1), g.random_features(2));
    }
}
