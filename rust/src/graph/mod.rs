//! Graph substrate: COO storage (paper Sec. 5.1), synthetic generators,
//! the Table-4 dataset registry, the Fiber-Shard partitioner (Sec. 6.5)
//! shared by the compiler, the simulator and the functional executor,
//! and the k-hop ego-network samplers behind the mini-batch serving
//! path.

pub mod coo;
pub mod datasets;
pub mod partition;
pub mod rmat;
pub mod sample;

pub use coo::{CooGraph, GraphMeta};
pub use datasets::{dataset, Dataset, ALL_DATASETS};
pub use partition::{CsrSubshard, PartitionConfig, PartitionedGraph, TileCounts};
pub use rmat::{rmat_edges, rmat_tile_counts, RmatParams};
pub use sample::{full_fanout, sample_view, EgoNet, NeighborView, Sampler, FULL_NEIGHBORHOOD};
