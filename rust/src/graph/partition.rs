//! Fiber-Shard data partitioning (paper Sec. 6.5, Fig. 8).
//!
//! * The adjacency matrix A (|V| x |V|, row = destination) is split into
//!   **shards** of N1 rows; each shard splits into **subshards** of N1
//!   columns. Subshard edges are stored contiguously (DDR mapping).
//! * The feature matrix H (|V| x f) is split into **fibers** of N2
//!   columns; each fiber splits into **subfibers** of N1 rows.
//!
//! The same (N1, N2) applies to every layer, so a layer's outputs are
//! already partitioned for the next layer — no re-partitioning between
//! layers (the property the partition-centric execution scheme needs).

use super::coo::CooGraph;

/// Partitioning configuration chosen by the compiler from the HwConfig
/// buffer dimensions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PartitionConfig {
    /// Subshard/subfiber height (rows) and subshard width (cols).
    pub n1: u64,
    /// Fiber width (feature columns).
    pub n2: u64,
}

impl PartitionConfig {
    pub fn shards(&self, n_vertices: u64) -> u64 {
        n_vertices.div_ceil(self.n1)
    }

    pub fn fibers(&self, feat_len: u64) -> u64 {
        feat_len.div_ceil(self.n2)
    }
}

/// Per-subshard edge counts — all the compiler and the cycle model need
/// for large graphs. counts[i * shards + j] = |edges(dst in shard i,
/// src in subshard j)|.
#[derive(Clone, Debug, PartialEq)]
pub struct TileCounts {
    pub n1: u64,
    pub shards: usize,
    pub counts: Vec<u64>,
}

impl TileCounts {
    pub fn total_edges(&self) -> u64 {
        self.counts.iter().sum()
    }

    #[inline]
    pub fn get(&self, shard: usize, subshard: usize) -> u64 {
        self.counts[shard * self.shards + subshard]
    }

    /// Edge count of a whole shard (row of subshards).
    pub fn shard_edges(&self, shard: usize) -> u64 {
        self.counts[shard * self.shards..(shard + 1) * self.shards]
            .iter()
            .sum()
    }

    /// Build from a materialized COO graph.
    pub fn from_coo(g: &CooGraph, n1: u64) -> TileCounts {
        TileCounts::from_edges(&g.src, &g.dst, g.meta.n_vertices, n1)
    }

    /// Histogram raw edge arrays into subshard counts — the O(|E|)
    /// partitioning pass whose wall-clock is the dominant T_LoC term.
    /// N1 is a buffer dimension (power of two), so the tile index is a
    /// shift, not a division (~5x on the 100M+-edge graphs).
    pub fn from_edges(src: &[u32], dst: &[u32], n_vertices: u64, n1: u64) -> TileCounts {
        let shards = n_vertices.div_ceil(n1) as usize;
        let mut counts = vec![0u64; shards * shards];
        if n1.is_power_of_two() {
            let sh = n1.trailing_zeros();
            for (&s, &d) in src.iter().zip(dst) {
                counts[((d >> sh) as usize) * shards + (s >> sh) as usize] += 1;
            }
        } else {
            for (&s, &d) in src.iter().zip(dst) {
                counts[(d as u64 / n1) as usize * shards + (s as u64 / n1) as usize] += 1;
            }
        }
        TileCounts { n1, shards, counts }
    }
}

/// One subshard's edges in destination-row CSR form, built once at
/// partition time so aggregation kernels run as independent
/// per-destination-row reductions instead of random scatter over the
/// COO stream (and SDDMM reuses the same row grouping for
/// destination-side feature-row reuse).
///
/// All indices are tile-local: row `r` is destination vertex
/// `shard * N1 + r`, column `cols[slot]` is source vertex
/// `k * N1 + cols[slot]`. Edge *weights* are not copied: `perm[slot]`
/// is the within-subshard edge index (into the subshard's range of
/// `src`/`dst`/`w`), so kernels gather the *live* weight array — which
/// an upstream SDDMM layer may have rewritten — and SDDMM scatters its
/// results back through the same map.
#[derive(Clone, Debug, PartialEq)]
pub struct CsrSubshard {
    /// Destination rows of this shard (= shard height, <= N1).
    pub rows: u32,
    /// len rows + 1; CSR slot range of local row r is
    /// `row_offsets[r]..row_offsets[r+1]`.
    pub row_offsets: Vec<u32>,
    /// Local source column per CSR slot.
    pub cols: Vec<u32>,
    /// Within-subshard edge index per CSR slot.
    pub perm: Vec<u32>,
}

impl CsrSubshard {
    /// Build from tile-local COO arrays (counting sort by row; stable,
    /// so edges within a row keep their subshard order).
    pub fn from_local_coo(local_dst: impl Iterator<Item = u32> + Clone, local_src: impl Iterator<Item = u32>, rows: usize) -> CsrSubshard {
        let mut row_offsets = vec![0u32; rows + 1];
        for d in local_dst.clone() {
            row_offsets[d as usize + 1] += 1;
        }
        for r in 0..rows {
            row_offsets[r + 1] += row_offsets[r];
        }
        let nnz = row_offsets[rows] as usize;
        let mut cols = vec![0u32; nnz];
        let mut perm = vec![0u32; nnz];
        let mut cursor: Vec<u32> = row_offsets[..rows].to_vec();
        for (e, (d, s)) in local_dst.zip(local_src).enumerate() {
            let at = cursor[d as usize] as usize;
            cols[at] = s;
            perm[at] = e as u32;
            cursor[d as usize] += 1;
        }
        CsrSubshard { rows: rows as u32, row_offsets, cols, perm }
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.cols.len()
    }

    /// CSR slot range of local destination row `r`.
    #[inline]
    pub fn row(&self, r: usize) -> std::ops::Range<usize> {
        self.row_offsets[r] as usize..self.row_offsets[r + 1] as usize
    }

    /// Internal consistency: offsets monotone and covering, columns and
    /// permutation in range, permutation a bijection.
    pub fn validate(&self, n_cols: usize) -> Result<(), String> {
        let rows = self.rows as usize;
        if self.row_offsets.len() != rows + 1 || self.row_offsets[0] != 0 {
            return Err("bad row_offsets shape".into());
        }
        if self.row_offsets[rows] as usize != self.nnz() {
            return Err("row_offsets do not cover nnz".into());
        }
        for r in 0..rows {
            if self.row_offsets[r] > self.row_offsets[r + 1] {
                return Err(format!("row_offsets not monotone at {r}"));
            }
        }
        let mut seen = vec![false; self.nnz()];
        for slot in 0..self.nnz() {
            if self.cols[slot] as usize >= n_cols {
                return Err(format!("column {} out of range", self.cols[slot]));
            }
            let p = self.perm[slot] as usize;
            if p >= self.nnz() || seen[p] {
                return Err(format!("perm slot {slot} invalid"));
            }
            seen[p] = true;
        }
        Ok(())
    }
}

/// A materialized, partition-ordered graph: edges grouped by (shard,
/// subshard) with CSR-like offsets, exactly the DDR layout of Fig. 8 —
/// plus a per-subshard destination-row CSR index ([`CsrSubshard`]) for
/// the optimized aggregation kernels.
///
/// `PartialEq` compares every array bit-exactly — it is what the
/// streaming tests use to pin incremental dirty-subshard rebuilds
/// against a from-scratch [`PartitionedGraph::build`].
#[derive(Clone, Debug, PartialEq)]
pub struct PartitionedGraph {
    pub cfg: PartitionConfig,
    pub n_vertices: u64,
    pub shards: usize,
    /// offsets[i * shards + j .. +1] index into src/dst/w for subshard
    /// (i, j); length shards*shards + 1.
    pub offsets: Vec<usize>,
    pub src: Vec<u32>,
    pub dst: Vec<u32>,
    pub w: Vec<f32>,
    /// Destination-row CSR per subshard, tile order (i * shards + j).
    pub csr: Vec<CsrSubshard>,
}

impl PartitionedGraph {
    /// Counting-sort edges into subshard order. O(|E| + shards^2).
    pub fn build(g: &CooGraph, cfg: PartitionConfig) -> PartitionedGraph {
        let n1 = cfg.n1;
        let shards = g.meta.n_vertices.div_ceil(n1) as usize;
        let tiles = shards * shards;
        let mut counts = vec![0usize; tiles];
        let tile_of = |s: u32, d: u32| -> usize {
            (d as u64 / n1) as usize * shards + (s as u64 / n1) as usize
        };
        for i in 0..g.m() {
            counts[tile_of(g.src[i], g.dst[i])] += 1;
        }
        let mut offsets = vec![0usize; tiles + 1];
        for t in 0..tiles {
            offsets[t + 1] = offsets[t] + counts[t];
        }
        let m = g.m();
        let mut src = vec![0u32; m];
        let mut dst = vec![0u32; m];
        let mut w = vec![0f32; m];
        let mut cursor = offsets.clone();
        for i in 0..m {
            let t = tile_of(g.src[i], g.dst[i]);
            let at = cursor[t];
            src[at] = g.src[i];
            dst[at] = g.dst[i];
            w[at] = g.w[i];
            cursor[t] += 1;
        }
        let csr = Self::build_csr(&offsets, &src, &dst, g.meta.n_vertices, n1, shards);
        PartitionedGraph {
            cfg,
            n_vertices: g.meta.n_vertices,
            shards,
            offsets,
            src,
            dst,
            w,
            csr,
        }
    }

    /// Destination-row CSR for every subshard (the second, row-granular
    /// half of the counting sort). O(|E| + shards * |V|).
    fn build_csr(
        offsets: &[usize],
        src: &[u32],
        dst: &[u32],
        n_vertices: u64,
        n1: u64,
        shards: usize,
    ) -> Vec<CsrSubshard> {
        let mut csr = Vec::with_capacity(shards * shards);
        for i in 0..shards {
            let row_base = (i as u64 * n1) as u32;
            let rows = (n_vertices - i as u64 * n1).min(n1) as usize;
            for j in 0..shards {
                let col_base = (j as u64 * n1) as u32;
                let t = i * shards + j;
                let range = offsets[t]..offsets[t + 1];
                csr.push(CsrSubshard::from_local_coo(
                    dst[range.clone()].iter().map(move |&d| d - row_base),
                    src[range].iter().map(move |&s| s - col_base),
                    rows,
                ));
            }
        }
        csr
    }

    /// The destination-row CSR of subshard (i, j).
    #[inline]
    pub fn csr(&self, i: usize, j: usize) -> &CsrSubshard {
        &self.csr[i * self.shards + j]
    }

    /// Edge index range of subshard (i, j).
    #[inline]
    pub fn subshard(&self, i: usize, j: usize) -> std::ops::Range<usize> {
        let t = i * self.shards + j;
        self.offsets[t]..self.offsets[t + 1]
    }

    pub fn tile_counts(&self) -> TileCounts {
        let counts = (0..self.shards * self.shards)
            .map(|t| (self.offsets[t + 1] - self.offsets[t]) as u64)
            .collect();
        TileCounts { n1: self.cfg.n1, shards: self.shards, counts }
    }

    /// Check the Fiber-Shard invariants: every edge lands in exactly one
    /// subshard and its indices fall inside that subshard's row/col range.
    pub fn validate(&self) -> Result<(), String> {
        let n1 = self.cfg.n1;
        if *self.offsets.last().unwrap() != self.src.len() {
            return Err("offsets do not cover all edges".into());
        }
        for i in 0..self.shards {
            for j in 0..self.shards {
                for e in self.subshard(i, j) {
                    let (s, d) = (self.src[e] as u64, self.dst[e] as u64);
                    if d / n1 != i as u64 || s / n1 != j as u64 {
                        return Err(format!(
                            "edge {e} ({s}->{d}) misplaced in subshard ({i},{j})"
                        ));
                    }
                }
            }
        }
        // CSR cross-check: every slot maps back (through perm) to an
        // edge of the subshard with the matching destination row and
        // source column.
        if self.csr.len() != self.shards * self.shards {
            return Err("csr index missing subshards".into());
        }
        for i in 0..self.shards {
            for j in 0..self.shards {
                let csr = self.csr(i, j);
                let range = self.subshard(i, j);
                let cols = (self.n_vertices - j as u64 * n1).min(n1) as usize;
                csr.validate(cols).map_err(|e| format!("csr ({i},{j}): {e}"))?;
                if csr.nnz() != range.len() {
                    return Err(format!("csr ({i},{j}) nnz != edge count"));
                }
                for r in 0..csr.rows as usize {
                    for slot in csr.row(r) {
                        let e = range.start + csr.perm[slot] as usize;
                        let (s, d) = (self.src[e] as u64, self.dst[e] as u64);
                        if d != i as u64 * n1 + r as u64
                            || s != j as u64 * n1 + csr.cols[slot] as u64
                        {
                            return Err(format!(
                                "csr ({i},{j}) slot {slot} maps to wrong edge"
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::coo::GraphMeta;
    use crate::graph::rmat::{rmat_edges, RmatParams};
    use crate::util::forall;

    #[test]
    fn ring_partition() {
        let g = CooGraph::ring(8, 4, 2);
        let pg = PartitionedGraph::build(&g, PartitionConfig { n1: 4, n2: 4 });
        pg.validate().unwrap();
        assert_eq!(pg.shards, 2);
        // Edge 3->0 and 7->4... wrap edges: (3,0) wraps? dst=(i+1)%8.
        // Edges: (0,1)(1,2)(2,3) in (0,0); (3,4) in (1,0); (4,5)(5,6)(6,7)
        // in (1,1); (7,0) in (0,1).
        assert_eq!(pg.subshard(0, 0).len(), 3);
        assert_eq!(pg.subshard(1, 0).len(), 1);
        assert_eq!(pg.subshard(1, 1).len(), 3);
        assert_eq!(pg.subshard(0, 1).len(), 1);
    }

    #[test]
    fn partition_preserves_multiset() {
        let meta = GraphMeta::new("t", 200, 2000, 8, 2);
        let g = rmat_edges(meta, RmatParams::default(), 3);
        let pg = PartitionedGraph::build(&g, PartitionConfig { n1: 64, n2: 8 });
        pg.validate().unwrap();
        let mut orig: Vec<(u32, u32)> =
            g.src.iter().zip(&g.dst).map(|(&s, &d)| (s, d)).collect();
        let mut part: Vec<(u32, u32)> =
            pg.src.iter().zip(&pg.dst).map(|(&s, &d)| (s, d)).collect();
        orig.sort_unstable();
        part.sort_unstable();
        assert_eq!(orig, part);
    }

    #[test]
    fn tile_counts_agree_with_from_coo() {
        let meta = GraphMeta::new("t", 300, 3000, 8, 2);
        let g = rmat_edges(meta, RmatParams::default(), 5);
        let pg = PartitionedGraph::build(&g, PartitionConfig { n1: 128, n2: 8 });
        assert_eq!(pg.tile_counts(), TileCounts::from_coo(&g, 128));
    }

    #[test]
    fn prop_partition_covers_every_edge_once() {
        forall("fiber-shard-coverage", 25, |rng| {
            let n = rng.range(2, 500);
            let m = rng.range(1, 4000);
            let n1 = 1 << rng.range(2, 8);
            let meta = GraphMeta::new("p", n, m, 8, 2);
            let g = rmat_edges(meta, RmatParams::default(), rng.next_u64());
            let pg = PartitionedGraph::build(&g, PartitionConfig { n1, n2: 8 });
            pg.validate().map_err(|e| e)?;
            let covered: usize =
                (0..pg.shards * pg.shards).map(|t| pg.offsets[t + 1] - pg.offsets[t]).sum();
            crate::prop_assert!(covered == g.m(), "covered {covered} != {}", g.m());
            Ok(())
        });
    }

    #[test]
    fn csr_roundtrips_to_coo_per_subshard() {
        // The CSR index must reproduce the exact (src, dst, w) multiset
        // of every subshard, with weights gathered through `perm`.
        forall("csr-coo-roundtrip", 20, |rng| {
            let n = rng.range(2, 400);
            let m = rng.range(1, 3000);
            let n1 = 1 << rng.range(3, 8);
            let meta = GraphMeta::new("p", n, m, 8, 2);
            let g = rmat_edges(meta, RmatParams::default(), rng.next_u64());
            let pg = PartitionedGraph::build(&g, PartitionConfig { n1, n2: 8 });
            pg.validate().map_err(|e| e)?;
            for i in 0..pg.shards {
                for j in 0..pg.shards {
                    let range = pg.subshard(i, j);
                    let csr = pg.csr(i, j);
                    crate::prop_assert!(
                        csr.nnz() == range.len(),
                        "({i},{j}): nnz {} != {}",
                        csr.nnz(),
                        range.len()
                    );
                    let mut from_csr: Vec<(u32, u32, u32)> = Vec::new();
                    for r in 0..csr.rows as usize {
                        for slot in csr.row(r) {
                            let e = range.start + csr.perm[slot] as usize;
                            from_csr.push((
                                j as u32 * n1 as u32 + csr.cols[slot],
                                i as u32 * n1 as u32 + r as u32,
                                pg.w[e].to_bits(),
                            ));
                        }
                    }
                    let mut from_coo: Vec<(u32, u32, u32)> = range
                        .map(|e| (pg.src[e], pg.dst[e], pg.w[e].to_bits()))
                        .collect();
                    from_csr.sort_unstable();
                    from_coo.sort_unstable();
                    crate::prop_assert!(
                        from_csr == from_coo,
                        "({i},{j}): csr multiset mismatch"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn csr_rows_are_sorted_and_touch_free() {
        // Row emptiness is the touched-row predicate the kernels rely
        // on: a row with offsets[r] == offsets[r+1] has no edges.
        let g = CooGraph::ring(8, 4, 2);
        let pg = PartitionedGraph::build(&g, PartitionConfig { n1: 4, n2: 4 });
        let csr = pg.csr(0, 0); // edges (0,1)(1,2)(2,3): rows 1..=3 touched
        assert_eq!(csr.rows, 4);
        assert_eq!(csr.row(0).len(), 0);
        assert_eq!(csr.row(1).len(), 1);
        assert_eq!(csr.cols[csr.row(1).start], 0);
        assert_eq!(csr.row(2).len(), 1);
        assert_eq!(csr.row(3).len(), 1);
    }

    #[test]
    fn partition_config_helpers() {
        let cfg = PartitionConfig { n1: 16384, n2: 16 };
        assert_eq!(cfg.shards(232_965), 15); // Reddit
        assert_eq!(cfg.fibers(602), 38);
    }
}
