//! Fiber-Shard data partitioning (paper Sec. 6.5, Fig. 8).
//!
//! * The adjacency matrix A (|V| x |V|, row = destination) is split into
//!   **shards** of N1 rows; each shard splits into **subshards** of N1
//!   columns. Subshard edges are stored contiguously (DDR mapping).
//! * The feature matrix H (|V| x f) is split into **fibers** of N2
//!   columns; each fiber splits into **subfibers** of N1 rows.
//!
//! The same (N1, N2) applies to every layer, so a layer's outputs are
//! already partitioned for the next layer — no re-partitioning between
//! layers (the property the partition-centric execution scheme needs).

use super::coo::CooGraph;

/// Partitioning configuration chosen by the compiler from the HwConfig
/// buffer dimensions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PartitionConfig {
    /// Subshard/subfiber height (rows) and subshard width (cols).
    pub n1: u64,
    /// Fiber width (feature columns).
    pub n2: u64,
}

impl PartitionConfig {
    pub fn shards(&self, n_vertices: u64) -> u64 {
        n_vertices.div_ceil(self.n1)
    }

    pub fn fibers(&self, feat_len: u64) -> u64 {
        feat_len.div_ceil(self.n2)
    }
}

/// Per-subshard edge counts — all the compiler and the cycle model need
/// for large graphs. counts[i * shards + j] = |edges(dst in shard i,
/// src in subshard j)|.
#[derive(Clone, Debug, PartialEq)]
pub struct TileCounts {
    pub n1: u64,
    pub shards: usize,
    pub counts: Vec<u64>,
}

impl TileCounts {
    pub fn total_edges(&self) -> u64 {
        self.counts.iter().sum()
    }

    #[inline]
    pub fn get(&self, shard: usize, subshard: usize) -> u64 {
        self.counts[shard * self.shards + subshard]
    }

    /// Edge count of a whole shard (row of subshards).
    pub fn shard_edges(&self, shard: usize) -> u64 {
        self.counts[shard * self.shards..(shard + 1) * self.shards]
            .iter()
            .sum()
    }

    /// Build from a materialized COO graph.
    pub fn from_coo(g: &CooGraph, n1: u64) -> TileCounts {
        TileCounts::from_edges(&g.src, &g.dst, g.meta.n_vertices, n1)
    }

    /// Histogram raw edge arrays into subshard counts — the O(|E|)
    /// partitioning pass whose wall-clock is the dominant T_LoC term.
    /// N1 is a buffer dimension (power of two), so the tile index is a
    /// shift, not a division (~5x on the 100M+-edge graphs).
    pub fn from_edges(src: &[u32], dst: &[u32], n_vertices: u64, n1: u64) -> TileCounts {
        let shards = n_vertices.div_ceil(n1) as usize;
        let mut counts = vec![0u64; shards * shards];
        if n1.is_power_of_two() {
            let sh = n1.trailing_zeros();
            for (&s, &d) in src.iter().zip(dst) {
                counts[((d >> sh) as usize) * shards + (s >> sh) as usize] += 1;
            }
        } else {
            for (&s, &d) in src.iter().zip(dst) {
                counts[(d as u64 / n1) as usize * shards + (s as u64 / n1) as usize] += 1;
            }
        }
        TileCounts { n1, shards, counts }
    }
}

/// A materialized, partition-ordered graph: edges grouped by (shard,
/// subshard) with CSR-like offsets, exactly the DDR layout of Fig. 8.
#[derive(Clone, Debug)]
pub struct PartitionedGraph {
    pub cfg: PartitionConfig,
    pub n_vertices: u64,
    pub shards: usize,
    /// offsets[i * shards + j .. +1] index into src/dst/w for subshard
    /// (i, j); length shards*shards + 1.
    pub offsets: Vec<usize>,
    pub src: Vec<u32>,
    pub dst: Vec<u32>,
    pub w: Vec<f32>,
}

impl PartitionedGraph {
    /// Counting-sort edges into subshard order. O(|E| + shards^2).
    pub fn build(g: &CooGraph, cfg: PartitionConfig) -> PartitionedGraph {
        let n1 = cfg.n1;
        let shards = g.meta.n_vertices.div_ceil(n1) as usize;
        let tiles = shards * shards;
        let mut counts = vec![0usize; tiles];
        let tile_of = |s: u32, d: u32| -> usize {
            (d as u64 / n1) as usize * shards + (s as u64 / n1) as usize
        };
        for i in 0..g.m() {
            counts[tile_of(g.src[i], g.dst[i])] += 1;
        }
        let mut offsets = vec![0usize; tiles + 1];
        for t in 0..tiles {
            offsets[t + 1] = offsets[t] + counts[t];
        }
        let m = g.m();
        let mut src = vec![0u32; m];
        let mut dst = vec![0u32; m];
        let mut w = vec![0f32; m];
        let mut cursor = offsets.clone();
        for i in 0..m {
            let t = tile_of(g.src[i], g.dst[i]);
            let at = cursor[t];
            src[at] = g.src[i];
            dst[at] = g.dst[i];
            w[at] = g.w[i];
            cursor[t] += 1;
        }
        PartitionedGraph {
            cfg,
            n_vertices: g.meta.n_vertices,
            shards,
            offsets,
            src,
            dst,
            w,
        }
    }

    /// Edge index range of subshard (i, j).
    #[inline]
    pub fn subshard(&self, i: usize, j: usize) -> std::ops::Range<usize> {
        let t = i * self.shards + j;
        self.offsets[t]..self.offsets[t + 1]
    }

    pub fn tile_counts(&self) -> TileCounts {
        let counts = (0..self.shards * self.shards)
            .map(|t| (self.offsets[t + 1] - self.offsets[t]) as u64)
            .collect();
        TileCounts { n1: self.cfg.n1, shards: self.shards, counts }
    }

    /// Check the Fiber-Shard invariants: every edge lands in exactly one
    /// subshard and its indices fall inside that subshard's row/col range.
    pub fn validate(&self) -> Result<(), String> {
        let n1 = self.cfg.n1;
        if *self.offsets.last().unwrap() != self.src.len() {
            return Err("offsets do not cover all edges".into());
        }
        for i in 0..self.shards {
            for j in 0..self.shards {
                for e in self.subshard(i, j) {
                    let (s, d) = (self.src[e] as u64, self.dst[e] as u64);
                    if d / n1 != i as u64 || s / n1 != j as u64 {
                        return Err(format!(
                            "edge {e} ({s}->{d}) misplaced in subshard ({i},{j})"
                        ));
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::coo::GraphMeta;
    use crate::graph::rmat::{rmat_edges, RmatParams};
    use crate::util::forall;

    #[test]
    fn ring_partition() {
        let g = CooGraph::ring(8, 4, 2);
        let pg = PartitionedGraph::build(&g, PartitionConfig { n1: 4, n2: 4 });
        pg.validate().unwrap();
        assert_eq!(pg.shards, 2);
        // Edge 3->0 and 7->4... wrap edges: (3,0) wraps? dst=(i+1)%8.
        // Edges: (0,1)(1,2)(2,3) in (0,0); (3,4) in (1,0); (4,5)(5,6)(6,7)
        // in (1,1); (7,0) in (0,1).
        assert_eq!(pg.subshard(0, 0).len(), 3);
        assert_eq!(pg.subshard(1, 0).len(), 1);
        assert_eq!(pg.subshard(1, 1).len(), 3);
        assert_eq!(pg.subshard(0, 1).len(), 1);
    }

    #[test]
    fn partition_preserves_multiset() {
        let meta = GraphMeta::new("t", 200, 2000, 8, 2);
        let g = rmat_edges(meta, RmatParams::default(), 3);
        let pg = PartitionedGraph::build(&g, PartitionConfig { n1: 64, n2: 8 });
        pg.validate().unwrap();
        let mut orig: Vec<(u32, u32)> =
            g.src.iter().zip(&g.dst).map(|(&s, &d)| (s, d)).collect();
        let mut part: Vec<(u32, u32)> =
            pg.src.iter().zip(&pg.dst).map(|(&s, &d)| (s, d)).collect();
        orig.sort_unstable();
        part.sort_unstable();
        assert_eq!(orig, part);
    }

    #[test]
    fn tile_counts_agree_with_from_coo() {
        let meta = GraphMeta::new("t", 300, 3000, 8, 2);
        let g = rmat_edges(meta, RmatParams::default(), 5);
        let pg = PartitionedGraph::build(&g, PartitionConfig { n1: 128, n2: 8 });
        assert_eq!(pg.tile_counts(), TileCounts::from_coo(&g, 128));
    }

    #[test]
    fn prop_partition_covers_every_edge_once() {
        forall("fiber-shard-coverage", 25, |rng| {
            let n = rng.range(2, 500);
            let m = rng.range(1, 4000);
            let n1 = 1 << rng.range(2, 8);
            let meta = GraphMeta::new("p", n, m, 8, 2);
            let g = rmat_edges(meta, RmatParams::default(), rng.next_u64());
            let pg = PartitionedGraph::build(&g, PartitionConfig { n1, n2: 8 });
            pg.validate().map_err(|e| e)?;
            let covered: usize =
                (0..pg.shards * pg.shards).map(|t| pg.offsets[t + 1] - pg.offsets[t]).sum();
            crate::prop_assert!(covered == g.m(), "covered {covered} != {}", g.m());
            Ok(())
        });
    }

    #[test]
    fn partition_config_helpers() {
        let cfg = PartitionConfig { n1: 16384, n2: 16 };
        assert_eq!(cfg.shards(232_965), 15); // Reddit
        assert_eq!(cfg.fibers(602), 38);
    }
}
