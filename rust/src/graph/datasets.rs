//! Dataset registry: the seven benchmark graphs of the paper (Table 4),
//! plus small fixtures. Real datasets are substituted with deterministic
//! R-MAT synthetics at the exact |V| / |E| / f / classes (DESIGN.md
//! "Substitutions"): latency depends on sizes and skew, not on the actual
//! feature values.

use super::coo::{CooGraph, GraphMeta};
use super::partition::TileCounts;
use super::rmat::{rmat_edges, rmat_tile_counts, RmatParams};

/// One Table-4 dataset row. `PartialEq` so a dataset decoded from a
/// recorded trace is testable against the registry row it came from.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Dataset {
    pub key: &'static str,
    pub name: &'static str,
    pub n_vertices: u64,
    pub n_edges: u64,
    pub feat_len: u64,
    pub n_classes: u64,
    /// Community-locality of the synthetic stand-in (fraction of edges
    /// kept within an N1-sized block; see `rmat::RmatParams::locality`).
    /// Citation/co-purchase graphs are strongly clustered; Reddit's
    /// dense social graph is not.
    pub locality: f64,
}

impl Dataset {
    pub fn meta(&self) -> GraphMeta {
        GraphMeta::new(
            self.key,
            self.n_vertices,
            self.n_edges,
            self.feat_len,
            self.n_classes,
        )
    }

    /// Generator parameters for the synthetic stand-in.
    pub fn params(&self) -> RmatParams {
        RmatParams::with_locality(self.locality)
    }

    /// Deterministic seed per dataset (stable across runs/binaries).
    fn seed(&self) -> u64 {
        self.key
            .bytes()
            .fold(0xDA7A5EEDu64, |h, b| h.wrapping_mul(131).wrapping_add(b as u64))
    }

    /// Materialize the synthetic stand-in (small datasets only; guards
    /// against accidentally materializing Reddit/Amazon-scale graphs).
    pub fn materialize(&self) -> CooGraph {
        assert!(
            self.n_edges <= 10_000_000,
            "{}: {} edges — use tile_counts() for large graphs",
            self.key,
            self.n_edges
        );
        rmat_edges(self.meta(), self.params(), self.seed())
    }

    /// Stream per-subshard edge counts (works at any scale).
    pub fn tile_counts(&self, n1: u64) -> TileCounts {
        rmat_tile_counts(&self.meta(), self.params(), self.seed(), n1)
    }

    /// Bulk-generate the raw (src, dst) edge arrays at any scale (the
    /// synthetic stand-in for "loading the dataset"; ~8 B/edge). The
    /// harness generates once per dataset and times only the O(|E|)
    /// partitioning pass over these arrays, matching what the paper's
    /// T_LoC measures.
    pub fn edge_arrays(&self) -> (Vec<u32>, Vec<u32>) {
        let mut rng = crate::util::Rng::new(self.seed());
        self.params().sample_edges(&mut rng, self.n_vertices, self.n_edges as usize)
    }

    /// A proportionally scaled-down copy (same avg degree & feature len)
    /// for fast CI runs: |V| and |E| divided by `factor` (min 64 verts).
    pub fn scaled(&self, factor: u64) -> Dataset {
        Dataset {
            n_vertices: (self.n_vertices / factor).max(64),
            n_edges: (self.n_edges / factor).max(128),
            ..*self
        }
    }
}

/// Table 4 of the paper.
pub const CITESEER: Dataset = Dataset {
    key: "CI",
    name: "Citeseer",
    n_vertices: 3327,
    n_edges: 4732,
    feat_len: 3703,
    n_classes: 6,
    locality: 0.5,
};

pub const CORA: Dataset = Dataset {
    key: "CO",
    name: "Cora",
    n_vertices: 2708,
    n_edges: 5429,
    feat_len: 1433,
    n_classes: 7,
    locality: 0.5,
};

pub const PUBMED: Dataset = Dataset {
    key: "PU",
    name: "Pubmed",
    n_vertices: 19717,
    n_edges: 44338,
    feat_len: 500,
    n_classes: 3,
    locality: 0.5,
};

pub const FLICKR: Dataset = Dataset {
    key: "FL",
    name: "Flickr",
    n_vertices: 89_250,
    n_edges: 899_756,
    feat_len: 500,
    n_classes: 7,
    locality: 0.3,
};

pub const REDDIT: Dataset = Dataset {
    key: "RE",
    name: "Reddit",
    n_vertices: 232_965,
    n_edges: 116_069_919,
    feat_len: 602,
    n_classes: 41,
    locality: 0.2,
};

pub const YELP: Dataset = Dataset {
    key: "YE",
    name: "Yelp",
    n_vertices: 716_847,
    n_edges: 6_977_410,
    feat_len: 300,
    n_classes: 100,
    locality: 0.7,
};

pub const AMAZON_PRODUCTS: Dataset = Dataset {
    key: "AP",
    name: "Amazon-Products",
    n_vertices: 1_569_960,
    n_edges: 264_339_468,
    feat_len: 200,
    n_classes: 107,
    locality: 0.8,
};

pub const ALL_DATASETS: [Dataset; 7] = [
    CITESEER, CORA, PUBMED, FLICKR, REDDIT, YELP, AMAZON_PRODUCTS,
];

/// Look up a dataset by its two-letter key (CI, CO, PU, FL, RE, YE, AP).
pub fn dataset(key: &str) -> Option<Dataset> {
    ALL_DATASETS.iter().find(|d| d.key.eq_ignore_ascii_case(key)).copied()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_matches_table4() {
        assert_eq!(ALL_DATASETS.len(), 7);
        assert_eq!(dataset("RE").unwrap().n_edges, 116_069_919);
        assert_eq!(dataset("co").unwrap().feat_len, 1433);
        assert!(dataset("XX").is_none());
    }

    #[test]
    fn small_datasets_materialize() {
        let g = CORA.materialize();
        assert_eq!(g.meta.n_vertices, 2708);
        assert_eq!(g.m(), 5429);
    }

    #[test]
    #[should_panic(expected = "use tile_counts")]
    fn large_dataset_materialize_guard() {
        let _ = REDDIT.materialize();
    }

    #[test]
    fn scaled_preserves_shape() {
        let s = REDDIT.scaled(1000);
        assert_eq!(s.n_vertices, 232);
        assert_eq!(s.feat_len, 602);
        let g = rmat_edges(s.meta(), RmatParams::default(), 1);
        assert_eq!(g.m() as u64, s.n_edges);
    }

    #[test]
    fn tile_counts_scale_free() {
        // Flickr at N1 = 16384: 6 shards, total edges preserved.
        let tc = FLICKR.tile_counts(16384);
        assert_eq!(tc.shards, 6);
        assert_eq!(tc.total_edges(), FLICKR.n_edges);
    }

    #[test]
    fn dataset_seeds_differ() {
        assert_ne!(CORA.seed(), CITESEER.seed());
    }

    #[test]
    fn input_sizes_order_of_table8_row9() {
        // Table 8 row 9 reports input sizes in MB: CI 47, CO 12.6, PU 38,
        // FL 181, RE 1863, YE 900, AP 4223. Our input_bytes() should land
        // in the same ballpark (the paper stores extra indices/padding).
        let mb = |d: &Dataset| d.meta().input_bytes() as f64 / 1e6;
        assert!((40.0..60.0).contains(&mb(&CITESEER)), "{}", mb(&CITESEER));
        assert!((10.0..20.0).contains(&mb(&CORA)));
        assert!((1300.0..2000.0).contains(&mb(&REDDIT)));
        assert!((3000.0..4500.0).contains(&mb(&AMAZON_PRODUCTS)));
    }
}
