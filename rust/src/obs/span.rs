//! The per-request phase model: reconstructing a response's exact
//! phase timeline, and recording span trees for admitted requests.
//!
//! The central fact this module leans on is that every serving path
//! bills its latency through the same public accounting fields
//! (`t_sample`, `t_compile`, `t_queue`, `t_exec`, `t_backoff`,
//! `t_qos`, `t_update`), each anchored at a position the path
//! documents. [`segments`] inverts that accounting: given only
//! `(arrival, &Response)` it rebuilds the phase windows on the virtual
//! clock, and their union covers the full `latency` — which is both
//! the span tree the tracer exports and the invariant the
//! coordinator's debug assertion (and the property test in
//! `rust/tests/obs_spans.rs`) checks on every admission.

use crate::compiler::CompileReport;
use crate::serve::{Request, Response};
use std::sync::Arc;

/// Absolute tolerance (seconds of virtual time) for the per-request
/// accounting invariant: the union of a response's phase segments must
/// match its `latency` to within one nanosecond. Float error across
/// the handful of additions each path performs is orders of magnitude
/// below this; real accounting drift is orders of magnitude above.
pub const ACCOUNTING_TOL_S: f64 = 1e-9;

/// A named serving phase of one request's lifetime.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Host-side ego-net sampling (mini-batch requests only).
    Sample,
    /// Compile stall: the program was not resident and the request
    /// waited for the four-pass compile (modeled
    /// [`CompileReport::total`]).
    Compile,
    /// Waiting for the device between program-ready and visit start.
    Queue,
    /// SFQ fair-queue pacing delay charged under a tenant config.
    QosPace,
    /// Exponential-backoff pauses across crashed-attempt retries.
    Backoff,
    /// Crash-discovery wait on the fault path: time between attempts
    /// that is neither a backoff pause nor a compile stall (a doomed
    /// attempt ran until its device's crash instant).
    RetryWait,
    /// Device execution of the visit serving this request.
    Exec,
    /// Riding another request's execution (coalesced or micro-batched:
    /// the span covers the host job's remaining timeline).
    Ride,
    /// Host-side apply of a streaming graph-update batch.
    Update,
}

impl Phase {
    /// Stable display name (the span name in the Chrome export).
    pub fn name(self) -> &'static str {
        match self {
            Phase::Sample => "sample",
            Phase::Compile => "compile",
            Phase::Queue => "queue",
            Phase::QosPace => "qos-pace",
            Phase::Backoff => "backoff",
            Phase::RetryWait => "retry-wait",
            Phase::Exec => "exec",
            Phase::Ride => "ride",
            Phase::Update => "update",
        }
    }
}

/// One phase window on the virtual clock, `[from, until)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Segment {
    /// Which phase this window spent its time in.
    pub phase: Phase,
    /// Window start (absolute virtual-clock seconds).
    pub from: f64,
    /// Window end (absolute virtual-clock seconds).
    pub until: f64,
}

impl Segment {
    fn new(phase: Phase, from: f64, until: f64) -> Segment {
        Segment { phase, from, until }
    }
}

/// Reconstruct the phase timeline of one response from its public
/// accounting fields. Zero-length phases are omitted. The union of
/// the returned windows covers `[arrival, arrival + latency]` to
/// within [`ACCOUNTING_TOL_S`] for every serving path; the only
/// intentional overlap is QoS pacing (anchored at arrival) against
/// sample + compile (pacing hides host work, by design).
pub fn segments(arrival: f64, r: &Response) -> Vec<Segment> {
    let done = arrival + r.latency;
    let mut out = Vec::new();
    let mut push = |phase: Phase, from: f64, until: f64| {
        if until > from {
            out.push(Segment::new(phase, from, until));
        }
    };
    if r.update {
        // Updates are host-side: the whole latency is the apply cost.
        push(Phase::Update, arrival, done);
        return out;
    }
    // Sampling always runs first, directly at arrival.
    let a = arrival + r.t_sample;
    push(Phase::Sample, arrival, a);
    if r.outcome.is_shed() {
        // A shed burns sampling plus backoff and nothing else
        // (`latency == t_sample + t_backoff`). A QoS deadline shed
        // additionally reports the pacing delay it *would* have paid
        // in `t_qos`, but that time is not part of its latency.
        push(Phase::Backoff, a, a + r.t_backoff);
        return out;
    }
    if r.coalesced || r.batched {
        // Riders do no device work of their own: after sampling they
        // queue until the host job starts, then ride it to completion.
        // (`t_exec` on a rider is the item-only time and is *not* a
        // wall phase — the Ride window is.)
        let boarded = a + r.t_queue;
        push(Phase::Queue, a, boarded);
        push(Phase::Ride, boarded, done);
        return out;
    }
    // Non-riders: walk backwards from completion. The visit executed
    // over [start, done], queued over [job_ready, start].
    let start = done - r.t_exec;
    let job_ready = start - r.t_queue;
    if r.t_qos > 0.0 {
        // QoS-paced placement: the compile stall is anchored right
        // after sampling, pacing at arrival, and the visit becomes
        // ready when the later of the two ends —
        // `job_ready == max(a + t_compile, arrival + t_qos)`.
        push(Phase::Compile, a, a + r.t_compile);
        push(Phase::QosPace, arrival, arrival + r.t_qos);
    } else {
        // Plain or faulty placement: the compile stall ends exactly at
        // job_ready and starts at the last attempt's floor. On the
        // fault-free path `floor == a` and the backoff/retry windows
        // are empty; under a fault plan the floor advanced past `a` by
        // backoff pauses (Backoff) plus the time doomed attempts ran
        // before their crash instants (RetryWait).
        let floor = job_ready - r.t_compile;
        push(Phase::Compile, floor, job_ready);
        let backoff_from = floor - r.t_backoff;
        push(Phase::Backoff, backoff_from, floor);
        push(Phase::RetryWait, a, backoff_from);
    }
    push(Phase::Queue, job_ready, start);
    push(Phase::Exec, start, done);
    out
}

/// Length of the union of the given windows (overlaps counted once).
pub fn coverage(segs: &[Segment]) -> f64 {
    let mut sorted: Vec<(f64, f64)> = segs.iter().map(|s| (s.from, s.until)).collect();
    sorted.sort_by(|x, y| x.0.total_cmp(&y.0).then(x.1.total_cmp(&y.1)));
    let mut total = 0.0;
    let mut hi = f64::NEG_INFINITY;
    for (from, until) in sorted {
        let from = from.max(hi);
        if until > from {
            total += until - from;
            hi = until;
        }
    }
    total
}

/// The per-request accounting gap: `|latency - coverage|` of the
/// response's reconstructed phase timeline. Zero (up to float noise)
/// on every serving path — the coordinator debug-asserts this against
/// [`ACCOUNTING_TOL_S`] on each admission.
pub fn accounting_gap(arrival: f64, r: &Response) -> f64 {
    (coverage(&segments(arrival, r)) - r.latency).abs()
}

/// Modeled per-layer execution slice of a compiled program: the cycle
/// simulator's per-layer breakdown, captured once per program key so
/// the tracer can subdivide an `exec` span into kernel spans.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LayerSlice {
    /// IR layer id.
    pub layer_id: u16,
    /// Raw [`crate::ir::LayerType`] discriminant.
    pub kind: u8,
    /// Modeled cycles the layer spent on the device.
    pub cycles: u64,
}

/// Kernel-span display name for a raw layer-type discriminant.
fn kind_name(kind: u8) -> &'static str {
    match kind {
        0 => "aggregate",
        1 => "linear",
        2 => "vector_inner",
        3 => "vector_add",
        4 => "activation",
        5 => "batch_norm",
        _ => "op",
    }
}

/// Per-request scratch the coordinator stashes for the tracer on the
/// six non-rider serving paths: the executed program's per-layer cycle
/// split and its compile report. Both are modeled, deterministic
/// quantities (the report's *measured* wall-clock pass times never
/// enter spans — only the modeled [`CompileReport::total`] split).
#[derive(Clone, Debug)]
pub struct ObsJob {
    /// Per-layer cycle split of the executed program.
    pub layers: Arc<[LayerSlice]>,
    /// Compile report of the executed program (modeled fields only).
    pub report: CompileReport,
}

/// A typed span argument (rendered into the Chrome event's `args`).
#[derive(Clone, Debug, PartialEq)]
pub enum ArgVal {
    /// An unsigned counter.
    U64(u64),
    /// A seconds / ratio value.
    F64(f64),
    /// A stable label.
    Str(String),
    /// A flag.
    Bool(bool),
}

/// One recorded span: a named window of one request's lifetime on the
/// virtual clock. `request` is the admission index (the Chrome export
/// maps it to a thread so each request renders as its own lane).
#[derive(Clone, Debug, PartialEq)]
pub struct Span {
    /// Admission index of the request this span belongs to.
    pub request: u64,
    /// Display name.
    pub name: String,
    /// Chrome event category (`request`, `phase`, `compiler`,
    /// `transfer`, or `kernel`).
    pub cat: &'static str,
    /// Span start (absolute virtual-clock seconds).
    pub from: f64,
    /// Span duration (seconds).
    pub dur: f64,
    /// Typed key/value annotations.
    pub args: Vec<(&'static str, ArgVal)>,
}

/// The live tracer: a flat, admission-ordered span stream. Dormant
/// pattern — the coordinator holds `Option<ObsState>` and never
/// touches it (or pays for it) when tracing is off.
#[derive(Debug, Default)]
pub struct ObsState {
    spans: Vec<Span>,
    seq: u64,
}

impl ObsState {
    /// An empty tracer.
    pub fn new() -> ObsState {
        ObsState::default()
    }

    /// Spans recorded so far, in admission order (root span first
    /// within each request).
    pub fn spans(&self) -> &[Span] {
        &self.spans
    }

    /// Record one admitted request: a root span over its full latency,
    /// one child per phase window, and — when the coordinator stashed
    /// the executed program's [`ObsJob`] — compiler-pass children
    /// under `compile` and transfer + per-layer kernel children under
    /// `exec`.
    pub fn record(
        &mut self,
        rq: &Request,
        r: &Response,
        job: Option<&ObsJob>,
        visit_overhead_s: f64,
    ) {
        let seq = self.seq;
        self.seq += 1;
        let kind = if r.update {
            "update"
        } else if r.minibatch {
            "minibatch"
        } else {
            "full"
        };
        let name = if r.update {
            format!("update {}", rq.dataset.key)
        } else {
            format!("{kind} {}@{}", rq.model.key(), rq.dataset.key)
        };
        self.spans.push(Span {
            request: seq,
            name,
            cat: "request",
            from: rq.arrival,
            dur: r.latency,
            args: vec![
                ("tenant", ArgVal::U64(r.tenant as u64)),
                ("device", ArgVal::U64(r.device as u64)),
                ("outcome", ArgVal::Str(r.outcome.key().to_string())),
                ("precision", ArgVal::Str(r.precision.key().to_string())),
                ("cache_hit", ArgVal::Bool(r.cache_hit)),
                ("epoch", ArgVal::U64(r.epoch as u64)),
            ],
        });
        for seg in segments(rq.arrival, r) {
            self.spans.push(Span {
                request: seq,
                name: seg.phase.name().to_string(),
                cat: "phase",
                from: seg.from,
                dur: seg.until - seg.from,
                args: Vec::new(),
            });
            match seg.phase {
                Phase::Compile => {
                    if let Some(j) = job {
                        self.record_compile(seq, &seg, &j.report);
                    }
                }
                Phase::Exec => {
                    if let Some(j) = job {
                        let overhead = if r.minibatch { visit_overhead_s } else { 0.0 };
                        self.record_exec(seq, &seg, &j.layers, overhead);
                    }
                }
                _ => {}
            }
        }
    }

    /// Subdivide a cold compile stall proportionally to the *modeled*
    /// report terms (pass setup / instruction emit / block schedule —
    /// the three addends of [`CompileReport::total`]). The measured
    /// wall-clock pass times are deliberately never used: they differ
    /// run to run and would break span bit-identity.
    fn record_compile(&mut self, seq: u64, seg: &Segment, report: &CompileReport) {
        let parts = [
            ("compile:passes", report.modeled_passes()),
            ("compile:emit", report.modeled_emit()),
            ("compile:schedule", report.modeled_schedule()),
        ];
        let total: f64 = parts.iter().map(|&(_, w)| w).sum();
        if total <= 0.0 {
            return;
        }
        let width = seg.until - seg.from;
        let mut acc = 0.0;
        for (name, w) in parts {
            let from = seg.from + width * (acc / total);
            acc += w;
            let until = seg.from + width * (acc / total);
            if until > from {
                self.spans.push(Span {
                    request: seq,
                    name: name.to_string(),
                    cat: "compiler",
                    from,
                    dur: until - from,
                    args: Vec::new(),
                });
            }
        }
    }

    /// Subdivide an `exec` span: the fixed visit overhead first (the
    /// host→device transfer / dispatch window of a mini-batch visit),
    /// then per-layer kernel spans tiling the remaining width in
    /// proportion to each layer's modeled cycles.
    fn record_exec(
        &mut self,
        seq: u64,
        seg: &Segment,
        layers: &[LayerSlice],
        overhead_s: f64,
    ) {
        let width = seg.until - seg.from;
        let overhead = overhead_s.min(width);
        if overhead > 0.0 {
            self.spans.push(Span {
                request: seq,
                name: "transfer".to_string(),
                cat: "transfer",
                from: seg.from,
                dur: overhead,
                args: Vec::new(),
            });
        }
        let base = seg.from + overhead;
        let kernel_width = width - overhead;
        let total_cycles: u64 = layers.iter().map(|l| l.cycles).sum();
        if total_cycles == 0 || kernel_width <= 0.0 {
            return;
        }
        let mut acc = 0u64;
        for l in layers {
            let from = base + kernel_width * (acc as f64 / total_cycles as f64);
            acc += l.cycles;
            let until = base + kernel_width * (acc as f64 / total_cycles as f64);
            if until > from {
                self.spans.push(Span {
                    request: seq,
                    name: format!("L{} {}", l.layer_id, kind_name(l.kind)),
                    cat: "kernel",
                    from,
                    dur: until - from,
                    args: vec![("cycles", ArgVal::U64(l.cycles))],
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::dataset;
    use crate::ir::ZooModel;
    use crate::serve::{Outcome, Request, Response, ShedReason};

    fn base_resp() -> Response {
        let rq = Request::full(0, ZooModel::B1, dataset("CO").unwrap(), 0.0);
        Response {
            tenant: 0,
            model: rq.model,
            device: 0,
            t_compile: 0.0,
            t_sample: 0.0,
            t_exec: 0.0,
            t_queue: 0.0,
            latency: 0.0,
            cache_hit: false,
            coalesced: false,
            batched: false,
            minibatch: false,
            sampled_vertices: 0,
            sampled_edges: 0,
            remaps: 0,
            precision: crate::serve::Precision::F32,
            quant_visits: 0,
            requant_ops: 0,
            int8_bytes: 0,
            update: false,
            epoch: 0,
            t_update: 0.0,
            dirty_subshards: 0,
            rebuilt_edges: 0,
            invalidated: 0,
            compacted: false,
            retries: 0,
            rerouted: false,
            t_backoff: 0.0,
            t_qos: 0.0,
            deadline_missed: false,
            outcome: Outcome::Completed,
        }
    }

    #[test]
    fn plain_full_request_covers_latency() {
        let r = Response {
            t_compile: 2e-3,
            t_exec: 5e-3,
            t_queue: 1e-3,
            latency: 8e-3,
            ..base_resp()
        };
        let segs = segments(1.0, &r);
        assert!((coverage(&segs) - r.latency).abs() < ACCOUNTING_TOL_S);
        assert_eq!(
            segs.iter().map(|s| s.phase).collect::<Vec<_>>(),
            vec![Phase::Compile, Phase::Queue, Phase::Exec]
        );
        // Compile is anchored at arrival on the plain path.
        assert!((segs[0].from - 1.0).abs() < 1e-12);
    }

    #[test]
    fn faulty_request_names_backoff_and_retry_wait() {
        // floor advanced 7 ms past arrival: 5 ms of backoff plus 2 ms
        // a doomed attempt ran before its crash.
        let r = Response {
            t_compile: 2e-3,
            t_exec: 4e-3,
            t_queue: 0.0,
            t_backoff: 5e-3,
            retries: 1,
            latency: 13e-3,
            ..base_resp()
        };
        let segs = segments(0.0, &r);
        assert!((coverage(&segs) - r.latency).abs() < ACCOUNTING_TOL_S);
        let phases: Vec<Phase> = segs.iter().map(|s| s.phase).collect();
        assert_eq!(
            phases,
            vec![
                Phase::Compile,
                Phase::Backoff,
                Phase::RetryWait,
                Phase::Exec
            ]
        );
    }

    #[test]
    fn qos_paced_request_overlaps_pacing_with_host_work() {
        // Pacing (3 ms from arrival) outlasts sample+compile (2 ms):
        // job_ready is the pacing end.
        let r = Response {
            t_sample: 1e-3,
            t_compile: 1e-3,
            t_qos: 3e-3,
            t_exec: 4e-3,
            t_queue: 0.0,
            latency: 7e-3,
            minibatch: true,
            ..base_resp()
        };
        let segs = segments(2.0, &r);
        assert!((coverage(&segs) - r.latency).abs() < ACCOUNTING_TOL_S);
        assert!(segs.iter().any(|s| s.phase == Phase::QosPace));
    }

    #[test]
    fn shed_covers_sample_plus_backoff() {
        let r = Response {
            t_sample: 2e-3,
            t_backoff: 15e-3,
            retries: 3,
            latency: 17e-3,
            device: u32::MAX,
            minibatch: true,
            outcome: Outcome::Shed(ShedReason::RetriesExhausted),
            ..base_resp()
        };
        let segs = segments(0.5, &r);
        assert!((coverage(&segs) - r.latency).abs() < ACCOUNTING_TOL_S);
        assert_eq!(segs.len(), 2);
    }

    #[test]
    fn rider_covers_queue_plus_ride() {
        let r = Response {
            t_sample: 1e-3,
            t_queue: 2e-3,
            t_exec: 9e-4, // item-only time: not a wall phase on riders
            latency: 8e-3,
            coalesced: true,
            cache_hit: true,
            ..base_resp()
        };
        let segs = segments(0.0, &r);
        assert!((coverage(&segs) - r.latency).abs() < ACCOUNTING_TOL_S);
        assert_eq!(segs.last().unwrap().phase, Phase::Ride);
    }

    #[test]
    fn update_is_one_segment() {
        let r = Response {
            update: true,
            t_update: 3e-3,
            latency: 3e-3,
            device: u32::MAX,
            ..base_resp()
        };
        let segs = segments(0.25, &r);
        assert_eq!(segs.len(), 1);
        assert_eq!(segs[0].phase, Phase::Update);
        assert!((coverage(&segs) - r.latency).abs() < ACCOUNTING_TOL_S);
    }

    #[test]
    fn record_builds_kernel_children_proportional_to_cycles() {
        let rq = Request::full(1, ZooModel::B1, dataset("CO").unwrap(), 0.0);
        let r = Response {
            t_compile: 1e-3,
            t_exec: 4e-3,
            latency: 5e-3,
            ..base_resp()
        };
        let layers: Arc<[LayerSlice]> = vec![
            LayerSlice { layer_id: 0, kind: 0, cycles: 300 },
            LayerSlice { layer_id: 1, kind: 1, cycles: 100 },
        ]
        .into();
        let job = ObsJob { layers, report: CompileReport::default() };
        let mut obs = ObsState::new();
        obs.record(&rq, &r, Some(&job), 4e-5);
        let kernels: Vec<&Span> = obs.spans().iter().filter(|s| s.cat == "kernel").collect();
        assert_eq!(kernels.len(), 2);
        assert!((kernels[0].dur - 3e-3).abs() < 1e-12);
        assert!((kernels[1].dur - 1e-3).abs() < 1e-12);
        // Kernel spans tile the exec window exactly.
        let exec = obs.spans().iter().find(|s| s.name == "exec").unwrap();
        assert!((kernels[0].from - exec.from).abs() < 1e-12);
        let k_end = kernels[1].from + kernels[1].dur;
        assert!((k_end - (exec.from + exec.dur)).abs() < 1e-12);
    }
}
