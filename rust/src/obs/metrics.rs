//! Live metrics: a log-bucketed latency histogram and the Prometheus
//! text-exposition renderer behind the daemon's `metrics` op.
//!
//! Bucket math: finite upper bounds are `1e-6 * 2^k` seconds for
//! `k = 0..N_BUCKETS` (1 µs doubling up to ~134 s), plus a `+Inf`
//! overflow bucket — the classic log₂ layout, cheap to index and
//! coarse enough that cumulative `_bucket` lines stay readable. The
//! histogram quantile is nearest-rank over cumulative bucket counts
//! and returns the containing bucket's upper bound, so it brackets the
//! exact sorted-sample percentile
//! ([`crate::serve::percentile`]) from above within one bucket factor
//! (2x) — the cheap path when responses are too many to sort.
//!
//! Naming conventions: every family is prefixed `ga_`, counters end
//! in `_total`, seconds-valued families end in `_seconds`, and
//! per-tenant families carry a `tenant="<id>"` label. Rendering is
//! fully deterministic (fixed family order, tenant rows sorted by id,
//! Rust's shortest-roundtrip float formatting).

use crate::serve::ServeStats;
use std::fmt::Write;

/// Smallest finite bucket upper bound, seconds (1 µs).
pub const BUCKET_FLOOR_S: f64 = 1e-6;

/// Number of finite buckets; bound `k` is `BUCKET_FLOOR_S * 2^k`, so
/// the largest finite bound is ~134 s — far beyond any modeled
/// serving latency.
pub const N_BUCKETS: usize = 28;

/// A log₂-bucketed latency histogram (Prometheus `histogram` type:
/// cumulative `le` buckets plus `_sum` and `_count`).
#[derive(Clone, Debug, PartialEq)]
pub struct Histogram {
    /// Per-bucket observation counts; index [`N_BUCKETS`] is `+Inf`.
    counts: [u64; N_BUCKETS + 1],
    sum: f64,
    count: u64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram { counts: [0; N_BUCKETS + 1], sum: 0.0, count: 0 }
    }
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Finite upper bound of bucket `k`.
    fn bound(k: usize) -> f64 {
        BUCKET_FLOOR_S * (1u64 << k) as f64
    }

    /// Record one latency observation (seconds).
    pub fn observe(&mut self, v: f64) {
        let k = (0..N_BUCKETS).find(|&k| v <= Self::bound(k)).unwrap_or(N_BUCKETS);
        self.counts[k] += 1;
        self.sum += v;
        self.count += 1;
    }

    /// Build a histogram from a latency iterator.
    pub fn from_latencies(lats: impl IntoIterator<Item = f64>) -> Histogram {
        let mut h = Histogram::new();
        for v in lats {
            h.observe(v);
        }
        h
    }

    /// Total observations.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of all observed values, seconds.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Nearest-rank quantile, resolved to the containing bucket's
    /// upper bound (an upper bracket of the exact sample quantile;
    /// within a 2x bucket factor of it). `0.0` on an empty histogram,
    /// `f64::INFINITY` when the rank lands in the overflow bucket.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for k in 0..N_BUCKETS {
            seen += self.counts[k];
            if seen >= rank {
                return Self::bound(k);
            }
        }
        f64::INFINITY
    }

    /// Render the cumulative `_bucket` / `_sum` / `_count` lines of
    /// one Prometheus histogram family.
    fn render(&self, out: &mut String, name: &str) {
        let mut cum = 0u64;
        for k in 0..N_BUCKETS {
            cum += self.counts[k];
            let _ = writeln!(out, "{name}_bucket{{le=\"{}\"}} {cum}", Self::bound(k));
        }
        cum += self.counts[N_BUCKETS];
        let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {cum}");
        let _ = writeln!(out, "{name}_sum {}", self.sum);
        let _ = writeln!(out, "{name}_count {}", self.count);
    }
}

/// Histogram-backed percentile: the log-bucket path alongside the
/// exact sorted-sample [`crate::serve::percentile`]. Returns the
/// bucket upper bound containing the nearest-rank sample — an upper
/// bracket of the exact percentile within one bucket factor (2x) —
/// without sorting.
pub fn histogram_percentile(latencies: &[f64], p: f64) -> f64 {
    Histogram::from_latencies(latencies.iter().copied()).quantile(p)
}

/// One `# HELP` + `# TYPE` header plus a sample line.
fn family(out: &mut String, name: &str, kind: &str, help: &str, value: impl std::fmt::Display) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
    let _ = writeln!(out, "{name} {value}");
}

/// Header only (for labeled families whose samples follow).
fn header(out: &mut String, name: &str, kind: &str, help: &str) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
}

/// Render a [`ServeStats`] snapshot plus a latency histogram as
/// Prometheus text exposition (version 0.0.4). Deterministic: the
/// same stats and histogram render byte-identically.
pub fn prometheus(stats: &ServeStats, hist: &Histogram) -> String {
    let mut o = String::new();
    // Throughput / cache family.
    family(&mut o, "ga_requests_completed_total", "counter",
        "Requests that reached a served outcome (completed or degraded).", stats.completed);
    family(&mut o, "ga_cache_hits_total", "counter",
        "Responses whose program came from a device cache.", stats.cache_hits);
    family(&mut o, "ga_coalesced_total", "counter",
        "Requests that rode an identical in-flight job.", stats.coalesced);
    // Mini-batch family.
    family(&mut o, "ga_minibatched_total", "counter",
        "Completed mini-batch requests.", stats.minibatched);
    family(&mut o, "ga_batched_total", "counter",
        "Mini-batch requests micro-batched onto an existing visit.", stats.batched);
    family(&mut o, "ga_bucket_hits_total", "counter",
        "Mini-batch requests whose bucket program was already compiled.", stats.bucket_hits);
    family(&mut o, "ga_sampled_vertices_total", "counter",
        "Ego-net vertices sampled across all mini-batch requests.", stats.sampled_vertices);
    family(&mut o, "ga_sampled_edges_total", "counter",
        "Ego-net edges sampled across all mini-batch requests.", stats.sampled_edges);
    // Kernel re-map + quantized datapath family.
    family(&mut o, "ga_remaps_total", "counter",
        "Density-driven kernel re-maps summed over executed jobs.", stats.remaps);
    family(&mut o, "ga_quantized_total", "counter",
        "Completed inference requests served on the int8 datapath.", stats.quantized);
    family(&mut o, "ga_quant_visits_total", "counter",
        "Quantized tile launches summed over executed jobs.", stats.quant_visits);
    family(&mut o, "ga_requant_ops_total", "counter",
        "Quantize/requantize epilogues summed over executed jobs.", stats.requant_ops);
    family(&mut o, "ga_int8_bytes_total", "counter",
        "Modeled 1-byte operand traffic summed over executed jobs.", stats.int8_bytes);
    // Streaming-update family.
    family(&mut o, "ga_updates_total", "counter",
        "Streaming update requests applied.", stats.updates);
    family(&mut o, "ga_graph_epoch", "gauge",
        "Highest graph epoch reached by any streamed dataset.", stats.max_epoch);
    family(&mut o, "ga_dirty_subshards_total", "counter",
        "Dirty subshards rebuilt across all updates.", stats.dirty_subshards);
    family(&mut o, "ga_rebuilt_edges_total", "counter",
        "Edges re-sorted rebuilding dirty subshards.", stats.rebuilt_edges);
    family(&mut o, "ga_invalidated_total", "counter",
        "Stale whole-graph programs invalidated across all updates.", stats.invalidated);
    family(&mut o, "ga_compactions_total", "counter",
        "Overlay compactions triggered across all updates.", stats.compactions);
    // Fault / degradation family.
    family(&mut o, "ga_retries_total", "counter",
        "Crashed attempts retried, summed over all requests.", stats.retries);
    family(&mut o, "ga_rerouted_total", "counter",
        "Requests whose serving device differs from their first route.", stats.rerouted);
    family(&mut o, "ga_degraded_total", "counter",
        "Requests that completed down the fidelity cascade.", stats.degraded);
    family(&mut o, "ga_shed_total", "counter",
        "Requests shed with a named reason.", stats.shed);
    family(&mut o, "ga_crashes_total", "counter",
        "Device-crash events fired from the fault plan.", stats.crashes);
    family(&mut o, "ga_stalls_total", "counter",
        "Transient-stall events fired from the fault plan.", stats.stalls);
    family(&mut o, "ga_corruptions_total", "counter",
        "Armed artifact corruptions that bit.", stats.corruptions);
    family(&mut o, "ga_downtime_seconds_total", "counter",
        "Scheduled device downtime summed over fired finite crashes.", stats.downtime);
    family(&mut o, "ga_backoff_seconds_total", "counter",
        "Backoff pause charged across all retried requests.", stats.t_backoff);
    // Latency family (exact sorted-sample percentiles as gauges, plus
    // the log-bucketed histogram).
    family(&mut o, "ga_latency_p50_seconds", "gauge",
        "Median served-inference latency (exact nearest-rank).", stats.p50);
    family(&mut o, "ga_latency_p99_seconds", "gauge",
        "99th-percentile served-inference latency (exact nearest-rank).", stats.p99);
    family(&mut o, "ga_latency_mean_seconds", "gauge",
        "Mean served-inference latency.", stats.mean);
    family(&mut o, "ga_device_busy_seconds", "gauge",
        "Sum of execution seconds across devices.", stats.device_busy);
    family(&mut o, "ga_makespan_seconds", "gauge",
        "Virtual time of the last processed event.", stats.makespan);
    header(&mut o, "ga_request_latency_seconds", "histogram",
        "Served-inference latency, log2 buckets from 1us.");
    hist.render(&mut o, "ga_request_latency_seconds");
    // Per-tenant family (rows sorted by tenant id; present only under
    // an installed tenant config, like `ServeStats::tenants` itself).
    if !stats.tenants.is_empty() {
        header(&mut o, "ga_tenant_completed_total", "counter",
            "Requests served per tenant.");
        for t in &stats.tenants {
            let _ = writeln!(o, "ga_tenant_completed_total{{tenant=\"{}\"}} {}", t.tenant, t.completed);
        }
        header(&mut o, "ga_tenant_degraded_total", "counter",
            "Requests served on a lower fidelity rung, per tenant.");
        for t in &stats.tenants {
            let _ = writeln!(o, "ga_tenant_degraded_total{{tenant=\"{}\"}} {}", t.tenant, t.degraded);
        }
        header(&mut o, "ga_tenant_shed_total", "counter", "Requests shed per tenant.");
        for t in &stats.tenants {
            let _ = writeln!(o, "ga_tenant_shed_total{{tenant=\"{}\"}} {}", t.tenant, t.shed);
        }
        header(&mut o, "ga_tenant_deadline_missed_total", "counter",
            "Requests past their deadline, per tenant.");
        for t in &stats.tenants {
            let _ = writeln!(o, "ga_tenant_deadline_missed_total{{tenant=\"{}\"}} {}", t.tenant, t.missed);
        }
        header(&mut o, "ga_tenant_latency_p99_seconds", "gauge",
            "Exact 99th-percentile served latency, per tenant.");
        for t in &stats.tenants {
            let _ = writeln!(o, "ga_tenant_latency_p99_seconds{{tenant=\"{}\"}} {}", t.tenant, t.p99);
        }
        header(&mut o, "ga_tenant_qos_delay_seconds_total", "counter",
            "Total QoS pacing delay charged, per tenant.");
        for t in &stats.tenants {
            let _ = writeln!(o, "ga_tenant_qos_delay_seconds_total{{tenant=\"{}\"}} {}", t.tenant, t.t_qos);
        }
    }
    o
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::percentile;

    #[test]
    fn quantile_brackets_the_exact_percentile() {
        let lats: Vec<f64> = (1..=500).map(|i| i as f64 * 3.7e-5).collect();
        let mut sorted = lats.clone();
        sorted.sort_by(f64::total_cmp);
        for p in [0.5, 0.9, 0.99] {
            let exact = percentile(&sorted, p);
            let bucketed = histogram_percentile(&lats, p);
            assert!(bucketed >= exact, "bucket bound must bracket from above");
            assert!(bucketed <= exact * 2.0, "within one log2 bucket factor");
        }
    }

    #[test]
    fn empty_and_overflow_quantiles() {
        assert_eq!(Histogram::new().quantile(0.5), 0.0);
        let mut h = Histogram::new();
        h.observe(1e9); // beyond the largest finite bound
        assert_eq!(h.quantile(0.5), f64::INFINITY);
    }

    #[test]
    fn exposition_has_well_formed_families() {
        let stats = ServeStats { completed: 42, p50: 1.25e-3, ..ServeStats::default() };
        let hist = Histogram::from_latencies([1e-4, 2e-4, 3e-3]);
        let text = prometheus(&stats, &hist);
        assert!(text.contains("# TYPE ga_requests_completed_total counter"));
        assert!(text.contains("ga_requests_completed_total 42"));
        assert!(text.contains("ga_request_latency_seconds_bucket{le=\"+Inf\"} 3"));
        assert!(text.contains("ga_request_latency_seconds_count 3"));
        // Every non-comment line is `name[{labels}] value`.
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let (name, value) = line.rsplit_once(' ').expect("sample line");
            assert!(!name.is_empty() && name.starts_with("ga_"), "{line}");
            assert!(value.parse::<f64>().is_ok() || value == "+Inf", "{line}");
        }
        // Deterministic rendering.
        assert_eq!(text, prometheus(&stats, &hist));
    }

    #[test]
    fn tenant_families_render_only_under_a_config() {
        let stats = ServeStats::default();
        let text = prometheus(&stats, &Histogram::new());
        assert!(!text.contains("ga_tenant_"));
    }
}
