//! Chrome trace-event JSON export: spans become `"X"` (complete)
//! events, fired fault-plan events become `"i"` (instant) events, and
//! a pair of `"M"` metadata events name the two process lanes. The
//! output is the top-level-array flavor of the trace-event format, so
//! it loads directly in `chrome://tracing` and Perfetto.
//!
//! Layout: requests render under pid 1 with one thread per admission
//! index (nested phase/compiler/kernel spans draw as a flame within
//! the request's lane); fault instants render under pid 0 with one
//! thread per device. Timestamps are virtual-clock microseconds. The
//! writer is [`crate::util::Json`] (insertion-ordered objects,
//! shortest-roundtrip floats), so a span stream serializes to
//! byte-identical JSON on every run that produced identical spans.

use super::span::{ArgVal, Span};
use crate::serve::{FaultEvent, FaultRecord};
use crate::util::Json;

/// Virtual-clock seconds → trace-event microseconds.
fn us(t: f64) -> Json {
    Json::Num(t * 1e6)
}

fn arg_json(v: &ArgVal) -> Json {
    match v {
        ArgVal::U64(n) => Json::Num(*n as f64),
        ArgVal::F64(x) => Json::Num(*x),
        ArgVal::Str(s) => Json::Str(s.clone()),
        ArgVal::Bool(b) => Json::Bool(*b),
    }
}

fn meta_event(pid: u64, name: &str) -> Json {
    Json::obj(vec![
        ("name", Json::Str("process_name".into())),
        ("ph", Json::Str("M".into())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(0.0)),
        (
            "args",
            Json::obj(vec![("name", Json::Str(name.to_string()))]),
        ),
    ])
}

fn span_event(s: &Span) -> Json {
    let mut pairs = vec![
        ("name", Json::Str(s.name.clone())),
        ("cat", Json::Str(s.cat.to_string())),
        ("ph", Json::Str("X".into())),
        ("ts", us(s.from)),
        ("dur", us(s.dur)),
        ("pid", Json::Num(1.0)),
        ("tid", Json::Num(s.request as f64)),
    ];
    if !s.args.is_empty() {
        let args: Vec<(&str, Json)> = s.args.iter().map(|(k, v)| (*k, arg_json(v))).collect();
        pairs.push(("args", Json::obj(args)));
    }
    Json::obj(pairs)
}

fn fault_event(rec: &FaultRecord) -> Json {
    let (name, device, args) = match &rec.fault {
        FaultEvent::DeviceCrash { device, recover_after, .. } => (
            "crash",
            *device,
            vec![("recover_after_s", Json::Num(*recover_after))],
        ),
        FaultEvent::TransientStall { device, duration, .. } => {
            ("stall", *device, vec![("duration_s", Json::Num(*duration))])
        }
        FaultEvent::ArtifactCorruption { device, model, dataset, .. } => (
            "corruption",
            *device,
            vec![
                ("model", Json::Str(model.key().to_string())),
                ("dataset", Json::Str(dataset.clone())),
            ],
        ),
    };
    Json::obj(vec![
        ("name", Json::Str(name.into())),
        ("cat", Json::Str("fault".into())),
        ("ph", Json::Str("i".into())),
        ("s", Json::Str("g".into())),
        ("ts", us(rec.at)),
        ("pid", Json::Num(0.0)),
        ("tid", Json::Num(device as f64)),
        ("args", Json::obj(args)),
    ])
}

/// Serialize a span stream plus the fired fault log as a Chrome
/// trace-event JSON document (a top-level array, newline-terminated).
pub fn chrome_trace(spans: &[Span], faults: &[FaultRecord]) -> String {
    let mut events = vec![meta_event(1, "requests"), meta_event(0, "devices")];
    events.extend(spans.iter().map(span_event));
    events.extend(faults.iter().map(fault_event));
    format!("{}\n", Json::Arr(events))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::FaultEvent;

    #[test]
    fn export_is_a_parseable_event_array() {
        let spans = vec![Span {
            request: 0,
            name: "full b1@CO".into(),
            cat: "request",
            from: 1.5,
            dur: 2e-3,
            args: vec![("tenant", ArgVal::U64(3))],
        }];
        let faults = vec![FaultRecord {
            at: 0.75,
            fault: FaultEvent::TransientStall { device: 1, at: 0.75, duration: 0.05 },
        }];
        let text = chrome_trace(&spans, &faults);
        let j = Json::parse(text.trim()).expect("valid JSON");
        let Json::Arr(events) = j else { panic!("top level must be an array") };
        // 2 metadata + 1 span + 1 instant.
        assert_eq!(events.len(), 4);
        let span = &events[2];
        assert_eq!(span.str_of("ph").unwrap(), "X");
        assert_eq!(span.f64_of("ts").unwrap(), 1.5e6);
        assert_eq!(span.f64_of("dur").unwrap(), 2e3);
        let inst = &events[3];
        assert_eq!(inst.str_of("ph").unwrap(), "i");
        assert_eq!(inst.str_of("s").unwrap(), "g");
        assert_eq!(inst.str_of("name").unwrap(), "stall");
    }

    #[test]
    fn identical_spans_serialize_identically() {
        let s = Span {
            request: 7,
            name: "exec".into(),
            cat: "phase",
            from: 0.123456789,
            dur: 4.2e-5,
            args: Vec::new(),
        };
        assert_eq!(
            chrome_trace(&[s.clone()], &[]),
            chrome_trace(&[s], &[])
        );
    }
}
