//! Deterministic observability for the serving fleet: span tracing on
//! the virtual clock, Chrome trace-event export, and Prometheus-style
//! metrics exposition.
//!
//! Three pieces, all driven by data the fleet already computes:
//!
//! * [`span`] — the per-request phase model. [`segments`] reconstructs
//!   the exact phase timeline (sample → qos-pace → compile → backoff →
//!   queue → exec) of any [`Response`](crate::serve::Response) from its
//!   public accounting fields; [`ObsState`] turns admitted requests
//!   into [`Span`] trees with compiler-pass and per-layer kernel
//!   children,
//! * [`chrome`] — serializes a span stream (plus fired fault events as
//!   instants) into Chrome trace-event JSON that loads directly in
//!   `chrome://tracing` / Perfetto,
//! * [`metrics`] — a log-bucketed latency [`Histogram`] and the
//!   [`prometheus`] text-exposition renderer behind the daemon's
//!   `metrics` op.
//!
//! Everything here is a function of virtual-clock quantities (modeled
//! costs, deterministic response fields), never wall time — so a span
//! stream is bit-identical across `GA_KERNEL_THREADS` values and
//! across record/replay. Tracing follows the dormant-`Option` pattern
//! of [`crate::serve::fault`] and [`crate::serve::qos`]: the
//! coordinator holds `Option<ObsState>`, and with tracing off (the
//! default) every response, stat, trace, and CLI byte is identical to
//! a build without this module.
#![warn(missing_docs)]

pub mod chrome;
pub mod metrics;
pub mod span;

pub use chrome::chrome_trace;
pub use metrics::{histogram_percentile, prometheus, Histogram};
pub use span::{
    accounting_gap, coverage, segments, ArgVal, LayerSlice, ObsJob, ObsState, Phase, Segment,
    Span, ACCOUNTING_TOL_S,
};
