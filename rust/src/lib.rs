//! # GraphAGILE
//!
//! A full reproduction of *GraphAGILE: An FPGA-based Overlay Accelerator for
//! Low-latency GNN Inference* (Zhang, Zeng, Prasanna — cs.DC 2023).
//!
//! The crate contains every layer of the system:
//!
//! * [`ir`] — the compiler's intermediate representation (six computation
//!   layer types) and the paper's model zoo **b1–b8** (Table 5),
//! * [`compiler`] — the four-pass optimizing compiler (Sec. 6): computation
//!   order optimization, layer fusion, Fiber-Shard data partitioning, and
//!   kernel mapping / task scheduling with mutex (WAR hazard) annotation,
//! * [`isa`] — the 128-bit high-level instruction set (Fig. 3), microcode
//!   expansion (Alg. 1–3), and the `.ga` executable format (Table 8),
//! * [`sim`] — a cycle-level model of the overlay hardware (Sec. 5): PEs,
//!   the Adaptive Computation Kernel's four execution modes, butterfly
//!   shuffle networks, the RAW unit, banked buffers, DDR channels, PCIe,
//!   and the dynamic tile scheduler (Alg. 9),
//! * [`runtime`] — the PJRT functional runtime that loads AOT-compiled HLO
//!   artifacts (produced once, at build time, by `python/compile/aot.py`)
//!   and executes real GNN numerics on tiles — python is never on this
//!   path,
//! * [`exec`] — the pure-rust executors and their kernel backend:
//!   golden whole-graph + partition-centric tile execution over
//!   blocked GEMM / CSR SpDMM / SDDMM kernels with a zero-alloc buffer
//!   arena (the naive scalar originals survive as `ops::reference`,
//!   the measured baseline),
//! * [`engine`] — the execution-substrate abstraction: one
//!   [`engine::InferenceEngine`] trait over the golden executor, the
//!   functional tile runtimes and the cycle simulator, all consuming the
//!   compiler's `Executable` and reporting a unified
//!   [`engine::ExecProfile`],
//! * [`serve`] — the multi-tenant serving fleet: N overlay devices, a
//!   deterministic virtual clock, per-device program caches with
//!   cache-affinity routing, cross-request coalescing, and a mini-batch
//!   request class (k-hop ego-network sampling + shape-bucketed
//!   executables + micro-batched dispatch) so per-request cost tracks
//!   the sampled neighborhood instead of the whole graph,
//! * [`sparsity`] — density-aware dynamic kernel re-mapping
//!   (Dynasparse-style): an exact per-tile adjacency profiler, an
//!   analytic feature-density estimator, and the threshold table the
//!   compiler embeds in the `.ga` binary so engines can override
//!   GEMM/SpDMM per Tiling Block at run time,
//! * [`stream`] — streaming graph updates: a seeded R-MAT-skewed churn
//!   generator, a delta-overlay [`stream::DynamicGraph`] with
//!   epoch-versioned snapshots and compaction, and incremental
//!   recompilation (dirty Fiber-Shard subshards only) so the serving
//!   fleet ingests edge churn between inference requests instead of
//!   assuming a frozen graph,
//! * [`daemon`] — the production shape of the fleet: a long-running
//!   TCP server with a length-prefixed JSON wire protocol that stamps
//!   real arrival times onto the virtual clock at admission, records
//!   every accepted event into a versioned `trace.json`, and a replay
//!   path that re-executes any recorded run bit-identically offline
//!   (`graphagile replay trace.json --verify`),
//! * [`obs`] — deterministic observability: a span tracer on the
//!   virtual clock (per-request phase timelines with compiler-pass and
//!   per-layer kernel children, exported as Chrome trace-event JSON)
//!   plus log-bucketed latency histograms and Prometheus text
//!   exposition behind the daemon's `metrics` op — all bit-identical
//!   across thread counts and record/replay,
//! * [`baselines`] — analytic models of the comparison systems in the
//!   paper's evaluation (PyG/DGL on CPU/GPU, HyGCN, AWB-GCN, BoostGCN),
//! * [`harness`] — regenerates every table and figure of Sec. 8.
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod baselines;
pub mod compiler;
pub mod config;
pub mod daemon;
pub mod engine;
pub mod exec;
pub mod graph;
pub mod harness;
pub mod ir;
pub mod isa;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod sparsity;
pub mod stream;
pub mod util;

pub use config::HwConfig;
pub use ir::{LayerIr, LayerType, ModelIr};
