//! The benchmark model zoo **b1–b8** (paper Table 5), expressed as IR
//! builders matching the per-model computation graphs of Fig. 10.

use super::graphgym::GraphGymConfig;
use super::layer::{LayerIr, LayerType};
use super::model::ModelIr;
use crate::graph::GraphMeta;
use crate::isa::{AggOp, Activation};

/// The eight benchmark models of Table 5.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ZooModel {
    /// GCN, 2 layers, hidden 16.
    B1,
    /// GCN, 2 layers, hidden 128.
    B2,
    /// GraphSAGE, 2 layers, hidden 128.
    B3,
    /// GraphSAGE, 2 layers, hidden 256.
    B4,
    /// GIN, 5 layers, hidden 128.
    B5,
    /// GAT, 2 layers, hidden 64.
    B6,
    /// SGC, 1 layer, k = 2.
    B7,
    /// GraphGym: 1 pre + 3 GNN + 1 post, hidden 256.
    B8,
}

pub const ALL_MODELS: [ZooModel; 8] = [
    ZooModel::B1,
    ZooModel::B2,
    ZooModel::B3,
    ZooModel::B4,
    ZooModel::B5,
    ZooModel::B6,
    ZooModel::B7,
    ZooModel::B8,
];

impl ZooModel {
    pub fn key(&self) -> &'static str {
        match self {
            ZooModel::B1 => "b1",
            ZooModel::B2 => "b2",
            ZooModel::B3 => "b3",
            ZooModel::B4 => "b4",
            ZooModel::B5 => "b5",
            ZooModel::B6 => "b6",
            ZooModel::B7 => "b7",
            ZooModel::B8 => "b8",
        }
    }

    /// Build the ModelIr of this benchmark over `graph`.
    pub fn build(&self, graph: GraphMeta) -> ModelIr {
        match self {
            ZooModel::B1 => gcn(self.key(), graph, 16),
            ZooModel::B2 => gcn(self.key(), graph, 128),
            ZooModel::B3 => sage(self.key(), graph, 128),
            ZooModel::B4 => sage(self.key(), graph, 256),
            ZooModel::B5 => gin(self.key(), graph, 128, 5),
            ZooModel::B6 => gat(self.key(), graph, 64),
            ZooModel::B7 => sgc(self.key(), graph, 2),
            ZooModel::B8 => GraphGymConfig::default().build(self.key(), graph),
        }
    }
}

/// Look up a zoo model by key ("b1".."b8").
pub fn zoo_model(key: &str) -> Option<ZooModel> {
    ALL_MODELS.iter().find(|m| m.key().eq_ignore_ascii_case(key)).copied()
}

/// All eight models built over `graph`.
pub fn model_zoo(graph: GraphMeta) -> Vec<ModelIr> {
    ALL_MODELS.iter().map(|m| m.build(graph.clone())).collect()
}

/// GCN (Listing 3 / Fig. 7): per layer Aggregate -> Linear -> Activation;
/// the last layer has no activation.
fn gcn(name: &str, graph: GraphMeta, hidden: u64) -> ModelIr {
    let (nv, ne) = (graph.n_vertices, graph.n_edges);
    let f0 = graph.feat_len;
    let classes = graph.n_classes;
    let mut ir = ModelIr::new(name, graph);
    ir.push(LayerIr::new(0, LayerType::Aggregate, f0, f0, nv, ne));
    ir.push(LayerIr::new(0, LayerType::Linear, f0, hidden, nv, ne));
    ir.push(
        LayerIr::new(0, LayerType::Activation, hidden, hidden, nv, ne)
            .with_act(Activation::Relu),
    );
    ir.push(LayerIr::new(0, LayerType::Aggregate, hidden, hidden, nv, ne));
    ir.push(LayerIr::new(0, LayerType::Linear, hidden, classes, nv, ne));
    ir
}

/// GraphSAGE-mean: h = act(W_self h + W_neigh mean_j h_j); two layers.
fn sage(name: &str, graph: GraphMeta, hidden: u64) -> ModelIr {
    let (nv, ne) = (graph.n_vertices, graph.n_edges);
    let classes = graph.n_classes;
    let mut ir = ModelIr::new(name, graph);
    let mut prev: Option<u16> = None;
    let mut f = ir.graph.feat_len;
    for (i, out) in [hidden, classes].iter().enumerate() {
        let parents: &[u16] = match &prev {
            Some(p) => std::slice::from_ref(p),
            None => &[],
        };
        let lin_self =
            ir.push_with_parents(LayerIr::new(0, LayerType::Linear, f, *out, nv, ne), parents);
        let agg = ir.push_with_parents(
            LayerIr::new(0, LayerType::Aggregate, f, f, nv, ne).with_aggop(AggOp::Mean),
            parents,
        );
        let lin_neigh = ir
            .push_with_parents(LayerIr::new(0, LayerType::Linear, f, *out, nv, ne), &[agg]);
        let vadd = ir.push_with_parents(
            LayerIr::new(0, LayerType::VectorAdd, *out, *out, nv, ne),
            &[lin_self, lin_neigh],
        );
        prev = Some(if i == 0 {
            ir.push_with_parents(
                LayerIr::new(0, LayerType::Activation, *out, *out, nv, ne)
                    .with_act(Activation::Relu),
                &[vadd],
            )
        } else {
            vadd
        });
        f = *out;
    }
    ir
}

/// GIN: h = MLP2((1+eps) h + sum_j h_j), `layers` rounds, then a
/// classifier Linear.
fn gin(name: &str, graph: GraphMeta, hidden: u64, layers: usize) -> ModelIr {
    let (nv, ne) = (graph.n_vertices, graph.n_edges);
    let classes = graph.n_classes;
    let mut ir = ModelIr::new(name, graph);
    let mut prev: Option<u16> = None;
    let mut f = ir.graph.feat_len;
    for _ in 0..layers {
        let parents: &[u16] = match &prev {
            Some(p) => std::slice::from_ref(p),
            None => &[],
        };
        let agg = ir.push_with_parents(
            LayerIr::new(0, LayerType::Aggregate, f, f, nv, ne),
            parents,
        );
        // (1+eps) h + aggregate: VectorAdd of the layer input and the
        // aggregation (eps folded into the add's scale at codegen).
        let vadd = match prev {
            Some(p) => ir.push_with_parents(
                LayerIr::new(0, LayerType::VectorAdd, f, f, nv, ne),
                &[agg, p],
            ),
            None => agg, // first layer: input is the graph itself
        };
        let l1 =
            ir.push_with_parents(LayerIr::new(0, LayerType::Linear, f, hidden, nv, ne), &[vadd]);
        let a1 = ir.push_with_parents(
            LayerIr::new(0, LayerType::Activation, hidden, hidden, nv, ne)
                .with_act(Activation::Relu),
            &[l1],
        );
        let l2 = ir.push_with_parents(
            LayerIr::new(0, LayerType::Linear, hidden, hidden, nv, ne),
            &[a1],
        );
        let a2 = ir.push_with_parents(
            LayerIr::new(0, LayerType::Activation, hidden, hidden, nv, ne)
                .with_act(Activation::Relu),
            &[l2],
        );
        prev = Some(a2);
        f = hidden;
    }
    ir.push_with_parents(
        LayerIr::new(0, LayerType::Linear, f, classes, nv, ne),
        &[prev.unwrap()],
    );
    ir
}

/// GAT (Eq. 4): Linear (W_att) -> Vector-Inner (attention logits) ->
/// edge Activation (exp of LeakyReLU; softmax denominator handled by the
/// following normalized Aggregate) -> Aggregate -> Activation; 2 layers.
fn gat(name: &str, graph: GraphMeta, hidden: u64) -> ModelIr {
    let (nv, ne) = (graph.n_vertices, graph.n_edges);
    let classes = graph.n_classes;
    let mut ir = ModelIr::new(name, graph);
    let mut f = ir.graph.feat_len;
    let mut prev: Option<u16> = None;
    for out in [hidden, classes] {
        let parents: &[u16] = match &prev {
            Some(p) => std::slice::from_ref(p),
            None => &[],
        };
        let lin =
            ir.push_with_parents(LayerIr::new(0, LayerType::Linear, f, out, nv, ne), parents);
        let vinner = ir.push_with_parents(
            LayerIr::new(0, LayerType::VectorInner, out, out, nv, ne),
            &[lin],
        );
        // Edge-score activation: the paper's GAT softmax is exp +
        // per-destination normalization; the normalization is folded into
        // the Aggregate's edge weights at runtime. For the synthetic
        // functional path we use the bounded sigmoid attention variant
        // (same SDDMM -> edge-activation -> weighted-aggregate dataflow,
        // no overflow on unnormalized synthetic features).
        let act_e = ir.push_with_parents(
            LayerIr::new(0, LayerType::Activation, out, out, nv, ne)
                .with_act(Activation::Sigmoid),
            &[vinner],
        );
        let agg = ir.push_with_parents(
            LayerIr::new(0, LayerType::Aggregate, out, out, nv, ne),
            &[act_e],
        );
        prev = Some(ir.push_with_parents(
            LayerIr::new(0, LayerType::Activation, out, out, nv, ne)
                .with_act(Activation::Elu),
            &[agg],
        ));
        f = out;
    }
    ir
}

/// SGC: k Aggregates then one Linear (paper b7, k = 2). The benefit of
/// the computation-order pass: the Linear hoists before both Aggregates.
fn sgc(name: &str, graph: GraphMeta, k: usize) -> ModelIr {
    let (nv, ne) = (graph.n_vertices, graph.n_edges);
    let f0 = graph.feat_len;
    let classes = graph.n_classes;
    let mut ir = ModelIr::new(name, graph);
    for _ in 0..k {
        ir.push(LayerIr::new(0, LayerType::Aggregate, f0, f0, nv, ne));
    }
    ir.push(LayerIr::new(0, LayerType::Linear, f0, classes, nv, ne));
    ir
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> GraphMeta {
        GraphMeta::new("t", 1000, 8000, 500, 7)
    }

    #[test]
    fn all_models_build_and_validate() {
        for m in ALL_MODELS {
            let ir = m.build(meta());
            ir.validate()
                .unwrap_or_else(|e| panic!("{}: {e}", m.key()));
            assert!(ir.n_layers() >= 3, "{}", m.key());
            assert_eq!(ir.layers.last().unwrap().f_out, 7, "{}", m.key());
        }
    }

    #[test]
    fn b1_matches_listing3_structure() {
        let ir = ZooModel::B1.build(meta());
        let kinds: Vec<LayerType> = ir.layers.iter().map(|l| l.ltype).collect();
        assert_eq!(
            kinds,
            vec![
                LayerType::Aggregate,
                LayerType::Linear,
                LayerType::Activation,
                LayerType::Aggregate,
                LayerType::Linear
            ]
        );
        assert_eq!(ir.layers[1].f_out, 16);
    }

    #[test]
    fn b7_is_two_aggregates_then_linear() {
        let ir = ZooModel::B7.build(meta());
        assert_eq!(ir.n_layers(), 3);
        assert_eq!(ir.count(LayerType::Aggregate), 2);
        assert_eq!(ir.layers[2].ltype, LayerType::Linear);
    }

    #[test]
    fn b6_contains_vector_inner() {
        let ir = ZooModel::B6.build(meta());
        assert_eq!(ir.count(LayerType::VectorInner), 2);
    }

    #[test]
    fn b5_depth() {
        let ir = ZooModel::B5.build(meta());
        // 5 GIN rounds x (Agg [+VAdd] + 2x(Lin+Act)) + classifier.
        assert_eq!(ir.count(LayerType::Aggregate), 5);
        assert_eq!(ir.count(LayerType::Linear), 11);
        assert_eq!(ir.count(LayerType::VectorAdd), 4);
    }

    #[test]
    fn sage_uses_mean_aggregation() {
        let ir = ZooModel::B3.build(meta());
        assert!(ir
            .layers
            .iter()
            .filter(|l| l.ltype == LayerType::Aggregate)
            .all(|l| l.aggop == Some(AggOp::Mean)));
    }

    #[test]
    fn zoo_lookup() {
        assert_eq!(zoo_model("b5"), Some(ZooModel::B5));
        assert_eq!(zoo_model("B8"), Some(ZooModel::B8));
        assert!(zoo_model("b9").is_none());
        assert_eq!(model_zoo(meta()).len(), 8);
    }

    #[test]
    fn complexity_ordering_b1_lt_b2() {
        let c1 = ZooModel::B1.build(meta()).total_complexity();
        let c2 = ZooModel::B2.build(meta()).total_complexity();
        assert!(c1 < c2);
    }
}
