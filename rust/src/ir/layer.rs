//! The IR of one computation layer (paper Table 2 / Listing 2).

use crate::isa::{AggOp, Activation};

/// The six computation-layer types (Table 2). Each maps onto one ACK
/// execution mode or the Activation Unit.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum LayerType {
    /// SpDMM mode: h_i = AggOp(A_ji * h_j).
    Aggregate = 0,
    /// GEMM mode: H_out = H_in W.
    Linear = 1,
    /// SDDMM mode: e.weight = `<h_i, h_j>`.
    VectorInner = 2,
    /// VecAdd mode: H_out = H_a + H_b (residuals).
    VectorAdd = 3,
    /// Element-wise activation (fusable into any of the above).
    Activation = 4,
    /// Batch normalization (fusable into Linear).
    BatchNorm = 5,
}

impl LayerType {
    pub fn from_u8(v: u8) -> Option<LayerType> {
        use LayerType::*;
        Some(match v {
            0 => Aggregate,
            1 => Linear,
            2 => VectorInner,
            3 => VectorAdd,
            4 => Activation,
            5 => BatchNorm,
            _ => return None,
        })
    }
}

/// One computation layer (the paper's `LayerIR`, Table 2). `nv`/`ne` are
/// copied from the graph meta data at parse time so every complexity and
/// partitioning decision is local to the node.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerIr {
    pub id: u16,
    pub ltype: LayerType,
    pub parents: Vec<u16>,
    pub children: Vec<u16>,
    /// Input feature dimension f_in.
    pub f_in: u64,
    /// Output feature dimension f_out.
    pub f_out: u64,
    /// |V| of the input graph.
    pub nv: u64,
    /// |E| of the input graph.
    pub ne: u64,
    /// Aggregation operator (Aggregate layers).
    pub aggop: Option<AggOp>,
    /// Activation function (Activation layers, or fused).
    pub act: Activation,
    /// Whether an activation is fused into this layer.
    pub act_enabled: bool,
    /// Whether a BatchNorm has been folded into this Linear layer.
    pub batchnorm_folded: bool,
}

impl LayerIr {
    /// Bare node of a given type; wire parents/children via ModelIr.
    pub fn new(id: u16, ltype: LayerType, f_in: u64, f_out: u64, nv: u64, ne: u64) -> Self {
        LayerIr {
            id,
            ltype,
            parents: Vec::new(),
            children: Vec::new(),
            f_in,
            f_out,
            nv,
            ne,
            aggop: match ltype {
                LayerType::Aggregate => Some(AggOp::Sum),
                _ => None,
            },
            act: Activation::None,
            act_enabled: false,
            batchnorm_folded: false,
        }
    }

    pub fn with_aggop(mut self, op: AggOp) -> Self {
        debug_assert_eq!(self.ltype, LayerType::Aggregate);
        self.aggop = Some(op);
        self
    }

    pub fn with_act(mut self, act: Activation) -> Self {
        self.act = act;
        self.act_enabled = act != Activation::None;
        self
    }

    /// Theoretical computation complexity (paper Eq. 10–11; flop counts
    /// for the other types follow the same 2-flops-per-MAC convention).
    pub fn complexity(&self) -> u64 {
        match self.ltype {
            // Eq. 10: 2 f_in |E| (f_in == f_out).
            LayerType::Aggregate => 2 * self.f_in * self.ne,
            // Eq. 11: 2 f_in f_out |V|.
            LayerType::Linear => 2 * self.f_in * self.f_out * self.nv,
            // One length-f inner product per edge.
            LayerType::VectorInner => 2 * self.f_in * self.ne,
            // One add per feature element.
            LayerType::VectorAdd => self.f_in * self.nv,
            // One activation per element.
            LayerType::Activation => self.f_in * self.nv,
            // Scale + shift per element.
            LayerType::BatchNorm => 2 * self.f_in * self.nv,
        }
    }

    /// Is this layer's aggregation operator linear (Definition 1)?
    pub fn has_linear_aggop(&self) -> bool {
        self.aggop.map(|op| op.is_linear()).unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_type_roundtrip() {
        for v in 0..=5u8 {
            assert_eq!(LayerType::from_u8(v).unwrap() as u8, v);
        }
        assert!(LayerType::from_u8(6).is_none());
    }

    #[test]
    fn complexity_matches_eq10_eq11() {
        let agg = LayerIr::new(1, LayerType::Aggregate, 128, 128, 1000, 5000);
        assert_eq!(agg.complexity(), 2 * 128 * 5000);
        let lin = LayerIr::new(2, LayerType::Linear, 128, 16, 1000, 5000);
        assert_eq!(lin.complexity(), 2 * 128 * 16 * 1000);
    }

    #[test]
    fn aggregate_linearity() {
        let sum = LayerIr::new(1, LayerType::Aggregate, 8, 8, 10, 20);
        assert!(sum.has_linear_aggop());
        let max = sum.clone().with_aggop(AggOp::Max);
        assert!(!max.has_linear_aggop());
        let lin = LayerIr::new(2, LayerType::Linear, 8, 8, 10, 20);
        assert!(!lin.has_linear_aggop());
    }

    #[test]
    fn with_act_sets_enable() {
        let l = LayerIr::new(1, LayerType::Linear, 8, 8, 10, 20)
            .with_act(Activation::Relu);
        assert!(l.act_enabled);
        let n = LayerIr::new(1, LayerType::Linear, 8, 8, 10, 20)
            .with_act(Activation::None);
        assert!(!n.act_enabled);
    }
}
