//! GraphGym design-space models (You et al., NeurIPS'20; paper Sec. 2.1
//! and model b8 of Table 5). A GraphGym instance is: `n_pre` MLP
//! pre-processing layers, `n_mp` message-passing layers (with optional
//! residual connections and BatchNorm), and `n_post` MLP post-processing
//! layers. GraphAGILE supports the whole space; b8 is one point in it.

use super::layer::{LayerIr, LayerType};
use super::model::ModelIr;
use crate::graph::GraphMeta;
use crate::isa::{AggOp, Activation};

/// One point in the GraphGym design space.
#[derive(Clone, Copy, Debug)]
pub struct GraphGymConfig {
    pub n_pre: usize,
    pub n_mp: usize,
    pub n_post: usize,
    pub hidden: u64,
    pub aggop: AggOp,
    pub act: Activation,
    /// Skip-sum residual connections across message-passing layers.
    pub residual: bool,
    /// BatchNorm after each Linear.
    pub batchnorm: bool,
}

impl Default for GraphGymConfig {
    /// The b8 benchmark of Table 5: 1 pre, 3 GNN, 1 post, hidden 256.
    fn default() -> Self {
        GraphGymConfig {
            n_pre: 1,
            n_mp: 3,
            n_post: 1,
            hidden: 256,
            aggop: AggOp::Sum,
            act: Activation::PRelu,
            residual: true,
            batchnorm: true,
        }
    }
}

impl GraphGymConfig {
    /// Build the ModelIr for this configuration over `graph`.
    pub fn build(&self, name: &str, graph: GraphMeta) -> ModelIr {
        let (nv, ne) = (graph.n_vertices, graph.n_edges);
        let h = self.hidden;
        let mut ir = ModelIr::new(name, graph);
        let mut f = ir.graph.feat_len;
        let mut prev: Option<u16> = None;

        let lin = |ir: &mut ModelIr, prev: Option<u16>, f_in: u64, f_out: u64| -> u16 {
            let l = LayerIr::new(0, LayerType::Linear, f_in, f_out, nv, ne);
            match prev {
                Some(p) => ir.push_with_parents(l, &[p]),
                None => ir.push_with_parents(l, &[]),
            }
        };

        // Pre-processing MLP: Linear (+BatchNorm) + Act.
        for _ in 0..self.n_pre {
            let mut id = lin(&mut ir, prev, f, h);
            f = h;
            if self.batchnorm {
                let bn = LayerIr::new(0, LayerType::BatchNorm, f, f, nv, ne);
                id = ir.push_with_parents(bn, &[id]);
            }
            let act = LayerIr::new(0, LayerType::Activation, f, f, nv, ne)
                .with_act(self.act);
            prev = Some(ir.push_with_parents(act, &[id]));
        }

        // Message-passing layers: Aggregate + Linear (+BN) + Act
        // (+ residual VectorAdd from the layer input).
        for _ in 0..self.n_mp {
            let input = prev;
            let agg = LayerIr::new(0, LayerType::Aggregate, f, f, nv, ne)
                .with_aggop(self.aggop);
            let mut id = match input {
                Some(p) => ir.push_with_parents(agg, &[p]),
                None => ir.push_with_parents(agg, &[]),
            };
            id = lin(&mut ir, Some(id), f, h);
            f = h;
            if self.batchnorm {
                let bn = LayerIr::new(0, LayerType::BatchNorm, f, f, nv, ne);
                id = ir.push_with_parents(bn, &[id]);
            }
            let act = LayerIr::new(0, LayerType::Activation, f, f, nv, ne)
                .with_act(self.act);
            id = ir.push_with_parents(act, &[id]);
            if self.residual {
                if let Some(inp) = input {
                    // Skip-sum requires equal widths; pre-processing
                    // guarantees f == hidden from the first MP layer on.
                    if ir.layer(inp).f_out == f {
                        let va = LayerIr::new(0, LayerType::VectorAdd, f, f, nv, ne);
                        id = ir.push_with_parents(va, &[id, inp]);
                    }
                }
            }
            prev = Some(id);
        }

        // Post-processing MLP (last layer maps to classes, no act).
        for i in 0..self.n_post {
            let out = if i + 1 == self.n_post { ir.graph.n_classes } else { h };
            let id = lin(&mut ir, prev, f, out);
            f = out;
            prev = Some(id);
        }
        ir
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> GraphMeta {
        GraphMeta::new("t", 1000, 5000, 64, 10)
    }

    #[test]
    fn b8_default_builds_and_validates() {
        let ir = GraphGymConfig::default().build("b8", meta());
        ir.validate().unwrap();
        // 1 pre (Lin+BN+Act) + 3 mp (Agg+Lin+BN+Act+VAdd) + 1 post (Lin).
        assert_eq!(ir.n_layers(), 3 + 3 * 5 + 1);
        assert_eq!(ir.count(LayerType::Aggregate), 3);
        assert_eq!(ir.count(LayerType::VectorAdd), 3);
        assert_eq!(ir.count(LayerType::BatchNorm), 4);
        // Output width is the class count.
        assert_eq!(ir.layers.last().unwrap().f_out, 10);
    }

    #[test]
    fn no_residual_no_vadd() {
        let cfg = GraphGymConfig { residual: false, ..Default::default() };
        let ir = cfg.build("gg", meta());
        ir.validate().unwrap();
        assert_eq!(ir.count(LayerType::VectorAdd), 0);
    }

    #[test]
    fn no_pre_layer_skips_first_residual() {
        // Without pre-processing the first MP layer changes width
        // (f -> hidden), so its residual is dropped.
        let cfg = GraphGymConfig { n_pre: 0, ..Default::default() };
        let ir = cfg.build("gg", meta());
        ir.validate().unwrap();
        assert_eq!(ir.count(LayerType::VectorAdd), 2);
    }

    #[test]
    fn design_space_sweep_validates() {
        for n_pre in 0..2 {
            for n_mp in 1..4 {
                for residual in [false, true] {
                    for batchnorm in [false, true] {
                        let cfg = GraphGymConfig {
                            n_pre,
                            n_mp,
                            n_post: 1,
                            hidden: 64,
                            residual,
                            batchnorm,
                            ..Default::default()
                        };
                        cfg.build("gg", meta()).validate().unwrap();
                    }
                }
            }
        }
    }

    #[test]
    fn max_aggregation_point() {
        let cfg = GraphGymConfig { aggop: AggOp::Max, ..Default::default() };
        let ir = cfg.build("gg-max", meta());
        ir.validate().unwrap();
        assert!(ir
            .layers
            .iter()
            .filter(|l| l.ltype == LayerType::Aggregate)
            .all(|l| l.aggop == Some(AggOp::Max)));
    }
}
