//! Intermediate representation (paper Sec. 6.1–6.2).
//!
//! A GNN layer decomposes into a DAG of six computation-layer types; the
//! compiler manipulates a [`ModelIr`] — an ordered list of [`LayerIr`]
//! nodes (the paper's `ModelIR` of Listing 2) — through its four
//! optimization passes.

pub mod graphgym;
pub mod layer;
pub mod model;
pub mod zoo;

pub use graphgym::GraphGymConfig;
pub use layer::{LayerIr, LayerType};
pub use model::ModelIr;
pub use zoo::{model_zoo, zoo_model, ZooModel, ALL_MODELS};
