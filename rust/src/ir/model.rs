//! The model-level IR (paper Listing 2's `ModelIR`): an ordered DAG of
//! computation layers plus graph meta data, with structural validation
//! used as an invariant by every compiler pass.

use super::layer::{LayerIr, LayerType};
use crate::graph::GraphMeta;
use std::collections::HashMap;

/// The computation graph of one (GNN model, input graph) instance.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelIr {
    pub name: String,
    pub graph: GraphMeta,
    /// Topologically ordered layers (parents precede children).
    pub layers: Vec<LayerIr>,
}

impl ModelIr {
    pub fn new(name: &str, graph: GraphMeta) -> Self {
        ModelIr { name: name.to_string(), graph, layers: Vec::new() }
    }

    /// Append a layer, chaining it to the previous layer (the common
    /// sequential case; use `add_layer_with_parents` for DAGs).
    pub fn push(&mut self, mut layer: LayerIr) -> u16 {
        let id = (self.layers.len() + 1) as u16;
        layer.id = id;
        if let Some(prev) = self.layers.last_mut() {
            prev.children.push(id);
            layer.parents.push(prev.id);
        }
        self.layers.push(layer);
        id
    }

    /// Append a layer with explicit parent ids (residual connections).
    pub fn push_with_parents(&mut self, mut layer: LayerIr, parents: &[u16]) -> u16 {
        let id = (self.layers.len() + 1) as u16;
        layer.id = id;
        layer.parents = parents.to_vec();
        for &p in parents {
            self.layer_mut(p).children.push(id);
        }
        self.layers.push(layer);
        id
    }

    pub fn layer(&self, id: u16) -> &LayerIr {
        self.layers.iter().find(|l| l.id == id).expect("unknown layer id")
    }

    pub fn layer_mut(&mut self, id: u16) -> &mut LayerIr {
        self.layers.iter_mut().find(|l| l.id == id).expect("unknown layer id")
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Total theoretical complexity (flops) — what the computation-order
    /// pass minimizes (Theorem 2).
    pub fn total_complexity(&self) -> u64 {
        self.layers.iter().map(|l| l.complexity()).sum()
    }

    /// Structural invariants maintained by every pass:
    /// * parent/child references are symmetric and point at real ids,
    /// * layers are topologically ordered,
    /// * feature dimensions agree across every edge of the DAG,
    /// * Aggregate layers preserve width (f_in == f_out, Eq. 5).
    pub fn validate(&self) -> Result<(), String> {
        let by_id: HashMap<u16, &LayerIr> =
            self.layers.iter().map(|l| (l.id, l)).collect();
        if by_id.len() != self.layers.len() {
            return Err("duplicate layer ids".into());
        }
        let mut seen: HashMap<u16, usize> = HashMap::new();
        for (pos, l) in self.layers.iter().enumerate() {
            seen.insert(l.id, pos);
            for &p in &l.parents {
                let parent = by_id.get(&p).ok_or(format!("layer {}: unknown parent {p}", l.id))?;
                if !parent.children.contains(&l.id) {
                    return Err(format!("asymmetric edge {} -> {}", p, l.id));
                }
                if !seen.contains_key(&p) {
                    return Err(format!("layer {} precedes its parent {p}", l.id));
                }
                // Width agreement: a child consumes the parent's output.
                let expect = parent.f_out;
                if l.f_in != expect {
                    return Err(format!(
                        "layer {}: f_in {} != parent {} f_out {expect}",
                        l.id, l.f_in, p
                    ));
                }
            }
            for &c in &l.children {
                let child = by_id.get(&c).ok_or(format!("layer {}: unknown child {c}", l.id))?;
                if !child.parents.contains(&l.id) {
                    return Err(format!("asymmetric edge {} -> {c}", l.id));
                }
            }
            if l.ltype == LayerType::Aggregate && l.f_in != l.f_out {
                return Err(format!("Aggregate layer {} changes width", l.id));
            }
        }
        Ok(())
    }

    /// Count layers of a given type (used by the fusion tests/ablation).
    pub fn count(&self, t: LayerType) -> usize {
        self.layers.iter().filter(|l| l.ltype == t).count()
    }

    /// Model parameter bytes (Linear weights + biases, f32) — part of
    /// the PCIe transfer volume in the E2E metric.
    pub fn weight_bytes(&self) -> u64 {
        self.layers
            .iter()
            .filter(|l| l.ltype == LayerType::Linear)
            .map(|l| (l.f_in * l.f_out + l.f_out) * 4)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Activation;

    fn meta() -> GraphMeta {
        GraphMeta::new("t", 100, 400, 32, 4)
    }

    fn chain() -> ModelIr {
        let mut ir = ModelIr::new("test", meta());
        ir.push(LayerIr::new(0, LayerType::Aggregate, 32, 32, 100, 400));
        ir.push(LayerIr::new(0, LayerType::Linear, 32, 16, 100, 400));
        ir.push(
            LayerIr::new(0, LayerType::Activation, 16, 16, 100, 400)
                .with_act(Activation::Relu),
        );
        ir
    }

    #[test]
    fn chain_validates() {
        let ir = chain();
        ir.validate().unwrap();
        assert_eq!(ir.n_layers(), 3);
        assert_eq!(ir.layer(1).children, vec![2]);
        assert_eq!(ir.layer(2).parents, vec![1]);
    }

    #[test]
    fn residual_dag_validates() {
        let mut ir = ModelIr::new("res", meta());
        let a = ir.push(LayerIr::new(0, LayerType::Linear, 32, 32, 100, 400));
        let b = ir.push(LayerIr::new(0, LayerType::Aggregate, 32, 32, 100, 400));
        let v = LayerIr::new(0, LayerType::VectorAdd, 32, 32, 100, 400);
        ir.push_with_parents(v, &[a, b]);
        ir.validate().unwrap();
        assert_eq!(ir.layer(a).children, vec![b, 3]);
    }

    #[test]
    fn width_mismatch_rejected() {
        let mut ir = chain();
        ir.layer_mut(2).f_in = 64;
        assert!(ir.validate().is_err());
    }

    #[test]
    fn aggregate_width_change_rejected() {
        let mut ir = chain();
        ir.layer_mut(1).f_out = 64;
        assert!(ir.validate().is_err());
    }

    #[test]
    fn complexity_totals() {
        let ir = chain();
        let want = 2 * 32 * 400 + 2 * 32 * 16 * 100 + 16 * 100;
        assert_eq!(ir.total_complexity(), want);
    }

    #[test]
    fn count_by_type() {
        let ir = chain();
        assert_eq!(ir.count(LayerType::Aggregate), 1);
        assert_eq!(ir.count(LayerType::Activation), 1);
        assert_eq!(ir.count(LayerType::BatchNorm), 0);
    }
}
