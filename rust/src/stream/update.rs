//! Update batches and the seeded churn generator.
//!
//! An [`UpdateBatch`] is the unit of graph mutation: edge inserts
//! (with weights), edge deletes (resolved against the previous epoch's
//! live set — a batch can never delete its own inserts), and vertex
//! additions. [`ChurnGenerator`] synthesizes batches deterministically:
//! insert endpoints are drawn from the same R-MAT quadrant walk the
//! dataset stand-ins use (so churn concentrates on the same hub
//! vertices real social/recommendation streams hammer), and deletes
//! pick live in-edges of random vertices so they almost always hit.

use super::delta::DynamicGraph;
use crate::graph::rmat::RmatParams;
use crate::graph::NeighborView;
use crate::util::Rng;

/// One batch of graph mutations, applied atomically as one epoch.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct UpdateBatch {
    /// Edges to insert as `(src, dst, weight)`.
    pub inserts: Vec<(u32, u32, f32)>,
    /// Edges to delete as `(src, dst)`; the first *live* occurrence (in
    /// materialized order) is removed, misses are counted, not errors.
    pub deletes: Vec<(u32, u32)>,
    /// Vertices appended after the current maximum id (isolated until
    /// an insert references them).
    pub new_vertices: u32,
}

impl UpdateBatch {
    pub fn is_empty(&self) -> bool {
        self.inserts.is_empty() && self.deletes.is_empty() && self.new_vertices == 0
    }

    /// Total mutations carried (the modeled apply cost's edge term).
    pub fn changes(&self) -> usize {
        self.inserts.len() + self.deletes.len() + self.new_vertices as usize
    }
}

/// Shape of one generated churn batch.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ChurnSpec {
    pub inserts: u32,
    /// Delete *attempts* (an attempt targeting an already-removed edge
    /// is reported as a miss by the apply).
    pub deletes: u32,
    pub new_vertices: u32,
}

/// Deterministic, R-MAT-skewed churn source over a [`DynamicGraph`].
///
/// Fully determined by `(params, seed)` and the graph states it is
/// shown — replaying the same request sequence regenerates the same
/// batches bit for bit, which is what keeps the serving fleet's
/// update-interleaved traces replayable.
pub struct ChurnGenerator {
    params: RmatParams,
    rng: Rng,
}

impl ChurnGenerator {
    pub fn new(params: RmatParams, seed: u64) -> ChurnGenerator {
        ChurnGenerator { params, rng: Rng::new(seed ^ 0xC4A8_57EA_D000_0001) }
    }

    /// Draw the next batch against the graph's current epoch.
    pub fn next_batch(&mut self, g: &DynamicGraph, spec: ChurnSpec) -> UpdateBatch {
        let nv_cur = g.n_vertices();
        let nv_new = nv_cur + spec.new_vertices as u64;
        let (src, dst) = if spec.inserts > 0 && nv_new > 0 {
            self.params.sample_edges(&mut self.rng, nv_new, spec.inserts as usize)
        } else {
            (Vec::new(), Vec::new())
        };
        let inserts: Vec<(u32, u32, f32)> = src
            .iter()
            .zip(&dst)
            .map(|(&s, &d)| (s, d, 0.5 + self.rng.f32()))
            .collect();
        let view = g.view();
        let mut deletes = Vec::with_capacity(spec.deletes as usize);
        let mut row: Vec<(u32, f32)> = Vec::new();
        for _ in 0..spec.deletes {
            if nv_cur == 0 {
                break;
            }
            let v = self.rng.below(nv_cur) as u32;
            row.clear();
            view.in_edges(v, &mut row);
            if row.is_empty() {
                continue;
            }
            let k = self.rng.below(row.len() as u64) as usize;
            deletes.push((row[k].0, v));
        }
        UpdateBatch { inserts, deletes, new_vertices: spec.new_vertices }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::rmat::rmat_edges;
    use crate::graph::{GraphMeta, PartitionConfig};

    fn dyn_graph(seed: u64) -> DynamicGraph {
        let g = rmat_edges(
            GraphMeta::new("t", 300, 2400, 8, 2),
            RmatParams::default(),
            seed,
        );
        DynamicGraph::new(g, PartitionConfig { n1: 64, n2: 8 })
    }

    #[test]
    fn generator_is_deterministic_and_in_range() {
        let g = dyn_graph(3);
        let spec = ChurnSpec { inserts: 50, deletes: 20, new_vertices: 4 };
        let a = ChurnGenerator::new(RmatParams::default(), 7).next_batch(&g, spec);
        let b = ChurnGenerator::new(RmatParams::default(), 7).next_batch(&g, spec);
        assert_eq!(a, b);
        assert_eq!(a.inserts.len(), 50);
        assert!(a.inserts.iter().all(|&(s, d, w)| {
            (s as u64) < 304 && (d as u64) < 304 && (0.5..1.5).contains(&w)
        }));
        assert!(!a.deletes.is_empty(), "a 2400-edge graph must yield deletes");
        let c = ChurnGenerator::new(RmatParams::default(), 8).next_batch(&g, spec);
        assert_ne!(a, c, "seed must matter");
    }

    #[test]
    fn generated_deletes_mostly_hit() {
        let mut g = dyn_graph(5);
        let spec = ChurnSpec { inserts: 24, deletes: 24, new_vertices: 0 };
        let mut gen = ChurnGenerator::new(RmatParams::default(), 11);
        let mut deleted = 0;
        let mut attempted = 0;
        for _ in 0..4 {
            let batch = gen.next_batch(&g, spec);
            attempted += batch.deletes.len() as u32;
            let r = g.apply(&batch);
            deleted += r.deleted;
            assert_eq!(r.deleted + r.missed_deletes, batch.deletes.len() as u32);
        }
        // Deletes are drawn from live rows: only same-batch duplicate
        // draws can miss.
        assert!(
            deleted * 10 >= attempted * 8,
            "only {deleted}/{attempted} deletes hit"
        );
    }

    #[test]
    fn batch_helpers() {
        assert!(UpdateBatch::default().is_empty());
        let b = UpdateBatch {
            inserts: vec![(0, 1, 1.0)],
            deletes: vec![(2, 3)],
            new_vertices: 2,
        };
        assert!(!b.is_empty());
        assert_eq!(b.changes(), 4);
    }
}
