//! Streaming graph updates: delta overlay, epoch snapshots, and
//! incremental recompilation.
//!
//! Every other execution path in the crate assumes the input graph is
//! frozen at load time. This subsystem makes the graph a *stream*: edge
//! inserts/deletes and vertex additions arrive in [`UpdateBatch`]es
//! (synthesized R-MAT-skewed by [`ChurnGenerator`], matching the
//! degree skew of the Table-4 stand-ins), and a [`DynamicGraph`]
//! absorbs them between inference requests.
//!
//! Three ideas carry the design:
//!
//! * **Delta overlay** — the base graph stays immutable (its whole-graph
//!   destination-row CSR keeps serving samplers); churn lands in an
//!   append-only overlay (inserts) plus tombstones (deletes). When the
//!   overlay plus tombstones exceed [`StreamConfig::compact_ratio`] of
//!   the live edge count, compaction folds everything back into a fresh
//!   base CSR.
//! * **Epoch snapshots** — every applied batch seals a new epoch.
//!   Edges carry insertion/deletion epoch stamps, so
//!   [`DynamicGraph::view_at`] / [`DynamicGraph::materialize`]
//!   reconstruct any retained epoch bit-exactly: an in-flight request
//!   always reads the consistent epoch current at its arrival, never a
//!   half-applied batch. Compaction rebases the retained window to the
//!   current epoch.
//! * **Incremental recompilation** — applying a batch marks only the
//!   *dirty* Fiber-Shard subshards (the tiles churned edges land in,
//!   plus the shard row whose height a vertex addition changed).
//!   Only those tiles' [`crate::graph::CsrSubshard`]s are rebuilt and
//!   only their densities re-profiled
//!   ([`crate::sparsity::DensityTracker`]), instead of re-running the
//!   full O(|E|) partition pass — and the result is *bit-identical* to
//!   a from-scratch [`crate::graph::PartitionedGraph::build`] at the
//!   same epoch (pinned across the model zoo in
//!   `rust/tests/streaming.rs`).
//!
//! The serving fleet integrates through
//! [`crate::serve::Target::Update`]: update requests interleave with
//! inference on the virtual clock (modeled apply cost from
//! [`crate::serve::clock::CostModel::update_cost`]), whole-graph cache
//! keys become epoch-versioned with selective invalidation, and bucket
//! executables — shape-only — survive epochs untouched.
//! [`crate::engine::StreamingSession`] is the functional counterpart:
//! apply → incremental repartition → compile-at-epoch → run.

pub mod delta;
pub mod update;

pub use delta::{ApplyReport, DynamicGraph, EpochView, StreamConfig};
pub use update::{ChurnGenerator, ChurnSpec, UpdateBatch};
