//! The delta-overlay dynamic graph: epoch-versioned snapshots over an
//! immutable base plus an append-only overlay, with incremental
//! (dirty-subshard-only) maintenance of the Fiber-Shard partition.
//!
//! Invariants the implementation leans on (and the tests pin):
//!
//! * **Materialized order** — [`DynamicGraph::materialize`] emits live
//!   base edges in base order, then live overlay edges in insertion
//!   order. Each tile stores its edges as the *subsequence* of that
//!   order landing in the tile (base edges were counting-sorted
//!   stably at build; inserts only append; deletes remove in place),
//!   so rebuilding a dirty tile's CSR from the tile store produces
//!   exactly what a from-scratch
//!   [`PartitionedGraph::build`](crate::graph::PartitionedGraph::build)
//!   of the materialized graph would — bit for bit, including float
//!   summation order in the aggregation kernels.
//! * **Epoch stamps** — a base edge is live at epoch `E` while its
//!   deletion stamp exceeds `E`; an overlay edge additionally needs its
//!   insertion stamp `<= E`. Stamps are never rewritten (compaction
//!   aside), so a sealed epoch's view can never change underneath an
//!   in-flight reader.
//! * **Dirty accounting** — the `(old edge count, old cell area)` of a
//!   tile is captured at its *first* modification in a batch, so the
//!   density tracker's incremental re-profile agrees exactly with a
//!   full re-scan.

use super::update::UpdateBatch;
use crate::graph::sample::{sample_view, EgoNet, NeighborView};
use crate::graph::{CooGraph, CsrSubshard, GraphMeta, PartitionConfig, PartitionedGraph, TileCounts};
use crate::sparsity::DensityTracker;
use std::collections::{BTreeMap, HashMap};

/// Deletion-epoch sentinel: the edge has not been deleted.
const LIVE: u32 = u32::MAX;

/// Tuning knobs of the dynamic graph.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StreamConfig {
    /// When `(overlay entries + base tombstones) / live edges` exceeds
    /// this, [`DynamicGraph::apply`] compacts: the overlay folds back
    /// into a fresh base CSR and the retained epoch window rebases to
    /// the current epoch.
    pub compact_ratio: f64,
}

impl Default for StreamConfig {
    fn default() -> StreamConfig {
        StreamConfig { compact_ratio: 0.25 }
    }
}

/// What one [`DynamicGraph::apply`] did — the incremental-recompilation
/// receipt the serving fleet turns into modeled apply cost and
/// telemetry.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ApplyReport {
    /// The epoch this batch sealed.
    pub epoch: u32,
    pub inserted: u32,
    /// Deletes that hit a live edge.
    pub deleted: u32,
    /// Deletes that found no live edge (already gone, or never existed).
    pub missed_deletes: u32,
    pub new_vertices: u32,
    /// Subshards whose CSR was rebuilt.
    pub dirty_subshards: u32,
    /// Subshards in the (possibly grown) grid.
    pub total_subshards: u32,
    /// Edges re-sorted while rebuilding dirty subshards — the work an
    /// incremental apply pays where a full rebuild pays O(|E|).
    pub rebuilt_edges: u64,
    /// Live edges after the batch.
    pub live_edges: u64,
    /// Whether this apply triggered an overlay compaction.
    pub compacted: bool,
    /// Adjacency density over non-empty subshards after the batch
    /// (incrementally re-profiled; feeds the next epoch-compile's GA02
    /// threshold table).
    pub adj_density: f32,
}

/// One subshard's live edges (global vertex ids), kept in
/// materialized-subsequence order.
#[derive(Clone, Debug, Default)]
struct TileStore {
    src: Vec<u32>,
    dst: Vec<u32>,
    w: Vec<f32>,
}

/// Where a live edge was found by the delete path.
enum EdgeRef {
    Base(usize),
    Overlay(usize),
}

/// Cell area of tile `(i, j)` under an `nv`-vertex, `shards`-wide grid
/// (0 for tiles outside the grid — they held no edges).
fn cells_at(nv: u64, shards: usize, n1: u64, i: usize, j: usize) -> u64 {
    if i >= shards || j >= shards {
        return 0;
    }
    (nv - i as u64 * n1).min(n1) * (nv - j as u64 * n1).min(n1)
}

/// A mutable graph layering a delta overlay on an immutable base, with
/// epoch-versioned snapshots and an incrementally maintained
/// Fiber-Shard partition (see the module docs).
pub struct DynamicGraph {
    cfg: PartitionConfig,
    scfg: StreamConfig,
    /// Metadata of the *current* epoch (name/features/classes fixed;
    /// vertex and edge counts track the stream).
    meta: GraphMeta,
    epoch: u32,
    /// Oldest retained epoch (advanced by compaction).
    base_epoch: u32,

    // --- base snapshot: the sampling substrate -----------------------
    base_src: Vec<u32>,
    base_dst: Vec<u32>,
    base_w: Vec<f32>,
    /// Deletion epoch per base edge ([`LIVE`] = live).
    base_del: Vec<u32>,
    /// Whole-graph destination-row CSR over the base arrays.
    base_csr: CsrSubshard,
    /// Vertex count the base CSR was built for.
    base_nv: u64,

    // --- delta overlay ----------------------------------------------
    ov_src: Vec<u32>,
    ov_dst: Vec<u32>,
    ov_w: Vec<f32>,
    /// Insertion epoch per overlay edge.
    ov_ins: Vec<u32>,
    /// Deletion epoch per overlay edge ([`LIVE`] = live).
    ov_del: Vec<u32>,
    /// Overlay edge ids per destination vertex (insertion order).
    ov_by_dst: HashMap<u32, Vec<u32>>,
    live_base: u64,
    live_overlay: u64,
    /// `(first epoch, vertex count)` marks for epoch-consistent views
    /// across vertex additions.
    nv_marks: Vec<(u32, u64)>,

    // --- current-epoch partition state ------------------------------
    shards: usize,
    tiles: Vec<TileStore>,
    /// Destination-row CSR per tile (rebuilt only when dirty).
    csr: Vec<CsrSubshard>,
    /// Edge count per tile (the live [`TileCounts`]).
    counts: Vec<u64>,
    density: DensityTracker,
    /// Compactions performed over the graph's lifetime.
    pub compactions: u64,
}

impl DynamicGraph {
    /// Wrap `g` as epoch 0 of a stream, partitioned with `cfg`.
    pub fn new(g: CooGraph, cfg: PartitionConfig) -> DynamicGraph {
        DynamicGraph::with_config(g, cfg, StreamConfig::default())
    }

    pub fn with_config(g: CooGraph, cfg: PartitionConfig, scfg: StreamConfig) -> DynamicGraph {
        let pg = PartitionedGraph::build(&g, cfg);
        let shards = pg.shards;
        let mut tiles = Vec::with_capacity(shards * shards);
        let mut counts = Vec::with_capacity(shards * shards);
        for t in 0..shards * shards {
            let r = pg.offsets[t]..pg.offsets[t + 1];
            counts.push(r.len() as u64);
            tiles.push(TileStore {
                src: pg.src[r.clone()].to_vec(),
                dst: pg.dst[r.clone()].to_vec(),
                w: pg.w[r].to_vec(),
            });
        }
        let tc = TileCounts { n1: cfg.n1, shards, counts: counts.clone() };
        let density = DensityTracker::from_tiles(&tc, g.meta.n_vertices);
        let base_csr =
            CsrSubshard::from_local_coo(g.dst.iter().copied(), g.src.iter().copied(), g.n());
        let CooGraph { meta, src, dst, w } = g;
        let m = src.len();
        DynamicGraph {
            cfg,
            scfg,
            base_nv: meta.n_vertices,
            nv_marks: vec![(0, meta.n_vertices)],
            meta,
            epoch: 0,
            base_epoch: 0,
            base_src: src,
            base_dst: dst,
            base_w: w,
            base_del: vec![LIVE; m],
            base_csr,
            ov_src: Vec::new(),
            ov_dst: Vec::new(),
            ov_w: Vec::new(),
            ov_ins: Vec::new(),
            ov_del: Vec::new(),
            ov_by_dst: HashMap::new(),
            live_base: m as u64,
            live_overlay: 0,
            shards,
            tiles,
            csr: pg.csr,
            counts,
            density,
            compactions: 0,
        }
    }

    pub fn epoch(&self) -> u32 {
        self.epoch
    }

    /// Oldest epoch still reconstructible (compaction advances it).
    pub fn base_epoch(&self) -> u32 {
        self.base_epoch
    }

    /// Current-epoch metadata.
    pub fn meta(&self) -> &GraphMeta {
        &self.meta
    }

    pub fn n_vertices(&self) -> u64 {
        self.meta.n_vertices
    }

    /// Live edges at the current epoch.
    pub fn n_edges(&self) -> u64 {
        self.meta.n_edges
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Incrementally profiled adjacency density over non-empty
    /// subshards at the current epoch.
    pub fn adj_density(&self) -> f32 {
        self.density.density()
    }

    /// `(overlay entries + base tombstones) / live edges` — the
    /// compaction trigger quantity.
    pub fn overlay_ratio(&self) -> f64 {
        let overhead = self.ov_src.len() as u64 + (self.base_src.len() as u64 - self.live_base);
        overhead as f64 / self.meta.n_edges.max(1) as f64
    }

    /// Live per-subshard edge counts of the current epoch — what an
    /// epoch-compile feeds the compiler (and the GA02 profiler).
    pub fn tile_counts(&self) -> TileCounts {
        TileCounts { n1: self.cfg.n1, shards: self.shards, counts: self.counts.clone() }
    }

    fn tile_of(&self, s: u32, d: u32) -> usize {
        (d as u64 / self.cfg.n1) as usize * self.shards + (s as u64 / self.cfg.n1) as usize
    }

    /// Vertex count at `epoch`.
    fn nv_at(&self, epoch: u32) -> u64 {
        self.nv_marks
            .iter()
            .rev()
            .find(|(e, _)| *e <= epoch)
            .map(|&(_, nv)| nv)
            .expect("epoch below the retained window")
    }

    /// Apply one update batch, sealing a new epoch. Deletes are
    /// resolved against the *previous* epoch (a batch cannot delete its
    /// own inserts), then inserts append. Only the dirty subshards are
    /// re-sorted and re-profiled; everything else is untouched.
    pub fn apply(&mut self, batch: &UpdateBatch) -> ApplyReport {
        let new_epoch = self.epoch + 1;
        let n1 = self.cfg.n1;
        let old_nv = self.meta.n_vertices;
        let old_shards = self.shards;
        // tile -> (edge count, cell area) before this batch touched it.
        let mut dirty: BTreeMap<usize, (u64, u64)> = BTreeMap::new();

        // 1. Vertex additions (grid growth + last-shard-row resize).
        let new_nv = old_nv + batch.new_vertices as u64;
        if batch.new_vertices > 0 {
            let new_shards = new_nv.div_ceil(n1) as usize;
            if new_shards != old_shards {
                let old_tiles = std::mem::take(&mut self.tiles);
                let old_counts = std::mem::take(&mut self.counts);
                let old_csr = std::mem::take(&mut self.csr);
                let mut tiles: Vec<TileStore> =
                    (0..new_shards * new_shards).map(|_| TileStore::default()).collect();
                let mut counts = vec![0u64; new_shards * new_shards];
                let mut csr = Vec::with_capacity(new_shards * new_shards);
                for i in 0..new_shards {
                    let rows = ((new_nv - i as u64 * n1).min(n1)) as usize;
                    for _ in 0..new_shards {
                        csr.push(CsrSubshard {
                            rows: rows as u32,
                            row_offsets: vec![0u32; rows + 1],
                            cols: Vec::new(),
                            perm: Vec::new(),
                        });
                    }
                }
                for (old_t, store) in old_tiles.into_iter().enumerate() {
                    let (i, j) = (old_t / old_shards, old_t % old_shards);
                    tiles[i * new_shards + j] = store;
                }
                for (old_t, c) in old_counts.into_iter().enumerate() {
                    let (i, j) = (old_t / old_shards, old_t % old_shards);
                    counts[i * new_shards + j] = c;
                }
                for (old_t, c) in old_csr.into_iter().enumerate() {
                    let (i, j) = (old_t / old_shards, old_t % old_shards);
                    csr[i * new_shards + j] = c;
                }
                self.tiles = tiles;
                self.counts = counts;
                self.csr = csr;
                self.shards = new_shards;
            }
            // The shard containing the old vertex boundary gains rows:
            // its whole row of subshards needs resized CSR offsets.
            let last_old = ((old_nv - 1) / n1) as usize;
            let rows_before = (old_nv - last_old as u64 * n1).min(n1);
            let rows_after = (new_nv - last_old as u64 * n1).min(n1);
            if rows_after != rows_before {
                for j in 0..self.shards {
                    let t = last_old * self.shards + j;
                    dirty
                        .entry(t)
                        .or_insert((self.counts[t], cells_at(old_nv, old_shards, n1, last_old, j)));
                }
            }
            self.nv_marks.push((new_epoch, new_nv));
            self.meta.n_vertices = new_nv;
        }

        // 2. Deletes (against the previous epoch's live set).
        let mut deleted = 0u32;
        let mut missed = 0u32;
        for &(s, d) in &batch.deletes {
            if s as u64 >= new_nv || d as u64 >= new_nv {
                missed += 1;
                continue;
            }
            match self.find_live(s, d) {
                None => missed += 1,
                Some(EdgeRef::Base(e)) => {
                    self.base_del[e] = new_epoch;
                    self.live_base -= 1;
                    self.remove_from_tile(s, d, &mut dirty, old_nv, old_shards);
                    deleted += 1;
                }
                Some(EdgeRef::Overlay(e)) => {
                    self.ov_del[e] = new_epoch;
                    self.live_overlay -= 1;
                    self.remove_from_tile(s, d, &mut dirty, old_nv, old_shards);
                    deleted += 1;
                }
            }
        }

        // 3. Inserts (appended to the overlay and their tiles).
        for &(s, d, w) in &batch.inserts {
            assert!(
                (s as u64) < new_nv && (d as u64) < new_nv,
                "insert ({s}->{d}) out of range (|V| = {new_nv})"
            );
            let ei = self.ov_src.len() as u32;
            self.ov_src.push(s);
            self.ov_dst.push(d);
            self.ov_w.push(w);
            self.ov_ins.push(new_epoch);
            self.ov_del.push(LIVE);
            self.ov_by_dst.entry(d).or_default().push(ei);
            self.live_overlay += 1;
            let t = self.tile_of(s, d);
            let (i, j) = (t / self.shards, t % self.shards);
            dirty
                .entry(t)
                .or_insert((self.counts[t], cells_at(old_nv, old_shards, n1, i, j)));
            let st = &mut self.tiles[t];
            st.src.push(s);
            st.dst.push(d);
            st.w.push(w);
            self.counts[t] += 1;
        }

        // 4. Rebuild only the dirty subshards' CSRs.
        let mut rebuilt_edges = 0u64;
        for &t in dirty.keys() {
            let (i, j) = (t / self.shards, t % self.shards);
            let rows = ((new_nv - i as u64 * n1).min(n1)) as usize;
            let row_base = (i as u64 * n1) as u32;
            let col_base = (j as u64 * n1) as u32;
            let store = &self.tiles[t];
            rebuilt_edges += store.src.len() as u64;
            self.csr[t] = CsrSubshard::from_local_coo(
                store.dst.iter().map(move |&d| d - row_base),
                store.src.iter().map(move |&s| s - col_base),
                rows,
            );
        }

        // 5. Re-profile: dirty tiles only (vertex growth changes many
        // tile areas at once, so it re-syncs with a full scan).
        if batch.new_vertices > 0 {
            let tc = TileCounts { n1, shards: self.shards, counts: self.counts.clone() };
            self.density = DensityTracker::from_tiles(&tc, new_nv);
        } else {
            for (&t, &(old_ne, old_cells)) in &dirty {
                let (i, j) = (t / self.shards, t % self.shards);
                let new_cells = cells_at(new_nv, self.shards, n1, i, j);
                self.density.retile(old_ne, old_cells, self.counts[t], new_cells);
            }
        }

        // 6. Seal the epoch; compact when the overlay outgrew its ratio.
        self.epoch = new_epoch;
        self.meta.n_edges = self.live_base + self.live_overlay;
        let mut compacted = false;
        if self.overlay_ratio() > self.scfg.compact_ratio {
            self.compact();
            compacted = true;
        }
        ApplyReport {
            epoch: new_epoch,
            inserted: batch.inserts.len() as u32,
            deleted,
            missed_deletes: missed,
            new_vertices: batch.new_vertices,
            dirty_subshards: dirty.len() as u32,
            total_subshards: (self.shards * self.shards) as u32,
            rebuilt_edges,
            live_edges: self.meta.n_edges,
            compacted,
            adj_density: self.density.density(),
        }
    }

    /// First live edge `(s, d)` in materialized order (base slot order,
    /// then overlay insertion order) — the same edge a scan of the
    /// tile store would find first.
    fn find_live(&self, s: u32, d: u32) -> Option<EdgeRef> {
        if (d as u64) < self.base_nv {
            for slot in self.base_csr.row(d as usize) {
                if self.base_csr.cols[slot] == s {
                    let e = self.base_csr.perm[slot] as usize;
                    if self.base_del[e] == LIVE {
                        return Some(EdgeRef::Base(e));
                    }
                }
            }
        }
        if let Some(list) = self.ov_by_dst.get(&d) {
            for &ei in list {
                let e = ei as usize;
                if self.ov_src[e] == s && self.ov_del[e] == LIVE {
                    return Some(EdgeRef::Overlay(e));
                }
            }
        }
        None
    }

    /// Remove the first `(s, d)` occurrence from its tile store,
    /// preserving order (the materialized-subsequence invariant).
    fn remove_from_tile(
        &mut self,
        s: u32,
        d: u32,
        dirty: &mut BTreeMap<usize, (u64, u64)>,
        old_nv: u64,
        old_shards: usize,
    ) {
        let t = self.tile_of(s, d);
        let (i, j) = (t / self.shards, t % self.shards);
        dirty
            .entry(t)
            .or_insert((self.counts[t], cells_at(old_nv, old_shards, self.cfg.n1, i, j)));
        let st = &mut self.tiles[t];
        let pos = st
            .src
            .iter()
            .zip(&st.dst)
            .position(|(&a, &b)| a == s && b == d)
            .expect("deleted edge must be present in its tile");
        st.src.remove(pos);
        st.dst.remove(pos);
        st.w.remove(pos);
        self.counts[t] -= 1;
    }

    /// Fold the overlay back into a fresh base: the current epoch's
    /// materialized edges become the new base arrays and whole-graph
    /// CSR, tombstones and overlay clear, and the retained epoch window
    /// rebases to the current epoch. Tile stores and per-tile CSRs are
    /// untouched — they always reflect the current epoch.
    fn compact(&mut self) {
        let g = self.materialize(self.epoch);
        self.base_nv = g.meta.n_vertices;
        self.base_csr =
            CsrSubshard::from_local_coo(g.dst.iter().copied(), g.src.iter().copied(), g.n());
        let m = g.m();
        let CooGraph { src, dst, w, .. } = g;
        self.base_src = src;
        self.base_dst = dst;
        self.base_w = w;
        self.base_del = vec![LIVE; m];
        self.live_base = m as u64;
        self.ov_src.clear();
        self.ov_dst.clear();
        self.ov_w.clear();
        self.ov_ins.clear();
        self.ov_del.clear();
        self.ov_by_dst.clear();
        self.live_overlay = 0;
        self.base_epoch = self.epoch;
        self.nv_marks = vec![(self.epoch, self.meta.n_vertices)];
        self.compactions += 1;
    }

    /// Reconstruct the COO graph of a retained `epoch` (live base edges
    /// in base order, then live overlay edges in insertion order).
    ///
    /// Panics when `epoch` falls outside `[base_epoch, epoch]` — those
    /// snapshots were folded away by compaction.
    pub fn materialize(&self, epoch: u32) -> CooGraph {
        assert!(
            epoch >= self.base_epoch && epoch <= self.epoch,
            "epoch {epoch} outside the retained window [{}, {}]",
            self.base_epoch,
            self.epoch
        );
        let mut src = Vec::new();
        let mut dst = Vec::new();
        let mut w = Vec::new();
        for e in 0..self.base_src.len() {
            if self.base_del[e] > epoch {
                src.push(self.base_src[e]);
                dst.push(self.base_dst[e]);
                w.push(self.base_w[e]);
            }
        }
        for e in 0..self.ov_src.len() {
            if self.ov_ins[e] <= epoch && self.ov_del[e] > epoch {
                src.push(self.ov_src[e]);
                dst.push(self.ov_dst[e]);
                w.push(self.ov_w[e]);
            }
        }
        let meta = GraphMeta::new(
            &self.meta.name,
            self.nv_at(epoch),
            src.len() as u64,
            self.meta.feat_len,
            self.meta.n_classes,
        );
        CooGraph::new(meta, src, dst, w)
    }

    /// Assemble the current epoch's [`PartitionedGraph`] from the
    /// incrementally maintained tile stores and CSRs — bit-identical to
    /// `PartitionedGraph::build(&self.materialize(self.epoch()), cfg)`
    /// without re-sorting any clean tile.
    pub fn export_partitioned(&self) -> PartitionedGraph {
        let tiles_n = self.shards * self.shards;
        let m = self.counts.iter().sum::<u64>() as usize;
        let mut offsets = Vec::with_capacity(tiles_n + 1);
        offsets.push(0usize);
        let mut src = Vec::with_capacity(m);
        let mut dst = Vec::with_capacity(m);
        let mut w = Vec::with_capacity(m);
        for st in &self.tiles {
            src.extend_from_slice(&st.src);
            dst.extend_from_slice(&st.dst);
            w.extend_from_slice(&st.w);
            offsets.push(src.len());
        }
        PartitionedGraph {
            cfg: self.cfg,
            n_vertices: self.meta.n_vertices,
            shards: self.shards,
            offsets,
            src,
            dst,
            w,
            csr: self.csr.clone(),
        }
    }

    /// Neighbor view of a retained `epoch` (sampling substrate).
    pub fn view_at(&self, epoch: u32) -> EpochView<'_> {
        assert!(
            epoch >= self.base_epoch && epoch <= self.epoch,
            "epoch {epoch} outside the retained window [{}, {}]",
            self.base_epoch,
            self.epoch
        );
        EpochView { g: self, epoch }
    }

    /// Neighbor view of the current epoch.
    pub fn view(&self) -> EpochView<'_> {
        self.view_at(self.epoch)
    }

    /// Sample a k-hop ego-network at the current epoch through the
    /// base-CSR + overlay merge — same algorithm and determinism
    /// contract as the static [`crate::graph::Sampler`].
    pub fn sample(&self, targets: &[u32], fanout: &[u32], seed: u64) -> EgoNet {
        sample_view(&self.view(), targets, fanout, seed)
    }

    /// [`DynamicGraph::sample`] against a retained past epoch.
    pub fn sample_at(&self, epoch: u32, targets: &[u32], fanout: &[u32], seed: u64) -> EgoNet {
        sample_view(&self.view_at(epoch), targets, fanout, seed)
    }
}

/// A consistent read of one retained epoch: in-edges merge the base CSR
/// (minus tombstones at or before the epoch) with the overlay inserts
/// stamped at or before it.
pub struct EpochView<'a> {
    g: &'a DynamicGraph,
    epoch: u32,
}

impl EpochView<'_> {
    pub fn epoch(&self) -> u32 {
        self.epoch
    }
}

impl NeighborView for EpochView<'_> {
    fn n_vertices(&self) -> u64 {
        self.g.nv_at(self.epoch)
    }

    fn feat_len(&self) -> u64 {
        self.g.meta.feat_len
    }

    fn n_classes(&self) -> u64 {
        self.g.meta.n_classes
    }

    fn in_edges(&self, v: u32, out: &mut Vec<(u32, f32)>) {
        let g = self.g;
        if (v as u64) < g.base_nv {
            for slot in g.base_csr.row(v as usize) {
                let e = g.base_csr.perm[slot] as usize;
                if g.base_del[e] > self.epoch {
                    out.push((g.base_csr.cols[slot], g.base_w[e]));
                }
            }
        }
        if let Some(list) = g.ov_by_dst.get(&v) {
            for &ei in list {
                let e = ei as usize;
                if g.ov_ins[e] <= self.epoch && g.ov_del[e] > self.epoch {
                    out.push((g.ov_src[e], g.ov_w[e]));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::rmat::{rmat_edges, RmatParams};
    use crate::graph::Sampler;

    fn graph(n: u64, m: u64, seed: u64) -> CooGraph {
        rmat_edges(GraphMeta::new("t", n, m, 8, 2), RmatParams::default(), seed)
    }

    fn cfg(n1: u64) -> PartitionConfig {
        PartitionConfig { n1, n2: 8 }
    }

    /// Reference check: incremental state == from-scratch build of the
    /// materialized current epoch, plus live TileCounts agreement.
    fn assert_matches_scratch(d: &DynamicGraph) {
        let g = d.materialize(d.epoch());
        let scratch = PartitionedGraph::build(&g, d.cfg);
        let inc = d.export_partitioned();
        assert_eq!(inc, scratch, "incremental partition diverged from scratch");
        assert_eq!(d.tile_counts(), TileCounts::from_coo(&g, d.cfg.n1));
        assert_eq!(d.n_edges(), g.meta.n_edges);
    }

    #[test]
    fn epoch0_matches_static_paths() {
        let g = graph(300, 2000, 5);
        let d = DynamicGraph::new(g.clone(), cfg(64));
        assert_eq!(d.epoch(), 0);
        assert_matches_scratch(&d);
        // Epoch-0 sampling == the static Sampler, bit for bit.
        let s = Sampler::new(g);
        let a = d.sample(&[3, 77], &[4, 2], 9);
        let b = s.sample(&[3, 77], &[4, 2], 9);
        assert_eq!(a.origin, b.origin);
        assert_eq!(a.graph.src, b.graph.src);
        assert_eq!(a.graph.dst, b.graph.dst);
        assert_eq!(a.graph.w, b.graph.w);
    }

    #[test]
    fn inserts_deletes_and_dirty_accounting() {
        let g = graph(400, 3000, 7);
        let mut d = DynamicGraph::new(g, cfg(64));
        let total = (d.shards() * d.shards()) as u32;
        let batch = UpdateBatch {
            inserts: vec![(1, 2, 0.5), (1, 2, 0.5), (300, 9, 1.5)],
            deletes: vec![(1, 2)],
            new_vertices: 0,
        };
        let r = d.apply(&batch);
        assert_eq!(r.epoch, 1);
        assert_eq!(r.inserted, 3);
        // The delete resolves against epoch 0 (never this batch's own
        // inserts): it hits iff (1, 2) existed in the base graph.
        assert_eq!(r.deleted + r.missed_deletes, 1);
        assert!(r.dirty_subshards >= 1 && r.dirty_subshards < total);
        assert_eq!(r.total_subshards, total);
        assert_matches_scratch(&d);
        // Density re-profile matches a full scan.
        assert_eq!(
            r.adj_density,
            crate::sparsity::adjacency_density(&d.tile_counts(), d.n_vertices())
        );
        // The inserted duplicate edge now appears in vertex 2's row.
        let mut row = Vec::new();
        d.view().in_edges(2, &mut row);
        let dup = row.iter().filter(|&&(s, _)| s == 1).count();
        assert!(dup >= 2, "inserted duplicates missing ({dup})");
    }

    #[test]
    fn deleting_an_inserted_edge_in_a_later_batch() {
        let g = graph(200, 1000, 3);
        let mut d = DynamicGraph::new(g, cfg(64));
        d.apply(&UpdateBatch {
            inserts: vec![(10, 20, 2.0)],
            deletes: vec![],
            new_vertices: 0,
        });
        let mut row = Vec::new();
        d.view().in_edges(20, &mut row);
        let live = row.iter().filter(|&&(s, w)| s == 10 && w == 2.0).count();
        assert_eq!(live, 1);
        let r = d.apply(&UpdateBatch {
            inserts: vec![],
            deletes: vec![(10, 20)],
            new_vertices: 0,
        });
        assert_eq!(r.deleted, 1);
        row.clear();
        d.view().in_edges(20, &mut row);
        assert!(!row.iter().any(|&(s, w)| s == 10 && w == 2.0));
        assert_matches_scratch(&d);
    }

    #[test]
    fn epoch_snapshots_are_immutable() {
        let g = graph(300, 2000, 11);
        let mut d = DynamicGraph::new(g, cfg(64));
        let snap0 = d.materialize(0);
        let ego0 = d.sample_at(0, &[5, 9], &[6, 3], 2);
        d.apply(&UpdateBatch {
            inserts: vec![(5, 9, 1.0), (9, 5, 1.0)],
            deletes: vec![(snap0.src[0], snap0.dst[0])],
            new_vertices: 0,
        });
        // The sealed epoch still reads exactly as before the batch.
        let snap0_again = d.materialize(0);
        assert_eq!(snap0.src, snap0_again.src);
        assert_eq!(snap0.dst, snap0_again.dst);
        assert_eq!(snap0.w, snap0_again.w);
        let ego0_again = d.sample_at(0, &[5, 9], &[6, 3], 2);
        assert_eq!(ego0.origin, ego0_again.origin);
        assert_eq!(ego0.graph.src, ego0_again.graph.src);
        // ...and the new epoch differs.
        let snap1 = d.materialize(1);
        assert_eq!(snap1.meta.n_edges, snap0.meta.n_edges + 2 - 1);
    }

    #[test]
    fn vertex_growth_extends_the_grid() {
        let g = graph(120, 800, 13);
        let mut d = DynamicGraph::new(g, cfg(64));
        assert_eq!(d.shards(), 2);
        // Grow past the 2-shard boundary and wire a new vertex in.
        let r = d.apply(&UpdateBatch {
            inserts: vec![(120, 0, 1.0), (3, 140, 1.0)],
            deletes: vec![],
            new_vertices: 30,
        });
        assert_eq!(r.new_vertices, 30);
        assert_eq!(d.n_vertices(), 150);
        assert_eq!(d.shards(), 3);
        assert_matches_scratch(&d);
        let mut row = Vec::new();
        d.view().in_edges(140, &mut row);
        assert_eq!(row, vec![(3, 1.0)]);
        // Old epoch still reports the old vertex count.
        assert_eq!(d.materialize(0).meta.n_vertices, 120);
        assert_eq!(d.view_at(0).n_vertices(), 120);
    }

    #[test]
    fn compaction_folds_overlay_and_rebases() {
        let g = graph(200, 500, 17);
        let scfg = StreamConfig { compact_ratio: 0.10 };
        let mut d = DynamicGraph::with_config(g, cfg(64), scfg);
        let mut compacted_at = None;
        for e in 0..6u32 {
            let inserts: Vec<(u32, u32, f32)> =
                (0..20).map(|i| ((i * 7 + e) % 200, (i * 13) % 200, 1.0)).collect();
            let r = d.apply(&UpdateBatch { inserts, deletes: vec![], new_vertices: 0 });
            if r.compacted {
                compacted_at = Some(r.epoch);
                break;
            }
        }
        let at = compacted_at.expect("10% ratio must compact within 6 batches");
        assert_eq!(d.base_epoch(), at);
        assert_eq!(d.compactions, 1);
        assert!(d.overlay_ratio() == 0.0);
        assert_matches_scratch(&d);
        // Pre-compaction epochs are folded away; the current one reads.
        let current = d.epoch();
        assert_eq!(d.materialize(current).meta.n_edges, d.n_edges());
        // Post-compaction updates still work incrementally.
        d.apply(&UpdateBatch {
            inserts: vec![(0, 1, 3.0)],
            deletes: vec![],
            new_vertices: 0,
        });
        assert_matches_scratch(&d);
    }

    #[test]
    #[should_panic(expected = "retained window")]
    fn folded_epoch_is_unreadable() {
        let g = graph(100, 300, 19);
        let mut d = DynamicGraph::with_config(g, cfg(64), StreamConfig { compact_ratio: 0.0 });
        // ratio 0: every apply compacts.
        let r = d.apply(&UpdateBatch {
            inserts: vec![(1, 2, 1.0)],
            deletes: vec![],
            new_vertices: 0,
        });
        assert!(r.compacted);
        let _ = d.materialize(0);
    }
}
