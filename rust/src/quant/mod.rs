//! Post-training int8 calibration for the quantized ACK datapath.
//!
//! Production FPGA overlays ship fixed-point datapaths (DLA, arXiv
//! 1807.06434); this module adds the software side: a per-layer
//! **symmetric** calibration pass producing a [`ScaleTable`] that the
//! compiler embeds as the versioned GA03 section of the `.ga` binary
//! (`isa::binary`), and the error-bound derivation the scale-aware
//! golden-equivalence tests gate on.
//!
//! * **Scales** — every quantized reduction has a *stationary* operand
//!   (Linear weights, or the aggregation's edge weights) and a
//!   *streamed* operand (the feature tile). Both quantize symmetrically:
//!   `q = clamp(round(v / s), -127, 127)` with `s = range / 127`. Weight
//!   ranges are exact max-abs over the [`WeightStore`]; feature ranges
//!   are propagated layer-to-layer analytically (the same closed-form
//!   DAG walk as `sparsity::feature_density_estimates`, over magnitudes
//!   instead of densities), inflated by the accumulated quantization
//!   error so the derived range always covers the quantized path's
//!   values — no clamping, which keeps the bound below sound.
//! * **Error bound** — for a length-`L` quantized reduction with
//!   streamed range `r_x` (scale `s_x`) and stationary range `r_w`
//!   (scale `s_w`), the per-element dequantized error is at most
//!   `G·E_x + L·E_w·r_x + L·(r_w·s_x/2 + s_w·r_x/2 + s_x·s_w/4)`, where
//!   `G` is the stationary operand's L∞ gain (max column abs-sum for
//!   weights, max row abs-sum for edge weights) and `E_x`/`E_w` are the
//!   operands' incoming errors. Non-quantized layers propagate errors by
//!   their Lipschitz constants. [`calibrate`] returns the final-layer
//!   bound alongside the table — derived from the calibration ranges,
//!   never hand-tuned.
//!
//! Eligible layers are Linear (GEMM) and Sum/Mean Aggregate (SpDMM —
//! Mean is sum-semantics here; GCN normalization lives in the edge
//! weights). Max/Min aggregation, SDDMM and element-wise layers stay
//! f32: their outputs feed the quantizers of downstream eligible layers.

use crate::exec::golden::WeightStore;
use crate::graph::CooGraph;
use crate::ir::{LayerIr, LayerType, ModelIr};
use crate::isa::{Activation, AggOp};
use anyhow::{bail, Result};
use std::collections::HashMap;

/// Requested numeric precision of one inference (`serve` carries it per
/// request; the compiled program carries scales when it can serve Int8).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Precision {
    /// Full f32 datapath (the default).
    #[default]
    F32,
    /// Quantized int8 operands with i32 accumulation.
    Int8,
}

impl Precision {
    pub fn key(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Int8 => "int8",
        }
    }
}

impl std::str::FromStr for Precision {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Precision, String> {
        match s {
            "f32" | "fp32" => Ok(Precision::F32),
            "int8" | "i8" => Ok(Precision::Int8),
            other => Err(format!("unknown precision '{other}' (expected int8|f32)")),
        }
    }
}

/// Per-layer row of the scale table: the two symmetric scales a
/// quantized layer executes with, plus the propagated output range the
/// error bound was derived from.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScaleEntry {
    pub layer_id: u16,
    /// Stationary-operand scale: Linear weights, or the aggregation's
    /// edge weights (`w = q * w_scale`).
    pub w_scale: f32,
    /// Streamed-operand (feature tile) scale (`x = q * x_scale`).
    pub x_scale: f32,
    /// Propagated |output|∞ range including accumulated error — what
    /// the next quantized layer's input range was derived from.
    pub y_absmax: f32,
}

/// Bytes per serialized [`ScaleEntry`]: u16 id + three f32.
pub const SCALE_ENTRY_BYTES: usize = 14;

/// The calibration result embedded as the GA03 section of the `.ga`
/// binary: one entry per quantized layer, plus the input range and the
/// derived output error bound (so an engine loading the binary can
/// reproduce the acceptance check without re-running calibration).
#[derive(Clone, Debug, PartialEq, Default)]
pub struct ScaleTable {
    /// |input features|∞ the calibration assumed.
    pub input_absmax: f32,
    /// Final-layer output error bound derived from the ranges below.
    pub bound: f32,
    pub entries: Vec<ScaleEntry>,
}

impl ScaleTable {
    /// Table row for `layer_id`, if the layer is quantized.
    pub fn entry(&self, layer_id: u16) -> Option<&ScaleEntry> {
        self.entries.iter().find(|e| e.layer_id == layer_id)
    }

    /// Serialized size of the GA03 section body.
    pub fn size_bytes(&self) -> u64 {
        4 + 4 + 4 + (self.entries.len() * SCALE_ENTRY_BYTES) as u64
    }

    /// Serialize the section body (input range, bound, entry count,
    /// then the fixed-width entries).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.size_bytes() as usize);
        out.extend_from_slice(&self.input_absmax.to_le_bytes());
        out.extend_from_slice(&self.bound.to_le_bytes());
        out.extend_from_slice(&(self.entries.len() as u32).to_le_bytes());
        for e in &self.entries {
            out.extend_from_slice(&e.layer_id.to_le_bytes());
            out.extend_from_slice(&e.w_scale.to_le_bytes());
            out.extend_from_slice(&e.x_scale.to_le_bytes());
            out.extend_from_slice(&e.y_absmax.to_le_bytes());
        }
        out
    }

    /// Parse a section body from the front of `data`. Returns the table
    /// and the number of bytes consumed; errors (never panics) on
    /// truncated or corrupt input.
    pub fn from_bytes(data: &[u8]) -> Result<(ScaleTable, usize)> {
        let mut at = 0usize;
        let take = |at: &mut usize, n: usize| -> Result<&[u8]> {
            if *at + n > data.len() {
                bail!("truncated scale table at offset {at}");
            }
            let s = &data[*at..*at + n];
            *at += n;
            Ok(s)
        };
        let rd_f32 = |at: &mut usize| -> Result<f32> {
            Ok(f32::from_le_bytes(take(at, 4)?.try_into().unwrap()))
        };
        let input_absmax = rd_f32(&mut at)?;
        let bound = rd_f32(&mut at)?;
        let n = u32::from_le_bytes(take(&mut at, 4)?.try_into().unwrap()) as usize;
        let mut entries = Vec::with_capacity(n.min(4096));
        for _ in 0..n {
            let layer_id = u16::from_le_bytes(take(&mut at, 2)?.try_into().unwrap());
            let w_scale = rd_f32(&mut at)?;
            let x_scale = rd_f32(&mut at)?;
            let y_absmax = rd_f32(&mut at)?;
            if !(w_scale > 0.0 && x_scale > 0.0) {
                bail!("corrupt scale entry for layer {layer_id}: non-positive scale");
            }
            entries.push(ScaleEntry { layer_id, w_scale, x_scale, y_absmax });
        }
        Ok((ScaleTable { input_absmax, bound, entries }, at))
    }
}

/// Graph-side magnitudes the feature-range propagation consumes. The
/// weight side is always exact (read from the store); the graph side is
/// exact when the graph is at hand ([`CalibrationProfile::exact`]) and
/// conservatively estimated otherwise ([`CalibrationProfile::analytic`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CalibrationProfile {
    /// |input features|∞.
    pub input_absmax: f32,
    /// |edge weights|∞ (GCN-normalized weights are <= 1 by construction).
    pub edge_absmax: f32,
    /// L∞ gain of aggregation: max over destination rows of Σ|w_e|.
    pub agg_gain: f32,
    /// Maximum in-degree (the aggregation reduction length).
    pub max_degree: f32,
}

impl CalibrationProfile {
    /// Exact magnitudes from the materialized graph and input features —
    /// what the golden-equivalence gate uses.
    pub fn exact(graph: &CooGraph, x: &[f32]) -> CalibrationProfile {
        let absmax = |v: &[f32]| v.iter().fold(0f32, |m, &a| m.max(a.abs()));
        let mut row_sum = vec![0f32; graph.n()];
        let mut row_deg = vec![0u32; graph.n()];
        for (&d, &w) in graph.dst.iter().zip(&graph.w) {
            row_sum[d as usize] += w.abs();
            row_deg[d as usize] += 1;
        }
        CalibrationProfile {
            input_absmax: absmax(x).max(1e-12),
            edge_absmax: absmax(&graph.w).max(1e-12),
            agg_gain: row_sum.iter().fold(0f32, |m, &a| m.max(a)).max(1e-12),
            max_degree: row_deg.iter().copied().max().unwrap_or(0).max(1) as f32,
        }
    }

    /// Conservative closed-form estimates from graph metadata alone
    /// (the serve path calibrates at compile time, before any features
    /// or materialized edges exist). Unit-range inputs, GCN-normalized
    /// edge weights (<= 1), and an R-MAT-skew allowance of 8x the mean
    /// degree. Estimates only widen scales — the bound stays derived
    /// from whatever ranges were used.
    pub fn analytic(nv: u64, ne: u64) -> CalibrationProfile {
        let mean_deg = (ne as f32 / nv.max(1) as f32).max(1.0);
        CalibrationProfile {
            input_absmax: 1.0,
            edge_absmax: 1.0,
            // GCN row sums are sqrt(d_i)-bounded; allow the skew factor.
            agg_gain: (8.0 * mean_deg).sqrt().max(1.5),
            max_degree: 8.0 * mean_deg,
        }
    }
}

/// A calibrated model: the table to embed, and the final-output error
/// bound derived from it.
#[derive(Clone, Debug, PartialEq)]
pub struct Calibration {
    pub table: ScaleTable,
    /// Per-element |int8 output - f32 output| bound at the final layer.
    pub bound: f32,
}

/// Whether a layer executes on the int8 datapath when scales are
/// present: Linear GEMMs always, Aggregate only with the linear Sum /
/// Mean reductions (Max/Min compare dequantized magnitudes and stay
/// f32, as do SDDMM and the element-wise path).
pub fn quantizable(l: &LayerIr) -> bool {
    match l.ltype {
        LayerType::Linear => true,
        LayerType::Aggregate => {
            matches!(l.aggop.unwrap_or(AggOp::Sum), AggOp::Sum | AggOp::Mean)
        }
        _ => false,
    }
}

/// Activation range/error propagation: returns the output |·|∞ range
/// and error for a layer output with range `a` and error `e`.
/// Monotone 1-Lipschitz activations pass both through; Swish's max
/// slope is < 1.1; Sigmoid saturates; Exp's gain on [-a, a] is e^a.
fn act_propagate(act: Activation, a: f32, e: f32) -> (f32, f32) {
    match act {
        Activation::None | Activation::Relu | Activation::PRelu | Activation::LRelu => (a, e),
        Activation::Elu => (a.max(1.0), e),
        Activation::Swish => (a, 1.1 * e),
        Activation::Sigmoid => (1.0, 0.25 * e),
        Activation::Exp => {
            let g = a.min(60.0).exp();
            (g, g * e)
        }
    }
}

/// Quantization error of one length-`len` reduction: streamed operand
/// (range `rx`, scale `sx`, incoming error `ex`), stationary operand
/// (range `rw`, scale `sw`, incoming error `ew`), stationary gain `g`.
fn reduction_err(len: f32, g: f32, rx: f32, sx: f32, ex: f32, rw: f32, sw: f32, ew: f32) -> f32 {
    g * ex + len * ew * rx + len * (rw * sx * 0.5 + sw * rx * 0.5 + sx * sw * 0.25)
}

/// Run the symmetric calibration pass: exact max-abs over the store's
/// weights, feature ranges propagated layer-to-layer, scales at
/// `range / 127`, and the error bound accumulated through the same walk.
pub fn calibrate(
    ir: &ModelIr,
    store: &WeightStore,
    profile: &CalibrationProfile,
) -> Calibration {
    // (range, error) of each layer's output features, keyed by id.
    let mut out: HashMap<u16, (f32, f32)> = HashMap::new();
    // Edge weights mutate sequentially through the layer list (SDDMM
    // overwrites them), exactly like the executors' edge_w state.
    let (mut aw, mut ew) = (profile.edge_absmax.max(1e-12), 0f32);
    let mut entries = Vec::new();
    let mut last = (profile.input_absmax, 0f32);
    for l in &ir.layers {
        let (ax, ex) = l
            .parents
            .first()
            .and_then(|p| out.get(p).copied())
            .unwrap_or((profile.input_absmax, 0.0));
        let act = if l.act_enabled { l.act } else { Activation::None };
        let (mut ay, mut ey) = match l.ltype {
            LayerType::Linear => {
                let (w, b) = store.get(l.id);
                let (f_in, f_out) = (l.f_in as usize, l.f_out as usize);
                // Exact per-weight magnitudes: max |W| for the scale,
                // max column abs-sum for the layer gain.
                let mut col_sum = vec![0f32; f_out];
                let mut wmax = 0f32;
                for (i, &v) in w.iter().enumerate() {
                    let a = v.abs();
                    wmax = wmax.max(a);
                    col_sum[i % f_out] += a;
                }
                let gain = col_sum.iter().fold(0f32, |m, &a| m.max(a)).max(1e-12);
                let bmax = b.iter().fold(0f32, |m, &a| m.max(a.abs()));
                let (rx, rw) = ((ax + ex).max(1e-12), wmax.max(1e-12));
                let (sx, sw) = (rx / 127.0, rw / 127.0);
                let qe = reduction_err(l.f_in as f32, gain, rx, sx, ex, rw, sw, 0.0);
                let ay = ax * gain + bmax;
                entries.push(ScaleEntry {
                    layer_id: l.id,
                    w_scale: sw,
                    x_scale: sx,
                    y_absmax: ay + qe,
                });
                // f32 summation rounding allowance on top of the exact-
                // arithmetic bound (length-f_in dot products).
                (ay, qe + f_in as f32 * f32::EPSILON * ay)
            }
            LayerType::Aggregate => {
                let aggop = l.aggop.unwrap_or(AggOp::Sum);
                let deg = profile.max_degree.max(1.0);
                if quantizable(l) {
                    let (rx, rw) = ((ax + ex).max(1e-12), (aw + ew).max(1e-12));
                    let (sx, sw) = (rx / 127.0, rw / 127.0);
                    let qe = reduction_err(deg, profile.agg_gain, rx, sx, ex, rw, sw, ew);
                    let ay = ax * profile.agg_gain;
                    entries.push(ScaleEntry {
                        layer_id: l.id,
                        w_scale: sw,
                        x_scale: sx,
                        y_absmax: ay + qe,
                    });
                    (ay, qe + deg * f32::EPSILON * ay)
                } else {
                    // Max/Min stay f32: per-term Lipschitz propagation.
                    debug_assert!(matches!(aggop, AggOp::Max | AggOp::Min));
                    (aw * ax, aw * ex + ew * (ax + ex))
                }
            }
            LayerType::VectorInner => {
                // New edge weights <x_i, x_j>; features pass through.
                let f = l.f_in as f32;
                aw = f * ax * ax;
                ew = f * (2.0 * ax * ex + ex * ex) + f * f32::EPSILON * aw;
                (ax, ex)
            }
            LayerType::VectorAdd => {
                let (a2, e2) = l
                    .parents
                    .get(1)
                    .and_then(|p| out.get(p).copied())
                    .unwrap_or((ax, ex));
                (ax + a2, ex + e2)
            }
            LayerType::Activation => {
                // An activation behind a Vector-Inner layer rescales the
                // edge weights, not the features (exec::golden).
                let edge_parent = l.parents.first().map(|&p| {
                    ir.layers.iter().any(|q| q.id == p && q.ltype == LayerType::VectorInner)
                });
                if edge_parent.unwrap_or(false) {
                    let (a2, e2) = act_propagate(l.act, aw, ew);
                    aw = a2;
                    ew = e2;
                    (ax, ex)
                } else {
                    act_propagate(l.act, ax, ex)
                }
            }
            LayerType::BatchNorm => (ax, ex), // inference BN: identity
        };
        if l.ltype != LayerType::Activation {
            (ay, ey) = act_propagate(act, ay, ey);
        }
        out.insert(l.id, (ay, ey));
        last = (ay, ey);
    }
    let bound = last.1;
    Calibration {
        table: ScaleTable { input_absmax: profile.input_absmax, bound, entries },
        bound,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{rmat::rmat_edges, GraphMeta};
    use crate::ir::ZooModel;

    fn small() -> (ModelIr, WeightStore, CooGraph) {
        let meta = GraphMeta::new("q", 64, 256, 16, 4);
        let g = rmat_edges(meta.clone(), Default::default(), 3).gcn_normalized();
        let ir = ZooModel::B1.build(meta);
        let store = WeightStore::deterministic(&ir, 33);
        (ir, store, g)
    }

    #[test]
    fn scale_table_roundtrips() {
        let (ir, store, g) = small();
        let x = g.random_features(5);
        let cal = calibrate(&ir, &store, &CalibrationProfile::exact(&g, &x));
        assert!(!cal.table.entries.is_empty());
        let bytes = cal.table.to_bytes();
        assert_eq!(bytes.len() as u64, cal.table.size_bytes());
        let (back, used) = ScaleTable::from_bytes(&bytes).unwrap();
        assert_eq!(used, bytes.len());
        assert_eq!(back, cal.table);
    }

    #[test]
    fn truncation_rejected_at_every_cut() {
        let (ir, store, g) = small();
        let x = g.random_features(5);
        let cal = calibrate(&ir, &store, &CalibrationProfile::exact(&g, &x));
        let bytes = cal.table.to_bytes();
        for cut in 0..bytes.len() {
            assert!(ScaleTable::from_bytes(&bytes[..cut]).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn corrupt_scale_rejected() {
        let (ir, store, g) = small();
        let x = g.random_features(5);
        let mut table = calibrate(&ir, &store, &CalibrationProfile::exact(&g, &x)).table;
        table.entries[0].w_scale = 0.0;
        assert!(ScaleTable::from_bytes(&table.to_bytes()).is_err());
    }

    #[test]
    fn scales_positive_and_bound_finite_for_all_models() {
        let meta = GraphMeta::new("q", 128, 512, 32, 8);
        let g = rmat_edges(meta.clone(), Default::default(), 7).gcn_normalized();
        let x = g.random_features(11);
        let profile = CalibrationProfile::exact(&g, &x);
        for model in crate::ir::ALL_MODELS {
            let ir = model.build(meta.clone());
            let store = WeightStore::deterministic(&ir, 33);
            let cal = calibrate(&ir, &store, &profile);
            assert!(cal.bound.is_finite() && cal.bound > 0.0, "{}", model.key());
            for e in &cal.table.entries {
                assert!(e.w_scale > 0.0 && e.x_scale > 0.0, "{} layer {}", model.key(), e.layer_id);
                assert!(e.y_absmax.is_finite());
            }
            // Every Linear and Sum/Mean Aggregate is covered.
            let want = ir.layers.iter().filter(|l| quantizable(l)).count();
            assert_eq!(cal.table.entries.len(), want, "{}", model.key());
        }
    }

    #[test]
    fn analytic_profile_is_no_tighter_than_defaults() {
        let p = CalibrationProfile::analytic(1000, 10_000);
        assert!(p.agg_gain >= 1.5);
        assert!(p.max_degree >= 10.0 * 8.0 - 1.0);
        assert_eq!(p.edge_absmax, 1.0);
    }

    #[test]
    fn precision_parses_and_prints() {
        assert_eq!("int8".parse::<Precision>().unwrap(), Precision::Int8);
        assert_eq!("f32".parse::<Precision>().unwrap(), Precision::F32);
        assert!("fp7".parse::<Precision>().is_err());
        assert_eq!(Precision::Int8.key(), "int8");
        assert_eq!(Precision::default(), Precision::F32);
    }
}
