//! Hardware configuration: the overlay's architecture parameters (paper
//! Sec. 4.2 "Hardware parameters" and Sec. 7 "System Details of Alveo
//! U250"), plus the platform constants of every system in the evaluation
//! (Tables 3 and 6).

/// Architecture parameters of one GraphAGILE overlay instance.
///
/// Defaults reproduce the Alveo U250 deployment of the paper: 8 PEs,
/// p_sys = 16, 300 MHz, per-PE buffers of 1 MB weight (double-buffered),
/// 2 MB edge (double-buffered), 3 MB feature (triple-buffered), 4 DDR
/// channels totalling 77 GB/s, PCIe at 31.5 GB/s.
#[derive(Clone, Debug, PartialEq)]
pub struct HwConfig {
    /// Number of processing elements (N_pe).
    pub n_pe: usize,
    /// ACK systolic dimension (p_sys); power of two.
    pub p_sys: usize,
    /// Clock frequency in Hz.
    pub freq_hz: f64,
    /// Weight Buffer rows (N_W); row width is p_sys f32 words.
    pub weight_rows: usize,
    /// Edge Buffer capacity in edges (N_E); an edge is 3 x 32 bits.
    pub edge_capacity: usize,
    /// Feature Buffer rows (N_F1); row width N_F2 = fiber width.
    pub feature_rows: usize,
    /// Feature Buffer row width in f32 words (N_F2 == partition N2).
    pub feature_cols: usize,
    /// Aggregate DDR bandwidth over all channels, bytes/s.
    pub ddr_bw: f64,
    /// Number of DDR channels (per-channel bw = ddr_bw / channels).
    pub ddr_channels: usize,
    /// Host-to-FPGA PCIe sustained bandwidth, bytes/s.
    pub pcie_bw: f64,
    /// Double buffering on Edge/Weight buffers, triple on Feature:
    /// enables compute/communication overlap (Fig. 16 ablates this).
    pub overlap: bool,
    /// RAW-unit reorder-buffer depth (Sec. 7, "RAW Unit").
    pub raw_reorder_depth: usize,
    /// Update/Reduce pipeline depth in cycles (drain latency per tile).
    pub ur_pipeline_depth: usize,
}

impl Default for HwConfig {
    fn default() -> Self {
        HwConfig::alveo_u250()
    }
}

impl HwConfig {
    /// The paper's deployment (Sec. 7).
    pub fn alveo_u250() -> Self {
        HwConfig {
            n_pe: 8,
            p_sys: 16,
            freq_hz: 300e6,
            weight_rows: 16384,
            edge_capacity: 65536,
            feature_rows: 16384,
            feature_cols: 16,
            ddr_bw: 77e9,
            ddr_channels: 4,
            pcie_bw: 31.5e9,
            overlap: true,
            raw_reorder_depth: 16,
            ur_pipeline_depth: 8,
        }
    }

    /// A small configuration used by tests and the functional runtime
    /// (tile shapes matching the AOT artifacts: N1 = 128, N2 = 64).
    pub fn functional_tiles() -> Self {
        HwConfig {
            n_pe: 2,
            p_sys: 16,
            feature_rows: 128,
            feature_cols: 64,
            edge_capacity: 1024,
            weight_rows: 128,
            ..HwConfig::alveo_u250()
        }
    }

    /// Fiber-Shard partition parameter N1 (subshard/subfiber rows):
    /// bounded by both the Feature Buffer rows and the Edge Buffer.
    pub fn n1(&self) -> usize {
        self.feature_rows
    }

    /// Fiber width N2 (feature columns per fiber).
    pub fn n2(&self) -> usize {
        self.feature_cols
    }

    /// Peak f32 performance in FLOP/s: each ALU does one multiply-add per
    /// cycle; N_pe * p_sys^2 ALUs * 2 flops (Table 3: 614 GFLOPS on U250).
    pub fn peak_flops(&self) -> f64 {
        self.n_pe as f64 * (self.p_sys * self.p_sys) as f64 * 2.0 * self.freq_hz
    }

    /// Seconds per clock cycle.
    pub fn cycle_time(&self) -> f64 {
        1.0 / self.freq_hz
    }

    /// Total on-chip memory (bytes) across PEs: weight (x2 double-buffer),
    /// edge (x2), feature (x3) — Sec. 7 gives 1 + 2 + 3 MB per PE.
    pub fn on_chip_bytes(&self) -> u64 {
        let w = (self.weight_rows * self.p_sys * 4) as u64 * 2;
        let e = (self.edge_capacity * 12) as u64 * 2;
        let f = (self.feature_rows * self.feature_cols * 4) as u64 * 3;
        (w + e + f) * self.n_pe as u64
    }

    /// Validate invariants the compiler/simulator rely on.
    pub fn validate(&self) -> Result<(), String> {
        if !self.p_sys.is_power_of_two() {
            return Err(format!("p_sys={} must be a power of two", self.p_sys));
        }
        if self.n_pe == 0 || self.freq_hz <= 0.0 {
            return Err("n_pe and freq must be positive".into());
        }
        if self.feature_rows % self.p_sys != 0 {
            return Err(format!(
                "feature_rows={} must be a multiple of p_sys={}",
                self.feature_rows, self.p_sys
            ));
        }
        Ok(())
    }
}

/// Platform constants for the evaluation baselines (Tables 3 and 6).
#[derive(Clone, Copy, Debug)]
pub struct Platform {
    pub name: &'static str,
    pub freq_hz: f64,
    /// Peak f32 throughput, FLOP/s.
    pub peak_flops: f64,
    /// On-chip (cache / BRAM+URAM) bytes.
    pub on_chip_bytes: u64,
    /// External memory bandwidth, bytes/s.
    pub mem_bw: f64,
}

/// AMD Ryzen 3990x (Table 6).
pub const CPU_RYZEN_3990X: Platform = Platform {
    name: "Ryzen 3990x",
    freq_hz: 2.9e9,
    peak_flops: 3.7e12,
    on_chip_bytes: 256 * 1024 * 1024,
    mem_bw: 107e9,
};

/// Nvidia RTX3090 (Table 6).
pub const GPU_RTX3090: Platform = Platform {
    name: "RTX3090",
    freq_hz: 1.7e9,
    peak_flops: 36e12,
    on_chip_bytes: 6 * 1024 * 1024,
    mem_bw: 936.2e9,
};

/// HyGCN ASIC (Table 6).
pub const ACCEL_HYGCN: Platform = Platform {
    name: "HyGCN",
    freq_hz: 1e9,
    peak_flops: 4608e9,
    on_chip_bytes: 35_800_000,
    mem_bw: 256e9,
};

/// AWB-GCN on Stratix 10 SX (Table 3).
pub const ACCEL_AWB_GCN: Platform = Platform {
    name: "AWB-GCN",
    freq_hz: 330e6,
    peak_flops: 1351e9,
    on_chip_bytes: 22 * 1024 * 1024,
    mem_bw: 57.3e9,
};

/// BoostGCN on Stratix 10 GX (Table 3).
pub const ACCEL_BOOSTGCN: Platform = Platform {
    name: "BoostGCN",
    freq_hz: 250e6,
    peak_flops: 640e9,
    on_chip_bytes: 32 * 1024 * 1024,
    mem_bw: 77e9,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u250_matches_paper_table3() {
        let hw = HwConfig::alveo_u250();
        hw.validate().unwrap();
        // Peak: 8 PEs x 256 ALUs x 2 flops x 300 MHz = 1228.8 GFLOPS raw.
        // The paper reports 614 GFLOPS (counting multiply-add as one op in
        // half the kernels); we assert the raw figure and document this.
        let gflops = hw.peak_flops() / 1e9;
        assert!((gflops - 1228.8).abs() < 1.0, "got {gflops}");
        // On-chip: (1 + 2x0.75 + 3) MB-ish per PE x 8 — paper says 45 MB
        // total; our accounting gives the same order.
        let mb = hw.on_chip_bytes() as f64 / 1024.0 / 1024.0;
        assert!((40.0..=56.0).contains(&mb), "on-chip {mb} MB");
    }

    #[test]
    fn functional_cfg_validates() {
        HwConfig::functional_tiles().validate().unwrap();
    }

    #[test]
    fn validate_rejects_bad_psys() {
        let hw = HwConfig { p_sys: 12, ..HwConfig::alveo_u250() };
        assert!(hw.validate().is_err());
    }

    #[test]
    fn validate_rejects_ragged_feature_rows() {
        let hw = HwConfig { feature_rows: 100, ..HwConfig::alveo_u250() };
        assert!(hw.validate().is_err());
    }

    #[test]
    fn partition_params() {
        let hw = HwConfig::alveo_u250();
        assert_eq!(hw.n1(), 16384);
        assert_eq!(hw.n2(), 16);
    }
}
