//! FPGA DDR memory model (paper Sec. 7: four channels, 77 GB/s total;
//! the paper uses Ramulator — we model sustained-bandwidth transfers with
//! per-transfer fixed overhead, which is what tile-granular streaming
//! reaches on an open-page DDR4 schedule).

use crate::config::HwConfig;

/// Sustained-bandwidth DDR model.
#[derive(Clone, Copy, Debug)]
pub struct DdrModel {
    /// Bytes per accelerator cycle, aggregate over all channels.
    pub bytes_per_cycle: f64,
    /// Fixed per-transfer overhead in cycles (row activation + burst
    /// alignment; dominates only for tiny transfers).
    pub fixed_cycles: u64,
    /// Channels (bandwidth shares under concurrent access).
    pub channels: usize,
}

impl DdrModel {
    pub fn from_hw(hw: &HwConfig) -> DdrModel {
        DdrModel {
            bytes_per_cycle: hw.ddr_bw / hw.freq_hz,
            fixed_cycles: 30,
            channels: hw.ddr_channels,
        }
    }

    /// Cycles to move `bytes` when `sharers` agents contend for the
    /// aggregate bandwidth (PEs executing concurrently).
    pub fn transfer_cycles(&self, bytes: u64, sharers: usize) -> u64 {
        if bytes == 0 {
            return 0;
        }
        let share = self.bytes_per_cycle / sharers.max(1) as f64;
        self.fixed_cycles + (bytes as f64 / share).ceil() as u64
    }

    /// Cycles to move an operand tile whose f32 image is `f32_bytes`
    /// when each element is streamed at `elem_bytes` instead — the int8
    /// datapath moves 1-byte operands, a quarter of the f32 traffic.
    /// The fixed per-burst overhead does not shrink with element width.
    pub fn transfer_cycles_elem(&self, f32_bytes: u64, elem_bytes: u64, sharers: usize) -> u64 {
        self.transfer_cycles(f32_bytes * elem_bytes.min(4) / 4, sharers)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> DdrModel {
        DdrModel::from_hw(&HwConfig::alveo_u250())
    }

    #[test]
    fn bandwidth_math() {
        let m = model();
        // 77 GB/s at 300 MHz = 256.67 B/cycle.
        assert!((m.bytes_per_cycle - 256.66).abs() < 1.0);
        // 1 MB solo: ~4096 cycles + overhead.
        let c = m.transfer_cycles(1 << 20, 1);
        assert!((4000..4500).contains(&c), "{c}");
    }

    #[test]
    fn sharing_divides_bandwidth() {
        let m = model();
        let solo = m.transfer_cycles(1 << 20, 1);
        let shared = m.transfer_cycles(1 << 20, 8);
        assert!(shared > solo * 7, "{shared} vs {solo}");
    }

    #[test]
    fn zero_bytes_free() {
        assert_eq!(model().transfer_cycles(0, 4), 0);
    }

    #[test]
    fn fixed_overhead_dominates_small() {
        let m = model();
        let tiny = m.transfer_cycles(64, 1);
        assert!(tiny >= m.fixed_cycles && tiny <= m.fixed_cycles + 2);
    }

    #[test]
    fn one_byte_operands_quarter_the_traffic() {
        let m = model();
        let f32_cycles = m.transfer_cycles(1 << 20, 1);
        let i8_cycles = m.transfer_cycles_elem(1 << 20, 1, 1);
        assert_eq!(i8_cycles, m.transfer_cycles(1 << 18, 1));
        // ~4x fewer streamed cycles, minus the constant burst overhead.
        assert!(i8_cycles < f32_cycles / 3, "{i8_cycles} vs {f32_cycles}");
        // 4-byte elements are exactly the f32 path.
        assert_eq!(m.transfer_cycles_elem(1 << 20, 4, 1), f32_cycles);
    }
}
