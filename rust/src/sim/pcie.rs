//! Host-to-FPGA PCIe transfer model: T_comm of the end-to-end latency
//! (paper Sec. 8, Performance Metric): binary file + GNN weights +
//! preprocessed graph moved at the sustained PCIe bandwidth (31.5 GB/s,
//! matched to the baseline CPU-GPU platform).

use crate::config::HwConfig;

/// Seconds to move `bytes` from host memory to FPGA DDR.
pub fn comm_seconds(hw: &HwConfig, bytes: u64) -> f64 {
    bytes as f64 / hw.pcie_bw
}

/// Total bytes moved before inference can start: the processed graph
/// (features + partition-ordered edges), the model weights, and the
/// compiled binary.
pub fn comm_bytes(graph_bytes: u64, weight_bytes: u64, binary_bytes: u64) -> u64 {
    graph_bytes + weight_bytes + binary_bytes
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reddit_scale_transfer() {
        let hw = HwConfig::alveo_u250();
        // ~1.95 GB input at 31.5 GB/s ~= 62 ms.
        let t = comm_seconds(&hw, 1_950_000_000);
        assert!((0.055..0.07).contains(&t), "{t}");
    }

    #[test]
    fn comm_bytes_sums() {
        assert_eq!(comm_bytes(100, 20, 3), 123);
    }
}
