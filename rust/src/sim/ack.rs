//! Adaptive Computation Kernel timing (paper Sec. 5.4).
//!
//! Effective cycles per compute instruction = microcode trip count
//! (Alg. 1–3 closed form, `isa::microcode`) x mode-specific derates:
//!
//! * **GEMM / VecAdd / Act / Init** — deterministic access patterns, no
//!   shuffle conflicts: base cycles plus pipeline fill.
//! * **SpDMM** — edge-centric: ISN/DSN bank conflicts (butterfly
//!   throughput under uniform traffic) and RAW-unit stalls.
//! * **SDDMM** — ISN/DSN conflicts only (no read-modify-write: results
//!   accumulate at the adder-tree root, so no RAW hazard).
//!
//! The butterfly derate is *measured* once per (p_sys, fifo depth) from
//! the switch-level simulation in [`super::shuffle`] and cached.

use super::raw::stall_factor;
use super::shuffle::uniform_throughput;
use crate::config::HwConfig;
use crate::isa::{instr_cycles, AggOp, Instr};
use crate::sparsity::{choose_mode, tile_density, KernelMode, ThresholdEntry, ThresholdTable};
use std::collections::HashMap;
use std::sync::Mutex;
use std::sync::OnceLock;

/// Mode-switch overhead: one cycle (paper Sec. 5.4).
pub const MODE_SWITCH_CYCLES: u64 = 1;

/// Overhead of a runtime re-map decision: read the profiled density,
/// compare against the threshold table, select the ACK mode.
pub const REMAP_DECISION_CYCLES: u64 = 2;

fn shuffle_eta(p_sys: usize, fifo_depth: usize) -> f64 {
    static CACHE: OnceLock<Mutex<HashMap<(usize, usize), f64>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    let mut guard = cache.lock().unwrap();
    *guard
        .entry((p_sys, fifo_depth))
        .or_insert_with(|| uniform_throughput(p_sys, fifo_depth, 0xACDC))
}

/// Timing context for one PE's ACK.
#[derive(Clone, Copy, Debug)]
pub struct AckModel {
    pub p_sys: usize,
    /// Butterfly throughput fraction under uniform traffic.
    pub eta_shuffle: f64,
    pub ur_depth: usize,
    pub raw_reorder: usize,
}

impl AckModel {
    pub fn from_hw(hw: &HwConfig) -> AckModel {
        AckModel {
            p_sys: hw.p_sys,
            eta_shuffle: shuffle_eta(hw.p_sys, 4),
            ur_depth: hw.ur_pipeline_depth,
            raw_reorder: hw.raw_reorder_depth,
        }
    }

    /// The same ACK timed for the int8 datapath: 8-bit operands pack
    /// two MACs per DSP slice (the standard INT8 double-pumping), so
    /// compute instructions are charged at SIMD width `2 * p_sys`. The
    /// butterfly throughput is re-measured at the wider lane count.
    pub fn int8_widened(&self) -> AckModel {
        AckModel {
            p_sys: self.p_sys * 2,
            eta_shuffle: shuffle_eta(self.p_sys * 2, 4),
            ..*self
        }
    }

    /// Effective ACK-busy cycles for `instr`. `out_rows` is the output
    /// tile height (RAW conflict domain for SpDMM).
    pub fn cycles(&self, instr: &Instr, out_rows: u64) -> u64 {
        let base = instr_cycles(instr, self.p_sys);
        if base == 0 {
            return 0;
        }
        match instr {
            Instr::Gemm { rows, cols, .. } => {
                // Output-stationary systolic: fill+drain of 2*p per tile.
                let tiles = (*rows as u64).div_ceil(self.p_sys as u64)
                    * (*cols as u64).div_ceil(self.p_sys as u64);
                base + tiles * 2 * self.p_sys as u64 + MODE_SWITCH_CYCLES
            }
            Instr::Spdmm { .. } => {
                let lanes = self.p_sys / 2;
                let raw = stall_factor(out_rows, lanes, self.ur_depth, self.raw_reorder);
                (base as f64 * raw / self.eta_shuffle).ceil() as u64 + MODE_SWITCH_CYCLES
            }
            Instr::Sddmm { .. } => {
                (base as f64 / self.eta_shuffle).ceil() as u64 + MODE_SWITCH_CYCLES
            }
            _ => base + MODE_SWITCH_CYCLES,
        }
    }

    /// Density-aware effective cycles (Dynasparse-style): consult the
    /// threshold table for this instruction's Tiling Block and charge
    /// the re-mapped mode when the cycle model says it is strictly
    /// cheaper (including [`REMAP_DECISION_CYCLES`]). Returns the
    /// charged cycles and whether a re-map happened.
    ///
    /// Only GEMM<->SpDMM re-map (they compute the same weighted sum).
    /// The adjacency-tile density is exact (`n_edges` over the tile
    /// area, with `out_rows` standing in for both tile dimensions —
    /// Fiber-Shard subshards are N1-square except at the graph edge);
    /// the feature density comes from the compiler's analytic estimate
    /// in the table entry. Because a re-map is only accepted when
    /// modeled cheaper, dynamic simulation is never slower than static.
    ///
    /// Granularity caveat: decisions are per *buffered chunk* — a
    /// subshard larger than the Edge Buffer arrives as several SpDMM
    /// instructions, each seeing its own `n_edges` over the full tile
    /// area, so an over-capacity dense tile under-reports density and
    /// keeps its edge-stream mapping. That is the implementable
    /// contract (the ACK only ever re-maps work that is resident in
    /// its buffers), and the `<` guard keeps it conservative: a chunk
    /// whose dense equivalent does not pay for itself is never
    /// re-mapped.
    pub fn cycles_dynamic(
        &self,
        instr: &Instr,
        out_rows: u64,
        tt: &ThresholdTable,
        entry: Option<&ThresholdEntry>,
    ) -> (u64, bool) {
        let static_cycles = self.cycles(instr, out_rows);
        match *instr {
            Instr::Spdmm { n_edges, feat, act, .. } => {
                let src_rows = out_rows.max(1);
                let d = tile_density(n_edges as u64, out_rows.max(1), src_rows);
                let provisional =
                    entry.map(|e| e.provisional).unwrap_or(KernelMode::Spdmm);
                if choose_mode(provisional, d, tt) == KernelMode::Gemm {
                    let dense = Instr::Gemm {
                        rows: out_rows.min(u32::MAX as u64) as u32,
                        len: src_rows.min(u16::MAX as u64) as u16,
                        cols: feat,
                        act,
                        accumulate: true,
                    };
                    let dynamic = self.cycles(&dense, out_rows) + REMAP_DECISION_CYCLES;
                    if dynamic < static_cycles {
                        return (dynamic, true);
                    }
                }
                (static_cycles, false)
            }
            Instr::Gemm { rows, len, cols, act, .. } => {
                let fd = entry.map(|e| e.feat_density).unwrap_or(1.0);
                if choose_mode(KernelMode::Gemm, fd, tt) == KernelMode::Spdmm {
                    // Nonzeros of the input tile as an equivalent edge
                    // stream through the SpDMM path.
                    let ne = (fd as f64 * rows as f64 * len as f64)
                        .min(u32::MAX as f64) as u32;
                    let sparse = Instr::Spdmm {
                        n_edges: ne,
                        feat: cols,
                        aggop: AggOp::Sum,
                        act,
                    };
                    let dynamic = self.cycles(&sparse, out_rows) + REMAP_DECISION_CYCLES;
                    if dynamic < static_cycles {
                        return (dynamic, true);
                    }
                }
                (static_cycles, false)
            }
            _ => (static_cycles, false),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Activation, AggOp};

    fn model() -> AckModel {
        AckModel::from_hw(&HwConfig::alveo_u250())
    }

    #[test]
    fn gemm_close_to_ideal() {
        let m = model();
        let g = Instr::Gemm {
            rows: 16384,
            len: 256,
            cols: 256,
            act: Activation::Relu,
            accumulate: false,
        };
        let eff = m.cycles(&g, 16384);
        let ideal = instr_cycles(&g, 16);
        // Fill/drain adds < 15% on a 256-deep K loop.
        assert!(eff >= ideal && (eff as f64) < ideal as f64 * 1.15,
            "eff {eff} ideal {ideal}");
    }

    #[test]
    fn spdmm_derates_but_bounded() {
        let m = model();
        let s = Instr::Spdmm {
            n_edges: 65536,
            feat: 16,
            aggop: AggOp::Sum,
            act: Activation::None,
        };
        let eff = m.cycles(&s, 16384);
        let ideal = instr_cycles(&s, 16);
        let ratio = eff as f64 / ideal as f64;
        assert!((1.0..4.0).contains(&ratio), "spdmm derate {ratio}");
    }

    #[test]
    fn sddmm_has_no_raw_penalty() {
        let m = model();
        let edges = 10_000;
        let sd = Instr::Sddmm { n_edges: edges, feat: 64, act: Activation::None };
        let sp = Instr::Spdmm {
            n_edges: edges,
            feat: 64,
            aggop: AggOp::Sum,
            act: Activation::None,
        };
        // On a tiny tile (RAW-heavy), SpDMM must be slower than SDDMM.
        assert!(m.cycles(&sp, 64) > m.cycles(&sd, 64));
    }

    #[test]
    fn zero_cost_for_memory_instrs() {
        let m = model();
        let r = Instr::MemRead {
            buf: crate::isa::BufferId::Edge0,
            addr: 0,
            bytes: 1 << 20,
            lock: true,
        };
        assert_eq!(m.cycles(&r, 16384), 0);
    }

    #[test]
    fn dynamic_remaps_dense_tiles_and_never_charges_more() {
        let m = model();
        let tt = ThresholdTable {
            dense_hi: 0.125,
            sparse_lo: 0.0625,
            entries: vec![],
        };
        // A 256x256 tile at density 0.75: the edge stream alone exceeds
        // the dense GEMM trip count, so the re-map must win for any
        // measured shuffle throughput.
        let dense = Instr::Spdmm {
            n_edges: 49152,
            feat: 16,
            aggop: AggOp::Sum,
            act: Activation::None,
        };
        let (dc, remapped) = m.cycles_dynamic(&dense, 256, &tt, None);
        let sc = m.cycles(&dense, 256);
        assert!(remapped, "0.75-dense tile must re-map to GEMM");
        assert!(dc < sc, "re-mapped {dc} must beat static {sc}");
        // A Reddit-scale sparse tile stays on the static mapping, at
        // exactly the static cost.
        let sparse = Instr::Spdmm {
            n_edges: 65536,
            feat: 16,
            aggop: AggOp::Sum,
            act: Activation::None,
        };
        let (c, r) = m.cycles_dynamic(&sparse, 16384, &tt, None);
        assert!(!r);
        assert_eq!(c, m.cycles(&sparse, 16384));
        // Non-remappable instructions pass through untouched.
        let v = Instr::Vadd { rows: 128, cols: 16, act: Activation::None };
        assert_eq!(m.cycles_dynamic(&v, 128, &tt, None), (m.cycles(&v, 128), false));
    }

    #[test]
    fn int8_widening_speeds_up_every_compute_mode() {
        let m = model();
        let w = m.int8_widened();
        assert_eq!(w.p_sys, 2 * m.p_sys);
        let g = Instr::Gemm {
            rows: 4096,
            len: 256,
            cols: 256,
            act: Activation::Relu,
            accumulate: false,
        };
        let s = Instr::Spdmm {
            n_edges: 65536,
            feat: 64,
            aggop: AggOp::Sum,
            act: Activation::None,
        };
        assert!(w.cycles(&g, 4096) < m.cycles(&g, 4096));
        assert!(w.cycles(&s, 4096) < m.cycles(&s, 4096));
    }

    #[test]
    fn eta_cached_and_sane() {
        let a = shuffle_eta(16, 4);
        let b = shuffle_eta(16, 4);
        assert_eq!(a.to_bits(), b.to_bits());
        assert!((0.3..=1.0).contains(&a), "eta {a}");
    }
}
