//! RAW Unit model (paper Sec. 7, Fig. 13).
//!
//! In SpDMM mode the Gather (Reduce) units read-modify-write vertex
//! accumulators in the Feature Buffer. When two in-flight edges target
//! the same destination vertex within the UR pipeline depth, the second
//! must wait for the first to retire — a read-after-write hazard. The
//! hardware inserts a reorder buffer (FIFO) that parks conflicting edges
//! so independent ones can proceed; only when the reorder buffer is
//! exhausted does the pipeline stall.
//!
//! Two models:
//! * [`stall_factor`] — analytic expected slow-down under uniformly
//!   random destinations (the macro model's input),
//! * [`simulate_stream`] — an explicit pipeline simulation used to
//!   validate the analytic curve and to expose worst cases (star graphs).

/// Analytic expected slow-down factor (>= 1.0) for edge-centric SpDMM:
/// `lanes` destinations issue per cycle into a pipeline `depth` deep,
/// over an output tile of `rows` vertices, with a reorder buffer of
/// `reorder` entries that hides that many conflicting edges.
///
/// P(conflict for one edge) = 1 - (1 - 1/rows)^(lanes * depth): the
/// probability some in-flight edge holds the same accumulator. Each
/// unhidden conflict costs ~depth/2 extra cycles for its lane group.
pub fn stall_factor(rows: u64, lanes: usize, depth: usize, reorder: usize) -> f64 {
    if rows == 0 {
        return 1.0;
    }
    let in_flight = (lanes * depth) as f64;
    let p_conflict = 1.0 - (1.0 - 1.0 / rows as f64).powf(in_flight);
    // The reorder buffer hides conflicts as long as independent edges are
    // available; its effectiveness decays as conflicts saturate it.
    let hidden = (reorder as f64 / (reorder as f64 + in_flight * p_conflict)).min(1.0);
    let visible = p_conflict * (1.0 - hidden);
    1.0 + visible * depth as f64 / 2.0
}

/// Cycle-accurate pipeline: feed `dsts` one batch of `lanes` per cycle;
/// a destination already in flight (issued < `depth` cycles ago) stalls
/// its batch unless the reorder buffer (capacity `reorder`) can park it.
/// Returns total cycles.
pub fn simulate_stream(dsts: &[u32], lanes: usize, depth: usize, reorder: usize) -> u64 {
    use std::collections::VecDeque;
    // (destination, retire_cycle): an issued edge holds its accumulator
    // for `depth` cycles (the UR pipeline latency).
    let mut in_flight: VecDeque<(u32, u64)> = VecDeque::new();
    let mut parked: VecDeque<u32> = VecDeque::new();
    let mut cycles = 0u64;
    let mut i = 0usize;
    while i < dsts.len() || !parked.is_empty() || !in_flight.is_empty() {
        cycles += 1;
        // Retire edges whose pipeline latency has elapsed.
        while in_flight.front().is_some_and(|&(_, r)| r <= cycles) {
            in_flight.pop_front();
        }
        let busy = |q: &VecDeque<(u32, u64)>, d: u32| q.iter().any(|&(x, _)| x == d);
        let mut issued = 0;
        // Parked edges retry first (in order).
        while issued < lanes {
            match parked.front() {
                Some(&d) if !busy(&in_flight, d) => {
                    parked.pop_front();
                    in_flight.push_back((d, cycles + depth as u64));
                    issued += 1;
                }
                _ => break, // head-of-line blocked or empty
            }
        }
        while issued < lanes && i < dsts.len() {
            let d = dsts[i];
            if busy(&in_flight, d) || parked.contains(&d) {
                if parked.len() < reorder {
                    parked.push_back(d);
                    i += 1;
                    continue; // parked; the lane can take the next edge
                } else {
                    break; // stall: reorder buffer full
                }
            }
            in_flight.push_back((d, cycles + depth as u64));
            i += 1;
            issued += 1;
        }
    }
    cycles
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn factor_bounds() {
        // Huge tile: conflicts vanish.
        let f = stall_factor(16384, 8, 8, 16);
        assert!(f < 1.05, "large-tile factor {f}");
        // Tiny tile: conflicts everywhere, factor grows but stays finite.
        let g = stall_factor(4, 8, 8, 16);
        assert!(g > 1.05 && g < 1.0 + 8.0, "small-tile factor {g}");
        assert_eq!(stall_factor(0, 8, 8, 16), 1.0);
    }

    #[test]
    fn factor_monotone_in_rows() {
        let f1 = stall_factor(16, 8, 8, 16);
        let f2 = stall_factor(256, 8, 8, 16);
        let f3 = stall_factor(4096, 8, 8, 16);
        assert!(f1 >= f2 && f2 >= f3, "{f1} {f2} {f3}");
    }

    #[test]
    fn uniform_stream_near_ideal() {
        let mut rng = Rng::new(1);
        let dsts: Vec<u32> = (0..8000).map(|_| rng.below(16384) as u32).collect();
        let cycles = simulate_stream(&dsts, 8, 8, 16);
        let ideal = (dsts.len() / 8) as u64;
        assert!(
            cycles < ideal * 13 / 10,
            "uniform stream {cycles} vs ideal {ideal}"
        );
    }

    #[test]
    fn star_stream_serializes() {
        // Every edge hits vertex 0: the pipeline degrades toward one edge
        // per `depth`-ish cycles; must be far worse than uniform.
        let dsts = vec![0u32; 2000];
        let star = simulate_stream(&dsts, 8, 8, 16);
        let mut rng = Rng::new(2);
        let uni: Vec<u32> = (0..2000).map(|_| rng.below(16384) as u32).collect();
        let uniform = simulate_stream(&uni, 8, 8, 16);
        assert!(star > uniform * 4, "star {star} uniform {uniform}");
    }

    #[test]
    fn reorder_buffer_helps() {
        let mut rng = Rng::new(3);
        // Moderately skewed: 32 distinct destinations.
        let dsts: Vec<u32> = (0..4000).map(|_| rng.below(32) as u32).collect();
        let none = simulate_stream(&dsts, 8, 8, 0);
        let some = simulate_stream(&dsts, 8, 8, 32);
        assert!(some <= none, "reorder {some} vs none {none}");
    }

    #[test]
    fn analytic_tracks_simulation_uniform() {
        // The analytic factor should land within ~35% of the simulated
        // slow-down for uniform traffic across tile sizes.
        let mut rng = Rng::new(4);
        for rows in [64u64, 1024, 16384] {
            let dsts: Vec<u32> =
                (0..16000).map(|_| rng.below(rows) as u32).collect();
            let cycles = simulate_stream(&dsts, 8, 8, 16) as f64;
            let ideal = (dsts.len() / 8) as f64;
            let sim_factor = cycles / ideal;
            let ana = stall_factor(rows, 8, 8, 16);
            assert!(
                (sim_factor / ana - 1.0).abs() < 0.35,
                "rows={rows}: sim {sim_factor:.3} vs analytic {ana:.3}"
            );
        }
    }
}
