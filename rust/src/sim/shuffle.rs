//! Butterfly shuffle networks (paper Sec. 5.5, Fig. 12; HBM-Connect
//! style [29]).
//!
//! The ISN routes p_sys edge indices per cycle to the Feature Buffer
//! banks; the DSN routes the fetched (feature, edge) pairs to the UR
//! pipelines. Both are log2(p)-stage butterflies of 2x2 switches with
//! small FIFOs that absorb transient congestion.
//!
//! This module simulates the network switch-by-switch: used directly by
//! the unit tests (any permutation routes; skewed traffic degrades) and
//! by [`uniform_throughput`], whose measured edges/cycle calibrates the
//! macro cycle model in [`super::ack`].

use crate::util::Rng;
use std::collections::VecDeque;

/// One butterfly network instance of radix `p` (power of two).
pub struct Butterfly {
    p: usize,
    stages: usize,
    fifo_depth: usize,
    /// fifos[stage][port]: packets waiting at the input of `stage`.
    fifos: Vec<Vec<VecDeque<Packet>>>,
    /// Packets that reached their output this cycle.
    pub delivered: Vec<Packet>,
    cycles: u64,
}

/// A routed packet: `dest` is the target bank/port.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Packet {
    pub dest: usize,
    pub tag: u64,
}

impl Butterfly {
    pub fn new(p: usize, fifo_depth: usize) -> Butterfly {
        assert!(p.is_power_of_two() && p >= 2);
        let stages = p.trailing_zeros() as usize;
        Butterfly {
            p,
            stages,
            fifo_depth,
            fifos: vec![vec![VecDeque::new(); p]; stages + 1],
            delivered: Vec::new(),
            cycles: 0,
        }
    }

    /// Try to inject a packet at input `port`; false if the stage-0 FIFO
    /// is full (back-pressure to the Edge Buffer).
    pub fn inject(&mut self, port: usize, pkt: Packet) -> bool {
        if self.fifos[0][port].len() >= self.fifo_depth {
            return false;
        }
        self.fifos[0][port].push_back(pkt);
        true
    }

    /// Advance one cycle: each 2x2 switch forwards at most one packet per
    /// output port per cycle (the source of congestion under conflicts).
    pub fn step(&mut self) {
        self.cycles += 1;
        // Walk stages back-to-front so a packet moves one stage per cycle.
        for s in (0..self.stages).rev() {
            // Pair width at stage s: ports differing in bit
            // (stages-1-s) form a switch.
            let bit = self.stages - 1 - s;
            let mask = 1usize << bit;
            let mut granted: Vec<Option<usize>> = vec![None; self.p]; // out port -> in port
            for port in 0..self.p {
                if let Some(pkt) = self.fifos[s][port].front() {
                    // Output port at this stage: keep all bits, set bit
                    // `bit` to the destination's bit.
                    let want_bit = (pkt.dest >> bit) & 1;
                    let out = (port & !mask) | (want_bit << bit);
                    // Next stage FIFO must have room; port priority: lower
                    // input wins (round-robin omitted for determinism).
                    let room = if s + 1 == self.stages {
                        true // delivery stage
                    } else {
                        self.fifos[s + 1][out].len() < self.fifo_depth
                    };
                    if room && granted[out].is_none() {
                        granted[out] = Some(port);
                    }
                }
            }
            for out in 0..self.p {
                if let Some(inp) = granted[out] {
                    let pkt = self.fifos[s][inp].pop_front().unwrap();
                    if s + 1 == self.stages {
                        debug_assert_eq!(
                            out, pkt.dest,
                            "butterfly misroute: port {out} != dest {}",
                            pkt.dest
                        );
                        self.delivered.push(pkt);
                    } else {
                        self.fifos[s + 1][out].push_back(pkt);
                    }
                }
            }
        }
    }

    pub fn is_empty(&self) -> bool {
        self.fifos.iter().all(|st| st.iter().all(|f| f.is_empty()))
    }

    pub fn cycles(&self) -> u64 {
        self.cycles
    }

    /// Route a whole batch list: returns cycles until all delivered.
    /// `batches[i]` is the set of (input port, dest) injected together.
    pub fn route_all(&mut self, batches: &[Vec<(usize, usize)>]) -> u64 {
        let start = self.cycles;
        let mut tag = 0u64;
        let mut pending: VecDeque<&Vec<(usize, usize)>> = batches.iter().collect();
        let mut current: Vec<(usize, Packet)> = Vec::new();
        loop {
            // Refill the injection window from the next batch.
            if current.is_empty() {
                if let Some(batch) = pending.pop_front() {
                    current = batch
                        .iter()
                        .map(|&(port, dest)| {
                            tag += 1;
                            (port, Packet { dest, tag })
                        })
                        .collect();
                }
            }
            // Inject whatever fits this cycle.
            current.retain(|&(port, pkt)| !self.inject(port, pkt));
            self.step();
            if current.is_empty() && pending.is_empty() && self.is_empty() {
                return self.cycles - start;
            }
        }
    }
}

/// Measured uniform-random throughput (delivered packets per cycle) of a
/// radix-`p` butterfly with `fifo_depth` FIFOs — the calibration constant
/// for the SpDMM/SDDMM cycle derate. Deterministic in `seed`.
pub fn uniform_throughput(p: usize, fifo_depth: usize, seed: u64) -> f64 {
    let mut net = Butterfly::new(p, fifo_depth);
    let mut rng = Rng::new(seed);
    let n_batches = 512;
    let batches: Vec<Vec<(usize, usize)>> = (0..n_batches)
        .map(|_| {
            (0..p)
                .map(|port| (port, rng.below(p as u64) as usize))
                .collect()
        })
        .collect();
    let cycles = net.route_all(&batches);
    let total = (n_batches * p) as f64;
    total / cycles as f64 / p as f64 // fraction of ideal (p per cycle)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::forall;

    #[test]
    fn identity_permutation_is_full_rate() {
        let mut net = Butterfly::new(8, 4);
        let batches: Vec<Vec<(usize, usize)>> =
            (0..64).map(|_| (0..8).map(|i| (i, i)).collect()).collect();
        let cycles = net.route_all(&batches);
        // Pipeline: 64 batches + log2(8) drain.
        assert!(cycles <= 64 + 3 + 1, "cycles {cycles}");
        assert_eq!(net.delivered.len(), 64 * 8);
    }

    #[test]
    fn prop_any_permutation_routes_correctly() {
        forall("butterfly-permutation", 40, |rng| {
            let p = 1 << rng.range(1, 6); // 2..32
            let mut perm: Vec<usize> = (0..p).collect();
            rng.shuffle(&mut perm);
            let mut net = Butterfly::new(p, 4);
            let batch: Vec<(usize, usize)> =
                (0..p).map(|i| (i, perm[i])).collect();
            net.route_all(std::slice::from_ref(&batch));
            crate::prop_assert!(
                net.delivered.len() == p,
                "delivered {} of {p}",
                net.delivered.len()
            );
            for pkt in &net.delivered {
                crate::prop_assert!(
                    perm.contains(&pkt.dest),
                    "bogus dest {}",
                    pkt.dest
                );
            }
            Ok(())
        });
    }

    #[test]
    fn hotspot_traffic_serializes() {
        // All packets to bank 0: throughput collapses to ~1/p.
        let p = 16;
        let mut net = Butterfly::new(p, 4);
        let batches: Vec<Vec<(usize, usize)>> =
            (0..32).map(|_| (0..p).map(|i| (i, 0usize)).collect()).collect();
        let cycles = net.route_all(&batches);
        assert!(cycles >= (32 * p) as u64, "hotspot cycles {cycles}");
    }

    #[test]
    fn uniform_throughput_reasonable() {
        for p in [8usize, 16, 32] {
            let eta = uniform_throughput(p, 4, 42);
            assert!(
                (0.3..=1.0).contains(&eta),
                "p={p}: eta={eta} out of expected band"
            );
        }
    }

    #[test]
    fn throughput_deterministic_in_seed() {
        assert_eq!(
            uniform_throughput(16, 4, 7).to_bits(),
            uniform_throughput(16, 4, 7).to_bits()
        );
    }

    #[test]
    fn deeper_fifos_do_not_hurt() {
        let shallow = uniform_throughput(16, 2, 11);
        let deep = uniform_throughput(16, 8, 11);
        assert!(deep >= shallow * 0.95, "shallow {shallow} deep {deep}");
    }
}
