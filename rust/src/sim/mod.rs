//! Cycle-level simulator of the GraphAGILE overlay (paper Sec. 5 and 7).
//!
//! The paper evaluates its Alveo U250 design with a cycle-accurate
//! simulator plus Ramulator for DDR; this module is the same kind of
//! artifact. It consumes the **compiled binary** (`isa::Program`) — not
//! the IR — so everything it times went through the real ISA encoding:
//!
//! * [`shuffle`] — the butterfly Index/Data Shuffle Networks (Sec. 5.5,
//!   Fig. 12), simulated switch-by-switch; the measured uniform-traffic
//!   throughput calibrates the SpDMM/SDDMM derate,
//! * [`raw`] — the RAW Unit (Sec. 7, Fig. 13): read-after-write hazard
//!   stalls with a reorder buffer,
//! * [`ack`] — effective cycles per compute instruction: microcode trip
//!   counts (Alg. 1–3) x shuffle/RAW derates,
//! * [`ddr`] — FPGA DDR channel model (77 GB/s over 4 channels),
//! * [`pcie`] — host-to-FPGA transfer for T_comm,
//! * [`scheduler`] — dynamic Tiling-Block-to-PE assignment (Alg. 9),
//! * [`engine`] — the full run: per-block compute/memory overlap (double
//!   / triple buffering), per-layer barriers, LoH.
//!
//! Two entry points: [`simulate`] charges the static compile-time kernel
//! mapping; [`simulate_dynamic`] additionally consults the program's
//! density-threshold table (`crate::sparsity`) and charges each compute
//! instruction at the cheaper of its encoded mode and the
//! density-selected re-map — never slower than static by construction.

pub mod ack;
pub mod ddr;
pub mod engine;
pub mod pcie;
pub mod raw;
pub mod scheduler;
pub mod shuffle;

pub use engine::{simulate, simulate_dynamic, simulate_with, LayerSim, SimResult};
pub use pcie::comm_seconds;
