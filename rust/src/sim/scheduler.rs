//! Dynamic task scheduling (paper Alg. 9): the Scheduler reads the CSI
//! of the current Layer Block and assigns each Tiling Block to the first
//! idle PE; a layer barrier separates Layer Blocks.
//!
//! Equivalent discrete-event formulation: blocks are assigned in program
//! order to the earliest-available PE (PEs signal Idle/Busy with a 1-bit
//! port; "first idle" == earliest available in event time).

/// Greedy earliest-idle-PE schedule. Returns (makespan, per-PE busy time).
pub fn schedule_blocks(durations: &[u64], n_pe: usize) -> (u64, Vec<u64>) {
    assert!(n_pe > 0);
    let mut avail = vec![0u64; n_pe];
    let mut busy = vec![0u64; n_pe];
    for &d in durations {
        // Earliest-available PE (ties: lowest index, like the priority
        // encoder on the Idle bit-vector).
        let (pe, _) = avail
            .iter()
            .enumerate()
            .min_by_key(|&(i, &t)| (t, i))
            .unwrap();
        avail[pe] += d;
        busy[pe] += d;
    }
    (avail.into_iter().max().unwrap_or(0), busy)
}

/// Load-balance quality: makespan / (sum/n_pe); 1.0 is perfect.
pub fn imbalance(durations: &[u64], n_pe: usize) -> f64 {
    let (makespan, busy) = schedule_blocks(durations, n_pe);
    let total: u64 = busy.iter().sum();
    if total == 0 {
        return 1.0;
    }
    makespan as f64 / (total as f64 / n_pe as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::forall;

    #[test]
    fn empty_and_trivial() {
        assert_eq!(schedule_blocks(&[], 4).0, 0);
        assert_eq!(schedule_blocks(&[10], 4).0, 10);
    }

    #[test]
    fn equal_blocks_balance_perfectly() {
        let durations = vec![5u64; 16];
        let (makespan, busy) = schedule_blocks(&durations, 8);
        assert_eq!(makespan, 10);
        assert!(busy.iter().all(|&b| b == 10));
    }

    #[test]
    fn one_giant_block_dominates() {
        let (makespan, _) = schedule_blocks(&[100, 1, 1, 1], 4);
        assert_eq!(makespan, 100);
    }

    #[test]
    fn prop_makespan_bounds() {
        // Greedy list scheduling: max(d) <= makespan <= sum/n + max(d).
        forall("greedy-bounds", 60, |rng| {
            let n = rng.range(1, 200) as usize;
            let n_pe = rng.range(1, 16) as usize;
            let durations: Vec<u64> = (0..n).map(|_| rng.range(0, 10_000)).collect();
            let (makespan, busy) = schedule_blocks(&durations, n_pe);
            let total: u64 = durations.iter().sum();
            let dmax = *durations.iter().max().unwrap();
            crate::prop_assert!(
                makespan >= dmax && makespan >= total / n_pe as u64,
                "lower bound violated: makespan {makespan}, dmax {dmax}"
            );
            crate::prop_assert!(
                makespan <= total / n_pe as u64 + dmax + 1,
                "greedy upper bound violated: {makespan} > {} + {dmax}",
                total / n_pe as u64
            );
            let busy_total: u64 = busy.iter().sum();
            crate::prop_assert!(busy_total == total, "lost work");
            Ok(())
        });
    }

    #[test]
    fn imbalance_reasonable_for_many_blocks() {
        let durations: Vec<u64> = (0..500).map(|i| 100 + (i % 37)).collect();
        let ib = imbalance(&durations, 8);
        assert!(ib < 1.05, "imbalance {ib}");
    }
}
