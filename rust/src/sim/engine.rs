//! The whole-accelerator simulation: executes a compiled [`Program`]
//! layer by layer (Alg. 9's barrier semantics), assigning Tiling Blocks
//! to PEs dynamically and overlapping each block's computation with its
//! DDR traffic via the double/triple buffering the hardware implements
//! (Sec. 6.6). Produces the latency-of-hardware-execution (T_LoH).

use super::ack::AckModel;
use super::ddr::DdrModel;
use super::scheduler::schedule_blocks;
use crate::config::HwConfig;
use crate::isa::{BufferId, Instr, Program, TilingBlock};
use crate::sparsity::{ThresholdEntry, ThresholdTable};

/// Per-layer simulation result.
#[derive(Clone, Debug)]
pub struct LayerSim {
    pub layer_id: u16,
    pub layer_type: u8,
    pub n_blocks: usize,
    /// Layer wall-clock cycles (after the PE barrier).
    pub cycles: u64,
    /// Sum of ACK-busy cycles over all blocks.
    pub compute_cycles: u64,
    /// Sum of DDR bytes moved.
    pub mem_bytes: u64,
    /// Compute instructions re-mapped to a cheaper kernel mode (dynamic
    /// simulation only; 0 under static mapping).
    pub remaps: u64,
}

/// Whole-run result.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub cycles: u64,
    pub layers: Vec<LayerSim>,
    pub freq_hz: f64,
    /// Total ACK-busy cycles across PEs (utilization numerator).
    pub total_compute_cycles: u64,
    pub total_mem_bytes: u64,
    pub n_pe: usize,
    /// Total density-driven kernel re-maps across the run.
    pub remaps: u64,
    /// Tiling Blocks charged on the int8 datapath (0 for programs
    /// without a GA03 scale section).
    pub quant_blocks: u64,
    /// Modeled requantize/dequantize epilogues (one per quantized
    /// compute instruction; fused into the activation step, so they
    /// cost no extra cycles but are counted for the serving profile).
    pub requant_ops: u64,
    /// Operand bytes actually moved at 1 byte/element on quantized
    /// layers (after the 4x shrink; edge-index traffic is excluded).
    pub int8_bytes: u64,
}

impl SimResult {
    /// Latency of hardware execution in seconds.
    pub fn loh_seconds(&self) -> f64 {
        self.cycles as f64 / self.freq_hz
    }

    pub fn loh_ms(&self) -> f64 {
        self.loh_seconds() * 1e3
    }

    /// Average ACK utilization across the run (0..=1).
    pub fn utilization(&self) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.total_compute_cycles as f64 / (self.cycles * self.n_pe as u64) as f64
    }

    /// Effective throughput in GFLOP/s given the model's total flops.
    pub fn gflops(&self, total_flops: u64) -> f64 {
        total_flops as f64 / self.loh_seconds() / 1e9
    }
}

/// Output tile height for the RAW conflict domain: the Init (Aggregate)
/// or Gemm/Vadd/Act rows; defaults to N1.
fn out_rows(block: &TilingBlock, n1: u64) -> u64 {
    for i in &block.instrs {
        match i {
            Instr::Init { rows, .. }
            | Instr::Gemm { rows, .. }
            | Instr::Vadd { rows, .. }
            | Instr::Act { rows, .. } => return *rows as u64,
            _ => {}
        }
    }
    n1
}

/// Per-block simulation cost, including quantized-datapath counters.
struct BlockCost {
    duration: u64,
    compute: u64,
    bytes: u64,
    remaps: u64,
    requants: u64,
    int8_bytes: u64,
}

/// Duration of one Tiling Block on one PE. `remap` carries the threshold
/// table (and this layer's entry) when density-aware re-mapping is on;
/// re-mapped instructions are charged at their cheaper mode. `quant`
/// carries the int8-widened ACK when this layer executes quantized:
/// compute is charged at the wider SIMD width and Weight/Feature/Result
/// buffer traffic at 1 byte per element (edge indices stay u32).
fn block_cycles(
    block: &TilingBlock,
    ack: &AckModel,
    ddr: &DdrModel,
    hw: &HwConfig,
    overlap: bool,
    remap: Option<(&ThresholdTable, Option<&ThresholdEntry>)>,
    quant: Option<&AckModel>,
) -> BlockCost {
    let rows = out_rows(block, hw.n1() as u64);
    let ack = quant.unwrap_or(ack);
    let mut compute = 0u64;
    let mut mem = 0u64;
    let mut bytes = 0u64;
    let mut first_load = 0u64;
    let mut remaps = 0u64;
    let mut requants = 0u64;
    let mut int8_bytes = 0u64;
    for instr in &block.instrs {
        match instr {
            Instr::MemRead { buf, bytes: b, .. } | Instr::MemWrite { buf, bytes: b, .. } => {
                let edge = matches!(buf, BufferId::Edge0 | BufferId::Edge1);
                let (t, moved) = if quant.is_some() && !edge {
                    (
                        ddr.transfer_cycles_elem(*b as u64, 1, hw.n_pe),
                        *b as u64 / 4,
                    )
                } else {
                    (ddr.transfer_cycles(*b as u64, hw.n_pe), *b as u64)
                };
                if quant.is_some() && !edge {
                    int8_bytes += moved;
                }
                if first_load == 0 {
                    first_load = t;
                }
                mem += t;
                bytes += moved;
            }
            _ => {
                match remap {
                    Some((tt, entry)) => {
                        let (c, remapped) = ack.cycles_dynamic(instr, rows, tt, entry);
                        compute += c;
                        remaps += remapped as u64;
                    }
                    None => compute += ack.cycles(instr, rows),
                }
                // Every quantized compute instruction carries a fused
                // requantize/dequantize epilogue (counted, not charged:
                // it rides the activation pipeline stage).
                if quant.is_some()
                    && matches!(
                        instr,
                        Instr::Gemm { .. } | Instr::Spdmm { .. } | Instr::Sddmm { .. }
                    )
                {
                    requants += 1;
                }
            }
        }
    }
    // Instruction issue: one cycle per instruction through the decoder.
    let decode = block.instrs.len() as u64;
    let serial = compute + mem + decode;
    let duration = if overlap {
        // Double/triple buffering pipelines loads against compute; the
        // first load cannot be hidden (pipeline fill), and the mutex
        // (WAR) protocol serializes at buffer granularity — modeled by
        // the max() with fill. Never worse than serial issue (tiny tiles
        // where the fill term would dominate just run serially).
        (compute.max(mem) + first_load + decode).min(serial)
    } else {
        serial
    };
    BlockCost { duration, compute, bytes, remaps, requants, int8_bytes }
}

/// Simulate the program with the *static* compile-time kernel mapping
/// (every instruction charged at its encoded mode).
pub fn simulate(program: &Program, hw: &HwConfig) -> SimResult {
    simulate_with(program, hw, false)
}

/// Simulate with density-aware dynamic kernel re-mapping: when the
/// program carries a threshold table (the GA02 section), each compute
/// instruction is charged at the cheaper of its encoded mode and the
/// density-selected alternative (`sparsity::choose_mode` gated by the
/// cycle model). Falls back to static simulation for legacy binaries.
/// By construction never slower than [`simulate`].
pub fn simulate_dynamic(program: &Program, hw: &HwConfig) -> SimResult {
    simulate_with(program, hw, true)
}

/// Shared implementation of [`simulate`] / [`simulate_dynamic`].
pub fn simulate_with(program: &Program, hw: &HwConfig, dynamic: bool) -> SimResult {
    let ack = AckModel::from_hw(hw);
    let ddr = DdrModel::from_hw(hw);
    let tt = if dynamic { program.thresholds.as_ref() } else { None };
    // A GA03 program executes its calibrated layers on the int8
    // datapath: one widened ACK serves every quantized layer.
    let ack_i8 = program.scales.as_ref().map(|_| ack.int8_widened());
    let mut layers = Vec::with_capacity(program.layers.len());
    let mut total = 0u64;
    let mut total_compute = 0u64;
    let mut total_bytes = 0u64;
    let mut total_remaps = 0u64;
    let mut quant_blocks = 0u64;
    let mut requant_ops = 0u64;
    let mut int8_bytes = 0u64;
    for lb in &program.layers {
        let (layer_id, layer_type) = match lb.csi {
            Instr::Csi { layer_id, layer_type, .. } => (layer_id, layer_type),
            _ => (0, 0),
        };
        let remap = tt.map(|t| (t, t.entry(layer_id)));
        let quant = match (&ack_i8, &program.scales) {
            (Some(w), Some(st)) if st.entry(layer_id).is_some() => Some(w),
            _ => None,
        };
        let mut durations = Vec::with_capacity(lb.blocks.len());
        let mut compute_cycles = 0u64;
        let mut mem_bytes = 0u64;
        let mut remaps = 0u64;
        for block in &lb.blocks {
            let c = block_cycles(block, &ack, &ddr, hw, hw.overlap, remap, quant);
            durations.push(c.duration);
            compute_cycles += c.compute;
            mem_bytes += c.bytes;
            remaps += c.remaps;
            requant_ops += c.requants;
            int8_bytes += c.int8_bytes;
            if quant.is_some() {
                quant_blocks += 1;
            }
        }
        // Alg. 9: CSI dispatch, then dynamic assignment, then barrier.
        let (makespan, _) = schedule_blocks(&durations, hw.n_pe);
        let csi_overhead = 4;
        let cycles = makespan + csi_overhead;
        total += cycles;
        total_compute += compute_cycles;
        total_bytes += mem_bytes;
        total_remaps += remaps;
        layers.push(LayerSim {
            layer_id,
            layer_type,
            n_blocks: lb.blocks.len(),
            cycles,
            compute_cycles,
            mem_bytes,
            remaps,
        });
    }
    SimResult {
        cycles: total,
        layers,
        freq_hz: hw.freq_hz,
        total_compute_cycles: total_compute,
        total_mem_bytes: total_bytes,
        n_pe: hw.n_pe,
        remaps: total_remaps,
        quant_blocks,
        requant_ops,
        int8_bytes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOptions};
    use crate::graph::dataset;
    use crate::ir::ZooModel;

    fn sim(model: ZooModel, ds_key: &str, overlap: bool) -> SimResult {
        let ds = dataset(ds_key).unwrap();
        let hw = HwConfig { overlap, ..HwConfig::alveo_u250() };
        let tiles = ds.tile_counts(hw.n1() as u64);
        let ir = model.build(ds.meta());
        let exe = compile(&ir, &tiles, &hw, CompileOptions::default());
        simulate(&exe.program, &hw)
    }

    #[test]
    fn b1_cora_has_sane_latency() {
        let r = sim(ZooModel::B1, "CO", true);
        let ms = r.loh_ms();
        // Paper: 0.103 ms. Same order of magnitude expected.
        assert!((0.01..5.0).contains(&ms), "b1/CO LoH {ms} ms");
        assert_eq!(r.layers.len(), 4); // after fusion: Agg,Lin,Agg,Lin or LA order
    }

    #[test]
    fn overlap_reduces_latency() {
        let with = sim(ZooModel::B2, "FL", true);
        let without = sim(ZooModel::B2, "FL", false);
        assert!(
            without.cycles > with.cycles,
            "overlap {} vs no-overlap {}",
            with.cycles,
            without.cycles
        );
        // Paper Fig. 16 reports 112%-186% speedup from overlapping.
        let speedup = without.cycles as f64 / with.cycles as f64;
        assert!((1.05..2.5).contains(&speedup), "overlap speedup {speedup}");
    }

    #[test]
    fn bigger_graph_bigger_latency() {
        let co = sim(ZooModel::B1, "CO", true);
        let pu = sim(ZooModel::B1, "PU", true);
        let fl = sim(ZooModel::B1, "FL", true);
        assert!(co.cycles < pu.cycles && pu.cycles < fl.cycles);
    }

    #[test]
    fn wider_model_slower() {
        let b1 = sim(ZooModel::B1, "PU", true);
        let b2 = sim(ZooModel::B2, "PU", true);
        assert!(b1.cycles < b2.cycles);
    }

    #[test]
    fn utilization_bounded() {
        let r = sim(ZooModel::B2, "FL", true);
        let u = r.utilization();
        assert!((0.0..=1.0).contains(&u), "utilization {u}");
    }

    #[test]
    fn dynamic_no_slower_than_static_and_wins_on_dense_tiles() {
        use crate::graph::{rmat_tile_counts, GraphMeta};
        let hw = HwConfig::alveo_u250();
        let grid = [
            GraphMeta::new("rmat-sparse", 4096, 16_384, 64, 8),
            GraphMeta::new("rmat-dense", 256, 49_152, 16, 8),
        ];
        let mut strictly_faster = false;
        // b1's chain aggregates narrow to the class width under order
        // opt (memory-bound either way: re-maps may only tie); b5's GIN
        // aggregates feed a two-parent VectorAdd, stay at hidden width
        // 128, and must win outright on the 0.75-dense cell.
        for meta in &grid {
            for model in [ZooModel::B1, ZooModel::B5] {
                let tiles =
                    rmat_tile_counts(meta, Default::default(), 17, hw.n1() as u64);
                let ir = model.build(meta.clone());
                let exe = compile(&ir, &tiles, &hw, CompileOptions::default());
                let stat = simulate(&exe.program, &hw);
                let dynv = simulate_dynamic(&exe.program, &hw);
                assert!(
                    dynv.cycles <= stat.cycles,
                    "{}/{}: dynamic {} > static {}",
                    model.key(),
                    meta.name,
                    dynv.cycles,
                    stat.cycles
                );
                if dynv.cycles < stat.cycles {
                    strictly_faster = true;
                    assert!(dynv.remaps > 0, "a win requires at least one re-map");
                }
                // Per-layer remap counts sum to the total.
                let per_layer: u64 = dynv.layers.iter().map(|l| l.remaps).sum();
                assert_eq!(per_layer, dynv.remaps);
            }
        }
        assert!(strictly_faster, "the dense cell must beat static mapping somewhere");
        // Legacy binaries (no threshold table) take the static path.
        let meta = &grid[1];
        let tiles = rmat_tile_counts(meta, Default::default(), 17, hw.n1() as u64);
        let ir = ZooModel::B1.build(meta.clone());
        let exe = compile(
            &ir,
            &tiles,
            &hw,
            CompileOptions { dynamic_thresholds: false, ..Default::default() },
        );
        let d = simulate_dynamic(&exe.program, &hw);
        assert_eq!(d.remaps, 0);
        assert_eq!(d.cycles, simulate(&exe.program, &hw).cycles);
    }

    #[test]
    fn dynamic_replay_is_deterministic() {
        let ds = dataset("PU").unwrap();
        let hw = HwConfig::alveo_u250();
        let tiles = ds.tile_counts(hw.n1() as u64);
        let ir = ZooModel::B2.build(ds.meta());
        let exe = compile(&ir, &tiles, &hw, CompileOptions::default());
        let a = simulate_dynamic(&exe.program, &hw);
        let b = simulate_dynamic(&exe.program, &hw);
        assert_eq!((a.cycles, a.remaps), (b.cycles, b.remaps));
    }

    #[test]
    fn quantized_program_is_faster_and_moves_fewer_bytes() {
        use crate::exec::WeightStore;
        use crate::quant::{calibrate, CalibrationProfile};
        let ds = dataset("PU").unwrap();
        let hw = HwConfig::alveo_u250();
        let tiles = ds.tile_counts(hw.n1() as u64);
        let ir = ZooModel::B2.build(ds.meta());
        let exe = compile(&ir, &tiles, &hw, CompileOptions::default());
        let f32_sim = simulate(&exe.program, &hw);
        assert_eq!(f32_sim.quant_blocks, 0);
        assert_eq!(f32_sim.int8_bytes, 0);
        let store = WeightStore::deterministic(&exe.ir, 33);
        let cal = calibrate(
            &exe.ir,
            &store,
            &CalibrationProfile::analytic(ds.n_vertices, ds.n_edges),
        );
        let mut qp = exe.program.clone();
        qp.scales = Some(cal.table);
        let q = simulate(&qp, &hw);
        assert!(q.quant_blocks > 0 && q.requant_ops > 0 && q.int8_bytes > 0);
        assert!(q.cycles < f32_sim.cycles, "int8 {} !< f32 {}", q.cycles, f32_sim.cycles);
        // Operand traffic shrinks 4x; edge indices stay u32, so the
        // total lands well under the f32 bytes on a feature-dominated
        // model (the strict 0.55x floor is enforced by the quant bench).
        assert!(
            (q.total_mem_bytes as f64) < 0.6 * f32_sim.total_mem_bytes as f64,
            "int8 bytes {} vs f32 {}",
            q.total_mem_bytes,
            f32_sim.total_mem_bytes
        );
        // Determinism: same program, same counters.
        let q2 = simulate(&qp, &hw);
        assert_eq!((q.cycles, q.quant_blocks, q.int8_bytes), (q2.cycles, q2.quant_blocks, q2.int8_bytes));
    }

    #[test]
    fn layer_accounting_sums() {
        let r = sim(ZooModel::B1, "PU", true);
        let per_layer: u64 = r.layers.iter().map(|l| l.cycles).sum();
        assert_eq!(per_layer, r.cycles);
        assert!(r.total_mem_bytes > 0);
    }
}
