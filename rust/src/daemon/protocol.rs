//! The daemon's wire protocol: length-prefixed JSON frames over a
//! byte stream.
//!
//! A frame is a 4-byte big-endian `u32` payload length followed by
//! exactly that many bytes of UTF-8 JSON. Frames are capped at
//! [`MAX_FRAME`] bytes so a hostile or corrupt length prefix cannot
//! make the daemon allocate gigabytes. Client frames carry an `"op"`
//! field (`submit` / `churn` / `stats` / `tenants` / `metrics` /
//! `drain` / `shutdown`); the
//! daemon replies with `{"ok": true, ...}` or
//! `{"ok": false, "error": "..."}` — one reply frame per request
//! frame, in order.

use super::trace::{dataset_from, request_from, request_json};
use crate::serve::Request;
use crate::util::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::io::{Read, Write};

/// Frame payload ceiling (1 MiB): larger than any real request — a
/// 60k-target mini-batch fits — while bounding a bad prefix.
pub const MAX_FRAME: u32 = 1 << 20;

/// True when an I/O error is a socket read/write timeout firing (the
/// platform reports it as `WouldBlock` on Unix, `TimedOut` on Windows).
pub fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

/// Write one frame: 4-byte big-endian length, then the JSON payload.
pub fn write_frame(w: &mut impl Write, v: &Json) -> Result<()> {
    let payload = v.to_string();
    let bytes = payload.as_bytes();
    if bytes.len() > MAX_FRAME as usize {
        bail!("frame of {} bytes exceeds MAX_FRAME ({MAX_FRAME})", bytes.len());
    }
    w.write_all(&(bytes.len() as u32).to_be_bytes()).context("writing frame length")?;
    w.write_all(bytes).context("writing frame payload")?;
    w.flush().context("flushing frame")?;
    Ok(())
}

/// Read one frame. `Ok(None)` on a clean EOF at a frame boundary (the
/// peer closed between frames); every torn state is an error naming
/// what was malformed — truncated length prefix, oversized frame,
/// truncated payload, invalid UTF-8, or invalid JSON.
pub fn read_frame(r: &mut impl Read) -> Result<Option<Json>> {
    let mut len_buf = [0u8; 4];
    match r.read_exact(&mut len_buf[..1]) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) if is_timeout(&e) => {
            bail!("read timed out waiting for a frame (silent client)")
        }
        Err(e) => return Err(e).context("reading frame length"),
    }
    r.read_exact(&mut len_buf[1..]).map_err(|e| {
        if is_timeout(&e) {
            anyhow!("read timed out mid-header (client went silent)")
        } else {
            anyhow!("truncated length prefix (connection died mid-header)")
        }
    })?;
    let len = u32::from_be_bytes(len_buf);
    if len > MAX_FRAME {
        bail!("frame length {len} exceeds MAX_FRAME ({MAX_FRAME})");
    }
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload).map_err(|e| {
        if is_timeout(&e) {
            anyhow!("read timed out mid-frame (client went silent after the header)")
        } else {
            anyhow!("truncated frame payload (got fewer than {len} bytes)")
        }
    })?;
    let text = String::from_utf8(payload).map_err(|_| anyhow!("frame payload is not UTF-8"))?;
    let v = Json::parse(&text).context("frame payload is not valid JSON")?;
    Ok(Some(v))
}

/// A decoded client request frame.
#[derive(Clone, Debug, PartialEq)]
pub enum ClientMsg {
    /// Submit an inference request (`arrival` in the payload is
    /// ignored: the daemon stamps real arrival time at admission).
    Submit(Request),
    /// Submit a streaming churn batch (arrival stamped likewise).
    Churn(Request),
    /// Query current serving stats.
    Stats,
    /// Query the installed tenant QoS policy table (`null` when the
    /// daemon runs tenant-blind).
    Tenants,
    /// Scrape a Prometheus text-exposition snapshot of the live
    /// counters and latency histogram. Read-only and never recorded
    /// into the trace, so scraping cannot perturb replay.
    Metrics,
    /// Wait until all admitted work is accounted (the virtual-clock
    /// fleet is always drained; this fences the event into the trace).
    Drain,
    /// Drain, persist the trace, and exit.
    Shutdown,
}

impl ClientMsg {
    /// Decode one client frame by its `"op"` discriminant; unknown ops
    /// are a named error (the connection survives, the frame does not).
    pub fn parse(j: &Json) -> Result<ClientMsg> {
        match j.str_of("op")? {
            "submit" => Ok(ClientMsg::Submit(request_from(
                j.get("request").ok_or_else(|| anyhow!("submit frame is missing 'request'"))?,
            )?)),
            "churn" => {
                let ds = dataset_from(
                    j.get("dataset").ok_or_else(|| anyhow!("churn frame is missing 'dataset'"))?,
                )?;
                let seed = j
                    .str_of("seed")?
                    .parse::<u64>()
                    .map_err(|_| anyhow!("churn field 'seed' is not a u64 string"))?;
                Ok(ClientMsg::Churn(Request::update(
                    j.u32_of("tenant")?,
                    ds,
                    j.u32_of("inserts")?,
                    j.u32_of("deletes")?,
                    j.u32_of("grow")?,
                    seed,
                    0.0,
                )))
            }
            "stats" => Ok(ClientMsg::Stats),
            "tenants" => Ok(ClientMsg::Tenants),
            "metrics" => Ok(ClientMsg::Metrics),
            "drain" => Ok(ClientMsg::Drain),
            "shutdown" => Ok(ClientMsg::Shutdown),
            op => bail!("unknown op '{op}'"),
        }
    }

    /// The client-side encoding of this message.
    pub fn to_json(&self) -> Json {
        match self {
            ClientMsg::Submit(rq) => Json::obj(vec![
                ("op", Json::Str("submit".into())),
                ("request", request_json(rq)),
            ]),
            ClientMsg::Churn(rq) => {
                // Churn frames are flat (no nested request): the op IS
                // the update description.
                let (inserts, deletes, grow, seed) = match rq.target {
                    crate::serve::Target::Update { inserts, deletes, grow, seed } => {
                        (inserts, deletes, grow, seed)
                    }
                    _ => unreachable!("Churn always wraps an update request"),
                };
                Json::obj(vec![
                    ("op", Json::Str("churn".into())),
                    ("tenant", Json::Num(rq.tenant as f64)),
                    ("dataset", super::trace::dataset_json(&rq.dataset)),
                    ("inserts", Json::Num(inserts as f64)),
                    ("deletes", Json::Num(deletes as f64)),
                    ("grow", Json::Num(grow as f64)),
                    ("seed", Json::Str(seed.to_string())),
                ])
            }
            ClientMsg::Stats => Json::obj(vec![("op", Json::Str("stats".into()))]),
            ClientMsg::Tenants => Json::obj(vec![("op", Json::Str("tenants".into()))]),
            ClientMsg::Metrics => Json::obj(vec![("op", Json::Str("metrics".into()))]),
            ClientMsg::Drain => Json::obj(vec![("op", Json::Str("drain".into()))]),
            ClientMsg::Shutdown => Json::obj(vec![("op", Json::Str("shutdown".into()))]),
        }
    }
}

/// `{"ok": true, ...fields}` — the daemon's success reply.
pub fn ok_reply(fields: Vec<(&str, Json)>) -> Json {
    let mut pairs = vec![("ok", Json::Bool(true))];
    pairs.extend(fields);
    Json::obj(pairs)
}

/// `{"ok": false, "error": msg}` — the daemon's error reply. The
/// connection stays up; one bad frame poisons only itself.
pub fn err_reply(msg: &str) -> Json {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::Str(msg.to_string()))])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::dataset;
    use crate::ir::ZooModel;
    use std::io::Cursor;

    #[test]
    fn frames_round_trip() {
        let msgs = [
            ClientMsg::Submit(Request::full(3, ZooModel::B2, dataset("CO").unwrap(), 0.0)),
            ClientMsg::Churn(Request::update(1, dataset("PU").unwrap(), 8, 2, 1, u64::MAX, 0.0)),
            ClientMsg::Stats,
            ClientMsg::Tenants,
            ClientMsg::Metrics,
            ClientMsg::Drain,
            ClientMsg::Shutdown,
        ];
        let mut buf = Vec::new();
        for m in &msgs {
            write_frame(&mut buf, &m.to_json()).unwrap();
        }
        let mut r = Cursor::new(buf);
        for m in &msgs {
            let j = read_frame(&mut r).unwrap().expect("frame present");
            assert_eq!(&ClientMsg::parse(&j).unwrap(), m);
        }
        // Clean EOF at a frame boundary.
        assert!(read_frame(&mut r).unwrap().is_none());
    }

    #[test]
    fn truncated_length_prefix_is_rejected() {
        let mut r = Cursor::new(vec![0u8, 0]);
        let err = read_frame(&mut r).unwrap_err().to_string();
        assert!(err.contains("truncated length prefix"), "{err}");
    }

    #[test]
    fn oversized_frame_is_rejected() {
        let mut bytes = (MAX_FRAME + 1).to_be_bytes().to_vec();
        bytes.extend_from_slice(b"{}");
        let mut r = Cursor::new(bytes);
        let err = read_frame(&mut r).unwrap_err().to_string();
        assert!(err.contains("exceeds MAX_FRAME"), "{err}");
    }

    #[test]
    fn truncated_payload_is_rejected() {
        let mut bytes = 10u32.to_be_bytes().to_vec();
        bytes.extend_from_slice(b"{}"); // 2 of the promised 10 bytes
        let mut r = Cursor::new(bytes);
        let err = read_frame(&mut r).unwrap_err().to_string();
        assert!(err.contains("truncated frame payload"), "{err}");
    }

    #[test]
    fn non_utf8_payload_is_rejected() {
        let mut bytes = 2u32.to_be_bytes().to_vec();
        bytes.extend_from_slice(&[0xFF, 0xFE]);
        let mut r = Cursor::new(bytes);
        let err = read_frame(&mut r).unwrap_err().to_string();
        assert!(err.contains("not UTF-8"), "{err}");
    }

    #[test]
    fn bad_json_payload_is_rejected() {
        let mut bytes = 3u32.to_be_bytes().to_vec();
        bytes.extend_from_slice(b"{\"a");
        let mut r = Cursor::new(bytes);
        let err = format!("{:#}", read_frame(&mut r).unwrap_err());
        assert!(err.contains("not valid JSON"), "{err}");
    }

    #[test]
    fn unknown_op_is_rejected() {
        let j = Json::parse(r#"{"op": "warp"}"#).unwrap();
        let err = ClientMsg::parse(&j).unwrap_err().to_string();
        assert!(err.contains("unknown op 'warp'"), "{err}");
    }

    #[test]
    fn replies_have_the_ok_discriminant() {
        let ok = ok_reply(vec![("n", Json::Num(1.0))]);
        assert!(ok.bool_of("ok").unwrap());
        assert_eq!(ok.f64_of("n").unwrap(), 1.0);
        let err = err_reply("nope");
        assert!(!err.bool_of("ok").unwrap());
        assert_eq!(err.str_of("error").unwrap(), "nope");
    }
}
