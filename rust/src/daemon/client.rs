//! A blocking client for the daemon's wire protocol, plus the scripted
//! mixed workload the CI record/replay job drives through it.

use super::protocol::{read_frame, write_frame, ClientMsg};
use super::trace::{response_from, stats_from};
use crate::graph::dataset;
use crate::ir::ZooModel;
use crate::quant::Precision;
use crate::serve::{Request, Response, ServeStats, TenantConfig};
use crate::util::{Json, Rng};
use anyhow::{anyhow, bail, Result};
use std::io::{BufReader, BufWriter};
use std::net::TcpStream;

/// One blocking connection to a live daemon, speaking the framed
/// protocol request-for-reply.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    /// Connect to a daemon listening on `127.0.0.1:port`.
    pub fn connect(port: u16) -> Result<Client> {
        let stream = TcpStream::connect(("127.0.0.1", port))
            .map_err(|e| anyhow!("connecting to daemon on port {port}: {e}"))?;
        let reader = BufReader::new(stream.try_clone().map_err(|e| anyhow!("{e}"))?);
        Ok(Client { reader, writer: BufWriter::new(stream) })
    }

    /// One request/reply round trip; errors on transport failure or an
    /// `{"ok": false}` reply.
    pub fn call(&mut self, msg: &ClientMsg) -> Result<Json> {
        write_frame(&mut self.writer, &msg.to_json())?;
        let reply = read_frame(&mut self.reader)?
            .ok_or_else(|| anyhow!("daemon closed the connection"))?;
        if !reply.bool_of("ok")? {
            bail!("{}", reply.str_of("error").unwrap_or("daemon error with no message"));
        }
        Ok(reply)
    }

    /// Submit an inference request; returns the daemon's completion
    /// record (with its stamped arrival accounted).
    pub fn submit(&mut self, rq: Request) -> Result<Response> {
        let reply = self.call(&ClientMsg::Submit(rq))?;
        response_from(reply.get("response").ok_or_else(|| anyhow!("reply missing 'response'"))?)
    }

    /// Submit a churn batch (an update-target request on the wire's
    /// flat churn encoding).
    pub fn churn(&mut self, rq: Request) -> Result<Response> {
        let reply = self.call(&ClientMsg::Churn(rq))?;
        response_from(reply.get("response").ok_or_else(|| anyhow!("reply missing 'response'"))?)
    }

    /// Query the daemon's aggregate serving stats (per-tenant families
    /// included when the daemon runs a tenant config).
    pub fn stats(&mut self) -> Result<ServeStats> {
        let reply = self.call(&ClientMsg::Stats)?;
        stats_from(reply.get("stats").ok_or_else(|| anyhow!("reply missing 'stats'"))?)
    }

    /// Query the daemon's installed tenant QoS policy table; `None`
    /// when it serves tenant-blind.
    pub fn tenants(&mut self) -> Result<Option<TenantConfig>> {
        let reply = self.call(&ClientMsg::Tenants)?;
        match reply.get("tenants").ok_or_else(|| anyhow!("reply missing 'tenants'"))? {
            Json::Null => Ok(None),
            j => Ok(Some(TenantConfig::from_json(j)?)),
        }
    }

    /// Scrape a Prometheus text-exposition snapshot of the daemon's
    /// live counters and latency histogram. Read-only: the scrape is
    /// not recorded into the trace and cannot perturb replay.
    pub fn metrics(&mut self) -> Result<String> {
        let reply = self.call(&ClientMsg::Metrics)?;
        Ok(reply.str_of("metrics")?.to_string())
    }

    /// Fence all admitted work into the trace and return final stats.
    pub fn drain(&mut self) -> Result<ServeStats> {
        let reply = self.call(&ClientMsg::Drain)?;
        stats_from(reply.get("stats").ok_or_else(|| anyhow!("reply missing 'stats'"))?)
    }

    /// Ask the daemon to persist its trace and exit; returns the number
    /// of recorded events.
    pub fn shutdown(mut self) -> Result<u64> {
        let reply = self.call(&ClientMsg::Shutdown)?;
        reply.u64_of("events")
    }
}

/// The deterministic mixed workload the CI job scripts against a live
/// daemon: whole-graph f32 and int8 requests, mini-batch ego-nets, and
/// streaming churn batches over two registry graphs and three models —
/// every serving path the trace format must capture. Arrival times are
/// left at 0 (the daemon stamps real ones at admission).
pub fn scripted_workload(n: usize, seed: u64) -> Vec<ClientMsg> {
    let mut rng = Rng::new(seed);
    let models = [ZooModel::B1, ZooModel::B2, ZooModel::B7];
    let graphs = [dataset("CO").unwrap(), dataset("PU").unwrap()];
    (0..n)
        .map(|i| {
            let tenant = rng.below(4) as u32;
            let ds = graphs[rng.below(2) as usize];
            let model = models[rng.below(3) as usize];
            match rng.below(8) {
                // ~1/8 churn batches.
                0 => ClientMsg::Churn(Request::update(
                    tenant,
                    ds,
                    16 + rng.below(48) as u32,
                    rng.below(8) as u32,
                    rng.below(3) as u32,
                    seed ^ i as u64,
                    0.0,
                )),
                // ~1/4 mini-batches.
                1 | 2 => {
                    let k = 1 + rng.below(3) as usize;
                    let targets =
                        (0..k).map(|_| rng.below(ds.n_vertices) as u32).collect();
                    ClientMsg::Submit(Request::minibatch(
                        tenant,
                        model,
                        ds,
                        targets,
                        vec![8, 4],
                        seed.wrapping_add(i as u64),
                        0.0,
                    ))
                }
                // ~1/8 int8 whole-graph.
                3 => ClientMsg::Submit(
                    Request::full(tenant, model, ds, 0.0).with_precision(Precision::Int8),
                ),
                // The rest: f32 whole-graph.
                _ => ClientMsg::Submit(Request::full(tenant, model, ds, 0.0)),
            }
        })
        .collect()
}

/// Drive `n` scripted requests through a live daemon, then drain.
/// Returns (accepted count, drained stats). Does not shut the daemon
/// down — callers decide whether the session continues.
pub fn drive(client: &mut Client, n: usize, seed: u64) -> Result<(usize, ServeStats)> {
    let mut accepted = 0;
    for msg in scripted_workload(n, seed) {
        match &msg {
            ClientMsg::Submit(rq) => {
                client.submit(rq.clone())?;
                accepted += 1;
            }
            ClientMsg::Churn(rq) => {
                client.churn(rq.clone())?;
                accepted += 1;
            }
            _ => {}
        }
    }
    let stats = client.drain()?;
    Ok((accepted, stats))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scripted_workload_is_deterministic_and_mixed() {
        let a = scripted_workload(64, 7);
        let b = scripted_workload(64, 7);
        assert_eq!(a, b);
        assert_ne!(a, scripted_workload(64, 8));
        let churn = a.iter().filter(|m| matches!(m, ClientMsg::Churn(_))).count();
        let mini = a
            .iter()
            .filter(|m| matches!(m, ClientMsg::Submit(rq) if rq.target.is_minibatch()))
            .count();
        let int8 = a
            .iter()
            .filter(|m| matches!(m, ClientMsg::Submit(rq) if rq.precision == Precision::Int8))
            .count();
        assert!(churn > 0, "no churn in the mix");
        assert!(mini > 0, "no mini-batches in the mix");
        assert!(int8 > 0, "no int8 in the mix");
        // Every scripted mini-batch is admissible (targets in range).
        for m in &a {
            if let ClientMsg::Submit(rq) = m {
                if let crate::serve::Target::MiniBatch { targets, .. } = &rq.target {
                    assert!(!targets.is_empty());
                    assert!(targets.iter().all(|&v| (v as u64) < rq.dataset.n_vertices));
                }
            }
        }
    }
}
