//! The recordable trace format (`trace.json`, versions 1–3).
//!
//! A trace is a complete, self-contained description of one serving
//! run: the hardware + fleet configuration, every admitted event in
//! admission order with its stamped virtual arrival time, and — when
//! the run finished — the recorded [`Response`] stream and final
//! [`ServeStats`]. `graphagile replay` re-executes the events through
//! [`Coordinator::admit`](crate::serve::Coordinator::admit) and, because
//! the coordinator never reads wall-clock time, reproduces the recorded
//! outputs bit-identically.
//!
//! Versioning rules (DESIGN.md Sec. 3g):
//!
//! * `version` is a required integer. Readers hard-error on a version
//!   they do not know — silently misreading a future trace would forge
//!   a "bit-identical" verdict.
//! * Unknown *fields* inside any object are ignored (lookup by key), so
//!   a same-version writer may append fields without breaking older
//!   readers. Unknown event `kind`s are a hard error, not skippable:
//!   dropping an event would change every subsequent virtual timestamp.
//! * All `f64` values round-trip bit-exactly
//!   ([`crate::util::json`]); `u64` seeds are encoded as decimal
//!   *strings* because JSON numbers are f64 and lose integer precision
//!   past 2^53.
//! * Writers stamp the *oldest sufficient* version
//!   ([`Trace::min_version`]): a trace is v2 only when it actually
//!   carries fault-era content (a fault plan, `fault`/`decision`
//!   events, non-default fault knobs, or fault counters in a response
//!   or the stats). A fault-free recording therefore stays
//!   byte-identical to what a v1 writer produced, and v1 readers keep
//!   reading it.
//! * v3 extends the same rule to tenant QoS content: a trace is v3
//!   only when it carries a tenant config, per-request `t_qos` /
//!   `deadline_missed` fields, a `shed:deadline_missed` outcome, or
//!   per-tenant stats families. Tenant-free recordings still stamp v2
//!   (or v1), bytes unchanged.

use crate::config::HwConfig;
use crate::graph::{dataset, Dataset};
use crate::ir::{zoo_model, ZooModel};
use crate::quant::Precision;
use crate::serve::fault::{fault_event_from, fault_event_json};
use crate::serve::{
    CostModel, DecisionRecord, FaultPlan, FaultRecord, FleetConfig, Outcome, Request, Response,
    ServeStats, ShedReason, Target, TenantConfig, TenantStats,
};
use crate::util::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

/// The newest trace schema version this build reads and writes (it
/// reads every version from 1 up).
pub const TRACE_VERSION: u32 = 3;

/// The configuration a trace was recorded under — everything the
/// replayer needs to rebuild an identical [`Coordinator`]
/// (crate::serve::Coordinator).
#[derive(Clone, Debug, PartialEq)]
pub struct TraceConfig {
    /// Hardware model the run was recorded on.
    pub hw: HwConfig,
    /// Fleet shape and routing policy of the recording run.
    pub fleet: FleetConfig,
    /// Fault plan the run was recorded under (v2; absent in v1 traces
    /// and in fault-free v2 recordings). Replay re-installs it so
    /// fault/decision events re-derive identically.
    pub fault_plan: Option<FaultPlan>,
    /// Tenant QoS config the run was recorded under (v3; absent in
    /// older traces and tenant-free recordings). Replay re-installs it
    /// so pacing, gap placement, and deadline decisions re-derive
    /// identically.
    pub tenants: Option<TenantConfig>,
}

/// One recorded daemon event, in admission order.
#[derive(Clone, Debug, PartialEq)]
pub enum TraceEvent {
    /// A request admitted to the coordinator at its stamped arrival.
    Admit(Request),
    /// A stats query served at virtual time `at` (a coordinator no-op;
    /// recorded so the operational timeline survives in the trace).
    Stats { at: f64 },
    /// A drain request at virtual time `at` (also a coordinator no-op:
    /// the virtual-clock fleet completes every admitted job "instantly"
    /// in wall time).
    Drain { at: f64 },
    /// A fault-plan event that fired at virtual time `record.at` (v2).
    /// Replay derives these from the re-installed plan and verifies
    /// them against the recorded stream.
    Fault(FaultRecord),
    /// A degradation/shed decision the coordinator took (v2).
    Decision(DecisionRecord),
}

/// A recorded serving run.
#[derive(Clone, Debug, PartialEq)]
pub struct Trace {
    /// Schema version the document is stamped with (oldest sufficient).
    pub version: u32,
    /// Configuration the run was recorded under.
    pub config: TraceConfig,
    /// Every recorded event, in admission order.
    pub events: Vec<TraceEvent>,
    /// Response stream the recording run produced, in admission order.
    /// Empty for hand-authored event-only traces (replay then has
    /// nothing to `--verify` against).
    pub responses: Vec<Response>,
    /// Final stats of the recording run, if it drained cleanly.
    pub stats: Option<ServeStats>,
}

impl Trace {
    /// An events-only trace over `requests` (benches use this to make
    /// synthesized workloads first-class trace inputs).
    pub fn from_requests(hw: HwConfig, fleet: FleetConfig, requests: Vec<Request>) -> Trace {
        let mut t = Trace {
            version: TRACE_VERSION,
            config: TraceConfig { hw, fleet, fault_plan: None, tenants: None },
            events: requests.into_iter().map(TraceEvent::Admit).collect(),
            responses: Vec::new(),
            stats: None,
        };
        t.version = t.min_version();
        t
    }

    /// The oldest schema version able to represent this trace: v3 when
    /// tenant QoS content is actually present (a tenant config, QoS
    /// fields or a `shed:deadline_missed` outcome in a response or
    /// decision, per-tenant stats families), else v2 when fault-era
    /// content is (a fault plan, fault/decision events, non-default
    /// fault knobs, or fault counters in a response or the stats), else
    /// v1. Writers stamp this, so a tenant-free recording stays
    /// byte-identical to what an older writer produced.
    pub fn min_version(&self) -> u32 {
        let qos = self.config.tenants.is_some()
            || self.events.iter().any(|e| {
                matches!(e, TraceEvent::Decision(d)
                    if d.outcome == Outcome::Shed(ShedReason::DeadlineMissed))
            })
            || self.responses.iter().any(response_has_qos_content)
            || self.stats.as_ref().is_some_and(|s| !s.tenants.is_empty());
        if qos {
            return 3;
        }
        let faulty = self.config.fault_plan.is_some()
            || !self.config.fleet.costs.fault_knobs_default()
            || self
                .events
                .iter()
                .any(|e| matches!(e, TraceEvent::Fault(_) | TraceEvent::Decision(_)))
            || self.responses.iter().any(response_has_fault_content)
            || self.stats.as_ref().is_some_and(stats_has_fault_content);
        if faulty {
            2
        } else {
            1
        }
    }

    /// The admitted requests, in recorded admission order.
    pub fn requests(&self) -> Vec<Request> {
        self.events
            .iter()
            .filter_map(|e| match e {
                TraceEvent::Admit(rq) => Some(rq.clone()),
                _ => None,
            })
            .collect()
    }

    /// The whole trace as one JSON value (tests and tooling; the
    /// on-disk format is [`Trace::encode`]).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", Json::Num(self.version as f64)),
            ("config", config_json(&self.config)),
            ("events", Json::Arr(self.events.iter().map(event_json).collect())),
            ("responses", Json::Arr(self.responses.iter().map(response_json).collect())),
            (
                "stats",
                match &self.stats {
                    Some(s) => stats_json(s),
                    None => Json::Null,
                },
            ),
        ])
    }

    /// Encode with one event/response per line: the file stays
    /// greppable and line-diffable while each record remains compact.
    pub fn encode(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("\"version\": {},\n", self.version));
        out.push_str(&format!("\"config\": {},\n", config_json(&self.config)));
        out.push_str("\"events\": [");
        for (i, e) in self.events.iter().enumerate() {
            out.push_str(if i == 0 { "\n" } else { ",\n" });
            out.push_str(&event_json(e).to_string());
        }
        out.push_str("\n],\n\"responses\": [");
        for (i, r) in self.responses.iter().enumerate() {
            out.push_str(if i == 0 { "\n" } else { ",\n" });
            out.push_str(&response_json(r).to_string());
        }
        out.push_str("\n],\n\"stats\": ");
        match &self.stats {
            Some(s) => out.push_str(&stats_json(s).to_string()),
            None => out.push_str("null"),
        }
        out.push_str("\n}\n");
        out
    }

    /// Decode a trace document, enforcing the version gate.
    pub fn parse(s: &str) -> Result<Trace> {
        let j = Json::parse(s).context("trace is not valid JSON")?;
        let version = j.u32_of("version")?;
        if version == 0 || version > TRACE_VERSION {
            bail!(
                "trace version {version} is not supported (this build reads 1..={TRACE_VERSION})"
            );
        }
        let config = config_from(
            j.get("config").ok_or_else(|| anyhow!("trace is missing 'config'"))?,
        )?;
        let mut events = Vec::new();
        for (i, e) in j.arr_of("events")?.iter().enumerate() {
            events.push(event_from(e).with_context(|| format!("events[{i}]"))?);
        }
        let mut responses = Vec::new();
        for (i, r) in j.arr_of("responses")?.iter().enumerate() {
            responses.push(response_from(r).with_context(|| format!("responses[{i}]"))?);
        }
        let stats = match j.get("stats") {
            None | Some(Json::Null) => None,
            Some(s) => Some(stats_from(s).context("stats")?),
        };
        Ok(Trace { version, config, events, responses, stats })
    }

    /// Read and parse a trace file.
    pub fn load(path: &Path) -> Result<Trace> {
        let s = std::fs::read_to_string(path)
            .with_context(|| format!("reading trace {}", path.display()))?;
        Trace::parse(&s).with_context(|| format!("parsing trace {}", path.display()))
    }

    /// Write the trace in the line-oriented on-disk encoding.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.encode())
            .with_context(|| format!("writing trace {}", path.display()))
    }
}

// ---- u64-as-string (seeds can use all 64 bits; JSON numbers cannot) ----

fn seed_json(v: u64) -> Json {
    Json::Str(v.to_string())
}

fn seed_from(j: &Json, key: &str) -> Result<u64> {
    let s = j.str_of(key)?;
    s.parse::<u64>().map_err(|_| anyhow!("field '{key}' is not a u64 string ({s:?})"))
}

// ---- optional-field reads (v2 fields default when absent, so v1
// documents and fault-free v2 documents decode identically) ----

fn opt_f64(j: &Json, key: &str, default: f64) -> Result<f64> {
    match j.get(key) {
        None => Ok(default),
        Some(_) => j.f64_of(key),
    }
}

fn opt_u32(j: &Json, key: &str, default: u32) -> Result<u32> {
    match j.get(key) {
        None => Ok(default),
        Some(_) => j.u32_of(key),
    }
}

fn opt_u64(j: &Json, key: &str, default: u64) -> Result<u64> {
    match j.get(key) {
        None => Ok(default),
        Some(_) => j.u64_of(key),
    }
}

fn opt_bool(j: &Json, key: &str, default: bool) -> Result<bool> {
    match j.get(key) {
        None => Ok(default),
        Some(_) => j.bool_of(key),
    }
}

fn opt_outcome(j: &Json) -> Result<Outcome> {
    match j.get("outcome") {
        None => Ok(Outcome::Completed),
        Some(_) => Outcome::parse(j.str_of("outcome")?),
    }
}

/// Whether a response carries any fault-era field a v1 reader would
/// miss (drives both emission and [`Trace::min_version`]).
fn response_has_fault_content(r: &Response) -> bool {
    r.retries != 0
        || r.rerouted
        || r.t_backoff != 0.0
        || r.outcome != Outcome::Completed
}

/// Whether a response carries any QoS-era field a v2 reader would
/// miss: a pacing delay, a missed deadline, or the
/// `shed:deadline_missed` outcome key v2 cannot parse.
fn response_has_qos_content(r: &Response) -> bool {
    r.t_qos != 0.0
        || r.deadline_missed
        || r.outcome == Outcome::Shed(ShedReason::DeadlineMissed)
}

/// Same, for the aggregate stats.
fn stats_has_fault_content(s: &ServeStats) -> bool {
    s.retries != 0
        || s.rerouted != 0
        || s.degraded != 0
        || s.shed != 0
        || s.crashes != 0
        || s.stalls != 0
        || s.corruptions != 0
        || s.downtime != 0.0
        || s.t_backoff != 0.0
}

// ---- leaked-string pool for datasets not in the registry ----

/// Intern a string to `&'static str`. The pool deduplicates, so
/// decoding the same off-registry dataset a million times leaks its
/// key/name exactly once.
fn intern(s: &str) -> &'static str {
    use std::sync::{Mutex, OnceLock};
    static POOL: OnceLock<Mutex<Vec<&'static str>>> = OnceLock::new();
    let mut pool = POOL.get_or_init(|| Mutex::new(Vec::new())).lock().unwrap();
    if let Some(&hit) = pool.iter().find(|&&p| p == s) {
        return hit;
    }
    let leaked: &'static str = Box::leak(s.to_string().into_boxed_str());
    pool.push(leaked);
    leaked
}

// ---- per-type codecs ----

fn u32_arr(j: &Json, key: &str) -> Result<Vec<u32>> {
    j.arr_of(key)?
        .iter()
        .map(|v| {
            let f = v.as_f64().ok_or_else(|| anyhow!("non-numeric element in '{key}'"))?;
            if f < 0.0 || f.fract() != 0.0 || f > u32::MAX as f64 {
                bail!("element of '{key}' is not a u32 ({f})");
            }
            Ok(f as u32)
        })
        .collect()
}

/// Encode a dataset row (self-contained: replay needs no registry).
pub fn dataset_json(d: &Dataset) -> Json {
    Json::obj(vec![
        ("key", Json::Str(d.key.to_string())),
        ("name", Json::Str(d.name.to_string())),
        ("n_vertices", Json::Num(d.n_vertices as f64)),
        ("n_edges", Json::Num(d.n_edges as f64)),
        ("feat_len", Json::Num(d.feat_len as f64)),
        ("n_classes", Json::Num(d.n_classes as f64)),
        ("locality", Json::Num(d.locality)),
    ])
}

/// Decode a dataset row, preferring the matching registry entry.
pub fn dataset_from(j: &Json) -> Result<Dataset> {
    let key = j.str_of("key")?;
    let name = j.str_of("name")?;
    let n_vertices = j.u64_of("n_vertices")?;
    let n_edges = j.u64_of("n_edges")?;
    let feat_len = j.u64_of("feat_len")?;
    let n_classes = j.u64_of("n_classes")?;
    let locality = j.f64_of("locality")?;
    // Prefer the registry row when it matches exactly: decoded requests
    // then compare equal (and share `&'static str`s) with the workload
    // that recorded them. Scaled or custom datasets fall through to the
    // intern pool.
    if let Some(d) = dataset(key) {
        if d.key == key
            && d.name == name
            && d.n_vertices == n_vertices
            && d.n_edges == n_edges
            && d.feat_len == feat_len
            && d.n_classes == n_classes
            && d.locality.to_bits() == locality.to_bits()
        {
            return Ok(d);
        }
    }
    Ok(Dataset {
        key: intern(key),
        name: intern(name),
        n_vertices,
        n_edges,
        feat_len,
        n_classes,
        locality,
    })
}

fn target_json(t: &Target) -> Json {
    match t {
        Target::FullGraph => Json::obj(vec![("kind", Json::Str("full".into()))]),
        Target::MiniBatch { targets, fanout, seed } => Json::obj(vec![
            ("kind", Json::Str("minibatch".into())),
            ("targets", Json::Arr(targets.iter().map(|&v| Json::Num(v as f64)).collect())),
            ("fanout", Json::Arr(fanout.iter().map(|&v| Json::Num(v as f64)).collect())),
            ("seed", seed_json(*seed)),
        ]),
        Target::Update { inserts, deletes, grow, seed } => Json::obj(vec![
            ("kind", Json::Str("update".into())),
            ("inserts", Json::Num(*inserts as f64)),
            ("deletes", Json::Num(*deletes as f64)),
            ("grow", Json::Num(*grow as f64)),
            ("seed", seed_json(*seed)),
        ]),
    }
}

fn target_from(j: &Json) -> Result<Target> {
    match j.str_of("kind")? {
        "full" => Ok(Target::FullGraph),
        "minibatch" => Ok(Target::MiniBatch {
            targets: u32_arr(j, "targets")?,
            fanout: u32_arr(j, "fanout")?,
            seed: seed_from(j, "seed")?,
        }),
        "update" => Ok(Target::Update {
            inserts: j.u32_of("inserts")?,
            deletes: j.u32_of("deletes")?,
            grow: j.u32_of("grow")?,
            seed: seed_from(j, "seed")?,
        }),
        k => bail!("unknown target kind '{k}'"),
    }
}

fn model_json(m: ZooModel) -> Json {
    Json::Str(m.key().to_string())
}

fn model_from(j: &Json, key: &str) -> Result<ZooModel> {
    let s = j.str_of(key)?;
    zoo_model(s).ok_or_else(|| anyhow!("unknown model '{s}'"))
}

fn precision_json(p: Precision) -> Json {
    Json::Str(p.key().to_string())
}

fn precision_from(j: &Json, key: &str) -> Result<Precision> {
    j.str_of(key)?.parse::<Precision>().map_err(|e| anyhow!("field '{key}': {e}"))
}

/// Encode one admitted request.
pub fn request_json(rq: &Request) -> Json {
    Json::obj(vec![
        ("tenant", Json::Num(rq.tenant as f64)),
        ("model", model_json(rq.model)),
        ("dataset", dataset_json(&rq.dataset)),
        ("target", target_json(&rq.target)),
        ("arrival", Json::Num(rq.arrival)),
        ("precision", precision_json(rq.precision)),
    ])
}

/// Decode one admitted request.
pub fn request_from(j: &Json) -> Result<Request> {
    Ok(Request {
        tenant: j.u32_of("tenant")?,
        model: model_from(j, "model")?,
        dataset: dataset_from(
            j.get("dataset").ok_or_else(|| anyhow!("request is missing 'dataset'"))?,
        )?,
        target: target_from(
            j.get("target").ok_or_else(|| anyhow!("request is missing 'target'"))?,
        )?,
        arrival: j.f64_of("arrival")?,
        precision: precision_from(j, "precision")?,
    })
}

/// Encode one response; era-specific fields (v2 fault, v3 QoS) are
/// emitted only when non-default.
pub fn response_json(r: &Response) -> Json {
    let mut fields = vec![
        ("tenant", Json::Num(r.tenant as f64)),
        ("model", model_json(r.model)),
        ("device", Json::Num(r.device as f64)),
        ("t_compile", Json::Num(r.t_compile)),
        ("t_sample", Json::Num(r.t_sample)),
        ("t_exec", Json::Num(r.t_exec)),
        ("t_queue", Json::Num(r.t_queue)),
        ("latency", Json::Num(r.latency)),
        ("cache_hit", Json::Bool(r.cache_hit)),
        ("coalesced", Json::Bool(r.coalesced)),
        ("batched", Json::Bool(r.batched)),
        ("minibatch", Json::Bool(r.minibatch)),
        ("sampled_vertices", Json::Num(r.sampled_vertices as f64)),
        ("sampled_edges", Json::Num(r.sampled_edges as f64)),
        ("remaps", Json::Num(r.remaps as f64)),
        ("precision", precision_json(r.precision)),
        ("quant_visits", Json::Num(r.quant_visits as f64)),
        ("requant_ops", Json::Num(r.requant_ops as f64)),
        ("int8_bytes", Json::Num(r.int8_bytes as f64)),
        ("update", Json::Bool(r.update)),
        ("epoch", Json::Num(r.epoch as f64)),
        ("t_update", Json::Num(r.t_update)),
        ("dirty_subshards", Json::Num(r.dirty_subshards as f64)),
        ("rebuilt_edges", Json::Num(r.rebuilt_edges as f64)),
        ("invalidated", Json::Num(r.invalidated as f64)),
        ("compacted", Json::Bool(r.compacted)),
    ];
    // Fault-era fields (v2) are emitted only when non-default, so a
    // fault-free response line stays byte-identical to a v1 writer's.
    if r.retries != 0 {
        fields.push(("retries", Json::Num(r.retries as f64)));
    }
    if r.rerouted {
        fields.push(("rerouted", Json::Bool(true)));
    }
    if r.t_backoff != 0.0 {
        fields.push(("t_backoff", Json::Num(r.t_backoff)));
    }
    // QoS fields (v3), same non-default rule: a tenant-free response
    // line stays byte-identical to a v2 (or v1) writer's.
    if r.t_qos != 0.0 {
        fields.push(("t_qos", Json::Num(r.t_qos)));
    }
    if r.deadline_missed {
        fields.push(("deadline_missed", Json::Bool(true)));
    }
    if r.outcome != Outcome::Completed {
        fields.push(("outcome", Json::Str(r.outcome.key().to_string())));
    }
    Json::obj(fields)
}

/// Decode one response (absent era-specific fields take defaults).
pub fn response_from(j: &Json) -> Result<Response> {
    Ok(Response {
        tenant: j.u32_of("tenant")?,
        model: model_from(j, "model")?,
        device: j.u32_of("device")?,
        t_compile: j.f64_of("t_compile")?,
        t_sample: j.f64_of("t_sample")?,
        t_exec: j.f64_of("t_exec")?,
        t_queue: j.f64_of("t_queue")?,
        latency: j.f64_of("latency")?,
        cache_hit: j.bool_of("cache_hit")?,
        coalesced: j.bool_of("coalesced")?,
        batched: j.bool_of("batched")?,
        minibatch: j.bool_of("minibatch")?,
        sampled_vertices: j.u64_of("sampled_vertices")?,
        sampled_edges: j.u64_of("sampled_edges")?,
        remaps: j.u64_of("remaps")?,
        precision: precision_from(j, "precision")?,
        quant_visits: j.u64_of("quant_visits")?,
        requant_ops: j.u64_of("requant_ops")?,
        int8_bytes: j.u64_of("int8_bytes")?,
        update: j.bool_of("update")?,
        epoch: j.u32_of("epoch")?,
        t_update: j.f64_of("t_update")?,
        dirty_subshards: j.u32_of("dirty_subshards")?,
        rebuilt_edges: j.u64_of("rebuilt_edges")?,
        invalidated: j.u32_of("invalidated")?,
        compacted: j.bool_of("compacted")?,
        retries: opt_u32(j, "retries", 0)?,
        rerouted: opt_bool(j, "rerouted", false)?,
        t_backoff: opt_f64(j, "t_backoff", 0.0)?,
        t_qos: opt_f64(j, "t_qos", 0.0)?,
        deadline_missed: opt_bool(j, "deadline_missed", false)?,
        outcome: opt_outcome(j)?,
    })
}

fn tenant_stats_json(t: &TenantStats) -> Json {
    Json::obj(vec![
        ("tenant", Json::Num(t.tenant as f64)),
        ("weight", Json::Num(t.weight)),
        ("completed", Json::Num(t.completed as f64)),
        ("degraded", Json::Num(t.degraded as f64)),
        ("shed", Json::Num(t.shed as f64)),
        ("missed", Json::Num(t.missed as f64)),
        ("p50", Json::Num(t.p50)),
        ("p99", Json::Num(t.p99)),
        ("t_qos", Json::Num(t.t_qos)),
        ("busy", Json::Num(t.busy)),
    ])
}

fn tenant_stats_from(j: &Json) -> Result<TenantStats> {
    Ok(TenantStats {
        tenant: j.u32_of("tenant")?,
        weight: j.f64_of("weight")?,
        completed: j.u64_of("completed")?,
        degraded: j.u64_of("degraded")?,
        shed: j.u64_of("shed")?,
        missed: j.u64_of("missed")?,
        p50: j.f64_of("p50")?,
        p99: j.f64_of("p99")?,
        t_qos: j.f64_of("t_qos")?,
        busy: j.f64_of("busy")?,
    })
}

/// Encode aggregate stats; the fault-counter block (v2) and per-tenant
/// families (v3) are emitted only when present.
pub fn stats_json(s: &ServeStats) -> Json {
    let mut fields = vec![
        ("completed", Json::Num(s.completed as f64)),
        ("cache_hits", Json::Num(s.cache_hits as f64)),
        ("coalesced", Json::Num(s.coalesced as f64)),
        ("minibatched", Json::Num(s.minibatched as f64)),
        ("batched", Json::Num(s.batched as f64)),
        ("bucket_hits", Json::Num(s.bucket_hits as f64)),
        ("sampled_vertices", Json::Num(s.sampled_vertices as f64)),
        ("sampled_edges", Json::Num(s.sampled_edges as f64)),
        ("remaps", Json::Num(s.remaps as f64)),
        ("quantized", Json::Num(s.quantized as f64)),
        ("quant_visits", Json::Num(s.quant_visits as f64)),
        ("requant_ops", Json::Num(s.requant_ops as f64)),
        ("int8_bytes", Json::Num(s.int8_bytes as f64)),
        ("updates", Json::Num(s.updates as f64)),
        ("max_epoch", Json::Num(s.max_epoch as f64)),
        ("dirty_subshards", Json::Num(s.dirty_subshards as f64)),
        ("rebuilt_edges", Json::Num(s.rebuilt_edges as f64)),
        ("invalidated", Json::Num(s.invalidated as f64)),
        ("compactions", Json::Num(s.compactions as f64)),
        ("p50", Json::Num(s.p50)),
        ("p99", Json::Num(s.p99)),
        ("mean", Json::Num(s.mean)),
        ("p50_mini", Json::Num(s.p50_mini)),
        ("p50_full", Json::Num(s.p50_full)),
        ("device_busy", Json::Num(s.device_busy)),
        ("makespan", Json::Num(s.makespan)),
    ];
    // The fault/degradation counter family (v2) is emitted as a block
    // only when any member is non-zero — a fault-free run's stats stay
    // byte-identical to a v1 writer's.
    if stats_has_fault_content(s) {
        fields.push(("retries", Json::Num(s.retries as f64)));
        fields.push(("rerouted", Json::Num(s.rerouted as f64)));
        fields.push(("degraded", Json::Num(s.degraded as f64)));
        fields.push(("shed", Json::Num(s.shed as f64)));
        fields.push(("crashes", Json::Num(s.crashes as f64)));
        fields.push(("stalls", Json::Num(s.stalls as f64)));
        fields.push(("corruptions", Json::Num(s.corruptions as f64)));
        fields.push(("downtime", Json::Num(s.downtime)));
        fields.push(("t_backoff", Json::Num(s.t_backoff)));
    }
    // Per-tenant families (v3) only exist under an installed tenant
    // config — tenant-free stats stay byte-identical to v2.
    if !s.tenants.is_empty() {
        fields.push(("tenants", Json::Arr(s.tenants.iter().map(tenant_stats_json).collect())));
    }
    Json::obj(fields)
}

/// Decode aggregate stats (absent era-specific blocks take defaults).
pub fn stats_from(j: &Json) -> Result<ServeStats> {
    Ok(ServeStats {
        completed: j.u64_of("completed")?,
        cache_hits: j.u64_of("cache_hits")?,
        coalesced: j.u64_of("coalesced")?,
        minibatched: j.u64_of("minibatched")?,
        batched: j.u64_of("batched")?,
        bucket_hits: j.u64_of("bucket_hits")?,
        sampled_vertices: j.u64_of("sampled_vertices")?,
        sampled_edges: j.u64_of("sampled_edges")?,
        remaps: j.u64_of("remaps")?,
        quantized: j.u64_of("quantized")?,
        quant_visits: j.u64_of("quant_visits")?,
        requant_ops: j.u64_of("requant_ops")?,
        int8_bytes: j.u64_of("int8_bytes")?,
        updates: j.u64_of("updates")?,
        max_epoch: j.u32_of("max_epoch")?,
        dirty_subshards: j.u64_of("dirty_subshards")?,
        rebuilt_edges: j.u64_of("rebuilt_edges")?,
        invalidated: j.u64_of("invalidated")?,
        compactions: j.u64_of("compactions")?,
        p50: j.f64_of("p50")?,
        p99: j.f64_of("p99")?,
        mean: j.f64_of("mean")?,
        p50_mini: j.f64_of("p50_mini")?,
        p50_full: j.f64_of("p50_full")?,
        device_busy: j.f64_of("device_busy")?,
        makespan: j.f64_of("makespan")?,
        retries: opt_u64(j, "retries", 0)?,
        rerouted: opt_u64(j, "rerouted", 0)?,
        degraded: opt_u64(j, "degraded", 0)?,
        shed: opt_u64(j, "shed", 0)?,
        crashes: opt_u64(j, "crashes", 0)?,
        stalls: opt_u64(j, "stalls", 0)?,
        corruptions: opt_u64(j, "corruptions", 0)?,
        downtime: opt_f64(j, "downtime", 0.0)?,
        t_backoff: opt_f64(j, "t_backoff", 0.0)?,
        tenants: match j.get("tenants") {
            None | Some(Json::Null) => Vec::new(),
            Some(_) => j
                .arr_of("tenants")?
                .iter()
                .enumerate()
                .map(|(i, t)| tenant_stats_from(t).with_context(|| format!("tenants[{i}]")))
                .collect::<Result<Vec<_>>>()?,
        },
    })
}

fn costs_json(c: &CostModel) -> Json {
    let mut fields = vec![
        ("sample_setup_s", Json::Num(c.sample_setup_s)),
        ("sample_per_vertex_s", Json::Num(c.sample_per_vertex_s)),
        ("sample_per_edge_s", Json::Num(c.sample_per_edge_s)),
        ("visit_overhead_s", Json::Num(c.visit_overhead_s)),
        ("update_setup_s", Json::Num(c.update_setup_s)),
        ("update_per_edge_s", Json::Num(c.update_per_edge_s)),
        ("update_per_subshard_s", Json::Num(c.update_per_subshard_s)),
        ("update_per_rebuilt_edge_s", Json::Num(c.update_per_rebuilt_edge_s)),
    ];
    // The fault knobs (v2) are emitted only when swept off their
    // defaults, keeping fault-free configs byte-identical to v1.
    if !c.fault_knobs_default() {
        fields.push(("retry_backoff_base_s", Json::Num(c.retry_backoff_base_s)));
        fields.push(("max_retries", Json::Num(c.max_retries as f64)));
        fields.push(("deadline_s", Json::Num(c.deadline_s)));
    }
    Json::obj(fields)
}

fn costs_from(j: &Json) -> Result<CostModel> {
    let d = CostModel::default();
    Ok(CostModel {
        sample_setup_s: j.f64_of("sample_setup_s")?,
        sample_per_vertex_s: j.f64_of("sample_per_vertex_s")?,
        sample_per_edge_s: j.f64_of("sample_per_edge_s")?,
        visit_overhead_s: j.f64_of("visit_overhead_s")?,
        update_setup_s: j.f64_of("update_setup_s")?,
        update_per_edge_s: j.f64_of("update_per_edge_s")?,
        update_per_subshard_s: j.f64_of("update_per_subshard_s")?,
        update_per_rebuilt_edge_s: j.f64_of("update_per_rebuilt_edge_s")?,
        retry_backoff_base_s: opt_f64(j, "retry_backoff_base_s", d.retry_backoff_base_s)?,
        max_retries: opt_u32(j, "max_retries", d.max_retries)?,
        deadline_s: opt_f64(j, "deadline_s", d.deadline_s)?,
    })
}

fn fleet_json(f: &FleetConfig) -> Json {
    Json::obj(vec![
        ("n_devices", Json::Num(f.n_devices as f64)),
        ("affinity", Json::Bool(f.affinity)),
        ("coalesce", Json::Bool(f.coalesce)),
        ("microbatch", Json::Bool(f.microbatch)),
        ("dynamic", Json::Bool(f.dynamic)),
        ("costs", costs_json(&f.costs)),
    ])
}

fn fleet_from(j: &Json) -> Result<FleetConfig> {
    Ok(FleetConfig {
        n_devices: j.u64_of("n_devices")? as usize,
        affinity: j.bool_of("affinity")?,
        coalesce: j.bool_of("coalesce")?,
        microbatch: j.bool_of("microbatch")?,
        dynamic: j.bool_of("dynamic")?,
        costs: costs_from(j.get("costs").ok_or_else(|| anyhow!("fleet is missing 'costs'"))?)?,
    })
}

fn hw_json(h: &HwConfig) -> Json {
    Json::obj(vec![
        ("n_pe", Json::Num(h.n_pe as f64)),
        ("p_sys", Json::Num(h.p_sys as f64)),
        ("freq_hz", Json::Num(h.freq_hz)),
        ("weight_rows", Json::Num(h.weight_rows as f64)),
        ("edge_capacity", Json::Num(h.edge_capacity as f64)),
        ("feature_rows", Json::Num(h.feature_rows as f64)),
        ("feature_cols", Json::Num(h.feature_cols as f64)),
        ("ddr_bw", Json::Num(h.ddr_bw)),
        ("ddr_channels", Json::Num(h.ddr_channels as f64)),
        ("pcie_bw", Json::Num(h.pcie_bw)),
        ("overlap", Json::Bool(h.overlap)),
        ("raw_reorder_depth", Json::Num(h.raw_reorder_depth as f64)),
        ("ur_pipeline_depth", Json::Num(h.ur_pipeline_depth as f64)),
    ])
}

fn hw_from(j: &Json) -> Result<HwConfig> {
    Ok(HwConfig {
        n_pe: j.u64_of("n_pe")? as usize,
        p_sys: j.u64_of("p_sys")? as usize,
        freq_hz: j.f64_of("freq_hz")?,
        weight_rows: j.u64_of("weight_rows")? as usize,
        edge_capacity: j.u64_of("edge_capacity")? as usize,
        feature_rows: j.u64_of("feature_rows")? as usize,
        feature_cols: j.u64_of("feature_cols")? as usize,
        ddr_bw: j.f64_of("ddr_bw")?,
        ddr_channels: j.u64_of("ddr_channels")? as usize,
        pcie_bw: j.f64_of("pcie_bw")?,
        overlap: j.bool_of("overlap")?,
        raw_reorder_depth: j.u64_of("raw_reorder_depth")? as usize,
        ur_pipeline_depth: j.u64_of("ur_pipeline_depth")? as usize,
    })
}

fn config_json(c: &TraceConfig) -> Json {
    let mut fields = vec![("hw", hw_json(&c.hw)), ("fleet", fleet_json(&c.fleet))];
    if let Some(p) = &c.fault_plan {
        fields.push(("fault_plan", p.to_json()));
    }
    if let Some(t) = &c.tenants {
        fields.push(("tenants", t.to_json()));
    }
    Json::obj(fields)
}

fn config_from(j: &Json) -> Result<TraceConfig> {
    Ok(TraceConfig {
        hw: hw_from(j.get("hw").ok_or_else(|| anyhow!("config is missing 'hw'"))?)
            .context("config.hw")?,
        fleet: fleet_from(j.get("fleet").ok_or_else(|| anyhow!("config is missing 'fleet'"))?)
            .context("config.fleet")?,
        fault_plan: match j.get("fault_plan") {
            None | Some(Json::Null) => None,
            Some(p) => Some(FaultPlan::from_json(p).context("config.fault_plan")?),
        },
        tenants: match j.get("tenants") {
            None | Some(Json::Null) => None,
            Some(t) => Some(TenantConfig::from_json(t).context("config.tenants")?),
        },
    })
}

/// Encode one trace event with its `kind` tag.
pub fn event_json(e: &TraceEvent) -> Json {
    match e {
        TraceEvent::Admit(rq) => Json::obj(vec![
            ("kind", Json::Str("admit".into())),
            ("request", request_json(rq)),
        ]),
        TraceEvent::Stats { at } => {
            Json::obj(vec![("kind", Json::Str("stats".into())), ("at", Json::Num(*at))])
        }
        TraceEvent::Drain { at } => {
            Json::obj(vec![("kind", Json::Str("drain".into())), ("at", Json::Num(*at))])
        }
        TraceEvent::Fault(f) => Json::obj(vec![
            ("kind", Json::Str("fault".into())),
            ("at", Json::Num(f.at)),
            ("fault", fault_event_json(&f.fault)),
        ]),
        TraceEvent::Decision(d) => Json::obj(vec![
            ("kind", Json::Str("decision".into())),
            ("at", Json::Num(d.at)),
            ("tenant", Json::Num(d.tenant as f64)),
            ("outcome", Json::Str(d.outcome.key().to_string())),
        ]),
    }
}

/// Decode one trace event; unknown kinds are a hard error.
pub fn event_from(j: &Json) -> Result<TraceEvent> {
    match j.str_of("kind")? {
        "admit" => Ok(TraceEvent::Admit(request_from(
            j.get("request").ok_or_else(|| anyhow!("admit event is missing 'request'"))?,
        )?)),
        "stats" => Ok(TraceEvent::Stats { at: j.f64_of("at")? }),
        "drain" => Ok(TraceEvent::Drain { at: j.f64_of("at")? }),
        "fault" => Ok(TraceEvent::Fault(FaultRecord {
            at: j.f64_of("at")?,
            fault: fault_event_from(
                j.get("fault").ok_or_else(|| anyhow!("fault event is missing 'fault'"))?,
            )?,
        })),
        "decision" => Ok(TraceEvent::Decision(DecisionRecord {
            at: j.f64_of("at")?,
            tenant: j.u32_of("tenant")?,
            outcome: Outcome::parse(j.str_of("outcome")?)?,
        })),
        // Skipping an unknown event would silently shift every later
        // virtual timestamp — hard-error instead.
        k => bail!("unknown trace event kind '{k}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_trace() -> Trace {
        let co = dataset("CO").unwrap();
        let pu = dataset("PU").unwrap();
        let events = vec![
            TraceEvent::Admit(Request::full(0, ZooModel::B2, co, 0.0)),
            TraceEvent::Admit(
                Request::full(1, ZooModel::B7, pu, 1e-4).with_precision(Precision::Int8),
            ),
            TraceEvent::Stats { at: 2e-4 },
            TraceEvent::Admit(Request::minibatch(
                2,
                ZooModel::B1,
                co,
                vec![5, 17, 400],
                vec![8, 4],
                u64::MAX - 3,
                3e-4,
            )),
            TraceEvent::Admit(Request::update(0, co, 64, 16, 2, 0x0123_4567_89AB_CDEF, 4e-4)),
            TraceEvent::Drain { at: 5e-4 },
        ];
        let mut t = Trace {
            version: TRACE_VERSION,
            config: TraceConfig {
                hw: HwConfig::alveo_u250(),
                fleet: FleetConfig { n_devices: 2, ..FleetConfig::default() },
                fault_plan: None,
                tenants: None,
            },
            events,
            responses: Vec::new(),
            stats: None,
        };
        t.version = t.min_version();
        t
    }

    #[test]
    fn trace_round_trips_every_event_kind() {
        let t = sample_trace();
        let back = Trace::parse(&t.encode()).unwrap();
        assert_eq!(back, t);
        // Seeds survive at full 64-bit precision.
        match &back.events[3] {
            TraceEvent::Admit(rq) => match rq.target {
                Target::MiniBatch { seed, .. } => assert_eq!(seed, u64::MAX - 3),
                _ => panic!("wrong target"),
            },
            _ => panic!("wrong event"),
        }
    }

    #[test]
    fn registry_datasets_decode_to_registry_rows() {
        let co = dataset("CO").unwrap();
        let d = dataset_from(&dataset_json(&co)).unwrap();
        assert_eq!(d, co);
        // The decoded row carries the registry's 'static strings, not a
        // leaked copy.
        assert!(std::ptr::eq(d.key, co.key));
    }

    #[test]
    fn off_registry_datasets_intern() {
        let scaled = dataset("RE").unwrap().scaled(1000);
        let d = dataset_from(&dataset_json(&scaled)).unwrap();
        assert_eq!(d, scaled);
        // Re-decoding reuses the interned strings.
        let d2 = dataset_from(&dataset_json(&scaled)).unwrap();
        assert!(std::ptr::eq(d.key, d2.key));
    }

    #[test]
    fn version_gate_rejects_future_traces() {
        let mut s = sample_trace().encode();
        s = s.replace("\"version\": 1", "\"version\": 4");
        let err = Trace::parse(&s).unwrap_err().to_string();
        assert!(err.contains("version 4"), "{err}");
        // Every version from 1 up to the current one still reads.
        for v in 2..=TRACE_VERSION {
            let doc = sample_trace()
                .encode()
                .replace("\"version\": 1", &format!("\"version\": {v}"));
            assert!(Trace::parse(&doc).is_ok(), "version {v} must read");
        }
    }

    #[test]
    fn unknown_event_kind_is_a_hard_error() {
        let mut t = sample_trace();
        t.events.clear();
        let mut s = t.encode();
        s = s.replace("\"events\": [", "\"events\": [{\"kind\":\"teleport\",\"at\":0}");
        let err = Trace::parse(&s).unwrap_err();
        assert!(format!("{err:#}").contains("teleport"), "{err:#}");
    }

    #[test]
    fn unknown_fields_are_forward_compatible() {
        let t = sample_trace();
        let s = t.encode().replace("\"version\": 1,", "\"version\": 1, \"recorded_by\": \"v9\",");
        assert_eq!(Trace::parse(&s).unwrap(), t);
    }

    #[test]
    fn responses_and_stats_round_trip() {
        use crate::serve::Coordinator;
        let mut c = Coordinator::new(HwConfig::alveo_u250());
        let t0 = sample_trace();
        let stats = c.run(t0.requests());
        let t = Trace {
            responses: c.responses.clone(),
            stats: Some(stats.clone()),
            ..t0
        };
        let back = Trace::parse(&t.encode()).unwrap();
        assert_eq!(back.responses, t.responses);
        assert_eq!(back.stats.as_ref().unwrap().diff(&stats), Vec::<String>::new());
    }

    #[test]
    fn fault_free_traces_stay_version_1_with_no_v2_keys() {
        let t = sample_trace();
        assert_eq!(t.version, 1, "oldest sufficient version");
        let s = t.encode();
        assert!(s.contains("\"version\": 1"));
        for key in ["fault_plan", "retries", "t_backoff", "outcome", "downtime"] {
            assert!(!s.contains(key), "fault-free trace leaked v2 key '{key}'");
        }
        for key in ["\"tenants\"", "t_qos", "deadline_missed"] {
            assert!(!s.contains(key), "tenant-free trace leaked v3 key '{key}'");
        }
    }

    #[test]
    fn v3_trace_round_trips_tenants_and_qos_fields() {
        use crate::serve::{Coordinator, PriorityClass, Tenant};
        let mut t = sample_trace();
        let config = TenantConfig {
            tenants: vec![
                Tenant { id: 0, weight: 4.0, deadline_s: Some(0.02), class: PriorityClass::Premium },
                Tenant { id: 1, weight: 2.0, deadline_s: None, class: PriorityClass::Standard },
                Tenant {
                    id: 2,
                    weight: 1.0,
                    deadline_s: Some(0.05),
                    class: PriorityClass::BestEffort,
                },
            ],
        };
        t.config.tenants = Some(config.clone());
        let mut c = Coordinator::new(HwConfig::alveo_u250());
        let stats = c.run(t.requests());
        let mut r = c.responses[0];
        r.t_qos = 2.5e-3;
        r.deadline_missed = true;
        r.outcome = Outcome::Shed(ShedReason::DeadlineMissed);
        let mut s = stats;
        s.tenants = vec![TenantStats {
            tenant: 2,
            weight: 1.0,
            completed: 3,
            shed: 1,
            missed: 1,
            p99: 4e-3,
            t_qos: 9e-3,
            busy: 1.5e-3,
            ..TenantStats::default()
        }];
        t.events.push(TraceEvent::Decision(DecisionRecord {
            at: 4e-4,
            tenant: 2,
            outcome: Outcome::Shed(ShedReason::DeadlineMissed),
        }));
        t.responses = vec![r];
        t.stats = Some(s);
        t.version = t.min_version();
        assert_eq!(t.version, 3, "tenant content promotes the version");
        let back = Trace::parse(&t.encode()).unwrap();
        assert_eq!(back, t);
        assert_eq!(back.config.tenants, Some(config));
    }

    #[test]
    fn qos_fields_alone_promote_to_v3() {
        // A response carrying a pacing delay, with no tenant config in
        // the document, still needs a v3 reader.
        use crate::serve::Coordinator;
        let mut t = sample_trace();
        let mut c = Coordinator::new(HwConfig::alveo_u250());
        c.run(t.requests());
        let mut r = c.responses[0];
        r.t_qos = 1e-3;
        t.responses = vec![r];
        t.version = t.min_version();
        assert_eq!(t.version, 3);
        assert_eq!(Trace::parse(&t.encode()).unwrap(), t);
        // A fault-era trace is untouched by the v3 rule.
        let mut f = sample_trace();
        f.config.fleet.costs.max_retries = 9;
        f.version = f.min_version();
        assert_eq!(f.version, 2, "fault content alone stays v2");
    }

    #[test]
    fn v2_trace_round_trips_faults_decisions_and_plan() {
        use crate::serve::{Degradation, FaultEvent, ShedReason};
        let mut t = sample_trace();
        t.config.fault_plan = Some(FaultPlan {
            seed: 7,
            events: vec![
                FaultEvent::DeviceCrash { device: 1, at: 2e-4, recover_after: 1e-3 },
                FaultEvent::TransientStall { device: 0, at: 1e-4, duration: 5e-5 },
                FaultEvent::ArtifactCorruption {
                    device: 0,
                    at: 3e-4,
                    model: ZooModel::B2,
                    dataset: "CO".to_string(),
                },
            ],
        });
        t.events.push(TraceEvent::Fault(FaultRecord {
            at: 2e-4,
            fault: FaultEvent::DeviceCrash { device: 1, at: 2e-4, recover_after: 1e-3 },
        }));
        t.events.push(TraceEvent::Decision(DecisionRecord {
            at: 3e-4,
            tenant: 2,
            outcome: Outcome::Degraded(Degradation::Int8),
        }));
        t.events.push(TraceEvent::Decision(DecisionRecord {
            at: 4e-4,
            tenant: 0,
            outcome: Outcome::Shed(ShedReason::RetriesExhausted),
        }));
        t.version = t.min_version();
        assert_eq!(t.version, 2, "fault content promotes the version");
        let back = Trace::parse(&t.encode()).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn fault_counters_in_responses_and_stats_round_trip() {
        use crate::serve::{Coordinator, Degradation};
        let mut t = sample_trace();
        let mut c = Coordinator::new(HwConfig::alveo_u250());
        let stats = c.run(t.requests());
        let mut r = c.responses[0];
        r.retries = 2;
        r.rerouted = true;
        r.t_backoff = 1.5e-2;
        r.outcome = Outcome::Degraded(Degradation::CappedFanout);
        let mut s = stats;
        s.retries = 2;
        s.shed = 1;
        s.downtime = 0.25;
        t.responses = vec![r];
        t.stats = Some(s);
        t.version = t.min_version();
        assert_eq!(t.version, 2);
        let back = Trace::parse(&t.encode()).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn non_default_fault_knobs_promote_and_round_trip() {
        let mut t = sample_trace();
        t.config.fleet.costs.max_retries = 7;
        t.config.fleet.costs.deadline_s = 0.5;
        t.version = t.min_version();
        assert_eq!(t.version, 2, "swept fault knobs promote the version");
        let back = Trace::parse(&t.encode()).unwrap();
        assert_eq!(back, t);
        assert_eq!(back.config.fleet.costs.max_retries, 7);
    }
}
