//! One daemon serving session: the bridge between real wall-clock
//! ingestion and the deterministic virtual-clock coordinator.
//!
//! Wall time enters the system in exactly one place —
//! [`DaemonSession::stamp`] — where a request's real arrival offset
//! since session start is written into `Request::arrival`. From that
//! point on everything is virtual and deterministic: the stamped value
//! is recorded in the trace, so an offline replay feeds the identical
//! arrivals through [`Coordinator::admit`] and reproduces every
//! response bit-for-bit.
//!
//! Validation happens *before* recording: a rejected submission never
//! enters the trace, so a recorded trace contains only events that
//! replay cleanly.

use super::trace::{Trace, TraceConfig, TraceEvent, TRACE_VERSION};
use crate::config::HwConfig;
use crate::serve::{
    Coordinator, FaultPlan, FleetConfig, Request, Response, ServeStats, Target, TenantConfig,
};
use anyhow::{bail, Result};
use std::time::Instant;

/// The largest dataset the mini-batch sampler / streaming overlay will
/// materialize (mirrors [`crate::graph::Dataset::materialize`]'s guard;
/// the daemon rejects instead of panicking).
const MAX_MATERIALIZE_EDGES: u64 = 10_000_000;

/// One recording serving session: a deterministic [`Coordinator`] plus
/// the growing event log that [`DaemonSession::finalize`] seals into a
/// [`Trace`].
pub struct DaemonSession {
    coord: Coordinator,
    config: TraceConfig,
    events: Vec<TraceEvent>,
    /// Session start; arrivals are stamped as elapsed seconds since it.
    t0: Instant,
    /// Last stamped arrival — stamps are forced monotone because
    /// [`Coordinator::admit`] requires nondecreasing arrivals.
    last_arrival: f64,
    /// Splice cursors into the coordinator's fault/decision logs:
    /// entries before these were already copied into `events`.
    faults_seen: usize,
    decisions_seen: usize,
}

impl DaemonSession {
    /// A plain session: no fault plan, no tenant QoS — records a v1
    /// trace byte-identical to the original format.
    pub fn new(hw: HwConfig, fleet: FleetConfig) -> DaemonSession {
        DaemonSession::with_config(hw, fleet, None, None)
    }

    /// A session serving under a fault plan (`daemon --fault-plan`).
    /// An empty (or absent) plan installs nothing: the session records
    /// a v1 trace byte-identical to the pre-fault format.
    pub fn with_plan(hw: HwConfig, fleet: FleetConfig, plan: Option<FaultPlan>) -> DaemonSession {
        DaemonSession::with_config(hw, fleet, plan, None)
    }

    /// A session serving under per-tenant QoS (`daemon --tenants`).
    /// An empty (or absent) config installs nothing: the session
    /// records a tenant-free trace, byte-identical to the pre-QoS
    /// format.
    pub fn with_tenants(
        hw: HwConfig,
        fleet: FleetConfig,
        tenants: Option<TenantConfig>,
    ) -> DaemonSession {
        DaemonSession::with_config(hw, fleet, None, tenants)
    }

    /// The general constructor behind the named variants. A fault plan
    /// and a tenant config are mutually exclusive — installing both
    /// panics (the coordinator enforces it), matching the CLI's
    /// rejection of `--fault-plan` + `--tenants`.
    pub fn with_config(
        hw: HwConfig,
        fleet: FleetConfig,
        plan: Option<FaultPlan>,
        tenants: Option<TenantConfig>,
    ) -> DaemonSession {
        let mut coord = Coordinator::fleet(hw.clone(), fleet);
        if let Some(p) = plan {
            coord.set_fault_plan(p);
        }
        if let Some(t) = tenants {
            coord.set_tenants(t);
        }
        let fault_plan = coord.fault_plan().cloned();
        let tenants = coord.tenants().cloned();
        DaemonSession {
            coord,
            config: TraceConfig { hw, fleet, fault_plan, tenants },
            events: Vec::new(),
            t0: Instant::now(),
            last_arrival: 0.0,
            faults_seen: 0,
            decisions_seen: 0,
        }
    }

    /// The one place wall-clock time becomes virtual time.
    fn stamp(&mut self) -> f64 {
        let t = self.t0.elapsed().as_secs_f64().max(self.last_arrival);
        self.last_arrival = t;
        t
    }

    /// Reject requests the coordinator would panic on, *before* they
    /// are recorded or admitted.
    fn validate(rq: &Request) -> Result<()> {
        match &rq.target {
            Target::FullGraph => Ok(()),
            Target::MiniBatch { targets, .. } => {
                if targets.is_empty() {
                    bail!("mini-batch request has no target vertices");
                }
                if rq.dataset.n_edges > MAX_MATERIALIZE_EDGES {
                    bail!(
                        "dataset {} ({} edges) is too large to sample (max {MAX_MATERIALIZE_EDGES})",
                        rq.dataset.key,
                        rq.dataset.n_edges
                    );
                }
                if let Some(&v) = targets.iter().find(|&&v| v as u64 >= rq.dataset.n_vertices) {
                    bail!(
                        "target vertex {v} is out of range for dataset {} (|V| = {})",
                        rq.dataset.key,
                        rq.dataset.n_vertices
                    );
                }
                Ok(())
            }
            Target::Update { .. } => {
                if rq.dataset.n_edges > MAX_MATERIALIZE_EDGES {
                    bail!(
                        "dataset {} ({} edges) is too large to stream (max {MAX_MATERIALIZE_EDGES})",
                        rq.dataset.key,
                        rq.dataset.n_edges
                    );
                }
                Ok(())
            }
        }
    }

    /// Admit one request: validate, stamp its real arrival onto the
    /// virtual clock, record the stamped event, and run it through the
    /// deterministic coordinator.
    pub fn submit(&mut self, mut rq: Request) -> Result<Response> {
        DaemonSession::validate(&rq)?;
        rq.arrival = self.stamp();
        self.events.push(TraceEvent::Admit(rq.clone()));
        let resp = self.coord.admit(rq);
        self.record_fault_activity();
        Ok(resp)
    }

    /// Splice the fault events fired and decisions taken by the last
    /// admission into the recorded stream, right after their admit
    /// event — replay re-derives the same interleaving from the plan.
    fn record_fault_activity(&mut self) {
        let log = self.coord.fault_log();
        for f in &log[self.faults_seen..] {
            self.events.push(TraceEvent::Fault(f.clone()));
        }
        self.faults_seen = log.len();
        let dec = self.coord.decision_log();
        for d in &dec[self.decisions_seen..] {
            self.events.push(TraceEvent::Decision(*d));
        }
        self.decisions_seen = dec.len();
    }

    /// Current aggregate stats; the query is recorded so the trace
    /// keeps the operational timeline.
    pub fn stats(&mut self) -> ServeStats {
        let at = self.stamp();
        self.events.push(TraceEvent::Stats { at });
        self.coord.stats()
    }

    /// Drain: the virtual-clock fleet accounts every admitted job at
    /// admission, so draining is already done — the event is recorded
    /// as a fence and the final stats are returned.
    pub fn drain(&mut self) -> ServeStats {
        let at = self.stamp();
        self.events.push(TraceEvent::Drain { at });
        self.coord.stats()
    }

    /// Number of events recorded so far (admits + stats/drain fences).
    pub fn events_len(&self) -> usize {
        self.events.len()
    }

    /// Number of responses the coordinator has produced so far.
    pub fn completed(&self) -> usize {
        self.coord.responses.len()
    }

    /// The installed tenant QoS config, if any — what the `tenants`
    /// protocol op reports back. Not recorded as an event: the config
    /// is static and already lives in the trace header.
    pub fn tenants(&self) -> Option<&TenantConfig> {
        self.coord.tenants()
    }

    /// Enable deterministic span tracing on the session's coordinator
    /// (`daemon --chrome-trace`). Off by default — a dormant session
    /// records traces byte-identical to a tracing-free build.
    pub fn enable_tracing(&mut self) {
        self.coord.set_tracing(true);
    }

    /// Chrome trace-event JSON of the spans recorded so far (empty
    /// event array apart from metadata when tracing is off).
    pub fn chrome_trace_json(&self) -> String {
        self.coord.chrome_trace_json()
    }

    /// Prometheus text exposition of the live session — the `metrics`
    /// protocol op. Read-only and deliberately *not* recorded as a
    /// trace event: scraping a daemon mid-run must never change the
    /// recorded byte stream a replay is verified against.
    pub fn metrics(&self) -> String {
        crate::obs::prometheus(&self.coord.stats(), &self.coord.latency_histogram())
    }

    /// Seal the session into a self-contained trace: config, events in
    /// admission order, and the recorded outcomes replay will be
    /// verified against.
    pub fn finalize(self) -> Trace {
        let stats = self.coord.stats();
        let mut t = Trace {
            version: TRACE_VERSION,
            config: self.config,
            events: self.events,
            responses: self.coord.responses,
            stats: Some(stats),
        };
        // Stamp the oldest sufficient version: a fault-free session
        // stays a v1 document, byte-identical to pre-fault recordings.
        t.version = t.min_version();
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::dataset;
    use crate::ir::ZooModel;
    use crate::quant::Precision;

    #[test]
    fn session_stamps_monotone_arrivals_and_records() {
        let mut s = DaemonSession::new(HwConfig::alveo_u250(), FleetConfig::default());
        let co = dataset("CO").unwrap();
        let r1 = s.submit(Request::full(0, ZooModel::B1, co, 0.0)).unwrap();
        let r2 = s
            .submit(
                Request::full(1, ZooModel::B1, co, 0.0).with_precision(Precision::Int8),
            )
            .unwrap();
        assert_eq!(r1.tenant, 0);
        assert_eq!(r2.precision, Precision::Int8);
        let _ = s.stats();
        let st = s.drain();
        assert_eq!(st.completed, 2);
        let trace = s.finalize();
        assert_eq!(trace.events.len(), 4); // 2 admits + stats + drain
        assert_eq!(trace.responses.len(), 2);
        let reqs = trace.requests();
        assert_eq!(reqs.len(), 2);
        // Stamped arrivals are nondecreasing (the admit contract).
        assert!(reqs[1].arrival >= reqs[0].arrival);
    }

    #[test]
    fn rejected_submissions_never_enter_the_trace() {
        let mut s = DaemonSession::new(HwConfig::alveo_u250(), FleetConfig::default());
        let co = dataset("CO").unwrap();
        let re = dataset("RE").unwrap();
        // Empty target list.
        assert!(s
            .submit(Request::minibatch(0, ZooModel::B1, co, vec![], vec![4], 1, 0.0))
            .is_err());
        // Out-of-range vertex.
        assert!(s
            .submit(Request::minibatch(0, ZooModel::B1, co, vec![999_999], vec![4], 1, 0.0))
            .is_err());
        // Unmaterializable dataset for sampling / streaming.
        assert!(s
            .submit(Request::minibatch(0, ZooModel::B1, re, vec![1], vec![4], 1, 0.0))
            .is_err());
        assert!(s.submit(Request::update(0, re, 8, 2, 0, 1, 0.0)).is_err());
        assert_eq!(s.events_len(), 0);
        assert_eq!(s.completed(), 0);
        // A valid one still goes through afterwards.
        assert!(s.submit(Request::full(0, ZooModel::B1, co, 0.0)).is_ok());
        assert_eq!(s.events_len(), 1);
    }

    #[test]
    fn fault_free_sessions_finalize_as_version_1() {
        let mut s = DaemonSession::with_plan(
            HwConfig::alveo_u250(),
            FleetConfig::default(),
            Some(FaultPlan::empty()),
        );
        let co = dataset("CO").unwrap();
        s.submit(Request::full(0, ZooModel::B1, co, 0.0)).unwrap();
        let t = s.finalize();
        assert_eq!(t.version, 1);
        assert!(t.config.fault_plan.is_none());
    }

    #[test]
    fn tenant_sessions_finalize_as_version_3_with_config_and_stats() {
        use crate::serve::{PriorityClass, Tenant};
        let tenants = TenantConfig {
            tenants: vec![
                Tenant { id: 0, weight: 3.0, deadline_s: None, class: PriorityClass::Premium },
                Tenant {
                    id: 1,
                    weight: 1.0,
                    deadline_s: Some(0.05),
                    class: PriorityClass::BestEffort,
                },
            ],
        };
        let mut s = DaemonSession::with_tenants(
            HwConfig::alveo_u250(),
            FleetConfig::default(),
            Some(tenants.clone()),
        );
        assert_eq!(s.tenants(), Some(&tenants));
        let co = dataset("CO").unwrap();
        let r = s.submit(Request::full(0, ZooModel::B1, co, 0.0)).unwrap();
        assert_eq!(r.tenant, 0);
        s.submit(Request::full(1, ZooModel::B1, co, 0.0)).unwrap();
        s.drain();
        let t = s.finalize();
        assert_eq!(t.version, 3);
        assert_eq!(t.config.tenants.as_ref(), Some(&tenants));
        let st = t.stats.as_ref().unwrap();
        assert_eq!(st.tenants.iter().map(|ts| ts.tenant).collect::<Vec<_>>(), vec![0, 1]);
    }

    #[test]
    fn empty_tenant_config_sessions_stay_version_1() {
        let mut s = DaemonSession::with_tenants(
            HwConfig::alveo_u250(),
            FleetConfig::default(),
            Some(TenantConfig::empty()),
        );
        assert!(s.tenants().is_none());
        let co = dataset("CO").unwrap();
        s.submit(Request::full(0, ZooModel::B1, co, 0.0)).unwrap();
        let t = s.finalize();
        assert_eq!(t.version, 1);
        assert!(t.config.tenants.is_none());
    }

    #[test]
    fn faulty_sessions_record_fault_and_decision_events() {
        use crate::serve::{CostModel, FaultEvent};
        let costs = CostModel { deadline_s: 0.0, ..CostModel::default() };
        let fleet = FleetConfig { costs, ..FleetConfig::default() };
        let plan = FaultPlan {
            seed: 1,
            events: vec![FaultEvent::TransientStall { device: 0, at: 0.0, duration: 1e-6 }],
        };
        let mut s = DaemonSession::with_plan(HwConfig::alveo_u250(), fleet, Some(plan));
        let co = dataset("CO").unwrap();
        let r = s.submit(Request::full(0, ZooModel::B1, co, 0.0)).unwrap();
        assert!(r.outcome.is_degraded());
        let t = s.finalize();
        assert_eq!(t.version, 2);
        assert!(t.config.fault_plan.is_some());
        assert!(t.events.iter().any(|e| matches!(e, TraceEvent::Fault(_))));
        assert!(t.events.iter().any(|e| matches!(e, TraceEvent::Decision(_))));
    }
}
