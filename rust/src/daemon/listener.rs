//! The daemon's TCP front end: accept connections on localhost, decode
//! request frames, feed them through the [`DaemonSession`], and reply
//! frame-for-frame. Connections are served sequentially — admission
//! order is the determinism contract, and a single accept loop makes
//! that order the order requests arrived on the wire.

use super::protocol::{err_reply, ok_reply, read_frame, write_frame, ClientMsg};
use super::session::DaemonSession;
use super::trace::{response_json, stats_json, Trace};
use crate::config::HwConfig;
use crate::serve::{FaultPlan, FleetConfig, TenantConfig};
use crate::util::Json;
use anyhow::{Context, Result};
use std::io::{BufReader, BufWriter};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::path::PathBuf;
use std::time::Duration;

/// Default per-connection socket timeout: a client that goes silent
/// mid-frame (or holds an idle connection open without closing it)
/// unblocks the sequential accept loop after this long instead of
/// wedging every client behind it.
pub const DEFAULT_CONN_TIMEOUT: Duration = Duration::from_secs(5);

/// The TCP front end: one listening socket, one recording
/// [`DaemonSession`], one sequential accept loop.
pub struct Daemon {
    listener: TcpListener,
    session: DaemonSession,
    port: u16,
    /// Per-connection read/write timeout (see [`DEFAULT_CONN_TIMEOUT`]).
    conn_timeout: Duration,
    /// Where to export the session's Chrome trace at shutdown, if
    /// anywhere ([`Daemon::set_chrome_trace`]).
    chrome_trace: Option<PathBuf>,
}

impl Daemon {
    /// Bind on `127.0.0.1:port` (`port = 0` picks an ephemeral port —
    /// read it back with [`Daemon::port`]). Localhost-only: the daemon
    /// has no authentication and is a lab tool, not an internet service.
    pub fn bind(port: u16, hw: HwConfig, fleet: FleetConfig) -> Result<Daemon> {
        Daemon::bind_with_config(port, hw, fleet, None, None)
    }

    /// Bind a daemon whose session serves under a fault plan
    /// (`daemon --fault-plan plan.json`). `None` — or an empty plan —
    /// is exactly [`Daemon::bind`].
    pub fn bind_with_plan(
        port: u16,
        hw: HwConfig,
        fleet: FleetConfig,
        plan: Option<FaultPlan>,
    ) -> Result<Daemon> {
        Daemon::bind_with_config(port, hw, fleet, plan, None)
    }

    /// Bind a daemon whose session serves under per-tenant QoS
    /// (`daemon --tenants tenants.json`). `None` — or an empty config —
    /// is exactly [`Daemon::bind`].
    pub fn bind_with_tenants(
        port: u16,
        hw: HwConfig,
        fleet: FleetConfig,
        tenants: Option<TenantConfig>,
    ) -> Result<Daemon> {
        Daemon::bind_with_config(port, hw, fleet, None, tenants)
    }

    /// The general bind behind the named variants. A fault plan and a
    /// tenant config are mutually exclusive (the session's coordinator
    /// panics on the combination; the CLI rejects it earlier).
    pub fn bind_with_config(
        port: u16,
        hw: HwConfig,
        fleet: FleetConfig,
        plan: Option<FaultPlan>,
        tenants: Option<TenantConfig>,
    ) -> Result<Daemon> {
        let listener =
            TcpListener::bind(("127.0.0.1", port)).context("binding daemon listener")?;
        let port = listener.local_addr().context("reading bound address")?.port();
        Ok(Daemon {
            listener,
            session: DaemonSession::with_config(hw, fleet, plan, tenants),
            port,
            conn_timeout: DEFAULT_CONN_TIMEOUT,
            chrome_trace: None,
        })
    }

    /// The bound port (useful after binding port 0).
    pub fn port(&self) -> u16 {
        self.port
    }

    /// Override the per-connection socket timeout (tests shrink it so a
    /// scripted silent client unwedges in milliseconds).
    pub fn set_conn_timeout(&mut self, timeout: Duration) {
        self.conn_timeout = timeout;
    }

    /// Enable span tracing and export the session's Chrome trace to
    /// `path` at shutdown (`daemon --chrome-trace out.json`). Without
    /// this call the session stays dormant and records traces
    /// byte-identical to a tracing-free build.
    pub fn set_chrome_trace(&mut self, path: PathBuf) {
        self.session.enable_tracing();
        self.chrome_trace = Some(path);
    }

    /// Accept and serve connections until a client sends `shutdown`,
    /// then seal and return the recorded trace.
    pub fn serve(mut self) -> Result<Trace> {
        loop {
            let (stream, _peer) = self.listener.accept().context("accepting connection")?;
            if self.handle_conn(stream)? {
                if let Some(path) = &self.chrome_trace {
                    std::fs::write(path, self.session.chrome_trace_json())
                        .with_context(|| format!("writing chrome trace {}", path.display()))?;
                }
                return Ok(self.session.finalize());
            }
        }
    }

    /// Serve one connection's frames; `Ok(true)` means shutdown was
    /// requested.
    fn handle_conn(&mut self, stream: TcpStream) -> Result<bool> {
        // Arm both socket timeouts before the first read: a peer that
        // stops talking mid-frame (or never talks) errors out of the
        // blocking read instead of wedging the accept loop, and a peer
        // that stops *reading* can't park us in a blocked reply write.
        stream
            .set_read_timeout(Some(self.conn_timeout))
            .context("arming connection read timeout")?;
        stream
            .set_write_timeout(Some(self.conn_timeout))
            .context("arming connection write timeout")?;
        let mut reader =
            BufReader::new(stream.try_clone().context("cloning connection handle")?);
        let mut writer = BufWriter::new(stream);
        loop {
            let frame = match read_frame(&mut reader) {
                Ok(Some(f)) => f,
                // Clean EOF: the client is done; wait for the next one.
                Ok(None) => return Ok(false),
                // Torn framing or a timed-out read: the byte stream is
                // unsynchronized, so reply best-effort, tear the
                // connection down, and move on. The session (and its
                // trace) survives.
                Err(e) => {
                    let _ = write_frame(&mut writer, &err_reply(&format!("{e:#}")));
                    let _ = writer.get_ref().shutdown(Shutdown::Both);
                    return Ok(false);
                }
            };
            match ClientMsg::parse(&frame) {
                // A well-framed but invalid message poisons only itself.
                Err(e) => write_frame(&mut writer, &err_reply(&format!("{e:#}")))?,
                Ok(ClientMsg::Submit(rq)) | Ok(ClientMsg::Churn(rq)) => {
                    match self.session.submit(rq) {
                        Ok(resp) => write_frame(
                            &mut writer,
                            &ok_reply(vec![("response", response_json(&resp))]),
                        )?,
                        Err(e) => write_frame(&mut writer, &err_reply(&format!("{e:#}")))?,
                    }
                }
                Ok(ClientMsg::Stats) => {
                    let st = self.session.stats();
                    write_frame(&mut writer, &ok_reply(vec![("stats", stats_json(&st))]))?;
                }
                Ok(ClientMsg::Tenants) => {
                    let t = self.session.tenants().map_or(Json::Null, |t| t.to_json());
                    write_frame(&mut writer, &ok_reply(vec![("tenants", t)]))?;
                }
                Ok(ClientMsg::Metrics) => {
                    // Read-only and unrecorded (see DaemonSession::
                    // metrics): a scrape never perturbs the trace.
                    let text = self.session.metrics();
                    write_frame(&mut writer, &ok_reply(vec![("metrics", Json::Str(text))]))?;
                }
                Ok(ClientMsg::Drain) => {
                    let st = self.session.drain();
                    write_frame(
                        &mut writer,
                        &ok_reply(vec![
                            ("stats", stats_json(&st)),
                            ("completed", Json::Num(st.completed as f64)),
                        ]),
                    )?;
                }
                Ok(ClientMsg::Shutdown) => {
                    write_frame(
                        &mut writer,
                        &ok_reply(vec![(
                            "events",
                            Json::Num(self.session.events_len() as f64),
                        )]),
                    )?;
                    return Ok(true);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::daemon::client::Client;
    use crate::graph::dataset;
    use crate::ir::ZooModel;
    use crate::serve::Request;

    #[test]
    fn daemon_serves_and_records_over_tcp() {
        let d = Daemon::bind(0, HwConfig::alveo_u250(), FleetConfig::default()).unwrap();
        let port = d.port();
        let server = std::thread::spawn(move || d.serve().unwrap());

        let mut c = Client::connect(port).unwrap();
        let co = dataset("CO").unwrap();
        let resp = c.submit(Request::full(0, ZooModel::B1, co, 0.0)).unwrap();
        assert_eq!(resp.tenant, 0);
        // Invalid request: error reply, connection stays usable.
        let err = c
            .submit(Request::minibatch(0, ZooModel::B1, co, vec![], vec![4], 1, 0.0))
            .unwrap_err()
            .to_string();
        assert!(err.contains("no target vertices"), "{err}");
        let st = c.drain().unwrap();
        assert_eq!(st.completed, 1);
        let events = c.shutdown().unwrap();
        assert_eq!(events, 2); // admit + drain; the reject was never recorded

        let trace = server.join().unwrap();
        assert_eq!(trace.requests().len(), 1);
        assert_eq!(trace.responses.len(), 1);
        assert_eq!(trace.stats.as_ref().unwrap().completed, 1);
    }

    #[test]
    fn tenant_daemon_reports_its_config_and_stamps_v3_traces() {
        use crate::serve::{PriorityClass, Tenant};
        let tenants = TenantConfig {
            tenants: vec![
                Tenant { id: 0, weight: 2.0, deadline_s: None, class: PriorityClass::Premium },
                Tenant { id: 1, weight: 1.0, deadline_s: None, class: PriorityClass::Standard },
            ],
        };
        let d = Daemon::bind_with_tenants(
            0,
            HwConfig::alveo_u250(),
            FleetConfig::default(),
            Some(tenants.clone()),
        )
        .unwrap();
        let port = d.port();
        let server = std::thread::spawn(move || d.serve().unwrap());

        let mut c = Client::connect(port).unwrap();
        assert_eq!(c.tenants().unwrap(), Some(tenants.clone()));
        let co = dataset("CO").unwrap();
        c.submit(Request::full(0, ZooModel::B1, co, 0.0)).unwrap();
        c.submit(Request::full(1, ZooModel::B1, co, 0.0)).unwrap();
        let st = c.drain().unwrap();
        assert_eq!(st.tenants.iter().map(|t| t.tenant).collect::<Vec<_>>(), vec![0, 1]);
        c.shutdown().unwrap();

        let trace = server.join().unwrap();
        assert_eq!(trace.version, 3);
        assert_eq!(trace.config.tenants.as_ref(), Some(&tenants));

        // A tenant-blind daemon reports no config over the same op.
        let d = Daemon::bind(0, HwConfig::alveo_u250(), FleetConfig::default()).unwrap();
        let port = d.port();
        let server = std::thread::spawn(move || d.serve().unwrap());
        let mut c = Client::connect(port).unwrap();
        assert_eq!(c.tenants().unwrap(), None);
        c.shutdown().unwrap();
        assert_eq!(server.join().unwrap().version, 1);
    }

    #[test]
    fn torn_and_silent_clients_do_not_wedge_the_accept_loop() {
        use std::io::Write;

        let mut d = Daemon::bind(0, HwConfig::alveo_u250(), FleetConfig::default()).unwrap();
        d.set_conn_timeout(Duration::from_millis(100));
        let port = d.port();
        let server = std::thread::spawn(move || d.serve().unwrap());

        // Client 1: a torn half-frame — the header promises 100 bytes,
        // 3 arrive, then the connection closes. The daemon must report
        // the tear and move on.
        {
            let mut s = TcpStream::connect(("127.0.0.1", port)).unwrap();
            s.write_all(&100u32.to_be_bytes()).unwrap();
            s.write_all(b"abc").unwrap();
        }

        // Client 2: goes silent after the header and holds the
        // connection open — only the read timeout can unwedge this one.
        let mut silent = TcpStream::connect(("127.0.0.1", port)).unwrap();
        silent.write_all(&100u32.to_be_bytes()).unwrap();

        // Client 3: a healthy client behind both is served normally.
        let mut c = Client::connect(port).unwrap();
        let co = dataset("CO").unwrap();
        let resp = c.submit(Request::full(0, ZooModel::B1, co, 0.0)).unwrap();
        assert_eq!(resp.tenant, 0);
        let events = c.shutdown().unwrap();
        assert_eq!(events, 1); // only the healthy admit was recorded

        drop(silent);
        let trace = server.join().unwrap();
        assert_eq!(trace.responses.len(), 1);
    }
}
